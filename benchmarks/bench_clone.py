"""T2 — cloning / snapshotting (paper Fig. 3) + per-buffer COW detach.

clone()    = deep copy (PetGraph/SNAP/cuGraph/our-DiGraph class), now one
             fused async device dispatch per representation;
snapshot() = version handle (Aspen zero-cost / GraphBLAS lazy class);
cow        = the first small in-place update AFTER a snapshot — what the
             per-buffer copy-on-write protocol (DESIGN.md §10) makes
             cheap by detaching only the buffers the update touches.
"""
from __future__ import annotations

import numpy as np

from repro.core import REPRESENTATIONS, edgebatch

from . import common


def _small_batch(c, rng):
    k = max(int(c.m * 1e-3), 1)
    return edgebatch.random_insertions(rng, c.n, k)


def run():
    rows = []
    for gname in ("web_small", "road_small"):
        c = common.make_graph(gname)
        for rep_name, cls in REPRESENTATIONS.items():
            g = cls.from_csr(c)
            rng = np.random.default_rng(7)

            def do_clone():
                g2 = g.clone()
                g2.block_on()

            def do_snap():
                g2 = g.snapshot()
                g2.block_on()

            t_clone = common.timeit(do_clone)
            t_snap = common.timeit(do_snap)

            # first-mutation-after-snapshot vs plain mutation: the gap is
            # the COW detach cost (buffers actually copied).  One fixed
            # batch for every repeat keeps jit shapes and the plan cache
            # warm, so the delta isolates the detach itself.
            batch = _small_batch(c, rng)

            def setup_plain():
                return cls.from_csr(c), batch

            def setup_snapped():
                h = cls.from_csr(c)
                h.snapshot()
                return h, batch

            def do_update(state):
                h, b = state
                h2, _ = h.add_edges(b, inplace=True)
                h2.block_on()

            t_plain = common.timeit_prepared(
                setup_plain, do_update, warmup=2
            )
            t_cow = common.timeit_prepared(setup_snapped, do_update, warmup=2)
            rows.append(
                {
                    "name": f"clone/{gname}/{rep_name}",
                    "us_per_call": round(t_clone * 1e6, 1),
                    "derived": f"snapshot_us={t_snap*1e6:.1f} "
                    f"edges_per_s={c.m/t_clone/1e6:.1f}M "
                    f"snap_speedup={t_clone/max(t_snap,1e-9):.0f}x "
                    f"cow_first_update_us={t_cow*1e6:.1f} "
                    f"plain_update_us={t_plain*1e6:.1f}",
                }
            )
    return common.emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
