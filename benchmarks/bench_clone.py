"""T2 — cloning / snapshotting (paper Fig. 3).

clone() = deep copy (PetGraph/SNAP/cuGraph/our-DiGraph class);
snapshot() = version handle (Aspen zero-cost / GraphBLAS lazy class).
"""
from __future__ import annotations

from repro.core import REPRESENTATIONS

from . import common


def run():
    rows = []
    for gname in ("web_small", "road_small"):
        c = common.make_graph(gname)
        for rep_name, cls in REPRESENTATIONS.items():
            g = cls.from_csr(c)

            def do_clone():
                g2 = g.clone()
                g2.block_on()

            def do_snap():
                g2 = g.snapshot()
                g2.block_on()

            t_clone = common.timeit(do_clone)
            t_snap = common.timeit(do_snap)
            rows.append(
                {
                    "name": f"clone/{gname}/{rep_name}",
                    "us_per_call": round(t_clone * 1e6, 1),
                    "derived": f"snapshot_us={t_snap*1e6:.1f} "
                    f"edges_per_s={c.m/t_clone/1e6:.1f}M "
                    f"snap_speedup={t_clone/max(t_snap,1e-9):.0f}x",
                }
            )
    return common.emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
