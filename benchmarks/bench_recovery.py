"""T7 — durability pipeline cost (DESIGN.md §13).

Four questions, one row each:

* ``checkpoint_save`` / ``restore`` — snapshot latency of the headline
  representation's full canonical state, and the cost of bringing it
  back (``restore_arrays`` + ``from_state_tree``);
* ``replay_L{4,16}`` — recovery time as a function of WAL length: a
  cold :func:`DurableGraph.recover` replays L update batches past the
  last checkpoint through the ordinary ``apply`` path (``ops_per_s`` is
  the replayed-op throughput, and the two L points expose the linear
  dependence smoke-gating cares about);
* ``wal_overhead`` — the WAL-first apply tax on the steady-state stream
  round (the acceptance bound is <15% vs the journal-free stream), plus
  the fused flush→walk ``round_dispatches`` proof re-measured UNDER the
  durability wrapper with no fault armed: journaling and the fallback
  chain must not add a dispatch (smoke.sh gates on both fields);
* ``fallback_engage`` — round latency while the primary backend is
  forced down (injected failures trip the breaker; the chain completes
  the stream via the host floor) — the degraded-mode cost, reported
  rather than gated;
* ``group_commit`` — the §15 group-commit proof: a round's plans land
  as ONE WAL flush (``wal_flushes_per_round``, smoke-gated == 1) with
  the round latency alongside;
* ``sharded_serial_full`` vs ``sharded_parallel_diff`` — the same
  16-round sharded (S=4, local mode) workload recovered two ways: the
  PR 6 pipeline (full-state restore + serial record-by-record replay of
  the whole window) against the §15 engine (differential-chain restore
  + owner-routed parallel replay of only the un-checkpointed suffix).
  Each row reports ``ckpt_restore_ms`` / ``replay_ms`` /
  ``records_replayed``; smoke gates diff+parallel strictly cheaper.

Row names keep the representation token OUT of last position on
purpose: ms-scale checkpoint/recovery latencies on a CFS-throttled
container are too noisy for the 1.3x ``--compare`` perf gate; the
correctness fields gate in smoke.sh instead.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import numpy as np

from repro.core import DiGraph, edgebatch, updates, walk_image
from repro.kernels import fallback
from repro.runtime import durable, faultinject

from . import common

ROUNDS = 8
WALK_STEPS = 4


def _batches(c, frac, rounds, seed=11):
    rng = np.random.default_rng(seed)
    half = max(int(c.m * frac) // 2, 1)
    return [
        (
            edgebatch.random_insertions(rng, c.n, half),
            edgebatch.random_deletions(rng, c, half),
        )
        for _ in range(rounds)
    ]


def _stream_once(g, batches, *, durable_wrap=None):
    """One apply+walk pass; returns wall seconds (jit must be warm)."""
    t0 = time.perf_counter()
    for ins, dele in batches:
        plan = updates.plan_update(inserts=ins, deletes=dele)
        if durable_wrap is not None:
            durable_wrap.apply(plan)
            g = durable_wrap.rep
        else:
            g, _ = g.apply(plan)
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
    return time.perf_counter() - t0


def run(graph: str = "web_small", frac: float = 1e-2):
    c = common.make_graph(graph)
    batches = _batches(c, frac, max(ROUNDS, 16))
    n_ops_per_round = batches[0][0].n + batches[0][1].n
    rows = []
    base = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # warm every jit shape the stream touches (same discipline as
        # bench_stream: compiles must not land in any measured region)
        g = DiGraph.from_csr(c)
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        _stream_once(g, batches[:ROUNDS])

        # -- checkpoint save / restore latency -------------------------
        g = DiGraph.from_csr(c)
        _stream_once(g, batches[:ROUNDS])
        wal, ck = f"{base}/wal", f"{base}/ckpt"
        d = durable.DurableGraph(g, wal, ck)
        t_save = common.timeit(d.checkpoint, warmup=1, repeats=3)
        rows.append(
            {
                "name": f"recovery/{graph}/checkpoint_save",
                "ms_per_call": round(t_save * 1e3, 2),
                "derived": f"edges={d.rep.m} rep=digraph",
            }
        )
        from repro.checkpoint import manager as ckpt_mod

        def _restore():
            arrays, _ = ckpt_mod.restore_arrays(ck)
            for k in ("__meta__/rep", "__meta__/wal_seq", "__meta__/nv_bound"):
                arrays.pop(k)
            DiGraph.from_state_tree(arrays).block_on()

        t_restore = common.timeit(_restore, warmup=1, repeats=3)
        rows.append(
            {
                "name": f"recovery/{graph}/restore",
                "ms_per_call": round(t_restore * 1e3, 2),
                "derived": f"edges={d.rep.m} rep=digraph",
            }
        )
        d.close()

        # -- recovery time vs WAL length -------------------------------
        for wal_len in (4, 16):
            wd, cd = f"{base}/wal{wal_len}", f"{base}/ck{wal_len}"
            dg = durable.DurableGraph(DiGraph.from_csr(c), wd, cd)
            for ins, dele in batches[:wal_len]:
                dg.apply(updates.plan_update(inserts=ins, deletes=dele))
            dg.close()
            t0 = time.perf_counter()
            r = durable.DurableGraph.recover(wd, cd, audit=False)
            r.rep.block_on()
            t_rec = time.perf_counter() - t0
            r.close()
            replayed = wal_len * n_ops_per_round
            rows.append(
                {
                    "name": f"recovery/{graph}/replay_L{wal_len}",
                    "ms_per_call": round(t_rec * 1e3, 2),
                    "derived": f"wal_records={wal_len} "
                    f"ops_per_s={replayed / max(t_rec, 1e-9):.0f} "
                    f"rep=digraph",
                }
            )

        # -- WAL-first apply overhead on the stream round --------------
        # min of two passes each (the throttled container's 2x slow mode
        # must not decide the ratio)
        t_plain = min(
            _stream_once(DiGraph.from_csr(c), batches[:ROUNDS])
            for _ in range(2)
        )
        t_wal = float("inf")
        for _ in range(2):
            wd, cd = tempfile.mkdtemp(dir=base), tempfile.mkdtemp(dir=base)
            dg = durable.DurableGraph(DiGraph.from_csr(c), wd, cd)
            t_wal = min(t_wal, _stream_once(dg.rep, batches[:ROUNDS], durable_wrap=dg))
            # steady-state dispatch proof UNDER the wrapper, no fault armed
            dispatches = []
            for ins, dele in batches[ROUNDS : ROUNDS + 2]:
                dg.apply(updates.plan_update(inserts=ins, deletes=dele))
                d0 = walk_image.stats_snapshot()["dispatches"]
                jax.block_until_ready(dg.rep.reverse_walk(WALK_STEPS))
                dispatches.append(
                    walk_image.stats_snapshot()["dispatches"] - d0
                )
            dg.close()
        overhead = (t_wal - t_plain) / t_plain * 100.0
        rows.append(
            {
                "name": f"recovery/{graph}/wal_overhead",
                "us_per_round": round(t_wal / ROUNDS * 1e6, 1),
                "overhead_pct": round(overhead, 2),
                "round_dispatches": min(dispatches),
                "derived": f"plain_us={t_plain / ROUNDS * 1e6:.1f} "
                f"wal_us={t_wal / ROUNDS * 1e6:.1f} rep=digraph",
            }
        )

        # -- group commit: one WAL flush per round ---------------------
        wd, cd = tempfile.mkdtemp(dir=base), tempfile.mkdtemp(dir=base)
        dg = durable.DurableGraph(DiGraph.from_csr(c), wd, cd)
        round_pairs = [
            (updates.plan_update(inserts=ins), updates.plan_update(deletes=dele))
            for ins, dele in batches[:ROUNDS]
        ]
        dg.apply_group(round_pairs[0])  # warm
        flush_deltas, t0 = [], time.perf_counter()
        for pair in round_pairs[1:]:
            f0 = dg.journal.flushes
            dg.apply_group(pair)
            flush_deltas.append(dg.journal.flushes - f0)
        t_grp = time.perf_counter() - t0
        dg.close()
        rows.append(
            {
                "name": f"recovery/{graph}/group_commit",
                "us_per_round": round(t_grp / len(flush_deltas) * 1e6, 1),
                "wal_flushes_per_round": max(flush_deltas),
                "derived": f"plans_per_round=2 rounds={len(flush_deltas)} "
                f"ungrouped_flushes=2 rep=digraph",
            }
        )

        # -- sharded recovery: PR6 serial-full vs §15 parallel-diff ----
        from repro.core import distributed as dist

        S, L, CKPT_AT = 4, 16, 12
        sh_plans = [
            updates.plan_update(inserts=ins, deletes=dele)
            for ins, dele in batches[:L]
        ]
        warm = dist.shard_csr(c, S)
        for p in sh_plans[:2]:
            warm.apply(p)
        warm.block_on()

        # serial + full: one step-0 full checkpoint, replay the whole window
        wd, cd = tempfile.mkdtemp(dir=base), tempfile.mkdtemp(dir=base)
        dg = durable.DurableGraph(dist.shard_csr(c, S), wd, cd)
        for p in sh_plans:
            dg.apply(p)
        dg.close()
        st_full: dict = {}
        r = durable.DurableGraph.recover(
            wd, cd, parallel=False, audit=False, stats=st_full
        )
        r.rep.block_on()
        r.close()
        t_serial = st_full["restore_s"] + st_full["replay_s"]
        rows.append(
            {
                "name": f"recovery/{graph}/sharded_serial_full",
                "ms_per_call": round(t_serial * 1e3, 2),
                "ckpt_restore_ms": round(st_full["restore_s"] * 1e3, 2),
                "replay_ms": round(st_full["replay_s"] * 1e3, 2),
                "records_replayed": st_full["records"],
                "derived": f"shards={S} mode=serial ckpt=full rep=sharded",
            }
        )

        # parallel + diff: a differential step inside the window bounds
        # replay to the suffix; owner-routed threads drain the shards
        wd, cd = tempfile.mkdtemp(dir=base), tempfile.mkdtemp(dir=base)
        dg = durable.DurableGraph(
            dist.shard_csr(c, S), wd, cd, diff=True, full_every=8
        )
        for i, p in enumerate(sh_plans):
            dg.apply(p)
            if i + 1 == CKPT_AT:
                dg.checkpoint()  # diff step vs the step-0 full base
        dg.close()
        st_diff: dict = {}
        r = durable.DurableGraph.recover(
            wd, cd, parallel=True, diff=True, audit=False, stats=st_diff
        )
        r.rep.block_on()
        r.close()
        t_par = st_diff["restore_s"] + st_diff["replay_s"]
        rows.append(
            {
                "name": f"recovery/{graph}/sharded_parallel_diff",
                "ms_per_call": round(t_par * 1e3, 2),
                "ckpt_restore_ms": round(st_diff["restore_s"] * 1e3, 2),
                "replay_ms": round(st_diff["replay_s"] * 1e3, 2),
                "records_replayed": st_diff["records"],
                "derived": f"shards={S} mode=parallel ckpt=diff "
                f"speedup={t_serial / max(t_par, 1e-9):.2f}x rep=sharded",
            }
        )

        # -- degraded mode: primary backend down, chain completes ------
        fallback.BREAKER.reset()
        g = DiGraph.from_csr(c)
        _stream_once(g, batches[:2])
        faultinject.arm("slot_update.xla", times=10**6)
        faultinject.arm("slot_walk.xla", times=10**6)
        try:
            t_deg = _stream_once(g, batches[2:4])
        finally:
            faultinject.disarm()
            fallback.BREAKER.reset()
        rows.append(
            {
                "name": f"recovery/{graph}/fallback_engage",
                "us_per_round": round(t_deg / 2 * 1e6, 1),
                "derived": f"chain=xla->ref last_used="
                f"{fallback.LAST_USED.get('slot_update')} rep=digraph",
            }
        )
    finally:
        shutil.rmtree(base, ignore_errors=True)
    header = ["name", "ms_per_call", "us_per_round", "overhead_pct",
              "round_dispatches", "ckpt_restore_ms", "replay_ms",
              "records_replayed", "wal_flushes_per_round", "derived"]
    for r in rows:  # heterogeneous rows: blank the columns a row lacks
        for k in header:
            r.setdefault(k, "")
    return common.emit(rows, header)


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "web_small")
