"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV per benchmark.

Usage: PYTHONPATH=src python -m benchmarks.run [--only load|clone|update|traversal|alloc]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    from . import bench_alloc, bench_clone, bench_load, bench_traversal, bench_update

    suites = {
        "load": bench_load.run,          # paper Fig. 2 / Table 1
        "clone": bench_clone.run,        # paper Fig. 3
        "update": bench_update.run,      # paper Figs. 5-8
        "traversal": bench_traversal.run,  # paper Figs. 9-10
        "alloc": bench_alloc.run,        # paper Fig. 11
    }
    t0 = time.time()
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        fn()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
