"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,...`` CSV per benchmark; ``--json PATH``
additionally writes the structured rows (suite -> [row dicts]) so
``BENCH_*.json`` trajectory files can accumulate across PRs.  Writing
MERGES by row name into the existing file: rows this run re-measured
are replaced in place, rows it did not produce (e.g. the normal
representation rows during a ``BENCH_SHARDS_ONLY=1`` sharded append,
or the sharded rows during a normal run) are preserved — a partial
run never drops the rest of the trajectory.

``--compare BASELINE.json`` diffs this run's per-row timing columns
against a checked-in trajectory file (loaded BEFORE ``--json``
overwrites it) and exits non-zero when any ``digraph`` row regresses by
more than ``REGRESSION_FACTOR`` — the smoke-gate guard for the paper's
headline representation.

Usage: PYTHONPATH=src python -m benchmarks.run \
    [--only load|clone|update|traversal|stream|alloc|recovery|serve] \
    [--json PATH] [--compare BASELINE.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

#: A gated row slower than baseline by more than this fails --compare.
REGRESSION_FACTOR = 1.3
#: Row columns holding the comparable per-row timing (first match wins).
_TIME_KEYS = ("us_per_call", "us_per_round", "ms_per_call")
#: Representation rows that gate the traversal/stream suites.  Elsewhere
#: only the paper's headline ``digraph`` rows gate — the other reps'
#: update/load costs are the measured result, not an invariant, but on
#: the walk suites every representation rides the same image engine, so
#: a regression in any of them is an engine regression.
GATED_REPS = ("digraph", "coo", "lazy", "chunked", "vector2d")
FULLY_GATED_SUITES = ("traversal", "stream")


def _row_time(row: dict):
    for k in _TIME_KEYS:
        if k in row:
            try:
                return float(row[k])
            except (TypeError, ValueError):
                return None
    return None


def compare_results(
    results: dict, baseline: dict, *, factor: float = REGRESSION_FACTOR
) -> list[str]:
    """Diff per-row timings vs a baseline; return regression messages.

    Rows are matched by their ``name`` field across all suites present
    in BOTH runs.  On the traversal and stream suites every one of the
    five representations' rows gates the run (all five ride the shared
    walk-image engine); on the other suites only the rows whose
    representation component (the last ``/``-separated token) is exactly
    ``digraph`` gate — the comparison ratios of the other
    representations there are the measured result, not an invariant.
    ``digraph_flat`` is the seed baseline row, never gated.
    """
    base_rows = {
        r["name"]: r
        for rows in baseline.values()
        if isinstance(rows, list)
        for r in rows
        if isinstance(r, dict) and "name" in r
    }
    failures: list[str] = []
    for suite, rows in results.items():
        for row in rows:
            name = row.get("name")
            old = base_rows.get(name)
            if old is None:
                continue
            t_new, t_old = _row_time(row), _row_time(old)
            if t_new is None or t_old is None or t_old <= 0:
                continue
            ratio = t_new / t_old
            rep = name.rsplit("/", 1)[-1]
            gate = rep == "digraph" or (
                suite in FULLY_GATED_SUITES and rep in GATED_REPS
            )
            tag = "FAIL" if gate and ratio > factor else "ok"
            print(
                f"# compare {tag}: {name} {t_old:.1f} -> {t_new:.1f} "
                f"({ratio:.2f}x)",
                file=sys.stderr,
            )
            if gate and ratio > factor:
                failures.append(
                    f"{name}: {t_old:.1f} -> {t_new:.1f} ({ratio:.2f}x > "
                    f"{factor}x)"
                )
    return failures


def merge_results(prev: dict, new: dict) -> dict:
    """Merge this run's rows into an existing trajectory file by name.

    Suites absent from ``new`` pass through untouched; within a suite
    present in both, rows keep the existing file's order, re-measured
    rows (matched on ``name``) are replaced in place, and rows new to
    this run append at the end.
    """
    out = dict(prev)
    for suite, rows in new.items():
        old = out.get(suite)
        if not isinstance(old, list):
            out[suite] = rows
            continue
        index = {
            r.get("name"): i
            for i, r in enumerate(old)
            if isinstance(r, dict) and "name" in r
        }
        merged = list(old)
        for r in rows:
            i = index.get(r.get("name") if isinstance(r, dict) else None)
            if i is None:
                merged.append(r)
            else:
                merged[i] = r
        out[suite] = merged
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write results as JSON: {suite: [row, ...]}",
    )
    ap.add_argument(
        "--compare", default=None, metavar="BASELINE",
        help="diff per-row timings against a BENCH_*.json baseline and "
        f"fail on >{REGRESSION_FACTOR}x regression of any digraph row",
    )
    args = ap.parse_args()
    from . import (
        bench_alloc,
        bench_clone,
        bench_load,
        bench_recovery,
        bench_serve,
        bench_stream,
        bench_traversal,
        bench_update,
    )

    suites = {
        "load": bench_load.run,          # paper Fig. 2 / Table 1
        "clone": bench_clone.run,        # paper Fig. 3
        "update": bench_update.run,      # paper Figs. 5-8
        "traversal": bench_traversal.run,  # paper Figs. 9-10
        "stream": bench_stream.run,      # paper Figs. 9-10, interleaved
        "alloc": bench_alloc.run,        # paper Fig. 11
        "recovery": bench_recovery.run,  # durability pipeline (§13)
        "serve": bench_serve.run,        # multi-tenant serving (§16)
    }
    if args.only and args.only not in suites:
        ap.error(f"unknown suite {args.only!r}; choose from {sorted(suites)}")
    if args.json:
        # fail fast on an unwritable --json path before burning suite time,
        # without truncating an existing trajectory file mid-failure
        with open(args.json, "a"):
            pass
    baseline = None
    if args.compare:
        # load the baseline up front: --json may overwrite the same file.
        # A missing/empty baseline (fresh checkout — note the --json
        # writability touch above may have just created a 0-byte file)
        # skips the gate instead of crashing: the first run seeds it.
        try:
            with open(args.compare) as fh:
                baseline = json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError):
            print(
                f"# no usable baseline at {args.compare}; skipping compare",
                file=sys.stderr,
            )

    t0 = time.time()
    results: dict[str, list] = {}
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        results[name] = fn()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    # compare BEFORE --json may overwrite the same file: a failed gate
    # must leave the checked-in baseline intact, or the next run would
    # silently ratchet the regression in by comparing against it.
    failures: list[str] = []
    if baseline is not None:
        failures = compare_results(results, baseline)
    if args.json:
        if failures:
            print(
                f"# regression: NOT updating {args.json}", file=sys.stderr
            )
        else:
            try:
                with open(args.json) as fh:
                    prev = json.load(fh)
                if not isinstance(prev, dict):
                    prev = {}
            except (FileNotFoundError, json.JSONDecodeError):
                prev = {}  # fresh (or 0-byte touched) file: nothing to keep
            with open(args.json, "w") as fh:
                json.dump(merge_results(prev, results), fh, indent=1,
                          default=str)
            print(f"# wrote {args.json} (merged by row name)",
                  file=sys.stderr)
    if baseline is not None:
        if failures:
            print(
                "# REGRESSION vs " + args.compare + ":\n#   "
                + "\n#   ".join(failures),
                file=sys.stderr,
            )
            sys.exit(1)
        print(f"# compare vs {args.compare}: ok", file=sys.stderr)


if __name__ == "__main__":
    main()
