"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,...`` CSV per benchmark; ``--json PATH``
additionally writes the structured rows (suite -> [row dicts]) so
``BENCH_*.json`` trajectory files can accumulate across PRs.

Usage: PYTHONPATH=src python -m benchmarks.run \
    [--only load|clone|update|traversal|stream|alloc] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write results as JSON: {suite: [row, ...]}",
    )
    args = ap.parse_args()
    from . import (
        bench_alloc,
        bench_clone,
        bench_load,
        bench_stream,
        bench_traversal,
        bench_update,
    )

    suites = {
        "load": bench_load.run,          # paper Fig. 2 / Table 1
        "clone": bench_clone.run,        # paper Fig. 3
        "update": bench_update.run,      # paper Figs. 5-8
        "traversal": bench_traversal.run,  # paper Figs. 9-10
        "stream": bench_stream.run,      # paper Figs. 9-10, interleaved
        "alloc": bench_alloc.run,        # paper Fig. 11
    }
    if args.only and args.only not in suites:
        ap.error(f"unknown suite {args.only!r}; choose from {sorted(suites)}")
    if args.json:
        # fail fast on an unwritable --json path before burning suite time,
        # without truncating an existing trajectory file mid-failure
        with open(args.json, "a"):
            pass

    t0 = time.time()
    results: dict[str, list] = {}
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        results[name] = fn()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=1, default=str)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
