"""T4 — 42-step reverse walks on updated graphs (paper Figs. 9/10),
plus the beyond-paper MXU path (BSR SpMM reverse walk, interpret-validated
on CPU; its roofline terms live in the dry-run tables).

Every representation now walks through the universal walk-image layer
(DESIGN.md §11), so the table compares image layouts, not engines.  For
DiGraph two rows are emitted per update kind: the seed full-capacity
gather+segment_sum path (``digraph_flat``) and the walk-image engine
(``digraph``) — their ratio is the headline of the slot_walk PR.
``occupancy`` records each representation's live-fraction (live edges /
allocated image slots) read off its walk image, making the paper's
occupancy story comparable across the whole table.  All rows warm
uniformly through ``common.timeit_prepared`` (jit compilation and the
one-time image build land in the untimed warmup for every
representation, not just digraph).
"""
from __future__ import annotations

import numpy as np

from repro.core import REPRESENTATIONS, edgebatch, traversal

from . import common

STEPS = 42


def run(graph: str = "social_small"):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    rows = []
    for kind in ("delete", "insert"):
        frac = 1e-2
        count = max(int(c.m * frac), 1)
        batch = (
            edgebatch.random_insertions(rng, c.n, count)
            if kind == "insert"
            else edgebatch.random_deletions(rng, c, count)
        )
        for rep_name, cls in REPRESENTATIONS.items():
            g = cls.from_csr(c)
            g, _ = (
                g.add_edges(batch) if kind == "insert" else g.remove_edges(batch)
            )
            m_now = g.to_csr().m

            if rep_name == "digraph":
                # seed baseline first (before reverse_walk may compact):
                # full-CAP_E gather+segment_sum, no prefix bound.
                nv = g.n_max_vertex() + 1
                occ0 = f"{g.live_fraction:.3f}"

                def walk_flat(_):
                    v = traversal.reverse_walk_flat(
                        g.dst, g.slot_rows, STEPS, nv
                    )
                    np.asarray(v)

                t_flat = common.timeit_prepared(
                    lambda: None, walk_flat, repeats=5, reduce="min"
                )
                rows.append(
                    {
                        "name": f"walk{STEPS}/{kind}/{graph}/digraph_flat",
                        "us_per_call": round(t_flat * 1e6, 1),
                        "occupancy": occ0,
                        "derived": f"edge_steps_per_s={m_now*STEPS/t_flat/1e6:.1f}M",
                    }
                )

            def walk(_):
                np.asarray(g.reverse_walk(STEPS))

            # uniform warmup: the untimed pass builds the walk image and
            # compiles the step programs for EVERY representation.  The
            # min-of-5 estimator keeps the --compare gate stable against
            # the container's bimodal CPU throttling.
            t = common.timeit_prepared(
                lambda: None, walk, repeats=5, reduce="min"
            )
            occ = f"{g.walk_occupancy():.3f}"
            rows.append(
                {
                    "name": f"walk{STEPS}/{kind}/{graph}/{rep_name}",
                    "us_per_call": round(t * 1e6, 1),
                    "occupancy": occ,
                    "derived": f"edge_steps_per_s={m_now*STEPS/t/1e6:.1f}M",
                }
            )
    return common.emit(rows, ["name", "us_per_call", "occupancy", "derived"])


if __name__ == "__main__":
    run()
