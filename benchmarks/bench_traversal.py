"""T4 — 42-step reverse walks on updated graphs (paper Figs. 9/10),
plus the beyond-paper MXU path (BSR SpMM reverse walk, interpret-validated
on CPU; its roofline terms live in the dry-run tables).

Every representation now walks through the universal walk-image layer
(DESIGN.md §11), so the table compares image layouts, not engines.  For
DiGraph two rows are emitted per update kind: the seed full-capacity
gather+segment_sum path (``digraph_flat``) and the walk-image engine
(``digraph``) — their ratio is the headline of the slot_walk PR.
``occupancy`` records each representation's live-fraction (live edges /
allocated image slots) read off its walk image, making the paper's
occupancy story comparable across the whole table.  All rows warm
uniformly through ``common.timeit_prepared`` (jit compilation and the
one-time image build land in the untimed warmup for every
representation, not just digraph).

``BENCH_SHARDS=N`` appends the multi-device rows (DESIGN.md §14): the
same updated graph walked through ``ShardedGraph`` at shards=1 and
shards=N per layout, with the jaxpr-measured ``collective_bytes_per_
step`` proof field on shard_map rows.  ``BENCH_SHARDS_ONLY=1`` emits
only those rows (smoke.sh merges them into the trajectory via --json).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import REPRESENTATIONS, edgebatch, traversal

from . import common

STEPS = 42


def _sharded_rows(c, graph: str, kind: str, plan, n_sh: int):
    """shards={1,N} walk rows on the post-update graph (DESIGN.md §14)."""
    import jax

    from repro.core import distributed as dist
    from repro.kernels.slot_walk import sharded as sw
    from repro.launch import mesh as mesh_mod

    rows = []
    for layout, dense in (("digraph", False), ("chunked", True)):
        for S in sorted({1, n_sh}):
            mesh = (
                mesh_mod.host_mesh(S)
                if S > 1 and len(jax.devices()) >= S
                else None
            )
            mode = "shmap" if mesh is not None else "local"
            g = dist.shard_csr(c, S, mesh=mesh, dense=dense)
            g.apply(plan)
            m_now = g.m

            def walk(_):
                np.asarray(g.reverse_walk(STEPS))

            t = common.timeit_prepared(
                lambda: None, walk, repeats=5, reduce="min"
            )
            coll = g.collective_bytes_per_step(STEPS)
            model = sw.model_bytes_per_step(g.n_shards, g.rows_max, 0)
            occ = g.m / (g.n_shards * g.cap_e)
            rows.append(
                {
                    "name": f"walk{STEPS}/{kind}/{graph}/shards{S}/{layout}",
                    "us_per_call": round(t * 1e6, 1),
                    "occupancy": f"{occ:.3f}",
                    "mode": mode,
                    "collective_bytes_per_step": int(coll),
                    "model_bytes_per_step": int(model),
                    "frontier_bound_bytes": int(1.5 * c.n * 4),
                    "derived": f"mode={mode} "
                    f"edge_steps_per_s={m_now*STEPS/t/1e6:.1f}M "
                    f"nv={c.n} rows_max={g.rows_max} dense={int(g.dense)}",
                }
            )
    return rows


def run(graph: str = "social_small"):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    n_sh = int(os.environ.get("BENCH_SHARDS", "0") or "0")
    only_shards = os.environ.get("BENCH_SHARDS_ONLY", "") not in ("", "0")
    rows = []
    for kind in ("delete", "insert"):
        frac = 1e-2
        count = max(int(c.m * frac), 1)
        batch = (
            edgebatch.random_insertions(rng, c.n, count)
            if kind == "insert"
            else edgebatch.random_deletions(rng, c, count)
        )
        if n_sh > 0:
            from repro.core import updates

            plan = (
                updates.plan_update(inserts=batch)
                if kind == "insert"
                else updates.plan_update(deletes=batch)
            )
            rows.extend(_sharded_rows(c, graph, kind, plan, n_sh))
        reps = {} if only_shards else REPRESENTATIONS
        for rep_name, cls in reps.items():
            g = cls.from_csr(c)
            g, _ = (
                g.add_edges(batch) if kind == "insert" else g.remove_edges(batch)
            )
            m_now = g.to_csr().m

            if rep_name == "digraph":
                # seed baseline first (before reverse_walk may compact):
                # full-CAP_E gather+segment_sum, no prefix bound.
                nv = g.n_max_vertex() + 1
                occ0 = f"{g.live_fraction:.3f}"

                def walk_flat(_):
                    v = traversal.reverse_walk_flat(
                        g.dst, g.slot_rows, STEPS, nv
                    )
                    np.asarray(v)

                t_flat = common.timeit_prepared(
                    lambda: None, walk_flat, repeats=5, reduce="min"
                )
                rows.append(
                    {
                        "name": f"walk{STEPS}/{kind}/{graph}/digraph_flat",
                        "us_per_call": round(t_flat * 1e6, 1),
                        "occupancy": occ0,
                        "derived": f"edge_steps_per_s={m_now*STEPS/t_flat/1e6:.1f}M",
                    }
                )

            def walk(_):
                np.asarray(g.reverse_walk(STEPS))

            # uniform warmup: the untimed pass builds the walk image and
            # compiles the step programs for EVERY representation.  The
            # min-of-5 estimator keeps the --compare gate stable against
            # the container's bimodal CPU throttling.
            t = common.timeit_prepared(
                lambda: None, walk, repeats=5, reduce="min"
            )
            occ = f"{g.walk_occupancy():.3f}"
            rows.append(
                {
                    "name": f"walk{STEPS}/{kind}/{graph}/{rep_name}",
                    "us_per_call": round(t * 1e6, 1),
                    "occupancy": occ,
                    "derived": f"edge_steps_per_s={m_now*STEPS/t/1e6:.1f}M",
                }
            )
    return common.emit(rows, ["name", "us_per_call", "occupancy", "derived"])


if __name__ == "__main__":
    run()
