"""T4 — 42-step reverse walks on updated graphs (paper Figs. 9/10),
plus the beyond-paper MXU path (BSR SpMM reverse walk, interpret-validated
on CPU; its roofline terms live in the dry-run tables)."""
from __future__ import annotations

import numpy as np

from repro.core import REPRESENTATIONS, edgebatch

from . import common

STEPS = 42


def run(graph: str = "social_small"):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    rows = []
    for kind in ("delete", "insert"):
        frac = 1e-2
        count = max(int(c.m * frac), 1)
        batch = (
            edgebatch.random_insertions(rng, c.n, count)
            if kind == "insert"
            else edgebatch.random_deletions(rng, c, count)
        )
        for rep_name, cls in REPRESENTATIONS.items():
            g = cls.from_csr(c)
            g, _ = (
                g.add_edges(batch) if kind == "insert" else g.remove_edges(batch)
            )

            def walk():
                v = g.reverse_walk(STEPS)
                np.asarray(v)

            t = common.timeit(walk, repeats=3)
            m_now = g.to_csr().m
            rows.append(
                {
                    "name": f"walk{STEPS}/{kind}/{graph}/{rep_name}",
                    "us_per_call": round(t * 1e6, 1),
                    "derived": f"edge_steps_per_s={m_now*STEPS/t/1e6:.1f}M",
                }
            )
    return common.emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
