"""Serve — multi-tenant walk serving under mixed update/walk traffic
(DESIGN.md §16).

Drives the ``runtime.serve`` WalkServer against the paper's web graph at
two load levels plus one fault-injected row, reporting walk latency
percentiles and the robustness proof fields smoke.sh gates on:

* ``steady`` — paced submission the server keeps up with: the latency
  row readers see when the queue never saturates;
* ``overload`` — open-loop submission far above capacity with a bounded
  queue and per-request deadlines: admission control must shed/reject
  the excess (``shed_count`` > 0) while everything admitted still
  resolves;
* ``fault`` — the requested pallas walk backend is killed mid-traffic:
  the breaker chain must complete the run via xla/ref
  (``breaker_fallbacks`` >= 1) with ZERO lost requests.

Proof fields on every row: ``torn_reads`` (served walks that match no
sealed generation — must be 0: the snapshot-isolation contract,
verified against the host per-generation oracle on a sampled subset),
``lost`` (admitted requests that neither served nor rejected — must be
0), ``shed_count``, ``breaker_fallbacks``.

Latency percentiles are deliberately NOT published under a
``--compare``-gated column name: on the CFS-throttled container p99
under load is a coin flip between throttle modes, and the gate would
flap.  The robustness proof fields are the invariant; the percentiles
are the measured result.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import REPRESENTATIONS
from repro.kernels import fallback
from repro.launch import serve as launch_serve
from repro.runtime import faultinject
from repro.runtime import serve as serve_mod

from . import common

STEPS = 4
UPDATE_EVERY = 10
UPDATE_SIZE = 256
VERIFY_SAMPLE = 0.25

#: (load level, traffic + server knobs)
LOADS = {
    "steady": dict(
        requests=240, submit_gap_s=0.002, timeout=None,
        max_queue=256, batch_max=16,
    ),
    "overload": dict(
        requests=480, submit_gap_s=0.0, timeout=0.25,
        max_queue=32, batch_max=16,
    ),
}


def _serve_row(c, graph, level, *, walk_backend="auto", fault_point=None,
               requests, submit_gap_s, timeout, max_queue, batch_max):
    rep = REPRESENTATIONS["digraph"].from_csr(c)
    fallback.BREAKER.reset()
    srv = serve_mod.WalkServer(
        rep, max_queue=max_queue, batch_max=batch_max,
        default_timeout=timeout, walk_backend=walk_backend,
    ).start()
    # warm the [B, V] walk shapes AND the update patch programs outside
    # the measured window (compiles on the 1-core container otherwise
    # dominate every percentile)
    from repro.core import edgebatch, updates as upd_mod

    wrng = np.random.default_rng(99)
    warm_upds = []
    for _ in range(3):
        eb = edgebatch.random_insertions(wrng, int(c.n), UPDATE_SIZE)
        plan = upd_mod.plan_update(inserts=eb)
        warm_upds.append((srv.submit_update(plan), plan))
    warm = [srv.submit_walk([1, 2], steps=STEPS) for _ in range(batch_max)]
    for t, _ in warm_upds:
        t.wait(60.0)
    for t in warm:
        t.wait(60.0)
    if fault_point:
        faultinject.arm(fault_point, times=2)
    t0 = time.monotonic()
    walks, upds = launch_serve.run_traffic(
        srv, int(c.n), requests=requests, steps=STEPS,
        update_every=UPDATE_EVERY, update_size=UPDATE_SIZE,
        seed=13, submit_gap_s=submit_gap_s, timeout=timeout,
    )
    for t in walks:
        t.wait(120.0)
    stats = srv.stop()
    wall = time.monotonic() - t0
    if fault_point:
        faultinject.disarm(fault_point)
    fallback.BREAKER.reset()

    walks = warm + walks
    served = [t for t in walks if t.status == serve_mod.SERVED]
    rejected = stats["rejected_backpressure"] + stats["rejected_other"]
    lost = stats["submitted"] - (
        stats["served"] + stats["shed_expired"] + rejected + stats["failed"]
    )
    torn, checked = launch_serve.count_torn_reads(
        launch_serve.GenerationOracle(c), walks, warm_upds + upds,
        sample=VERIFY_SAMPLE, seed=7,
    )
    pct = launch_serve.percentiles([t.latency_s for t in served])
    return {
        "name": f"serve/{graph}/{level}/digraph",
        "p50_ms": round(pct["p50_ms"], 2),
        "p95_ms": round(pct["p95_ms"], 2),
        "p99_ms": round(pct["p99_ms"], 2),
        "served": stats["served"],
        "shed_count": stats["shed_expired"] + rejected,
        "torn_reads": torn,
        "torn_checked": checked,
        "lost": lost,
        "breaker_fallbacks": stats["breaker_fallbacks"],
        "derived": (
            f"submitted={stats['submitted']} "
            f"shed_expired={stats['shed_expired']} rejected={rejected} "
            f"failed={stats['failed']} batches={stats['batches']} "
            f"max_batch={stats['max_batch']} seals={stats['seals']} "
            f"updates={stats['updates_applied']} "
            f"req_per_s={stats['served'] / max(wall, 1e-9):.1f} "
            f"backend={walk_backend} wall_s={wall:.2f}"
        ),
    }


def run(graph: str = "web_small"):
    c = common.make_graph(graph)
    rows = []
    for level, cfg in LOADS.items():
        rows.append(_serve_row(c, graph, level, **cfg))
    # fault row: pallas requested, killed mid-traffic -> breaker chain
    # must complete the run via xla/ref with zero lost requests
    rows.append(
        _serve_row(
            c, graph, "fault", walk_backend="pallas",
            fault_point="slot_walk.pallas",
            requests=120, submit_gap_s=0.0, timeout=None,
            max_queue=256, batch_max=16,
        )
    )
    return common.emit(
        rows,
        ["name", "p50_ms", "p95_ms", "p99_ms", "served", "shed_count",
         "torn_reads", "lost", "breaker_fallbacks", "derived"],
    )


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "web_small")
