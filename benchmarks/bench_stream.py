"""T6 — interleaved streaming workload (paper Figs. 9-10 setting).

Alternating rounds of one *mixed* update batch (half deletions of
existing edges, half uniform-random insertions, applied through the
shared ``apply(UpdatePlan)`` entry point every representation now
exposes) followed by a reverse-walk traversal.  This is the regime the
paper's headline comparison lives in: update cost, traversal cost, and
any deferred image maintenance the traversal triggers (walk-image patch
flush, DiGraph auto-compaction) all land inside the measured rounds.
Each representation replays the identical stream three times — the
first pass compiles every jit shape the sequence touches, then two
fresh-graph passes are measured and the faster one reported (the gated
digraph row must not flap when a pass lands in the container's ~2x slow
throttle mode) — so the steady-state regime is what the table reports,
independent of benchmark order.

Since the walk-image layer (DESIGN.md §11) the walk half of a round
patches the cached image in O(batch) instead of re-materializing a flat
view per walk; the ``img_*`` derived fields prove it: ``img_builds``
counts full image (re)builds across the measured rounds and ``walk2_us``
times a back-to-back second walk whose host image work is zero
(``img_builds2 = img_patches2 = 0``).  Since the fused flush→walk
dispatch (§12) the row additionally records ``round_dispatches`` — the
image-engine device dispatches the walk half of a steady-state round
issues, which must be exactly 1 (the queued plan's patch groups and the
step scan run in the SAME jitted program; smoke.sh gates on it).

``BENCH_SHARDS=N`` adds the multi-device rows (DESIGN.md §14): the same
stream replayed on a ``ShardedGraph`` at shards=1 and shards=N for both
walk-image layouts (``digraph`` = slot layout, ``chunked`` = dense).
Under forced host devices the N-shard row runs the real shard_map
program and publishes its proof fields: ``round_dispatches`` is the
fused slot_update dispatches per TOUCHED DEVICE of a steady-state
routed apply (must be 1), and ``collective_bytes_per_step`` is the
jaxpr-measured per-device frontier exchange, gated against the
``(S-1)·rows_max·4 ≈ |V|·4`` model.  ``BENCH_SHARDS_ONLY=1`` skips the
single-device representation rows (smoke.sh uses it to append the
sharded rows into the same trajectory file via ``--json`` merge).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import REPRESENTATIONS, edgebatch, updates, walk_image

from . import common

ROUNDS = 12
WALK_STEPS = 4


def _sharded_rows(c, graph: str, frac: float, batches, n_sh: int):
    """shards={1,N} stream rows for both layouts (DESIGN.md §14)."""
    from repro.core import distributed as dist
    from repro.kernels.slot_update import ops as su_ops
    from repro.kernels.slot_walk import sharded as sw
    from repro.launch import mesh as mesh_mod

    rows = []
    for layout, dense in (("digraph", False), ("chunked", True)):
        for S in sorted({1, n_sh}):
            # real mesh when the host exposes enough devices (smoke.sh
            # forces 4); otherwise the bit-identical local emulation —
            # recorded in ``mode`` so proof gates only bind shmap rows.
            mesh = (
                mesh_mod.host_mesh(S)
                if S > 1 and len(jax.devices()) >= S
                else None
            )
            mode = "shmap" if mesh is not None else "local"
            # warm pass: compile every jit shape the stream touches
            g = dist.shard_csr(c, S, mesh=mesh, dense=dense)
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            for ins, dele in batches:
                g.apply(updates.plan_update(inserts=ins, deletes=dele))
                jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            # measured: fresh graph, identical replay, best of two passes
            t_upd = t_walk = float("inf")
            for _ in range(2):
                g = dist.shard_csr(c, S, mesh=mesh, dense=dense)
                jax.block_until_ready(g.reverse_walk(WALK_STEPS))
                p_upd = p_walk = 0.0
                for ins, dele in batches:
                    plan = updates.plan_update(inserts=ins, deletes=dele)
                    t0 = time.perf_counter()
                    g.apply(plan)
                    jax.block_until_ready([im.dst for im in g.shards])
                    p_upd += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    jax.block_until_ready(g.reverse_walk(WALK_STEPS))
                    p_walk += time.perf_counter() - t0
                if p_upd + p_walk < t_upd + t_walk:
                    t_upd, t_walk = p_upd, p_walk
            # routed-patch proof: fused slot_update dispatches per touched
            # device over two more rounds.  A legal occasional rebuild
            # round dispatches FEWER than one per routed shard, so the
            # max is the steady-state figure (clean rounds are exactly 1).
            disp = []
            for ins, dele in batches[:2]:
                plan = updates.plan_update(inserts=ins, deletes=dele)
                routed = dist.route_updates(plan, g.n_shards, g.rows_max)
                d0 = su_ops.STATS["dispatches"]
                g.apply(plan)
                delta = su_ops.STATS["dispatches"] - d0
                disp.append(delta / max(len(routed), 1))
            rd = max(disp)
            coll = g.collective_bytes_per_step(WALK_STEPS)
            model = sw.model_bytes_per_step(g.n_shards, g.rows_max, 0)
            per_round = (t_upd + t_walk) / ROUNDS
            rows.append(
                {
                    "name": f"stream/{graph}/f{frac:g}/shards{S}/{layout}",
                    "us_per_round": round(per_round * 1e6, 1),
                    "round_dispatches": int(rd) if rd == int(rd) else rd,
                    "mode": mode,
                    "collective_bytes_per_step": int(coll),
                    "model_bytes_per_step": int(model),
                    "frontier_bound_bytes": int(1.5 * c.n * 4),
                    "derived": f"mode={mode} "
                    f"update_us={t_upd/ROUNDS*1e6:.1f} "
                    f"walk_us={t_walk/ROUNDS*1e6:.1f} "
                    f"nv={c.n} rows_max={g.rows_max} "
                    f"dense={int(g.dense)} rounds={ROUNDS}",
                }
            )
    return rows


def run(graph: str = "web_small", frac: float = 1e-2):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    half = max(int(c.m * frac) // 2, 1)
    # one batch pair per round, shared across representations: the plan
    # cache hands every structure the identical canonical UpdatePlan.
    batches = [
        (
            edgebatch.random_insertions(rng, c.n, half),
            edgebatch.random_deletions(rng, c, half),
        )
        for _ in range(ROUNDS)
    ]
    n_sh = int(os.environ.get("BENCH_SHARDS", "0") or "0")
    only_shards = os.environ.get("BENCH_SHARDS_ONLY", "") not in ("", "0")
    rows = []
    reps = {} if only_shards else REPRESENTATIONS
    for rep_name, cls in reps.items():
        # pass 1 (untimed): replay the whole stream once so every jit
        # shape the sequence will ever touch is compiled — benchmark
        # order no longer decides which representation pays the one-time
        # compiles (the image evolves identically on both passes, so the
        # measured pass hits only warm programs)
        g = cls.from_csr(c)
        g.reverse_walk(WALK_STEPS)
        for ins, dele in batches:
            g, _ = g.apply(updates.plan_update(inserts=ins, deletes=dele))
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        # measured: fresh graph, identical batch replay — best of two
        # passes, since the gated digraph row must not flap when a pass
        # lands in the container's ~2x slow throttle mode (same rationale
        # as the traversal bench's min-of-5)
        t_upd = t_walk = float("inf")
        stats0 = stats1 = None
        for _ in range(2):
            g = cls.from_csr(c)
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            p_upd = p_walk = 0.0
            s0 = walk_image.stats_snapshot()
            for ins, dele in batches:
                plan = updates.plan_update(inserts=ins, deletes=dele)
                t0 = time.perf_counter()
                g, _ = g.apply(plan)
                g.block_on()
                p_upd += time.perf_counter() - t0
                t0 = time.perf_counter()
                jax.block_until_ready(g.reverse_walk(WALK_STEPS))
                p_walk += time.perf_counter() - t0
            if p_upd + p_walk < t_upd + t_walk:
                t_upd, t_walk = p_upd, p_walk
                stats0, stats1 = s0, walk_image.stats_snapshot()
        # back-to-back second walk: must do ZERO host image work
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        stats2a = walk_image.stats_snapshot()
        t0 = time.perf_counter()
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        walk2 = time.perf_counter() - t0
        stats2b = walk_image.stats_snapshot()
        # fused flush→walk proof (DESIGN.md §12): replay two more rounds
        # and count the image-engine device dispatches the walk half of a
        # steady-state round issues — the fused flush→walk path must
        # lower apply-then-walk to ONE dispatch.  min of two rounds, so a
        # scheduled occupancy rebuild landing on a proof round (legal,
        # occasional) doesn't flap the smoke gate.
        dispatches = []
        for ins, dele in batches[:2]:
            plan = updates.plan_update(inserts=ins, deletes=dele)
            g, _ = g.apply(plan)
            g.block_on()
            d0 = walk_image.stats_snapshot()["dispatches"]
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            dispatches.append(walk_image.stats_snapshot()["dispatches"] - d0)
        n_meas = ROUNDS
        per_round = (t_upd + t_walk) / n_meas
        rows.append(
            {
                "name": f"stream/{graph}/f{frac:g}/{rep_name}",
                "us_per_round": round(per_round * 1e6, 1),
                "round_dispatches": min(dispatches),
                "img_builds2": stats2b["builds"] - stats2a["builds"],
                "img_patches2": stats2b["patches"] - stats2a["patches"],
                "derived": f"update_us={t_upd/n_meas*1e6:.1f} "
                f"walk_us={t_walk/n_meas*1e6:.1f} "
                f"walk2_us={walk2*1e6:.1f} "
                f"img_builds={stats1['builds'] - stats0['builds']} "
                f"img_patches={stats1['patches'] - stats0['patches']} "
                f"img_builds2={stats2b['builds'] - stats2a['builds']} "
                f"img_patches2={stats2b['patches'] - stats2a['patches']} "
                f"edges_per_s={2*half/(t_upd/n_meas)/1e6:.2f}M "
                f"rounds={n_meas}",
            }
        )
    if n_sh > 0:
        rows.extend(_sharded_rows(c, graph, frac, batches, n_sh))
    return common.emit(
        rows, ["name", "us_per_round", "round_dispatches", "derived"]
    )


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "web_small")
