"""T6 — interleaved streaming workload (paper Figs. 9-10 setting).

Alternating rounds of one *mixed* update batch (half deletions of
existing edges, half uniform-random insertions, applied through the
shared ``apply(UpdatePlan)`` entry point every representation now
exposes) followed by a reverse-walk traversal.  This is the regime the
paper's headline comparison lives in: update cost, traversal cost, and
any deferred image maintenance the traversal triggers (walk-image patch
flush, DiGraph auto-compaction) all land inside the measured rounds.
Each representation replays the identical stream three times — the
first pass compiles every jit shape the sequence touches, then two
fresh-graph passes are measured and the faster one reported (the gated
digraph row must not flap when a pass lands in the container's ~2x slow
throttle mode) — so the steady-state regime is what the table reports,
independent of benchmark order.

Since the walk-image layer (DESIGN.md §11) the walk half of a round
patches the cached image in O(batch) instead of re-materializing a flat
view per walk; the ``img_*`` derived fields prove it: ``img_builds``
counts full image (re)builds across the measured rounds and ``walk2_us``
times a back-to-back second walk whose host image work is zero
(``img_builds2 = img_patches2 = 0``).  Since the fused flush→walk
dispatch (§12) the row additionally records ``round_dispatches`` — the
image-engine device dispatches the walk half of a steady-state round
issues, which must be exactly 1 (the queued plan's patch groups and the
step scan run in the SAME jitted program; smoke.sh gates on it).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import REPRESENTATIONS, edgebatch, updates, walk_image

from . import common

ROUNDS = 12
WALK_STEPS = 4


def run(graph: str = "web_small", frac: float = 1e-2):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    half = max(int(c.m * frac) // 2, 1)
    # one batch pair per round, shared across representations: the plan
    # cache hands every structure the identical canonical UpdatePlan.
    batches = [
        (
            edgebatch.random_insertions(rng, c.n, half),
            edgebatch.random_deletions(rng, c, half),
        )
        for _ in range(ROUNDS)
    ]
    rows = []
    for rep_name, cls in REPRESENTATIONS.items():
        # pass 1 (untimed): replay the whole stream once so every jit
        # shape the sequence will ever touch is compiled — benchmark
        # order no longer decides which representation pays the one-time
        # compiles (the image evolves identically on both passes, so the
        # measured pass hits only warm programs)
        g = cls.from_csr(c)
        g.reverse_walk(WALK_STEPS)
        for ins, dele in batches:
            g, _ = g.apply(updates.plan_update(inserts=ins, deletes=dele))
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        # measured: fresh graph, identical batch replay — best of two
        # passes, since the gated digraph row must not flap when a pass
        # lands in the container's ~2x slow throttle mode (same rationale
        # as the traversal bench's min-of-5)
        t_upd = t_walk = float("inf")
        stats0 = stats1 = None
        for _ in range(2):
            g = cls.from_csr(c)
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            p_upd = p_walk = 0.0
            s0 = walk_image.stats_snapshot()
            for ins, dele in batches:
                plan = updates.plan_update(inserts=ins, deletes=dele)
                t0 = time.perf_counter()
                g, _ = g.apply(plan)
                g.block_on()
                p_upd += time.perf_counter() - t0
                t0 = time.perf_counter()
                jax.block_until_ready(g.reverse_walk(WALK_STEPS))
                p_walk += time.perf_counter() - t0
            if p_upd + p_walk < t_upd + t_walk:
                t_upd, t_walk = p_upd, p_walk
                stats0, stats1 = s0, walk_image.stats_snapshot()
        # back-to-back second walk: must do ZERO host image work
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        stats2a = walk_image.stats_snapshot()
        t0 = time.perf_counter()
        jax.block_until_ready(g.reverse_walk(WALK_STEPS))
        walk2 = time.perf_counter() - t0
        stats2b = walk_image.stats_snapshot()
        # fused flush→walk proof (DESIGN.md §12): replay two more rounds
        # and count the image-engine device dispatches the walk half of a
        # steady-state round issues — the fused flush→walk path must
        # lower apply-then-walk to ONE dispatch.  min of two rounds, so a
        # scheduled occupancy rebuild landing on a proof round (legal,
        # occasional) doesn't flap the smoke gate.
        dispatches = []
        for ins, dele in batches[:2]:
            plan = updates.plan_update(inserts=ins, deletes=dele)
            g, _ = g.apply(plan)
            g.block_on()
            d0 = walk_image.stats_snapshot()["dispatches"]
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            dispatches.append(walk_image.stats_snapshot()["dispatches"] - d0)
        n_meas = ROUNDS
        per_round = (t_upd + t_walk) / n_meas
        rows.append(
            {
                "name": f"stream/{graph}/f{frac:g}/{rep_name}",
                "us_per_round": round(per_round * 1e6, 1),
                "round_dispatches": min(dispatches),
                "img_builds2": stats2b["builds"] - stats2a["builds"],
                "img_patches2": stats2b["patches"] - stats2a["patches"],
                "derived": f"update_us={t_upd/n_meas*1e6:.1f} "
                f"walk_us={t_walk/n_meas*1e6:.1f} "
                f"walk2_us={walk2*1e6:.1f} "
                f"img_builds={stats1['builds'] - stats0['builds']} "
                f"img_patches={stats1['patches'] - stats0['patches']} "
                f"img_builds2={stats2b['builds'] - stats2a['builds']} "
                f"img_patches2={stats2b['patches'] - stats2a['patches']} "
                f"edges_per_s={2*half/(t_upd/n_meas)/1e6:.2f}M "
                f"rounds={n_meas}",
            }
        )
    return common.emit(
        rows, ["name", "us_per_round", "round_dispatches", "derived"]
    )


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "web_small")
