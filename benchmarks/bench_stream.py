"""T6 — interleaved streaming workload (paper Figs. 9-10 setting).

Alternating rounds of one *mixed* update batch (half deletions of
existing edges, half uniform-random insertions, applied through the
shared ``apply(UpdatePlan)`` entry point every representation now
exposes) followed by a reverse-walk traversal.  This is the regime the
paper's headline comparison lives in: update cost, traversal cost, and
any deferred consolidation the traversal triggers (LazyCSR assemble,
DiGraph auto-compaction) all land inside the measured rounds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import REPRESENTATIONS, edgebatch, updates

from . import common

ROUNDS = 12      # early rounds compile fresh shapes; measure the tail
WARMUP_ROUNDS = 6
WALK_STEPS = 4


def run(graph: str = "web_small", frac: float = 1e-2):
    c = common.make_graph(graph)
    rng = np.random.default_rng(11)
    half = max(int(c.m * frac) // 2, 1)
    # one batch pair per round, shared across representations: the plan
    # cache hands every structure the identical canonical UpdatePlan.
    batches = [
        (
            edgebatch.random_insertions(rng, c.n, half),
            edgebatch.random_deletions(rng, c, half),
        )
        for _ in range(ROUNDS)
    ]
    rows = []
    for rep_name, cls in REPRESENTATIONS.items():
        g = cls.from_csr(c)
        t_upd = t_walk = 0.0
        for i, (ins, dele) in enumerate(batches):
            plan = updates.plan_update(inserts=ins, deletes=dele)
            t0 = time.perf_counter()
            g, _ = g.apply(plan)
            g.block_on()
            du = time.perf_counter() - t0
            t0 = time.perf_counter()
            jax.block_until_ready(g.reverse_walk(WALK_STEPS))
            dw = time.perf_counter() - t0
            if i >= WARMUP_ROUNDS:  # early rounds pay compilation; skip
                t_upd += du
                t_walk += dw
        n_meas = ROUNDS - WARMUP_ROUNDS
        per_round = (t_upd + t_walk) / n_meas
        rows.append(
            {
                "name": f"stream/{graph}/f{frac:g}/{rep_name}",
                "us_per_round": round(per_round * 1e6, 1),
                "derived": f"update_us={t_upd/n_meas*1e6:.1f} "
                f"walk_us={t_walk/n_meas*1e6:.1f} "
                f"edges_per_s={2*half/(t_upd/n_meas)/1e6:.2f}M "
                f"rounds={n_meas}",
            }
        )
    return common.emit(rows, ["name", "us_per_round", "derived"])


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "web_small")
