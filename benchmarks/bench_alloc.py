"""Allocator microbenchmark (paper Fig. 11, §A.1.5) — the CP2AA-analogue
capacity policy vs naive exact-fit growth.

Workload mirrors the paper's: N allocations, N frees, and a mixed loop.
"Allocation" here = requesting a block from the device-arena layout;
"naive" = exact-size blocks (no pow-2 classes, no free-list reuse), which
forces a new slot range for every request — the vector2d behaviour whose
74% alloc share motivates the paper (Fig. 1).
"""
from __future__ import annotations

import numpy as np

from repro.core import alloc, arena

from . import common

N = 1 << 14


def run():
    rng = np.random.default_rng(3)
    sizes = rng.integers(1, 1024, N)
    rows = []

    def cp2aa_cycle():
        lay = arena.ArenaLayout(capacity=1 << 26)
        blocks = []
        for s in sizes:
            c = alloc.edge_capacity(int(s))
            blocks.append((lay.try_alloc(c), c))
        for b, c in blocks:
            lay.free(b, c)
        # mixed phase: reuse hits the free lists (paper Fig. 11c)
        for s in sizes[: N // 2]:
            c = alloc.edge_capacity(int(s))
            b = lay.try_alloc(c)
            lay.free(b, c)
        return lay

    def naive_cycle():
        bump = 0
        blocks = []
        for s in sizes:
            blocks.append((bump, int(s)))
            bump += int(s)
        blocks.clear()
        for s in sizes[: N // 2]:  # no reuse: bump keeps growing
            blocks.append((bump, int(s)))
            bump += int(s)
        return bump

    t_c = common.timeit(cp2aa_cycle, repeats=3)
    t_n = common.timeit(naive_cycle, repeats=3)
    lay = cp2aa_cycle()
    rows.append(
        {
            "name": "alloc/cp2aa_mixed",
            "us_per_call": round(t_c * 1e6, 1),
            "derived": f"reuse_hits={lay.n_reuse} "
            f"pool_slots={lay.bump} naive_us={t_n*1e6:.1f}",
        }
    )
    # fragmentation: pow-2 slack never exceeds 2x
    total_req = int(sum(alloc.edge_capacity(int(s)) for s in sizes))
    total_exact = int(sizes.sum())
    rows.append(
        {
            "name": "alloc/slack_fraction",
            "us_per_call": 0,
            "derived": f"pow2_slack={(total_req-total_exact)/total_exact:.2f} (<1.0 bound)",
        }
    )
    return common.emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
