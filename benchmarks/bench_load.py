"""T1 — graph loading (paper Fig. 2 / Table 1 t_load).

Compares our Alg-3 vectorized MTX loader against a naive line-by-line
parser (the PetGraph/SNAP-class ingestion loop).
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import csr as csr_mod
from repro.io import mtx

from . import common


def naive_load(path: str) -> csr_mod.CSR:
    """Per-line python parse + per-edge append — the strawman loader."""
    src, dst, wgt = [], [], []
    n = 0
    with open(path) as f:
        header = f.readline()
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        rows, cols, nnz = map(int, line.split()[:3])
        n = max(rows, cols)
        for line in f:
            parts = line.split()
            if len(parts) < 2:
                continue
            src.append(int(parts[0]) - 1)
            dst.append(int(parts[1]) - 1)
            wgt.append(float(parts[2]) if len(parts) > 2 else 1.0)
    return csr_mod.from_coo(
        np.array(src), np.array(dst), np.array(wgt), n=n, dedup=False
    )


def run():
    rows = []
    with tempfile.TemporaryDirectory() as td:
        for gname in common.GRAPHS:
            c = common.make_graph(gname)
            p = os.path.join(td, f"{gname}.mtx")
            mtx.write_mtx(p, c)
            t_ours = common.timeit(lambda: mtx.load_mtx(p), repeats=3)
            t_naive = common.timeit(lambda: naive_load(p), warmup=0, repeats=1)
            rows.append(
                {
                    "name": f"load/{gname}",
                    "n": c.n,
                    "m": c.m,
                    "us_per_call": round(t_ours * 1e6, 1),
                    "derived": f"ours={c.m/t_ours/1e6:.2f}Medges/s "
                    f"naive={c.m/t_naive/1e6:.2f}Medges/s "
                    f"speedup={t_naive/t_ours:.1f}x",
                }
            )
    return common.emit(rows, ["name", "n", "m", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
