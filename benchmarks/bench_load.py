"""T1 — graph loading (paper Fig. 2 / Table 1 t_load).

Rows per graph (each variant timed in its own consecutive block, the
loader's steady-state; see _timeit_each):
  load/<g>          — the device-resident ingest engine (DESIGN.md §10)
  load/<g>/digraph  — same, continued into the DiGraph arena image
  load/<g>/seed     — SEED BASELINE: the pre-ingest-engine loader kept
                      verbatim below (per-digit numpy cursor passes +
                      host np.lexsort build), on the same file
  load/<g>/naive    — per-line python parse (PetGraph/SNAP-class loop)
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import csr as csr_mod
from repro.io import mtx

from . import common


# ---------------------------------------------------------------------------
# seed baseline — the loader this PR replaced, kept for the perf trajectory
# ---------------------------------------------------------------------------
def _seed_parse_fields(data, line_starts, n_fields):
    """The seed's vectorized-per-digit parser (verbatim behaviour)."""
    n = line_starts.shape[0]
    cur = line_starts.copy()
    out = []
    size = data.shape[0]
    for f in range(n_fields):
        for _ in range(4):
            c = data[np.minimum(cur, size - 1)]
            isdig = (c >= 48) & (c <= 57) | (c == 45) | (c == 46)
            cur = np.where(~isdig & (cur < size), cur + 1, cur)
            if isdig.all():
                break
        neg = data[np.minimum(cur, size - 1)] == 45
        cur = np.where(neg, cur + 1, cur)
        if f < 2:
            val = np.zeros(n, np.int64)
            active = np.ones(n, bool)
            for _ in range(12):
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                val = np.where(isdig, val * 10 + (c - 48), val)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            out.append(np.where(neg, -val, val))
        else:
            ival = np.zeros(n, np.float64)
            active = np.ones(n, bool)
            for _ in range(12):
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                ival = np.where(isdig, ival * 10 + (c - 48), ival)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            has_dot = data[np.minimum(cur, size - 1)] == 46
            cur = np.where(has_dot, cur + 1, cur)
            frac = np.zeros(n, np.float64)
            scale = np.ones(n, np.float64)
            active = has_dot.copy()
            for _ in range(9):
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                frac = np.where(isdig, frac * 10 + (c - 48), frac)
                scale = np.where(isdig, scale * 10, scale)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            has_e = np.isin(data[np.minimum(cur, size - 1)], (101, 69))
            if has_e.any():
                cur = np.where(has_e, cur + 1, cur)
                esign = data[np.minimum(cur, size - 1)] == 45
                cur = np.where(
                    has_e
                    & (esign | (data[np.minimum(cur, size - 1)] == 43)),
                    cur + 1,
                    cur,
                )
                ev = np.zeros(n, np.int64)
                active = has_e.copy()
                for _ in range(3):
                    c = data[np.minimum(cur, size - 1)]
                    isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                    ev = np.where(isdig, ev * 10 + (c - 48), ev)
                    cur = np.where(isdig, cur + 1, cur)
                    active &= isdig
                val = (ival + frac / scale) * np.power(
                    10.0, np.where(esign, -ev, ev)
                )
            else:
                val = ival + frac / scale
            out.append(np.where(neg, -val, val))
    return out


def seed_load(path: str) -> csr_mod.CSR:
    """The seed load_mtx: cursor parse + host np.lexsort CSR build."""
    import jax.numpy as jnp

    with open(path, "rb") as f:
        buf = f.read()
    header = mtx.read_header(buf)
    data = np.frombuffer(buf, dtype=np.uint8)
    body = data[header.header_end :]
    nl = np.flatnonzero(body == 10)
    line_starts = np.concatenate([[0], nl + 1]).astype(np.int64)
    line_starts = line_starts[line_starts < body.shape[0]]
    if line_starts.shape[0] > header.nnz:
        line_starts = line_starts[: header.nnz]
    n_fields = 3 if header.weighted else 2
    fields = _seed_parse_fields(body, line_starts, n_fields)
    src = fields[0] - 1
    dst = fields[1] - 1
    wgt = fields[2].astype(np.float32) if header.weighted else None
    if header.symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if wgt is not None:
            wgt = np.concatenate([wgt, wgt])
    n = max(header.rows, header.cols)
    # seed from_coo: partitioned bincount degrees + np.lexsort placement
    degrees = np.zeros(n, dtype=np.int64)
    bounds = np.linspace(0, src.shape[0], 5).astype(np.int64)
    for p in range(4):
        degrees += np.bincount(src[bounds[p] : bounds[p + 1]], minlength=n)
    order = np.lexsort((dst, src))
    src_s, dst_s = src[order], dst[order]
    w_s = wgt[order] if wgt is not None else None
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(degrees, out=offsets[1:])
    return csr_mod.CSR(
        offsets=jnp.asarray(offsets, jnp.int32),
        dst=jnp.asarray(dst_s, jnp.int32),
        wgt=jnp.asarray(w_s, jnp.float32) if w_s is not None else None,
        n=int(n),
        m=int(dst_s.shape[0]),
    )


def naive_load(path: str) -> csr_mod.CSR:
    """Per-line python parse + per-edge append — the strawman loader."""
    src, dst, wgt = [], [], []
    n = 0
    with open(path) as f:
        f.readline()  # banner
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        rows, cols, nnz = map(int, line.split()[:3])
        n = max(rows, cols)
        for line in f:
            parts = line.split()
            if len(parts) < 2:
                continue
            src.append(int(parts[0]) - 1)
            dst.append(int(parts[1]) - 1)
            wgt.append(float(parts[2]) if len(parts) > 2 else 1.0)
    return csr_mod.from_coo(
        np.array(src), np.array(dst), np.array(wgt), n=n, dedup=False
    )


def _timeit_each(fns: dict, *, warmup: int = 1, repeats: int = 7):
    """Median seconds per variant, each timed in its own consecutive
    block (the loader's steady-state: real ingest loads files
    back-to-back, so scratch/cache reuse is part of the measured
    design, exactly as the seed bench measured the seed loader).  GC is
    paused around every timed block — collection pauses otherwise land
    on whichever variant happens to trip the threshold."""
    import gc

    out = {}
    for k, fn in fns.items():
        for _ in range(warmup):
            fn()
        times = []
        gc.collect()
        gc.disable()
        try:
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn()
                times.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        out[k] = float(np.median(times))
    return out


def run():
    rows = []
    with tempfile.TemporaryDirectory() as td:
        for gname in common.GRAPHS:
            c = common.make_graph(gname)
            p = os.path.join(td, f"{gname}.mtx")
            mtx.write_mtx(p, c)
            t = _timeit_each(
                {
                    "ours": lambda: mtx.load_mtx(p).dst.block_until_ready(),
                    "seed": lambda: seed_load(p).dst.block_until_ready(),
                    "digraph": lambda: mtx.load_digraph(p).block_on(),
                }
            )
            t_naive = common.timeit(lambda: naive_load(p), warmup=0, repeats=1)
            speedup = t["seed"] / t["ours"]
            rows.append(
                {
                    "name": f"load/{gname}",
                    "n": c.n,
                    "m": c.m,
                    "us_per_call": round(t["ours"] * 1e6, 1),
                    "derived": f"ours={c.m/t['ours']/1e6:.2f}Medges/s "
                    f"speedup_vs_seed={speedup:.1f}x "
                    f"speedup_vs_naive={t_naive/t['ours']:.1f}x",
                }
            )
            rows.append(
                {
                    "name": f"load/{gname}/digraph",
                    "n": c.n,
                    "m": c.m,
                    "us_per_call": round(t["digraph"] * 1e6, 1),
                    "derived": f"file->arena {c.m/t['digraph']/1e6:.2f}Medges/s",
                }
            )
            rows.append(
                {
                    "name": f"load/{gname}/seed",
                    "n": c.n,
                    "m": c.m,
                    "us_per_call": round(t["seed"] * 1e6, 1),
                    "derived": "seed baseline (cursor parse + lexsort)",
                }
            )
            rows.append(
                {
                    "name": f"load/{gname}/naive",
                    "n": c.n,
                    "m": c.m,
                    "us_per_call": round(t_naive * 1e6, 1),
                    "derived": "python per-line strawman",
                }
            )
    return common.emit(rows, ["name", "n", "m", "us_per_call", "derived"])


if __name__ == "__main__":
    run()
