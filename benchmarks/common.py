"""Benchmark harness utilities: timing, graph/table setup, CSV output.

Absolute times on this 1-core container are not comparable to the paper's
32-core server; the paper's CLAIMS are about *ratios between
representations*, which are preserved (DESIGN.md §8).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import REPRESENTATIONS, from_coo
from repro.io import synthetic

#: container-scale stand-ins for the paper's Table 1 graph families
GRAPHS = {
    "web_small": dict(kind="web", scale=12, edge_factor=8),
    "social_small": dict(kind="social", scale=12, edge_factor=12),
    "road_small": dict(kind="road", scale=14),
    "uniform_small": dict(kind="uniform", scale=12, edge_factor=8),
}

BATCH_FRACTIONS = (1e-4, 1e-3, 1e-2, 1e-1)


def make_graph(name: str):
    return synthetic.make_graph(seed=42, **GRAPHS[name])


def timeit(fn, *, warmup: int = 1, repeats: int = 3) -> float:
    """Median wall seconds; fn must block on its own result."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def timeit_prepared(
    setup, fn, *, warmup: int = 1, repeats: int = 3, reduce: str = "median"
) -> float:
    """Wall seconds of ``fn(setup())`` with ``setup()`` untimed.

    For in-place mutation benchmarks: ``setup`` builds a fresh victim
    (e.g. a clone) outside the timed region, so the measurement contains
    only the operation itself — no clone-cost subtraction heuristics.
    ``reduce`` picks the estimator: ``median`` (default), or ``min`` for
    rows feeding regression gates — on a CFS-throttled container the
    same program alternates between a fast and a ~2x slow mode, and the
    minimum is the reproducible cost while a 3-sample median is a coin
    flip between modes.
    """
    for _ in range(warmup):
        fn(setup())
    times = []
    for _ in range(repeats):
        state = setup()
        t0 = time.perf_counter()
        fn(state)
        times.append(time.perf_counter() - t0)
    return float(np.min(times) if reduce == "min" else np.median(times))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r[h]) for h in header))
    return rows
