"""T3 — batch edge deletions/insertions, in-place and new-instance
(paper Figs. 5-8): batch sizes 1e-4|E| .. 1e-1|E|, uniform random.

In-place timing pre-clones the victim graph *outside* the timed region
(``common.timeit_prepared``), so the reported numbers contain only the
update itself — the seed's negative-time ``clone_dominated`` subtraction
heuristic is gone.
"""
from __future__ import annotations

import numpy as np

from repro.core import REPRESENTATIONS, edgebatch

from . import common


def run(op: str = "both", graph: str = "web_small"):
    c = common.make_graph(graph)
    rng = np.random.default_rng(7)
    rows = []
    ops = ("delete", "insert") if op == "both" else (op,)
    for kind in ops:
        for frac in common.BATCH_FRACTIONS:
            count = max(int(c.m * frac), 1)
            if kind == "insert":
                batch = edgebatch.random_insertions(rng, c.n, count)
            else:
                batch = edgebatch.random_deletions(rng, c, count)
            for rep_name, cls in REPRESENTATIONS.items():
                base = cls.from_csr(c)

                def setup():
                    g = base.clone()
                    g.block_on()
                    return g

                def inplace(g):
                    if kind == "insert":
                        g2, _ = g.add_edges(batch, inplace=True)
                    else:
                        g2, _ = g.remove_edges(batch, inplace=True)
                    g2.block_on()

                def newinst():
                    if kind == "insert":
                        g2, _ = base.add_edges(batch, inplace=False)
                    else:
                        g2, _ = base.remove_edges(batch, inplace=False)
                    g2.block_on()

                t_in = common.timeit_prepared(setup, inplace, repeats=3)
                t_new = common.timeit(newinst, repeats=3)
                rows.append(
                    {
                        "name": f"{kind}/{graph}/f{frac:g}/{rep_name}",
                        "us_per_call": round(t_in * 1e6, 1),
                        "derived": f"newinst_us={t_new*1e6:.1f} "
                        f"edges_per_s={count/t_in/1e6:.2f}M",
                    }
                )
    return common.emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    import sys

    run(sys.argv[1] if len(sys.argv) > 1 else "both")
