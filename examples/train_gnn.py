"""End-to-end driver (deliverable b): train a ~100M-param GraphCast-style
mesh GNN for a few hundred steps on synthetic weather-like data, with
checkpointing + simulated failure + restart mid-run.

  PYTHONPATH=src python examples/train_gnn.py [--steps 300]
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.io import synthetic
from repro.models.gnn import graphcast
from repro.train import loop, optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: 8 layers × (edge MLP 3d·d+d·d + node MLP 2d·d+d·d) at d=256
    cfg = graphcast.GraphCastConfig(
        n_layers=args.layers, d_hidden=args.d_hidden, n_vars=64
    )
    key = jax.random.PRNGKey(0)
    params = graphcast.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    csr = synthetic.make_graph("road", scale=11, seed=3)
    rng = np.random.default_rng(0)
    rows = np.repeat(np.arange(csr.n), np.diff(np.asarray(csr.offsets)))
    x0 = rng.standard_normal((csr.n, cfg.n_vars)).astype(np.float32)
    g = {
        "node_feat": jnp.asarray(x0),
        "edge_src": jnp.asarray(rows, jnp.int32),
        "edge_dst": jnp.asarray(np.asarray(csr.dst), jnp.int32),
        "positions": jnp.asarray(rng.standard_normal((csr.n, 3)), jnp.float32),
        # synthetic "next state": smoothed + drift (learnable signal)
        "labels": jnp.asarray(x0 * 0.9 + 0.1, jnp.float32),
    }

    opt_cfg = opt.OptimizerConfig(lr=2e-4, warmup_steps=20, total_steps=args.steps)
    state = loop.init_state(params, opt_cfg)
    step = jax.jit(
        loop.make_train_step(
            lambda p, b: graphcast.loss_fn(p, b, cfg), opt_cfg
        ),
        donate_argnums=(0,),
    )

    ckdir = os.path.join(tempfile.gettempdir(), "repro_graphcast_ck")
    losses = []
    t0 = time.time()
    i = 0
    while i < args.steps:
        state, metrics = step(state, g)
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            print(f"step {i:4d} loss {losses[-1]:.5f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)", flush=True)
            ckpt.save(ckdir, i, state)
        if i == args.steps // 2:
            # simulate a failure: discard live state, restart from durable
            print("!! simulated node failure — restoring from checkpoint")
            state, at = ckpt.restore(ckdir, state)
            print(f"   restored step {at}")
        i += 1
    print(f"done: loss {losses[0]:.5f} -> {losses[-1]:.5f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    assert losses[-1] < losses[0], "training must reduce the loss"


if __name__ == "__main__":
    main()
