"""Quickstart: the paper's four tasks on every representation in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import REPRESENTATIONS, edgebatch
from repro.io import synthetic

# 1. LOAD — build a small power-law graph (T1; mtx.load_mtx for real files)
csr = synthetic.make_graph("web", scale=10, edge_factor=8, seed=0)
print(f"graph: |V|={csr.n} |E|={csr.m}")

rng = np.random.default_rng(0)
ins = edgebatch.random_insertions(rng, csr.n, 500)
dele = edgebatch.random_deletions(rng, csr, 500)

for name, cls in REPRESENTATIONS.items():
    g = cls.from_csr(csr)

    # 2. CLONE / SNAPSHOT (T2)
    snap = g.snapshot()          # O(1) for chunked/lazy; sealed COW elsewhere
    deep = g.clone()             # always a deep copy

    # 3. BATCH UPDATES (T3): union then subtraction, in place
    g, dm_in = g.add_edges(ins, inplace=True)
    g, dm_out = g.remove_edges(dele, inplace=True)

    # 4. TRAVERSAL (T4): 42-step reverse walk on the UPDATED graph
    visits = np.asarray(g.reverse_walk(8))

    m_now = g.to_csr().m
    assert snap.to_csr().m == csr.m, "snapshot must be isolated"
    print(
        f"{name:10s} +{dm_in:4d} -{-dm_out if dm_out < 0 else dm_out:4d} "
        f"edges -> m={m_now}  walk[:3]={np.round(visits[:3], 1)}"
    )

print("OK — all representations agree with the snapshot/update contract")
