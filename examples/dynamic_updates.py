"""Streaming dynamic-graph scenario (the paper's core workload):

a stream of insert/delete batches applied to every representation, with a
GCN forward pass (traversal analogue) after each batch — measuring both
update cost and query cost, like the paper's Figs. 5-10 pipeline.  Also
demonstrates the distributed path when >1 device is available.

  PYTHONPATH=src python examples/dynamic_updates.py
"""
import time

import jax
import numpy as np

from repro.core import REPRESENTATIONS, edgebatch
from repro.io import synthetic
from repro.models.gnn import gcn

csr = synthetic.make_graph("social", scale=10, edge_factor=8, seed=1)
rng = np.random.default_rng(2)

cfg = gcn.GCNConfig(d_in=16, n_classes=4)
params = gcn.init_params(jax.random.PRNGKey(0), cfg)
feats = rng.standard_normal((csr.n, 16)).astype(np.float32)

print(f"stream over |V|={csr.n} |E|={csr.m}; 6 batches of 2% |E|")
print("(cold-start: jit compiles land on the first batches; benchmarks/ warms up)")
for name, cls in REPRESENTATIONS.items():
    g = cls.from_csr(csr)
    t_upd = t_query = 0.0
    for step in range(6):
        count = max(csr.m // 50, 1)
        if step % 2 == 0:
            batch = edgebatch.random_insertions(rng, csr.n, count)
            t0 = time.perf_counter()
            g, _ = g.add_edges(batch)
        else:
            batch = edgebatch.random_deletions(rng, g.to_csr(), count)
            t0 = time.perf_counter()
            g, _ = g.remove_edges(batch)
        g.block_on()
        t_upd += time.perf_counter() - t0

        # query the updated graph: GCN forward = the SpMM traversal
        cc = g.to_csr()
        rows = np.repeat(np.arange(cc.n), np.diff(np.asarray(cc.offsets)))
        gb = {
            "node_feat": feats[: cc.n],
            "edge_src": rows.astype(np.int32),
            "edge_dst": np.asarray(cc.dst),
        }
        t0 = time.perf_counter()
        out = gcn.forward(params, {k: jax.numpy.asarray(v) for k, v in gb.items()}, cfg)
        out.block_until_ready()
        t_query += time.perf_counter() - t0
    print(f"{name:10s} update={t_upd*1e3:7.1f}ms  gcn-query={t_query*1e3:7.1f}ms")
print("OK")
