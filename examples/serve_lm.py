"""Serve a small LM with batched requests: continuous batching over the
decode step, sliding-window KV cache (h2o-danube style), per-request exit.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import config as tcfg, model as tmodel

cfg = tcfg.TransformerConfig(
    name="serve-demo", n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
    d_head=16, d_ff=256, vocab=512, sliding_window=32, attn_impl="ref",
    compute_dtype=jnp.float32,
)
BATCH, CACHE = 8, 64
EOS = 7

params = tmodel.init_params(jax.random.PRNGKey(0), cfg)
cache = tmodel.init_cache(cfg, BATCH, CACHE)
step = jax.jit(lambda p, c, t: tmodel.decode_step(p, c, t, cfg), donate_argnums=(1,))

# batched request queue: slots are refilled as sequences hit EOS
rng = np.random.default_rng(0)
pending = list(rng.integers(1, cfg.vocab, (32,)))   # 32 queued prompts
active = np.array(pending[:BATCH], np.int32)
pending = pending[BATCH:]
done, generated = 0, {i: [] for i in range(BATCH)}

tok = jnp.asarray(active[:, None], jnp.int32)
t0 = time.time()
steps = 0
while done < 24 and steps < 400:
    logits, cache = step(params, cache, tok)
    nxt = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1).copy()
    for slot in range(BATCH):
        generated[slot].append(int(nxt[slot]))
        if int(nxt[slot]) == EOS or len(generated[slot]) >= 24:
            done += 1
            generated[slot] = []
            if pending:
                nxt[slot] = pending.pop()   # continuous batching refill
    tok = jnp.asarray(nxt[:, None], jnp.int32)
    steps += 1
dt = time.time() - t0
print(f"served {done} sequences in {steps} decode steps, "
      f"{BATCH*steps/dt:.0f} tok/s, ring cache = {CACHE} slots "
      f"(window {cfg.sliding_window})")
print("OK")
