"""Live shard-failover chaos gate (smoke, DESIGN.md §17).

Run with ``XLA_FLAGS="--xla_force_host_platform_device_count=4"``.
Kills one shard of a served 4-shard mesh mid-traffic — twice, once per
failure mode — and gates the full failover story end to end:

1. **crash-stop** — ``shard.walk`` armed to fault exactly one shard
   during a batched dispatch; the dispatcher attributes the fault
   (``ShardFaultError.sid``), queues the quarantine, and retries the
   batch, so surviving shards keep serving with an explicit
   ``coverage < 1`` mask while routed updates spool;
2. **silent corruption** — ``failover.corrupt_shard`` flips a live
   weight in place (no exception anywhere); the writer's paced
   ``AuditScheduler`` catches the CRC violation within one sweep and
   quarantines BEFORE the damage can reach a sealed generation;
3. after each: **online rebuild** (``DurableGraph.rebuild_shard`` —
   diff-chain restore of the lost shard only + WAL-window and spool
   replay through its fused patch path) reintegrates on the writer
   thread and readers flip back to full coverage on the next seal.

Gates: zero lost tickets, zero torn reads (degraded responses verify
against the SAME per-generation oracle with their ``down_shards`` rows
masked), served > 0 during both outages, and post-reintegration
bit-parity (gathered CSR + exact walk) against an uncrashed twin.
Emits a ``shard_failover`` row (detect/rebuild latency, degraded
rounds) into BENCH_recovery.json.  Exits non-zero on any violation.
"""
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import csr as csr_mod, edgebatch, updates  # noqa: E402
from repro.core import distributed as dist  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.launch import serve as serve_launch  # noqa: E402
from repro.runtime import durable, faultinject, failover  # noqa: E402
from repro.runtime import serve as serve_mod  # noqa: E402

S = 4
N_V = 96
STEPS = 3
ROOT = os.path.join(os.path.dirname(__file__), "..")


def fail(msg: str) -> int:
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def make_plan(rng, k=10):
    ib = edgebatch.from_arrays(
        rng.integers(0, N_V, k), rng.integers(0, N_V, k),
        rng.random(k).astype(np.float32),
    )
    db = edgebatch.from_arrays(rng.integers(0, N_V, 3), rng.integers(0, N_V, 3))
    return updates.plan_update(inserts=ib, deletes=db)


class Traffic:
    """Submission helper pooling every ticket for the final ledger/oracle."""

    def __init__(self, srv, rng):
        self.srv = srv
        self.rng = rng
        self.walks: list = []
        self.upds: list = []

    def walk_round(self, k=4):
        ts = [
            self.srv.submit_walk(
                self.rng.integers(0, N_V, 3), steps=STEPS, timeout=30.0
            )
            for _ in range(k)
        ]
        self.walks.extend(ts)
        for t in ts:
            t.wait(30.0)
        return ts

    def update(self, plan):
        t = self.srv.submit_update(plan)
        self.upds.append((t, plan))
        t.wait(30.0)
        return t


def down_rows_for(t):
    if not t.down_shards:
        return None
    rm = (N_V + S - 1) // S  # rows_max of a 4-way block partition
    return np.concatenate([
        np.arange(s * rm, min((s + 1) * rm, N_V)) for s in t.down_shards
    ])


def await_stat(srv, key, minimum, timeout=20.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if srv.stats()[key] >= minimum:
            return time.monotonic() - t0
        time.sleep(0.01)
    return None


def await_coverage(srv, want=1.0, timeout=20.0):
    """Admin reseals land on the writer's next tick — wait for the flip."""
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if srv.stats()["coverage"] == want:
            return True
        time.sleep(0.01)
    return False


def bench_row(row: dict) -> None:
    path = os.path.join(ROOT, "BENCH_recovery.json")
    data = {"recovery": []}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    rows = data.setdefault("recovery", [])
    rows[:] = [r for r in rows if r.get("name") != row["name"]]
    rows.append(row)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main() -> int:
    if len(jax.devices()) < S:
        print(f"chaos_check: need {S} devices, have {len(jax.devices())} "
              f"— set XLA_FLAGS", file=sys.stderr)
        return 2
    mesh = mesh_mod.host_mesh(S)
    rng = np.random.default_rng(23)
    c = csr_mod.from_coo(
        rng.integers(0, N_V, 420), rng.integers(0, N_V, 420),
        rng.random(420).astype(np.float32), n=N_V,
    )
    base = tempfile.mkdtemp(prefix="chaos_check_")
    wd, cd = os.path.join(base, "wal"), os.path.join(base, "ckpt")
    dg = durable.DurableGraph(
        dist.shard_csr(c, S, mesh=mesh), wd, cd, diff=True, full_every=8
    )
    dg.rep.enable_integrity()
    twin = dist.shard_csr(c, S, mesh=mesh)
    oracle = serve_launch.GenerationOracle(c)

    srv = serve_mod.WalkServer(
        dg, batch_max=8, dispatch_retries=4, retry_backoff=0.005,
        audit_every=1, seal_group_max=4,
    ).start()
    tr = Traffic(srv, rng)
    try:
        # -- warmup: steady mixed traffic, then a bounding checkpoint ----
        for _ in range(3):
            tr.update(make_plan(rng))
            tr.walk_round()
        srv.run_on_writer(lambda s: dg.checkpoint()).result(30.0)

        # ===== scenario 1: crash-stop of one shard mid-dispatch =========
        sid1 = 2
        faultinject.arm("shard.walk", after=sid1, times=1)
        t0 = time.monotonic()
        tr.walk_round()
        dt = await_stat(srv, "shard_quarantines", 1)
        faultinject.disarm("shard.walk")
        if dt is None:
            return fail("crash-stop quarantine never detected")
        detect1_ms = (time.monotonic() - t0) * 1e3
        if sid1 not in dg.rep.down:
            return fail(f"expected shard {sid1} down, got {dg.rep.down}")

        # degraded window: surviving shards serve, routed updates spool
        degraded = served_outage = 0
        for _ in range(4):
            tr.update(make_plan(rng))
            for t in tr.walk_round():
                if t.status == serve_mod.SERVED:
                    served_outage += 1
                    if (t.coverage or 1.0) < 1.0:
                        degraded += 1
        if served_outage == 0:
            return fail("no requests served during the outage")
        if degraded == 0:
            return fail("no degraded (coverage < 1) responses during outage")
        if not dg.rep.spooled(sid1):
            return fail("no updates spooled for the down shard")

        # online rebuild + reintegration on the writer thread
        t0 = time.monotonic()
        srv.run_on_writer(lambda s: dg.rebuild_shard(sid1),
                          reseal=True).result(60.0)
        rebuild1_ms = (time.monotonic() - t0) * 1e3
        if dg.rep.down:
            return fail(f"shards still down after rebuild: {dg.rep.down}")
        if not await_coverage(srv):
            return fail("serving generation never returned to full coverage")
        healed = [t for t in tr.walk_round()
                  if t.status == serve_mod.SERVED and t.coverage == 1.0]
        if not healed:
            return fail("no full-coverage responses after reintegration")

        # ===== scenario 2: silent corruption, audit-paced detection =====
        srv.run_on_writer(lambda s: dg.checkpoint()).result(30.0)
        sid2 = 1
        det0 = srv.stats()["audit_detections"]
        t0 = time.monotonic()
        srv.run_on_writer(
            lambda s: failover.corrupt_shard(dg.rep, sid2, kind="wgt")
        ).result(30.0)
        # walk-only traffic while the audit sweep closes in — every
        # response serves a generation sealed before the damage
        while srv.stats()["audit_detections"] == det0:
            tr.walk_round(k=2)
            if time.monotonic() - t0 > 20.0:
                return fail("silent corruption never detected by audits")
        detect2_ms = (time.monotonic() - t0) * 1e3
        if sid2 not in dg.rep.down:
            return fail(f"expected shard {sid2} down, got {dg.rep.down}")
        t0 = time.monotonic()
        srv.run_on_writer(lambda s: dg.rebuild_shard(sid2),
                          reseal=True).result(60.0)
        rebuild2_ms = (time.monotonic() - t0) * 1e3
        if not await_coverage(srv):
            return fail("coverage never recovered after corruption rebuild")

        # healed steady state
        for _ in range(2):
            tr.update(make_plan(rng))
            tr.walk_round()
    finally:
        faultinject.disarm()
        stats = srv.stop()
    srv.assert_no_lost()

    # -- twin replay + bit-parity ---------------------------------------
    for t, plan in tr.upds:
        if t.status == serve_mod.SERVED:
            twin.apply(plan)
    dg.rep.audit()
    ca, cb = dist.gather_csr(dg.rep), dist.gather_csr(twin)
    checks = (
        (np.asarray(ca.offsets), np.asarray(cb.offsets)),
        (np.asarray(ca.dst)[: ca.m], np.asarray(cb.dst)[: cb.m]),
        (np.asarray(ca.wgt)[: ca.m], np.asarray(cb.wgt)[: cb.m]),
        (np.asarray(dg.rep.reverse_walk(STEPS)),
         np.asarray(twin.reverse_walk(STEPS))),
    )
    for i, (a, b) in enumerate(checks):
        if a.shape != b.shape or not np.array_equal(a, b):
            return fail(f"bit-parity check {i} diverged vs uncrashed twin")

    # -- torn-read sweep (degraded responses masked, same oracle) -------
    torn, checked = serve_launch.count_torn_reads(
        oracle, tr.walks, tr.upds, sample=1.0, down_rows_of=down_rows_for
    )
    if torn:
        return fail(f"torn_reads={torn}/{checked}")
    if stats["served_degraded"] == 0:
        return fail("server never accounted a degraded response")
    if stats["audit_detections"] < 1 or stats["shard_quarantines"] < 2:
        return fail(f"failover counters off: {stats}")

    bench_row({
        "name": "recovery/chaos/shard_failover",
        "ms_per_call": round(rebuild1_ms, 2),
        "derived": (
            f"S={S} detect_crash_ms={detect1_ms:.1f} "
            f"detect_audit_ms={detect2_ms:.1f} "
            f"rebuild_ms={rebuild1_ms:.1f}/{rebuild2_ms:.1f} "
            f"degraded_rounds={degraded} served_during_outage={served_outage} "
            f"torn_reads={torn}/{checked} lost=0"
        ),
        "detect_ms": round(detect1_ms, 2),
        "rebuild_ms": round(rebuild1_ms, 2),
        "degraded_rounds": int(degraded),
    })
    print(
        f"# chaos check ok: S={S}, crash-stop detect {detect1_ms:.0f}ms / "
        f"rebuild {rebuild1_ms:.0f}ms, corruption detect {detect2_ms:.0f}ms "
        f"/ rebuild {rebuild2_ms:.0f}ms, {served_outage} served during "
        f"outage ({degraded} degraded), torn_reads=0/{checked}, "
        f"zero lost, bit-parity exact"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
