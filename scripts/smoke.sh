#!/usr/bin/env bash
# PR smoke gate: tier-1 tests + the traversal benchmark (slot_walk vs the
# seed digraph_flat path), writing BENCH_traversal.json so perf
# regressions on the hot path show up in every PR's diff.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== traversal benchmark (social_small, 1e-2 update batches) =="
python -m benchmarks.run --only traversal --json BENCH_traversal.json

echo "== BENCH_traversal.json written =="
