#!/usr/bin/env bash
# PR smoke gate: tier-1 tests + the perf-trajectory benchmarks.
#  * load      (device-resident ingest vs seed loader) -> BENCH_load.json
#  * clone     (fused clone / snapshot / COW detach)   -> BENCH_clone.json
#  * traversal (slot_walk vs the seed digraph_flat path) -> BENCH_traversal.json
#  * update    (batch insert/delete, fixed pre-cloned timing) -> BENCH_update.json
#  * stream    (interleaved mixed-batch apply + walk rounds) -> BENCH_stream.json
#  * recovery  (WAL/checkpoint/replay + fallback chain, §13) -> BENCH_recovery.json
#  * serve     (multi-tenant walk serving under load, §16)   -> BENCH_serve.json
# so perf regressions on every paper task (load, clone, updates,
# traversal) show up in every PR's diff.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== load benchmark (paper Fig. 2, seed-baseline row) =="
python -m benchmarks.run --only load --json BENCH_load.json

echo "== clone benchmark (paper Fig. 3 + COW detach) =="
python -m benchmarks.run --only clone --json BENCH_clone.json

echo "== traversal benchmark (social_small, 1e-2 update batches) =="
# --compare gates the smoke run: >1.3x regression of any digraph row vs
# the checked-in trajectory fails (the baseline is read before --json
# rewrites the file)
python -m benchmarks.run --only traversal \
  --compare BENCH_traversal.json --json BENCH_traversal.json

echo "== update benchmark (web_small, Figs. 5-8) =="
python -m benchmarks.run --only update --json BENCH_update.json

echo "== stream benchmark (web_small, interleaved mixed batches) =="
python -m benchmarks.run --only stream \
  --compare BENCH_stream.json --json BENCH_stream.json

echo "== stream proof fields (fused flush→walk, DESIGN.md §12) =="
# steady-state invariants recorded into BENCH_stream.json: the walk half
# of a stream round must be ONE device dispatch (flush fused into the
# walk program), and a back-to-back second walk must do zero host image
# work (no builds, no patches).
python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_stream.json"))["stream"]
bad = [
    r["name"]
    for r in rows
    if r.get("img_builds2", 0) != 0
    or r.get("img_patches2", 0) != 0
    or r.get("round_dispatches", 1) != 1
]
if bad:
    sys.exit(f"flush→walk proof regressed (dispatches != 1 or walk2 host work): {bad}")
print("# stream proof ok: 1-dispatch flush→walk, host-free second walk")
EOF

echo "== sharded stream rows (forced 4-device shard_map, DESIGN.md §14) =="
# appends shards={1,4} rows into the same trajectory (--json merges by
# row name); --compare gates them with the usual 1.3x/no-ratchet rule.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  BENCH_SHARDS=4 BENCH_SHARDS_ONLY=1 \
  python -m benchmarks.run --only stream \
    --compare BENCH_stream.json --json BENCH_stream.json

echo "== sharded traversal rows (forced 4-device shard_map) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  BENCH_SHARDS=4 BENCH_SHARDS_ONLY=1 \
  python -m benchmarks.run --only traversal \
    --compare BENCH_traversal.json --json BENCH_traversal.json

echo "== sharded proof fields (frontier bytes model, routed 1-dispatch) =="
# the shard_map rows must prove the §14 model: a steady-state routed
# apply is exactly ONE fused slot_update dispatch per touched device,
# and the per-device collective traffic of a walk step equals the
# jaxpr-measured frontier exchange, within 1.5x of |V|*4 bytes.
python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_stream.json"))["stream"]
sh = [r for r in rows if "/shards" in r["name"]]
if not sh:
    sys.exit("stream suite missing shards rows (BENCH_SHARDS append failed)")
bad = []
for r in sh:
    if float(r.get("round_dispatches", 1)) != 1:
        bad.append(f"{r['name']}: round_dispatches={r.get('round_dispatches')}")
    if r.get("mode") == "shmap":
        c = int(r.get("collective_bytes_per_step", -1))
        m = int(r.get("model_bytes_per_step", 0))
        b = int(r.get("frontier_bound_bytes", 0))
        if not (0 < c <= b):
            bad.append(f"{r['name']}: collective={c} not in (0, {b}]")
        if c != m:
            bad.append(f"{r['name']}: collective={c} != model={m}")
if not any(r.get("mode") == "shmap" for r in sh):
    sys.exit("no shard_map stream rows (forced devices missing?)")
if bad:
    sys.exit("sharded proof regressed: " + "; ".join(bad))
tr = json.load(open("BENCH_traversal.json"))["traversal"]
if not any("/shards4/" in r["name"] and r.get("mode") == "shmap" for r in tr):
    sys.exit("traversal suite missing shard_map shards4 rows")
print("# sharded proof ok: routed 1-dispatch patches, "
      "frontier bytes == model <= 1.5x |V|*4")
EOF

echo "== recovery benchmark (durability pipeline, DESIGN.md §13) =="
python -m benchmarks.run --only recovery --json BENCH_recovery.json

echo "== recovery proof fields (WAL overhead + dispatch invariance) =="
# journaling must stay off the critical path: the WAL-first stream round
# pays <15% over the journal-free stream, and with no fault armed the
# fused flush→walk round under the durability wrapper is still exactly
# ONE device dispatch (the fallback chain must not change steady-state
# dispatch behaviour).
python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_recovery.json"))["recovery"]
ov = [r for r in rows if r["name"].endswith("/wal_overhead")]
if not ov:
    sys.exit("recovery suite missing the wal_overhead row")
bad = [
    f"{r['name']}: overhead_pct={r.get('overhead_pct')} "
    f"round_dispatches={r.get('round_dispatches')}"
    for r in ov
    if float(r.get("overhead_pct", 0.0)) >= 15.0
    or int(r.get("round_dispatches", 1)) != 1
]
if bad:
    sys.exit("recovery proof regressed (WAL overhead >= 15% or steady-state "
             "dispatches != 1): " + "; ".join(bad))
print("# recovery proof ok: WAL overhead < 15%, 1-dispatch durable rounds")
EOF

echo "== sharded recovery proof fields (group commit + diff replay, §15) =="
# the §15 engine's two proof obligations in BENCH_recovery.json: a
# group-committed round is exactly ONE WAL flush, and the differential
# checkpoint + owner-routed parallel replay recovers the same 16-round
# sharded workload strictly cheaper than the PR 6 serial full-restore.
python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_recovery.json"))["recovery"]
by = {r["name"].rsplit("/", 1)[-1]: r for r in rows}
gc = by.get("group_commit")
if gc is None:
    sys.exit("recovery suite missing the group_commit row")
if int(gc.get("wal_flushes_per_round", 0)) != 1:
    sys.exit(f"group commit regressed: wal_flushes_per_round="
             f"{gc.get('wal_flushes_per_round')} (want 1)")
ser, par = by.get("sharded_serial_full"), by.get("sharded_parallel_diff")
if ser is None or par is None:
    sys.exit("recovery suite missing the sharded_serial_full / "
             "sharded_parallel_diff rows")
if int(par["records_replayed"]) >= int(ser["records_replayed"]):
    sys.exit("diff checkpoint did not bound the replay window: "
             f"{par['records_replayed']} vs {ser['records_replayed']} records")
if float(par["ms_per_call"]) >= float(ser["ms_per_call"]):
    sys.exit("sharded recovery regressed: parallel+diff "
             f"{par['ms_per_call']}ms not under serial+full {ser['ms_per_call']}ms")
print(f"# sharded recovery proof ok: 1 flush/round, parallel+diff "
      f"{par['ms_per_call']}ms < serial+full {ser['ms_per_call']}ms "
      f"({par['records_replayed']} vs {ser['records_replayed']} records)")
EOF

echo "== forced-4-device sharded crash/recover roundtrip (§15) =="
# a real mesh (4 forced host devices): group-committed rounds, an
# injected crash, owner-routed parallel replay onto the mesh, then
# audit() + bit-parity against an uncrashed twin.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  python scripts/sharded_recovery_check.py

echo "== live shard failover chaos gate (§17) =="
# kills one shard of a served 4-shard mesh mid-traffic, twice (injected
# crash-stop + silent corruption caught by the paced audit): surviving
# shards must keep serving with explicit coverage < 1, zero tickets
# lost, zero torn reads (degraded responses verify masked against the
# same oracle), and the online single-shard rebuild must reintegrate to
# bit-parity with an uncrashed twin.  Emits the shard_failover row into
# BENCH_recovery.json.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  python scripts/chaos_check.py

echo "== serve benchmark (multi-tenant walk serving, DESIGN.md §16) =="
python -m benchmarks.run --only serve --json BENCH_serve.json

echo "== serve proof fields (snapshot isolation + zero-lost, §16) =="
# every row must prove the serving contract: no served walk contradicts
# its sealed generation (torn_reads == 0 against the host oracle), and
# no admitted request vanished (lost == 0 — served, shed, or rejected,
# never silent).  The overload row must actually exercise admission
# control (shed_count > 0), and the fault row — pallas killed
# mid-traffic — must complete via the breaker chain (breaker_fallbacks
# >= 1) without losing a single request.
python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_serve.json"))["serve"]
by = {r["name"].split("/")[-2]: r for r in rows}
bad = []
for r in rows:
    if int(r.get("torn_reads", 1)) != 0:
        bad.append(f"{r['name']}: torn_reads={r.get('torn_reads')}")
    if int(r.get("torn_checked", 0)) <= 0:
        bad.append(f"{r['name']}: oracle checked 0 walks")
    if int(r.get("lost", 1)) != 0:
        bad.append(f"{r['name']}: lost={r.get('lost')}")
for lvl in ("steady", "overload", "fault"):
    if lvl not in by:
        bad.append(f"missing serve row: {lvl}")
if "overload" in by and int(by["overload"].get("shed_count", 0)) <= 0:
    bad.append("overload row shed/rejected nothing (admission control idle)")
if "fault" in by:
    f = by["fault"]
    if int(f.get("breaker_fallbacks", 0)) < 1:
        bad.append("fault row never fell back (pallas injection missed)")
    if int(f.get("served", 0)) <= 0:
        bad.append("fault row served nothing")
if bad:
    sys.exit("serve proof regressed: " + "; ".join(bad))
print("# serve proof ok: torn_reads==0, lost==0, overload sheds, "
      "injected pallas failure completes via fallback")
EOF

echo "== BENCH_{load,clone,traversal,update,stream,recovery,serve}.json written =="
