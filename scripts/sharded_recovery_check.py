"""Forced-4-device sharded crash/recover roundtrip (smoke gate, §15).

Run with ``XLA_FLAGS="--xla_force_host_platform_device_count=4"``.
Drives the full sharded recovery engine on a REAL (forced-host) mesh:

1. a ``DurableGraph`` over a 4-shard mesh-placed ``ShardedGraph`` with
   differential checkpoints, fed group-committed rounds (asserting one
   WAL flush per round);
2. an injected crash mid-stream (``durable.post_append`` — the record
   is durable, the apply never ran);
3. ``recover()`` with owner-routed parallel replay onto the same mesh;
4. the per-shard + cross-boundary ``audit()`` plus bit-parity (gathered
   CSR streams and exact walk outputs) against an uncrashed twin.

Exits non-zero on any violation; prints one OK line on success.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import csr as csr_mod, edgebatch, updates  # noqa: E402
from repro.core import distributed as dist  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.runtime import durable, faultinject  # noqa: E402

S = 4
N_V = 96


def make_round(rng, n, k=3):
    out = []
    for _ in range(k):
        ib = edgebatch.from_arrays(
            rng.integers(0, n, 10), rng.integers(0, n, 10),
            rng.random(10).astype(np.float32),
        )
        db = edgebatch.from_arrays(rng.integers(0, n, 4), rng.integers(0, n, 4))
        out.append(updates.plan_update(inserts=ib, deletes=db))
    return out


def main() -> int:
    if len(jax.devices()) < S:
        print(f"sharded_recovery_check: need {S} devices, have "
              f"{len(jax.devices())} — set XLA_FLAGS", file=sys.stderr)
        return 2
    mesh = mesh_mod.host_mesh(S)
    rng = np.random.default_rng(17)
    c = csr_mod.from_coo(
        rng.integers(0, N_V, 420), rng.integers(0, N_V, 420),
        rng.random(420).astype(np.float32), n=N_V,
    )
    base = tempfile.mkdtemp(prefix="sharded_recovery_check_")
    wd, cd = os.path.join(base, "wal"), os.path.join(base, "ckpt")
    g = durable.DurableGraph(
        dist.shard_csr(c, S, mesh=mesh), wd, cd, diff=True, full_every=8
    )
    twin = dist.shard_csr(c, S, mesh=mesh)
    rounds = [make_round(rng, N_V) for _ in range(4)]

    for i, plans in enumerate(rounds[:3]):
        f0 = g.journal.flushes
        g.apply_group(plans)
        if g.journal.flushes - f0 != 1:
            print(f"FAIL: round {i} took {g.journal.flushes - f0} WAL "
                  f"flushes (want 1)", file=sys.stderr)
            return 1
        for p in plans:
            twin.apply(p)
    g.checkpoint()  # differential step against the step-0 full base

    faultinject.arm("durable.post_append")
    try:
        g.apply_group(rounds[3])
        print("FAIL: injected crash never fired", file=sys.stderr)
        return 1
    except faultinject.SimulatedCrash:
        pass
    faultinject.disarm()
    for p in rounds[3]:  # the group was durable before the crash
        twin.apply(p)

    stats = {}
    g2 = durable.DurableGraph.recover(
        wd, cd, parallel=True, mesh=mesh, diff=True, stats=stats
    )
    g2.rep.audit()  # per-shard + cross-boundary invariant pass

    ca, cb = dist.gather_csr(g2.rep), dist.gather_csr(twin)
    checks = (
        (np.asarray(ca.offsets), np.asarray(cb.offsets)),
        (np.asarray(ca.dst)[: ca.m], np.asarray(cb.dst)[: cb.m]),
        (np.asarray(ca.wgt)[: ca.m], np.asarray(cb.wgt)[: cb.m]),
        (np.asarray(g2.rep.reverse_walk(3)), np.asarray(twin.reverse_walk(3))),
    )
    for i, (a, b) in enumerate(checks):
        if a.shape != b.shape or not np.array_equal(a, b):
            print(f"FAIL: bit-parity check {i} diverged after recovery",
                  file=sys.stderr)
            return 1
    print(f"# sharded recovery check ok: S={S} mesh devices, "
          f"{stats['records']} records replayed in parallel "
          f"(restore {stats['restore_s'] * 1e3:.1f}ms, "
          f"replay {stats['replay_s'] * 1e3:.1f}ms), audit clean, "
          f"bit-parity exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
