"""Walk-image layer tests (DESIGN.md §11).

Every representation lowers to one canonical traversal image; these
tests pin the maintenance contract: back-to-back walks do ZERO host
image work, applied plans patch the cached image in place (bit-parity
with the dense oracle), and the patch path falls back to a rebuild
exactly when it must (vertex growth, row outgrowing its slack with no
bump headroom, queue overflow).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    REPRESENTATIONS,
    edgebatch,
    from_coo,
    traversal,
    updates,
    walk_image,
)
from repro.io import synthetic

STEPS = 4
REPS = list(REPRESENTATIONS.items())


def _make_csr(n=200, m=1600, seed=7):
    rng = np.random.default_rng(seed)
    src, dst = synthetic.uniform_edges(rng, n, m)
    return from_coo(src, dst, n=n), rng


def _oracle(g, steps=STEPS):
    return traversal.reverse_walk_dense_oracle(g.to_csr().to_dense(), steps)


def _assert_walk(g, steps=STEPS):
    exp = _oracle(g, steps)
    got = np.asarray(g.reverse_walk(steps))
    np.testing.assert_allclose(got[: exp.shape[0]], exp, rtol=1e-4)


# ---------------------------------------------------------------------------
# back-to-back walks: the image is cached, the second walk is host-free
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,cls", REPS)
def test_back_to_back_walk_zero_host_image_work(name, cls):
    c, _ = _make_csr()
    g = cls.from_csr(c)
    g.reverse_walk(STEPS)  # builds + caches the image
    img = g.to_walk_image()
    before = walk_image.stats_snapshot()
    v = np.asarray(g.reverse_walk(STEPS))
    after = walk_image.stats_snapshot()
    assert g.to_walk_image() is img, name
    assert after["builds"] == before["builds"], name
    assert after["patches"] == before["patches"], name
    np.testing.assert_allclose(v, _oracle(g), rtol=1e-4)


@pytest.mark.parametrize("name,cls", REPS)
def test_update_patches_cached_image_in_place(name, cls):
    c, rng = _make_csr()
    g = cls.from_csr(c)
    g.reverse_walk(STEPS)
    img = g.to_walk_image()
    plan = updates.plan_update(
        inserts=edgebatch.random_insertions(rng, c.n, 60),
        deletes=edgebatch.random_deletions(rng, c, 60),
    )
    g, _ = g.apply(plan)
    before = walk_image.stats_snapshot()
    _assert_walk(g)
    after = walk_image.stats_snapshot()
    if name == "digraph":
        # the arena IS the image: the rep's own update engine keeps it
        # current, and re-wrapping the live buffers is zero-cost
        assert g.to_walk_image().shared
        assert after["patches"] == before["patches"]
    else:
        assert g.to_walk_image() is img, name
        assert after["patches"] == before["patches"] + 1, name
        assert after["builds"] == before["builds"], name


@pytest.mark.parametrize("name,cls", REPS)
def test_walk_occupancy_reported_from_image(name, cls):
    c, rng = _make_csr()
    g = cls.from_csr(c)
    occ0 = g.walk_occupancy()
    assert 0.0 < occ0 <= 1.0
    g, dm = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 2))
    assert dm < 0 or dm > 0  # something happened
    occ1 = g.walk_occupancy()
    assert 0.0 <= occ1 <= 1.0
    if name != "digraph":  # digraph may auto-compact back to dense
        assert occ1 < occ0


# ---------------------------------------------------------------------------
# patch-vs-rebuild decision
# ---------------------------------------------------------------------------
def test_row_outgrows_slack_falls_back_to_rebuild():
    c, rng = _make_csr(n=64, m=256)
    g = REPRESENTATIONS["coo"].from_csr(c)
    g.reverse_walk(STEPS)
    img = g.to_walk_image()
    # densify to the complete graph: every row outgrows its slack and the
    # summed relocation demand necessarily exceeds the bump headroom
    uu, vv = np.meshgrid(np.arange(64), np.arange(64))
    ins = edgebatch.from_arrays(uu.reshape(-1), vv.reshape(-1))
    before = walk_image.stats_snapshot()
    g, _ = g.apply(updates.plan_update(inserts=ins))
    _assert_walk(g)
    after = walk_image.stats_snapshot()
    assert after["rebuilds"] == before["rebuilds"] + 1
    assert after["builds"] == before["builds"] + 1
    assert g.to_walk_image() is not img


def test_small_growth_patches_without_rebuild():
    c, rng = _make_csr()
    g = REPRESENTATIONS["lazy"].from_csr(c)
    g.reverse_walk(STEPS)
    img = g.to_walk_image()
    # grow one existing row past its CP2AA class but well inside the
    # image's bump headroom: must relocate the block, not rebuild
    u = int(np.argmax(np.diff(np.asarray(c.offsets))))
    deg = int(np.diff(np.asarray(c.offsets))[u])
    ins = edgebatch.from_arrays(
        np.full(2 * deg + 4, u, np.int64),
        np.arange(2 * deg + 4, dtype=np.int64) % c.n,
    )
    before = walk_image.stats_snapshot()
    g, _ = g.apply(updates.plan_update(inserts=ins))
    _assert_walk(g)
    after = walk_image.stats_snapshot()
    assert after["rebuilds"] == before["rebuilds"]
    assert after["patches"] == before["patches"] + 1
    assert g.to_walk_image() is img


def test_vertex_growth_rebuilds_image():
    c, _ = _make_csr(n=50, m=300)
    g = REPRESENTATIONS["chunked"].from_csr(c)
    g.reverse_walk(STEPS)
    ins = edgebatch.from_arrays(
        np.array([3, 70], np.int64), np.array([70, 3], np.int64)
    )
    before = walk_image.stats_snapshot()
    g, _ = g.apply(updates.plan_update(inserts=ins))
    _assert_walk(g)
    after = walk_image.stats_snapshot()
    assert after["rebuilds"] == before["rebuilds"] + 1
    assert g.to_walk_image().nv >= 71


def test_queue_overflow_rebuilds_instead_of_replaying():
    c, rng = _make_csr(n=64, m=256)
    g = REPRESENTATIONS["vector2d"].from_csr(c)
    g.reverse_walk(STEPS)
    for _ in range(walk_image.MAX_PENDING + 1):
        ins = edgebatch.random_insertions(rng, 64, 2)
        g, _ = g.apply(updates.plan_update(inserts=ins))
    before = walk_image.stats_snapshot()
    _assert_walk(g)
    after = walk_image.stats_snapshot()
    assert after["rebuilds"] == before["rebuilds"] + 1
    assert after["patches"] == before["patches"]


def test_snapshot_gets_private_image():
    c, rng = _make_csr()
    for name, cls in REPS:
        g = cls.from_csr(c)
        g.reverse_walk(STEPS)
        s = g.snapshot()
        plan = updates.plan_update(
            inserts=edgebatch.random_insertions(rng, c.n, 40)
        )
        g, _ = g.apply(plan)
        # the snapshot must keep walking the PRE-update graph
        np.testing.assert_allclose(
            np.asarray(s.reverse_walk(STEPS)),
            _oracle(s),
            rtol=1e-4,
            err_msg=name,
        )
        _assert_walk(g)


# ---------------------------------------------------------------------------
# multi-walk batching
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "backend,kw",
    [("xla", {}), ("pallas", {"interpret": True}), ("auto", {})],
)
def test_multi_walk_matches_stacked_singles(backend, kw):
    c, rng = _make_csr(n=96, m=700)
    g = REPRESENTATIONS["digraph"].from_csr(c)
    img = g.to_walk_image()
    v0 = np.abs(rng.normal(size=(3, img.nv))).astype(np.float32)
    a = (c.to_dense() != 0).astype(np.float64)[: img.nv, : img.nv]
    exp = np.stack([np.linalg.matrix_power(a, STEPS) @ v for v in v0])
    got = np.asarray(
        img.walk(STEPS, backend=backend, visits0=jnp.asarray(v0), **kw)
    )
    np.testing.assert_allclose(got, exp, rtol=1e-4)


def test_multi_walk_via_representation_entry():
    c, rng = _make_csr(n=64, m=400)
    for name, cls in REPS:
        g = cls.from_csr(c)
        nv = g.to_walk_image().nv
        v0 = np.ones((2, nv), np.float32)
        got = np.asarray(g.reverse_walk(STEPS, visits0=jnp.asarray(v0)))
        single = np.asarray(g.reverse_walk(STEPS))
        np.testing.assert_allclose(got[0], single, rtol=1e-4, err_msg=name)
        np.testing.assert_allclose(got[1], single, rtol=1e-4, err_msg=name)


def test_multi_walk_rejects_bad_shape():
    c, _ = _make_csr(n=32, m=100)
    img = REPRESENTATIONS["digraph"].from_csr(c).to_walk_image()
    with pytest.raises(ValueError):
        img.walk(STEPS, visits0=jnp.ones((img.nv,), jnp.float32))


# ---------------------------------------------------------------------------
# interleaved update/walk property sweep (hypothesis)
# ---------------------------------------------------------------------------
def test_interleaved_streams_match_dense_oracle_all_reps():
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency — pip install repro[dev]"
    )
    from hypothesis import given, settings, strategies as st

    op = st.tuples(
        st.integers(0, 2),  # 0 = mixed update, 1 = walk, 2 = hub insert
        st.integers(0, 1 << 30),
    )

    @settings(deadline=None, max_examples=12)
    @given(st.lists(op, min_size=2, max_size=6), st.integers(0, 1 << 30))
    def prop(ops, seed):
        rng = np.random.default_rng(seed)
        src, dst = synthetic.uniform_edges(rng, 24, 96)
        c = from_coo(src, dst, n=24)
        graphs = {name: cls.from_csr(c) for name, cls in REPS}
        for g in graphs.values():
            g.reverse_walk(2)  # everyone starts with a cached image
        for kind, opseed in ops:
            oprng = np.random.default_rng(opseed)
            if kind == 1:
                ref = None
                for name, g in graphs.items():
                    got = np.asarray(g.reverse_walk(3))
                    exp = _oracle(g, 3)
                    np.testing.assert_allclose(
                        got[: exp.shape[0]], exp, rtol=1e-4, err_msg=name
                    )
                    if ref is None:
                        ref = got
                continue
            if kind == 2:
                u = int(oprng.integers(0, 24))
                k = int(oprng.integers(8, 40))  # may outgrow the row's slack
                ins = edgebatch.from_arrays(
                    np.full(k, u, np.int64),
                    oprng.integers(0, 24, size=k).astype(np.int64),
                )
                plan = updates.plan_update(inserts=ins)
            else:
                half = int(oprng.integers(1, 8))
                any_csr = graphs["digraph"].to_csr()
                plan = updates.plan_update(
                    inserts=edgebatch.random_insertions(oprng, 24, half),
                    deletes=edgebatch.random_deletions(oprng, any_csr, half)
                    if any_csr.m
                    else None,
                )
            for name in graphs:
                graphs[name], _ = graphs[name].apply(plan)
        # final sweep: every rep, walk + edge content agree
        exp_sets = graphs["digraph"].to_edge_sets()
        for name, g in graphs.items():
            got = np.asarray(g.reverse_walk(3))
            exp = _oracle(g, 3)
            np.testing.assert_allclose(
                got[: exp.shape[0]], exp, rtol=1e-4, err_msg=name
            )
            sets = g.to_edge_sets()
            n_min = min(len(sets), len(exp_sets))
            assert [set(x) for x in sets[:n_min]] == [
                set(x) for x in exp_sets[:n_min]
            ], name

    prop()


# ---------------------------------------------------------------------------
# benchmark --compare gate (pure row-diff logic)
# ---------------------------------------------------------------------------
def test_compare_results_gates_all_reps_on_walk_suites():
    from benchmarks.run import compare_results

    base = {
        "traversal": [
            {"name": "walk/x/digraph", "us_per_call": 100.0},
            {"name": "walk/x/digraph_flat", "us_per_call": 100.0},
            {"name": "walk/x/coo", "us_per_call": 100.0},
        ],
        "update": [
            {"name": "upd/x/coo", "us_per_call": 100.0},
        ],
    }
    ok = {
        "traversal": [
            {"name": "walk/x/digraph", "us_per_call": 120.0},
            # the seed baseline row never gates
            {"name": "walk/x/digraph_flat", "us_per_call": 900.0},
            {"name": "walk/x/coo", "us_per_call": 129.0},
        ],
        # off the walk suites, non-digraph rows still don't gate
        "update": [{"name": "upd/x/coo", "us_per_call": 900.0}],
    }
    assert compare_results(ok, base) == []
    slow = {"traversal": [{"name": "walk/x/digraph", "us_per_call": 131.0}]}
    fails = compare_results(slow, base)
    assert len(fails) == 1 and "walk/x/digraph" in fails[0]
    # on traversal/stream EVERY representation's row gates (all five ride
    # the shared walk-image engine)
    slow_coo = {"traversal": [{"name": "walk/x/coo", "us_per_call": 131.0}]}
    fails = compare_results(slow_coo, base)
    assert len(fails) == 1 and "walk/x/coo" in fails[0]
    # unknown rows and missing columns are ignored, not errors
    odd = {"s": [{"name": "new/row", "us_per_call": 5.0}, {"name": "walk/x/digraph"}]}
    assert compare_results(odd, base) == []
    # sharded rows ride the same gate: the last /-token is the layout
    slow_sh = {
        "stream": [{"name": "stream/x/shards4/chunked", "us_per_round": 131.0}]
    }
    base_sh = {
        "stream": [{"name": "stream/x/shards4/chunked", "us_per_round": 100.0}]
    }
    fails = compare_results(slow_sh, base_sh)
    assert len(fails) == 1 and "shards4" in fails[0]


def test_merge_results_preserves_unreplayed_rows():
    """--json merge: re-measured rows replace in place, others survive."""
    from benchmarks.run import merge_results

    prev = {
        "stream": [
            {"name": "stream/x/digraph", "us_per_round": 10.0},
            {"name": "stream/x/shards4/digraph", "us_per_round": 40.0},
        ],
        "load": [{"name": "load/x", "us_per_call": 5.0}],
    }
    new = {
        "stream": [
            {"name": "stream/x/shards4/digraph", "us_per_round": 42.0},
            {"name": "stream/x/shards1/digraph", "us_per_round": 11.0},
        ]
    }
    out = merge_results(prev, new)
    # untouched suite passes through
    assert out["load"] == prev["load"]
    names = [r["name"] for r in out["stream"]]
    # existing order kept, replaced in place, new row appended
    assert names == [
        "stream/x/digraph",
        "stream/x/shards4/digraph",
        "stream/x/shards1/digraph",
    ]
    assert out["stream"][1]["us_per_round"] == 42.0
    assert out["stream"][0]["us_per_round"] == 10.0
    # suite absent from prev comes in whole
    assert merge_results({}, new) == new


# ---------------------------------------------------------------------------
# dense image compaction + fused flush→walk (DESIGN.md §12)
# ---------------------------------------------------------------------------
def test_chunked_dense_image_strips_page_slack():
    """ChunkedGraph's image compacts PAGE tails: occupancy ~1.0, dense
    parity with the oracle, and the dense layout keeps patching under an
    update stream (grown rows relocate into the deep bump reserve)."""
    c, rng = _make_csr(n=150, m=1200)
    g = REPRESENTATIONS["chunked"].from_csr(c)
    img = g.to_walk_image()
    assert img.occupancy > 0.95  # PAGE-quantized layout would be ~0.15
    assert img.base_occupancy > 0.95
    _assert_walk(g)
    for _ in range(3):
        plan = updates.plan_update(
            inserts=edgebatch.random_insertions(rng, c.n, 30),
            deletes=edgebatch.random_deletions(rng, g.to_csr(), 30),
        )
        g, _ = g.apply(plan)
        before = walk_image.stats_snapshot()
        _assert_walk(g)
        after = walk_image.stats_snapshot()
        assert after["patches"] == before["patches"] + 1
        assert after["builds"] == before["builds"]


@pytest.mark.parametrize("name,cls", REPS)
def test_fused_flush_walk_single_dispatch_equivalence(name, cls):
    """apply → walk must be ONE image-engine dispatch and bit-compatible
    with the eager patch-then-walk pipeline on a twin graph."""
    c, _ = _make_csr()
    rng_a = np.random.default_rng(5)
    rng_b = np.random.default_rng(5)
    ga = cls.from_csr(c)
    gb = cls.from_csr(c)
    ga.reverse_walk(STEPS)
    gb.reverse_walk(STEPS)
    plan_a = updates.plan_update(
        inserts=edgebatch.random_insertions(rng_a, c.n, 50),
        deletes=edgebatch.random_deletions(rng_a, c, 50),
    )
    plan_b = updates.plan_update(
        inserts=edgebatch.random_insertions(rng_b, c.n, 50),
        deletes=edgebatch.random_deletions(rng_b, c, 50),
    )
    ga, _ = ga.apply(plan_a)
    gb, _ = gb.apply(plan_b)
    # path A: fused flush→walk (reverse_walk on the dirty image)
    before = walk_image.stats_snapshot()
    va = np.asarray(ga.reverse_walk(STEPS))
    after = walk_image.stats_snapshot()
    assert after["dispatches"] - before["dispatches"] == 1, name
    # path B: eager flush (separate patch dispatch), then a plain walk
    vb = np.asarray(gb.to_walk_image().walk(STEPS))
    np.testing.assert_allclose(va, vb, rtol=1e-5, err_msg=name)
    np.testing.assert_allclose(va, _oracle(ga), rtol=1e-4, err_msg=name)


def test_walk_invariance_under_image_compaction():
    """Hypothesis sweep: dense and slack-padded images of the same CSR
    walk identically (compaction changes layout, never results)."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency — pip install repro[dev]"
    )
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=15)
    @given(
        st.integers(2, 40),
        st.integers(1, 160),
        st.integers(0, 1 << 30),
        st.integers(0, 3),
    )
    def prop(n, m, seed, steps):
        rng = np.random.default_rng(seed)
        src, dst = synthetic.uniform_edges(rng, n, m)
        c = from_coo(src, dst, n=n)
        offsets = np.asarray(c.offsets, np.int64)
        dstv = np.asarray(c.dst)
        wgtv = np.asarray(c.wgt) if c.wgt is not None else None
        dense = walk_image.WalkImage.from_csr_arrays(
            offsets, dstv, wgtv, n, dense=True
        )
        slack = walk_image.WalkImage.from_csr_arrays(
            offsets, dstv, wgtv, n, dense=False
        )
        assert dense.occupancy == 1.0 or dense.live == 0
        vd = np.asarray(dense.walk(steps))
        vs = np.asarray(slack.walk(steps))
        np.testing.assert_allclose(vd, vs, rtol=1e-5)
        exp = traversal.reverse_walk_dense_oracle(c.to_dense(), steps)
        np.testing.assert_allclose(vd[: exp.shape[0]], exp, rtol=1e-4)

    prop()


def test_single_walk_pallas_blocked_interpret_parity():
    """The interval walk's Pallas tile-cumsum engine == XLA engine."""
    c, _ = _make_csr(n=96, m=700)
    img = REPRESENTATIONS["digraph"].from_csr(c).to_walk_image()
    x = np.asarray(img.walk(STEPS, backend="xla"))
    p = np.asarray(img.walk(STEPS, backend="pallas", interpret=True))
    np.testing.assert_allclose(p, x, rtol=1e-5)
