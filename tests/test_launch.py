"""Launch-layer tests: HLO collective parser, spec rules, cell builders,
roofline model-flops sanity, e2e reduced training driver."""
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps


def test_collective_stats_parser():
    from repro.launch import dryrun

    hlo = """
HloModule test

%fused (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  ROOT %r = f32[128,256] add(%a, %a)
}

while_body_1 {
  %p = f32[64,64] parameter(0)
  %ar2 = f32[64,64] all-reduce(%p), replica_groups={}
  ROOT %t = f32[64,64] add(%ar2, %ar2)
}

ENTRY main {
  %x = f32[1024,1024] parameter(0)
  %y = bf16[512] parameter(1)
  %ag = bf16[8192] all-gather(%y), dimensions={0}
  %ar = f32[1024,1024] all-reduce(%x), to_apply=%sum
  ROOT %out = f32[1024,1024] add(%ar, %ar)
}
"""
    stats = dryrun.collective_stats(hlo)
    assert stats["total_bytes"]["all-gather"] == 512 * 2
    assert stats["total_bytes"]["all-reduce"] == 1024 * 1024 * 4 + 64 * 64 * 4
    assert stats["while_body_bytes"]["all-reduce"] == 64 * 64 * 4


def test_type_bytes():
    from repro.launch.dryrun import _type_bytes

    assert _type_bytes("f32[128,256]") == 128 * 256 * 4
    assert _type_bytes("bf16[10]") == 20
    assert _type_bytes("(f32[4], s32[2])") == 16 + 8
    assert _type_bytes("pred[]") == 1


def test_divisibility_guard_drops_axes():
    """tree_spec must replicate leaves whose dims don't divide the mesh."""
    import subprocess
    import sys
    import textwrap
    import os

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch import shardings, mesh as mesh_mod
        mesh = mesh_mod.make_mesh_like((2, 4), ("data", "model"))
        tree = {"ok": jnp.zeros((8, 4)), "odd": jnp.zeros((7, 4)),
                "scalar": jnp.zeros(())}
        out = shardings.tree_spec(tree, lambda p, m: P("data", None), mesh)
        assert out["ok"].spec == P("data", None), out["ok"].spec
        assert out["odd"].spec == P(None, None), out["odd"].spec
        print("guard-ok")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
        timeout=300,
    )
    assert "guard-ok" in r.stdout, r.stderr[-2000:]


def test_shard_map_compat_version_shim():
    """shard_map_compat must resolve the check kwarg on THIS jax and run."""
    import subprocess
    import sys
    import textwrap
    import os

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch import mesh as mesh_mod

        sm = mesh_mod._resolve_shard_map()
        assert callable(sm)
        # kwarg detection: inspectable signatures must name one spelling
        kw = mesh_mod._check_kwarg(sm)
        assert kw in ("check_vma", "check_rep", None), kw

        mesh = mesh_mod.host_mesh(4)
        f = mesh_mod.shard_map_compat(
            lambda x: jax.lax.psum(x, "data"),
            mesh=mesh, in_specs=P("data"), out_specs=P(), check=True)
        out = jax.jit(f)(jnp.arange(8, dtype=jnp.float32))
        assert float(out.sum()) == 28.0, out
        # check=False path compiles too (device-varying out under P())
        g = mesh_mod.shard_map_compat(
            lambda x: jax.lax.all_gather(x, "data", tiled=True),
            mesh=mesh, in_specs=P("data"), out_specs=P(), check=False)
        out2 = jax.jit(g)(jnp.arange(8, dtype=jnp.float32))
        assert out2.shape == (8,) and float(out2[5]) == 5.0
        print("shim-ok")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
        timeout=300,
    )
    assert "shim-ok" in r.stdout, r.stderr[-2000:]


def test_host_mesh_rejects_oversubscription():
    from repro.launch import mesh as mesh_mod

    import jax

    with pytest.raises(ValueError, match="host_mesh"):
        mesh_mod.host_mesh(len(jax.devices()) + 1)


@pytest.mark.parametrize("arch", ["gcn-cora", "h2o-danube-1.8b", "two-tower-retrieval"])
def test_build_cell_full_specs_are_abstract(arch):
    """Full-scale cells must be pure ShapeDtypeStructs (no allocation)."""
    import jax

    fam = cfgbase.get(arch).family
    shape = {"lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch"}[fam]
    cell = steps.build_cell(arch, shape, reduced=False)
    for leaf in jax.tree.leaves(
        cell.args, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    ):
        assert isinstance(leaf, jax.ShapeDtypeStruct) or not hasattr(leaf, "shape"), type(leaf)


def test_model_flops_matches_small_scale_hlo():
    """Closed-form MODEL_FLOPS validated against a compiled small model."""
    import dataclasses
    import functools
    import jax
    import jax.numpy as jnp
    from repro.models.gnn import graphcast
    from repro.train import loop, optimizer as opt

    cfg = graphcast.GraphCastConfig(n_layers=3, d_hidden=32, n_vars=8)
    n, e = 256, 1024
    rng = np.random.default_rng(0)
    g = {
        "node_feat": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "labels": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
    }
    params = graphcast.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt.OptimizerConfig()
    state = loop.init_state(params, ocfg)
    step = loop.make_train_step(lambda p, b: graphcast.loss_fn(p, b, cfg), ocfg)
    c = jax.jit(step).lower(state, g).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns one dict per device
        ca = ca[0]
    hlo = ca["flops"]
    d, nv = cfg.d_hidden, cfg.n_vars
    fwd = 2 * n * (nv * d + d * d) * 2 + cfg.n_layers * (
        2 * e * (3 * d * d + d * d) + 2 * n * (2 * d * d + d * d)
    )
    assert 0.5 < hlo / (3 * fwd) < 2.0, hlo / (3 * fwd)


def test_train_driver_e2e(tmp_path):
    """launch/train.py reduces loss and restarts from checkpoints."""
    from repro.launch import train as train_mod

    ck = str(tmp_path / "ck")
    losses = train_mod.main(
        ["--arch", "gcn-cora", "--steps", "25", "--ckpt-dir", ck,
         "--ckpt-every", "10", "--log-every", "10"]
    )
    assert losses[-1] < losses[0]
    # resume path
    losses2 = train_mod.main(
        ["--arch", "gcn-cora", "--steps", "5", "--ckpt-dir", ck, "--resume"]
    )
    assert losses2[0] <= losses[0]


def test_all_cell_variants_buildable():
    """Every non-skipped cell × its roofline variants constructs."""
    from repro.launch import dryrun

    for arch, shape, skip in cfgbase.all_cells():
        if skip:
            continue
        for v in dryrun.variants_for(arch, shape):
            if v.startswith("opt"):
                cell = steps.build_opt_cell(arch, variant=v)
            else:
                cell = steps.build_cell(arch, shape, variant=v)
            assert cell.step_fn is not None
