"""Batch-update engine tests: UpdatePlan, slot_update parity, apply().

Covers the three layers of DESIGN.md §9: host planning (canonical op
stream, runs, cache), the fused device merge (Pallas-interpret vs XLA vs
the numpy oracle), and the mixed-batch ``apply`` entry point on every
representation.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    REPRESENTATIONS,
    DiGraph,
    edgebatch,
    from_coo,
    traversal,
    updates,
    util,
)
from repro.io import synthetic
from repro.kernels.slot_update import ops as su_ops
from repro.kernels.slot_update.ref import merge_rows_reference

SENT = util.SENTINEL
REPS = list(REPRESENTATIONS.items())


# ---------------------------------------------------------------------------
# host planning layer
# ---------------------------------------------------------------------------
def test_plan_canonicalization_insert_wins():
    ins = edgebatch.from_arrays([3, 3, 1], [5, 9, 2], [1.0, 2.0, 3.0])
    dele = edgebatch.from_arrays([3, 2, 3], [9, 4, 5])  # (3,9),(3,5) clash
    p = updates.plan_update(inserts=ins, deletes=dele)
    ops = set(zip(p.q_src.tolist(), p.q_dst.tolist(), p.q_del.tolist()))
    assert (3, 9, False) in ops and (3, 5, False) in ops  # inserts won
    assert (2, 4, True) in ops
    assert p.n_del == 1 and p.n_ins == 3
    # ascending (src, dst), one op per key
    keys = list(zip(p.q_src.tolist(), p.q_dst.tolist()))
    assert keys == sorted(keys) and len(keys) == len(set(keys))


def test_plan_runs_and_tiles():
    ins = edgebatch.from_arrays([7, 7, 7, 0], [1, 2, 3, 9])
    p = updates.plan_update(inserts=ins)
    assert p.rows.tolist() == [0, 7]
    assert p.run_count.tolist() == [1, 3]
    assert p.ins_count.tolist() == [1, 3]
    assert p.run_width == 4
    bd, bw, bl = p.run_tiles(np.arange(2), 4, a_pad=4)
    assert bd.shape == (4, 4)
    assert bd[1, :3].tolist() == [1, 2, 3]
    assert (bd[0, 1:] == SENT).all()
    assert (bd[2:] == SENT).all()  # pad rows
    assert bl.sum() == 0
    # a subset selection only materializes its own rows
    bd7, _, _ = p.run_tiles(np.array([1]), 4)
    assert bd7.shape == (1, 4) and bd7[0, :3].tolist() == [1, 2, 3]


def test_plan_enforces_one_op_per_key():
    """dedup=False batches with duplicate keys must not corrupt a plan."""
    ins = edgebatch.from_arrays([0, 0], [5, 5], [1.0, 2.0], dedup=False)
    p = updates.plan_update(inserts=ins)
    assert p.n_ops == 1 and p.q_wgt[0] == pytest.approx(1.0)  # first wins
    g = DiGraph.from_csr(from_coo([0], [1], n=2))
    g, dm = g.apply(p)
    assert dm == 1 and g.m == 2
    row = g.edges_of(0)
    assert row.tolist() == [1, 5] and (np.diff(row) > 0).all()


def test_plan_cache_identity():
    ins = edgebatch.from_arrays([1], [2])
    p1 = updates.plan_update(inserts=ins)
    assert updates.plan_update(inserts=ins) is p1
    # a different batch object builds a fresh plan
    ins2 = edgebatch.from_arrays([1], [2])
    assert updates.plan_update(inserts=ins2) is not p1


def test_empty_plan():
    p = updates.plan_update()
    assert p.n_ops == 0 and p.n_rows == 0
    for name, cls in REPS:
        g = cls.from_csr(from_coo([0], [1], n=4))
        g2, dm = g.apply(p)
        assert dm == 0


# ---------------------------------------------------------------------------
# EdgeBatch validation (satellite)
# ---------------------------------------------------------------------------
def test_edgebatch_rejects_negative_ids():
    with pytest.raises(ValueError, match="negative"):
        edgebatch.from_arrays([-1], [2])
    with pytest.raises(ValueError, match="negative"):
        edgebatch.from_arrays([1], [-2])


def test_edgebatch_rejects_overflow_and_bad_dtypes():
    with pytest.raises(ValueError, match="overflow"):
        edgebatch.from_arrays([2**31 - 1], [0])
    with pytest.raises(ValueError, match="non-integral"):
        edgebatch.from_arrays([1.5], [0])
    with pytest.raises(TypeError):
        edgebatch.from_arrays(["a"], [0])
    with pytest.raises(ValueError, match="mismatch"):
        edgebatch.from_arrays([1, 2], [0])


def test_edgebatch_accepts_integral_floats_and_int64():
    b = edgebatch.from_arrays(np.array([1.0, 2.0]), np.array([3, 4], np.int64))
    assert b.n == 2 and b.src.dtype == jnp.int32


def test_dedup_arrays_keep_first_last():
    s = np.array([1, 1, 0], np.int32)
    d = np.array([2, 2, 5], np.int32)
    w = np.array([10.0, 20.0, 30.0], np.float32)
    s1, d1, w1 = edgebatch.dedup_arrays(s, d, w, keep="first")
    assert w1.tolist() == [30.0, 10.0]
    s2, d2, w2 = edgebatch.dedup_arrays(s, d, w, keep="last")
    assert w2.tolist() == [30.0, 20.0]


# ---------------------------------------------------------------------------
# device merge parity: xla == pallas(interpret) == numpy oracle
# ---------------------------------------------------------------------------
def _random_merge_case(rng, a=8, w=64, k=8):
    d_rows = np.full((a, w), SENT, np.int32)
    w_rows = np.zeros((a, w), np.float32)
    degs = rng.integers(0, w // 2, a).astype(np.int32)
    for i in range(a):
        vals = np.sort(rng.choice(500, degs[i], replace=False)).astype(np.int32)
        d_rows[i, : degs[i]] = vals
        w_rows[i, : degs[i]] = rng.random(degs[i])
    b_d = np.full((a, k), SENT, np.int32)
    b_w = np.zeros((a, k), np.float32)
    b_l = np.zeros((a, k), np.int32)
    for i in range(a):
        kk = int(rng.integers(0, k + 1))
        pool = np.concatenate([d_rows[i, : degs[i]], rng.choice(500, 10)])
        vals = np.unique(rng.choice(pool, kk)) if kk else np.empty(0, np.int64)
        b_d[i, : len(vals)] = vals
        b_w[i, : len(vals)] = rng.random(len(vals))
        b_l[i, : len(vals)] = rng.integers(0, 2, len(vals))
    return d_rows, w_rows, degs, b_d, b_w, b_l


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_rows_backend_parity(seed):
    rng = np.random.default_rng(seed)
    case = _random_merge_case(rng)
    exp_d, exp_w, exp_c = merge_rows_reference(*case)
    args = tuple(jnp.asarray(x) for x in case)
    for backend, kw in (("xla", {}), ("pallas", {"interpret": True})):
        od, ow, cnt = su_ops.merge_rows(*args, backend=backend, **kw)
        np.testing.assert_array_equal(np.asarray(od), exp_d, err_msg=backend)
        np.testing.assert_allclose(np.asarray(ow), exp_w, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(cnt), exp_c)


# ---------------------------------------------------------------------------
# mixed-batch apply on every representation
# ---------------------------------------------------------------------------
def _apply_oracle(sets, plan):
    for s, d, dl in zip(plan.q_src, plan.q_dst, plan.q_del):
        while len(sets) <= int(s) or len(sets) <= int(d):
            sets.append(set())
        if dl:
            sets[int(s)].discard(int(d))
        else:
            sets[int(s)].add(int(d))
    return sets


@pytest.mark.parametrize("name,cls", REPS)
def test_apply_mixed_batch_vs_oracle(name, cls):
    rng = np.random.default_rng(23)
    n = 48
    src, dst = synthetic.uniform_edges(rng, n, 300)
    c = from_coo(src, dst, n=n)
    g = cls.from_csr(c)
    sets = [set(x) for x in c.to_edge_sets()]
    for _ in range(4):
        ins = edgebatch.random_insertions(rng, n, 25)
        dele = edgebatch.random_deletions(rng, g.to_csr(), 20)
        plan = updates.plan_update(inserts=ins, deletes=dele)
        g, dm = g.apply(plan)
        sets = _apply_oracle(sets, plan)
        got = g.to_edge_sets()
        while len(got) < len(sets):
            got.append(set())
        assert got[: len(sets)] == sets, f"{name}: mixed apply diverged"


@pytest.mark.parametrize("name,cls", REPS)
def test_apply_delete_then_reinsert_same_key(name, cls):
    """A key in both halves of one mixed batch ends up present (upsert)."""
    c = from_coo([0, 0], [1, 2], [1.0, 2.0], n=3)
    g = cls.from_csr(c)
    plan = updates.plan_update(
        inserts=edgebatch.from_arrays([0], [1], [9.0]),
        deletes=edgebatch.from_arrays([0, 0], [1, 2]),
    )
    g, dm = g.apply(plan)
    cc = g.to_csr()
    assert g.to_edge_sets()[0] == {1}, f"{name}: insert did not win"
    i0, i1 = int(np.asarray(cc.offsets)[0]), int(np.asarray(cc.offsets)[1])
    ww = dict(
        zip(np.asarray(cc.dst)[i0:i1].tolist(), np.asarray(cc.wgt)[i0:i1].tolist())
    )
    assert ww[1] == pytest.approx(9.0), f"{name}: weight not upserted"


@pytest.mark.parametrize("name,cls", REPS)
def test_walk_after_mixed_apply(name, cls):
    rng = np.random.default_rng(31)
    n = 40
    src, dst = synthetic.uniform_edges(rng, n, 240)
    c = from_coo(src, dst, n=n)
    g = cls.from_csr(c)
    plan = updates.plan_update(
        inserts=edgebatch.random_insertions(rng, n, 30),
        deletes=edgebatch.random_deletions(rng, c, 25),
    )
    g, _ = g.apply(plan)
    cc = g.to_csr()
    exp = traversal.reverse_walk_dense_oracle(cc.to_dense(), 4)
    got = np.asarray(g.reverse_walk(4))[: cc.n]
    np.testing.assert_allclose(got, exp, rtol=1e-5)


def test_digraph_apply_grow_path_mixed():
    """Mixed plan whose inserts force CP2AA block moves inside apply."""
    rng = np.random.default_rng(5)
    n = 32
    src, dst = synthetic.uniform_edges(rng, n, 150)
    c = from_coo(src, dst, n=n)
    g = DiGraph.from_csr(c)
    relayouts0 = g.stats.relayouts
    # a hub row gains many edges (growth) while others lose some
    ins = edgebatch.from_arrays(np.zeros(40, np.int64), 100 + np.arange(40))
    dele = edgebatch.random_deletions(rng, c, 30)
    g, dm = g.apply(updates.plan_update(inserts=ins, deletes=dele))
    assert g.stats.relayouts > relayouts0
    assert g.degree(0) >= 40
    row = g.edges_of(0)
    assert (np.diff(row) > 0).all()  # ascending invariant held
    # delete-only rows beyond cap_v are filtered, not fatal
    g, dm2 = g.apply(
        updates.plan_update(deletes=edgebatch.from_arrays([10 * n], [1]))
    )
    assert dm2 == 0


def test_edgebatch_rejects_wgt_length_mismatch():
    with pytest.raises(ValueError, match="wgt length"):
        edgebatch.from_arrays([0, 1], [2, 3], [9.0, 8.0, 7.0])
    with pytest.raises(ValueError, match="wgt length"):
        edgebatch.from_arrays([0, 1], [2, 3], [9.0])


def test_digraph_scatter_writeback_path(monkeypatch):
    """Force the per-group scatter write-back (the TPU/big-arena path)."""
    monkeypatch.setattr(su_ops, "REBUILD_MAX_CAP", 0)
    rng = np.random.default_rng(41)
    n = 48
    src, dst = synthetic.uniform_edges(rng, n, 300)
    c = from_coo(src, dst, n=n)
    g = DiGraph.from_csr(c)
    sets = [set(x) for x in c.to_edge_sets()]
    for _ in range(3):
        # hub growth + random churn exercises block moves in scatter mode
        ins = edgebatch.from_arrays(
            np.concatenate([np.zeros(20, np.int64), rng.integers(0, n, 15)]),
            np.concatenate([200 + rng.integers(0, 500, 20), rng.integers(0, n, 15)]),
        )
        dele = edgebatch.random_deletions(rng, g.to_csr(), 20)
        plan = updates.plan_update(inserts=ins, deletes=dele)
        g, _ = g.apply(plan)
        sets = _apply_oracle(sets, plan)
        got = g.to_edge_sets()
        while len(got) < len(sets):
            got.append(set())
        assert got[: len(sets)] == sets, "scatter path diverged"
    # arena invariants: packed ascending rows, SENTINEL tails
    dstbuf = np.asarray(g.dst)
    for u in range(g.cap_v):
        cp, s, d_ = int(g.capacities[u]), int(g.starts[u]), int(g.degrees[u])
        if cp == 0:
            assert d_ == 0
            continue
        row = dstbuf[s : s + cp]
        live = row[row != SENT]
        assert live.shape[0] == d_
        assert (row[d_:] == SENT).all()
    assert g.m == int(g.degrees.sum())


def test_digraph_apply_net_dm_sign():
    c = from_coo([0, 0, 1], [1, 2, 2], n=3)
    g = DiGraph.from_csr(c)
    plan = updates.plan_update(
        inserts=edgebatch.from_arrays([2], [0]),
        deletes=edgebatch.from_arrays([0, 0], [1, 2]),
    )
    g, dm = g.apply(plan)
    assert dm == -1  # +1 insert, -2 deletes
    assert g.m == 2


def test_coo_galloping_merge_mixed_oracle():
    """The sort-free SortedCOO rebuild (DESIGN.md §12): deletes, weight
    upserts and interleaved new keys land exactly where the old
    full-re-sort put them, across several churn rounds."""
    rng = np.random.default_rng(77)
    n = 40
    src, dst = synthetic.uniform_edges(rng, n, 220)
    c = from_coo(src, dst, n=n)
    g = REPRESENTATIONS["coo"].from_csr(c)
    sets = [set(x) for x in c.to_edge_sets()]
    for _ in range(4):
        ins = edgebatch.random_insertions(rng, n, 25)
        dele = edgebatch.random_deletions(rng, g.to_csr(), 25)
        plan = updates.plan_update(inserts=ins, deletes=dele)
        g, _ = g.apply(plan)
        sets = _apply_oracle(sets, plan)
        got = g.to_edge_sets()
        while len(got) < len(sets):
            got.append(set())
        assert got[: len(sets)] == sets
        # the rebuilt buffer stays (src, dst)-lexsorted with SENTINEL tail
        s = np.asarray(g.src)
        d = np.asarray(g.dst)
        keys = (s[: g.m].astype(np.int64) << 32) | d[: g.m].astype(np.int64)
        assert (np.diff(keys) > 0).all()
        assert (s[g.m :] == SENT).all()


def test_coo_merge_weight_upsert_in_place():
    """Re-inserting an existing edge replaces its weight, no duplicate."""
    g = REPRESENTATIONS["coo"].from_csr(
        from_coo([0, 0, 1], [1, 2, 0], [1.0, 2.0, 3.0], n=3)
    )
    g, dm = g.apply(
        updates.plan_update(
            inserts=edgebatch.from_arrays([0], [2], [9.5])
        )
    )
    assert dm == 0 and g.m == 3
    s, d, w = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.wgt)
    i = int(np.nonzero((s == 0) & (d == 2))[0][0])
    assert w[i] == np.float32(9.5)
