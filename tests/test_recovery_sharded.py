"""Sharded-scale recovery engine (DESIGN.md §15): owner-routed parallel
WAL replay, group commit, and differential checkpoints.

The crash matrix crosses injection points × {single-device, ShardedGraph
S∈{2,4}} × {full, differential} checkpoints (plus a torn group-commit
tail) and requires the recovered graph to be bit-identical to an
uncrashed twin — dense CSR equality AND exact walk equality — with the
per-shard + cross-boundary audit clean.
"""
import os

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.core import REPRESENTATIONS, csr as csr_mod, edgebatch, updates
from repro.core import distributed as dist
from repro.runtime import durable, faultinject

N_V = 48
CRASH_POINTS = ("durable.pre_append", "durable.post_append", "durable.post_apply")


@pytest.fixture(autouse=True)
def _clean_fault_state():
    faultinject.disarm()
    yield
    faultinject.disarm()


@pytest.fixture(scope="module")
def base_csr():
    rng = np.random.default_rng(11)
    m = 220
    return csr_mod.from_coo(
        rng.integers(0, N_V, m),
        rng.integers(0, N_V, m),
        rng.random(m).astype(np.float32),
        n=N_V,
    )


def make_plans(k=6, seed=7, n=N_V):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        ib = edgebatch.from_arrays(
            rng.integers(0, n, 12),
            rng.integers(0, n, 12),
            rng.random(12).astype(np.float32),
        )
        db = edgebatch.from_arrays(rng.integers(0, n, 6), rng.integers(0, n, 6))
        out.append(updates.plan_update(inserts=ib, deletes=db))
    return out


def assert_sharded_parity(g: dist.ShardedGraph, twin: dist.ShardedGraph):
    """Bit-identity at the content level: gathered CSR streams AND the
    exact (unweighted small-integer) walk outputs must match."""
    ca, cb = dist.gather_csr(g), dist.gather_csr(twin)
    np.testing.assert_array_equal(np.asarray(ca.offsets), np.asarray(cb.offsets))
    np.testing.assert_array_equal(
        np.asarray(ca.dst)[: ca.m], np.asarray(cb.dst)[: cb.m]
    )
    np.testing.assert_array_equal(
        np.asarray(ca.wgt)[: ca.m], np.asarray(cb.wgt)[: cb.m]
    )
    np.testing.assert_array_equal(
        np.asarray(g.reverse_walk(3)), np.asarray(twin.reverse_walk(3))
    )


# ---------------------------------------------------------------------------
# the crash matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("diff", [False, True])
def test_sharded_crash_matrix(base_csr, tmp_path, point, n_shards, diff):
    """Crash at every pipeline point × shard width × checkpoint kind;
    parallel owner-routed replay must reproduce the uncrashed twin."""
    wd, cd = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    g = durable.DurableGraph(
        dist.shard_csr(base_csr, n_shards), wd, cd, diff=diff, full_every=4
    )
    twin = dist.shard_csr(base_csr, n_shards)
    plans = make_plans(6, seed=29)
    kcrash = 3
    faultinject.arm(point, after=kcrash)
    survived = 0
    try:
        for i, p in enumerate(plans):
            g.apply(p)
            survived = i + 1
            if i == 1:
                g.checkpoint()  # mid-stream snapshot (diff or full)
    except faultinject.SimulatedCrash:
        pass
    else:
        raise AssertionError("crash point never fired")
    faultinject.disarm(point)
    # pre_append dies before the record is durable; the post_* points die
    # after it — the twin must replay exactly the durable prefix
    upto = kcrash if point == "durable.pre_append" else kcrash + 1
    for p in plans[:upto]:
        twin.apply(p)
    g2 = durable.DurableGraph.recover(wd, cd, parallel=True, diff=diff)
    assert g2.rep_name == "sharded"
    assert_sharded_parity(g2.rep, twin)
    g2.rep.audit()


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("rep_name", ["digraph", "lazy"])
def test_single_device_diff_crash_matrix(base_csr, tmp_path, point, rep_name):
    """The §13 single-device matrix, rerun over differential checkpoints
    (hash-compare dirty detection)."""
    wd, cd = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    rep = REPRESENTATIONS[rep_name].from_csr(base_csr)
    g = durable.DurableGraph(rep, wd, cd, diff=True, full_every=3)
    twin = REPRESENTATIONS[rep_name].from_csr(base_csr)
    plans = make_plans(6, seed=41)
    kcrash = 3
    faultinject.arm(point, after=kcrash)
    try:
        for i, p in enumerate(plans):
            g.apply(p)
            if i in (0, 2):
                g.checkpoint()  # two diff steps on the chain
    except faultinject.SimulatedCrash:
        pass
    else:
        raise AssertionError("crash point never fired")
    faultinject.disarm(point)
    upto = kcrash if point == "durable.pre_append" else kcrash + 1
    for p in plans[:upto]:
        twin, _ = twin.apply(p)
    g2 = durable.DurableGraph.recover(wd, cd, diff=True)
    c1, c2 = g2.to_csr(), twin.to_csr()
    np.testing.assert_array_equal(np.asarray(c1.offsets), np.asarray(c2.offsets))
    np.testing.assert_array_equal(
        np.asarray(c1.dst)[: c1.m], np.asarray(c2.dst)[: c2.m]
    )
    np.testing.assert_array_equal(
        np.asarray(c1.wgt)[: c1.m], np.asarray(c2.wgt)[: c2.m]
    )
    faultinject.audit(g2.rep)


# ---------------------------------------------------------------------------
# group commit
# ---------------------------------------------------------------------------
def test_group_commit_one_flush_per_round(base_csr, tmp_path):
    g = durable.DurableGraph(
        dist.shard_csr(base_csr, 2), str(tmp_path / "w"), str(tmp_path / "c")
    )
    twin = dist.shard_csr(base_csr, 2)
    plans = make_plans(9, seed=53)
    for r in range(3):
        round_plans = plans[3 * r : 3 * r + 3]
        f0 = g.journal.flushes
        g.apply_group(round_plans)
        assert g.journal.flushes - f0 == 1
        for p in round_plans:
            twin.apply(p)
    # seqs are contiguous and individually framed: ordinary replay parity
    assert g.seq == 9
    g2 = durable.DurableGraph.recover(
        str(tmp_path / "w"), str(tmp_path / "c"), parallel=True
    )
    assert_sharded_parity(g2.rep, twin)


def test_group_commit_empty_and_filtered(base_csr, tmp_path):
    g = durable.DurableGraph(
        REPRESENTATIONS["digraph"].from_csr(base_csr),
        str(tmp_path / "w"), str(tmp_path / "c"),
    )
    _, dm = g.apply_group([])
    assert dm == 0 and g.seq == 0
    empty = updates.plan_update()
    g.apply_group([empty, empty])
    assert g.seq == 0 and g.journal.flushes == 0


def test_torn_group_commit_tail(base_csr, tmp_path):
    """Tear bytes off a group's suffix: recovery keeps the complete
    record prefix (never acked past it) and stays bit-identical."""
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(dist.shard_csr(base_csr, 2), wd, cd)
    twin = dist.shard_csr(base_csr, 2)
    plans = make_plans(6, seed=61)
    g.apply_group(plans[:3])
    for p in plans[:3]:
        twin.apply(p)
    g.apply_group(plans[3:])  # this group's tail gets torn
    g.close()
    seg = g.journal.segments()[-1]
    # tear into the middle of the last record's payload
    faultinject.tear_tail(seg, 17)
    g2 = durable.DurableGraph.recover(wd, cd, parallel=True)
    for p in plans[3:5]:  # records 4, 5 survived; record 6 was torn off
        twin.apply(p)
    assert g2.seq == 5
    assert_sharded_parity(g2.rep, twin)
    g2.rep.audit()


# ---------------------------------------------------------------------------
# parallel replay semantics
# ---------------------------------------------------------------------------
def test_parallel_matches_serial_replay(base_csr, tmp_path):
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(dist.shard_csr(base_csr, 4), wd, cd)
    for p in make_plans(8, seed=67):
        g.apply(p)
    gp = durable.DurableGraph.recover(wd, cd, parallel=True)
    gs = durable.DurableGraph.recover(wd, cd, parallel=False)
    assert_sharded_parity(gp.rep, gs.rep)
    assert gp.seq == gs.seq == 8


def test_parallel_replay_growth_epochs(base_csr, tmp_path):
    """Growth records fence the fan-out: records after a growth see the
    re-sharded geometry, exactly like the live path."""
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(dist.shard_csr(base_csr, 2), wd, cd)
    twin = dist.shard_csr(base_csr, 2)
    gb = edgebatch.from_arrays(
        np.array([N_V + 9, 5]), np.array([5, N_V + 9]), np.ones(2, np.float32)
    )
    stream = (
        make_plans(2, seed=71)
        + [updates.plan_update(inserts=gb)]
        + make_plans(2, seed=73, n=N_V + 10)
    )
    for p in stream:
        g.apply(p)
        twin.apply(p)
    g2 = durable.DurableGraph.recover(wd, cd, parallel=True)
    assert g2.rep.n == twin.n == N_V + 10
    assert_sharded_parity(g2.rep, twin)


def test_recover_stats_surface(base_csr, tmp_path):
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(dist.shard_csr(base_csr, 2), wd, cd)
    for p in make_plans(4, seed=79):
        g.apply(p)
    stats = {}
    durable.DurableGraph.recover(wd, cd, parallel=True, stats=stats)
    assert stats["records"] == 4
    assert stats["restore_s"] >= 0 and stats["replay_s"] >= 0


# ---------------------------------------------------------------------------
# differential checkpoints through the wrapper
# ---------------------------------------------------------------------------
def test_diff_chain_compacts_to_full(base_csr, tmp_path):
    """full_every bounds the chain: every k-th snapshot re-anchors."""
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(
        dist.shard_csr(base_csr, 2), wd, cd, diff=True, full_every=2
    )
    for p in make_plans(6, seed=83):
        g.apply(p)
        g.checkpoint()
    kinds = []
    for s in ckpt.all_steps(cd):
        kinds.append(
            ckpt._read_manifest(ckpt._step_dir(cd, s)).get("kind", "full")
        )
    assert "diff" in kinds and kinds.count("full") >= 2
    # every step on disk is a complete restore point
    for s in ckpt.all_steps(cd):
        trees, _ = ckpt.restore_arrays_diff(cd, step=s)
        assert set(trees) == {0, 1}


def test_diff_dirty_hints_shrink_payload(base_csr, tmp_path):
    """Tracked sharded diffs persist far less than the full state."""
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(
        dist.shard_csr(base_csr, 4), wd, cd, diff=True, full_every=8
    )
    full_bytes = sum(
        sum(np.asarray(v).nbytes for v in t.values())
        for t in g.rep.state_trees().values()
    )
    # one tiny plan → one diff step whose payload is a few chunks
    ib = edgebatch.from_arrays(
        np.array([1, 2]), np.array([3, 4]), np.ones(2, np.float32)
    )
    g.apply(updates.plan_update(inserts=ib))
    path = g.checkpoint()
    man = ckpt._read_manifest(path)
    assert man["kind"] == "diff"
    diff_bytes = sum(b.get("diff_bytes", 0) for b in man["shards"].values())
    assert 0 < diff_bytes < full_bytes / 2
    # untouched shards persisted nothing (no npz file at all)
    clean = [
        s for s in man["shards"]
        if man["shards"][s]["diff_bytes"] == 0
        and not os.path.exists(os.path.join(path, f"shard_{s}.npz"))
    ]
    assert len(clean) >= 2
    # and the diff restores bit-identically
    g2 = durable.DurableGraph.recover(wd, cd, diff=True)
    assert_sharded_parity(g2.rep, g.rep)


def test_post_recovery_checkpoint_is_full(base_csr, tmp_path):
    """Replay applies are untracked → the next snapshot re-anchors."""
    wd, cd = str(tmp_path / "w"), str(tmp_path / "c")
    g = durable.DurableGraph(
        dist.shard_csr(base_csr, 2), wd, cd, diff=True, full_every=8
    )
    for p in make_plans(3, seed=89):
        g.apply(p)
    g2 = durable.DurableGraph.recover(wd, cd, diff=True, full_every=8)
    path = g2.checkpoint()
    assert ckpt._read_manifest(path)["kind"] == "full"
