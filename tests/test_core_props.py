"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency — pip install repro[dev]"
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    REPRESENTATIONS,
    alloc,
    edgebatch,
    from_coo,
    traversal,
    updates,
    util,
)
import jax.numpy as jnp


# --- allocator policy (paper Alg 11 lines 30-33) ---------------------------
@given(st.integers(min_value=0, max_value=1 << 24))
def test_allocation_size_policy(nbytes):
    a = alloc.allocation_size(nbytes)
    assert a >= max(nbytes, alloc.MIN_ALLOC_BYTES)
    if nbytes <= 16:
        assert a == 16
    elif nbytes < 8192:
        assert a == alloc.next_pow2(nbytes) and (a & (a - 1)) == 0
    else:
        assert a % alloc.PAGE_SIZE == 0 and a - nbytes < alloc.PAGE_SIZE


@given(st.lists(st.integers(min_value=0, max_value=1 << 20), min_size=1, max_size=50))
def test_edge_capacities_vector_matches_scalar(degrees):
    vec = alloc.edge_capacities(np.array(degrees))
    for d, v in zip(degrees, vec):
        assert v == alloc.edge_capacity(d)
        assert v >= max(d, 1)


@given(st.integers(min_value=0, max_value=1 << 30))
def test_next_pow2(n):
    p = alloc.next_pow2(n)
    assert p >= max(n, 1) and (p & (p - 1)) == 0
    if n > 1:
        assert p < 2 * n


# --- util invariants --------------------------------------------------------
@given(
    st.lists(
        st.tuples(st.integers(0, 30), st.integers(0, 30)), min_size=1, max_size=64
    )
)
@settings(deadline=None, max_examples=30)
def test_searchsorted_2d_membership(pairs):
    arr = sorted(set(pairs))
    s = jnp.array([p[0] for p in arr], jnp.int32)
    d = jnp.array([p[1] for p in arr], jnp.int32)
    qs = jnp.array([p[0] for p in pairs], jnp.int32)
    qd = jnp.array([p[1] + 1 for p in pairs], jnp.int32)  # half perturbed
    pos, found = util.searchsorted_2d(s, d, qs, qd)
    for i, p in enumerate(pairs):
        assert bool(found[i]) == ((p[0], p[1] + 1) in set(arr))


@given(
    st.lists(st.integers(0, 100), min_size=1, max_size=40),
    st.lists(st.integers(0, 100), min_size=1, max_size=40),
)
@settings(deadline=None, max_examples=30)
def test_binsearch_window(row, queries):
    row = sorted(set(row))
    flat = jnp.array(row + [0] * 5, jnp.int32)  # trailing garbage outside window
    lo = jnp.zeros(len(queries), jnp.int32)
    hi = jnp.full(len(queries), len(row), jnp.int32)
    pos, found = util.binsearch_window(flat, lo, hi, jnp.array(queries, jnp.int32))
    for i, q in enumerate(queries):
        assert bool(found[i]) == (q in row)
        if found[i]:
            assert row[int(pos[i])] == q


# --- representation algebra: union/difference are set ops -------------------
edge_lists = st.lists(
    st.tuples(st.integers(0, 15), st.integers(0, 15)), min_size=0, max_size=60
)


@given(base=edge_lists, ins=edge_lists, rem=edge_lists)
@settings(deadline=None, max_examples=20)
def test_update_algebra_all_reps(base, ins, rem):
    n = 16
    base_set = set(base)
    if base_set:
        bs, bd = zip(*sorted(base_set))
    else:
        bs, bd = (), ()
    c = from_coo(np.array(bs + (0,))[: len(bs)] if bs else np.empty(0, np.int64),
                 np.array(bd)[: len(bd)] if bd else np.empty(0, np.int64),
                 n=n)
    for name, cls in REPRESENTATIONS.items():
        g = cls.from_csr(c)
        expect = set(base_set)
        if ins:
            b = edgebatch.from_arrays([e[0] for e in ins], [e[1] for e in ins])
            g, _ = g.add_edges(b)
            expect |= set(ins)
        if rem:
            b = edgebatch.from_arrays([e[0] for e in rem], [e[1] for e in rem])
            g, _ = g.remove_edges(b)
            expect -= set(rem)
        got = set()
        for u, row in enumerate(g.to_edge_sets()):
            got |= {(u, v) for v in row}
        assert got == expect, f"{name}: set algebra violated"


# --- interleaved streaming property: all reps vs a numpy CSR oracle ---------
stream_rounds = st.lists(
    st.tuples(edge_lists, edge_lists, st.booleans()),  # (inserts, deletes, walk?)
    min_size=1,
    max_size=4,
)


@given(rounds=stream_rounds)
@settings(deadline=None, max_examples=12)
def test_interleaved_stream_all_reps_vs_csr_oracle(rounds):
    """Random mixed insert/delete/walk streams through apply(UpdatePlan).

    The oracle is a dense numpy adjacency: mixed-batch semantics (one op
    per key, insert wins over delete) applied per round, with
    reverse-walk equivalence checked whenever the stream asks for it —
    the paper's interleaved update/traversal regime end-to-end.
    """
    n = 16
    adj = np.zeros((n, n), bool)
    adj[0, 1] = True  # non-empty seed graph
    c = from_coo([0], [1], n=n)
    graphs = {name: cls.from_csr(c) for name, cls in REPRESENTATIONS.items()}
    for ins, rem, do_walk in rounds:
        ins_b = edgebatch.from_arrays(
            [e[0] for e in ins], [e[1] for e in ins]
        ) if ins else None
        rem_b = edgebatch.from_arrays(
            [e[0] for e in rem], [e[1] for e in rem]
        ) if rem else None
        plan = updates.plan_update(inserts=ins_b, deletes=rem_b)
        # oracle: deletes first, inserts win conflicts
        for s, d, dl in zip(plan.q_src, plan.q_dst, plan.q_del):
            adj[int(s), int(d)] = not dl
        expect = [set(np.nonzero(adj[u])[0].tolist()) for u in range(n)]
        for name, g in graphs.items():
            g, _ = g.apply(plan)
            graphs[name] = g
            got = g.to_edge_sets()
            while len(got) < n:
                got.append(set())
            assert got[:n] == expect, f"{name}: stream diverged"
        if do_walk:
            walk_exp = traversal.reverse_walk_dense_oracle(adj, 3)
            for name, g in graphs.items():
                got = np.asarray(g.reverse_walk(3))
                got = np.pad(got, (0, max(n - got.shape[0], 0)))[:n]
                np.testing.assert_allclose(
                    got, walk_exp, rtol=1e-5, err_msg=f"{name}: walk diverged"
                )


# --- DiGraph structural invariants ------------------------------------------
@given(ins=edge_lists, rem=edge_lists)
@settings(deadline=None, max_examples=20)
def test_digraph_invariants(ins, rem):
    from repro.core import DiGraph

    g = DiGraph.empty(16)
    if ins:
        g, _ = g.add_edges(edgebatch.from_arrays([e[0] for e in ins], [e[1] for e in ins]))
    if rem:
        g, _ = g.remove_edges(edgebatch.from_arrays([e[0] for e in rem], [e[1] for e in rem]))
    dst = np.asarray(g.dst)
    for u in range(g.cap_v):
        cap, start, deg = g.capacities[u], g.starts[u], g.degrees[u]
        if cap == 0:
            assert deg == 0
            continue
        # pow-2 class invariant (CP2AA policy)
        assert cap == alloc.edge_capacity(max(deg, 1)) or cap >= deg
        row = dst[start : start + cap]
        live = row[row != util.SENTINEL]
        assert live.shape[0] == deg
        assert (np.diff(live) > 0).all() if live.shape[0] > 1 else True
        # live entries packed to the left
        assert (row[deg:] == util.SENTINEL).all()
    # edge count consistency
    assert g.m == int(g.degrees.sum())
