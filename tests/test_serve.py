"""Multi-tenant walk serving (DESIGN.md §16): snapshot-isolated
generations, admission control / backpressure / deadline shedding,
graceful degradation through the breaker chain, the half-open breaker
protocol, and the fault-injected zero-lost contract."""
import threading
import time

import numpy as np
import pytest

from repro.core import REPRESENTATIONS, csr as csr_mod, edgebatch, updates, walk_image
from repro.kernels import fallback
from repro.launch import serve as launch_serve
from repro.runtime import faultinject
from repro.runtime import serve as serve_mod

N_V = 48


@pytest.fixture(autouse=True)
def _clean_fault_state():
    faultinject.disarm()
    fallback.BREAKER.reset()
    fallback.LAST_USED.clear()
    yield
    faultinject.disarm()
    fallback.BREAKER.reset()
    fallback.LAST_USED.clear()


@pytest.fixture(scope="module")
def base_csr():
    rng = np.random.default_rng(23)
    m = 220
    return csr_mod.from_coo(
        rng.integers(0, N_V, m),
        rng.integers(0, N_V, m),
        rng.random(m).astype(np.float32),
        n=N_V,
    )


def make_plan(rng, n=N_V, n_ins=12, n_del=6):
    ib = edgebatch.from_arrays(
        rng.integers(0, n, n_ins),
        rng.integers(0, n, n_ins),
        rng.random(n_ins).astype(np.float32),
    )
    db = edgebatch.from_arrays(
        rng.integers(0, n, n_del), rng.integers(0, n, n_del)
    )
    return updates.plan_update(inserts=ib, deletes=db)


def serve_and_verify(rep_kind, base, *, requests=24, update_every=4,
                     seed=3, **server_kw):
    """Run mixed traffic against ``rep_kind`` and return (stats, torn,
    checked) with the zero-lost ledger already asserted."""
    rep = REPRESENTATIONS[rep_kind].from_csr(base)
    srv = serve_mod.WalkServer(rep, **server_kw).start()
    rng = np.random.default_rng(seed)
    walks, upds = [], []
    for i in range(requests):
        if update_every and i % update_every == 0:
            plan = make_plan(rng)
            upds.append((srv.submit_update(plan), plan))
        walks.append(srv.submit_walk(rng.integers(0, N_V, 3), steps=3))
    for t in walks:
        assert t.wait(60.0)
    stats = srv.stop()
    srv.assert_no_lost()
    torn, checked = launch_serve.count_torn_reads(
        launch_serve.GenerationOracle(base), walks, upds
    )
    return stats, torn, checked


# ---------------------------------------------------------------------------
# snapshot isolation: every served walk is consistent with its generation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rep_kind", ["digraph", "chunked"])
def test_served_walks_match_generation_oracle(rep_kind, base_csr):
    stats, torn, checked = serve_and_verify(rep_kind, base_csr)
    assert stats["served"] == 24
    assert checked == 24 and torn == 0
    assert stats["seals"] >= 2  # updates actually advanced generations


@pytest.mark.parametrize("rep_kind", ["coo", "lazy", "vector2d"])
def test_served_walks_match_oracle_all_reps(rep_kind, base_csr):
    stats, torn, checked = serve_and_verify(
        rep_kind, base_csr, requests=12, update_every=3
    )
    assert torn == 0 and checked == stats["served"] == 12


def test_sealed_generation_immutable_under_writer(base_csr):
    """The COW contract directly: a sealed generation's walk result must
    not change while the live rep keeps applying plans."""
    for kind in ("digraph", "chunked"):
        rep = REPRESENTATIONS[kind].from_csr(base_csr)
        gen = walk_image.seal_generation(rep, 1)
        before = np.asarray(gen.walk(3)).copy()
        rng = np.random.default_rng(5)
        for _ in range(4):
            rep, _ = rep.apply(make_plan(rng))
            rep.reverse_walk(2)  # force flush/patch of the live image
        np.testing.assert_array_equal(np.asarray(gen.walk(3)), before)


def test_seal_api_guards(base_csr):
    rep = REPRESENTATIONS["chunked"].from_csr(base_csr)
    img = rep.to_walk_image()
    gen = img.seal(7)
    assert gen.generation == 7 and gen._frozen
    with pytest.raises(RuntimeError, match="read-only"):
        gen.queue(make_plan(np.random.default_rng(0)))
    img.queue(make_plan(np.random.default_rng(1)))
    with pytest.raises(ValueError, match="unflushed"):
        img.seal(8)
    shared = REPRESENTATIONS["digraph"].from_csr(base_csr).to_walk_image()
    with pytest.raises(ValueError, match="shared"):
        shared.seal(9)


def test_concurrent_reader_writer_sweep(base_csr):
    """Deterministic concurrent sweep (always runs): a writer thread
    applies+seals while reader threads walk; every served walk must
    match the oracle for its own sealed generation — no torn reads."""
    for kind in ("digraph", "chunked"):
        rep = REPRESENTATIONS[kind].from_csr(base_csr)
        srv = serve_mod.WalkServer(rep, batch_max=4).start()
        rng = np.random.default_rng(17)
        upds, walks, stop = [], [], threading.Event()
        lock = threading.Lock()

        def reader(seed):
            r = np.random.default_rng(seed)
            while not stop.is_set():
                t = srv.submit_walk(r.integers(0, N_V, 2), steps=2)
                t.wait(30.0)
                with lock:
                    walks.append(t)

        threads = [
            threading.Thread(target=reader, args=(s,)) for s in (31, 32, 33)
        ]
        for th in threads:
            th.start()
        for _ in range(8):
            plan = make_plan(rng)
            upds.append((srv.submit_update(plan), plan))
            time.sleep(0.01)
        for t, _ in upds:
            assert t.wait(30.0)
        stop.set()
        for th in threads:
            th.join(30.0)
        srv.stop()
        srv.assert_no_lost()
        torn, checked = launch_serve.count_torn_reads(
            launch_serve.GenerationOracle(base_csr), walks, upds
        )
        assert checked > 0 and torn == 0, kind


def test_hypothesis_reader_writer_sweep(base_csr):
    """Hypothesis-driven schedules over the same contract (gated: the
    container may not ship hypothesis)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=5, deadline=None)
    @hyp.given(
        seed=st.integers(0, 2**16),
        n_updates=st.integers(1, 6),
        batch_max=st.sampled_from([1, 2, 8]),
        rep_kind=st.sampled_from(["digraph", "chunked"]),
    )
    def inner(seed, n_updates, batch_max, rep_kind):
        rep = REPRESENTATIONS[rep_kind].from_csr(base_csr)
        srv = serve_mod.WalkServer(rep, batch_max=batch_max).start()
        rng = np.random.default_rng(seed)
        walks, upds = [], []
        for _ in range(n_updates):
            plan = make_plan(rng)
            upds.append((srv.submit_update(plan), plan))
            for _ in range(int(rng.integers(1, 4))):
                walks.append(
                    srv.submit_walk(rng.integers(0, N_V, 2), steps=2)
                )
        for t in walks:
            assert t.wait(60.0)
        srv.stop()
        srv.assert_no_lost()
        torn, checked = launch_serve.count_torn_reads(
            launch_serve.GenerationOracle(base_csr), walks, upds
        )
        assert torn == 0 and checked == len(walks)

    inner()


# ---------------------------------------------------------------------------
# admission control: backpressure, deadlines, shedding
# ---------------------------------------------------------------------------


def test_backpressure_rejects_with_retry_after(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep, max_queue=4, batch_max=2).start()
    tickets = [srv.submit_walk([1, 2], steps=2) for _ in range(120)]
    for t in tickets:
        assert t.wait(60.0)
    stats = srv.stop()
    srv.assert_no_lost()
    rejected = [t for t in tickets if t.status == serve_mod.REJECTED]
    assert stats["rejected_backpressure"] == len(rejected) > 0
    for t in rejected:
        assert t.reason == "backpressure"
        assert t.retry_after is not None and t.retry_after > 0
        with pytest.raises(serve_mod.RejectedError, match="backpressure"):
            t.result()


def test_expired_requests_are_shed_not_walked(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep, batch_max=1, max_queue=512).start()
    tickets = [
        srv.submit_walk([1], steps=2, timeout=1e-4) for _ in range(60)
    ]
    for t in tickets:
        assert t.wait(60.0)
    stats = srv.stop()
    srv.assert_no_lost()
    assert stats["shed_expired"] > 0
    shed = [t for t in tickets if t.reason == "expired"]
    assert len(shed) == stats["shed_expired"]


def test_bad_seeds_rejected_cleanly(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep).start()
    bad = srv.submit_walk([N_V + 100], steps=2)
    ok = srv.submit_walk([1], steps=2)
    assert bad.wait(30.0) and ok.wait(30.0)
    srv.stop()
    srv.assert_no_lost()
    assert bad.status == serve_mod.REJECTED
    assert bad.reason == "seed_out_of_range"
    assert ok.status == serve_mod.SERVED


def test_shutdown_rejects_new_requests(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep).start()
    srv.stop()
    t = srv.submit_walk([1], steps=2)
    assert t.status == serve_mod.REJECTED and t.reason == "shutdown"
    srv.assert_no_lost()


# ---------------------------------------------------------------------------
# fault-injected audits: enqueue / seal / dispatch boundaries
# ---------------------------------------------------------------------------


def test_enqueue_fault_is_clean_rejection(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep).start()
    faultinject.arm("serve.enqueue", times=1)
    t1 = srv.submit_walk([1], steps=2)
    t2 = srv.submit_walk([2], steps=2)
    assert t1.status == serve_mod.REJECTED and t1.reason == "enqueue_fault"
    assert t2.wait(30.0) and t2.status == serve_mod.SERVED
    faultinject.disarm()
    srv.stop()
    srv.assert_no_lost()


def test_dispatch_fault_retried_zero_lost(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep).start()
    faultinject.arm("serve.dispatch", times=1)
    tickets = [srv.submit_walk([1, 2], steps=2) for _ in range(8)]
    for t in tickets:
        assert t.wait(60.0)
    stats = srv.stop()
    faultinject.disarm()
    srv.assert_no_lost()
    assert stats["served"] == 8 and stats["dispatch_retries"] >= 1


def test_dispatch_fault_exhausted_fails_visibly(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep, dispatch_retries=1).start()
    faultinject.arm("serve.dispatch", times=50)
    t = srv.submit_walk([1], steps=2)
    assert t.wait(60.0)
    stats = srv.stop()
    faultinject.disarm()
    srv.assert_no_lost()
    assert t.status == serve_mod.FAILED and stats["failed"] == 1
    with pytest.raises(RuntimeError, match="request failed"):
        t.result()


def test_seal_fault_keeps_readers_on_previous_generation(base_csr):
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep).start()
    faultinject.arm("serve.seal", times=1)
    plan = make_plan(np.random.default_rng(9))
    ut = srv.submit_update(plan)
    assert ut.wait(30.0)  # writer retried the seal and acked
    wt = srv.submit_walk([1, 2], steps=2)
    assert wt.wait(30.0)
    stats = srv.stop()
    faultinject.disarm()
    srv.assert_no_lost()
    assert stats["seal_failures"] >= 1
    assert ut.status == serve_mod.SERVED and ut.generation == 1
    assert wt.generation >= 1
    torn, checked = launch_serve.count_torn_reads(
        launch_serve.GenerationOracle(base_csr), [wt], [(ut, plan)]
    )
    assert checked == 1 and torn == 0


def test_pallas_trip_mid_traffic_served_via_fallback(base_csr):
    """ISSUE acceptance: an injected pallas failure mid-traffic completes
    via the breaker chain with zero lost requests."""
    rep = REPRESENTATIONS["digraph"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep, walk_backend="pallas", batch_max=4).start()
    faultinject.arm("slot_walk.pallas", times=2)
    tickets = [srv.submit_walk([1, 2], steps=2) for _ in range(12)]
    for t in tickets:
        assert t.wait(60.0)
    stats = srv.stop()
    faultinject.disarm()
    srv.assert_no_lost()
    assert stats["served"] == 12
    assert stats["breaker_fallbacks"] >= 1
    assert fallback.LAST_USED.get("slot_walk") in ("xla", "ref")


@pytest.mark.timeout(120)
def test_serve_soak_mixed_traffic(base_csr):
    """Soak: sustained mixed traffic with a mid-run injected dispatch
    fault; everything resolves, torn_reads == 0 (explicit per-test
    timeout so a queue bug can never hang tier-1)."""
    rep = REPRESENTATIONS["chunked"].from_csr(base_csr)
    srv = serve_mod.WalkServer(rep, batch_max=8, max_queue=64).start()
    rng = np.random.default_rng(41)
    walks, upds = [], []
    for i in range(120):
        if i % 6 == 0:
            plan = make_plan(rng)
            upds.append((srv.submit_update(plan), plan))
        if i == 60:
            faultinject.arm("serve.dispatch", times=2)
        walks.append(srv.submit_walk(rng.integers(0, N_V, 2), steps=2))
    for t in walks:
        assert t.wait(120.0)
    srv.stop()
    faultinject.disarm()
    stats = srv.assert_no_lost()
    torn, checked = launch_serve.count_torn_reads(
        launch_serve.GenerationOracle(base_csr), walks, upds
    )
    assert torn == 0 and checked == stats["served"] > 0


# ---------------------------------------------------------------------------
# half-open circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_half_open_probe_then_close():
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(
        cooldown=1.0, max_cooldown=8.0, clock=lambda: t["now"]
    )
    key = ("site", "pallas")
    assert br.admit(key) == "closed"
    br.trip(key)
    assert br.admit(key) is None  # open
    t["now"] = 1.1
    assert br.admit(key) == "probe"  # half-open: single probe admitted
    assert br.admit(key) is None  # second caller refused while probing
    br.record_success(key)  # probe succeeded
    assert br.admit(key) == "closed" and br.state(key) is None


def test_breaker_probe_failure_retrips_with_backoff():
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(
        cooldown=1.0, max_cooldown=8.0, clock=lambda: t["now"]
    )
    key = ("site", "xla")
    br.trip(key)
    t["now"] = 1.1
    assert br.admit(key) == "probe"
    br.trip(key)  # probe failed: re-trip, cooldown doubles
    assert br.admit(key) is None
    t["now"] = 1.1 + 1.9
    assert br.admit(key) is None  # still inside the doubled window
    t["now"] = 1.1 + 2.1
    assert br.admit(key) == "probe"


def test_breaker_stranded_probe_expires():
    """A probe whose thread died must not strand the backend half-open."""
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(cooldown=1.0, clock=lambda: t["now"])
    key = ("site", "pallas")
    br.trip(key)
    t["now"] = 1.1
    assert br.admit(key) == "probe"
    # the probe never reports back; after one base cooldown the slot frees
    t["now"] = 2.2
    assert br.admit(key) == "probe"


def test_run_chain_probe_gets_single_attempt():
    """A half-open probe gets exactly one attempt (no retry-once), so a
    still-broken backend costs one failure before falling through."""
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(cooldown=1.0, clock=lambda: t["now"])
    calls = []

    def attempt(b):
        calls.append(b)
        if b == "xla":
            raise RuntimeError("xla down")
        return "ok"

    out, used = fallback.run_chain("s", "xla", attempt, breaker=br)
    assert used == "ref" and calls.count("xla") == 2  # closed: retry-once
    calls.clear()
    t["now"] = 1.1  # xla half-open now
    out, used = fallback.run_chain("s", "xla", attempt, breaker=br)
    assert used == "ref" and calls.count("xla") == 1  # probe: one attempt


def test_run_chain_probe_success_repromotes():
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(cooldown=1.0, clock=lambda: t["now"])
    healthy = {"xla": False}

    def attempt(b):
        if b == "xla" and not healthy["xla"]:
            raise RuntimeError("down")
        return b

    out, used = fallback.run_chain("s2", "xla", attempt, breaker=br)
    assert used == "ref"
    healthy["xla"] = True
    t["now"] = 1.1
    out, used = fallback.run_chain("s2", "xla", attempt, breaker=br)
    assert used == "xla" and br.state(("s2", "xla")) is None


def test_breaker_thread_safety_smoke():
    """Concurrent admit/trip/record_success must not corrupt state."""
    br = fallback.CircuitBreaker(cooldown=1e-4)
    key = ("s", "b")
    errs = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(300):
                mode = br.admit(key)
                if mode and rng.random() < 0.5:
                    br.trip(key)
                elif mode:
                    br.record_success(key)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(30.0)
    assert not errs


# ---------------------------------------------------------------------------
# validation messages name the offending indices
# ---------------------------------------------------------------------------


def test_edgebatch_nonfinite_weights_name_indices():
    w = np.ones(8, np.float32)
    w[2] = np.nan
    w[5] = np.inf
    with pytest.raises(ValueError, match=r"wgt: non-finite edge weights at "
                                         r"indices \[2, 5\]"):
        edgebatch.from_arrays(np.arange(8), np.arange(8), w)


def test_edgebatch_negative_ids_name_indices():
    src = np.arange(8)
    src[1] = -3
    src[4] = -7
    with pytest.raises(ValueError, match=r"src: negative vertex ids at "
                                         r"indices \[1, 4\].*-3"):
        edgebatch.from_arrays(src, np.arange(8))


def test_edgebatch_index_lists_truncate():
    w = np.full(16, np.nan, np.float32)
    with pytest.raises(ValueError, match=r"\(\+11 more\)"):
        edgebatch.from_arrays(np.arange(16), np.arange(16), w)


def test_edgebatch_length_mismatch_names_arrays():
    with pytest.raises(ValueError, match="wgt has 3 weights for 5 edges"):
        edgebatch.from_arrays(
            np.arange(5), np.arange(5), np.ones(3, np.float32)
        )


def test_updateplan_validation_names_indices():
    q_src = np.array([0, 1], np.int32)
    q_dst = np.array([1, 2], np.int32)
    q_wgt = np.array([1.0, np.nan], np.float32)
    q_del = np.array([False, False])
    with pytest.raises(ValueError, match=r"q_wgt at indices \[1\]"):
        updates.plan_from_canonical(q_src, q_dst, q_wgt, q_del).validate()


# ---------------------------------------------------------------------------
# faultinject leak guard plumbing
# ---------------------------------------------------------------------------


def test_faultinject_armed_introspection():
    assert faultinject.armed() == ()
    faultinject.arm("serve.enqueue", times=1)
    faultinject.arm("serve.seal", times=1)
    assert faultinject.armed() == ("serve.enqueue", "serve.seal")
    faultinject.disarm("serve.enqueue")
    assert faultinject.armed() == ("serve.seal",)
    faultinject.disarm()
    assert faultinject.armed() == ()
