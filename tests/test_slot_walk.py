"""slot_walk engine parity on UPDATED graphs (interpret mode, CPU).

The interesting inputs are post-update slotted buffers: dead SENTINEL
slots after deletions, stale ``slot_rows`` on freed blocks, and moved
blocks after insert-driven growth — exactly the states the fused kernel's
run-rank trick must survive.  All paths are checked against the dense
numpy oracle and against the full-buffer jnp reference.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import DiGraph, edgebatch, from_coo, traversal
from repro.core.digraph import COMPACT_MIN_SLOTS
from repro.io import synthetic
from repro.kernels.slot_walk import ops as sw_ops
from repro.kernels.slot_walk.ref import slot_walk_reference

STEPS = 4


def _make_graph(n=300, m=2400, seed=3):
    rng = np.random.default_rng(seed)
    src, dst = synthetic.uniform_edges(rng, n, m)
    return from_coo(src, dst, n=n), rng


def _oracle(g: DiGraph, steps: int) -> np.ndarray:
    nv = g.n_max_vertex() + 1
    return traversal.reverse_walk_dense_oracle(g.to_csr().to_dense(), steps)[:nv]


def _assert_walk_parity(g: DiGraph, steps: int = STEPS):
    nv = g.n_max_vertex() + 1
    exp = _oracle(g, steps)
    for backend, kw in (("pallas", {"interpret": True}), ("xla", {})):
        got = np.asarray(
            traversal.reverse_walk_slotted(
                g.dst, g.slot_rows, steps, nv, backend=backend, **kw
            )
        )
        np.testing.assert_allclose(got, exp, rtol=1e-4, err_msg=backend)
    ref = np.asarray(slot_walk_reference(g.dst, g.slot_rows, steps, nv))
    np.testing.assert_allclose(ref, exp, rtol=1e-4)


def test_parity_fresh_graph():
    c, _ = _make_graph()
    _assert_walk_parity(DiGraph.from_csr(c))


def test_parity_post_delete_dead_slots():
    """Heavy deletion leaves dead SENTINEL slots + stale slot_rows."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    g, dm = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 3))
    assert dm > 0 and g.live_fraction < 1.0
    _assert_walk_parity(g)


def test_parity_post_insert_block_growth():
    """Dense insert batch forces CP2AA block moves (stale freed blocks)."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    relayouts0 = g.stats.relayouts
    g, dm = g.add_edges(edgebatch.random_insertions(rng, c.n, c.m))
    assert dm > 0 and g.stats.relayouts > relayouts0
    _assert_walk_parity(g)


def test_parity_delete_then_insert_churn():
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    for _ in range(3):
        g, _ = g.remove_edges(edgebatch.random_deletions(rng, g.to_csr(), g.m // 4))
        g, _ = g.add_edges(edgebatch.random_insertions(rng, c.n, c.m // 5))
    _assert_walk_parity(g)


def test_edges_hi_prefix_matches_full_buffer():
    """Walking only the bump prefix must equal walking the whole buffer."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 5))
    nv = g.n_max_vertex() + 1
    full = np.asarray(
        sw_ops.slot_walk(g.dst, g.slot_rows, STEPS, nv, backend="xla")
    )
    from repro.core import alloc

    hi = min(alloc.next_pow2(max(int(g.layout.bump), 1)), g.cap_e)
    pref = np.asarray(
        sw_ops.slot_walk(
            g.dst, g.slot_rows, STEPS, nv, edges_hi=hi, backend="xla"
        )
    )
    np.testing.assert_allclose(pref, full, rtol=1e-5)


def test_blocked_prefix_sum_path_parity():
    """Scatter-free block-interval path == segment-sum path on churned graphs."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 3))
    g, _ = g.add_edges(edgebatch.random_insertions(rng, c.n, c.m // 4))
    nv = g.n_max_vertex() + 1
    starts = g.starts[:nv]
    has = starts >= 0
    lo = jnp.asarray(np.where(has, starts, 0).astype(np.int32))
    hi = jnp.asarray(np.where(has, starts + g.degrees[:nv], 0).astype(np.int32))
    blocked = np.asarray(
        sw_ops.slot_walk(
            g.dst, g.slot_rows, STEPS, nv,
            backend="xla", block_lo=lo, block_hi=hi,
        )
    )
    plain = np.asarray(
        sw_ops.slot_walk(g.dst, g.slot_rows, STEPS, nv, backend="xla")
    )
    np.testing.assert_allclose(blocked, plain, rtol=1e-4)
    np.testing.assert_allclose(blocked, _oracle(g, STEPS), rtol=1e-4)


def test_blocked_path_no_prefix_cancellation():
    """Large prefix totals must not leak float error into small row sums.

    Regression: a naive global f32 cumsum gave P[hi]-P[lo] errors of
    ~ulp(total) (≈0.6% rel on this flow); the two-level compensated
    prefix keeps integer-valued counts exact.
    """
    rng = np.random.default_rng(1)
    src, dst = synthetic.uniform_edges(rng, 1024, 10240)
    c = from_coo(src, dst, n=1024)
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, int(c.m * 0.85)))
    got = np.asarray(g.reverse_walk(6, auto_compact=False))
    exp = _oracle(g, 6)
    np.testing.assert_allclose(got, exp, rtol=1e-6)


def test_compaction_invariance():
    """Walk result (and edge sets) identical before/after compact()."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 2))
    before_walk = np.asarray(g.reverse_walk(STEPS, auto_compact=False))
    before_sets = g.to_edge_sets()
    before_m = g.m
    reclaimed = g.compact()
    assert reclaimed >= 0
    assert g.m == before_m
    assert g.layout.bump <= g.cap_e
    after_walk = np.asarray(g.reverse_walk(STEPS, auto_compact=False))
    np.testing.assert_allclose(after_walk, before_walk, rtol=1e-4)
    assert g.to_edge_sets() == before_sets
    _assert_walk_parity(g)


def test_auto_compact_triggers_on_heavy_delete():
    c, rng = _make_graph(n=200, m=4000, seed=9)
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, int(c.m * 0.8)))
    assert g.layout.bump >= COMPACT_MIN_SLOTS
    assert g.live_fraction < 0.5
    exp = _oracle(g, STEPS)
    got = np.asarray(g.reverse_walk(STEPS))  # auto_compact=True default
    assert g.live_fraction >= 0.5  # compaction ran and repacked the prefix
    np.testing.assert_allclose(got, exp, rtol=1e-4)


def test_updates_after_compaction():
    """Compaction must leave a graph that still accepts updates."""
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, c, c.m // 2))
    g.compact()
    g, dm = g.add_edges(edgebatch.random_insertions(rng, c.n, c.m // 4))
    assert dm > 0
    _assert_walk_parity(g)


def test_to_csr_memoized_and_invalidated():
    c, rng = _make_graph()
    g = DiGraph.from_csr(c)
    a = g.to_csr()
    assert g.to_csr() is a  # cached
    g, _ = g.add_edges(edgebatch.random_insertions(rng, c.n, 10))
    b = g.to_csr()
    assert b is not a  # invalidated by mutation
    assert b.m == g.m


def test_empty_and_tiny_graphs():
    g = DiGraph.empty(4)
    nv = 4
    got = np.asarray(
        sw_ops.slot_walk(
            g.dst, g.slot_rows, 3, nv, backend="pallas", interpret=True
        )
    )
    np.testing.assert_allclose(got, 0.0)
    g, _ = g.add_edges(edgebatch.from_arrays([0, 1, 2], [1, 2, 3]))
    _assert_walk_parity(g, steps=3)
