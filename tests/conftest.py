import os
import sys

# tests run on the single real CPU device (dry-run is the only place that
# forces 512 placeholder devices — see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
