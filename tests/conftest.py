import os
import sys

import pytest

# tests run on the single real CPU device (dry-run is the only place that
# forces 512 placeholder devices — see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime import faultinject  # noqa: E402

#: suite-level deadline (seconds) applied when pytest-timeout is installed;
#: generous — the slowest legitimate tests (sharded sweeps) run ~60s cold.
DEFAULT_TIMEOUT = 300


def pytest_collection_modifyitems(config, items):
    # Apply a suite-level timeout default only when the pytest-timeout
    # plugin is actually present (it is a [dev] extra, not a hard dep):
    # fault-injection and serve-queue tests then can never hang tier-1.
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if item.get_closest_marker("timeout") is None:
            item.add_marker(pytest.mark.timeout(DEFAULT_TIMEOUT))


@pytest.fixture(autouse=True)
def _faultinject_leak_guard():
    """Fail any test that leaks armed faultinject points.

    A point armed by a test that failed (or returned) before its
    ``disarm()`` would otherwise fire inside an unrelated later test and
    misattribute the failure.  Leftovers are cleared *and* reported.
    """
    faultinject.disarm()
    yield
    leaked = faultinject.armed()
    if leaked:
        faultinject.disarm()
        pytest.fail(f"test leaked armed faultinject points: {leaked}")
