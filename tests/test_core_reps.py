"""System behaviour tests: every representation vs a python-set oracle.

Covers the paper's four tasks — build/load, clone/snapshot, batch
insert/delete (in-place and new-instance), traversal — on every
representation in the registry.
"""
import numpy as np
import pytest

from repro.core import (
    REPRESENTATIONS,
    edgebatch,
    from_coo,
    traversal,
)
from repro.io import synthetic

REPS = list(REPRESENTATIONS.items())


def _oracle_csr(oracle):
    srcs, dsts = [], []
    for i, x in enumerate(oracle):
        for v in x:
            srcs.append(i)
            dsts.append(v)
    if not srcs:
        return None
    return from_coo(np.array(srcs), np.array(dsts), n=len(oracle))


def _edge_sets(g, min_len):
    got = g.to_edge_sets()
    while len(got) < min_len:
        got.append(set())
    return got


@pytest.mark.parametrize("name,cls", REPS)
def test_from_csr_roundtrip(name, cls):
    rng = np.random.default_rng(0)
    src, dst = synthetic.uniform_edges(rng, 64, 400)
    c = from_coo(src, dst, n=64)
    g = cls.from_csr(c)
    assert _edge_sets(g, c.n)[: c.n] == c.to_edge_sets()


@pytest.mark.parametrize("name,cls", REPS)
@pytest.mark.parametrize("seed", [3, 7, 11])
def test_random_update_sequence_vs_oracle(name, cls, seed):
    rng = np.random.default_rng(seed)
    n = 40
    src, dst = synthetic.uniform_edges(rng, n, 200)
    c = from_coo(src, dst, n=n)
    g = cls.from_csr(c)
    oracle = [set(x) for x in c.to_edge_sets()]
    for it in range(10):
        if rng.integers(0, 2) == 0:
            b = edgebatch.random_insertions(
                rng, n + int(rng.integers(0, 4)), int(rng.integers(1, 30))
            )
            s, d, _ = b.to_numpy()
            g, _ = g.add_edges(b, inplace=True)
            need = int(max(s.max(initial=0), d.max(initial=0))) + 1
            while len(oracle) < need:
                oracle.append(set())
            for u, v in zip(s.tolist(), d.tolist()):
                oracle[u].add(v)
        else:
            cc = _oracle_csr(oracle)
            if cc is None or cc.m == 0:
                continue
            b = edgebatch.random_deletions(
                rng, cc, int(rng.integers(1, min(30, cc.m + 1)))
            )
            s, d, _ = b.to_numpy()
            g, _ = g.remove_edges(b, inplace=True)
            for u, v in zip(s.tolist(), d.tolist()):
                if u < len(oracle):
                    oracle[u].discard(v)
        got = _edge_sets(g, len(oracle))
        exp = [set(x) for x in oracle] + [set()] * (len(got) - len(oracle))
        assert got == exp, f"{name} diverged at iter {it}"


@pytest.mark.parametrize("name,cls", REPS)
def test_new_instance_updates_leave_original(name, cls):
    rng = np.random.default_rng(5)
    src, dst = synthetic.uniform_edges(rng, 32, 150)
    c = from_coo(src, dst, n=32)
    g = cls.from_csr(c)
    before = g.to_edge_sets()
    b = edgebatch.random_insertions(rng, 32, 20)
    g2, _ = g.add_edges(b, inplace=False)
    assert g.to_edge_sets() == before, f"{name}: original mutated"
    s, d, _ = b.to_numpy()
    exp = [set(x) for x in before]
    for u, v in zip(s.tolist(), d.tolist()):
        exp[u].add(v)
    assert _edge_sets(g2, 32)[:32] == exp


@pytest.mark.parametrize("name,cls", REPS)
def test_snapshot_isolation(name, cls):
    rng = np.random.default_rng(9)
    src, dst = synthetic.uniform_edges(rng, 32, 150)
    c = from_coo(src, dst, n=32)
    g = cls.from_csr(c)
    snap = g.snapshot()
    before = [sorted(x) for x in snap.to_edge_sets()]
    g, _ = g.add_edges(edgebatch.random_insertions(rng, 32, 25), inplace=True)
    g, _ = g.remove_edges(
        edgebatch.random_deletions(rng, g.to_csr(), 10), inplace=True
    )
    after = [sorted(x) for x in snap.to_edge_sets()]
    assert before == after, f"{name}: snapshot saw later updates"


@pytest.mark.parametrize("name,cls", REPS)
def test_clone_independence(name, cls):
    rng = np.random.default_rng(13)
    src, dst = synthetic.uniform_edges(rng, 32, 150)
    g = cls.from_csr(from_coo(src, dst, n=32))
    cl = g.clone()
    g, _ = g.add_edges(edgebatch.random_insertions(rng, 32, 25), inplace=True)
    assert cl.to_csr().m != g.to_csr().m or cl.to_edge_sets() != g.to_edge_sets()


@pytest.mark.parametrize("name,cls", REPS)
def test_reverse_walk_matches_dense_oracle(name, cls):
    rng = np.random.default_rng(17)
    src, dst = synthetic.uniform_edges(rng, 48, 250)
    c = from_coo(src, dst, n=48)
    g = cls.from_csr(c)
    # walk on an UPDATED graph (paper §4.2.5: traversal after batch updates)
    g, _ = g.add_edges(edgebatch.random_insertions(rng, 48, 30), inplace=True)
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, g.to_csr(), 20), inplace=True)
    cc = g.to_csr()
    oracle = traversal.reverse_walk_dense_oracle(cc.to_dense(), 5)
    got = np.asarray(g.reverse_walk(5))[: cc.n]
    np.testing.assert_allclose(got, oracle, rtol=1e-5)


def test_weight_upsert_semantics():
    """Re-inserting an existing edge updates its weight (documented policy)."""
    src, dst, w = [0, 0, 1], [1, 2, 2], [1.0, 2.0, 3.0]
    c = from_coo(src, dst, w, n=3)
    for name, cls in REPS:
        g = cls.from_csr(c)
        b = edgebatch.from_arrays([0], [1], [9.0])
        g, dm = g.add_edges(b, inplace=True)
        cc = g.to_csr()
        i = int(np.asarray(cc.offsets)[0])
        row = np.asarray(cc.dst)[i : int(np.asarray(cc.offsets)[1])]
        ww = np.asarray(cc.wgt)[i : int(np.asarray(cc.offsets)[1])]
        got = dict(zip(row.tolist(), ww.tolist()))
        assert got[1] == pytest.approx(9.0), f"{name}: weight not upserted"
        assert cc.m == 3, f"{name}: duplicate edge created"


def test_digraph_empty_to_populated():
    from repro.core import DiGraph

    g = DiGraph.empty(4)
    b = edgebatch.from_arrays([0, 0, 3, 2], [1, 2, 0, 2], [1, 1, 1, 1])
    g, dm = g.add_edges(b)
    assert dm == 4 and g.m == 4
    assert g.to_edge_sets()[:4] == [{1, 2}, set(), {2}, {0}]


def test_digraph_grow_through_many_classes():
    """One vertex grows 2 -> 1024+ edges: block moves across every class."""
    from repro.core import DiGraph

    g = DiGraph.empty(2)
    total = 0
    for k in range(1, 9):
        lo = total
        total += 2**k
        b = edgebatch.from_arrays(
            np.zeros(2**k, np.int64), 10 + np.arange(lo, total)
        )
        g, dm = g.add_edges(b)
        assert dm == 2**k
    assert g.degree(0) == total
    row = g.edges_of(0)
    assert row.shape[0] == total and (np.diff(row) > 0).all()
