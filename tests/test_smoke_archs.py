"""Per-architecture smoke tests (deliverable f): REDUCED config of every
assigned arch runs one forward/train step on CPU; output shapes + no NaNs.
Every (arch × shape-kind) combination that isn't skipped gets a cell."""
import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.isfinite(arr).all(), "non-finite values"


SMOKE_CELLS = [
    (arch, shape)
    for arch, shape, skip in cfgbase.all_cells()
    if skip is None
]


@pytest.mark.parametrize("arch,shape", SMOKE_CELLS)
def test_smoke_cell(arch, shape):
    cell = steps.build_cell(arch, shape, reduced=True)
    out = jax.jit(cell.step_fn)(*cell.args)
    _finite(out)
    entry = cfgbase.get(arch)
    kind = cfgbase.FAMILY_SHAPES[entry.family][shape]["kind"]
    if entry.family == "lm" and kind == "train":
        state, metrics = out
        assert float(metrics["loss"]) > 0
        # params actually changed
        before = cell.args[0]["params"]["embed"]
        after = state["params"]["embed"]
        assert not np.allclose(np.asarray(before), np.asarray(after))
    if entry.family == "lm" and kind == "decode":
        logits, cache = out
        assert logits.shape[0] == cell.args[2].shape[0]
        assert int(cache["pos"]) == 1


def test_all_40_cells_accounted():
    cells = cfgbase.all_cells()
    assert len(cells) == 40
    skips = [(a, s) for a, s, sk in cells if sk is not None]
    # exactly the 4 pure-full-attention LMs skip long_500k
    assert sorted(skips) == sorted(
        [
            ("mistral-large-123b", "long_500k"),
            ("qwen2-72b", "long_500k"),
            ("qwen3-moe-235b-a22b", "long_500k"),
            ("arctic-480b", "long_500k"),
        ]
    )


def test_lm_param_counts_match_names():
    targets = {
        "mistral-large-123b": 123e9,
        "h2o-danube-1.8b": 1.8e9,
        "qwen2-72b": 72e9,
        "qwen3-moe-235b-a22b": 235e9,
        "arctic-480b": 480e9,
    }
    for name, want in targets.items():
        got = cfgbase.get(name).full.n_params()
        assert abs(got - want) / want < 0.05, f"{name}: {got/1e9:.1f}B vs {want/1e9}B"
    # active params for the MoEs
    assert abs(cfgbase.get("qwen3-moe-235b-a22b").full.n_active_params() - 22e9) / 22e9 < 0.05
