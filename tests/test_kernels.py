"""Per-kernel allclose vs the pure-jnp ref oracles (interpret=True),
with shape/dtype sweeps + hypothesis property tests."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis", reason="optional dev dependency — pip install repro[dev]"
)
from hypothesis import given, settings, strategies as st

from repro.core import alloc, from_coo, traversal
from repro.io import synthetic
from repro.kernels.bsr_spmm import ops as bsr_ops
from repro.kernels.bsr_spmm.ref import bsr_to_dense
from repro.kernels.edge_segment_sum import ops as seg_ops
from repro.kernels.embedding_bag import ops as bag_ops
from repro.kernels.flash_attention import ops as fa_ops


# --------------------------------------------------------------------------
# bsr_spmm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,b,d", [(100, 600, 32, 16), (300, 2000, 128, 64),
                                     (64, 300, 8, 8), (200, 1500, 64, 130)])
def test_bsr_spmm_shapes(n, m, b, d):
    rng = np.random.default_rng(n)
    src, dst = synthetic.uniform_edges(rng, n, m)
    c = from_coo(src, dst, n=n)
    bsr = bsr_ops.csr_to_bsr(c, block_size=b)
    dense = bsr_to_dense(bsr.row_ptr, bsr.block_cols, bsr.blocks, bsr.n_rows, bsr.n_cols)
    np.testing.assert_allclose(dense[:n, :n], c.to_dense() != 0)
    x = rng.standard_normal((n, d)).astype(np.float32)
    got = np.asarray(bsr_ops.spmm(bsr, jnp.asarray(x), interpret=True))
    exp = (c.to_dense() != 0).astype(np.float32) @ x
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


def test_bsr_spmm_weighted():
    rng = np.random.default_rng(7)
    src, dst = synthetic.uniform_edges(rng, 90, 400)
    w = rng.uniform(0.1, 2.0, src.shape[0]).astype(np.float32)
    c = from_coo(src, dst, w, n=90)
    bsr = bsr_ops.csr_to_bsr(c, block_size=32, weighted=True)
    x = rng.standard_normal((90, 8)).astype(np.float32)
    got = np.asarray(bsr_ops.spmm(bsr, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, c.to_dense() @ x, rtol=1e-4, atol=1e-4)


def test_bsr_reverse_walk_vs_dense_oracle():
    rng = np.random.default_rng(11)
    c = from_coo(*synthetic.uniform_edges(rng, 200, 1500), n=200)
    bsr = bsr_ops.csr_to_bsr(c, block_size=64)
    got = np.asarray(bsr_ops.reverse_walk_bsr(bsr, 5, 200, interpret=True))
    exp = traversal.reverse_walk_dense_oracle(c.to_dense(), 5)
    np.testing.assert_allclose(got, exp, rtol=1e-5)


def test_bsr_spmm_matches_ref_module():
    rng = np.random.default_rng(13)
    c = from_coo(*synthetic.uniform_edges(rng, 96, 500), n=96)
    bsr = bsr_ops.csr_to_bsr(c, block_size=32)
    x = jnp.asarray(rng.standard_normal((96, 16)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(bsr_ops.spmm(bsr, x, interpret=True)),
        np.asarray(bsr_ops.spmm_reference(bsr, x)),
        rtol=1e-4, atol=1e-4,
    )


# --------------------------------------------------------------------------
# edge_segment_sum
# --------------------------------------------------------------------------
@pytest.mark.parametrize("e,d,n", [(100, 16, 20), (700, 64, 50), (128, 1, 5),
                                   (513, 200, 300), (4096, 32, 17)])
def test_edge_segment_sum_shapes(e, d, n):
    rng = np.random.default_rng(e)
    rows = np.sort(rng.integers(0, n, e)).astype(np.int32)
    vals = rng.standard_normal((e, d)).astype(np.float32)
    got = np.asarray(seg_ops.edge_segment_sum(
        jnp.asarray(rows), jnp.asarray(vals), num_segments=n, interpret=True))
    exp = np.asarray(seg_ops.edge_segment_sum_reference(
        jnp.asarray(rows), jnp.asarray(vals), num_segments=n))
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


@given(
    e=st.integers(1, 300),
    n=st.integers(1, 64),
    seed=st.integers(0, 100),
)
@settings(deadline=None, max_examples=15)
def test_edge_segment_sum_property(e, n, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, n, e)).astype(np.int32)
    vals = rng.standard_normal((e, 8)).astype(np.float32)
    got = np.asarray(seg_ops.edge_segment_sum(
        jnp.asarray(rows), jnp.asarray(vals), num_segments=n, interpret=True))
    exp = np.zeros((n, 8), np.float32)
    np.add.at(exp, rows, vals)
    np.testing.assert_allclose(got, exp, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------
# embedding_bag
# --------------------------------------------------------------------------
@pytest.mark.parametrize("combine", ["sum", "mean", "max"])
@pytest.mark.parametrize("v,d,b,k", [(50, 16, 8, 5), (200, 128, 4, 16), (30, 8, 6, 3)])
def test_embedding_bag(combine, v, d, b, k):
    rng = np.random.default_rng(v + k)
    table = rng.standard_normal((v, d)).astype(np.float32)
    idx = rng.integers(-1, v, (b, k)).astype(np.int32)
    w = rng.uniform(0.5, 1.5, (b, k)).astype(np.float32)
    if combine == "max":
        w = np.ones_like(w)
    kp = alloc.next_pow2(k)
    idx_p = np.concatenate([idx, np.full((b, kp - k), -1, np.int32)], 1)
    w_p = np.concatenate([w, np.zeros((b, kp - k), np.float32)], 1)
    got = np.asarray(bag_ops.embedding_bag(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(w),
        combine=combine, interpret=True))
    exp = np.asarray(bag_ops.embedding_bag_reference(
        jnp.asarray(table), jnp.asarray(idx_p), jnp.asarray(w_p), combine=combine))
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding_bag():
    table = jnp.asarray(np.random.default_rng(0).standard_normal((10, 8)), jnp.float32)
    idx = jnp.asarray(np.array([[-1, -1], [0, 1]], np.int32))
    out = np.asarray(bag_ops.embedding_bag(table, idx, combine="sum", interpret=True))
    np.testing.assert_allclose(out[0], 0.0)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,causal,window",
    [
        (2, 4, 2, 256, 64, True, 0),
        (1, 4, 4, 128, 32, False, 0),
        (1, 8, 2, 256, 64, True, 96),
        (1, 2, 1, 512, 128, True, 128),
        (1, 1, 1, 128, 64, True, 32),
    ],
)
def test_flash_attention(b, hq, hkv, s, d, causal, window):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    got = np.asarray(fa_ops.attention(q, k, v, causal=causal, window=window, interpret=True))
    exp = np.asarray(fa_ops.attention_reference(q, k, v, causal=causal, window=window))
    np.testing.assert_allclose(got, exp, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    got = np.asarray(fa_ops.attention(q, k, v, causal=True, interpret=True), np.float32)
    exp = np.asarray(fa_ops.attention_reference(q, k, v, causal=True), np.float32)
    np.testing.assert_allclose(got, exp, rtol=5e-2, atol=5e-2)


def test_decode_attention_matches_full():
    """Decode path == last row of full attention over the live prefix."""
    rng = np.random.default_rng(5)
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    kv_len = 40
    q_full = rng.standard_normal((b, hq, kv_len, d)).astype(np.float32)
    k = np.zeros((b, hkv, s, d), np.float32)
    v = np.zeros((b, hkv, s, d), np.float32)
    k[:, :, :kv_len] = rng.standard_normal((b, hkv, kv_len, d))
    v[:, :, :kv_len] = rng.standard_normal((b, hkv, kv_len, d))
    out_dec = np.asarray(fa_ops.decode_attention(
        jnp.asarray(q_full[:, :, -1:]), jnp.asarray(k), jnp.asarray(v), kv_len))
    out_full = np.asarray(fa_ops.attention_reference(
        jnp.asarray(q_full), jnp.asarray(k[:, :, :kv_len]), jnp.asarray(v[:, :, :kv_len]),
        causal=True))
    np.testing.assert_allclose(out_dec[:, :, 0], out_full[:, :, -1], rtol=1e-4, atol=1e-4)
