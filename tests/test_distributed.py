"""Distributed graph tests — run in a subprocess with 8 forced host devices
(the main test process must keep the default single device)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, "src")
    import jax
    import numpy as np
    from repro.core import distributed as dist, from_coo, traversal
    from repro.io import synthetic
    from repro.launch import mesh as mesh_mod

    assert len(jax.devices()) == 8
    mesh = mesh_mod.make_mesh_like((8,), ("data",))

    rng = np.random.default_rng(0)
    src, dstv = synthetic.uniform_edges(rng, 64, 500)
    c = from_coo(src, dstv, n=64)
    g = dist.shard_csr(c, 8)

    # 1) sharded reverse walk == dense oracle
    out = np.asarray(dist.reverse_walk(g, 4, mesh))
    oracle = traversal.reverse_walk_dense_oracle(c.to_dense(), 4)
    np.testing.assert_allclose(out, oracle, rtol=1e-5)
    print("sharded reverse walk OK")

    # 2) distributed insert + delete == host-set oracle
    ins_s = rng.integers(0, 64, 100); ins_d = rng.integers(0, 64, 100)
    g2, m_after = dist.apply_updates(g, ins_s, ins_d, None, mesh, op="insert")
    got = g2 and dist.gather_csr(g2)
    exp = set(zip(src.tolist(), dstv.tolist())) | set(zip(ins_s.tolist(), ins_d.tolist()))
    got_set = set()
    o = np.asarray(got.offsets); d = np.asarray(got.dst)
    for u in range(got.n):
        for v in d[o[u]:o[u+1]]:
            got_set.add((u, int(v)))
    assert got_set == exp, (len(got_set), len(exp))
    print("distributed insert OK, m =", m_after)

    del_s = np.array([p[0] for p in list(exp)[:50]]); del_d = np.array([p[1] for p in list(exp)[:50]])
    g3, m3 = dist.apply_updates(g2, del_s, del_d, None, mesh, op="delete")
    got = dist.gather_csr(g3)
    exp2 = exp - set(zip(del_s.tolist(), del_d.tolist()))
    got_set = set()
    o = np.asarray(got.offsets); d = np.asarray(got.dst)
    for u in range(got.n):
        for v in d[o[u]:o[u+1]]:
            got_set.add((u, int(v)))
    assert got_set == exp2
    print("distributed delete OK, m =", m3)

    # 3) walk on the updated sharded graph still matches oracle
    out = np.asarray(dist.reverse_walk(g3, 3, mesh))
    oracle = traversal.reverse_walk_dense_oracle(got.to_dense(), 3)
    np.testing.assert_allclose(out, oracle, rtol=1e-5)
    print("walk-after-update OK")
    """
)


def test_distributed_graph_8dev(tmp_path):
    p = tmp_path / "dist_check.py"
    p.write_text(SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, str(p)],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "walk-after-update OK" in r.stdout
