"""Sharded walk-image tests (DESIGN.md §14).

In-process tests run the single-device LOCAL emulation of the sharded
walk (bit-identical math, no mesh needed — the main test process must
keep the default single device).  The shard_map path itself runs in a
subprocess with 4 forced host devices: walk/update bit-parity against
the single-device WalkImage path, the |V|·4 collective-bytes model, and
per-device round_dispatches=1 accounting.

Parity is asserted EXACTLY: the reverse walk is unweighted, so visit
counts are small integers represented exactly in f32 on these graph
sizes and step counts — any layout- or summation-order difference that
changed a value would be a real defect, not noise.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import csr as csr_mod, distributed as dist, edgebatch
from repro.core import updates as upd_mod
from repro.core.walk_image import WalkImage
from repro.kernels.csr_build import ref as csr_ref

STEPS = 4


def _random_csr(rng, n, m):
    src = rng.integers(0, n, m)
    dstv = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32)
    return src, dstv, w, csr_mod.from_coo(src, dstv, w, n=n)


def _single_device_walk(c, steps, visits0=None):
    img = WalkImage.from_csr_arrays(
        np.asarray(c.offsets), np.asarray(c.dst), np.asarray(c.wgt), c.n
    )
    return np.asarray(img.walk(steps, visits0=visits0))


def _plan(ins=None, dels=None):
    ib = edgebatch.from_arrays(*ins) if ins is not None else None
    db = edgebatch.from_arrays(*dels) if dels is not None else None
    return upd_mod.plan_update(ib, db)


# ---------------------------------------------------------------------------
# local-mode parity (single device, no mesh)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_walk_parity_local(n_shards):
    rng = np.random.default_rng(0)
    _, _, _, c = _random_csr(rng, 23, 140)
    g = dist.shard_csr(c, n_shards)
    got = np.asarray(g.reverse_walk(STEPS))
    ref = _single_device_walk(c, STEPS)
    assert np.array_equal(got, ref)


def test_multi_walk_parity_local():
    rng = np.random.default_rng(1)
    _, _, _, c = _random_csr(rng, 19, 90)
    v0 = rng.integers(0, 3, (3, c.n)).astype(np.float32)
    g = dist.shard_csr(c, 4)
    got = np.asarray(g.reverse_walk(STEPS, visits0=v0))
    ref = _single_device_walk(c, STEPS, visits0=v0)
    assert got.shape == (3, c.n)
    assert np.array_equal(got, ref)


def test_apply_routes_and_matches_single_device():
    rng = np.random.default_rng(2)
    src, dstv, w, c = _random_csr(rng, 29, 160)
    g = dist.shard_csr(c, 4)
    base = dist.gather_csr(g)
    plan = _plan(
        ins=(rng.integers(0, 29, 40), rng.integers(0, 29, 40),
             rng.random(40).astype(np.float32)),
        dels=(src[:25].copy(), dstv[:25].copy()),
    )
    routed = dist.route_updates(plan, g.n_shards, g.rows_max)
    assert sum(p.n_ops for _, p in routed) == plan.n_ops
    for sid, sub in routed:
        lo, hi = sid * g.rows_max, (sid + 1) * g.rows_max
        assert int(sub.q_src.min()) >= lo and int(sub.q_src.max()) < hi
    g.apply(plan)
    bs = np.repeat(np.arange(base.n), np.diff(np.asarray(base.offsets)))
    s2, d2, w2 = dist._host_apply(
        bs, np.asarray(base.dst), np.asarray(base.wgt), plan
    )
    want = csr_mod.from_coo(s2, d2, w2, n=base.n, dedup=False)
    got = dist.gather_csr(g)
    assert np.array_equal(np.asarray(got.offsets), np.asarray(want.offsets))
    assert np.array_equal(np.asarray(got.dst), np.asarray(want.dst))
    assert np.allclose(np.asarray(got.wgt), np.asarray(want.wgt))
    assert np.array_equal(
        np.asarray(g.reverse_walk(STEPS)), _single_device_walk(want, STEPS)
    )


def test_vertex_growth_reshards_across_boundary():
    """New vertices land beyond the last shard's range: one re-shard."""
    rng = np.random.default_rng(3)
    _, _, _, c = _random_csr(rng, 16, 80)
    g = dist.shard_csr(c, 4)
    rows_max0 = g.rows_max
    base = dist.gather_csr(g)
    n_new = 16 + 9  # forces rows_max to grow: old boundaries all move
    plan = _plan(ins=(
        np.array([n_new - 1, 0, 7]), np.array([0, n_new - 1, n_new - 2]),
        np.ones(3, np.float32),
    ))
    g.apply(plan)
    assert g.n == n_new
    assert g.rows_max > rows_max0
    bs = np.repeat(np.arange(base.n), np.diff(np.asarray(base.offsets)))
    s2, d2, w2 = dist._host_apply(
        bs, np.asarray(base.dst), np.asarray(base.wgt), plan
    )
    want = csr_mod.from_coo(s2, d2, w2, n=n_new, dedup=False)
    got = dist.gather_csr(g)
    assert np.array_equal(np.asarray(got.offsets), np.asarray(want.offsets))
    assert np.array_equal(
        np.asarray(g.reverse_walk(STEPS)), _single_device_walk(want, STEPS)
    )
    g.audit()


def test_grown_row_overflow_rebuilds():
    """A hub row outgrowing its shard's bump slack takes the rebuild path
    (relocation through gather + re-shard) and stays correct."""
    rng = np.random.default_rng(4)
    _, _, _, c = _random_csr(rng, 12, 40)
    g = dist.shard_csr(c, 4)
    cap0 = g.cap_e
    # grow vertex 0 far past shard 0's slot capacity, in several plans
    hub = np.arange(1, 12, dtype=np.int64)
    for rep in range(6):
        dsts = (hub + rep) % 12
        plan = _plan(ins=(
            np.zeros_like(dsts) + (rep % 3), dsts,
            np.full(dsts.shape[0], 1.0, np.float32),
        ))
        g.apply(plan)
        g.audit()
    got = dist.gather_csr(g)
    # dense oracle: replay the same plans on a host edge set
    base = dist.gather_csr(dist.shard_csr(c, 4))
    bs = np.repeat(np.arange(base.n), np.diff(np.asarray(base.offsets)))
    s2, d2, w2 = bs, np.asarray(base.dst), np.asarray(base.wgt)
    for rep in range(6):
        dsts = (hub + rep) % 12
        plan = _plan(ins=(
            np.zeros_like(dsts) + (rep % 3), dsts,
            np.full(dsts.shape[0], 1.0, np.float32),
        ))
        s2, d2, w2 = dist._host_apply(s2, d2, w2, plan)
    want = csr_mod.from_coo(s2, d2, w2, n=12, dedup=False)
    assert np.array_equal(np.asarray(got.offsets), np.asarray(want.offsets))
    assert np.array_equal(np.asarray(got.dst), np.asarray(want.dst))
    assert np.array_equal(
        np.asarray(g.reverse_walk(STEPS)), _single_device_walk(want, STEPS)
    )
    assert g.cap_e >= cap0  # rebuild re-sized the shared slot space


def test_gather_csr_matches_reference_oracle():
    rng = np.random.default_rng(5)
    src, dstv, w, c = _random_csr(rng, 17, 110)
    g = dist.shard_csr(c, 4)
    got = dist.gather_csr(g)
    ro, rd, rw = csr_ref.coo_to_csr_reference(src, dstv, w, n=17, dedup=True)
    assert np.array_equal(np.asarray(got.offsets), ro)
    assert np.array_equal(np.asarray(got.dst), rd)
    assert np.allclose(np.asarray(got.wgt), rw)


def test_gather_csr_rejects_row_count_mismatch():
    rng = np.random.default_rng(6)
    _, _, _, c = _random_csr(rng, 16, 60)
    g = dist.shard_csr(c, 4)
    # corrupt shard 1's geometry: claim an edge on a row shard 0 owns
    img = g.shards[1]
    img.degs[0] = 1
    img.starts[0] = 0
    with pytest.raises(ValueError, match="row-count mismatch"):
        dist.gather_csr(g)


def test_sharded_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import manager as ckpt

    rng = np.random.default_rng(7)
    _, _, _, c = _random_csr(rng, 21, 120)
    g = dist.shard_csr(c, 4)
    d = str(tmp_path / "ck")
    g.save(d, 3)
    # one file per shard under one step manifest
    step_dir = os.path.join(d, "step_0000000003")
    files = sorted(os.listdir(step_dir))
    assert files == ["manifest.json", "shard_0.npz", "shard_1.npz",
                     "shard_2.npz", "shard_3.npz"]
    g2 = dist.ShardedGraph.restore(d)
    assert (g2.n, g2.n_shards, g2.rows_max) == (g.n, g.n_shards, g.rows_max)
    assert np.array_equal(
        np.asarray(g2.reverse_walk(STEPS)), np.asarray(g.reverse_walk(STEPS))
    )
    # single-shard restore API addresses one shard of the manifest
    arrays, step = ckpt.restore_arrays(d, shard_id=2)
    assert step == 3 and "dst" in arrays
    with pytest.raises(FileNotFoundError):
        ckpt.restore_arrays(d, shard_id=9)


def test_hypothesis_sweep_parity():
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency — pip install repro[dev]"
    )
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=4, max_value=40),
        m=st.integers(min_value=0, max_value=160),
        n_shards=st.sampled_from([2, 3, 4]),
        steps=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        n_ins=st.integers(min_value=0, max_value=30),
        n_del=st.integers(min_value=0, max_value=30),
    )
    def sweep(n, m, n_shards, steps, seed, n_ins, n_del):
        if n < n_shards:
            return
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dstv = rng.integers(0, n, m)
        w = rng.random(m).astype(np.float32)
        c = csr_mod.from_coo(src, dstv, w, n=n)
        g = dist.shard_csr(c, n_shards)
        base = dist.gather_csr(g)
        assert np.array_equal(
            np.asarray(g.reverse_walk(steps)), _single_device_walk(c, steps)
        )
        ins = (
            rng.integers(0, n, n_ins), rng.integers(0, n, n_ins),
            rng.random(n_ins).astype(np.float32),
        ) if n_ins else None
        dels = (
            rng.integers(0, n, n_del), rng.integers(0, n, n_del),
        ) if n_del else None
        if ins is None and dels is None:
            return
        plan = _plan(ins=ins, dels=dels)
        g.apply(plan)
        bs = np.repeat(np.arange(base.n), np.diff(np.asarray(base.offsets)))
        s2, d2, w2 = dist._host_apply(
            bs, np.asarray(base.dst), np.asarray(base.wgt), plan
        )
        want = csr_mod.from_coo(s2, d2, w2, n=n, dedup=False)
        got = dist.gather_csr(g)
        assert np.array_equal(
            np.asarray(got.offsets), np.asarray(want.offsets)
        )
        assert np.array_equal(np.asarray(got.dst), np.asarray(want.dst))
        assert np.array_equal(
            np.asarray(g.reverse_walk(steps)),
            _single_device_walk(want, steps),
        )

    sweep()


# ---------------------------------------------------------------------------
# shard_map path — subprocess with 4 forced host devices
# ---------------------------------------------------------------------------
SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, "src")
    import jax
    import numpy as np
    from repro.core import csr as csr_mod, distributed as dist, edgebatch
    from repro.core import updates as upd_mod
    from repro.core.walk_image import WalkImage
    from repro.kernels.slot_update import ops as su_ops
    from repro.launch import mesh as mesh_mod

    assert len(jax.devices()) == 4
    mesh = mesh_mod.host_mesh(4)
    devs = list(np.asarray(mesh.devices).reshape(-1))

    rng = np.random.default_rng(11)
    n, m, STEPS = 37, 260, 4
    src = rng.integers(0, n, m); dstv = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32)
    c = csr_mod.from_coo(src, dstv, w, n=n)

    img = WalkImage.from_csr_arrays(
        np.asarray(c.offsets), np.asarray(c.dst), np.asarray(c.wgt), c.n)
    ref = np.asarray(img.walk(STEPS))

    g = dist.shard_csr(c, 4, mesh=mesh)
    out = np.asarray(g.reverse_walk(STEPS))
    assert np.array_equal(out, ref), abs(out - ref).max()
    print("shmap walk parity OK")

    g_local = dist.shard_csr(c, 4)
    assert np.array_equal(np.asarray(g_local.reverse_walk(STEPS)), out)
    print("shmap vs local bit parity OK")

    got = g.collective_bytes_per_step(STEPS)
    model = (g.n_shards - 1) * g.rows_max * 4
    assert got == model, (got, model)
    assert 0 < got <= 1.5 * n * 4, (got, n * 4)
    print("collective bytes/step", got, "<= 1.5x |V|*4 =", 1.5 * n * 4)

    plan = upd_mod.plan_update(edgebatch.from_arrays(
        rng.integers(0, n, 24), rng.integers(0, n, 24),
        rng.random(24).astype(np.float32)), None)
    routed = dist.route_updates(plan, g.n_shards, g.rows_max)
    shard_ids = [id(im) for im in g.shards]
    before = su_ops.STATS["dispatches"]
    g.apply(plan)
    delta = su_ops.STATS["dispatches"] - before
    assert shard_ids == [id(im) for im in g.shards], "unexpected rebuild"
    assert delta == len(routed), (delta, len(routed))
    print("per-device round_dispatches=1 OK over", len(routed), "shards")

    for s, im in enumerate(g.shards):
        ds = list(im.dst.devices())
        assert len(ds) == 1 and ds[0] == devs[s], (s, ds)
    print("buffers stay committed per device after patch")

    base = dist.gather_csr(g)
    img2 = WalkImage.from_csr_arrays(
        np.asarray(base.offsets), np.asarray(base.dst),
        np.asarray(base.wgt), n)
    assert np.array_equal(np.asarray(g.reverse_walk(STEPS)),
                          np.asarray(img2.walk(STEPS)))
    print("walk-after-update OK")

    # grown-row relocation crossing a shard boundary: growth re-shard
    plan2 = upd_mod.plan_update(edgebatch.from_arrays(
        np.array([n + 6, 2]), np.array([1, n + 6]),
        np.ones(2, np.float32)), None)
    g.apply(plan2)
    assert g.n == n + 7 and g.mesh is mesh
    bs = np.repeat(np.arange(base.n), np.diff(np.asarray(base.offsets)))
    s2, d2, w2 = dist._host_apply(
        bs, np.asarray(base.dst), np.asarray(base.wgt), plan2)
    want = csr_mod.from_coo(s2, d2, w2, n=n + 7, dedup=False)
    img3 = WalkImage.from_csr_arrays(
        np.asarray(want.offsets), np.asarray(want.dst),
        np.asarray(want.wgt), n + 7)
    assert np.array_equal(np.asarray(g.reverse_walk(STEPS)),
                          np.asarray(img3.walk(STEPS)))
    print("growth re-shard on mesh OK")
    """
)


def test_sharded_graph_4dev_shmap(tmp_path):
    p = tmp_path / "dist_check.py"
    p.write_text(SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, str(p)],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "growth re-shard on mesh OK" in r.stdout
