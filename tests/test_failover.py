"""Live shard failover (DESIGN.md §17): quarantine, degraded serving,
online rebuild, reintegration.

Covers the full §17 lifecycle at both layers:

* representation level — a ``shard.patch`` fault quarantines exactly
  the faulted shard (the rest of the mesh still patches, routed updates
  spool), degraded walks mask the lost rows to exact zeros, integrity
  descriptors catch silent weight corruption the structural audit
  can't, and ``DurableGraph.rebuild_shard`` restores + replays the one
  lost shard back to bit-parity with an uncrashed twin;
* serving level — the ``WalkServer`` keeps serving through a shard
  loss with explicit per-response ``coverage``/``down_shards``,
  writer-paced audits detect corruption, ``run_on_writer`` serializes
  admin mutations with the apply stream, and the dispatch retry backoff
  is exponential, capped, and jittered.
"""
import numpy as np
import pytest

from repro.core import csr as csr_mod, edgebatch, updates
from repro.core import distributed as dist
from repro.runtime import durable, failover, faultinject
from repro.runtime import serve as serve_mod

N_V = 48
S = 4


@pytest.fixture(autouse=True)
def _clean_fault_state():
    faultinject.disarm()
    yield
    faultinject.disarm()


@pytest.fixture(scope="module")
def base_csr():
    rng = np.random.default_rng(5)
    m = 260
    return csr_mod.from_coo(
        rng.integers(0, N_V, m),
        rng.integers(0, N_V, m),
        rng.random(m).astype(np.float32),
        n=N_V,
    )


def make_plan(seed=0, k=12, n=N_V):
    rng = np.random.default_rng(seed)
    ib = edgebatch.from_arrays(
        rng.integers(0, n, k), rng.integers(0, n, k),
        rng.random(k).astype(np.float32),
    )
    db = edgebatch.from_arrays(rng.integers(0, n, 4), rng.integers(0, n, 4))
    return updates.plan_update(inserts=ib, deletes=db)


def masked_walk_oracle(g, steps, down_rows):
    """Numpy reverse walk with the §17 coverage mask: a down shard's
    rows accumulate nothing at every step while edges from healthy rows
    still read the full visit vector."""
    c = dist.gather_csr(g)
    off = np.asarray(c.offsets, np.int64)
    rows = np.repeat(np.arange(N_V, dtype=np.int64), np.diff(off))
    d = np.asarray(c.dst)[: c.m].astype(np.int64)
    v = np.ones(N_V, np.float64)
    for _ in range(steps):
        nxt = np.zeros(N_V, np.float64)
        np.add.at(nxt, rows, v[d])
        if len(down_rows):
            nxt[down_rows] = 0.0
        v = nxt
    return v


def assert_parity(g, twin):
    ca, cb = dist.gather_csr(g), dist.gather_csr(twin)
    np.testing.assert_array_equal(np.asarray(ca.offsets), np.asarray(cb.offsets))
    np.testing.assert_array_equal(
        np.asarray(ca.dst)[: ca.m], np.asarray(cb.dst)[: cb.m]
    )
    np.testing.assert_array_equal(
        np.asarray(ca.wgt)[: ca.m], np.asarray(cb.wgt)[: cb.m]
    )
    np.testing.assert_array_equal(
        np.asarray(g.reverse_walk(3)), np.asarray(twin.reverse_walk(3))
    )


# ---------------------------------------------------------------------------
# representation level: quarantine / degraded walk / guards
# ---------------------------------------------------------------------------


def test_patch_fault_quarantines_only_faulted_shard(base_csr):
    g = dist.shard_csr(base_csr, S)
    twin = dist.shard_csr(base_csr, S)
    plan = make_plan(seed=1)
    routed = dist.route_updates(plan, S, g.rows_max)
    subs = dict(routed)
    assert len(routed) >= 2  # the plan must span shards for the test
    # fault the second touched shard's patch: hits run in routed order
    victim = routed[1][0]
    faultinject.arm("shard.patch", after=1, times=1)
    g.apply(plan)  # non-raising: healthy shards still patch
    assert g.down == {victim}
    assert g.coverage < 1.0
    assert len(g.spooled(victim)) == 1
    # healthy shards took their slices — parity with a twin that applied
    # only the non-victim subs
    for sid, sub in subs.items():
        if sid != victim:
            twin.shards[sid].queue(sub)
            assert twin.shards[sid].flush()
    for sid in range(S):
        if sid != victim:
            np.testing.assert_array_equal(
                np.asarray(g.shards[sid].dst), np.asarray(twin.shards[sid].dst)
            )
    # a second routed update for the victim spools too (dedup'd append)
    plan2 = make_plan(seed=2)
    g.apply(plan2)
    assert all(s is not None for s in g.spooled(victim))


def test_degraded_walk_masks_down_rows(base_csr):
    g = dist.shard_csr(base_csr, S)
    full = dist.shard_csr(base_csr, S)
    sid = 1
    g.quarantine(sid)
    down = g.down_rows()
    lo, hi = g.owned_range(sid)
    np.testing.assert_array_equal(down, np.arange(lo, hi))
    got = np.asarray(g.reverse_walk(3), np.float64)
    want = masked_walk_oracle(full, 3, down)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    # lost rows read exact zeros; healthy rows are untouched by the mask
    assert np.all(got[down] == 0.0)


def test_walk_fault_raises_shard_fault_error(base_csr):
    g = dist.shard_csr(base_csr, S)
    sid = 2
    faultinject.arm("shard.walk", after=sid, times=1)
    with pytest.raises(dist.ShardFaultError) as ei:
        g.reverse_walk(2)
    assert ei.value.sid == sid and ei.value.stage == "walk"
    # the fire is spent: the next walk (still healthy mesh) succeeds
    g.reverse_walk(2)


def test_degraded_guards(base_csr, tmp_path):
    g = dist.shard_csr(base_csr, S)
    g.quarantine(0)
    with pytest.raises(dist.ShardDownError):
        g.state_trees()  # a checkpoint would persist garbage
    with pytest.raises(dist.ShardDownError):
        dist.gather_csr(g)
    growth = updates.plan_update(
        inserts=edgebatch.from_arrays([N_V + 3], [0], [1.0])
    )
    with pytest.raises(dist.ShardDownError):
        g.apply(growth)  # global re-shard impossible while degraded
    with pytest.raises(dist.ShardDownError):
        g.audit_shard(0)  # the down shard itself is not auditable
    with pytest.raises(RuntimeError):
        g.seal_generation(1).apply(make_plan())  # sealed gens read-only


def test_reintegrate_validates_geometry(base_csr):
    g = dist.shard_csr(base_csr, S)
    g.quarantine(3)
    other = dist.shard_csr(base_csr, 2)  # wrong layout on purpose
    with pytest.raises((ValueError, dist.ShardFaultError)):
        g.reintegrate(3, other.shards[0])
    assert 3 in g.down  # rejected reintegration leaves the shard down


def test_sealed_generation_keeps_down_mask(base_csr):
    g = dist.shard_csr(base_csr, S)
    g.quarantine(2)
    sealed = g.seal_generation(7)
    assert sealed.down == {2} and sealed._frozen
    assert sealed.coverage == g.coverage
    got = np.asarray(sealed.reverse_walk(2))
    assert np.all(got[np.asarray(sealed.down_rows())] == 0.0)


# ---------------------------------------------------------------------------
# integrity descriptors + audit scheduling
# ---------------------------------------------------------------------------


def test_corrupt_weight_caught_only_by_integrity(base_csr):
    g = dist.shard_csr(base_csr, S)
    g.enable_integrity()
    sid = 1
    slot = failover.corrupt_shard(g, sid, kind="wgt")
    assert slot is not None
    g.shards[sid].audit()  # structurally valid: the plain audit passes
    with pytest.raises(dist.ShardIntegrityError, match="wgt"):
        g.verify_shard(sid)


def test_corrupt_dst_caught_without_integrity(base_csr):
    g = dist.shard_csr(base_csr, S)  # integrity OFF
    sid = 0
    assert failover.corrupt_shard(g, sid, kind="dst") is not None
    with pytest.raises(Exception):
        g.audit_shard(sid)  # structural violation trips the content sweep


def test_audit_scheduler_round_robin_detection(base_csr):
    g = dist.shard_csr(base_csr, S)
    g.enable_integrity()
    sched = failover.AuditScheduler(g)
    for _ in range(S):  # one clean sweep: no false positives
        assert sched.tick() is None
    sid = 2
    failover.corrupt_shard(g, sid, kind="wgt")
    hits = [sched.tick() for _ in range(S)]
    det = [h for h in hits if h is not None]
    assert len(det) == 1 and det[0][0] == sid
    g.quarantine(sid)
    # the scheduler keeps sweeping the healthy remainder
    for _ in range(S):
        assert sched.tick() is None


def test_no_false_positives_after_patches(base_csr):
    g = dist.shard_csr(base_csr, S)
    g.enable_integrity()
    for seed in range(4):
        g.apply(make_plan(seed=seed))
        for sid in range(S):
            g.audit_shard(sid)  # descriptors refreshed per patch


# ---------------------------------------------------------------------------
# online rebuild + reintegration (DurableGraph.rebuild_shard)
# ---------------------------------------------------------------------------


def _durable_pair(base_csr, tmp_path):
    wd, cd = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    dg = durable.DurableGraph(
        dist.shard_csr(base_csr, S), wd, cd, diff=True, full_every=8
    )
    twin = dist.shard_csr(base_csr, S)
    return dg, twin


def test_rebuild_shard_crash_stop_roundtrip(base_csr, tmp_path):
    dg, twin = _durable_pair(base_csr, tmp_path)
    dg.rep.enable_integrity()
    plans = [make_plan(seed=s) for s in range(6)]
    for p in plans[:2]:
        dg.apply(p)
        twin.apply(p)
    dg.checkpoint()  # bounds the single-shard replay window
    # quarantine via an injected patch fault mid-stream: the first
    # routed shard's patch faults
    victim_plan = plans[2]
    victim = dist.route_updates(victim_plan, S, dg.rep.rows_max)[0][0]
    faultinject.arm("shard.patch", times=1)
    dg.apply(victim_plan)
    twin.apply(victim_plan)
    assert dg.rep.down == {victim}
    # degraded window: more traffic spools the victim's subs, the WAL
    # holds everything, checkpoints are refused
    for p in plans[3:]:
        dg.apply(p)
        twin.apply(p)
    with pytest.raises(dist.ShardDownError):
        dg.checkpoint()
    # rebuild every down shard (an overflowing shard may legitimately
    # join the quarantine while degraded — global re-shard is refused)
    assert victim in dg.rep.down
    for sid in sorted(dg.rep.down):
        stats = {}
        records = dg.rebuild_shard(sid, stats=stats)
        assert records >= 1 and stats["records"] == records
    assert not dg.rep.down
    dg.rep.audit()
    for sid in range(S):
        dg.rep.verify_shard(sid)
    assert_parity(dg.rep, twin)


def test_rebuild_shard_discards_silent_corruption(base_csr, tmp_path):
    dg, twin = _durable_pair(base_csr, tmp_path)
    dg.rep.enable_integrity()
    for s in range(3):
        p = make_plan(seed=s)
        dg.apply(p)
        twin.apply(p)
    dg.checkpoint()
    sid = 1
    failover.corrupt_shard(dg.rep, sid, kind="wgt")
    sched = failover.AuditScheduler(dg.rep)
    det = next(h for h in (sched.tick() for _ in range(S)) if h is not None)
    assert det[0] == sid
    dg.rep.quarantine(sid)
    dg.rebuild_shard(sid)
    assert not dg.rep.down
    assert_parity(dg.rep, twin)  # the flipped weight is gone


def test_rebuild_shard_requires_quarantine(base_csr, tmp_path):
    dg, _ = _durable_pair(base_csr, tmp_path)
    with pytest.raises(ValueError):
        dg.rebuild_shard(0)  # healthy shard: refuse to clobber


def test_rebuild_shard_refuses_stale_layout(base_csr, tmp_path):
    """A checkpoint that predates a global re-shard (vertex growth) can't
    seed a single-shard rebuild — the block partition moved."""
    dg, _ = _durable_pair(base_csr, tmp_path)
    dg.apply(make_plan(seed=0))
    dg.checkpoint()
    growth = updates.plan_update(
        inserts=edgebatch.from_arrays([N_V + 5], [0], [1.0])
    )
    dg.apply(growth)  # global re-shard: n grows, rows_max moves
    dg.rep.quarantine(2)
    with pytest.raises(dist.ShardDownError, match="re-shard|recover"):
        dg.rebuild_shard(2)


# ---------------------------------------------------------------------------
# serving level: coverage lifecycle, admin plane, backoff
# ---------------------------------------------------------------------------


def _drain(tickets, timeout=30.0):
    for t in tickets:
        t.wait(timeout)
    return tickets


def test_serve_sharded_steady_full_coverage(base_csr):
    g = dist.shard_csr(base_csr, S)
    with serve_mod.WalkServer(g, batch_max=4) as srv:
        upd = srv.submit_update(make_plan(seed=3))
        walks = _drain([
            srv.submit_walk([i % N_V, (i * 7) % N_V], steps=2, timeout=10.0)
            for i in range(6)
        ])
        assert isinstance(upd.result(10.0), int)  # ΔM may be negative
    stats = srv.assert_no_lost()
    assert stats["served"] >= 1 and stats["served_degraded"] == 0
    for t in walks:
        if t.status == serve_mod.SERVED:
            assert t.coverage == 1.0 and t.down_shards == ()


def test_serve_degraded_coverage_lifecycle(base_csr):
    import time as _time

    g = dist.shard_csr(base_csr, S)
    sid = 1
    srv = serve_mod.WalkServer(
        g, batch_max=4, dispatch_retries=4, retry_backoff=0.002
    ).start()
    try:
        _drain([srv.submit_walk([3], steps=2, timeout=10.0)])
        faultinject.arm("shard.walk", after=sid, times=1)
        # the faulted batch must be retried, not lost; the writer
        # quarantines and reseals degraded
        _drain([srv.submit_walk([5], steps=2, timeout=10.0)])
        deadline = _time.monotonic() + 10.0
        while srv.stats()["coverage"] == 1.0:
            assert _time.monotonic() < deadline, "never resealed degraded"
            _time.sleep(0.01)
        assert g.down == {sid}
        degraded = _drain([
            srv.submit_walk([7, 9], steps=2, timeout=10.0) for _ in range(3)
        ])
        served = [t for t in degraded if t.status == serve_mod.SERVED]
        assert served and all(
            t.coverage < 1.0 and sid in t.down_shards for t in served
        )
        # updates are still accepted while degraded (victim's slice spools)
        assert isinstance(srv.submit_update(make_plan(seed=9)).result(10.0), int)
        # admin plane reads the spool depth on the writer thread
        tk = srv.run_on_writer(lambda s: len(g.spooled(sid)), reseal=False)
        assert tk.result(10.0) >= 0
    finally:
        stats = srv.stop()
    srv.assert_no_lost()
    assert stats["shard_quarantines"] >= 1
    assert stats["served_degraded"] >= 1
    assert stats["failed"] == 0  # retry path, never batch loss


def test_serve_audit_pacing_detects_corruption(base_csr):
    import time as _time

    g = dist.shard_csr(base_csr, S)
    g.enable_integrity()
    sid = 2
    srv = serve_mod.WalkServer(g, batch_max=4, audit_every=1).start()
    try:
        _drain([srv.submit_walk([1], steps=2, timeout=10.0)])
        srv.run_on_writer(
            lambda s: failover.corrupt_shard(g, sid, kind="wgt")
        ).result(10.0)
        deadline = _time.monotonic() + 10.0
        while srv.stats()["audit_detections"] == 0:
            assert _time.monotonic() < deadline, "paced audit never detected"
            _time.sleep(0.01)
        assert sid in g.down
        # responses after the degraded reseal carry the mask
        deadline = _time.monotonic() + 10.0
        while srv.stats()["coverage"] == 1.0:
            assert _time.monotonic() < deadline
            _time.sleep(0.01)
        t = _drain([srv.submit_walk([1], steps=2, timeout=10.0)])[0]
        assert t.status == serve_mod.SERVED and t.coverage < 1.0
    finally:
        stats = srv.stop()
    srv.assert_no_lost()
    assert stats["audit_detections"] >= 1


def test_run_on_writer_serializes_and_accounts(base_csr):
    g = dist.shard_csr(base_csr, S)
    with serve_mod.WalkServer(g) as srv:
        tk = srv.run_on_writer(lambda s: s is srv)
        assert tk.result(10.0) is True
        bad = srv.run_on_writer(lambda s: 1 / 0)
        with pytest.raises(RuntimeError):
            bad.result(10.0)
        assert srv.stats()["admin_ops"] == 1  # failures don't count
    late = srv.run_on_writer(lambda s: None)
    assert late.status == serve_mod.REJECTED  # after stop: clean reject


def test_retry_backoff_exponential_capped_jittered():
    srv = serve_mod.WalkServer(
        object(), retry_backoff=0.01, retry_max_backoff=0.08
    )
    for attempt in (1, 2, 3, 4, 5, 8):
        base = min(0.01 * 2 ** (attempt - 1), 0.08)
        samples = [srv._retry_sleep_s(attempt) for _ in range(50)]
        assert all(0.5 * base <= s <= 1.5 * base for s in samples)
    # jitter actually spreads (not a constant)
    assert len({round(s, 6) for s in (srv._retry_sleep_s(3) for _ in range(20))}) > 1
