"""Fleet fault-tolerance state machine (runtime/fault.py) under a
simulated clock: deadline-driven death, consecutive-strike stragglers,
pow-2 elastic re-meshing, and the ElasticTrainer event stream."""
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import fault


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _monitor(n=4, **kw):
    clock = FakeClock()
    mon = fault.FleetMonitor(n, clock=clock, **kw)
    return mon, clock


# -- FleetMonitor -----------------------------------------------------------


def test_dead_by_deadline():
    mon, clock = _monitor(3, fail_timeout=60.0)
    clock.advance(30.0)
    mon.heartbeat(0, 1.0)
    mon.heartbeat(1, 1.0)  # worker 2 stays silent
    clock.advance(45.0)    # 2 is now 75s stale; 0/1 only 45s
    report = mon.check()
    assert report["dead"] == [2]
    assert report["healthy"] == 2
    assert mon.alive_workers() == [0, 1]
    # a dead worker stays dead — no resurrection on later checks
    clock.advance(1.0)
    assert mon.check()["dead"] == []
    assert mon.check()["healthy"] == 2


def test_straggler_needs_consecutive_strikes():
    mon, clock = _monitor(4, strike_limit=3, straggler_factor=2.0)
    slow, fast = 10.0, 1.0
    for _ in range(2):
        clock.advance(1.0)
        for w in range(4):
            mon.heartbeat(w, slow if w == 3 else fast)
        assert mon.check()["stragglers"] == []  # strikes 1, 2: not yet
    clock.advance(1.0)
    for w in range(4):
        mon.heartbeat(w, slow if w == 3 else fast)
    assert mon.check()["stragglers"] == [3]  # third consecutive strike


def test_fast_step_resets_strikes():
    mon, clock = _monitor(4, strike_limit=3, straggler_factor=2.0)
    for w in range(4):
        mon.heartbeat(w, 10.0 if w == 3 else 1.0)
    for _ in range(2):
        clock.advance(1.0)
        assert mon.check()["stragglers"] == []
    # one on-median step wipes the strike count...
    mon.heartbeat(3, 1.0)
    clock.advance(1.0)
    assert mon.check()["stragglers"] == []
    assert mon.workers[3].slow_strikes == 0
    # ...so the NEXT slow streak starts from zero again
    for _ in range(2):
        mon.heartbeat(3, 10.0)
        clock.advance(1.0)
        assert mon.check()["stragglers"] == []


def test_evict_removes_from_alive_set():
    mon, _ = _monitor(3)
    mon.evict(1)
    assert mon.alive_workers() == [0, 2]
    assert mon.check()["healthy"] == 2


# -- elastic re-mesh --------------------------------------------------------


@pytest.mark.parametrize(
    "n_devices,expect",
    [
        (64, (4, 16)),   # full fleet
        (48, (2, 16)),   # lost a quarter: data axis rounds DOWN to pow-2
        (33, (2, 16)),
        (16, (1, 16)),
        (8, (1, 16)),    # fewer devices than one TP group: floor at 1
    ],
)
def test_elastic_mesh_shape_pow2(n_devices, expect):
    assert fault.elastic_mesh_shape(n_devices, model_parallel=16) == expect


# -- ElasticTrainer orchestration -------------------------------------------


def _state():
    return {"w": np.arange(8, dtype=np.float32)}


def test_trainer_remesh_and_restore_on_death(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    state = _state()
    ckpt.save(ckpt_dir, 5, state)
    clock = FakeClock()
    mon = fault.FleetMonitor(64, fail_timeout=60.0, clock=clock)
    tr = fault.ElasticTrainer(monitor=mon, ckpt_dir=ckpt_dir, model_parallel=16)
    # 16 workers (one TP group) go silent past the deadline
    clock.advance(61.0)
    live_times = {w: 1.0 for w in range(16, 64)}
    restored, new_mesh = tr.on_step(7, state, live_times)
    assert new_mesh == (2, 16)  # 48 survivors -> pow-2 data axis 2
    kinds = [e["kind"] for e in tr.events]
    assert kinds == ["remesh", "restore"]
    assert tr.events[0]["dead"] == list(range(16))
    assert tr.events[1]["from_step"] == 5
    np.testing.assert_array_equal(restored["w"], state["w"])


def test_trainer_evicts_stragglers_without_restore(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    ckpt.save(ckpt_dir, 1, _state())
    clock = FakeClock()
    mon = fault.FleetMonitor(8, strike_limit=2, clock=clock)
    tr = fault.ElasticTrainer(monitor=mon, ckpt_dir=ckpt_dir)
    state = _state()
    for step in range(2):
        clock.advance(1.0)
        out, mesh = tr.on_step(step, state, {w: (9.0 if w == 0 else 1.0) for w in range(8)})
        assert mesh is None  # stragglers never force a re-mesh/restore
    assert [e["kind"] for e in tr.events] == ["evict_stragglers"]
    assert tr.events[0]["workers"] == [0]
    assert 0 not in tr.monitor.alive_workers()
