"""Model-level invariants: attention impl equivalence, decode==forward,
MACE E(3) equivariance, MoE routing conservation, two-tower scoring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import graphcast, mace, schnet
from repro.models.recsys import two_tower
from repro.models.transformer import config as tcfg, model as tmodel, moe as tmoe

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(**kw):
    base = dict(
        name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=101, attn_impl="ref", compute_dtype=jnp.float32,
    )
    base.update(kw)
    return tcfg.TransformerConfig(**base)


def test_blocked_attention_equals_ref():
    cfg = _tiny_cfg(sliding_window=16, qkv_bias=True)
    cfg_b = dataclasses.replace(cfg, attn_impl="blocked", attn_block=8)
    p = tmodel.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    l1, _ = tmodel.forward(p, toks, cfg)
    l2, _ = tmodel.forward(p, toks, cfg_b)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=2e-3)


def test_scan_equals_unrolled_layers():
    cfg = _tiny_cfg()
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    p = tmodel.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    l1, _ = tmodel.forward(p, toks, cfg)
    l2, _ = tmodel.forward(p, toks, cfg_u)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


def test_decode_matches_forward_last_token():
    cfg = _tiny_cfg()
    p = tmodel.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    full, _ = tmodel.forward(p, toks, cfg)
    cache = tmodel.init_cache(cfg, 2, 16)
    for i in range(8):
        logits, cache = tmodel.decode_step(p, cache, toks[:, i : i + 1], cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_decode_scan_equals_unrolled():
    cfg = _tiny_cfg()
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    p = tmodel.init_params(KEY, cfg)
    tok = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0, cfg.vocab)
    l1, c1 = tmodel.decode_step(p, tmodel.init_cache(cfg, 2, 8), tok, cfg)
    l2, c2 = tmodel.decode_step(p, tmodel.init_cache(cfg, 2, 8), tok, cfg_u)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]), rtol=1e-5, atol=1e-6)


def test_sliding_window_cache_is_ring():
    cfg = _tiny_cfg(sliding_window=4)
    cache = tmodel.init_cache(cfg, 2, 1024)
    # SWA cache must be bounded by the (pow-2 rounded) window, not 1024
    assert cache["k"].shape[3] <= 8


def test_moe_conserves_tokens_and_routes_topk():
    t, d, e, k, cap = 64, 16, 8, 2, 32
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (t, d))
    router = jax.random.normal(jax.random.PRNGKey(5), (d, e))
    w1 = jax.random.normal(jax.random.PRNGKey(6), (e, d, 24)) / 4
    w3 = jax.random.normal(jax.random.PRNGKey(7), (e, d, 24)) / 4
    w2 = jax.random.normal(jax.random.PRNGKey(8), (e, 24, d)) / 5
    out, aux = tmoe.moe_ffn(
        x, router, w1, w3, w2, top_k=k, capacity=cap, compute_dtype=jnp.float32
    )
    assert out.shape == (t, d)
    assert np.isfinite(np.asarray(out)).all() and float(aux) > 0
    # capacity large enough -> no token dropped -> output nonzero rows
    assert (np.abs(np.asarray(out)).sum(-1) > 0).all()


def test_moe_drops_over_capacity():
    """capacity=1: most assignments dropped, output partially zero, no NaN."""
    t, d, e = 32, 8, 4
    x = jax.random.normal(KEY, (t, d))
    router = jnp.zeros((d, e)).at[0, 0].set(10.0)  # everyone wants expert 0
    w1 = jnp.ones((e, d, 8)) * 0.1
    w3 = jnp.ones((e, d, 8)) * 0.1
    w2 = jnp.ones((e, 8, d)) * 0.1
    out, _ = tmoe.moe_ffn(
        x, router, w1, w3, w2, top_k=1, capacity=1, compute_dtype=jnp.float32
    )
    assert np.isfinite(np.asarray(out)).all()


# --- MACE equivariance ------------------------------------------------------
def _mol(rng, n=24, e=64):
    return {
        "node_feat": jnp.asarray(rng.integers(0, 10, n), jnp.int32),
        "positions": jnp.asarray(rng.standard_normal((n, 3)) * 2, jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "graph_ids": jnp.zeros((n,), jnp.int32),
        "n_graphs": 1,
        "labels": jnp.asarray([0.0], jnp.float32),
    }


def _rotation(rng):
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


@pytest.mark.parametrize("seed", [0, 1])
def test_mace_e3_equivariance(seed):
    rng = np.random.default_rng(seed)
    cfg = mace.MACEConfig(d_hidden=16, n_rbf=6)
    p = mace.init_params(KEY, cfg)
    g = _mol(rng)
    q = _rotation(rng)
    pos = np.asarray(g["positions"])
    e1 = np.asarray(mace.forward(p, g, cfg))
    e2 = np.asarray(mace.forward(p, {**g, "positions": jnp.asarray(pos @ q.T, jnp.float32)}, cfg))
    np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-5)  # E invariant
    f1 = np.asarray(mace.forces(p, g, cfg))
    f2 = np.asarray(mace.forces(p, {**g, "positions": jnp.asarray(pos @ q.T, jnp.float32)}, cfg))
    np.testing.assert_allclose(f1 @ q.T, f2, rtol=1e-2, atol=1e-2)  # F equivariant (f32 rounding; violations would be O(1))
    e3 = np.asarray(mace.forward(p, {**g, "positions": jnp.asarray(pos + 7.0, jnp.float32)}, cfg))
    np.testing.assert_allclose(e1, e3, rtol=1e-4, atol=1e-5)  # translation


def test_mace_chunked_equals_unchunked():
    rng = np.random.default_rng(3)
    g = _mol(rng)
    cfg1 = mace.MACEConfig(d_hidden=16, n_rbf=6)
    cfg2 = dataclasses.replace(cfg1, edge_chunks=4)
    p = mace.init_params(KEY, cfg1)
    np.testing.assert_allclose(
        np.asarray(mace.forward(p, g, cfg1)),
        np.asarray(mace.forward(p, g, cfg2)),
        rtol=1e-4,
    )


def test_schnet_cutoff_kills_far_edges():
    """Edges beyond the cutoff must contribute (numerically) nothing."""
    rng = np.random.default_rng(5)
    cfg = schnet.SchNetConfig(n_rbf=8, d_hidden=16, cutoff=2.0)
    p = schnet.init_params(KEY, cfg)
    n = 8
    pos = np.zeros((n, 3), np.float32)
    pos[4:] += 100.0  # second cluster far beyond cutoff
    g = {
        "node_feat": jnp.asarray(rng.integers(0, 5, n), jnp.int32),
        "positions": jnp.asarray(pos),
        "edge_src": jnp.asarray([0, 4], jnp.int32),   # 0-4 crosses clusters
        "edge_dst": jnp.asarray([4, 0], jnp.int32),
        "graph_ids": jnp.zeros((n,), jnp.int32),
        "n_graphs": 1,
        "labels": jnp.asarray([0.0], jnp.float32),
    }
    e_with = np.asarray(schnet.forward(p, g, cfg))
    g2 = {**g, "edge_src": jnp.asarray([n, n], jnp.int32),
          "edge_dst": jnp.asarray([n, n], jnp.int32)}  # masked edges
    e_without = np.asarray(schnet.forward(p, g2, cfg))
    np.testing.assert_allclose(e_with, e_without, atol=1e-5)


def test_graphcast_bf16_close_to_f32():
    rng = np.random.default_rng(6)
    cfg = graphcast.GraphCastConfig(n_layers=2, d_hidden=32, n_vars=8)
    cfgb = dataclasses.replace(cfg, bf16=True)
    p = graphcast.init_params(KEY, cfg)
    n, e = 64, 256
    g = {
        "node_feat": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "labels": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
    }
    o1 = np.asarray(graphcast.forward(p, g, cfg))
    o2 = np.asarray(graphcast.forward(p, g, cfgb))
    np.testing.assert_allclose(o1, o2, rtol=0.1, atol=0.15)


def test_two_tower_retrieval_matches_serve():
    cfg = two_tower.TwoTowerConfig(
        n_users=100, n_items=100, embed_dim=8, tower_mlp=(16, 8),
        n_user_fields=2, n_item_fields=2, bag_size=4,
    )
    p = two_tower.init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    ub = jnp.asarray(rng.integers(-1, 100, (1, 2, 4)), jnp.int32)
    cb = jnp.asarray(rng.integers(-1, 100, (5, 2, 4)), jnp.int32)
    scores = np.asarray(two_tower.score_candidates(p, ub, cb, cfg))
    # pairwise serve on the same pairs must agree
    batch = {"user_bags": jnp.tile(ub, (5, 1, 1)), "item_bags": cb}
    pair = np.asarray(two_tower.serve_step(p, batch, cfg))
    np.testing.assert_allclose(scores, pair, rtol=1e-5, atol=1e-6)
