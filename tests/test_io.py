"""MTX loader tests (paper Alg 3-5) + synthetic generators."""
import os

import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.io import mtx, synthetic


def test_mtx_roundtrip_weighted(tmp_path):
    c = synthetic.make_graph("social", scale=8, edge_factor=4, seed=2)
    p = str(tmp_path / "g.mtx")
    mtx.write_mtx(p, c)
    c2 = mtx.load_mtx(p)
    assert (c2.n, c2.m) == (c.n, c.m)
    np.testing.assert_array_equal(np.asarray(c2.offsets), np.asarray(c.offsets))
    np.testing.assert_array_equal(np.asarray(c2.dst), np.asarray(c.dst))
    np.testing.assert_allclose(np.asarray(c2.wgt), np.asarray(c.wgt), rtol=1e-5)


def test_mtx_pattern_symmetric(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate pattern symmetric\n"
        "% a comment line\n4 4 3\n2 1\n3 1\n4 3\n"
    )
    p = tmp_path / "s.mtx"
    p.write_text(body)
    c = mtx.load_mtx(str(p))
    assert c.n == 4 and c.m == 6
    assert c.to_edge_sets() == [{1, 2}, {0}, {0, 3}, {2}]


def test_mtx_scientific_weights(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "3 3 2\n1 2 1.5e-2\n3 1 -2.25E+1\n"
    )
    p = tmp_path / "e.mtx"
    p.write_text(body)
    c = mtx.load_mtx(str(p))
    np.testing.assert_allclose(
        sorted(np.asarray(c.wgt).tolist()), [-22.5, 0.015], rtol=1e-6
    )


def test_mtx_partition_invariance(tmp_path):
    """Alg 5's partition count must not change the result."""
    c = synthetic.make_graph("road", scale=9, seed=4)
    p = str(tmp_path / "r.mtx")
    mtx.write_mtx(p, c)
    a = mtx.load_mtx(p, num_partitions=1)
    b = mtx.load_mtx(p, num_partitions=7)
    np.testing.assert_array_equal(np.asarray(a.offsets), np.asarray(b.offsets))
    np.testing.assert_array_equal(np.asarray(a.dst), np.asarray(b.dst))


@pytest.mark.parametrize("kind", ["web", "social", "road", "uniform"])
def test_synthetic_families(kind):
    c = synthetic.make_graph(kind, scale=8, edge_factor=4, seed=1)
    csr_mod.validate(c)
    assert c.n == 256 and c.m > 0


def test_update_batches_shapes():
    c = synthetic.make_graph("uniform", scale=8, edge_factor=4, seed=1)
    for f, b in synthetic.update_batches(c, fractions=(1e-2, 1e-1), kind="insert"):
        assert b.n == max(int(round(c.m * f)), 1) or b.n <= c.m
    for f, b in synthetic.update_batches(c, fractions=(1e-2,), kind="delete"):
        s, d, _ = b.to_numpy()
        sets = c.to_edge_sets()
        assert all(v in sets[u] for u, v in zip(s.tolist(), d.tolist()))
