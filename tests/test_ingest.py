"""Ingest-engine tests (DESIGN.md §10): tokenizer, fixed-width fast
path, csr_build engines, arena parity, chunked mmap loads, and the
per-buffer copy-on-write clone/snapshot protocol."""
import os

import numpy as np
import pytest

from repro.core import DiGraph, REPRESENTATIONS, csr as csr_mod, edgebatch
from repro.io import mtx, synthetic
from repro.kernels.csr_build import kernel as cb_kernel, ops as cb_ops, ref as cb_ref


def _write(tmp_path, body: str) -> str:
    p = str(tmp_path / "g.mtx")
    with open(p, "w") as f:
        f.write(body)
    return p


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tokenizer / parser
# ---------------------------------------------------------------------------
def test_general_tokenizer_matches_fixed_path(tmp_path):
    c = synthetic.make_graph("social", scale=9, edge_factor=4, seed=3)
    p = str(tmp_path / "g.mtx")
    mtx.write_mtx(p, c)
    a = mtx.load_mtx(p)                 # fixed-width fast path
    b = mtx.load_mtx(p, fixed=False)    # general mask/cumsum tokenizer
    _eq(a.offsets, b.offsets)
    _eq(a.dst, b.dst)
    _eq(a.wgt, b.wgt)
    _eq(a.dst, c.dst)


def test_ragged_whitespace_and_signs(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "% comment\n%% another\n% third\n"
        "4 4 5\n"
        "1 2 1.5\n"
        "  2\t3   -2.25\n"
        "3   4 +3e2\n"
        "4 1 .5\n"
        "1 1 5.\n"
    )
    c = mtx.load_mtx(_write(tmp_path, body))
    got = dict()
    o = np.asarray(c.offsets)
    d = np.asarray(c.dst)
    w = np.asarray(c.wgt)
    for u in range(4):
        for j in range(o[u], o[u + 1]):
            got[(u, int(d[j]))] = float(w[j])
    assert got == {
        (0, 1): 1.5, (1, 2): -2.25, (2, 3): 300.0, (3, 0): 0.5, (0, 0): 5.0
    }


def test_scientific_weights_roundtrip(tmp_path):
    vals = np.array(
        [1.5e-2, -2.25e1, 3.25e-30, -4.5e30, 0.0, 1.0, -1.0],
        np.float32,
    )
    n = vals.shape[0]
    src = np.arange(n)
    dst = (src + 1) % n
    c = csr_mod.from_coo(src, dst, vals, n=n)
    p = str(tmp_path / "e.mtx")
    mtx.write_mtx(p, c)
    for fixed in (True, False):
        c2 = mtx.load_mtx(p, fixed=fixed)
        _eq(c2.wgt, c.wgt)


def test_pattern_symmetric(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate pattern symmetric\n"
        "% a comment line\n4 4 3\n2 1\n3 1\n4 3\n"
    )
    c = mtx.load_mtx(_write(tmp_path, body))
    assert c.n == 4 and c.m == 6
    assert c.to_edge_sets() == [{1, 2}, {0}, {0, 3}, {2}]


def test_truncated_body_raises(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "4 4 5\n1 2 1.0\n2 3 1.0\n"
    )
    with pytest.raises(ValueError, match="truncated|tokens"):
        mtx.load_mtx(_write(tmp_path, body))


def test_malformed_token_count_raises(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "4 4 2\n1 2 1.0\n2 3 1.0 7 8\n"
    )
    with pytest.raises(ValueError):
        mtx.load_mtx(_write(tmp_path, body), fixed=False)


def test_garbage_byte_raises(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "4 4 2\n1 2 1.0\nx y 1.0\n"
    )
    with pytest.raises(ValueError):
        mtx.load_mtx(_write(tmp_path, body), fixed=False)


def test_out_of_range_coordinate_raises(tmp_path):
    body = (
        "%%MatrixMarket matrix coordinate real general\n"
        "4 4 2\n1 2 1.0\n9 1 1.0\n"
    )
    with pytest.raises(ValueError, match="out of range"):
        mtx.load_mtx(_write(tmp_path, body))


def test_partition_parallel_parse_invariance(tmp_path):
    c = synthetic.make_graph("uniform", scale=10, edge_factor=8, seed=5)
    p = str(tmp_path / "u.mtx")
    mtx.write_mtx(p, c)
    base = mtx.load_mtx(p, num_partitions=1)
    # force the thread fan-out regardless of body size
    old = mtx._PARALLEL_MIN_BYTES
    mtx._PARALLEL_MIN_BYTES = 1
    try:
        for rho in (2, 3):
            for fixed in (True, False):
                c2 = mtx.load_mtx(p, num_partitions=rho, fixed=fixed)
                _eq(c2.offsets, base.offsets)
                _eq(c2.dst, base.dst)
                _eq(c2.wgt, base.wgt)
    finally:
        mtx._PARALLEL_MIN_BYTES = old


def test_compiled_parser_matches_numpy_folds(tmp_path):
    """io/_cparse.py (when buildable) must be bit-identical to the sgemm
    fold path, including negative weights and id range validation."""
    rng = np.random.default_rng(41)
    src, dst = synthetic.uniform_edges(rng, 200, 900)
    w = (rng.uniform(0.5, 1.5, 900) * np.where(rng.random(900) < 0.3, -1, 1))
    c = csr_mod.from_coo(src, dst, w.astype(np.float32), n=200)
    p = str(tmp_path / "c.mtx")
    mtx.write_mtx(p, c)
    a = mtx.load_mtx(p)
    old = mtx.USE_C_PARSE
    try:
        mtx.USE_C_PARSE = False
        b = mtx.load_mtx(p)
    finally:
        mtx.USE_C_PARSE = old
    _eq(a.offsets, b.offsets)
    _eq(a.dst, b.dst)
    _eq(a.wgt, b.wgt)


def test_mmap_chunked_load_matches_whole_buffer(tmp_path):
    c = synthetic.make_graph("web", scale=9, edge_factor=4, seed=7)
    p = str(tmp_path / "m.mtx")
    mtx.write_mtx(p, c)
    whole = mtx.load_mtx(p)
    chunked = mtx.load_mtx(p, mmap_threshold=0, chunk_bytes=1 << 12)
    _eq(chunked.offsets, whole.offsets)
    _eq(chunked.dst, whole.dst)
    _eq(chunked.wgt, whole.wgt)


def test_write_mtx_is_valid_for_foreign_parsers(tmp_path):
    """The fixed-width writer must stay plain Matrix Market (python parse)."""
    c = synthetic.make_graph("road", scale=8, seed=2)
    p = str(tmp_path / "r.mtx")
    mtx.write_mtx(p, c)
    src, dst, wgt = [], [], []
    with open(p) as f:
        assert f.readline().startswith("%%MatrixMarket")
        n, n2, m = map(int, f.readline().split())
        for line in f:
            a, b, w = line.split()
            src.append(int(a) - 1)
            dst.append(int(b) - 1)
            wgt.append(float(w))
    assert len(src) == c.m
    c2 = csr_mod.from_coo(src, dst, np.array(wgt, np.float32), n=n, dedup=False)
    _eq(c2.dst, c.dst)
    np.testing.assert_allclose(
        np.asarray(c2.wgt), np.asarray(c.wgt), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# csr_build engines
# ---------------------------------------------------------------------------
def _random_coo(seed, n=64, m=400):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, n, m),
        rng.integers(0, n, m),
        rng.uniform(0.5, 1.5, m).astype(np.float32),
        n,
    )


@pytest.mark.parametrize("seed", [0, 1])
def test_count_degrees_engines_agree(seed):
    src, _, _, n = _random_coo(seed)
    ref = cb_ref.count_degrees_reference(src, n)
    host = cb_ops.count_degrees(src, n, engine="host")
    xla = np.asarray(cb_ops.count_degrees(src, n, engine="xla"))
    pallas = np.asarray(
        cb_ops.count_degrees(src, n, engine="pallas", interpret=True)
    )
    _eq(host, ref)
    _eq(xla, ref)
    _eq(pallas, ref)


def test_pallas_degree_kernel_tiles():
    src = np.arange(300, dtype=np.int64) % 130
    tiles = np.full(384, 256, np.int32)
    tiles[:300] = src
    deg = np.asarray(
        cb_kernel.count_degrees_pallas(
            np.asarray(tiles.reshape(-1, cb_kernel.EB)), nv=256, interpret=True
        )
    )
    _eq(deg[:130], cb_ref.count_degrees_reference(src, 130))


@pytest.mark.parametrize("seed", [3, 4])
def test_from_coo_engine_parity(seed):
    src, dst, wgt, n = _random_coo(seed)
    host = csr_mod.from_coo(src, dst, wgt, n=n, dedup=False, engine="host")
    xla = csr_mod.from_coo(src, dst, wgt, n=n, dedup=False, engine="xla")
    o_ref, d_ref, w_ref = cb_ref.coo_to_csr_reference(src, dst, wgt, n=n)
    _eq(host.offsets, o_ref)
    _eq(host.dst, d_ref)
    _eq(xla.offsets, o_ref)
    _eq(xla.dst, xla.dst)
    _eq(np.asarray(xla.dst), d_ref)
    # weights: dedup=False keeps duplicates; ref emits them in file order
    np.testing.assert_allclose(np.asarray(host.wgt), w_ref, rtol=0)


def test_from_coo_presorted_shortcut_matches_sorted():
    src, dst, wgt, n = _random_coo(9)
    a = csr_mod.from_coo(src, dst, wgt, n=n, dedup=False)
    # feed the already-sorted edges back through (triggers the skip path)
    b = csr_mod.from_coo(
        np.repeat(np.arange(n), np.diff(np.asarray(a.offsets))),
        np.asarray(a.dst),
        np.asarray(a.wgt),
        n=n,
        dedup=False,
    )
    _eq(a.offsets, b.offsets)
    _eq(a.dst, b.dst)
    _eq(a.wgt, b.wgt)


def test_arena_image_engines_and_reference():
    from repro.core import alloc

    src, dst, wgt, n = _random_coo(11)
    c = csr_mod.from_coo(src, dst, wgt, n=n, dedup=True)
    degrees = np.diff(np.asarray(c.offsets))
    caps = np.where(degrees > 0, alloc.edge_capacities(degrees), 0)
    csum = np.cumsum(caps)
    starts = np.where(caps > 0, csum - caps, -1)
    total = int(csum[-1])
    cap_e = alloc.next_pow2(max(total, 2))
    cap_v = n + 7
    args = (c.offsets, c.dst, c.wgt, starts, caps, cap_e, cap_v)
    r_d, r_w, r_r = cb_ref.arena_image_reference(*args)
    h = cb_ops.arena_image(*args, total=total, engine="host")
    d = cb_ops.arena_image(*args, total=total, engine="xla")
    for got in (h, d):
        _eq(got[0], r_d)
        _eq(got[1], r_w)
        _eq(got[2], r_r)


def test_load_digraph_bit_identical_to_host_from_csr(tmp_path):
    c = synthetic.make_graph("web", scale=9, edge_factor=4, seed=13)
    p = str(tmp_path / "w.mtx")
    mtx.write_mtx(p, c)
    g1 = mtx.load_digraph(p)
    g2 = DiGraph.from_csr(mtx.load_mtx(p), engine="host")
    _eq(g1.dst, g2.dst)
    _eq(g1.wgt, g2.wgt)
    _eq(g1.slot_rows, g2.slot_rows)
    assert (g1.n, g1.m) == (g2.n, g2.m)
    np.testing.assert_array_equal(g1.starts, g2.starts)
    np.testing.assert_array_equal(g1.capacities, g2.capacities)


# ---------------------------------------------------------------------------
# clone isolation + per-buffer COW (dense-oracle checks)
# ---------------------------------------------------------------------------
def _dense(g, n):
    c = g.to_csr()
    a = np.zeros((n, n), np.float32)
    d = c.to_dense()
    a[: d.shape[0], : d.shape[1]] = d
    return a


@pytest.mark.parametrize("name,cls", list(REPRESENTATIONS.items()))
def test_clone_isolation_dense_oracle(name, cls):
    rng = np.random.default_rng(21)
    src, dst = synthetic.uniform_edges(rng, 48, 300)
    c = csr_mod.from_coo(src, dst, n=48)
    g = cls.from_csr(c)
    before = _dense(g, 64)
    cl = g.clone()
    # mutate the clone: the original must not move (and vice versa)
    cl, _ = cl.add_edges(edgebatch.random_insertions(rng, 60, 25))
    cl, _ = cl.remove_edges(edgebatch.random_deletions(rng, cl.to_csr(), 10))
    np.testing.assert_array_equal(_dense(g, 64), before)
    after_clone = _dense(cl, 64)
    g, _ = g.add_edges(edgebatch.random_insertions(rng, 60, 25))
    np.testing.assert_array_equal(_dense(cl, 64), after_clone)


@pytest.mark.parametrize("name,cls", list(REPRESENTATIONS.items()))
def test_post_snapshot_mutation_isolation(name, cls):
    rng = np.random.default_rng(23)
    src, dst = synthetic.uniform_edges(rng, 48, 300)
    c = csr_mod.from_coo(src, dst, n=48)
    g = cls.from_csr(c)
    snap = g.snapshot()
    frozen = _dense(snap, 64)
    for _ in range(3):
        g, _ = g.add_edges(edgebatch.random_insertions(rng, 60, 20))
        g, _ = g.remove_edges(edgebatch.random_deletions(rng, g.to_csr(), 8))
        np.testing.assert_array_equal(_dense(snap, 64), frozen)


def test_digraph_cow_detaches_only_touched_buffers():
    """A non-growing post-snapshot update must keep sharing slot_rows."""
    rng = np.random.default_rng(29)
    src, dst = synthetic.uniform_edges(rng, 32, 400)
    g = DiGraph.from_csr(csr_mod.from_coo(src, dst, n=32))
    snap = g.snapshot()
    assert g.sealed and snap.sealed
    # delete a handful of edges: no CP2AA class changes, no block moves
    b = edgebatch.random_deletions(rng, g.to_csr(), 4)
    g, _ = g.remove_edges(b)
    assert g.slot_rows is snap.slot_rows, "owner map should stay shared"
    assert g.dst is not snap.dst and g.wgt is not snap.wgt
    assert "slot_rows" in g._sealed and "dst" not in g._sealed
    # a growing update (class spill) must now detach the owner map too
    hub = np.zeros(600, np.int64)
    g, _ = g.add_edges(edgebatch.from_arrays(hub, 40 + np.arange(600)))
    assert g.slot_rows is not snap.slot_rows


def test_lazy_cow_base_arrays_never_copied():
    rng = np.random.default_rng(31)
    src, dst = synthetic.uniform_edges(rng, 32, 300)
    from repro.core import LazyCSR

    g = LazyCSR.from_csr(csr_mod.from_coo(src, dst, n=32))
    snap = g.snapshot()
    g, _ = g.remove_edges(edgebatch.random_deletions(rng, g.to_csr(), 5))
    # zombie marking detaches only the masks
    assert g.base_dst is snap.base_dst and g.base_wgt is snap.base_wgt
    assert g.dead is not snap.dead
    g, _ = g.add_edges(edgebatch.random_insertions(rng, 32, 5))
    assert g.base_dst is snap.base_dst, "appends must not copy the base"


def test_digraph_clone_single_fused_dispatch(monkeypatch):
    """clone() must route every device buffer through ONE fused_copy call."""
    from repro.core import util as core_util

    rng = np.random.default_rng(37)
    src, dst = synthetic.uniform_edges(rng, 32, 200)
    g = DiGraph.from_csr(csr_mod.from_coo(src, dst, n=32))
    calls = []
    real = core_util.fused_copy

    def spy(*arrays):
        calls.append(len(arrays))
        return real(*arrays)

    monkeypatch.setattr(core_util, "fused_copy", spy)
    monkeypatch.setattr(
        "repro.core.digraph.util.fused_copy", spy, raising=False
    )
    cl = g.clone()
    assert calls == [3], f"expected one fused 3-buffer copy, got {calls}"
    _eq(cl.dst, g.dst)
    assert cl.dst is not g.dst
