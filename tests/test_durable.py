"""Durability pipeline (DESIGN.md §13): WAL journal, checkpoint/restore
bit-parity, crash-recovery sweeps over every representation × injection
point, the kernel fallback chain, and the cross-layer invariant audit."""
import json
import os
import shutil

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.core import REPRESENTATIONS, csr as csr_mod, edgebatch, updates
from repro.kernels import fallback
from repro.runtime import durable, faultinject

N_V = 48
CRASH_POINTS = ("durable.pre_append", "durable.post_append", "durable.post_apply")


@pytest.fixture(autouse=True)
def _clean_fault_state():
    faultinject.disarm()
    fallback.BREAKER.reset()
    fallback.LAST_USED.clear()
    yield
    faultinject.disarm()
    fallback.BREAKER.reset()
    fallback.LAST_USED.clear()


@pytest.fixture(scope="module")
def base_csr():
    rng = np.random.default_rng(11)
    m = 220
    return csr_mod.from_coo(
        rng.integers(0, N_V, m),
        rng.integers(0, N_V, m),
        rng.random(m).astype(np.float32),
        n=N_V,
    )


def make_plans(k=6, seed=7, n=N_V):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        ib = edgebatch.from_arrays(
            rng.integers(0, n, 12),
            rng.integers(0, n, 12),
            rng.random(12).astype(np.float32),
        )
        db = edgebatch.from_arrays(rng.integers(0, n, 6), rng.integers(0, n, 6))
        out.append(updates.plan_update(inserts=ib, deletes=db))
    return out


def dense_oracle(rep):
    c = rep.to_csr()
    return (
        np.asarray(c.offsets),
        np.asarray(c.dst)[: c.m],
        np.asarray(c.wgt)[: c.m],
    )


def assert_bit_parity(a, b):
    for x, y in zip(dense_oracle(a), dense_oracle(b)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# WAL record / journal mechanics
# ---------------------------------------------------------------------------


def test_wal_record_roundtrip():
    plan = make_plans(1)[0]
    rec = durable.encode_record(9, 77, plan)
    seq, nv, (qs, qd, qw, ql) = durable.decode_record(
        rec[: durable._HEADER.size], rec[durable._HEADER.size :]
    )
    assert (seq, nv) == (9, 77)
    np.testing.assert_array_equal(qs, plan.q_src)
    np.testing.assert_array_equal(qd, plan.q_dst)
    np.testing.assert_array_equal(qw, plan.q_wgt)
    np.testing.assert_array_equal(ql, plan.q_del)


def test_journal_append_replay_rotation(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256)  # force rotation
    plans = make_plans(5)
    seqs = [j.append(p, N_V) for p in plans]
    assert seqs == [1, 2, 3, 4, 5]
    assert len(j.segments()) > 1  # each ~240-byte record rotates
    j.close()
    j2 = durable.UpdateJournal(wal, segment_bytes=256)
    got = list(j2.replay())
    assert [s for s, _, _ in got] == seqs
    for (_, nv, (qs, qd, qw, ql)), p in zip(got, plans):
        assert nv == N_V
        np.testing.assert_array_equal(qs, p.q_src)
        np.testing.assert_array_equal(qw, p.q_wgt)
    assert [s for s, _, _ in j2.replay(after=3)] == seqs[3:]
    assert j2.next_seq == 6  # reopen resumes the sequence
    j2.close()


def test_journal_truncate_through(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256)
    for p in make_plans(6):
        j.append(p, N_V)
    n_before = len(j.segments())
    assert n_before >= 3
    j.truncate_through(6)
    # everything but the append-target segment is redundant
    assert len(j.segments()) == 1
    # the surviving records still replay cleanly
    assert all(s <= 6 for s, _, _ in j.replay())
    j.close()


def test_torn_tail_repaired_on_recovery_open(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal)
    for p in make_plans(3):
        j.append(p, N_V)
    j.close()
    seg = j.segments()[-1]
    faultinject.tear_tail(seg, 10)  # torn mid-record at the tail
    j2 = durable.UpdateJournal(wal, repair=True)
    assert [s for s, _, _ in j2.replay()] == [1, 2]  # record 3 cut
    assert j2.next_seq == 3  # its sequence number is reused
    j2.close()


def test_corrupt_record_raises(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal)
    for p in make_plans(3):
        j.append(p, N_V)
    j.close()
    seg = j.segments()[0]
    # flip a payload byte of the FIRST record: complete but rotten
    faultinject.corrupt_byte(seg, durable._HEADER.size + 3)
    with pytest.raises(durable.WalCorruptError):
        list(durable.UpdateJournal(wal).replay())
    # repair refuses too — truncating would drop acknowledged updates
    with pytest.raises(durable.WalCorruptError):
        durable.UpdateJournal(wal, repair=True)


def test_scan_next_seq_reads_final_segment_only(tmp_path):
    """Opening a journal must not decode the whole log: a rotten byte in
    an EARLIER segment is invisible to the open (filenames carry
    first_seq, only the final segment is walked) but still fatal to a
    full replay."""
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256)
    for p in make_plans(6):
        j.append(p, N_V)
    j.close()
    segs = j.segments()
    assert len(segs) >= 3
    faultinject.corrupt_byte(segs[0], durable._HEADER.size + 3)
    j2 = durable.UpdateJournal(wal, segment_bytes=256)  # opens fine
    assert j2.next_seq == 7
    with pytest.raises(durable.WalCorruptError):
        list(j2.replay())  # the full decode still sees the rot
    j2.close()


def test_scan_next_seq_torn_final_segment(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256)
    for p in make_plans(4):
        j.append(p, N_V)
    j.close()
    faultinject.tear_tail(j.segments()[-1], 10)
    # without repair the torn record is simply not counted
    j2 = durable.UpdateJournal(wal, segment_bytes=256)
    assert j2.next_seq == 4
    j2.close()


def test_journal_fsync_rotation_durable(tmp_path):
    """fsync=True also fsyncs the WAL directory after each rotation (the
    new segment NAME must survive power loss, not just its bytes)."""
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256, fsync=True)
    plans = make_plans(5)
    for p in plans:
        j.append(p, N_V)
    assert len(j.segments()) > 1  # rotation happened under fsync
    assert [s for s, _, _ in j.replay()] == [1, 2, 3, 4, 5]
    j.close()


def test_group_append_one_flush_one_segment(tmp_path):
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal, segment_bytes=256)
    plans = make_plans(4)
    f0 = j.flushes
    seqs = j.append_group(plans, [N_V] * 4)
    assert seqs == [1, 2, 3, 4] and j.flushes - f0 == 1
    # a group never splits across segments: all records in one file
    assert len(j.segments()) == 1
    got = list(j.replay())
    assert [s for s, _, _ in got] == seqs
    for (_, _, (qs, _, _, _)), p in zip(got, plans):
        np.testing.assert_array_equal(qs, p.q_src)
    # the NEXT group rotates first (segment is over budget), then lands
    j.append_group(make_plans(2, seed=5), [N_V] * 2)
    assert len(j.segments()) == 2
    assert [s for s, _, _ in j.replay()] == [1, 2, 3, 4, 5, 6]
    j.close()


# ---------------------------------------------------------------------------
# WAL segment-write hardening (ENOSPC / short write, §17 satellite)
# ---------------------------------------------------------------------------
def test_wal_disk_full_rolls_back_and_retries(tmp_path):
    """A failed segment write surfaces as WalDiskFullError with the
    prior segment contents intact and the sequence NOT burned — the
    same journal object retries the same plan under the same seq."""
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal)
    plans = make_plans(3)
    j.append(plans[0], N_V)
    seq0, flushes0 = j.next_seq, j.flushes
    size0 = os.path.getsize(j.segments()[-1])
    faultinject.arm("wal.write", times=1)
    with pytest.raises(durable.WalDiskFullError):
        j.append(plans[1], N_V)
    assert j.next_seq == seq0  # the failed record's seq is reusable
    assert j.flushes == flushes0  # no flush accounted for a dead write
    assert os.path.getsize(j.segments()[-1]) == size0  # truncated back
    assert [s for s, _, _ in j.replay()] == [1]  # prior record intact
    seq = j.append(plans[1], N_V)  # retry on the SAME handle
    assert seq == seq0
    assert [s for s, _, _ in j.replay()] == [1, 2]
    # ...and a reopened journal agrees (the reopened "ab" handle works)
    j.append(plans[2], N_V)
    j.close()
    j2 = durable.UpdateJournal(wal)
    assert [s for s, _, _ in j2.replay()] == [1, 2, 3]
    j2.close()


def test_wal_disk_full_group_append_atomic(tmp_path):
    """append_group is one buffered write: a disk-full fault loses the
    WHOLE group atomically, and the retry reuses the same seqs."""
    wal = str(tmp_path / "wal")
    j = durable.UpdateJournal(wal)
    base = make_plans(2, seed=3)
    j.append_group(base, [N_V] * 2)
    group = make_plans(3, seed=4)
    faultinject.arm("wal.write", times=1)
    with pytest.raises(durable.WalDiskFullError):
        j.append_group(group, [N_V] * 3)
    assert j.next_seq == 3
    assert [s for s, _, _ in j.replay()] == [1, 2]  # no torn group suffix
    assert j.append_group(group, [N_V] * 3) == [3, 4, 5]
    assert [s for s, _, _ in j.replay()] == [1, 2, 3, 4, 5]
    j.close()


# ---------------------------------------------------------------------------
# checkpoint manager: stale sweep, legacy manifests, diff chains
# ---------------------------------------------------------------------------
def test_clean_stale_sweeps_tmp_dirs(tmp_path):
    cd = str(tmp_path / "ckpt")
    ckpt.save_arrays(cd, 0, {"a": np.arange(4)})
    os.makedirs(os.path.join(cd, ".tmp_ckpt_dead1", "sub"))
    os.makedirs(os.path.join(cd, ".tmp_ckpt_dead2"))
    removed = ckpt.clean_stale(cd)
    assert sorted(removed) == [".tmp_ckpt_dead1", ".tmp_ckpt_dead2"]
    assert not [n for n in os.listdir(cd) if n.startswith(".tmp_ckpt_")]
    # committed steps are untouched, and a second sweep is a no-op
    assert ckpt.all_steps(cd) == [0]
    assert ckpt.clean_stale(cd) == []


def test_legacy_flat_manifest_restores(tmp_path):
    """Pre-§14 manifests (no "shards" key, flat keys/shapes/dtypes) must
    keep restoring through every entry point."""
    cd = str(tmp_path / "ckpt")
    d = os.path.join(cd, "step_0000000007")
    os.makedirs(d)
    arrays = {"dst": np.arange(10, dtype=np.int32), "deg": np.ones(5, np.int64)}
    np.savez(os.path.join(d, "shard_0.npz"), **arrays)
    manifest = {
        "step": 7,
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    got, step = ckpt.restore_arrays(cd)
    assert step == 7
    np.testing.assert_array_equal(got["dst"], arrays["dst"])
    shards, _ = ckpt.restore_arrays_sharded(cd)
    assert list(shards) == [0]
    np.testing.assert_array_equal(shards[0]["deg"], arrays["deg"])
    # diff-aware chain restore treats it as a full base too
    trees, _ = ckpt.restore_arrays_diff(cd)
    np.testing.assert_array_equal(trees[0]["dst"], arrays["dst"])
    with pytest.raises(FileNotFoundError):
        ckpt.restore_arrays(cd, shard_id=1)


def test_manager_diff_chain_and_crc_gate(tmp_path):
    cd = str(tmp_path / "ckpt")
    rng = np.random.default_rng(3)
    a0 = {"dst": rng.integers(0, 99, 9000).astype(np.int32),
          "deg": rng.integers(0, 9, 300).astype(np.int64)}
    ckpt.save_arrays_sharded(cd, 0, {0: dict(a0)})
    a1 = {k: v.copy() for k, v in a0.items()}
    a1["dst"][4096 // 4 + 1] = 777  # second 16 KiB chunk
    # hash-compare diff, then a ranged-hint diff on top of it
    ckpt.save_arrays_diff(cd, 1, {0: a1})
    a2 = {k: v.copy() for k, v in a1.items()}
    a2["deg"][5] = 42
    hint = {0: {"dst": "clean", "deg": np.array([[5, 6]])}}
    p2 = ckpt.save_arrays_diff(cd, 2, {0: a2}, dirty=hint)
    man = ckpt._read_manifest(p2)
    assert man["kind"] == "diff" and man["base_step"] == 1
    for s, want in ((0, a0), (1, a1), (2, a2)):
        trees, _ = ckpt.restore_arrays_diff(cd, step=s)
        for k in want:
            np.testing.assert_array_equal(trees[0][k], want[k])
    # a digest that disagrees with the patched bytes must fail the gate
    man_path = os.path.join(p2, "manifest.json")
    man["shards"]["0"]["chunks"]["deg"][0] ^= 0xFF
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="CRC"):
        ckpt.restore_arrays_diff(cd, step=2)


def test_diff_rotation_keeps_chain_base(tmp_path):
    cd = str(tmp_path / "ckpt")
    a = {"x": np.arange(64, dtype=np.int64)}
    ckpt.save_arrays_sharded(cd, 0, {0: dict(a)})
    for s in (1, 2, 3, 4):
        ckpt.save_arrays_diff(cd, s, {0: dict(a)}, keep=2)
    steps = ckpt.all_steps(cd)
    assert 0 in steps  # the full base survives keep=2
    trees, _ = ckpt.restore_arrays_diff(cd)
    np.testing.assert_array_equal(trees[0]["x"], a["x"])
    # a NEW full step re-anchors; old chain becomes rotatable
    ckpt.save_arrays_sharded(cd, 5, {0: dict(a)}, keep=2)
    ckpt.save_arrays_sharded(cd, 6, {0: dict(a)}, keep=2)
    assert ckpt.all_steps(cd) == [5, 6]


# ---------------------------------------------------------------------------
# diff-chain pathologies (§17 satellite): a damaged or missing BASE must
# fail the restore atomically with a diagnosable error, never patch
# garbage; rotation must never orphan a kept diff's base mid-chain
# ---------------------------------------------------------------------------
def _diff_chain(tmp_path, nshards=1):
    cd = str(tmp_path / "ckpt")
    rng = np.random.default_rng(9)
    shards0 = {
        s: {"dst": rng.integers(0, 99, 4000).astype(np.int32) + s,
            "deg": rng.integers(0, 9, 64).astype(np.int64)}
        for s in range(nshards)
    }
    ckpt.save_arrays_sharded(cd, 0, {s: dict(t) for s, t in shards0.items()})
    shards1 = {s: {k: v.copy() for k, v in t.items()}
               for s, t in shards0.items()}
    for s in shards1:
        shards1[s]["dst"][7] = 12345 + s
    ckpt.save_arrays_diff(cd, 1, {s: dict(t) for s, t in shards1.items()})
    return cd, shards1


def test_restore_diff_corrupt_base_manifest_json(tmp_path):
    cd, _ = _diff_chain(tmp_path)
    man = os.path.join(cd, "step_0000000000", "manifest.json")
    with open(man, "w") as f:
        f.write('{"step": 0, "kind": "fu')  # torn JSON
    with pytest.raises(ValueError, match="corrupt"):
        ckpt.restore_arrays_diff(cd, step=1)
    with pytest.raises(ValueError, match="corrupt"):
        ckpt.restore_shard_diff(cd, 0, step=1)


def test_restore_diff_base_payload_digest_gate(tmp_path):
    """A base whose manifest digests disagree with its payload bytes is
    untrusted — the restore aborts BEFORE applying any diff patch."""
    cd, _ = _diff_chain(tmp_path)
    man_path = os.path.join(cd, "step_0000000000", "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["shards"]["0"]["chunks"]["dst"][0] ^= 0xFF
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="aborted before patching"):
        ckpt.restore_arrays_diff(cd, step=1)
    # the base itself (no chain, no patching) still restores by bytes
    assert ckpt.restore_arrays(cd, step=0) is not None


def test_restore_diff_missing_base_step(tmp_path):
    cd, _ = _diff_chain(tmp_path)
    shutil.rmtree(os.path.join(cd, "step_0000000000"))
    with pytest.raises((FileNotFoundError, ValueError)):
        ckpt.restore_arrays_diff(cd, step=1)
    with pytest.raises((FileNotFoundError, ValueError)):
        ckpt.restore_shard_diff(cd, 0, step=1)


def test_restore_shard_diff_matches_full_restore(tmp_path):
    cd, want = _diff_chain(tmp_path, nshards=2)
    full, step_f = ckpt.restore_arrays_diff(cd, step=1)
    for sid in (0, 1):
        arrays, step = ckpt.restore_shard_diff(cd, sid, step=1)
        assert step == step_f == 1
        for k in want[sid]:
            np.testing.assert_array_equal(arrays[k], want[sid][k])
            np.testing.assert_array_equal(arrays[k], full[sid][k])
    with pytest.raises(FileNotFoundError):
        ckpt.restore_shard_diff(cd, 7, step=1)


def test_rotation_never_orphans_mid_chain_base(tmp_path):
    """keep=N counts CHAIN-CLOSED prefixes: a kept diff's base must
    survive rotation even when an unrelated newer full exists."""
    cd = str(tmp_path / "ckpt")
    a = {"x": np.arange(32, dtype=np.int64)}
    ckpt.save_arrays_sharded(cd, 0, {0: dict(a)})
    ckpt.save_arrays_diff(cd, 1, {0: dict(a)}, keep=2)
    ckpt.save_arrays_sharded(cd, 2, {0: dict(a)}, keep=2)
    ckpt.save_arrays_diff(cd, 3, {0: dict(a)}, keep=2)
    steps = ckpt.all_steps(cd)
    # every surviving diff's base chain is closed
    for s in steps:
        man = ckpt._read_manifest(os.path.join(cd, f"step_{s:010d}"))
        if man.get("kind") == "diff":
            assert man["base_step"] in steps, f"diff {s} orphaned"
        trees, got = ckpt.restore_arrays_diff(cd, step=s)
        assert got == s and trees  # every kept step restores


# ---------------------------------------------------------------------------
# boundary validation
# ---------------------------------------------------------------------------


def test_edgebatch_rejects_nonfinite_weight():
    with pytest.raises(ValueError, match="non-finite"):
        edgebatch.from_arrays(
            np.array([0, 1]), np.array([1, 2]),
            np.array([1.0, np.nan], np.float32),
        )
    with pytest.raises(ValueError, match="non-finite"):
        edgebatch.from_arrays(
            np.array([0]), np.array([1]), np.array([np.inf], np.float32)
        )


def test_plan_from_canonical_rejects_unsorted_and_negative():
    with pytest.raises(ValueError, match="sorted"):
        updates.plan_from_canonical(
            np.array([1, 0], np.int32), np.array([0, 0], np.int32),
            np.ones(2, np.float32), np.zeros(2, bool),
        )
    with pytest.raises(ValueError, match="negative"):
        updates.plan_from_canonical(
            np.array([-1, 0], np.int32), np.array([0, 0], np.int32),
            np.ones(2, np.float32), np.zeros(2, bool),
        )
    with pytest.raises(ValueError, match="length"):
        updates.plan_from_canonical(
            np.array([0], np.int32), np.array([0, 1], np.int32),
            np.ones(2, np.float32), np.zeros(2, bool),
        )


def _nan_plan():
    # plan_from_canonical defers value checks to validate()/apply()
    return updates.plan_from_canonical(
        np.array([0, 1], np.int32), np.array([1, 2], np.int32),
        np.array([1.0, np.nan], np.float32), np.array([False, False]),
    )


@pytest.mark.parametrize("name", list(REPRESENTATIONS))
def test_apply_rejects_nan_weight_every_rep(name, base_csr):
    g = REPRESENTATIONS[name].from_csr(base_csr)
    with pytest.raises(ValueError, match="non-finite"):
        g.apply(_nan_plan())


def test_validate_vertex_bound_replay_only():
    plan = updates.plan_from_canonical(
        np.array([5], np.int32), np.array([7], np.int32),
        np.ones(1, np.float32), np.zeros(1, bool),
    )
    plan.validate()  # unbounded: fine (apply grows the vertex set)
    with pytest.raises(ValueError, match="bound"):
        plan.validate(num_vertices=7)  # replay watermark says <= 6


# ---------------------------------------------------------------------------
# checkpoint bit-parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(REPRESENTATIONS))
def test_checkpoint_roundtrip_bit_parity(name, base_csr, tmp_path):
    cls = REPRESENTATIONS[name]
    g = cls.from_csr(base_csr)
    plans = make_plans(4, seed=3)
    for p in plans[:2]:
        g, _ = g.apply(p)
    d = str(tmp_path / "ck")
    ckpt.save_arrays(d, 0, g.state_tree())
    arrays, step = ckpt.restore_arrays(d)
    h = cls.from_state_tree(arrays)
    assert_bit_parity(g, h)
    # the restored instance keeps applying in lockstep — exact state, not
    # just an equivalent edge set (arena geometry included)
    for p in plans[2:]:
        g, _ = g.apply(p)
        h, _ = h.apply(p)
    assert_bit_parity(g, h)
    np.testing.assert_array_equal(
        np.asarray(g.reverse_walk(3)), np.asarray(h.reverse_walk(3))
    )


# ---------------------------------------------------------------------------
# crash-recovery sweeps
# ---------------------------------------------------------------------------


def run_crash(cls, base_csr, tmp_path, point, kcrash=3, n_plans=6, seed=7):
    """Drive a durable stream into a crash at ``point``; return
    (recovered DurableGraph, uncrashed twin rep, remaining plans)."""
    wal, ck = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    plans = make_plans(n_plans, seed=seed)
    g = durable.DurableGraph(cls.from_csr(base_csr), wal, ck)
    crashed = False
    for i, p in enumerate(plans):
        if i == kcrash:
            faultinject.arm(point)
        try:
            g.apply(p)
        except faultinject.SimulatedCrash:
            crashed = True
            break
        finally:
            faultinject.disarm()
    assert crashed
    g.close()
    r = durable.DurableGraph.recover(wal, ck)
    # pre-append: the crashed apply never hit the log; post-*: it did
    upto = kcrash if point == "durable.pre_append" else kcrash + 1
    twin = cls.from_csr(base_csr)
    for p in plans[:upto]:
        twin, _ = twin.apply(p)
    return r, twin, plans[upto:]


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("name", list(REPRESENTATIONS))
def test_crash_recovery_bit_parity(name, point, base_csr, tmp_path):
    r, twin, rest = run_crash(REPRESENTATIONS[name], base_csr, tmp_path, point)
    assert_bit_parity(r.rep, twin)
    np.testing.assert_array_equal(
        np.asarray(r.rep.reverse_walk(3)), np.asarray(twin.reverse_walk(3))
    )
    # the recovered stream keeps going — and stays in lockstep
    for p in rest:
        r.apply(p)
        twin, _ = twin.apply(p)
    assert_bit_parity(r.rep, twin)
    r.close()


def test_crash_with_torn_tail(base_csr, tmp_path):
    cls = REPRESENTATIONS["digraph"]
    wal, ck = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    plans = make_plans(4)
    g = durable.DurableGraph(cls.from_csr(base_csr), wal, ck)
    for p in plans:
        g.apply(p)
    g.close()
    # the final append itself was torn mid-write: record 4 is damaged
    faultinject.tear_tail(g.journal.segments()[-1], 7)
    r = durable.DurableGraph.recover(wal, ck)
    twin = cls.from_csr(base_csr)
    for p in plans[:3]:
        twin, _ = twin.apply(p)
    assert r.seq == 3
    assert_bit_parity(r.rep, twin)
    r.close()


def test_interrupted_checkpoint_leaves_debris_and_recovers(base_csr, tmp_path):
    cls = REPRESENTATIONS["lazy"]
    wal, ck = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    plans = make_plans(3)
    g = durable.DurableGraph(cls.from_csr(base_csr), wal, ck)
    for p in plans[:2]:
        g.apply(p)
    faultinject.arm("checkpoint.pre_rename")
    with pytest.raises(faultinject.SimulatedCrash):
        g.checkpoint()
    faultinject.disarm()
    g.close()
    debris = [n for n in os.listdir(ck) if n.startswith(".tmp_ckpt_")]
    assert debris  # a real crash leaves the tmp dir behind
    r = durable.DurableGraph.recover(wal, ck)
    assert not [n for n in os.listdir(ck) if n.startswith(".tmp_ckpt_")]
    twin = cls.from_csr(base_csr)
    for p in plans[:2]:
        twin, _ = twin.apply(p)
    assert_bit_parity(r.rep, twin)  # step-0 base + full WAL replay
    r.close()


def test_auto_checkpoint_prunes_wal(base_csr, tmp_path):
    cls = REPRESENTATIONS["coo"]
    wal, ck = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    g = durable.DurableGraph(
        cls.from_csr(base_csr), wal, ck,
        checkpoint_every=2, segment_bytes=256,
    )
    plans = make_plans(6, seed=5)
    for p in plans:
        g.apply(p)
    assert ckpt.latest_step(ck) == 6
    assert len(g.journal.segments()) == 1  # pruned behind the checkpoint
    g.close()
    r = durable.DurableGraph.recover(wal, ck)
    twin = cls.from_csr(base_csr)
    for p in plans:
        twin, _ = twin.apply(p)
    assert_bit_parity(r.rep, twin)
    r.close()


def test_hypothesis_random_crash_sweep(base_csr, tmp_path):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    names = list(REPRESENTATIONS)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def sweep(seed):
        sched = faultinject.FaultSchedule(seed, CRASH_POINTS)
        kcrash, point = sched.plan(4)
        cls = REPRESENTATIONS[names[seed % len(names)]]
        base = str(tmp_path / f"s{seed}")
        os.makedirs(base, exist_ok=True)
        try:
            r, twin, _ = run_crash(
                cls, base_csr, __import__("pathlib").Path(base), point,
                kcrash=kcrash, n_plans=5, seed=seed,
            )
            assert_bit_parity(r.rep, twin)
            np.testing.assert_array_equal(
                np.asarray(r.rep.reverse_walk(2)),
                np.asarray(twin.reverse_walk(2)),
            )
            r.close()
        finally:
            shutil.rmtree(base, ignore_errors=True)

    sweep()


# ---------------------------------------------------------------------------
# kernel fallback chain
# ---------------------------------------------------------------------------


def test_slot_update_falls_back_to_ref(base_csr):
    cls = REPRESENTATIONS["digraph"]
    g = cls.from_csr(base_csr)
    twin = cls.from_csr(base_csr)
    plan = make_plans(1, seed=13)[0]
    # kill both xla tries (attempt + retry) -> chain lands on host ref
    faultinject.arm("slot_update.xla", times=2)
    g, _ = g.apply(plan)
    faultinject.disarm()
    assert fallback.LAST_USED["slot_update"] == "ref"
    twin, _ = twin.apply(plan)
    assert_bit_parity(g, twin)
    # breaker re-promotes xla after its cooldown; parity must hold across
    # the ref->xla seam on the SAME graph state
    fallback.BREAKER.reset()
    p2 = make_plans(1, seed=14)[0]
    g, _ = g.apply(p2)
    twin, _ = twin.apply(p2)
    assert fallback.LAST_USED["slot_update"] == "xla"
    assert_bit_parity(g, twin)


def test_slot_walk_falls_back_to_ref(base_csr):
    cls = REPRESENTATIONS["chunked"]
    g = cls.from_csr(base_csr)
    clean = np.asarray(g.reverse_walk(3))
    faultinject.arm("slot_walk.xla", times=2)
    out = np.asarray(g.reverse_walk(3))
    faultinject.disarm()
    assert fallback.LAST_USED["slot_walk"] == "ref"
    np.testing.assert_allclose(out, clean, rtol=1e-5, atol=1e-5)


def test_forced_pallas_failure_completes_via_xla(base_csr, monkeypatch):
    """ISSUE acceptance: a Pallas failure mid-stream completes through the
    xla link without raising."""
    from repro.kernels.slot_update import ops as _su_ops

    orig = _su_ops.fused_apply

    def force_pallas(*args, **kw):
        kw["backend"] = "pallas"
        return orig(*args, **kw)

    monkeypatch.setattr(_su_ops, "fused_apply", force_pallas)
    cls = REPRESENTATIONS["digraph"]
    g = cls.from_csr(base_csr)
    twin = cls.from_csr(base_csr)
    plan = make_plans(1, seed=21)[0]
    # both pallas tries die before launch; xla completes the dispatch
    faultinject.arm("slot_update.pallas", times=2)
    g, _ = g.apply(plan)
    faultinject.disarm()
    assert fallback.LAST_USED["slot_update"] == "xla"
    st = fallback.BREAKER.state(("slot_update", "pallas"))
    assert st is not None and st["trips"] >= 1  # breaker tripped open
    monkeypatch.setattr(_su_ops, "fused_apply", orig)
    twin, _ = twin.apply(plan)
    assert_bit_parity(g, twin)


def test_breaker_cooldown_and_repromotion():
    t = {"now": 0.0}
    br = fallback.CircuitBreaker(cooldown=1.0, max_cooldown=8.0, clock=lambda: t["now"])
    key = ("site", "xla")
    assert br.available(key)
    br.trip(key)
    assert not br.available(key)  # open
    t["now"] = 1.1
    assert br.available(key)  # half-open: cooldown expired, probe allowed
    br.trip(key)  # probe failed: exponential backoff (2.0s now)
    t["now"] = 2.0
    assert not br.available(key)
    t["now"] = 3.2
    assert br.available(key)
    br.record_success(key)  # probe succeeded: full re-promotion
    assert br.state(key) is None
    br.trip(key)  # next trip starts from the base cooldown again
    t["now"] = 3.2 + 1.1
    assert br.available(key)


def test_run_chain_exhaustion_raises():
    def attempt(b):
        raise RuntimeError(f"{b} down")

    br = fallback.CircuitBreaker(clock=lambda: 0.0)
    with pytest.raises(fallback.FallbackExhausted):
        fallback.run_chain("site2", "xla", attempt, breaker=br)


def test_simulated_crash_not_swallowed_by_chain(base_csr):
    """SimulatedCrash is a BaseException: the fallback chain must let a
    process-kill fly instead of retrying around it."""
    cls = REPRESENTATIONS["digraph"]
    g = cls.from_csr(base_csr)
    faultinject.arm("slot_update.xla", exc=faultinject.SimulatedCrash)
    with pytest.raises(faultinject.SimulatedCrash):
        g.apply(make_plans(1, seed=31)[0])
    faultinject.disarm()


def test_steady_state_untouched_by_chain(base_csr):
    """No fault armed -> the primary backend serves every dispatch and the
    breaker holds no state (the <15%-overhead guarantee's control side)."""
    cls = REPRESENTATIONS["digraph"]
    g = cls.from_csr(base_csr)
    for p in make_plans(3, seed=17):
        g, _ = g.apply(p)
        g.reverse_walk(2)
    assert fallback.LAST_USED.get("slot_update") == "xla"
    assert fallback.LAST_USED.get("slot_walk") in (None, "xla")
    assert fallback.BREAKER.state(("slot_update", "xla")) is None


# ---------------------------------------------------------------------------
# invariant audit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(REPRESENTATIONS))
def test_audit_passes_on_live_stream(name, base_csr):
    g = REPRESENTATIONS[name].from_csr(base_csr)
    for p in make_plans(3, seed=23):
        g, _ = g.apply(p)
    stats = faultinject.audit(g)
    assert stats["m"] == g.to_csr().m
    assert stats["blocks"] >= 1


def test_audit_detects_edge_count_drift(base_csr):
    g = REPRESENTATIONS["digraph"].from_csr(base_csr)
    g.m += 1  # simulated accounting corruption
    with pytest.raises(faultinject.AuditError, match="rep.m"):
        faultinject.audit(g)


def test_audit_detects_image_geometry_corruption(base_csr):
    g = REPRESENTATIONS["vector2d"].from_csr(base_csr)
    img = g.to_walk_image()
    img.degs[0] += 1  # degree drift: live-count / payload checks trip
    with pytest.raises(faultinject.AuditError):
        img.audit()
