"""Training substrate: optimizer convergence, grad accumulation equivalence,
checkpoint atomicity/rotation/restart, fault-tolerance state machine,
gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import compression, fault
from repro.train import loop, optimizer as opt


def _quadratic_loss(params, batch):
    x = params["x"]
    loss = jnp.sum((x - batch["target"]) ** 2)
    return loss, {"l": loss}


def test_adamw_converges():
    params = {"x": jnp.ones((4, 4))}
    cfg = opt.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    state = loop.init_state(params, cfg)
    step = loop.make_train_step(_quadratic_loss, cfg)
    batch = {"target": jnp.full((4, 4), 3.0)}
    for _ in range(200):
        state, m = jax.jit(step)(state, batch)
    assert float(m["loss"]) < 1e-2


@pytest.mark.parametrize("name", ["adafactor", "sgd"])
def test_other_optimizers_step(name):
    params = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    cfg = opt.OptimizerConfig(name=name, lr=0.05, warmup_steps=0, total_steps=100)
    state = loop.init_state(params, cfg)
    step = loop.make_train_step(
        lambda p, b: (jnp.sum((jnp.ones((8,)) @ p["w"] + p["b"] - 1.0) ** 2), {}), cfg
    )
    l0 = None
    for i in range(50):
        state, m = jax.jit(step)(state, {"x": jnp.zeros(())})
        if i == 0:
            l0 = float(m["loss"])
    assert float(m["loss"]) < l0


def test_grad_accum_matches_full_batch():
    """accumulated microbatch grads == one full-batch grad step."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (6, 3))
    params = {"w": w}
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    ys = jax.random.normal(jax.random.PRNGKey(2), (8, 3))

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    cfg = opt.OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0, clip_norm=0.0)
    full = loop.make_train_step(loss_fn, cfg)
    s1, _ = jax.jit(full)(loop.init_state(params, cfg), {"x": xs, "y": ys})

    accum = loop.make_train_step(loss_fn, cfg, grad_accum=4)
    mb = {"x": xs.reshape(4, 2, 6), "y": ys.reshape(4, 2, 3)}
    s2, _ = jax.jit(accum)(loop.init_state(params, cfg), mb)
    np.testing.assert_allclose(
        np.asarray(s1["params"]["w"]), np.asarray(s2["params"]["w"]), rtol=1e-5
    )


# --------------------------------------------------------------------------
def test_checkpoint_roundtrip_rotation_restart(tmp_path):
    d = str(tmp_path / "ckpts")
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32), "b": {"c": jnp.ones(4)}}
    for s in (10, 20, 30, 40):
        ckpt.save(d, s, jax.tree.map(lambda x: x * s, tree), keep=2)
    assert ckpt.all_steps(d) == [30, 40]  # rotation
    restored, at = ckpt.restore(d, tree)
    assert at == 40
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) * 40)


def test_checkpoint_structure_mismatch_fails(tmp_path):
    d = str(tmp_path / "c")
    ckpt.save(d, 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones(3), "extra": jnp.ones(2)})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones(5)})


def test_checkpoint_atomicity_no_partial(tmp_path, monkeypatch):
    """A failed save must leave no visible checkpoint directory."""
    d = str(tmp_path / "c")

    class Boom(Exception):
        pass

    def boom(*a, **k):
        raise Boom()

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(Boom):
        ckpt.save(d, 5, {"a": jnp.ones(3)})
    monkeypatch.undo()
    assert ckpt.all_steps(d) == []
    # and no stray tmp dirs remain
    assert [f for f in os.listdir(d) if f.startswith(".tmp")] == []


# --------------------------------------------------------------------------
def test_fleet_monitor_failure_and_straggler():
    t = {"now": 0.0}
    mon = fault.FleetMonitor(4, fail_timeout=10, straggler_factor=2.0,
                             strike_limit=2, clock=lambda: t["now"])
    # normal steps
    for step in range(2):
        t["now"] += 1
        for w in range(4):
            mon.heartbeat(w, step_time=1.0 if w != 3 else 3.0)  # w3 slow
        rep = mon.check()
    assert rep["stragglers"] == [3]
    # worker 1 stops heartbeating
    for _ in range(12):
        t["now"] += 1
        for w in (0, 2, 3):
            mon.heartbeat(w, 1.0)
    rep = mon.check()
    assert 1 in rep["dead"]


def test_elastic_mesh_shrinks_pow2():
    assert fault.elastic_mesh_shape(256, model_parallel=16) == (16, 16)
    assert fault.elastic_mesh_shape(255, model_parallel=16) == (8, 16)
    assert fault.elastic_mesh_shape(129, model_parallel=16) == (8, 16)
    assert fault.elastic_mesh_shape(16, model_parallel=16) == (1, 16)


def test_elastic_trainer_restores_after_failure(tmp_path):
    d = str(tmp_path / "ck")
    state = {"params": {"w": jnp.ones(4)}}
    ckpt.save(d, 100, state)
    t = {"now": 0.0}
    mon = fault.FleetMonitor(4, fail_timeout=5, clock=lambda: t["now"])
    tr = fault.ElasticTrainer(monitor=mon, ckpt_dir=d, model_parallel=2)
    # step with worker 2 dead (no heartbeat), clock advanced past timeout
    t["now"] = 10.0
    live_times = {0: 1.0, 1: 1.0, 3: 1.0}
    mutated = {"params": {"w": jnp.zeros(4)}}  # in-flight state to be discarded
    state2, new_mesh = tr.on_step(101, mutated, live_times)
    assert new_mesh is not None
    np.testing.assert_allclose(np.asarray(state2["params"]["w"]), 1.0)
    kinds = [e["kind"] for e in tr.events]
    assert "remesh" in kinds and "restore" in kinds


# --------------------------------------------------------------------------
def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32) * 1e-3)
    comp = compression.make_int8_ef_compressor()
    total_c = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        out = comp({"g": g})["g"]
        total_c += out
        total += g
    # with error feedback, accumulated compressed sum tracks the true sum
    rel = float(jnp.linalg.norm(total_c - total) / jnp.linalg.norm(total))
    assert rel < 0.02, rel


def test_topk_compressor_preserves_largest():
    g = jnp.asarray(np.array([0.0, 10.0, -0.1, 0.2, -20.0] + [0.01] * 95, np.float32))
    comp = compression.make_topk_ef_compressor(frac=0.02)
    out = comp({"g": g})["g"]
    assert float(out[4]) == pytest.approx(-20.0)
    assert float(out[1]) == pytest.approx(10.0)
    assert float(jnp.count_nonzero(out)) == 2


def test_training_with_compression_still_converges():
    params = {"x": jnp.ones((8,))}
    cfg = opt.OptimizerConfig(lr=0.2, warmup_steps=0, weight_decay=0.0)
    comp = compression.make_int8_ef_compressor()
    step = loop.make_train_step(_quadratic_loss, cfg, compress_fn=comp)
    state = loop.init_state(params, cfg)
    batch = {"target": jnp.full((8,), -2.0)}
    for _ in range(150):
        state, m = step(state, batch)  # not jitted: compressor carries state
    assert float(m["loss"]) < 1e-2
