"""Production mesh builders and the shard_map entry point (DESIGN.md §5, §14).

Functions, not module constants — importing this module never touches jax
device state.  The dry-run (and only the dry-run) forces 512 host devices.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5 exposes explicit axis types; older versions imply Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _resolve_shard_map():
    """Locate shard_map across jax versions (top-level vs experimental)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # jax <= 0.4.x
    return fn


def _check_kwarg(fn) -> str | None:
    """Name of the replication-check kwarg this jax spells, if inspectable.

    jax <= 0.4.x calls it ``check_rep``; >= 0.5 renamed it ``check_vma``.
    Returns ``None`` when the signature is opaque (C++ wrappers) — the
    caller then falls back to trying both spellings.
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - opaque builtin
        return None
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = True):
    """The one shard_map entry point (DESIGN.md §14).

    Wraps ``f`` as a per-shard program on ``mesh``, papering over the
    ``check_rep`` -> ``check_vma`` kwarg rename between jax 0.4.x and
    0.5.x.  ``check=False`` disables the replication checker — required
    whenever an ``out_specs`` of ``P()`` is produced from device-varying
    values (e.g. an all_gather'ed result that jax cannot prove replicated).
    """
    sm = _resolve_shard_map()
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    name = _check_kwarg(sm)
    if name is not None:
        return sm(f, **kw, **{name: check})
    for name in ("check_vma", "check_rep"):  # opaque signature: probe
        try:
            return sm(f, **kw, **{name: check})
        except TypeError:  # pragma: no cover - depends on installed jax
            continue
    return sm(f, **kw)  # pragma: no cover - kwarg dropped upstream


def host_mesh(n_shards: int):
    """1-D ``("data",)`` mesh over the first ``n_shards`` local devices.

    Used by the sharded walk image: devices come from ``jax.devices()``
    so forced host platforms (``--xla_force_host_platform_device_count``)
    work the same as real accelerators.
    """
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"host_mesh: need {n_shards} devices, have {len(devs)}"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), ("data",))


def _make_mesh(shape: tuple, axes: tuple):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch/vertex dimension (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_mesh_like(shape: tuple, axes: tuple):
    """Elastic re-mesh helper: arbitrary (shape, axes) from survivors."""
    return _make_mesh(shape, axes)
