"""Production mesh builders (DESIGN.md §5).

Functions, not module constants — importing this module never touches jax
device state.  The dry-run (and only the dry-run) forces 512 host devices.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older versions imply Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape: tuple, axes: tuple):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch/vertex dimension (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_mesh_like(shape: tuple, axes: tuple):
    """Elastic re-mesh helper: arbitrary (shape, axes) from survivors."""
    return _make_mesh(shape, axes)
