"""Roofline analysis (deliverable g) from the dry-run's compiled artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``cost_analysis()`` returns PER-DEVICE flops/bytes for the SPMD partition
(verified: a [1024,1024]² matmul contraction-sharded 16 ways reports
2·1024³/16), and XLA counts while-loop bodies ONCE (verified: an 8-step
scanned matmul reports 1× flops).  Terms therefore come from *unrolled
reduced-depth variants* extrapolated linearly:

  LM train : f(L) = cost1 + (L-1)·(cost2-cost1)   (per microbatch, depth L)
             g(L) = opt1  + (L-1)·(opt2-opt1)     (optimizer apply)
             step = accum·(f(L) - g(L)) + g(L)
  LM infer : step = cost1 + (L-1)·(cost2-cost1)
  MACE ogb : f(C) = base + D/C  (C = edge chunks) → D = 4·(f(2)-f(4)),
             step = base + D  (scan body = density, linear in edges)
  others   : no loops — the full variant's costs are exact.

Terms (seconds per step, 256-chip pod):
  compute    = flops_dev / 197e12
  memory     = bytes_dev / 819e9
  collective = collective_bytes_dev / 50e9
"""
from __future__ import annotations

import json
import os
from typing import Optional

from ..configs import base as cfgbase

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256
HBM_BYTES = 16 * 2**30

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


def _load(arch, shape, variant, mesh="16x16") -> Optional[dict]:
    p = os.path.abspath(
        os.path.join(RESULTS_DIR, mesh, f"{arch}__{shape}__{variant}.json")
    )
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _vec(rec) -> dict:
    """(flops, bytes, collective bytes) per device for one lowering."""
    coll = sum(rec["collectives"]["total_bytes"].values())
    return {
        "flops": rec["cost"]["flops"],
        "bytes": rec["cost"]["bytes_accessed"],
        "coll": float(coll),
    }


def _axpy(a, x, y=None):
    out = {k: a * x[k] + (y[k] if y else 0.0) for k in x}
    return out


def _sub(x, y):
    return {k: x[k] - y[k] for k in x}


def _add(x, y):
    return {k: x[k] + y[k] for k in x}


def step_costs(arch: str, shape: str) -> Optional[dict]:
    """Extrapolated per-device, per-step (flops, bytes, coll bytes)."""
    entry = cfgbase.get(arch)
    full = _load(arch, shape, "full")
    if full is None or full.get("status") != "ok":
        return None
    lc = full.get("loop_correction", {})
    kind = lc.get("kind", "")
    if kind in ("lm_train", "lm_prefill", "lm_decode"):
        c2 = _load(arch, shape, "cost2")
        c4 = _load(arch, shape, "cost4")
        if not (c2 and c4 and c2["status"] == c4["status"] == "ok"):
            return None
        L = entry.full.n_layers
        per_layer = {k: max(v, 0.0) for k, v in _axpy(0.5, _sub(_vec(c4), _vec(c2))).items()}
        base = {k: max(v, 0.0) for k, v in _sub(_vec(c2), _axpy(2, per_layer)).items()}
        f_l = _axpy(L, per_layer, base)
        if kind == "lm_train":
            o1 = _load(arch, shape, "opt1")
            o2 = _load(arch, shape, "opt2")
            accum = lc.get("accum", 16)
            if o1 and o2 and o1["status"] == o2["status"] == "ok":
                g_l = _axpy(L - 1, _sub(_vec(o2), _vec(o1)), _vec(o1))
                g_l = {k: max(v, 0.0) for k, v in g_l.items()}
                fwdbwd = {k: max(v, 0.0) for k, v in _sub(f_l, g_l).items()}
                step = _add(_axpy(accum, fwdbwd), g_l)
            else:
                step = _axpy(accum, f_l)
            return step
        return f_l
    if kind == "gnn_chunked":
        f2 = _load(arch, shape, "chunk2")
        f4 = _load(arch, shape, "chunk4")
        if f2 and f4 and f2["status"] == f4["status"] == "ok":
            d = _axpy(4, _sub(_vec(f2), _vec(f4)))
            base = _sub(_vec(f2), _axpy(0.5, d))
            return _add(base, d)
        return _vec(full)
    return _vec(full)


# ---------------------------------------------------------------------------
# MODEL_FLOPS — useful-compute yardsticks (global, per step)
# ---------------------------------------------------------------------------
def model_flops(arch: str, shape_name: str) -> float:
    entry = cfgbase.get(arch)
    shape = cfgbase.FAMILY_SHAPES[entry.family][shape_name]
    if entry.family == "lm":
        cfg = entry.full
        n_act = cfg.n_active_params()
        L, hq, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
        if shape["kind"] == "train":
            toks = shape["seq_len"] * shape["global_batch"]
            s_eff = min(shape["seq_len"], cfg.sliding_window or shape["seq_len"])
            attn = 6 * L * toks * hq * dh * s_eff  # 2 matmuls·(S/2 causal)·3x bwd
            return 6.0 * n_act * toks + attn
        if shape["kind"] == "prefill":
            toks = shape["seq_len"] * shape["global_batch"]
            s_eff = min(shape["seq_len"], cfg.sliding_window or shape["seq_len"])
            return 2.0 * n_act * toks + 2 * L * toks * hq * dh * s_eff
        # decode: one token per sequence
        b = shape["global_batch"]
        s_ctx = min(shape["seq_len"], cfg.sliding_window or shape["seq_len"])
        return 2.0 * n_act * b + 4.0 * L * b * hq * dh * s_ctx
    if entry.family == "gnn":
        cfg = entry.full
        if shape["kind"] == "sampled":
            from ..sampling import neighbor

            sizes = neighbor.flat_sizes(shape["batch_nodes"], shape["fanout"])
            n = sum(sizes)
            e = sum(sizes[i + 1] for i in range(len(shape["fanout"])))
        elif shape["kind"] == "batched":
            n = shape["n_nodes"] * shape["batch"]
            e = shape["n_edges"] * shape["batch"]
        else:
            n, e = shape["n_nodes"], shape["n_edges"]
        train_mult = 3.0  # fwd+bwd
        if entry.model == "gcn":
            d0 = shape.get("d_feat", cfg.d_in)
            h, c = cfg.d_hidden, cfg.n_classes
            fwd = 2 * n * d0 * h + 2 * e * h + 2 * n * h * c + 2 * e * c
        elif entry.model == "schnet":
            d, r = cfg.d_hidden, cfg.n_rbf
            fwd = cfg.n_interactions * (
                2 * e * (r * d + d * d) + e * d + 2 * n * (2 * d * d)
            )
        elif entry.model == "mace":
            c = cfg.d_hidden
            per_l = (
                2 * e * (cfg.n_rbf * 32 + 32 * 3 * c)  # radial MLP
                + 2 * e * c * 13                        # density s/v/t
                + 3 * 2 * n * c * c                     # channel mixing
                + 2 * 24 * n * c * 13                   # product basis (2 rounds)
            )
            fwd = cfg.n_layers * per_l
        else:  # graphcast
            d, nv = cfg.d_hidden, cfg.n_vars
            fwd = (
                2 * n * (nv * d + d * d) * 2            # enc+dec
                + cfg.n_layers * (2 * e * (3 * d * d + d * d) + 2 * n * (2 * d * d + d * d))
            )
        return train_mult * fwd
    # recsys
    cfg = entry.full
    d = cfg.embed_dim
    sizes = [0, *cfg.tower_mlp]
    mlp_flops = sum(2 * sizes[i] * sizes[i + 1] for i in range(1, len(sizes) - 1))
    per_ex_user = cfg.n_user_fields * cfg.bag_size * d + 2 * (
        cfg.n_user_fields * d * cfg.tower_mlp[0]
    ) + mlp_flops
    per_ex_item = cfg.n_item_fields * cfg.bag_size * d + 2 * (
        cfg.n_item_fields * d * cfg.tower_mlp[0]
    ) + mlp_flops
    if shape["kind"] == "train":
        b = shape["batch"]
        return 3.0 * b * (per_ex_user + per_ex_item) + 3.0 * 2 * b * b * cfg.tower_mlp[-1]
    if shape["kind"] == "retrieval":
        c = shape["n_candidates"]
        return per_ex_user + c * per_ex_item + 2 * c * cfg.tower_mlp[-1]
    b = shape["batch"]
    return b * (per_ex_user + per_ex_item + 2 * cfg.tower_mlp[-1])


# ---------------------------------------------------------------------------
def analyze_cell(arch: str, shape: str) -> dict:
    entry = cfgbase.get(arch)
    skip = entry.skip_shapes.get(shape)
    row = {"arch": arch, "shape": shape}
    if skip:
        row["status"] = "skipped"
        row["reason"] = skip
        return row
    full = _load(arch, shape, "full")
    if full is None:
        row["status"] = "missing"
        return row
    if full["status"] != "ok":
        row["status"] = full["status"]
        row["error"] = full.get("error", "")[:200]
        return row
    step = step_costs(arch, shape)
    if step is None:
        row["status"] = "partial"
        return row
    compute_s = step["flops"] / PEAK_FLOPS
    memory_s = step["bytes"] / HBM_BW
    coll_s = step["coll"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    hlo_global = step["flops"] * CHIPS
    row.update(
        status="ok",
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        bound_s=terms[dominant],
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        roofline_fraction=(
            (mf / PEAK_FLOPS / CHIPS) / terms[dominant] if terms[dominant] else 0.0
        ),
        hbm_peak_gib=full["memory"].get(
            "peak_bytes_aliased", full["memory"]["total_bytes"]
        )
        / 2**30,
        fits_hbm=full["memory"].get(
            "peak_bytes_aliased", full["memory"]["total_bytes"]
        )
        <= HBM_BYTES,
    )
    return row


def analyze_all() -> list[dict]:
    return [analyze_cell(a, s) for a, s, _ in cfgbase.all_cells()]


def markdown_table(rows) -> str:
    hdr = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | bound | "
        "MODEL_FLOPS | useful/HLO | roofline frac | HBM GiB | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r.get('status')} "
                f"| — | — | — | — | {r.get('reason', r.get('error', ''))[:60]} |\n"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['hbm_peak_gib']:.1f} | "
            f"{'✓' if r['fits_hbm'] else '✗'} |\n"
        )
    return "".join(out)


def main():
    rows = analyze_all()
    out = os.path.abspath(os.path.join(RESULTS_DIR, "..", "roofline.json"))
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))
    print(f"\nwritten: {out}")


if __name__ == "__main__":
    main()
