"""Partition-spec rules per architecture family (DESIGN.md §5).

LM: FSDP(ZeRO-3) over 'data' + TP over 'model'; pod axis = pure DP
(params replicated across pods, gradients all-reduced).  MoE: experts over
'data' (EP=DP groups, all-to-all dispatch), expert d_ff over 'model'.
Decode: batch over (pod, data), KV-cache sequence over 'model'
(context-parallel decode).  GNN: vertex/edge block-sharding over
(pod, data) — the paper's per-partition CSR as the shard layout.  recsys:
embedding-table rows over 'model', batch over (pod, data).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import mesh as mesh_mod


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# LM params
# ---------------------------------------------------------------------------
def lm_param_spec(path: str, mesh) -> P:
    d = mesh_mod.data_axes(mesh)
    fs = "data"  # FSDP axis (within-pod only; pod = pure DP)
    if path.endswith("unembed"):
        return P(fs, "model")
    if path.endswith("embed"):
        return P("model", fs)
    leaf = path.split("/")[-1]
    if leaf in ("wq", "wk", "wv"):
        return P(None, fs, "model")
    if leaf == "wo":
        return P(None, "model", fs)
    if leaf in ("bq", "bk", "bv"):
        return P(None, "model")
    if leaf in ("ln1", "ln2"):
        return P(None, None)
    if leaf == "ln_f":
        return P(None)
    if leaf == "router":
        return P(None, fs, None)
    if leaf in ("w1", "w3"):
        # dense: [L, D, F]; moe: [L, E, D, F]
        return P(None, fs, None, "model") if _is_moe_leaf(path) else P(None, fs, "model")
    if leaf == "w2":
        return P(None, fs, "model", None) if _is_moe_leaf(path) else P(None, "model", fs)
    if leaf in ("dw1", "dw3"):
        return P(None, fs, "model")
    if leaf == "dw2":
        return P(None, "model", fs)
    return P()


_MOE_HINT = {"moe": False}


def _is_moe_leaf(path: str) -> bool:
    return _MOE_HINT["moe"]


def tree_spec(tree, rule, mesh) -> Any:
    """Map a path->spec rule over a pytree; returns NamedSharding tree.

    Trims specs to leaf rank and DROPS any mesh axis that does not divide
    the corresponding dimension (those leaves replicate on that axis) —
    the divisibility guard that keeps odd sizes (offsets arrays, batch=1
    decode, graph-level labels, PRNG keys) compiling.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = rule(key, mesh)
        shape = tuple(getattr(leaf, "shape", ()))
        nd = len(shape)
        parts = (list(spec) + [None] * nd)[:nd]
        fixed = []
        for dim, part in enumerate(parts):
            if part is None:
                fixed.append(None)
                continue
            axes = part if isinstance(part, tuple) else (part,)
            total = 1
            for a in axes:
                total *= sizes[a]
            fixed.append(part if shape[dim] % total == 0 else None)
        out.append(_named(mesh, P(*fixed)))
    return jax.tree_util.tree_unflatten(treedef, out)


def lm_state_sharding(state, mesh, *, is_moe: bool):
    """Params + optimizer state (m/v follow params; scalars replicated)."""
    _MOE_HINT["moe"] = is_moe

    def rule(path, mesh):
        if path.endswith("step"):
            return P()
        # strip opt-state prefixes so m/v reuse the param rule
        p = path
        for pre in ("opt_state/m/", "opt_state/v/", "opt_state/f/", "params/"):
            if p.startswith(pre):
                p = p[len(pre):]
        return lm_param_spec(p, mesh)

    return tree_spec(state, rule, mesh)


def lm_batch_sharding(batch, mesh):
    d = mesh_mod.data_axes(mesh)
    rank = len(jax.tree.leaves(batch)[0].shape)

    def rule(path, mesh):
        # [accum, ubatch, seq] with grad accumulation, else [ubatch, seq]
        return P(None, d) if rank == 3 else P(d)

    return tree_spec(batch, rule, mesh)


def lm_infer_batch_sharding(batch, mesh):
    d = mesh_mod.data_axes(mesh)
    return tree_spec(batch, lambda p, m: P(d), mesh)


def lm_cache_sharding(cache, mesh, *, batch: int):
    d = mesh_mod.data_axes(mesh)
    n_data = 1
    for a in d:
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    def rule(path, mesh):
        if path.endswith("pos"):
            return P()
        # [L, B, Hkv, S, Dh]: batch over data axes when divisible, cache
        # sequence over 'model' (context-parallel decode)
        bspec = d if batch % n_data == 0 else None
        return P(None, bspec, None, "model", None)

    return tree_spec(cache, rule, mesh)


# ---------------------------------------------------------------------------
# GNN / recsys
# ---------------------------------------------------------------------------
def gnn_batch_sharding(batch, mesh):
    # §Perf iteration (graphcast×ogb_products): node/edge dim over ALL axes
    # (data AND model) — GNN params are replicated, so the model axis would
    # otherwise idle (measured 16× replicated compute on the 16×16 mesh).
    d = mesh_mod.data_axes(mesh) + ("model",)

    def rule(path, mesh):
        leaf = path.split("/")[-1]
        if leaf in ("n_graphs",):
            return P()
        return P(d)  # leading node/edge dim block-sharded

    return tree_spec(batch, rule, mesh)


def gnn_state_sharding(state, mesh):
    # GNN params are small: replicate (grads all-reduce over data axes)
    return tree_spec(state, lambda p, m: P(), mesh)


def recsys_state_sharding(state, mesh):
    def rule(path, mesh):
        if path.endswith("table"):
            return P("model", None)  # rows over model axis
        if path.endswith("step"):
            return P()
        return P()

    return tree_spec(state, rule, mesh)


def recsys_batch_sharding(batch, mesh):
    d = mesh_mod.data_axes(mesh)
    return tree_spec(batch, lambda p, m: P(d), mesh)
