import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the production meshes, record
memory_analysis / cost_analysis / collective bytes.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init (only the dry-run sees 512 devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch gcn-cora --shape molecule
Variants (roofline support): full cost1 cost2 opt1 opt2 chunk2 chunk4.
Results cached as JSON under results/dryrun/.
"""
import argparse
import json
import re
import time
import traceback
from collections import Counter

import jax

from ..configs import base as cfgbase
from . import mesh as mesh_mod
from . import steps

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(%?[\w\.\-]+) = (.+?) ([a-z\-]+)\(", re.M)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op, per collective kind,
    attributed to the computation (entry vs while-body) it appears in.

    Operand bytes are taken from each operand's defining instruction type
    (built from a full symbol table of the module).
    """
    # symbol table: instruction name -> output type bytes
    sym: dict[str, int] = {}
    comp_of: dict[str, str] = {}
    current_comp = "entry"
    for line in hlo_text.splitlines():
        mcomp = re.match(r"^(%?[\w\.\-]+) \{", line.strip())
        if line.startswith("ENTRY"):
            current_comp = "entry"
        elif mcomp and "=" not in line:
            current_comp = mcomp.group(1)
        m = re.match(r"\s*(ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s", line)
        if m:
            name = m.group(2)
            sym[name] = _type_bytes(m.group(3))
            comp_of[name] = current_comp

    per_kind = Counter()
    per_comp_kind: dict[str, Counter] = {}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            token = f" {kind}(" if not line.strip().startswith(kind) else f"{kind}("
            if f"{kind}(" in line and "=" in line:
                m = re.match(r"\s*(ROOT\s+)?(%?[\w\.\-]+)\s*=", line)
                name = m.group(2) if m else "?"
                # operand list
                mo = re.search(re.escape(kind) + r"\(([^)]*)\)", line)
                bytes_ = 0
                if mo:
                    for op in mo.group(1).split(","):
                        op = op.strip().split(" ")[-1]
                        bytes_ += sym.get(op, 0)
                if bytes_ == 0:
                    bytes_ = sym.get(name, 0)  # fall back to output size
                comp = comp_of.get(name, "entry")
                per_kind[kind] += bytes_
                per_comp_kind.setdefault(comp, Counter())[kind] += bytes_
                break
    in_while = Counter()
    for comp, c in per_comp_kind.items():
        if "while" in comp or "body" in comp or "scan" in comp:
            in_while.update(c)
    return {
        "total_bytes": dict(per_kind),
        "while_body_bytes": dict(in_while),
        "count": sum(per_kind.values()) and int(sum(
            1 for line in hlo_text.splitlines()
            if any(f"{k}(" in line and "=" in line for k in COLLECTIVES)
        )),
    }


def run_cell(arch: str, shape: str, *, multi_pod: bool, variant: str = "full") -> dict:
    entry = cfgbase.get(arch)
    skip = entry.skip_shapes.get(shape)
    rec = {
        "arch": arch,
        "shape": shape,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
    }
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    t0 = time.time()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    daxes = mesh_mod.data_axes(mesh)
    cell = steps.build_cell(
        arch, shape, variant=variant, data_axes=daxes
    ) if not variant.startswith("opt") else steps.build_opt_cell(arch, variant=variant)
    shardings = steps.attach_shardings(cell, mesh, arch, shape)
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.step_fn, in_shardings=shardings, donate_argnums=cell.donate
        )
        lowered = jitted.lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
        # conservative total (no aliasing assumed)
        "total_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
        ),
        # true peak when donated inputs alias outputs (state buffers reused)
        "peak_bytes_aliased": int(
            max(ma.argument_size_in_bytes, ma.output_size_in_bytes)
            + ma.temp_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    txt = compiled.as_text()
    rec["collectives"] = collective_stats(txt)
    rec["hlo_chars"] = len(txt)
    rec["loop_correction"] = cell.loop_correction
    rec["status"] = "ok"
    return rec


def result_path(arch, shape, variant, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    d = os.path.abspath(os.path.join(RESULTS_DIR, mesh))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}__{variant}.json")


def lm_variants(shape_kind: str) -> list[str]:
    # (cost2, cost4) pair: the 1-layer lowering fuses anomalously (measured
    # non-monotonic bytes), so extrapolation uses depths 2 and 4
    if shape_kind == "train":
        return ["full", "cost2", "cost4", "opt1", "opt2"]
    return ["full", "cost2", "cost4"]


def variants_for(arch: str, shape: str) -> list[str]:
    entry = cfgbase.get(arch)
    if entry.family == "lm":
        kind = cfgbase.FAMILY_SHAPES["lm"][shape]["kind"]
        return lm_variants(kind)
    if arch == "mace" and shape in ("ogb_products",):
        return ["full", "chunk2", "chunk4"]
    return ["full"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--missing-only", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch, shape, skip in cfgbase.all_cells():
            vs = ["full"] if args.multi_pod else variants_for(arch, shape)
            if skip:
                vs = ["full"]
            for v in vs:
                todo.append((arch, shape, v))
    else:
        vs = [args.variant] if args.variant else (
            ["full"] if args.multi_pod else variants_for(args.arch, args.shape)
        )
        todo = [(args.arch, args.shape, v) for v in vs]

    n_ok = n_fail = n_skip = 0
    for arch, shape, variant in todo:
        path = result_path(arch, shape, variant, args.multi_pod)
        if args.missing_only and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    continue
        print(f"[dryrun] {arch} × {shape} ({variant}) "
              f"mesh={'2x16x16' if args.multi_pod else '16x16'}", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod, variant=variant)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = {
                "arch": arch, "shape": shape, "variant": variant,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        st = rec["status"]
        n_ok += st == "ok"
        n_fail += st == "error"
        n_skip += st == "skipped"
        msg = {"ok": f"ok  lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
                     f"mem={rec.get('memory', {}).get('total_bytes', 0)/2**30:.2f}GiB/dev",
               "skipped": f"SKIP ({rec.get('reason', '')[:60]})",
               "error": f"FAIL {rec.get('error', '')[:120]}"}[st]
        print(f"  -> {msg}", flush=True)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
