"""Training launcher: end-to-end driver for any registered arch.

On-container usage trains the REDUCED config on synthetic data with
checkpoint/restart fault tolerance; on a real fleet the same entry point
takes ``--full --mesh 16x16`` and the production shardings from
launch/steps.py apply unchanged.

  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --shape train_4k --steps 20 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import manager as ckpt
from ..configs import base as cfgbase
from . import steps


def default_shape(arch: str) -> str:
    fam = cfgbase.get(arch).family
    return {"lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch"}[fam]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    shape = args.shape or default_shape(args.arch)
    cell = steps.build_cell(args.arch, shape, reduced=True)
    state, batch = cell.args

    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, at = ckpt.restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {at}")

    jitted = jax.jit(cell.step_fn, donate_argnums=(0,))
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        state, metrics = jitted(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % args.log_every == 0:
            print(f"[train] step {i:4d} loss {loss:.4f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.1f} ms/step)", flush=True)
        if args.ckpt_dir and i > 0 and i % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i, state)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    if not (losses[-1] < losses[0] or np.isclose(losses[-1], losses[0], rtol=0.2)):
        print("[train] WARNING: loss did not decrease")
    return losses


if __name__ == "__main__":
    main()
