"""Serving launcher: batched decode for LM archs / scoring for recsys.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import base as cfgbase
from ..models.transformer import model as tmodel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args(argv)

    entry = cfgbase.get(args.arch)
    assert entry.family == "lm", "serve.py drives LM archs; recsys uses examples/"
    cfg = entry.smoke
    params = tmodel.init_params(jax.random.PRNGKey(0), cfg)
    cache = tmodel.init_cache(cfg, args.batch, args.cache_len)
    step = jax.jit(
        lambda p, c, t: tmodel.decode_step(p, c, t, cfg), donate_argnums=(1,)
    )

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    outs = []
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)[:, :, 0] \
            if logits.ndim == 4 else jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    toks = np.stack(outs, 1)
    print(f"[serve] {args.batch} seqs × {args.tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s)")
    print("[serve] sample:", toks[0][:16].tolist())
    return toks


if __name__ == "__main__":
    main()
