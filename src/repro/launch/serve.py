"""Graph walk serving launcher (DESIGN.md §16) — the serving front-end CLI.

Retires the seed's LM-decode launcher: the graph engine IS the product
now, and this entry point drives the multi-tenant ``runtime.serve``
WalkServer against a synthetic graph under mixed update/walk traffic,
printing latency percentiles and the zero-lost / torn-read proof fields.

  PYTHONPATH=src python -m repro.launch.serve --rep digraph --scale 10 \\
      --requests 400 --update-every 10 --verify 0.25

Besides ``main``, this module hosts the *shared* traffic machinery the
bench suite and the serve tests reuse:

* :func:`build_rep` — synthetic graph → representation instance;
* :func:`run_traffic` — the mixed walk/update submission loop;
* :class:`GenerationOracle` — a host edge-set replayed one sealed
  generation at a time, walking each with numpy; the torn-read check
  (:func:`count_torn_reads`) proves every served walk matches the
  oracle *for its own generation* — the snapshot-isolation contract.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import REPRESENTATIONS, edgebatch, updates
from ..io import synthetic
from ..runtime import serve as serve_mod


def build_rep(rep: str = "digraph", *, kind: str = "web", scale: int = 10,
              edge_factor: int = 8, seed: int = 7):
    """Synthetic graph → (representation, base CSR)."""
    csr = synthetic.make_graph(
        kind, scale=scale, edge_factor=edge_factor, seed=seed, weighted=True
    )
    return REPRESENTATIONS[rep].from_csr(csr), csr


def seed_visits_row(nv: int, seeds, weights=None) -> np.ndarray:
    """The [nv] initial visit vector a seed list denotes (matches the
    server's dispatch-side materialization)."""
    row = np.zeros(nv, np.float32)
    seeds = np.atleast_1d(np.asarray(seeds, np.int64))
    w = (
        np.ones(seeds.shape[0], np.float32)
        if weights is None
        else np.asarray(weights, np.float32).reshape(-1)
    )
    np.add.at(row, seeds, w)
    return row


class GenerationOracle:
    """Host replica of the served graph, one sealed generation at a time.

    Updates are recorded against the generation that first exposed them
    (the ack's ``ticket.generation``); ``walk(gen, row, steps)`` advances
    the edge-set replica to exactly that generation and walks it with
    numpy (visits1[u] = Σ_{(u,v)∈E} visits0[v], weights don't enter the
    count walk).  Verification must proceed in nondecreasing generation
    order — the torn-read check sorts served tickets by generation.
    """

    def __init__(self, csr):
        off = np.asarray(csr.offsets, np.int64)
        self.nv = int(csr.n)
        m = int(csr.m)
        rows = np.repeat(np.arange(self.nv, dtype=np.int64), np.diff(off))
        d = np.asarray(csr.dst)[:m].astype(np.int64)
        self._edges = set(zip(rows.tolist(), d.tolist()))
        self._gen = 0
        self._plans: dict = {}
        self._arrays = None

    def record(self, gen: int, plan) -> None:
        """Register ``plan`` as first visible at sealed generation ``gen``."""
        self._plans.setdefault(int(gen), []).append(plan)

    def _advance(self, gen: int) -> None:
        if gen < self._gen:
            raise ValueError(
                f"oracle at generation {self._gen}, asked to rewind to {gen}"
            )
        while self._gen < gen:
            self._gen += 1
            for plan in self._plans.pop(self._gen, ()):
                # canonical op stream: each (src, dst) appears once, so
                # apply order within a plan doesn't matter
                srcs = plan.q_src.astype(np.int64).tolist()
                dsts = plan.q_dst.astype(np.int64).tolist()
                for s, d, rm in zip(srcs, dsts, plan.q_del.tolist()):
                    if rm:
                        self._edges.discard((s, d))
                    else:
                        self._edges.add((s, d))
            self._arrays = None

    def walk(self, gen: int, visits_row: np.ndarray, steps: int,
             *, drop_rows=None) -> np.ndarray:
        """Oracle walk at ``gen``; ``drop_rows`` models degraded coverage.

        A quarantined shard's rows are masked out of the sharded walk
        (their lo/hi read zero-length), so their accumulations vanish at
        EVERY step while edges from healthy rows into them still read
        the visit vector — exactly ``nxt[drop_rows] = 0`` per step
        (§17).  ``drop_rows=None`` (or empty) is the full-coverage walk.
        """
        self._advance(int(gen))
        if self._arrays is None:
            if self._edges:
                arr = np.array(sorted(self._edges), np.int64)
                self._arrays = (arr[:, 0], arr[:, 1])
            else:
                e = np.empty(0, np.int64)
                self._arrays = (e, e)
        s, d = self._arrays
        drop = (
            None if drop_rows is None or len(drop_rows) == 0
            else np.asarray(drop_rows, np.int64)
        )
        v = np.asarray(visits_row, np.float64)
        for _ in range(steps):
            nxt = np.zeros(self.nv, np.float64)
            np.add.at(nxt, s, v[d])
            if drop is not None:
                nxt[drop] = 0.0
            v = nxt
        return v


def run_traffic(
    server: "serve_mod.WalkServer",
    nv: int,
    *,
    requests: int = 200,
    steps: int = 4,
    seeds_per_request: int = 4,
    update_every: int = 10,
    update_size: int = 256,
    delete_every: int = 4,
    seed: int = 0,
    submit_gap_s: float = 0.0,
    timeout=None,
):
    """Drive a mixed update/walk stream through a running server.

    Every ``update_every``-th request is preceded by an update batch
    (every ``delete_every``-th of those deletes random pairs instead of
    inserting).  Returns ``(walk_tickets, update_tickets)`` where each
    update ticket is paired with its plan for oracle replay.  Tickets
    are NOT waited on here — callers decide how long to block.
    """
    rng = np.random.default_rng(seed)
    walk_tickets, update_tickets = [], []
    n_updates = 0
    for i in range(int(requests)):
        if update_every and i % update_every == 0:
            if delete_every and n_updates % delete_every == delete_every - 1:
                eb = edgebatch.from_arrays(
                    rng.integers(0, nv, update_size),
                    rng.integers(0, nv, update_size),
                )
                plan = updates.plan_update(deletes=eb)
            else:
                eb = edgebatch.random_insertions(rng, nv, update_size)
                plan = updates.plan_update(inserts=eb)
            update_tickets.append((server.submit_update(plan), plan))
            n_updates += 1
        seeds = rng.integers(0, nv, size=seeds_per_request)
        walk_tickets.append(
            server.submit_walk(seeds, steps=steps, timeout=timeout)
        )
        if submit_gap_s:
            time.sleep(submit_gap_s)
    return walk_tickets, update_tickets


def count_torn_reads(
    oracle: GenerationOracle,
    walk_tickets,
    update_tickets,
    *,
    sample: float = 1.0,
    seed: int = 0,
    rtol: float = 1e-4,
    atol: float = 1e-2,
    down_rows_of=None,
):
    """Verify served walks against the per-generation oracle.

    Returns ``(torn, checked)``: ``torn`` counts served walks whose
    visits do NOT match the oracle at their own generation — any torn
    read (a walk that saw a half-applied plan) fails the allclose, since
    no sealed edge-set produces its numbers.  ``sample`` < 1 checks a
    random subset (bench runs on larger graphs bound verify cost; tests
    use 1.0).  ``down_rows_of`` (ticket → row-id array or None) maps a
    degraded response's ``down_shards`` to the masked rows so §17
    coverage-degraded answers verify against the SAME oracle — a
    degraded walk is still exact on the part it claims to cover.
    """
    rng = np.random.default_rng(seed)
    for t, plan in update_tickets:
        if t.status == serve_mod.SERVED:
            oracle.record(t.generation, plan)
    served = sorted(
        (t for t in walk_tickets if t.status == serve_mod.SERVED),
        key=lambda t: t.generation,
    )
    torn = checked = 0
    for t in served:
        if sample < 1.0 and rng.random() > sample:
            continue
        row = (
            np.asarray(t.visits_row, np.float32)
            if t.visits_row is not None
            else seed_visits_row(oracle.nv, t.seeds, t.weights)
        )
        drop = None if down_rows_of is None else down_rows_of(t)
        expect = oracle.walk(t.generation, row, t.steps, drop_rows=drop)
        checked += 1
        if not np.allclose(np.asarray(t.visits, np.float64), expect,
                           rtol=rtol, atol=atol):
            torn += 1
    return torn, checked


def percentiles(latencies_s, qs=(50, 95, 99)) -> dict:
    """{"p50_ms": ..., ...} from a list of per-request latencies."""
    if not latencies_s:
        return {f"p{q}_ms": float("nan") for q in qs}
    arr = np.asarray(latencies_s, np.float64) * 1e3
    return {f"p{q}_ms": float(np.percentile(arr, q)) for q in qs}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="serve mixed walk/update traffic from a WalkServer"
    )
    ap.add_argument("--rep", default="digraph", choices=sorted(REPRESENTATIONS))
    ap.add_argument("--kind", default="web")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--update-every", type=int, default=10)
    ap.add_argument("--update-size", type=int, default=256)
    ap.add_argument("--batch-max", type=int, default=32)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request deadline in seconds")
    ap.add_argument("--backend", default="auto",
                    help="slot_walk backend request (auto/pallas/xla/ref)")
    ap.add_argument("--verify", type=float, default=0.25,
                    help="fraction of served walks checked against the "
                         "per-generation oracle (0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rep, csr = build_rep(
        args.rep, kind=args.kind, scale=args.scale,
        edge_factor=args.edge_factor,
    )
    nv = int(csr.n)
    print(f"[serve] {args.rep} kind={args.kind} |V|={nv} |E|={int(csr.m)}")
    server = serve_mod.WalkServer(
        rep, max_queue=args.max_queue, batch_max=args.batch_max,
        default_timeout=args.timeout, walk_backend=args.backend,
    ).start()
    t0 = time.monotonic()
    walks, upds = run_traffic(
        server, nv, requests=args.requests, steps=args.steps,
        update_every=args.update_every, update_size=args.update_size,
        seed=args.seed, timeout=args.timeout,
    )
    for t in walks:
        t.wait(60.0)
    stats = server.stop()
    dt = time.monotonic() - t0
    server.assert_no_lost()

    lat = [t.latency_s for t in walks if t.status == serve_mod.SERVED]
    pct = percentiles(lat)
    torn = checked = 0
    if args.verify > 0:
        torn, checked = count_torn_reads(
            GenerationOracle(csr), walks, upds, sample=args.verify
        )
    print(
        f"[serve] {stats['served']}/{stats['submitted']} served in {dt:.2f}s "
        f"({stats['served'] / max(dt, 1e-9):.1f} req/s), "
        f"shed={stats['shed_expired']} "
        f"rejected={stats['rejected_backpressure'] + stats['rejected_other']} "
        f"failed={stats['failed']}"
    )
    print(
        f"[serve] latency p50={pct['p50_ms']:.2f}ms p95={pct['p95_ms']:.2f}ms "
        f"p99={pct['p99_ms']:.2f}ms | generations={stats['generation'] + 1} "
        f"updates={stats['updates_applied']} "
        f"fallbacks={stats['breaker_fallbacks']}"
    )
    if checked:
        print(f"[serve] torn_reads={torn}/{checked} checked")
        assert torn == 0, "snapshot isolation violated"
    return stats


if __name__ == "__main__":
    main()
