"""Cell programs: (arch × shape) -> step function + inputs + shardings.

Three variants per cell:
  * "full"  — the production program (scan over layers, grad-accum scan,
              edge chunking): compile-success + memory_analysis gate.
  * "cost1"/"cost2" — reduced-depth UNROLLED variants (1 / 2 layers,
              accum=1, no chunk scan) whose cost_analysis extrapolates the
              true per-step roofline terms (XLA counts while bodies once —
              verified; launch/roofline.py does the linear extrapolation).
  * reduced=True — tiny smoke configs with real arrays (CPU one-step tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import base as cfgbase
from ..models.gnn import gcn as gcn_mod
from ..models.gnn import graphcast as graphcast_mod
from ..models.gnn import mace as mace_mod
from ..models.gnn import schnet as schnet_mod
from ..models.recsys import two_tower as tt_mod
from ..models.transformer import config as tconfig
from ..models.transformer import model as tmodel
from ..sampling import neighbor
from ..train import loop as train_loop
from ..train import optimizer as opt_mod
from . import shardings as shard_mod

GNN_MODULES = {
    "gcn": gcn_mod,
    "schnet": schnet_mod,
    "mace": mace_mod,
    "graphcast": graphcast_mod,
}

OPT_CFG = opt_mod.OptimizerConfig(lr=1e-4, warmup_steps=10, total_steps=1000)
OPT_CFG_BF16 = dataclasses.replace(OPT_CFG, state_dtype=jnp.bfloat16)

# grad-accumulation microbatching for LM training (DESIGN.md §5)
LM_TRAIN_ACCUM = 16


@dataclasses.dataclass
class CellProgram:
    step_fn: Callable
    args: tuple                   # pytrees (arrays if reduced, SDS otherwise)
    in_shardings: Optional[tuple]
    donate: tuple = ()
    loop_correction: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)


def _sds_like(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------
def _lm_cfg_variant(cfg: tconfig.TransformerConfig, variant: str):
    if variant == "full":
        return cfg
    n = {"cost1": 1, "cost2": 2, "cost4": 4}[variant]
    return dataclasses.replace(cfg, n_layers=n, scan_layers=False)


def _lm_state(cfg, opt_cfg, *, concrete: bool):
    def init():
        params = tmodel.init_params(jax.random.PRNGKey(0), cfg)
        return train_loop.init_state(params, opt_cfg)

    if concrete:
        return init()
    return jax.eval_shape(init)


def _lm_train_cell(cfg, shape, *, reduced, variant):
    # §Perf iteration 3 (mistral-large): bf16 adam m/v for every LM train —
    # frees 2 bytes/param of HBM (mistral peak 16.9 -> 15.0 GiB, fits v5e)
    opt_cfg = OPT_CFG_BF16 if not reduced else OPT_CFG
    if reduced:
        cfg = dataclasses.replace(cfg, n_layers=2)
        seq, gb, accum = 32, 4, 2
    else:
        cfg = _lm_cfg_variant(cfg, variant)
        seq, gb = shape["seq_len"], shape["global_batch"]
        accum = 1 if variant != "full" else LM_TRAIN_ACCUM
    ub = max(gb // LM_TRAIN_ACCUM, 1) if not reduced else gb // accum

    loss = functools.partial(tmodel.loss_fn, cfg=cfg)
    step = train_loop.make_train_step(
        lambda p, b: loss(p, b), opt_cfg, grad_accum=accum
    )
    state = _lm_state(cfg, opt_cfg, concrete=reduced)
    tok_shape = (accum, ub, seq) if accum > 1 else (ub, seq)
    if reduced:
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, tok_shape, 0, cfg.vocab, jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
    else:
        sds = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
        batch = {"tokens": sds, "labels": sds}
    return CellProgram(
        step_fn=step,
        args=(state, batch),
        in_shardings=None,
        donate=(0,),
        loop_correction={
            "kind": "lm_train",
            "n_layers": int(_orig_layers(cfg, variant, reduced)),
            "accum": LM_TRAIN_ACCUM,
        },
        meta={"cfg": cfg, "tokens_per_step": gb * seq},
    )


def _orig_layers(cfg, variant, reduced):
    return cfg.n_layers  # caller passes the already-variant cfg; roofline
    # uses the FULL config's layer count from the registry instead.


def _lm_prefill_cell(cfg, shape, *, reduced, variant):
    if reduced:
        cfg = dataclasses.replace(cfg, n_layers=2)
        seq, b = 32, 2
    else:
        cfg = _lm_cfg_variant(cfg, variant)
        seq, b = shape["seq_len"], shape["global_batch"]

    def step(params, tokens):
        logits, _ = tmodel.forward(params, tokens, cfg)
        return logits

    if reduced:
        params = tmodel.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (b, seq), 0, cfg.vocab, jnp.int32
        )
    else:
        params = jax.eval_shape(
            lambda: tmodel.init_params(jax.random.PRNGKey(0), cfg)
        )
        tokens = jax.ShapeDtypeStruct((b, seq), jnp.int32)
    return CellProgram(
        step_fn=step,
        args=(params, tokens),
        in_shardings=None,
        loop_correction={"kind": "lm_prefill"},
        meta={"cfg": cfg},
    )


def _lm_decode_cell(cfg, shape, *, reduced, variant):
    if reduced:
        cfg = dataclasses.replace(cfg, n_layers=2)
        seq, b = 64, 2
    else:
        cfg = _lm_cfg_variant(cfg, variant)
        seq, b = shape["seq_len"], shape["global_batch"]

    def step(params, cache, tokens):
        return tmodel.decode_step(params, cache, tokens, cfg)

    if reduced:
        params = tmodel.init_params(jax.random.PRNGKey(0), cfg)
        cache = tmodel.init_cache(cfg, b, seq)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, 1), 0, cfg.vocab)
    else:
        params = jax.eval_shape(
            lambda: tmodel.init_params(jax.random.PRNGKey(0), cfg)
        )
        cache = tmodel.cache_shapes(cfg, b, seq)
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return CellProgram(
        step_fn=step,
        args=(params, cache, tokens),
        in_shardings=None,
        donate=(1,),
        loop_correction={"kind": "lm_decode"},
        meta={"cfg": cfg, "batch": b, "cache_len": seq},
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------
def _gnn_graph_arrays(model: str, cfg, n, e, d_feat, *, reduced, n_graphs=1):
    """Synthetic padded graph batch (arrays when reduced, SDS otherwise)."""
    mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if not reduced else None

    def arr(shape, dtype, gen):
        if mk:
            return mk(shape, dtype)
        return gen()

    rng = np.random.default_rng(0)
    g = {
        "edge_src": arr((e,), jnp.int32, lambda: jnp.asarray(rng.integers(0, n, e), jnp.int32)),
        "edge_dst": arr((e,), jnp.int32, lambda: jnp.asarray(rng.integers(0, n, e), jnp.int32)),
    }
    if model in ("mace", "schnet"):
        g["node_feat"] = arr((n,), jnp.int32, lambda: jnp.asarray(rng.integers(0, 10, n), jnp.int32))
        g["positions"] = arr((n, 3), jnp.float32, lambda: jnp.asarray(rng.standard_normal((n, 3)) * 3, jnp.float32))
        g["graph_ids"] = arr((n,), jnp.int32, lambda: jnp.asarray(np.minimum(np.arange(n) * n_graphs // max(n, 1), n_graphs - 1), jnp.int32))
        g["labels"] = arr((n_graphs,), jnp.float32, lambda: jnp.asarray(rng.standard_normal(n_graphs), jnp.float32))
    elif model == "graphcast":
        nv = cfg.n_vars
        g["node_feat"] = arr((n, nv), jnp.float32, lambda: jnp.asarray(rng.standard_normal((n, nv)), jnp.float32))
        g["positions"] = arr((n, 3), jnp.float32, lambda: jnp.asarray(rng.standard_normal((n, 3)), jnp.float32))
        g["labels"] = arr((n, nv), jnp.float32, lambda: jnp.asarray(rng.standard_normal((n, nv)), jnp.float32))
    else:  # gcn
        g["node_feat"] = arr((n, d_feat), jnp.float32, lambda: jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32))
        g["labels"] = arr((n,), jnp.int32, lambda: jnp.asarray(rng.integers(0, cfg.n_classes, n), jnp.int32))
    return g


def _gnn_train_cell(entry, cfg, shape, *, reduced, variant):
    mod = GNN_MODULES[entry.model]
    kind = shape["kind"]
    pad512 = lambda x: -(-x // 512) * 512
    if reduced:
        n, e, d_feat, n_graphs = 48, 160, getattr(cfg, "d_in", 16), 4
    elif kind == "batched":
        n_graphs = shape["batch"]
        n = shape["n_nodes"] * n_graphs
        e = shape["n_edges"] * n_graphs
        d_feat = getattr(cfg, "d_in", 16)
    else:
        # pad node/edge counts to 512 so vertex blocks shard evenly
        # (pow-2/page rounding — core.alloc policy applied to shapes)
        n, e = pad512(shape["n_nodes"]), pad512(shape["n_edges"])
        d_feat = shape.get("d_feat", getattr(cfg, "d_in", 16))
        n_graphs = 1
    if entry.model == "gcn" and not reduced and kind != "batched":
        cfg = dataclasses.replace(cfg, d_in=d_feat)
    if entry.model == "graphcast" and not reduced and e > 2_000_000:
        cfg = dataclasses.replace(cfg, remat=True, bf16=True)
    if entry.model == "mace" and variant.startswith("chunk"):
        # two-point chunk variants for roofline extrapolation of the
        # scan-counted density body (launch/roofline.py)
        cfg = dataclasses.replace(cfg, edge_chunks=int(variant[5:]))
    elif entry.model == "mace" and e > 2_000_000 and variant == "full":
        cfg = dataclasses.replace(cfg, edge_chunks=64)

    loss = functools.partial(mod.loss_fn, cfg=cfg)
    # n_graphs is a STATIC segment count — injected via closure, never traced
    if entry.model in ("mace", "schnet"):
        step = train_loop.make_train_step(
            lambda p, b: loss(p, {**b, "n_graphs": n_graphs}), OPT_CFG
        )
    else:
        step = train_loop.make_train_step(lambda p, b: loss(p, b), OPT_CFG)
    if reduced:
        params = mod.init_params(jax.random.PRNGKey(0), cfg)
        state = train_loop.init_state(params, OPT_CFG)
    else:
        state = jax.eval_shape(
            lambda: train_loop.init_state(
                mod.init_params(jax.random.PRNGKey(0), cfg), OPT_CFG
            )
        )
    g = _gnn_graph_arrays(entry.model, cfg, n, e, d_feat, reduced=reduced, n_graphs=n_graphs)
    lc = {"kind": "gnn"}
    if getattr(cfg, "edge_chunks", 0) > 1:
        lc = {"kind": "gnn_chunked", "chunks": cfg.edge_chunks, "layers": cfg.n_layers}
    return CellProgram(
        step_fn=step,
        args=(state, g),
        in_shardings=None,
        donate=(0,),
        loop_correction=lc,
        meta={"cfg": cfg, "n": n, "e": e},
    )


def _gnn_sampled_cell(entry, cfg, shape, *, reduced, variant):
    """minibatch_lg: in-step fanout sampling from the big CSR."""
    mod = GNN_MODULES[entry.model]
    if reduced:
        n, e, seeds_n, fanout = 64, 256, 4, (3, 2)
        d_feat = getattr(cfg, "d_in", 16)
    else:
        pad512 = lambda x: -(-x // 512) * 512
        n, e = pad512(shape["n_nodes"]), pad512(shape["n_edges"])
        seeds_n, fanout = shape["batch_nodes"], tuple(shape["fanout"])
        d_feat = getattr(cfg, "d_in", 100)
    if entry.model == "gcn":
        cfg = dataclasses.replace(cfg, d_in=d_feat)
    elif entry.model == "graphcast":
        d_feat = cfg.n_vars  # encoder consumes the physical variables
    sizes = neighbor.flat_sizes(seeds_n, fanout)
    n_sub = sum(sizes)

    def build_subgraph(offsets, dst, seeds, key, node_feat, positions, labels):
        blocks, layers, masks = neighbor.sample_subgraph(key, offsets, dst, seeds, fanout)
        nodes = jnp.concatenate(layers)                      # [n_sub] global ids
        off = np.cumsum([0] + sizes)
        es, ed, ems = [], [], []
        for h, blk in enumerate(blocks):
            es.append(off[h + 1] + blk.edge_src)
            ed.append(off[h] + blk.edge_dst)
            ems.append(blk.mask)
        esrc = jnp.concatenate(es)
        edst = jnp.concatenate(ed)
        em = jnp.concatenate(ems)
        esrc = jnp.where(em, esrc, n_sub)
        edst = jnp.where(em, edst, n_sub)
        g = {"edge_src": esrc, "edge_dst": edst}
        if entry.model in ("mace", "schnet"):
            g["node_feat"] = node_feat[jnp.clip(nodes, 0, n - 1)]
            g["positions"] = positions[jnp.clip(nodes, 0, n - 1)]
            g["graph_ids"] = jnp.zeros((n_sub,), jnp.int32)
            g["n_graphs"] = 1
            g["labels"] = jnp.zeros((1,), jnp.float32)
        elif entry.model == "graphcast":
            g["node_feat"] = node_feat[jnp.clip(nodes, 0, n - 1)]
            g["positions"] = positions[jnp.clip(nodes, 0, n - 1)]
            g["labels"] = labels[jnp.clip(nodes, 0, n - 1)]
        else:
            g["node_feat"] = node_feat[jnp.clip(nodes, 0, n - 1)]
            lab = labels[jnp.clip(nodes, 0, n - 1)]
            # supervise seeds only
            seed_mask = jnp.arange(n_sub) < seeds_n
            g["labels"] = jnp.where(seed_mask, lab, -1)
        return g

    loss = functools.partial(mod.loss_fn, cfg=cfg)

    def step(state, batch):
        g = build_subgraph(
            batch["offsets"], batch["dst"], batch["seeds"], batch["key"],
            batch["node_feat"], batch.get("positions"), batch["labels"],
        )
        inner = train_loop.make_train_step(lambda p, b: loss(p, b), OPT_CFG)
        return inner(state, g)

    if reduced:
        params = mod.init_params(jax.random.PRNGKey(0), cfg)
        state = train_loop.init_state(params, OPT_CFG)
        rng = np.random.default_rng(0)
        src_np = rng.integers(0, n, e)
        order = np.argsort(src_np)
        counts = np.bincount(src_np, minlength=n)
        offsets = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        batch = {
            "offsets": jnp.asarray(offsets, jnp.int32),
            "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
            "seeds": jnp.asarray(rng.integers(0, n, seeds_n), jnp.int32),
            "key": jax.random.PRNGKey(7),
            "node_feat": jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32)
            if entry.model not in ("mace", "schnet")
            else jnp.asarray(rng.integers(0, 10, n), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, getattr(cfg, "n_classes", 2), n), jnp.int32)
            if entry.model == "gcn"
            else jnp.asarray(rng.standard_normal((n, getattr(cfg, "n_vars", 1))) if entry.model == "graphcast" else rng.standard_normal(n), jnp.float32),
        }
        if entry.model in ("mace", "schnet", "graphcast"):
            batch["positions"] = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    else:
        state = jax.eval_shape(
            lambda: train_loop.init_state(
                mod.init_params(jax.random.PRNGKey(0), cfg), OPT_CFG
            )
        )
        nf = (
            jax.ShapeDtypeStruct((n, d_feat), jnp.float32)
            if entry.model not in ("mace", "schnet")
            else jax.ShapeDtypeStruct((n,), jnp.int32)
        )
        lab = (
            jax.ShapeDtypeStruct((n,), jnp.int32)
            if entry.model == "gcn"
            else jax.ShapeDtypeStruct(
                (n, getattr(cfg, "n_vars", 1)) if entry.model == "graphcast" else (n,),
                jnp.float32,
            )
        )
        batch = {
            "offsets": jax.ShapeDtypeStruct((n + 1,), jnp.int32),
            "dst": jax.ShapeDtypeStruct((e,), jnp.int32),
            "seeds": jax.ShapeDtypeStruct((seeds_n,), jnp.int32),
            "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
            "node_feat": nf,
            "labels": lab,
        }
        if entry.model in ("mace", "schnet", "graphcast"):
            batch["positions"] = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    return CellProgram(
        step_fn=step,
        args=(state, batch),
        in_shardings=None,
        donate=(0,),
        loop_correction={"kind": "gnn"},
        meta={"cfg": cfg, "n_sub": n_sub},
    )


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------
def _recsys_cell(cfg, shape, *, reduced, variant):
    kind = shape["kind"]
    if reduced:
        b, ncand = 8, 64
    else:
        b = shape["batch"]
        ncand = shape.get("n_candidates", 0)
    nf_u, nf_i, k = cfg.n_user_fields, cfg.n_item_fields, cfg.bag_size

    def mk_bags(n, nf):
        if reduced:
            rng = np.random.default_rng(0)
            return jnp.asarray(
                rng.integers(-1, cfg.n_items, (n, nf, k)), jnp.int32
            )
        return jax.ShapeDtypeStruct((n, nf, k), jnp.int32)

    if kind == "train":
        loss = functools.partial(tt_mod.loss_fn, cfg=cfg)
        step = train_loop.make_train_step(lambda p, bb: loss(p, bb), OPT_CFG)
        if reduced:
            params = tt_mod.init_params(jax.random.PRNGKey(0), cfg)
            state = train_loop.init_state(params, OPT_CFG)
        else:
            state = jax.eval_shape(
                lambda: train_loop.init_state(
                    tt_mod.init_params(jax.random.PRNGKey(0), cfg), OPT_CFG
                )
            )
        batch = {
            "user_bags": mk_bags(b, nf_u),
            "item_bags": mk_bags(b, nf_i),
            "item_logq": jnp.zeros((b,), jnp.float32)
            if reduced
            else jax.ShapeDtypeStruct((b,), jnp.float32),
        }
        return CellProgram(
            step_fn=step, args=(state, batch), in_shardings=None, donate=(0,),
            loop_correction={"kind": "recsys"}, meta={"cfg": cfg},
        )

    params = (
        tt_mod.init_params(jax.random.PRNGKey(0), cfg)
        if reduced
        else jax.eval_shape(lambda: tt_mod.init_params(jax.random.PRNGKey(0), cfg))
    )
    if kind == "serve":
        def step(p, batch):
            return tt_mod.serve_step(p, batch, cfg)

        batch = {"user_bags": mk_bags(b, nf_u), "item_bags": mk_bags(b, nf_i)}
        return CellProgram(
            step_fn=step, args=(params, batch), in_shardings=None,
            loop_correction={"kind": "recsys"}, meta={"cfg": cfg},
        )
    # retrieval: 1 query vs n_candidates
    def step(p, batch):
        return tt_mod.score_candidates(p, batch["user_bags"], batch["cand_bags"], cfg)

    batch = {"user_bags": mk_bags(1, nf_u), "cand_bags": mk_bags(ncand, nf_i)}
    return CellProgram(
        step_fn=step, args=(params, batch), in_shardings=None,
        loop_correction={"kind": "recsys"}, meta={"cfg": cfg},
    )


# ---------------------------------------------------------------------------
# dispatch + sharding attach
# ---------------------------------------------------------------------------
def build_cell(
    arch: str,
    shape_name: str,
    *,
    reduced: bool = False,
    variant: str = "full",
    data_axes: tuple = (),
) -> CellProgram:
    entry = cfgbase.get(arch)
    shape = cfgbase.FAMILY_SHAPES[entry.family][shape_name]
    cfg = entry.smoke if reduced else entry.full
    if data_axes and not reduced:
        # activation sharding constraints (models/sharding_utils.py)
        if entry.family == "lm":
            cfg = dataclasses.replace(cfg, batch_axes=tuple(data_axes), tp_axis="model")
        elif entry.family == "gnn":
            cfg = dataclasses.replace(cfg, shard_axes=tuple(data_axes) + ("model",))
        else:
            cfg = dataclasses.replace(cfg, shard_axes=tuple(data_axes))
    if entry.family == "lm":
        kind = shape["kind"]
        if kind == "train":
            cell = _lm_train_cell(cfg, shape, reduced=reduced, variant=variant)
        elif kind == "prefill":
            cell = _lm_prefill_cell(cfg, shape, reduced=reduced, variant=variant)
        else:
            cell = _lm_decode_cell(cfg, shape, reduced=reduced, variant=variant)
        cell.loop_correction["full_layers"] = entry.full.n_layers
        return cell
    if entry.family == "gnn":
        if shape["kind"] == "sampled":
            return _gnn_sampled_cell(entry, cfg, shape, reduced=reduced, variant=variant)
        return _gnn_train_cell(entry, cfg, shape, reduced=reduced, variant=variant)
    return _recsys_cell(cfg, shape, reduced=reduced, variant=variant)


def build_opt_cell(arch: str, *, variant: str = "cost1") -> CellProgram:
    """Optimizer-apply-only program (LM): separates optimizer flops/bytes
    from fwd/bwd so grad-accum scaling in roofline extrapolation is exact."""
    entry = cfgbase.get(arch)
    cfg = _lm_cfg_variant(entry.full, variant.replace("opt", "cost"))
    opt_cfg = OPT_CFG_BF16
    state = _lm_state(cfg, opt_cfg, concrete=False)
    grads = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), state["params"]
    )

    def step(state, grads):
        new_params, new_opt, _ = opt_mod.update(
            grads, state["opt_state"], state["params"], opt_cfg
        )
        return {"params": new_params, "opt_state": new_opt}

    return CellProgram(
        step_fn=step,
        args=(state, grads),
        in_shardings=None,
        donate=(0,),
        loop_correction={"kind": "lm_opt", "full_layers": entry.full.n_layers},
        meta={"cfg": cfg},
    )


def attach_shardings(cell: CellProgram, mesh, arch: str, shape_name: str):
    """NamedShardings for the cell's args on the given mesh."""
    entry = cfgbase.get(arch)
    shape = cfgbase.FAMILY_SHAPES[entry.family][shape_name]
    args = cell.args
    if entry.family == "lm":
        kind = shape["kind"]
        if kind == "train":
            state_s = shard_mod.lm_state_sharding(
                args[0], mesh, is_moe=entry.full.moe is not None
            )
            batch_s = shard_mod.lm_batch_sharding(args[1], mesh)
            return (state_s, batch_s)
        if kind == "prefill":
            shard_mod._MOE_HINT["moe"] = entry.full.moe is not None
            p_s = shard_mod.tree_spec(
                args[0], lambda p, m: shard_mod.lm_param_spec(p, m), mesh
            )
            t_s = shard_mod.lm_infer_batch_sharding(args[1], mesh)
            return (p_s, t_s)
        # decode
        shard_mod._MOE_HINT["moe"] = entry.full.moe is not None
        p_s = shard_mod.tree_spec(
            args[0], lambda p, m: shard_mod.lm_param_spec(p, m), mesh
        )
        c_s = shard_mod.lm_cache_sharding(args[1], mesh, batch=shape["global_batch"])
        t_s = shard_mod.lm_infer_batch_sharding(args[2], mesh)
        return (p_s, c_s, t_s)
    if entry.family == "gnn":
        state_s = shard_mod.gnn_state_sharding(args[0], mesh)
        batch_s = shard_mod.gnn_batch_sharding(args[1], mesh)
        return (state_s, batch_s)
    # recsys
    if len(args) == 2 and isinstance(args[0], dict) and "opt_state" in args[0]:
        state_s = shard_mod.recsys_state_sharding(args[0], mesh)
        batch_s = shard_mod.recsys_batch_sharding(args[1], mesh)
        return (state_s, batch_s)
    p_s = shard_mod.recsys_state_sharding(args[0], mesh)
    b_s = shard_mod.recsys_batch_sharding(args[1], mesh)
    return (p_s, b_s)
