"""repro.core — dynamic graph representations (the paper's contribution).

Representations (DESIGN.md §3):
  DiGraph      — paper's CP2AA-backed slotted CSR (ours)
  SortedCOO    — cuGraph-analogue sort/merge rebuild
  LazyCSR      — GraphBLAS-analogue zombies + pending tuples
  ChunkedGraph — Aspen-analogue append-only pages, O(1) snapshots
  Vector2D     — naive per-vertex host arrays (Fig. 1 strawman)
"""
from . import alloc, arena, bitset, traversal, updates, util, walk_image  # noqa: F401
from .chunked import ChunkedGraph  # noqa: F401
from .coo import SortedCOO  # noqa: F401
from .csr import CSR, from_coo, from_dense  # noqa: F401
from .digraph import DiGraph  # noqa: F401
from .edgebatch import EdgeBatch, from_arrays, random_deletions, random_insertions  # noqa: F401
from .lazy import LazyCSR  # noqa: F401
from .updates import UpdatePlan, plan_update  # noqa: F401
from .vector2d import Vector2D  # noqa: F401
from .walk_image import WalkImage  # noqa: F401

#: Representation registry used by benchmarks/tests; ordering mirrors the
#: paper's comparison tables.
REPRESENTATIONS = {
    "digraph": DiGraph,       # ours
    "coo": SortedCOO,         # cuGraph-analogue
    "lazy": LazyCSR,          # GraphBLAS-analogue
    "chunked": ChunkedGraph,  # Aspen-analogue
    "vector2d": Vector2D,     # PetGraph/SNAP-class strawman
}
