"""Packed bitset for vertex-existence flags (paper Alg 1: ``exists``).

The paper stores existence flags in 64-bit chunks (BOOL_BITS = 64); JAX's
default int width is 32, so we pack into uint32 words.  All ops are
vectorized and jit-safe.
"""
from __future__ import annotations

import jax.numpy as jnp

BITS = 32


def make(capacity: int) -> jnp.ndarray:
    """Zeroed bitset able to hold ``capacity`` flags."""
    words = -(-int(capacity) // BITS)
    return jnp.zeros((max(words, 1),), dtype=jnp.uint32)


def capacity(bits: jnp.ndarray) -> int:
    return bits.shape[0] * BITS


def get(bits: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Vectorized getBit: True where the flag is set. OOB reads are False."""
    idx = jnp.asarray(idx)
    word = idx // BITS
    off = (idx % BITS).astype(jnp.uint32)
    in_range = (idx >= 0) & (word < bits.shape[0])
    w = bits[jnp.clip(word, 0, bits.shape[0] - 1)]
    return in_range & (((w >> off) & jnp.uint32(1)) != 0)


def set_(bits: jnp.ndarray, idx: jnp.ndarray, value: bool = True) -> jnp.ndarray:
    """Vectorized setBit/clearBit; returns the new word array."""
    idx = jnp.asarray(idx).reshape(-1)
    word = idx // BITS
    off = (idx % BITS).astype(jnp.uint32)
    mask = (jnp.uint32(1) << off).astype(jnp.uint32)
    if value:
        return bits.at[word].set(bits[word] | mask, mode="drop")
    return bits.at[word].set(bits[word] & ~mask, mode="drop")


def set_many(bits: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Set several (possibly duplicate) indices at once.

    Scatter-OR is not a native XLA accumulator, so we sort indices by word
    and OR within equal-word runs using an associative scan, then scatter
    the per-run result once per word.
    """
    import jax

    idx = jnp.asarray(idx).reshape(-1)
    word = idx // BITS
    off = (idx % BITS).astype(jnp.uint32)
    mask = (jnp.uint32(1) << off).astype(jnp.uint32)
    order = jnp.argsort(word, stable=True)
    w_s, m_s = word[order], mask[order]
    seg_start = jnp.concatenate([jnp.array([True]), w_s[1:] != w_s[:-1]])

    def combine(a, b):
        # carry OR across a run; reset at segment starts
        (av, astart), (bv, bstart) = a, b
        return jnp.where(bstart, bv, av | bv), astart | bstart

    vals, _ = jax.lax.associative_scan(combine, (m_s, seg_start))
    # last element of each run holds the full OR
    run_end = jnp.concatenate([w_s[1:] != w_s[:-1], jnp.array([True])])
    upd_words = jnp.where(run_end, w_s, bits.shape[0])
    upd_vals = vals
    return bits.at[upd_words].set(
        bits[jnp.clip(upd_words, 0, bits.shape[0] - 1)] | upd_vals, mode="drop"
    )


def count(bits: jnp.ndarray) -> jnp.ndarray:
    """Population count across the whole bitset."""
    w = bits
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return jnp.sum((w * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def grow(bits: jnp.ndarray, new_capacity: int) -> jnp.ndarray:
    """Reallocate to a larger capacity, preserving flags (paper reallocate())."""
    new = make(new_capacity)
    return new.at[: bits.shape[0]].set(bits)
