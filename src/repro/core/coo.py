"""SortedCOO — the cuGraph-analogue representation (DESIGN.md §3).

cuGraph applies batch updates by sort-merging the batch with the existing
edge list and rebuilding the graph.  Here: a (src,dst)-lexsorted COO with
SENTINEL padding to a pow-2 capacity; *every update builds a new instance*
(there is no in-place path — exactly cuGraph's behaviour).  All updates —
insert, delete, or a mixed batch — run through one fused program
(``_jit_apply``) fed by the shared ``UpdatePlan`` layer (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, edgebatch, updates, util, walk_image

SENTINEL = util.SENTINEL


@functools.lru_cache(maxsize=None)
def _jit_apply(out_cap: int):
    """Mixed delete+insert rebuild: mark deletes, sort-merge inserts.

    Graph entries found in the (sorted) delete set blank to SENTINEL;
    insert entries concatenate *ahead* of the graph so the stable
    dedup-keep-first pass implements weight upsert.  The plan guarantees
    one op per key, so deletes and inserts never fight.
    """

    def fn(gs, gd, gw, ds, dd, is_, id_, iw):
        _, found = util.searchsorted_2d(ds, dd, gs, gd)
        gs = jnp.where(found, SENTINEL, gs)
        gd = jnp.where(found, SENTINEL, gd)
        s = jnp.concatenate([is_, gs])
        d = jnp.concatenate([id_, gd])
        w = jnp.concatenate([iw, gw])
        order = util.lexsort2(s, d)
        s, d, w = s[order], d[order], w[order]
        dup = jnp.concatenate(
            [jnp.array([False]), (s[1:] == s[:-1]) & (d[1:] == d[:-1])]
        )
        s = jnp.where(dup, SENTINEL, s)
        d = jnp.where(dup, SENTINEL, d)
        order = util.lexsort2(s, d)
        s, d, w = s[order], d[order], w[order]
        m = jnp.sum(s != SENTINEL).astype(jnp.int32)
        pad = out_cap - s.shape[0]
        if pad > 0:
            s = jnp.concatenate([s, jnp.full((pad,), SENTINEL, s.dtype)])
            d = jnp.concatenate([d, jnp.full((pad,), SENTINEL, d.dtype)])
            w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
        else:
            s, d, w = s[:out_cap], d[:out_cap], w[:out_cap]
        return s, d, w, m

    return jax.jit(fn)


@dataclasses.dataclass
class SortedCOO:
    src: jnp.ndarray
    dst: jnp.ndarray
    wgt: jnp.ndarray
    n: int
    m: int
    # cached walk image (DESIGN.md §11), migrated to the successor
    # instance on apply() so update/walk streams never rebuild it
    _image: Optional[walk_image.WalkImage] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "SortedCOO":
        from ..kernels.csr_build import ops as _cb_ops

        cap = alloc.next_pow2(max(c.m, 2))
        w = c.wgt if c.wgt is not None else np.ones(c.m, np.float32)
        src, dst, wgt = _cb_ops.flat_image(c.offsets, c.dst, w, cap)
        return cls(src, dst, wgt, int(c.n), int(c.m))

    def block_on(self) -> None:
        self.src.block_until_ready()

    # -- updates (always a new instance, cuGraph semantics) --------------
    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = False):
        g, dm = self.apply(updates.plan_update(inserts=batch), inplace=inplace)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = False):
        g, dm = self.apply(updates.plan_update(deletes=batch), inplace=inplace)
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = False):
        """Mixed delete+insert rebuild in one fused dispatch (net ΔM)."""
        del inplace  # rebuild-only representation
        if plan.n_ops == 0:
            return self, 0
        ins = plan.insert_batch()
        dele = plan.delete_batch()
        n = max(self.n, plan.max_insert_vertex() + 1)
        out_cap = alloc.next_pow2(max(self.m + plan.n_ins, 2))
        s, d, w, m = _jit_apply(out_cap)(
            self.src, self.dst, self.wgt,
            dele.src, dele.dst,
            ins.src, ins.dst, ins.wgt,
        )
        m = int(m)
        g = SortedCOO(s, d, w, n, m)
        # the successor inherits the walk image + queues the plan on it;
        # this handle's arrays are rebuilt anyway (cuGraph semantics), so
        # it rebuilds its image lazily if walked again.
        img, self._image = self._image, None
        if img is not None:
            img.queue(plan)
            g._image = img
        return g, m - self.m

    # -- export / queries -------------------------------------------------
    def clone(self) -> "SortedCOO":
        return SortedCOO(
            *util.fused_copy(self.src, self.dst, self.wgt), self.n, self.m
        )

    def snapshot(self) -> "SortedCOO":
        return dataclasses.replace(self, _image=None)

    def to_csr(self) -> csr_mod.CSR:
        s = np.asarray(self.src)[: self.m]
        d = np.asarray(self.dst)[: self.m]
        w = np.asarray(self.wgt)[: self.m]
        return csr_mod.from_coo(s, d, w, n=self.n, dedup=False)

    def to_walk_image(self) -> walk_image.WalkImage:
        """Cached walk image: patched per queued plan, rebuilt on demand.

        The (src, dst)-sorted buffer is already CSR-ordered, so the
        build reads offsets off one host ``searchsorted`` and reuses the
        ingest engine's slack-padded arena fill.
        """
        img = self._image
        if img is not None and img.flush():
            return img
        s = np.asarray(self.src)[: self.m].astype(np.int64)
        offsets = np.searchsorted(s, np.arange(self.n + 1, dtype=np.int64))
        self._image = img = walk_image.WalkImage.from_csr_arrays(
            offsets, self.dst, self.wgt, self.n
        )
        return img

    def walk_occupancy(self) -> float:
        return self.to_walk_image().occupancy

    def reverse_walk(
        self, steps: int, *, visits0: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        return self.to_walk_image().walk(steps, visits0=visits0)

    def to_edge_sets(self) -> list[set[int]]:
        return self.to_csr().to_edge_sets()
