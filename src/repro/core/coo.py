"""SortedCOO — the cuGraph-analogue representation (DESIGN.md §3).

cuGraph applies batch updates by sort-merging the batch with the existing
edge list and rebuilding the graph.  Here: a (src,dst)-lexsorted COO with
SENTINEL padding to a pow-2 capacity; *every update builds a new instance*
(there is no in-place path — exactly cuGraph's behaviour).  All updates —
insert, delete, or a mixed batch — run through one fused program
(``_jit_apply``) fed by the shared ``UpdatePlan`` layer (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, edgebatch, updates, util, walk_image

SENTINEL = util.SENTINEL


@functools.lru_cache(maxsize=None)
def _jit_apply(out_cap: int):
    """Mixed delete+insert rebuild as a GALLOPING merge (DESIGN.md §12).

    The base is (src, dst)-lexsorted with a SENTINEL tail and both batch
    halves arrive sorted from the UpdatePlan, so the merged order is
    fully determined by binary-search ranks plus prefix counts — no
    O((M+B) log(M+B)) re-sort of the whole edge list per update:

      * deletes:  one windowed binary search marks dead base slots,
      * upserts:  inserts whose key exists overwrite the weight in place,
      * placement: output slot ``o`` holds the r-th surviving base entry
        (r = o − #new-inserts-before-o) or the matching new insert —
        both resolved with searchsorted over prefix-count arrays, then
        materialized by ONE gather per output array.  Only the [B]-sized
        batch is ever sorted (by output slot).

    The plan guarantees one op per key, so deletes and inserts never
    fight and all new-insert keys are distinct.
    """

    def fn(gs, gd, gw, ds, dd, is_, id_, iw):
        cap = gs.shape[0]
        glive = gs != SENTINEL
        # -- deletes: which base slots die (SENTINEL pads only ever
        #    match SENTINEL base slots, excluded by glive)
        _, hit = util.searchsorted_2d(ds, dd, gs, gd)
        keep = glive & ~hit
        # -- inserts: upserts (key present) vs genuinely new keys
        ilive = is_ != SENTINEL
        pos_i, found_i = util.searchsorted_2d(gs, gd, is_, id_)
        is_new = ilive & ~found_i
        up_idx = jnp.where(ilive & found_i, pos_i, cap)
        gw = gw.at[up_idx].set(iw, mode="drop")  # weight upsert in place
        # -- merge ranks
        kcum = jnp.cumsum(keep.astype(jnp.int32))          # inclusive keeps
        n_keep = kcum[-1]
        kcum0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), kcum])
        ins_rank = jnp.cumsum(is_new.astype(jnp.int32)) - is_new.astype(
            jnp.int32
        )
        # output slot of each new insert: surviving base entries before
        # its key + new inserts before it in the (sorted) batch
        o_i = jnp.where(is_new, kcum0[pos_i] + ins_rank, out_cap)
        order = jnp.argsort(o_i)                           # [B] tiny sort
        srt = o_i[order]
        s_srt, d_srt, w_srt = is_[order], id_[order], iw[order]
        # -- materialize: one gather per output array
        o = jnp.arange(out_cap, dtype=jnp.int32)
        idx = jnp.searchsorted(srt, o, side="left").astype(jnp.int32)
        safe_i = jnp.clip(idx, 0, srt.shape[0] - 1)
        from_ins = srt[safe_i] == o
        r = o - idx                                        # surviving-base rank
        j = jnp.searchsorted(kcum, r + 1, side="left").astype(jnp.int32)
        safe_j = jnp.clip(j, 0, cap - 1)
        g_ok = r < n_keep
        out_s = jnp.where(
            from_ins, s_srt[safe_i],
            jnp.where(g_ok, gs[safe_j], SENTINEL),
        )
        out_d = jnp.where(
            from_ins, d_srt[safe_i],
            jnp.where(g_ok, gd[safe_j], SENTINEL),
        )
        out_w = jnp.where(
            from_ins, w_srt[safe_i],
            jnp.where(g_ok, gw[safe_j], 0.0),
        )
        m = n_keep + jnp.sum(is_new).astype(jnp.int32)
        return out_s, out_d, out_w, m

    return jax.jit(fn)


@dataclasses.dataclass
class SortedCOO:
    src: jnp.ndarray
    dst: jnp.ndarray
    wgt: jnp.ndarray
    n: int
    m: int
    # cached walk image (DESIGN.md §11), migrated to the successor
    # instance on apply() so update/walk streams never rebuild it
    _image: Optional[walk_image.WalkImage] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "SortedCOO":
        from ..kernels.csr_build import ops as _cb_ops

        cap = alloc.next_pow2(max(c.m, 2))
        w = c.wgt if c.wgt is not None else np.ones(c.m, np.float32)
        src, dst, wgt = _cb_ops.flat_image(c.offsets, c.dst, w, cap)
        return cls(src, dst, wgt, int(c.n), int(c.m))

    def block_on(self) -> None:
        self.src.block_until_ready()

    # -- updates (always a new instance, cuGraph semantics) --------------
    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = False):
        g, dm = self.apply(updates.plan_update(inserts=batch), inplace=inplace)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = False):
        g, dm = self.apply(updates.plan_update(deletes=batch), inplace=inplace)
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = False):
        """Mixed delete+insert rebuild in one fused dispatch (net ΔM)."""
        del inplace  # rebuild-only representation
        if plan.n_ops == 0:
            return self, 0
        plan.validate()  # corrupt plans (WAL replay) fail loudly (§13)
        ins = plan.insert_batch()
        dele = plan.delete_batch()
        n = max(self.n, plan.max_insert_vertex() + 1)
        out_cap = alloc.next_pow2(max(self.m + plan.n_ins, 2))
        s, d, w, m = _jit_apply(out_cap)(
            self.src, self.dst, self.wgt,
            dele.src, dele.dst,
            ins.src, ins.dst, ins.wgt,
        )
        m = int(m)
        g = SortedCOO(s, d, w, n, m)
        # the successor inherits the walk image + queues the plan on it;
        # this handle's arrays are rebuilt anyway (cuGraph semantics), so
        # it rebuilds its image lazily if walked again.
        img, self._image = self._image, None
        if img is not None:
            img.queue(plan)
            g._image = img
        return g, m - self.m

    # -- export / queries -------------------------------------------------
    def clone(self) -> "SortedCOO":
        return SortedCOO(
            *util.fused_copy(self.src, self.dst, self.wgt), self.n, self.m
        )

    def snapshot(self) -> "SortedCOO":
        return dataclasses.replace(self, _image=None)

    # -- durable state (checkpoint/restore, DESIGN.md §13) ---------------
    def state_tree(self) -> dict:
        return {
            "src": np.asarray(self.src),
            "dst": np.asarray(self.dst),
            "wgt": np.asarray(self.wgt),
            "n": np.int64(self.n),
            "m": np.int64(self.m),
        }

    @classmethod
    def from_state_tree(cls, t: dict) -> "SortedCOO":
        return cls(
            jnp.asarray(t["src"]),
            jnp.asarray(t["dst"]),
            jnp.asarray(t["wgt"]),
            int(t["n"]),
            int(t["m"]),
        )

    def to_csr(self) -> csr_mod.CSR:
        s = np.asarray(self.src)[: self.m]
        d = np.asarray(self.dst)[: self.m]
        w = np.asarray(self.wgt)[: self.m]
        return csr_mod.from_coo(s, d, w, n=self.n, dedup=False)

    def to_walk_image(self) -> walk_image.WalkImage:
        """Cached walk image: patched per queued plan, rebuilt on demand.

        The (src, dst)-sorted buffer is already CSR-ordered, so the
        build reads offsets off one host ``searchsorted`` and reuses the
        ingest engine's slack-padded arena fill.
        """
        img = self._image
        if img is not None and img.flush():
            return img
        s = np.asarray(self.src)[: self.m].astype(np.int64)
        offsets = np.searchsorted(s, np.arange(self.n + 1, dtype=np.int64))
        self._image = img = walk_image.WalkImage.from_csr_arrays(
            offsets, self.dst, self.wgt, self.n
        )
        return img

    def walk_occupancy(self) -> float:
        return self.to_walk_image().occupancy

    def reverse_walk(
        self, steps: int, *, visits0: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        # fused flush→walk: one dispatch per stream round (§12)
        return walk_image.reverse_walk_via_image(self, steps, visits0=visits0)

    def to_edge_sets(self) -> list[set[int]]:
        return self.to_csr().to_edge_sets()
