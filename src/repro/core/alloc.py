"""CP2AA-analogue capacity policy (paper Alg 11/12, adapted per DESIGN.md §2).

On CPU the paper's Concurrent Power-of-2 Arena Allocator amortizes *malloc*
cost; under XLA the analogous cost is *recompilation + whole-buffer copy* when
a shape changes.  We therefore keep CP2AA's exact size-class policy
(Alg 11 lines 30-33) but apply it to **shapes**: every dynamic array in the
system only ever takes power-of-2 (or page-rounded) sizes, so the jit cache
stays O(log N) and in-place growth uses pre-reserved slack.

All functions are pure python/numpy (shape decisions happen on host, never
inside a traced program).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# --- constants mirroring the paper's configuration (§4.1.2, Alg 11) ---------
MIN_ALLOC_BYTES = 16        # smallest size class
MAX_POW2_BYTES = 8192       # largest pow-2 class; beyond -> page rounding
PAGE_SIZE = 4096            # bytes; reserve() rounds vertex arrays to pages
EDGE_SIZE = 8               # bytes per edge: (int32 dst, float32 weight)
BOOL_BITS = 32              # existence bitset chunk width (jax default int32)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 0)."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def pow2_with_headroom(total: int, min_frac: float = 0.25) -> int:
    """Pow-2 capacity >= ``total`` with at least ``min_frac`` bump headroom.

    The walk-image build paths size their buffers with this so grown
    rows can relocate to bump blocks a while before a rebuild; keeping
    the policy here means every image layout shares one rebuild cadence.
    Dense (slack-free) images pass ``min_frac=1.0``: with zero in-block
    slack EVERY insert-touched row relocates, so they need a deeper bump
    reserve — walks only process the (quantized) bump prefix, so the
    extra capacity costs memory, not step bytes.
    """
    total = int(total)
    cap = next_pow2(max(total, 2))
    while cap < total * (1 + min_frac):
        cap *= 2
    return cap


def allocation_size(nbytes: int) -> int:
    """Paper Alg 11, allocationSize(): size class in bytes for a request.

    <=16 -> 16;  <8192 -> next pow2;  else -> round up to page multiple.
    """
    nbytes = int(nbytes)
    if nbytes <= MIN_ALLOC_BYTES:
        return MIN_ALLOC_BYTES
    if nbytes < MAX_POW2_BYTES:
        return next_pow2(nbytes)
    return -(-nbytes // PAGE_SIZE) * PAGE_SIZE


def edge_capacity(deg: int) -> int:
    """Per-vertex edge-slot capacity for a desired degree (elements)."""
    return allocation_size(max(int(deg), 1) * EDGE_SIZE) // EDGE_SIZE


def edge_capacities(degrees: np.ndarray) -> np.ndarray:
    """Vectorized `edge_capacity` over an int array of degrees."""
    deg = np.maximum(np.asarray(degrees, dtype=np.int64), 1)
    nbytes = deg * EDGE_SIZE
    # pow-2 branch
    exp = np.ceil(np.log2(np.maximum(nbytes, MIN_ALLOC_BYTES))).astype(np.int64)
    pow2 = np.maximum(1 << exp, MIN_ALLOC_BYTES)
    # page branch
    paged = -(-nbytes // PAGE_SIZE) * PAGE_SIZE
    out = np.where(nbytes < MAX_POW2_BYTES, pow2, paged)
    return (out // EDGE_SIZE).astype(np.int64)


def reserve_size(n: int, elem_bytes: int = 4) -> int:
    """Paper Alg 1 reserve(): round a vertex-array length up to a page."""
    n = max(int(n), 1)
    per_page = PAGE_SIZE // elem_bytes
    return -(-n // per_page) * per_page


@dataclasses.dataclass
class AllocStats:
    """Bookkeeping mirroring the paper's allocator microbenchmarks.

    ``relayouts`` counts whole-buffer reallocations (the expensive path the
    pow-2 slack exists to avoid); ``inplace_updates`` counts updates served
    entirely from existing slack (the cheap path).  ``used_elems`` /
    ``slack_elems`` track live edges vs dead-or-slack slots inside the
    arena's bump prefix — the occupancy signal the traversal engine uses to
    trigger block compaction (DESIGN.md §7).
    """

    relayouts: int = 0
    inplace_updates: int = 0
    slack_elems: int = 0
    used_elems: int = 0

    def record_relayout(self) -> None:
        self.relayouts += 1

    def record_inplace(self) -> None:
        self.inplace_updates += 1

    @property
    def slack_fraction(self) -> float:
        total = self.slack_elems + self.used_elems
        return self.slack_elems / total if total else 0.0

    @property
    def live_fraction(self) -> float:
        """Live-slot share of the occupied arena prefix (1.0 when empty)."""
        total = self.slack_elems + self.used_elems
        return self.used_elems / total if total else 1.0
