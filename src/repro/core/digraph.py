"""DiGraph — the paper's representation (Alg 1/2) adapted to TPU/XLA.

Layout (SoA, DESIGN.md §2):
  host metadata : degrees / capacities / block starts / exists  (numpy)
  device payload: dst[CAP_E] int32, wgt[CAP_E] f32, slot_rows[CAP_E] int32

Each vertex owns a contiguous *block* of edge slots whose size is a CP2AA
power-of-2 class (``alloc.edge_capacity``).  Blocks are handed out by the
host-side ``ArenaLayout`` (free lists + bump pointer) over one flat device
buffer.  Rows are ascending with SENTINEL padding.

Updates flow through the shared batch-update engine (DESIGN.md §9):
``core/updates.py`` canonicalizes a batch into an ``UpdatePlan`` once
(sort, dedup, per-row runs, padded operands — plan-cached for replayed
batches), then ``apply`` runs ONE fused ``kernels/slot_update`` dispatch
per pow-2 width group: gather touched rows, merge the sorted runs
(deletes + weight upserts + ranked inserts), re-sort, and scatter back —
with grown rows landing directly in their new CP2AA block.  Buffer
donation keeps it in place; capacity classes double as jit-cache buckets,
so steady-state updates never recompile.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, arena, csr as csr_mod, edgebatch, updates, util, walk_image
from ..kernels.csr_build import ops as _cb_ops
from ..kernels.slot_update import ops as _su_ops

SENTINEL = util.SENTINEL

#: Live-slot fraction of the arena bump prefix below which traversal-time
#: auto-compaction kicks in (DESIGN.md §7).
COMPACT_THRESHOLD = 0.5
#: Don't bother compacting arenas smaller than this many slots.
COMPACT_MIN_SLOTS = 4 * 128


# ---------------------------------------------------------------------------
# jitted device helpers (module level, cached per static shape)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jit_compact(cap_e: int):
    """Gather every live edge into a freshly packed buffer (DESIGN.md §7).

    ``src_idx``/``dst_idx`` are host-computed per-edge moves, pow-2 padded
    (pad src clipped, pad dst out-of-bounds so it drops).  A fresh target
    buffer makes the pass order-free — no aliasing hazards from moving
    blocks left within one buffer.
    """

    def fn(dst, wgt, src_idx, dst_idx):
        safe = jnp.clip(src_idx, 0, dst.shape[0] - 1)
        nd = jnp.full((cap_e,), SENTINEL, jnp.int32).at[dst_idx].set(
            dst[safe], mode="drop"
        )
        nw = jnp.zeros((cap_e,), jnp.float32).at[dst_idx].set(
            wgt[safe], mode="drop"
        )
        return nd, nw

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_grow_buffer(new_cap: int, cap_v: int):
    def fn(dst, wgt, slot_rows):
        nd = jnp.full((new_cap,), SENTINEL, jnp.int32).at[: dst.shape[0]].set(dst)
        nw = jnp.zeros((new_cap,), jnp.float32).at[: wgt.shape[0]].set(wgt)
        nr = (
            jnp.full((new_cap,), cap_v, jnp.int32)
            .at[: slot_rows.shape[0]]
            .set(slot_rows)
        )
        return nd, nw, nr

    return jax.jit(fn)


def _pad_pow2(a: np.ndarray, fill) -> np.ndarray:
    cap = alloc.next_pow2(max(a.shape[0], 1))
    if cap == a.shape[0]:
        return a
    return np.concatenate([a, np.full(cap - a.shape[0], fill, a.dtype)])


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DiGraph:
    """Mutable host handle around immutable device payloads."""

    # host metadata
    degrees: np.ndarray        # int64 [CAP_V]
    capacities: np.ndarray     # int64 [CAP_V]  (0 = no block)
    starts: np.ndarray         # int64 [CAP_V]  (-1 = no block)
    exists: np.ndarray         # bool  [CAP_V]
    layout: arena.ArenaLayout
    n: int
    m: int
    # device payload
    dst: jnp.ndarray
    wgt: jnp.ndarray
    slot_rows: jnp.ndarray
    stats: alloc.AllocStats = dataclasses.field(default_factory=alloc.AllocStats)
    # per-buffer seal-on-snapshot (DESIGN.md §10): names of device buffers
    # currently shared with a snapshot.  A mutation detaches ONLY the
    # buffers it is about to write — a small post-snapshot update copies
    # dst/wgt but keeps sharing slot_rows until a block actually moves.
    _sealed: set = dataclasses.field(default_factory=set)
    # memoized derived views; any mutation resets them to None.
    _csr_cache: Optional[csr_mod.CSR] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _image: Optional[walk_image.WalkImage] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------------
    @property
    def cap_v(self) -> int:
        return self.degrees.shape[0]

    @property
    def cap_e(self) -> int:
        return int(self.dst.shape[0])

    def has_vertex(self, u: int) -> bool:
        return 0 <= u < self.cap_v and bool(self.exists[u])

    def degree(self, u: int) -> int:
        return int(self.degrees[u]) if u < self.cap_v else 0

    def edges_of(self, u: int) -> np.ndarray:
        if u >= self.cap_v or self.starts[u] < 0:
            return np.empty((0,), np.int32)
        s, d = int(self.starts[u]), int(self.degrees[u])
        return np.asarray(self.dst[s : s + d])

    def block_on(self) -> None:
        self.dst.block_until_ready()

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_csr(cls, c: csr_mod.CSR, *, engine: str = "auto") -> "DiGraph":
        """Direct CSR -> arena-image construction (DESIGN.md §10).

        Host metadata (CP2AA block placement) stays numpy; the device
        payload comes from ``kernels/csr_build.arena_image`` — a numpy
        shifted-offset fill + one transfer off-TPU, or a fused on-device
        scatter program on TPU (no host round-trip for a device CSR).
        """
        offsets_h = np.asarray(c.offsets, dtype=np.int64)
        degrees = np.diff(offsets_h)
        n_cap = alloc.reserve_size(c.n)
        deg = np.zeros(n_cap, np.int64)
        deg[: c.n] = degrees
        caps = np.zeros(n_cap, np.int64)
        caps[: c.n] = np.where(degrees > 0, alloc.edge_capacities(degrees), 0)
        starts = np.full(n_cap, -1, np.int64)
        csum = np.zeros(c.n, np.int64)
        np.cumsum(caps[: c.n], out=csum)
        starts[: c.n] = np.where(caps[: c.n] > 0, csum - caps[: c.n], -1)
        total = int(csum[-1]) if c.n else 0
        cap_e = alloc.next_pow2(max(total, 2))
        lay = arena.ArenaLayout(capacity=cap_e, bump=total)

        wgt_src = c.wgt if c.wgt is not None else np.ones(c.m, np.float32)
        dst_d, wgt_d, rows_d = _cb_ops.arena_image(
            c.offsets, c.dst, wgt_src,
            starts[: c.n], caps[: c.n], cap_e, n_cap,
            total=total, engine=engine,
        )
        exists = np.zeros(n_cap, bool)
        exists[: c.n] = True
        g = cls(
            degrees=deg,
            capacities=caps,
            starts=starts,
            exists=exists,
            layout=lay,
            n=int(c.n),
            m=int(c.m),
            dst=dst_d,
            wgt=wgt_d,
            slot_rows=rows_d,
        )
        g._refresh_occupancy()
        return g

    @classmethod
    def empty(cls, n_vertices: int = 0) -> "DiGraph":
        n_cap = alloc.reserve_size(max(n_vertices, 1))
        cap_e = 2
        exists = np.zeros(n_cap, bool)
        exists[:n_vertices] = True
        return cls(
            degrees=np.zeros(n_cap, np.int64),
            capacities=np.zeros(n_cap, np.int64),
            starts=np.full(n_cap, -1, np.int64),
            exists=exists,
            layout=arena.ArenaLayout(capacity=cap_e),
            n=n_vertices,
            m=0,
            dst=jnp.full((cap_e,), SENTINEL, jnp.int32),
            wgt=jnp.zeros((cap_e,), jnp.float32),
            slot_rows=jnp.full((cap_e,), n_cap, jnp.int32),
        )

    # ------------------------------------------------------------------
    # vertex ops (paper reserve()/addVertex())
    # ------------------------------------------------------------------
    def _reserve(self, n_needed: int) -> None:
        if n_needed <= self.cap_v:
            return
        new_cap = alloc.reserve_size(n_needed)

        def grow(a, fill):
            out = np.full(new_cap, fill, a.dtype)
            out[: a.shape[0]] = a
            return out

        self.degrees = grow(self.degrees, 0)
        self.capacities = grow(self.capacities, 0)
        self.starts = grow(self.starts, -1)
        self.exists = grow(self.exists, False)
        self.stats.record_relayout()

    def add_vertices(self, ids: np.ndarray) -> int:
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        if ids.size == 0:
            return 0
        self._reserve(int(ids.max()) + 1)
        newly = ~self.exists[ids]
        self.exists[ids] = True
        added = int(np.unique(ids[newly]).shape[0])
        self.n += added
        if added:
            self._invalidate_derived()
        return added

    # ------------------------------------------------------------------
    # occupancy bookkeeping (live vs dead slots in the bump prefix)
    # ------------------------------------------------------------------
    def _refresh_occupancy(self) -> None:
        self.stats.used_elems = int(self.m)
        self.stats.slack_elems = max(int(self.layout.bump) - int(self.m), 0)

    @property
    def live_fraction(self) -> float:
        """Fraction of the arena's bump prefix holding live edges."""
        return self.stats.live_fraction

    def _invalidate_derived(self) -> None:
        self._csr_cache = None
        self._image = None

    def _refresh_image(self, blocks=None) -> None:
        """Keep the cached shared walk image current across an update.

        The arena IS the image (``shared=True``), so after an in-place
        update only the buffer references, bump and live count change —
        re-pointing them beats rebuilding the wrap (and its device
        interval cache) every stream round.  ``blocks`` is the
        in-program-updated [lo, hi) pair from the fused dispatch (None
        drops the interval cache instead).  Vertex-set changes already
        dropped the wrap before this runs (``add_vertices`` →
        ``_invalidate_derived``; there is no vertex-removal path), so
        the only staleness left to guard is a replaced metadata array —
        an O(V) nv recount here would tax every steady-state round.
        """
        img = self._image
        if img is None:
            return
        if img.starts is not self.starts:
            self._image = None
            return
        img.dst, img.wgt, img.rows = self.dst, self.wgt, self.slot_rows
        img.bump = int(self.layout.bump)
        img.live = int(self.m)
        img._blocks = tuple(blocks) if blocks is not None else None

    # ------------------------------------------------------------------
    # the paper's core ops
    # ------------------------------------------------------------------
    @property
    def sealed(self) -> bool:
        """True while ANY device buffer is shared with a snapshot."""
        return bool(self._sealed)

    def _detach(self, *names: str) -> None:
        """Per-buffer copy-on-write (DESIGN.md §10).

        Copies ONLY the named snapshot-shared buffers (all of them when
        called bare), in one fused dispatch, and marks them private.
        """
        util.cow_detach(
            self, self._sealed, names or ("dst", "wgt", "slot_rows")
        )

    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        """Graph union G ∪ ΔG (paper Alg 8).  Returns (graph, ΔM)."""
        g, dm = self.apply(updates.plan_update(inserts=batch), inplace=inplace)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        """Graph subtraction G \\ ΔG (paper Alg 7).  Returns (graph, ΔM)."""
        g, dm = self.apply(updates.plan_update(deletes=batch), inplace=inplace)
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = True):
        """Apply a mixed delete+insert UpdatePlan in one pass (DESIGN.md §9).

        Returns ``(graph, ΔM)`` with ΔM the *net* edge-count change
        (negative when deletions dominate).  Detaching from snapshots is
        per-buffer and happens inside ``_apply_impl`` once it knows which
        buffers the batch actually writes.
        """
        plan.validate()  # corrupt plans (WAL replay) fail loudly (§13)
        g = self if inplace else self.clone()
        dm = g._apply_impl(plan, donate=True)
        return g, dm

    # -- the fused plan/apply pipeline ------------------------------------
    def _apply_impl(self, plan: updates.UpdatePlan, donate: bool) -> int:
        if plan.n_ops == 0:
            return 0
        if plan.n_ins:
            s, d, _ = plan.insert_arrays()
            self.add_vertices(np.concatenate([s, d]))

        # shared dirty-row export: drops out-of-range rows and inert runs
        sel, rows, deg_old, ins_count = plan.active_rows(
            self.degrees, self.cap_v
        )
        if sel.shape[0] == 0:
            return 0
        old_caps = self.capacities[rows]
        old_starts = self.starts[rows]

        # CP2AA grow decisions (host): rows whose insert upper bound spills
        # their class get a fresh block — the slot_update dispatch moves
        # them as part of the same program.
        ub = deg_old + ins_count
        grow = ub > old_caps
        new_caps = old_caps.copy()
        new_starts = old_starts.copy()
        if grow.any():
            g_idx = np.nonzero(grow)[0]
            need = alloc.edge_capacities(ub[grow])
            new_caps[g_idx] = need
            pending: list[int] = []
            for i, c in zip(g_idx, need):
                got = self.layout.try_alloc(int(c))
                if got is None:
                    pending.append(int(i))
                else:
                    new_starts[i] = got
            if pending:
                target = self.layout.grow_target(int(need.sum()))
                self.dst, self.wgt, self.slot_rows = _jit_grow_buffer(
                    target, self.cap_v
                )(self.dst, self.wgt, self.slot_rows)
                self._sealed.clear()  # grow copies into fresh buffers
                self.layout.capacity = target
                self.stats.record_relayout()
                for i in pending:
                    got = self.layout.try_alloc(int(new_caps[i]))
                    assert got is not None
                    new_starts[i] = got
            self.stats.record_relayout()
        else:
            self.stats.record_inplace()

        # ONE fused dispatch applies every pow-2 width group of the plan
        # (DESIGN.md §12): gather + merge per group (exact capacity
        # classes off-TPU, 128-slot tiles on TPU), then one write-back —
        # the jit launch and the host counts sync are paid once per
        # BATCH instead of once per width class.  Write-back picks the
        # cheaper of two formulations (``choose_scatter``): TPU always
        # scatters; off-TPU the full-buffer gather rebuild pays a
        # ~cap_e-proportional constant (~5ns/slot/array + the host slot
        # map) while scatters pay ~100ns per touched slot, so only a big
        # arena with a proportionally tiny batch takes the scatter path
        # (keeping small updates O(batch), not O(|E|)).  The Pallas
        # merge is only exact for ids < 2**24 (f32 one-hot matmuls), so
        # huge-vertex graphs fall back to the XLA merge.
        on_tpu = jax.default_backend() == "tpu"
        merge_backend = (
            "pallas" if on_tpu and self.cap_v < _su_ops.PALLAS_MAX_ID else "xla"
        )
        touched = int(new_caps.sum() + old_caps[grow].sum())
        use_scatter = _su_ops.choose_scatter(self.cap_e, touched)
        has_moves = bool(grow.any())
        # per-buffer COW: dst/wgt are always written; the owner map only
        # when a block moves — a sealed slot_rows stays snapshot-shared
        # through every non-moving update.
        self._detach("dst", "wgt", *(("slot_rows",) if has_moves else ()))
        groups, layout = plan.fused_groups(
            sel, rows, deg_old, grow,
            old_starts, old_caps, new_starts, new_caps,
            _su_ops.width_floor(), self.cap_v,
        )
        slot_map = owner_patch = None
        rebuild_hi = 0
        if not use_scatter:
            rebuild_hi = _su_ops.quantized_prefix(
                self.cap_e, int(self.layout.bump)
            )
            slot_map, owner_patch = _su_ops.host_patch_layout(
                layout, rows, old_starts, old_caps, new_starts, new_caps,
                grow, rebuild_hi, self.cap_v, has_moves,
            )
        # interval-cache refresh rides the same dispatch: when the shared
        # walk image has warm [lo, hi) blocks, the program updates them
        # from the merge counts and hands them back — the next walk
        # skips the host geometry rebuild entirely.
        img = self._image
        blk = (
            img._blocks
            if img is not None and img.starts is self.starts
            else None
        )
        self.dst, self.wgt, self.slot_rows, counts_list, extra = (
            _su_ops.fused_apply(
                self.dst, self.wgt, self.slot_rows, groups,
                scatter=use_scatter, backend=merge_backend, donate=donate,
                slot_map=slot_map, owner_patch=owner_patch,
                rebuild_hi=rebuild_hi,
                lo=blk[0] if blk is not None else None,
                hi=blk[1] if blk is not None else None,
            )
        )
        net = 0
        for (_wv, gsel, _a), counts in zip(layout, counts_list):
            counts = np.asarray(counts, dtype=np.int64)[: gsel.shape[0]]
            self.degrees[rows[gsel]] = counts
            net += int(counts.sum() - deg_old[gsel].sum())

        # free vacated blocks, install the new geometry
        if has_moves:
            for st, cp in zip(old_starts[grow], old_caps[grow]):
                if cp > 0 and st >= 0:
                    self.layout.free(int(st), int(cp))
            self.starts[rows] = new_starts
            self.capacities[rows] = new_caps
        self.m += net
        self._csr_cache = None
        # the shared walk image tracks the arena in place
        self._refresh_image(extra if blk is not None else None)
        self._refresh_occupancy()
        return net

    # ------------------------------------------------------------------
    # block compaction (DESIGN.md §7)
    # ------------------------------------------------------------------
    def compact(self) -> int:
        """Repack every live block into a dense arena prefix.

        Heavy deletions leave dead SENTINEL slots (and freed/oversized
        blocks) inside the bump prefix; traversal tiles then burn MXU lanes
        on padding.  This pass re-derives minimal CP2AA capacity classes
        from the current degrees, gathers all live edges into a fresh
        pow-2 buffer in one jitted pass, and resets the arena.  Returns
        the number of slots reclaimed from the traversal prefix.
        """
        live = np.nonzero(self.degrees > 0)[0]
        deg = self.degrees[live]
        new_caps = alloc.edge_capacities(deg) if live.size else np.zeros(0, np.int64)
        csum = np.cumsum(new_caps) if live.size else np.zeros(0, np.int64)
        new_starts = csum - new_caps
        total = int(csum[-1]) if live.size else 0
        new_cap_e = alloc.next_pow2(max(total, 2))
        old_bump = int(self.layout.bump)

        m = int(deg.sum())
        if m:
            dcs = np.cumsum(deg)
            off = np.arange(m, dtype=np.int64) - np.repeat(dcs - deg, deg)
            src_idx = (np.repeat(self.starts[live], deg) + off).astype(np.int32)
            dst_idx = (np.repeat(new_starts, deg) + off).astype(np.int32)
        else:
            src_idx = np.zeros(0, np.int32)
            dst_idx = np.zeros(0, np.int32)
        self.dst, self.wgt = _jit_compact(new_cap_e)(
            self.dst,
            self.wgt,
            jnp.asarray(_pad_pow2(src_idx, 0)),
            jnp.asarray(_pad_pow2(dst_idx, new_cap_e)),
        )
        slot_rows = np.full(new_cap_e, self.cap_v, np.int32)
        if total:
            slot_rows[:total] = np.repeat(live.astype(np.int32), new_caps)
        self.slot_rows = jnp.asarray(slot_rows)

        self.capacities[:] = 0
        self.capacities[live] = new_caps
        self.starts[:] = -1
        self.starts[live] = new_starts
        self.layout = arena.ArenaLayout(capacity=new_cap_e, bump=total)
        self._sealed.clear()  # fresh buffers: snapshots keep the old payload
        self.stats.record_relayout()
        self._refresh_occupancy()
        self._invalidate_derived()
        return old_bump - total

    def maybe_compact(self, threshold: float = COMPACT_THRESHOLD) -> bool:
        """Compact iff dead slots dominate the bump prefix (DESIGN.md §7)."""
        bump = int(self.layout.bump)
        if bump < COMPACT_MIN_SLOTS or self.m >= threshold * bump:
            return False
        self.compact()
        return True

    # ------------------------------------------------------------------
    # cloning / snapshots / export (paper Alg 6)
    # ------------------------------------------------------------------
    def clone(self) -> "DiGraph":
        """Deep copy in ONE fused async device dispatch (DESIGN.md §10).

        The seed issued three ``jnp.array(copy=True)`` dispatches (each a
        synchronous transfer-queue round-trip); ``util.fused_copy`` runs
        a single jitted program that copies all three payload buffers and
        returns without blocking — the clone is usable immediately and
        only synchronizes when first read.
        """
        dst, wgt, slot_rows = util.fused_copy(self.dst, self.wgt, self.slot_rows)
        g = DiGraph(
            degrees=self.degrees.copy(),
            capacities=self.capacities.copy(),
            starts=self.starts.copy(),
            exists=self.exists.copy(),
            layout=self.layout.clone(),
            n=self.n,
            m=self.m,
            dst=dst,
            wgt=wgt,
            slot_rows=slot_rows,
        )
        g._refresh_occupancy()  # clone starts with fresh stats
        return g

    def snapshot(self) -> "DiGraph":
        """O(1) device-cost snapshot: shares payload, seals both handles.

        The next in-place update on either handle pays a detach copy of
        ONLY the buffers it writes (per-buffer COW) — JAX immutability
        gives Aspen-style snapshots for free as long as donation is
        suspended on shared buffers (DESIGN.md §2/§10).
        """
        self._sealed = {"dst", "wgt", "slot_rows"}
        return dataclasses.replace(
            self,
            degrees=self.degrees.copy(),
            capacities=self.capacities.copy(),
            starts=self.starts.copy(),
            exists=self.exists.copy(),
            layout=self.layout.clone(),
            stats=dataclasses.replace(self.stats),
            _sealed={"dst", "wgt", "slot_rows"},
            _image=None,  # the image aliases THIS handle's host metadata
        )

    # -- durable state (checkpoint/restore, DESIGN.md §13) ---------------
    def state_tree(self) -> dict:
        """Flat array dict of the FULL canonical state — bit-exact restore.

        Includes the arena geometry (bump pointer and the free lists in
        their stack order): a restored graph must hand out the same
        blocks the original would have, or replayed updates diverge from
        the uncrashed twin at the first grow.
        """
        lay = self.layout
        sizes = sorted(k for k, v in lay.freed.items() if v)
        return {
            "degrees": self.degrees.copy(),
            "capacities": self.capacities.copy(),
            "starts": self.starts.copy(),
            "exists": self.exists.copy(),
            "dst": np.asarray(self.dst),
            "wgt": np.asarray(self.wgt),
            "slot_rows": np.asarray(self.slot_rows),
            "n": np.int64(self.n),
            "m": np.int64(self.m),
            "arena/capacity": np.int64(lay.capacity),
            "arena/bump": np.int64(lay.bump),
            "arena/freed_sizes": np.asarray(sizes, np.int64),
            "arena/freed_counts": np.asarray(
                [len(lay.freed[s]) for s in sizes], np.int64
            ),
            "arena/freed_starts": np.asarray(
                [st for s in sizes for st in lay.freed[s]], np.int64
            ),
        }

    @classmethod
    def from_state_tree(cls, t: dict) -> "DiGraph":
        lay = arena.ArenaLayout(
            capacity=int(t["arena/capacity"]), bump=int(t["arena/bump"])
        )
        at = 0
        starts_f = np.asarray(t["arena/freed_starts"], np.int64)
        for s, c in zip(
            np.asarray(t["arena/freed_sizes"], np.int64).tolist(),
            np.asarray(t["arena/freed_counts"], np.int64).tolist(),
        ):
            lay.freed[int(s)] = [int(x) for x in starts_f[at:at + c]]
            at += c
        g = cls(
            degrees=np.asarray(t["degrees"], np.int64).copy(),
            capacities=np.asarray(t["capacities"], np.int64).copy(),
            starts=np.asarray(t["starts"], np.int64).copy(),
            exists=np.asarray(t["exists"], bool).copy(),
            layout=lay,
            n=int(t["n"]),
            m=int(t["m"]),
            dst=jnp.asarray(t["dst"]),
            wgt=jnp.asarray(t["wgt"]),
            slot_rows=jnp.asarray(t["slot_rows"]),
        )
        g._refresh_occupancy()
        return g

    def to_csr(self) -> csr_mod.CSR:
        """Compact CSR export, memoized until the next mutation."""
        if self._csr_cache is None:
            self._csr_cache = self._build_csr()
        return self._csr_cache

    def _build_csr(self) -> csr_mod.CSR:
        nv = self.n_max_vertex() + 1
        deg = self.degrees[:nv]
        total = int(deg.sum())
        offsets = np.zeros(nv + 1, np.int64)
        np.cumsum(deg, out=offsets[1:])
        if total:
            gidx = np.repeat(self.starts[:nv].clip(0), deg) + (
                np.arange(total) - np.repeat(offsets[:-1], deg)
            )
            dsel = jnp.asarray(self.dst)[jnp.asarray(gidx)]
            wsel = jnp.asarray(self.wgt)[jnp.asarray(gidx)]
        else:
            dsel = jnp.zeros((0,), jnp.int32)
            wsel = jnp.zeros((0,), jnp.float32)
        return csr_mod.CSR(
            offsets=jnp.asarray(offsets, jnp.int32),
            dst=dsel,
            wgt=wsel,
            n=nv,
            m=total,
        )

    def to_walk_image(self) -> walk_image.WalkImage:
        """The canonical traversal image (DESIGN.md §11) — zero-cost here.

        The arena *is* the image: the wrap shares the device payload and
        host block metadata (``shared=True``), so building it moves no
        data.  The rep's own update engine keeps the buffers current;
        any mutation drops the cached wrap via ``_invalidate_derived``.
        """
        if self._image is None:
            nv = self.n_max_vertex() + 1
            self._image = walk_image.WalkImage.from_blocks(
                self.dst, self.wgt, self.slot_rows,
                self.starts, self.capacities, self.degrees,
                nv, int(self.layout.bump), int(self.m), shared=True,
            )
        return self._image

    def walk_occupancy(self) -> float:
        """Live-edge fraction of the walk image's slot prefix."""
        return self.to_walk_image().occupancy

    def reverse_walk(
        self,
        steps: int,
        *,
        backend: str = "auto",
        auto_compact: bool = True,
        interpret: bool = False,
        visits0: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Paper Alg 13 via the walk-image layer (DESIGN.md §6/§11).

        Only the arena's bump prefix (quantized) is walked, and when
        dead slots dominate after heavy deletions the blocks are first
        compacted so traversal tiles stay dense (``auto_compact``).
        ``visits0`` [B, V] batches B walks through one fused step loop.
        """
        if auto_compact:
            self.maybe_compact()
        return self.to_walk_image().walk(
            steps, backend=backend, interpret=interpret, visits0=visits0
        )

    def n_max_vertex(self) -> int:
        nz = np.nonzero(self.exists)[0]
        return int(nz[-1]) if nz.size else -1

    def to_edge_sets(self) -> list[set[int]]:
        c = self.to_csr()
        return c.to_edge_sets()
