"""DiGraph — the paper's representation (Alg 1/2) adapted to TPU/XLA.

Layout (SoA, DESIGN.md §2):
  host metadata : degrees / capacities / block starts / exists  (numpy)
  device payload: dst[CAP_E] int32, wgt[CAP_E] f32, slot_rows[CAP_E] int32

Each vertex owns a contiguous *block* of edge slots whose size is a CP2AA
power-of-2 class (``alloc.edge_capacity``).  Blocks are handed out by the
host-side ``ArenaLayout`` (free lists + bump pointer) over one flat device
buffer.  Rows are ascending with SENTINEL padding, so:

  * membership/insert position = windowed binary search (device),
  * batch insert  = scatter into slack + per-class row sort   (paper setUnion,
    O(d_u + Δd_u) per touched row),
  * batch delete  = scatter SENTINEL + per-class row sort      (setDifference),
  * growth        = block move to a bigger class (CP2AA realloc path),
  * "in-place"    = buffer donation (XLA reuses the allocation).

Capacity classes double as jit-cache buckets: every compiled shape is a
power of two, so steady-state updates never recompile.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, arena, csr as csr_mod, edgebatch, util

SENTINEL = util.SENTINEL

#: Live-slot fraction of the arena bump prefix below which traversal-time
#: auto-compaction kicks in (DESIGN.md §7).
COMPACT_THRESHOLD = 0.5
#: Don't bother compacting arenas smaller than this many slots.
COMPACT_MIN_SLOTS = 4 * 128


# ---------------------------------------------------------------------------
# jitted device helpers (module level, cached per static shape)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jit_move_blocks(w_old: int, w_new: int, donate: bool):
    def fn(dst, wgt, slot_rows, old_starts, new_starts, rows, deg, old_caps):
        # gather old rows (width w_old), write into new blocks (width w_new)
        a = old_starts.shape[0]
        lane_o = jnp.arange(w_old, dtype=jnp.int32)[None, :]
        lane_n = jnp.arange(w_new, dtype=jnp.int32)[None, :]
        valid = old_starts[:, None] >= 0
        src_idx = jnp.clip(old_starts[:, None] + lane_o, 0, dst.shape[0] - 1)
        row_d = jnp.where(
            valid & (lane_o < deg[:, None]), dst[src_idx], SENTINEL
        )
        row_w = jnp.where(valid & (lane_o < deg[:, None]), wgt[src_idx], 0.0)
        # sentinel-fill the old region first (freed block must read empty);
        # each row fills only its OWN old capacity — w_old is the group max.
        old_flat = jnp.where(
            valid & (lane_o < old_caps[:, None]),
            old_starts[:, None] + lane_o,
            dst.shape[0],
        ).reshape(-1)
        dst = dst.at[old_flat].set(SENTINEL, mode="drop")
        # scatter into the new region
        ok = new_starts[:, None] >= 0
        new_flat = jnp.where(ok, new_starts[:, None] + lane_n, dst.shape[0]).reshape(-1)
        pad_d = jnp.full((a, w_new), SENTINEL, jnp.int32).at[:, :w_old].set(row_d)
        pad_w = jnp.zeros((a, w_new), jnp.float32).at[:, :w_old].set(row_w)
        dst = dst.at[new_flat].set(pad_d.reshape(-1), mode="drop")
        wgt = wgt.at[new_flat].set(pad_w.reshape(-1), mode="drop")
        slot_rows = slot_rows.at[new_flat].set(
            jnp.broadcast_to(rows[:, None], (a, w_new)).reshape(-1), mode="drop"
        )
        return dst, wgt, slot_rows

    return jax.jit(fn, donate_argnums=(0, 1, 2) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_insert_chain(num_rows: int, donate: bool):
    """Fused insert program: lookup + rank + scatter + per-row counts.

    One dispatch per batch instead of the seed's four-hop micro-dispatch
    chain (lookup → ranks → apply → counts).  Query arrays are pow-2
    padded by the caller (pad ``qd`` = SENTINEL, pad windows empty) so the
    jit cache stays O(log B); ``num_rows`` is the pow-2-padded segment
    count.
    """

    def fn(dst, wgt, lo, hi, qd, qw, row_first, row_ids):
        pos, found = util.binsearch_window(dst, lo, hi, qd)
        nf = ((~found) & (qd != SENTINEL)).astype(jnp.int32)
        c = jnp.cumsum(nf)
        excl = c - nf  # exclusive cumsum
        ranks = excl - excl[row_first]  # rank among this row's new edges
        ins_pos = hi + ranks  # hi == row start + degree == first free slot
        oob = dst.shape[0]
        upd_pos = jnp.where(found, pos, oob)          # weight upsert
        wgt = wgt.at[upd_pos].set(qw, mode="drop")
        new_pos = jnp.where(nf == 0, oob, ins_pos)
        dst = dst.at[new_pos].set(qd, mode="drop")
        wgt = wgt.at[new_pos].set(qw, mode="drop")
        nf_counts = jax.ops.segment_sum(nf, row_ids, num_segments=num_rows)
        return dst, wgt, nf_counts

    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_delete_chain(num_rows: int, donate: bool):
    """Fused delete program: lookup + SENTINEL scatter + per-row counts."""

    def fn(dst, lo, hi, qd, row_ids):
        pos, found = util.binsearch_window(dst, lo, hi, qd)
        oob = dst.shape[0]
        del_pos = jnp.where(found, pos, oob)
        dst = dst.at[del_pos].set(SENTINEL, mode="drop")
        del_counts = jax.ops.segment_sum(
            found.astype(jnp.int32), row_ids, num_segments=num_rows
        )
        return dst, del_counts

    return jax.jit(fn, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_compact(cap_e: int):
    """Gather every live edge into a freshly packed buffer (DESIGN.md §7).

    ``src_idx``/``dst_idx`` are host-computed per-edge moves, pow-2 padded
    (pad src clipped, pad dst out-of-bounds so it drops).  A fresh target
    buffer makes the pass order-free — no aliasing hazards from moving
    blocks left within one buffer.
    """

    def fn(dst, wgt, src_idx, dst_idx):
        safe = jnp.clip(src_idx, 0, dst.shape[0] - 1)
        nd = jnp.full((cap_e,), SENTINEL, jnp.int32).at[dst_idx].set(
            dst[safe], mode="drop"
        )
        nw = jnp.zeros((cap_e,), jnp.float32).at[dst_idx].set(
            wgt[safe], mode="drop"
        )
        return nd, nw

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_sort_rows(width: int, donate: bool):
    def fn(dst, wgt, starts):
        lane = jnp.arange(width, dtype=jnp.int32)[None, :]
        valid = starts[:, None] >= 0
        idx = jnp.where(valid, starts[:, None] + lane, dst.shape[0])
        safe = jnp.clip(idx, 0, dst.shape[0] - 1)
        keys = jnp.where(valid, dst[safe], SENTINEL)
        vals = wgt[safe]
        order = jnp.argsort(keys, axis=1, stable=True)
        keys = jnp.take_along_axis(keys, order, axis=1)
        vals = jnp.take_along_axis(vals, order, axis=1)
        flat = idx.reshape(-1)
        dst = dst.at[flat].set(keys.reshape(-1), mode="drop")
        wgt = wgt.at[flat].set(vals.reshape(-1), mode="drop")
        return dst, wgt

    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_grow_buffer(new_cap: int, cap_v: int):
    def fn(dst, wgt, slot_rows):
        nd = jnp.full((new_cap,), SENTINEL, jnp.int32).at[: dst.shape[0]].set(dst)
        nw = jnp.zeros((new_cap,), jnp.float32).at[: wgt.shape[0]].set(wgt)
        nr = (
            jnp.full((new_cap,), cap_v, jnp.int32)
            .at[: slot_rows.shape[0]]
            .set(slot_rows)
        )
        return nd, nw, nr

    return jax.jit(fn)


def _pad_pow2(a: np.ndarray, fill) -> np.ndarray:
    cap = alloc.next_pow2(max(a.shape[0], 1))
    if cap == a.shape[0]:
        return a
    return np.concatenate([a, np.full(cap - a.shape[0], fill, a.dtype)])


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DiGraph:
    """Mutable host handle around immutable device payloads."""

    # host metadata
    degrees: np.ndarray        # int64 [CAP_V]
    capacities: np.ndarray     # int64 [CAP_V]  (0 = no block)
    starts: np.ndarray         # int64 [CAP_V]  (-1 = no block)
    exists: np.ndarray         # bool  [CAP_V]
    layout: arena.ArenaLayout
    n: int
    m: int
    # device payload
    dst: jnp.ndarray
    wgt: jnp.ndarray
    slot_rows: jnp.ndarray
    stats: alloc.AllocStats = dataclasses.field(default_factory=alloc.AllocStats)
    # seal-on-snapshot: while True, a snapshot shares the device payload and
    # the next in-place mutation pays one detach copy before donating again.
    sealed: bool = False
    # memoized derived views; any mutation resets them to None.
    _csr_cache: Optional[csr_mod.CSR] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _blocks_cache: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------------
    @property
    def cap_v(self) -> int:
        return self.degrees.shape[0]

    @property
    def cap_e(self) -> int:
        return int(self.dst.shape[0])

    def has_vertex(self, u: int) -> bool:
        return 0 <= u < self.cap_v and bool(self.exists[u])

    def degree(self, u: int) -> int:
        return int(self.degrees[u]) if u < self.cap_v else 0

    def edges_of(self, u: int) -> np.ndarray:
        if u >= self.cap_v or self.starts[u] < 0:
            return np.empty((0,), np.int32)
        s, d = int(self.starts[u]), int(self.degrees[u])
        return np.asarray(self.dst[s : s + d])

    def block_on(self) -> None:
        self.dst.block_until_ready()

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "DiGraph":
        degrees = np.asarray(c.degrees, dtype=np.int64)
        n_cap = alloc.reserve_size(c.n)
        deg = np.zeros(n_cap, np.int64)
        deg[: c.n] = degrees
        caps = np.zeros(n_cap, np.int64)
        caps[: c.n] = np.where(degrees > 0, alloc.edge_capacities(degrees), 0)
        starts = np.full(n_cap, -1, np.int64)
        csum = np.zeros(c.n, np.int64)
        np.cumsum(caps[: c.n], out=csum)
        starts[: c.n] = np.where(caps[: c.n] > 0, csum - caps[: c.n], -1)
        total = int(csum[-1]) if c.n else 0
        cap_e = alloc.next_pow2(max(total, 2))
        lay = arena.ArenaLayout(capacity=cap_e, bump=total)

        # device fill
        gidx = np.repeat(starts[: c.n].clip(0), degrees) + (
            np.arange(c.m) - np.repeat(np.asarray(c.offsets)[:-1], degrees)
        )
        dst = np.full(cap_e, SENTINEL, np.int32)
        dst[gidx] = np.asarray(c.dst)
        wgt = np.zeros(cap_e, np.float32)
        wgt[gidx] = (
            np.asarray(c.wgt) if c.wgt is not None else np.ones(c.m, np.float32)
        )
        slot_rows = np.full(cap_e, n_cap, np.int32)
        row_of_block = np.repeat(
            np.arange(c.n, dtype=np.int32), caps[: c.n].astype(np.int64)
        )
        slot_rows[:total] = row_of_block
        exists = np.zeros(n_cap, bool)
        exists[: c.n] = True
        g = cls(
            degrees=deg,
            capacities=caps,
            starts=starts,
            exists=exists,
            layout=lay,
            n=int(c.n),
            m=int(c.m),
            dst=jnp.asarray(dst),
            wgt=jnp.asarray(wgt),
            slot_rows=jnp.asarray(slot_rows),
        )
        g._refresh_occupancy()
        return g

    @classmethod
    def empty(cls, n_vertices: int = 0) -> "DiGraph":
        n_cap = alloc.reserve_size(max(n_vertices, 1))
        cap_e = 2
        exists = np.zeros(n_cap, bool)
        exists[:n_vertices] = True
        return cls(
            degrees=np.zeros(n_cap, np.int64),
            capacities=np.zeros(n_cap, np.int64),
            starts=np.full(n_cap, -1, np.int64),
            exists=exists,
            layout=arena.ArenaLayout(capacity=cap_e),
            n=n_vertices,
            m=0,
            dst=jnp.full((cap_e,), SENTINEL, jnp.int32),
            wgt=jnp.zeros((cap_e,), jnp.float32),
            slot_rows=jnp.full((cap_e,), n_cap, jnp.int32),
        )

    # ------------------------------------------------------------------
    # vertex ops (paper reserve()/addVertex())
    # ------------------------------------------------------------------
    def _reserve(self, n_needed: int) -> None:
        if n_needed <= self.cap_v:
            return
        new_cap = alloc.reserve_size(n_needed)

        def grow(a, fill):
            out = np.full(new_cap, fill, a.dtype)
            out[: a.shape[0]] = a
            return out

        self.degrees = grow(self.degrees, 0)
        self.capacities = grow(self.capacities, 0)
        self.starts = grow(self.starts, -1)
        self.exists = grow(self.exists, False)
        self.stats.record_relayout()

    def add_vertices(self, ids: np.ndarray) -> int:
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        if ids.size == 0:
            return 0
        self._reserve(int(ids.max()) + 1)
        newly = ~self.exists[ids]
        self.exists[ids] = True
        added = int(np.unique(ids[newly]).shape[0])
        self.n += added
        if added:
            self._invalidate_derived()
        return added

    # ------------------------------------------------------------------
    # occupancy bookkeeping (live vs dead slots in the bump prefix)
    # ------------------------------------------------------------------
    def _refresh_occupancy(self) -> None:
        self.stats.used_elems = int(self.m)
        self.stats.slack_elems = max(int(self.layout.bump) - int(self.m), 0)

    @property
    def live_fraction(self) -> float:
        """Fraction of the arena's bump prefix holding live edges."""
        return self.stats.live_fraction

    def _invalidate_derived(self) -> None:
        self._csr_cache = None
        self._blocks_cache = None

    # ------------------------------------------------------------------
    # the paper's core ops
    # ------------------------------------------------------------------
    def _detach(self) -> None:
        if not self.sealed:
            return
        self.dst = jnp.array(self.dst, copy=True)
        self.wgt = jnp.array(self.wgt, copy=True)
        self.slot_rows = jnp.array(self.slot_rows, copy=True)
        self.sealed = False

    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        """Graph union G ∪ ΔG (paper Alg 8).  Returns (graph, ΔM)."""
        g = self if inplace else self.clone()
        g._detach()
        dm = g._add_edges_impl(batch, donate=True)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        """Graph subtraction G \\ ΔG (paper Alg 7).  Returns (graph, ΔM)."""
        g = self if inplace else self.clone()
        g._detach()
        dm = g._remove_edges_impl(batch, donate=True)
        return g, dm

    # -- insertion ------------------------------------------------------
    def _add_edges_impl(self, batch: edgebatch.EdgeBatch, donate: bool) -> int:
        if batch.n == 0:
            return 0
        s, d, w = batch.to_numpy()
        self.add_vertices(np.concatenate([s, d]))

        rows, first_idx, counts = np.unique(s, return_index=True, return_counts=True)
        rows64 = rows.astype(np.int64)
        deg_old = self.degrees[rows64]
        ub = deg_old + counts
        need = alloc.edge_capacities(ub)
        grow_mask = need > self.capacities[rows64]

        if grow_mask.any():
            self._grow_blocks(rows64[grow_mask], need[grow_mask], donate)
        else:
            self.stats.record_inplace()

        # fused lookup + rank + scatter + count (one dispatch, DESIGN.md §2)
        lo = self.starts[s.astype(np.int64)]
        lo = np.where(lo < 0, 0, lo)
        hi = lo + self.degrees[s.astype(np.int64)]
        row_first = np.repeat(first_idx, counts).astype(np.int32)
        row_ids = np.repeat(np.arange(rows.shape[0], dtype=np.int32), counts)
        nr_pad = alloc.next_pow2(max(rows.shape[0], 1))

        self.dst, self.wgt, nf_counts = _jit_insert_chain(nr_pad, donate)(
            self.dst,
            self.wgt,
            jnp.asarray(_pad_pow2(lo.astype(np.int32), 0)),
            jnp.asarray(_pad_pow2(hi.astype(np.int32), 0)),
            jnp.asarray(_pad_pow2(d.astype(np.int32), SENTINEL)),
            jnp.asarray(_pad_pow2(w.astype(np.float32), 0.0)),
            jnp.asarray(_pad_pow2(row_first, 0)),
            jnp.asarray(_pad_pow2(row_ids, 0)),
        )
        nf_counts = np.asarray(nf_counts, dtype=np.int64)[: rows.shape[0]]
        self.degrees[rows64] += nf_counts
        dm = int(nf_counts.sum())
        self.m += dm
        self._invalidate_derived()
        self._refresh_occupancy()

        # restore sorted rows per capacity class
        self._sort_dirty_rows(rows64[nf_counts > 0], donate)
        return dm

    # -- deletion ---------------------------------------------------------
    def _remove_edges_impl(self, batch: edgebatch.EdgeBatch, donate: bool) -> int:
        if batch.n == 0:
            return 0
        s, d, _ = batch.to_numpy()
        in_range = s < self.cap_v
        s, d = s[in_range], d[in_range]
        if s.shape[0] == 0:
            return 0
        rows, first_idx, counts = np.unique(s, return_index=True, return_counts=True)
        rows64 = rows.astype(np.int64)

        lo = self.starts[s.astype(np.int64)]
        lo = np.where(lo < 0, 0, lo)
        hi = np.where(
            self.starts[s.astype(np.int64)] < 0,
            0,
            lo + self.degrees[s.astype(np.int64)],
        )
        row_ids = np.repeat(np.arange(rows.shape[0], dtype=np.int32), counts)
        nr_pad = alloc.next_pow2(max(rows.shape[0], 1))
        self.dst, del_counts = _jit_delete_chain(nr_pad, donate)(
            self.dst,
            jnp.asarray(_pad_pow2(lo.astype(np.int32), 0)),
            jnp.asarray(_pad_pow2(hi.astype(np.int32), 0)),
            jnp.asarray(_pad_pow2(d.astype(np.int32), SENTINEL)),
            jnp.asarray(_pad_pow2(row_ids, 0)),
        )
        del_counts = np.asarray(del_counts, dtype=np.int64)[: rows.shape[0]]
        self.degrees[rows64] -= del_counts
        dm = int(del_counts.sum())
        self.m -= dm
        self._invalidate_derived()
        self._refresh_occupancy()
        self._sort_dirty_rows(rows64[del_counts > 0], donate)
        self.stats.record_inplace()
        return dm

    # -- block growth (CP2AA realloc path) -------------------------------
    def _grow_blocks(self, rows: np.ndarray, new_caps: np.ndarray, donate: bool) -> None:
        # ensure pool space, regrow device buffer if the arena is exhausted
        demand = int(new_caps.sum())
        new_starts = np.empty(rows.shape[0], np.int64)
        pending: list[int] = []
        for i, (r, c) in enumerate(zip(rows, new_caps)):
            got = self.layout.try_alloc(int(c))
            if got is None:
                pending.append(i)
                new_starts[i] = -1
            else:
                new_starts[i] = got
        if pending:
            target = self.layout.grow_target(demand)
            self.dst, self.wgt, self.slot_rows = _jit_grow_buffer(
                target, self.cap_v
            )(self.dst, self.wgt, self.slot_rows)
            self.layout.capacity = target
            self.stats.record_relayout()
            for i in pending:
                got = self.layout.try_alloc(int(new_caps[i]))
                assert got is not None
                new_starts[i] = got

        # group moves by (old-class, new-class) so jit shapes stay pow-2
        old_caps = self.capacities[rows]
        for w_new in np.unique(new_caps):
            sel = new_caps == w_new
            r_sel = rows[sel]
            w_old = int(old_caps[sel].max()) if sel.any() else 0
            w_old = int(min(max(w_old, 0), w_new))
            a_pad = alloc.next_pow2(max(r_sel.shape[0], 1))
            os_ = _pad_pow2(self.starts[r_sel].astype(np.int32), -1)[:a_pad]
            ns_ = _pad_pow2(new_starts[sel].astype(np.int32), -1)[:a_pad]
            rr = _pad_pow2(r_sel.astype(np.int32), self.cap_v)[:a_pad]
            dg = _pad_pow2(self.degrees[r_sel].astype(np.int32), 0)[:a_pad]
            oc_ = _pad_pow2(old_caps[sel].astype(np.int32), 0)[:a_pad]
            self.dst, self.wgt, self.slot_rows = _jit_move_blocks(
                max(w_old, 1) if w_old else 1, int(w_new), donate
            )(
                self.dst,
                self.wgt,
                self.slot_rows,
                jnp.asarray(os_),
                jnp.asarray(ns_),
                jnp.asarray(rr),
                jnp.asarray(dg),
                jnp.asarray(oc_),
            )

        # free old blocks, install new ones
        for r, ns, nc in zip(rows, new_starts, new_caps):
            oc, ost = int(self.capacities[r]), int(self.starts[r])
            if oc > 0 and ost >= 0:
                self.layout.free(ost, oc)
            self.starts[r] = ns
            self.capacities[r] = nc
        self.stats.record_relayout()

    # -- row re-sort ------------------------------------------------------
    def _sort_dirty_rows(self, rows: np.ndarray, donate: bool) -> None:
        if rows.shape[0] == 0:
            return
        caps = self.capacities[rows]
        for c in np.unique(caps):
            sel = caps == c
            r_sel = rows[sel]
            a_pad = alloc.next_pow2(max(r_sel.shape[0], 1))
            st = _pad_pow2(self.starts[r_sel].astype(np.int32), -1)[:a_pad]
            self.dst, self.wgt = _jit_sort_rows(int(c), donate)(
                self.dst, self.wgt, jnp.asarray(st)
            )

    # ------------------------------------------------------------------
    # block compaction (DESIGN.md §7)
    # ------------------------------------------------------------------
    def compact(self) -> int:
        """Repack every live block into a dense arena prefix.

        Heavy deletions leave dead SENTINEL slots (and freed/oversized
        blocks) inside the bump prefix; traversal tiles then burn MXU lanes
        on padding.  This pass re-derives minimal CP2AA capacity classes
        from the current degrees, gathers all live edges into a fresh
        pow-2 buffer in one jitted pass, and resets the arena.  Returns
        the number of slots reclaimed from the traversal prefix.
        """
        live = np.nonzero(self.degrees > 0)[0]
        deg = self.degrees[live]
        new_caps = alloc.edge_capacities(deg) if live.size else np.zeros(0, np.int64)
        csum = np.cumsum(new_caps) if live.size else np.zeros(0, np.int64)
        new_starts = csum - new_caps
        total = int(csum[-1]) if live.size else 0
        new_cap_e = alloc.next_pow2(max(total, 2))
        old_bump = int(self.layout.bump)

        m = int(deg.sum())
        if m:
            dcs = np.cumsum(deg)
            off = np.arange(m, dtype=np.int64) - np.repeat(dcs - deg, deg)
            src_idx = (np.repeat(self.starts[live], deg) + off).astype(np.int32)
            dst_idx = (np.repeat(new_starts, deg) + off).astype(np.int32)
        else:
            src_idx = np.zeros(0, np.int32)
            dst_idx = np.zeros(0, np.int32)
        self.dst, self.wgt = _jit_compact(new_cap_e)(
            self.dst,
            self.wgt,
            jnp.asarray(_pad_pow2(src_idx, 0)),
            jnp.asarray(_pad_pow2(dst_idx, new_cap_e)),
        )
        slot_rows = np.full(new_cap_e, self.cap_v, np.int32)
        if total:
            slot_rows[:total] = np.repeat(live.astype(np.int32), new_caps)
        self.slot_rows = jnp.asarray(slot_rows)

        self.capacities[:] = 0
        self.capacities[live] = new_caps
        self.starts[:] = -1
        self.starts[live] = new_starts
        self.layout = arena.ArenaLayout(capacity=new_cap_e, bump=total)
        self.sealed = False  # fresh buffers: snapshots keep the old payload
        self.stats.record_relayout()
        self._refresh_occupancy()
        self._invalidate_derived()
        return old_bump - total

    def maybe_compact(self, threshold: float = COMPACT_THRESHOLD) -> bool:
        """Compact iff dead slots dominate the bump prefix (DESIGN.md §7)."""
        bump = int(self.layout.bump)
        if bump < COMPACT_MIN_SLOTS or self.m >= threshold * bump:
            return False
        self.compact()
        return True

    # ------------------------------------------------------------------
    # cloning / snapshots / export (paper Alg 6)
    # ------------------------------------------------------------------
    def clone(self) -> "DiGraph":
        """Deep copy — device buffers copied, layout preserved."""
        g = DiGraph(
            degrees=self.degrees.copy(),
            capacities=self.capacities.copy(),
            starts=self.starts.copy(),
            exists=self.exists.copy(),
            layout=self.layout.clone(),
            n=self.n,
            m=self.m,
            dst=jnp.array(self.dst, copy=True),
            wgt=jnp.array(self.wgt, copy=True),
            slot_rows=jnp.array(self.slot_rows, copy=True),
        )
        g._refresh_occupancy()  # clone starts with fresh stats
        return g

    def snapshot(self) -> "DiGraph":
        """O(1) device-cost snapshot: shares payload, seals both handles.

        The next in-place update on either handle pays one detach copy —
        JAX immutability gives Aspen-style snapshots for free as long as
        donation is suspended (DESIGN.md §2).
        """
        self.sealed = True
        return dataclasses.replace(
            self,
            degrees=self.degrees.copy(),
            capacities=self.capacities.copy(),
            starts=self.starts.copy(),
            exists=self.exists.copy(),
            layout=self.layout.clone(),
            stats=dataclasses.replace(self.stats),
            sealed=True,
        )

    def to_csr(self) -> csr_mod.CSR:
        """Compact CSR export, memoized until the next mutation."""
        if self._csr_cache is None:
            self._csr_cache = self._build_csr()
        return self._csr_cache

    def _build_csr(self) -> csr_mod.CSR:
        nv = self.n_max_vertex() + 1
        deg = self.degrees[:nv]
        total = int(deg.sum())
        offsets = np.zeros(nv + 1, np.int64)
        np.cumsum(deg, out=offsets[1:])
        if total:
            gidx = np.repeat(self.starts[:nv].clip(0), deg) + (
                np.arange(total) - np.repeat(offsets[:-1], deg)
            )
            dsel = jnp.asarray(self.dst)[jnp.asarray(gidx)]
            wsel = jnp.asarray(self.wgt)[jnp.asarray(gidx)]
        else:
            dsel = jnp.zeros((0,), jnp.int32)
            wsel = jnp.zeros((0,), jnp.float32)
        return csr_mod.CSR(
            offsets=jnp.asarray(offsets, jnp.int32),
            dst=dsel,
            wgt=wsel,
            n=nv,
            m=total,
        )

    def reverse_walk(
        self,
        steps: int,
        *,
        backend: str = "auto",
        auto_compact: bool = True,
        interpret: bool = False,
    ) -> jnp.ndarray:
        """Paper Alg 13 via the fused slot_walk tile engine (DESIGN.md §6).

        Only the arena's bump prefix (pow-2 rounded) is walked, and when
        dead slots dominate after heavy deletions the blocks are first
        compacted so traversal tiles stay dense (``auto_compact``).
        """
        from . import traversal

        if auto_compact:
            self.maybe_compact()
        # quantize the prefix bound so the jit cache stays bounded (<= 64
        # shapes per buffer capacity) without pow-2's up-to-2x overshoot.
        q = max(self.cap_e // 64, 128)
        edges_hi = min(-(-max(int(self.layout.bump), 1) // q) * q, self.cap_e)
        nv = self.n_max_vertex() + 1
        # block intervals feed only the off-TPU scatter-free path
        use_blocks = backend == "xla" or (
            backend == "auto" and jax.default_backend() != "tpu"
        )
        block_lo, block_hi = self._walk_blocks(nv) if use_blocks else (None, None)
        return traversal.reverse_walk_slotted(
            self.dst,
            self.slot_rows,
            steps,
            nv,
            edges_hi=edges_hi,
            backend=backend,
            block_lo=block_lo,
            block_hi=block_hi,
            interpret=interpret,
        )

    def _walk_blocks(self, nv: int):
        """Per-vertex [lo, hi) slot intervals, memoized until mutation."""
        if self._blocks_cache is None or self._blocks_cache[0] != nv:
            starts = self.starts[:nv]
            has_block = starts >= 0
            lo = np.where(has_block, starts, 0).astype(np.int32)
            hi = np.where(has_block, starts + self.degrees[:nv], 0).astype(
                np.int32
            )
            self._blocks_cache = (nv, jnp.asarray(lo), jnp.asarray(hi))
        return self._blocks_cache[1], self._blocks_cache[2]

    def n_max_vertex(self) -> int:
        nz = np.nonzero(self.exists)[0]
        return int(nz[-1]) if nz.size else -1

    def to_edge_sets(self) -> list[set[int]]:
        c = self.to_csr()
        return c.to_edge_sets()
