"""Shared jnp utilities for the graph representations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel marking empty edge slots.  INT32_MAX sorts after every valid
# vertex id, so ascending sorts push padding to the row tail for free.
SENTINEL = np.int32(np.iinfo(np.int32).max)
# GraphBLAS-style zombie marker for lazily-deleted edges (LazyCSR).
ZOMBIE = np.int32(np.iinfo(np.int32).max - 1)


@jax.jit
def _fused_copy(*arrays):
    return tuple(jnp.copy(a) for a in arrays)


def fused_copy(*arrays):
    """Deep-copy device arrays in ONE jitted dispatch (async).

    ``clone()`` paths used to issue one ``jnp.array(copy=True)`` dispatch
    per buffer; a single fused program copies a whole representation's
    payload with one launch and no host sync — the caller blocks only
    when it first reads the clone.
    """
    return _fused_copy(*arrays)


def cow_detach(obj, sealed: set, names) -> None:
    """Per-buffer copy-on-write detach (DESIGN.md §10), shared by every
    representation: copy the named snapshot-shared attribute buffers of
    ``obj`` in one fused dispatch and mark them private.  The protocol
    lives here once so the donation-discipline invariant (a sealed
    buffer is never donated) has a single implementation to audit.
    """
    need = [n for n in names if n in sealed]
    if not need:
        return
    copies = fused_copy(*(getattr(obj, n) for n in need))
    for n, c in zip(need, copies):
        setattr(obj, n, c)
        sealed.discard(n)


def lexsort2(primary: jnp.ndarray, secondary: jnp.ndarray) -> jnp.ndarray:
    """Order sorting by (primary, secondary), both int arrays.

    Two stable argsorts: sort by secondary first, then stably by primary.
    Equivalent to ``np.lexsort((secondary, primary))``.
    """
    order = jnp.argsort(secondary, stable=True)
    order = order[jnp.argsort(primary[order], stable=True)]
    return order


def dedup_sorted_rows(keys: jnp.ndarray, *values: jnp.ndarray):
    """Row-wise dedup of key-sorted 2D arrays, compacting to the left.

    ``keys``: [R, K] int32, each row ascending with SENTINEL padding.
    Duplicate keys (after the first occurrence) are replaced by SENTINEL and
    the rows re-sorted so live entries stay contiguous.  ``values`` are
    carried through the same permutation.  Returns (keys, *values, counts).
    """
    prev = jnp.concatenate(
        [jnp.full((keys.shape[0], 1), -1, keys.dtype), keys[:, :-1]], axis=1
    )
    dup = (keys == prev) | (keys == SENTINEL)
    masked = jnp.where(keys == prev, SENTINEL, keys)
    order = jnp.argsort(masked, axis=1, stable=True)
    keys_out = jnp.take_along_axis(masked, order, axis=1)
    vals_out = tuple(jnp.take_along_axis(v, order, axis=1) for v in values)
    counts = jnp.sum(keys_out != SENTINEL, axis=1).astype(jnp.int32)
    del dup
    return (keys_out, *vals_out, counts)


def rows_to_padded(
    flat_vals: jnp.ndarray,
    starts: jnp.ndarray,
    lengths: jnp.ndarray,
    width: int,
    fill,
) -> jnp.ndarray:
    """Gather variable-length row segments of a flat buffer into [R, width].

    Slots >= length are ``fill``.  Out-of-range gathers are clamped (their
    lanes are masked anyway).
    """
    idx = starts[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    mask = jnp.arange(width, dtype=jnp.int32)[None, :] < lengths[:, None]
    safe = jnp.clip(idx, 0, flat_vals.shape[0] - 1)
    vals = flat_vals[safe]
    return jnp.where(mask, vals, fill)


def scatter_padded_rows(
    flat_vals: jnp.ndarray,
    rows: jnp.ndarray,
    starts: jnp.ndarray,
    width_mask: jnp.ndarray,
) -> jnp.ndarray:
    """Scatter padded rows [R, K] back into a flat buffer at ``starts``.

    Lanes where ``width_mask`` is False are dropped (left unchanged).
    """
    k = rows.shape[1]
    idx = starts[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    idx = jnp.where(width_mask, idx, flat_vals.shape[0])  # OOB -> dropped
    return flat_vals.at[idx.reshape(-1)].set(
        rows.reshape(-1), mode="drop", unique_indices=True
    )


def searchsorted_rows(rows: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Vectorized per-row searchsorted: rows [R,K] asc, queries [R,Q]."""
    return jax.vmap(jnp.searchsorted)(rows, queries)


def row_contains(rows: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Membership of queries [R,Q] in sorted rows [R,K] -> bool [R,Q]."""
    pos = searchsorted_rows(rows, queries)
    pos = jnp.clip(pos, 0, rows.shape[1] - 1)
    found = jnp.take_along_axis(rows, pos, axis=1) == queries
    return found & (queries != SENTINEL)


def segment_sum(vals: jnp.ndarray, segment_ids: jnp.ndarray, num: int) -> jnp.ndarray:
    return jax.ops.segment_sum(vals, segment_ids, num_segments=num)


def coo_sort(src: jnp.ndarray, dst: jnp.ndarray, *values: jnp.ndarray):
    """Sort COO edges by (src, dst); carries values. Stable."""
    order = lexsort2(src, dst)
    return (src[order], dst[order], *(v[order] for v in values))


def coo_dedup_mask(src: jnp.ndarray, dst: jnp.ndarray) -> jnp.ndarray:
    """For (src,dst)-sorted COO: True where the entry is the FIRST of its key."""
    prev_same = jnp.concatenate(
        [jnp.array([False]), (src[1:] == src[:-1]) & (dst[1:] == dst[:-1])]
    )
    return ~prev_same


def binsearch_window(
    flat: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, queries: jnp.ndarray
):
    """Per-query binary search in windows of a flat sorted buffer.

    ``flat`` is ascending within each window [lo_i, hi_i).  Returns
    (pos, found): ``pos`` is the leftmost index with flat[pos] >= q (within
    the window), ``found`` whether flat[pos] == q.  Vectorized over queries
    with a fori_loop (32 steps covers int32 windows).
    """
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)

    def body(_, lh):
        l, h = lh
        mid = (l + h) // 2
        v = flat[jnp.clip(mid, 0, flat.shape[0] - 1)]
        go_right = v < queries
        l2 = jnp.where(go_right & (l < h), mid + 1, l)
        h2 = jnp.where(go_right | (l >= h), h, mid)
        return l2, h2

    l, h = jax.lax.fori_loop(0, 32, body, (lo, hi))
    pos = l
    safe = jnp.clip(pos, 0, flat.shape[0] - 1)
    found = (pos < hi) & (flat[safe] == queries)
    return pos, found


def searchsorted_2d(
    s_sorted: jnp.ndarray,
    d_sorted: jnp.ndarray,
    qs: jnp.ndarray,
    qd: jnp.ndarray,
):
    """Binary search for (qs, qd) pairs in a (src, dst)-lexsorted COO.

    Returns (pos, found) like ``binsearch_window``.
    """
    n = s_sorted.shape[0]
    lo = jnp.zeros_like(qs, dtype=jnp.int32)
    hi = jnp.full_like(qs, n, dtype=jnp.int32)

    def body(_, lh):
        l, h = lh
        mid = (l + h) // 2
        safe = jnp.clip(mid, 0, n - 1)
        ms, md = s_sorted[safe], d_sorted[safe]
        less = (ms < qs) | ((ms == qs) & (md < qd))
        l2 = jnp.where(less & (l < h), mid + 1, l)
        h2 = jnp.where(less | (l >= h), h, mid)
        return l2, h2

    l, h = jax.lax.fori_loop(0, 32, body, (lo, hi))
    safe = jnp.clip(l, 0, n - 1)
    found = (l < n) & (s_sorted[safe] == qs) & (d_sorted[safe] == qd)
    return l, found


def expand_rows(offsets: jnp.ndarray, total: int) -> jnp.ndarray:
    """CSR offsets [N+1] -> row id per edge slot [total] (searchsorted trick)."""
    return (
        jnp.searchsorted(
            offsets, jnp.arange(total, dtype=offsets.dtype), side="right"
        ).astype(jnp.int32)
        - 1
    )
