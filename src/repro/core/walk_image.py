"""WalkImage — the universal traversal-image layer (DESIGN.md §11).

Every representation lowers to ONE canonical device traversal image: a
packed edge buffer (``dst``/``wgt``/``rows``, SENTINEL on dead slots)
plus per-vertex ``[lo, hi)`` block intervals — exactly the operand set
the fused ``kernels/slot_walk`` engine consumes (§6).  The image is
**incrementally maintained** under update streams instead of being
re-materialized per walk:

  * representations *queue* each applied ``UpdatePlan`` on their cached
    image (``queue``), and the next walk *flushes* the queue by patching
    touched rows in place (``flush`` → ``_patch_one``) through the same
    fused ``kernels/slot_update`` merge the DiGraph arena uses — so an
    interleaved update/walk stream pays O(batch) per round, never a full
    image rebuild, and walks keep hitting warm jit shapes;
  * rows are laid out in CP2AA slack-padded blocks (``alloc.edge_
    capacities``); a row that outgrows its slack relocates to a fresh
    block at the image's bump pointer inside the same fused dispatch;
  * the patch path falls back to a full rebuild (returning ``False`` so
    the owner drops its cache) only when the bump slack is exhausted,
    the vertex set grows, or the queue got too deep to be worth
    replaying (``MAX_PENDING``).

``DiGraph`` is the degenerate case: its arena *is* the image, so
``shared=True`` wraps the live buffers zero-copy and the rep's own
update engine keeps them current (shared images never patch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, util

SENTINEL = util.SENTINEL

#: Queue depth beyond which replaying patches is judged worse than one
#: rebuild (each pending plan costs a fused dispatch per width group).
MAX_PENDING = 32
#: Fraction of the BUILD-TIME occupancy below which a flush demands a
#: rebuild instead of further patching — the image-level analogue of
#: DiGraph's traversal-time compaction (§7): dead slots from relocated /
#: deleted rows otherwise accumulate in the walked prefix forever.  The
#: trigger is relative to the layout's own slack (ChunkedGraph's PAGE
#: quantization builds at ~0.3 occupancy; rebuilding can never beat
#: that), so it fires only when a rebuild would actually densify.
COMPACT_THRESHOLD = 0.5
#: Don't bother occupancy-rebuilding images smaller than this.
COMPACT_MIN_SLOTS = 4 * 128

#: Module-level maintenance counters; tests and benchmarks read these to
#: prove walks do zero host image work (builds) between updates.
STATS = {"builds": 0, "patches": 0, "rebuilds": 0}


def stats_snapshot() -> dict:
    return dict(STATS)


@dataclasses.dataclass
class WalkImage:
    """Packed traversal image + host block geometry (one per owner rep)."""

    # device payload
    dst: jnp.ndarray   # int32 [cap_e], SENTINEL on dead slots
    wgt: jnp.ndarray   # f32   [cap_e] (carried for the patch merges)
    rows: jnp.ndarray  # int32 [cap_e] slot owner (stale allowed on dead)
    # host block geometry (CP2AA classes)
    starts: np.ndarray  # int64 [>= nv], -1 = no block
    caps: np.ndarray    # int64 [>= nv]
    degs: np.ndarray    # int64 [>= nv]
    nv: int             # vertices the walk covers (visits length)
    bump: int           # first never-allocated slot
    live: int           # live edges in the image
    #: True when dst/wgt/rows alias the owner's own arena (DiGraph):
    #: zero-cost wrap, kept current by the rep — never patched here.
    shared: bool = False
    #: occupancy as built — the densest this layout can be; the compact
    #: trigger fires relative to it (see COMPACT_THRESHOLD).
    base_occupancy: float = 1.0
    # device [lo, hi) interval cache + queued plans
    _blocks: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _pending: list = dataclasses.field(
        default_factory=list, repr=False, compare=False
    )
    #: set once the queue overflowed MAX_PENDING: the image can only be
    #: rebuilt, so further plans are dropped instead of pinned in memory
    _stale: bool = dataclasses.field(default=False, repr=False, compare=False)

    # ------------------------------------------------------------------
    @property
    def cap_e(self) -> int:
        return int(self.dst.shape[0])

    @property
    def occupancy(self) -> float:
        """Live-edge fraction of the image's allocated slot prefix."""
        return self.live / max(int(self.bump), 1)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_csr_arrays(cls, offsets, dst, wgt, nv: int, *,
                        engine: str = "auto") -> "WalkImage":
        """Build a slack-padded image from CSR-ordered edge arrays.

        Reuses the ingest engine's ``arena_image`` fill (DESIGN.md §10):
        CP2AA block placement on host, one fused fill + transfer for the
        device payload.  ``cap_e`` keeps >= 25% bump headroom so grown
        rows can relocate without an immediate rebuild.
        """
        from ..kernels.csr_build import ops as _cb_ops

        o = np.asarray(offsets, np.int64)
        nv = int(nv)
        deg = np.diff(o)
        m = int(o[-1]) if o.shape[0] else 0
        caps = np.where(deg > 0, alloc.edge_capacities(deg), 0)
        csum = np.cumsum(caps)
        starts = np.where(caps > 0, csum - caps, -1)
        total = int(csum[-1]) if caps.shape[0] else 0
        cap_e = alloc.pow2_with_headroom(total)
        w = wgt if wgt is not None else np.ones(m, np.float32)
        # slice padded source buffers to the live prefix: the device
        # arena_image path derives its edge count (and jit-cache key)
        # from dst.shape[0], so SENTINEL tail capacity would be scattered
        # for nothing on TPU
        dst_d, wgt_d, rows_d = _cb_ops.arena_image(
            o, dst[:m], w[:m], starts, caps, cap_e, nv,
            total=total, engine=engine,
        )
        STATS["builds"] += 1
        return cls(
            dst=dst_d, wgt=wgt_d, rows=rows_d,
            starts=starts.astype(np.int64), caps=caps.astype(np.int64),
            degs=deg.astype(np.int64), nv=nv, bump=total, live=m,
            base_occupancy=m / max(total, 1),
        )

    @classmethod
    def from_blocks(cls, dst, wgt, rows, starts, caps, degs, nv: int,
                    bump: int, live: int, *, shared: bool = False) -> "WalkImage":
        """Wrap pre-blocked device buffers (DiGraph arena, page gathers)."""
        STATS["builds"] += 1
        return cls(
            dst=dst, wgt=wgt, rows=rows,
            starts=np.asarray(starts, np.int64),
            caps=np.asarray(caps, np.int64),
            degs=np.asarray(degs, np.int64),
            nv=int(nv), bump=int(bump), live=int(live), shared=shared,
            base_occupancy=int(live) / max(int(bump), 1),
        )

    # ------------------------------------------------------------------
    # incremental maintenance
    # ------------------------------------------------------------------
    def queue(self, plan) -> None:
        """Record an applied UpdatePlan; the next walk flushes it.

        Past MAX_PENDING the image is only ever rebuilt, so the queue is
        dropped and the image marked stale — an update-only stream must
        not pin every plan's batch arrays in memory until someone walks.
        """
        if self.shared or self._stale:  # shared: the arena IS the image
            return
        self._pending.append(plan)
        if len(self._pending) > MAX_PENDING:
            self._pending.clear()
            self._stale = True

    def flush(self) -> bool:
        """Patch all queued plans in; False = owner must rebuild."""
        if self._stale:
            STATS["rebuilds"] += 1
            return False
        if not self._pending:
            return True
        while self._pending:
            if not self._patch_one(self._pending[0]):
                STATS["rebuilds"] += 1
                return False
            self._pending.pop(0)
        # occupancy-triggered compaction (§7, image-level): once dead
        # slots dominate the walked prefix — relative to how dense this
        # layout was as built — one rebuild beats every subsequent walk
        # dragging them through the step loop.
        if (
            self.bump >= COMPACT_MIN_SLOTS
            and self.occupancy < COMPACT_THRESHOLD * self.base_occupancy
        ):
            STATS["rebuilds"] += 1
            return False
        return True

    def _patch_one(self, plan) -> bool:
        """Apply one plan's per-row runs to the image in place.

        Mirrors ``DiGraph._apply_impl``'s group loop against the image's
        own geometry: one fused ``slot_update`` dispatch per pow-2 width
        class (gather touched blocks, merge the sorted runs, scatter
        back, grown rows landing in fresh bump blocks).  Returns False
        when only a rebuild can represent the result (new vertices, or
        a grown row with no bump slack left).
        """
        from ..kernels.slot_update import ops as _su_ops

        if plan.n_ops == 0:
            return True
        if plan.max_insert_vertex() >= self.nv:
            return False  # vertex growth changes the visits shape: rebuild
        sel, rows, deg_old, ins_count = plan.active_rows(self.degs, self.nv)
        if sel.shape[0] == 0:
            return True
        old_caps = self.caps[rows]
        old_starts = self.starts[rows]
        ub = deg_old + ins_count
        grow = ub > old_caps
        new_caps = old_caps.copy()
        new_starts = old_starts.copy()
        if grow.any():
            need = alloc.edge_capacities(ub[grow])
            if self.bump + int(need.sum()) > self.cap_e:
                return False  # slack exhausted: rebuild repacks densely
            g_idx = np.nonzero(grow)[0]
            new_caps[g_idx] = need
            new_starts[g_idx] = self.bump + (np.cumsum(need) - need)
            self.bump += int(need.sum())

        on_tpu = jax.default_backend() == "tpu"
        backend = (
            "pallas" if on_tpu and self.nv < _su_ops.PALLAS_MAX_ID else "xla"
        )
        net = 0
        deferred = []
        for wv, gsel, _a_pad, pad1, bd, bw, bl in plan.width_groups(
            sel, new_caps, _su_ops.width_floor()
        ):
            self.dst, self.wgt, self.rows, counts = _su_ops.slot_update(
                self.dst,
                self.wgt,
                self.rows,
                pad1(old_starts[gsel], -1),
                pad1(old_caps[gsel], 0),
                pad1(new_starts[gsel], -1),
                pad1(new_caps[gsel], 0),
                pad1(deg_old[gsel], 0),
                pad1(rows[gsel], self.nv),
                bd,
                bw,
                bl,
                width=int(wv),
                backend=backend,
                donate=True,
                has_moves=bool(grow[gsel].any()),
            )
            deferred.append((gsel, counts))
        for gsel, counts in deferred:
            counts = np.asarray(counts, dtype=np.int64)[: gsel.shape[0]]
            self.degs[rows[gsel]] = counts
            net += int(counts.sum() - deg_old[gsel].sum())
        if grow.any():
            self.starts[rows] = new_starts
            self.caps[rows] = new_caps
        self.live += net
        self._blocks = None
        STATS["patches"] += 1
        return True

    # ------------------------------------------------------------------
    # walking
    # ------------------------------------------------------------------
    def edges_hi(self) -> int:
        """Bump prefix bound, quantized so jit shapes stay coarse (§6).

        cap_e/8 granularity (<= 8 shapes per capacity): under update
        streams the bump pointer only grows, and every quantum crossing
        recompiles the walk scan — a coarse lattice trades <= 12.5% dead
        pad slots for rounds of warm-shape walks between crossings.
        """
        q = max(self.cap_e // 8, 128)
        return min(-(-max(int(self.bump), 1) // q) * q, self.cap_e)

    def device_blocks(self):
        """Device [lo, hi) interval arrays, memoized until the next patch."""
        if self._blocks is None:
            starts = self.starts[: self.nv]
            has_block = starts >= 0
            lo = np.where(has_block, starts, 0).astype(np.int32)
            hi = np.where(
                has_block, starts + self.degs[: self.nv], 0
            ).astype(np.int32)
            self._blocks = (jnp.asarray(lo), jnp.asarray(hi))
        return self._blocks

    def walk(
        self,
        steps: int,
        *,
        backend: str = "auto",
        normalize: bool = False,
        interpret: bool = False,
        visits0: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """k-step reverse walk over the image via the slot_walk engine.

        ``visits0`` may be a ``[B, num_vertices]`` stack of initial visit
        vectors — all B walks then ride the same fused step programs
        (one-hot matmul batching on the Pallas backend).
        """
        from ..kernels.slot_walk import ops as _sw_ops

        return _sw_ops.slot_walk_image(
            self,
            steps,
            backend=backend,
            normalize=normalize,
            interpret=interpret,
            visits0=visits0,
        )
