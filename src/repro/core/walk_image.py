"""WalkImage — the universal traversal-image layer (DESIGN.md §11/§12).

Every representation lowers to ONE canonical device traversal image: a
packed edge buffer (``dst``/``wgt``/``rows``, SENTINEL on dead slots)
plus per-vertex ``[lo, hi)`` block intervals — exactly the operand set
the fused ``kernels/slot_walk`` engine consumes (§6).  The image is
**incrementally maintained** under update streams instead of being
re-materialized per walk:

  * representations *queue* each applied ``UpdatePlan`` on their cached
    image (``queue``), and the next walk *flushes* the queue by patching
    touched rows in place through the fused ``kernels/slot_update``
    engine — ALL pow-2 width groups of a plan in ONE dispatch
    (``fused_apply``), and, on the walk path, the k-step walk scan fused
    into the SAME program (``walk_flush``): a steady-state update/walk
    stream round is one device dispatch, zero intermediate
    materialization (§12);
  * rows are laid out in CP2AA slack-padded blocks (``alloc.edge_
    capacities``) — or DENSELY when the source layout's slack would
    dominate the walked prefix (``DENSE_THRESHOLD``, §12): ChunkedGraph
    PAGE tails and low-occupancy arenas compact to live edges only, so
    walks never drag dead lanes through the step loop;
  * a row that outgrows its slack relocates to a fresh block at the
    image's bump pointer inside the same fused dispatch;
  * the patch path falls back to a full rebuild (returning ``False`` so
    the owner drops its cache) only when the bump slack is exhausted,
    the vertex set grows, or the queue got too deep to be worth
    replaying (``MAX_PENDING``).

``DiGraph`` is the degenerate case: its arena *is* the image, so
``shared=True`` wraps the live buffers zero-copy and the rep's own
update engine keeps them current (shared images never patch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, util

SENTINEL = util.SENTINEL

#: Queue depth beyond which replaying patches is judged worse than one
#: rebuild (each pending plan costs a fused dispatch).
MAX_PENDING = 32
#: Fraction of the BUILD-TIME occupancy below which a flush demands a
#: rebuild instead of further patching — the image-level analogue of
#: DiGraph's traversal-time compaction (§7): dead slots from relocated /
#: deleted rows otherwise accumulate in the walked prefix forever.  The
#: trigger is relative to the layout's own slack, so it fires only when
#: a rebuild would actually densify.
COMPACT_THRESHOLD = 0.5
#: Don't bother occupancy-rebuilding images smaller than this.
COMPACT_MIN_SLOTS = 4 * 128
#: Build-time live fraction below which an image build strips the source
#: layout's slack entirely (caps == degrees, occupancy 1.0) instead of
#: inheriting it — dense image compaction (§12).  CP2AA arenas build at
#: ~0.65-0.7 and keep their slack (in-place patches stay cheap);
#: ChunkedGraph's PAGE quantization builds at ~0.3 and compacts, since
#: 3x dead lanes per step cost far more than relocating grown rows.
DENSE_THRESHOLD = 0.55

#: Module-level maintenance counters; tests and benchmarks read these to
#: prove walks do zero host image work (builds) between updates, and
#: that a steady-state flush→walk round is ONE device dispatch.
STATS = {"builds": 0, "patches": 0, "rebuilds": 0, "dispatches": 0, "seals": 0}


def stats_snapshot() -> dict:
    return dict(STATS)


def seal_generation(rep, generation: int = 0) -> "WalkImage":
    """Seal ``rep``'s current state as an immutable walk generation (§16).

    The single writer calls this after applying a group of UpdatePlans;
    the returned frozen :class:`WalkImage` is what concurrent readers
    walk until the next seal — they can never observe a half-applied
    plan, because generations are immutable and the live structure's
    subsequent patches copy-on-write instead of donating shared buffers.

    Two shapes, one contract:

    * queueing reps (coo/lazy/chunked/vector2d): ``to_walk_image()``
      flushes or rebuilds the cached image, then :meth:`WalkImage.seal`
      snapshots it O(1) and arms the COW flag on the live image;
    * arena-backed reps (DiGraph, ``shared=True`` images): the rep's own
      per-buffer COW *is* the isolation — ``rep.snapshot()`` seals the
      arena buffers (the next in-place update detaches only what it
      writes, §10) and the snapshot's image wrap becomes the frozen
      generation.  The snapshot handle is dropped; the image keeps its
      host geometry arrays alive.

    Reps with their own ``seal_generation`` (``ShardedGraph``: per-shard
    seals + quarantine masking, §17) delegate wholesale.
    """
    own = getattr(rep, "seal_generation", None)
    if own is not None:
        return own(generation)
    img = rep.to_walk_image()
    if not img.shared:
        return img.seal(generation)
    snap = rep.snapshot()
    gen = snap.to_walk_image()
    gen.generation = int(generation)
    gen._frozen = True
    # detach from the snapshot handle: the generation must stay exactly
    # as sealed even if someone mutates the snapshot rep later.
    snap._image = None
    STATS["seals"] += 1
    return gen


def reverse_walk_via_image(rep, steps: int, *, visits0=None):
    """The shared reverse_walk body of every image-queueing representation.

    Try the fused flush→walk dispatch on the cached image (§12); fall
    back to the eager flush-or-rebuild path (``to_walk_image``) when the
    image is absent or can only be rebuilt.
    """
    img = rep._image
    if img is not None:
        out = img.walk_flush(steps, visits0=visits0)
        if out is not None:
            return out
    return rep.to_walk_image().walk(steps, visits0=visits0)


@dataclasses.dataclass
class WalkImage:
    """Packed traversal image + host block geometry (one per owner rep)."""

    # device payload
    dst: jnp.ndarray   # int32 [cap_e], SENTINEL on dead slots
    wgt: jnp.ndarray   # f32   [cap_e] (carried for the patch merges)
    rows: jnp.ndarray  # int32 [cap_e] slot owner (stale allowed on dead)
    # host block geometry (CP2AA classes, or exact degrees when dense)
    starts: np.ndarray  # int64 [>= nv], -1 = no block
    caps: np.ndarray    # int64 [>= nv]
    degs: np.ndarray    # int64 [>= nv]
    nv: int             # vertices the walk covers (visits length)
    bump: int           # first never-allocated slot
    live: int           # live edges in the image
    #: True when dst/wgt/rows alias the owner's own arena (DiGraph):
    #: zero-cost wrap, kept current by the rep — never patched here.
    shared: bool = False
    #: occupancy as built — the densest this layout can be; the compact
    #: trigger fires relative to it (see COMPACT_THRESHOLD).
    base_occupancy: float = 1.0
    # device [lo, hi) interval cache + queued plans
    _blocks: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _pending: list = dataclasses.field(
        default_factory=list, repr=False, compare=False
    )
    #: set once the queue overflowed MAX_PENDING (or a fused walk left
    #: the occupancy below the compaction trigger): the image can only
    #: be rebuilt, so further plans are dropped instead of pinned.
    _stale: bool = dataclasses.field(default=False, repr=False, compare=False)
    #: sealed-generation id (§16); -1 on live (unsealed) images.
    generation: int = -1
    #: True on a sealed generation: the image is read-only — ``queue``
    #: raises and the patch engine never touches it.  Readers walk it
    #: while the live writer image keeps patching (snapshot isolation).
    _frozen: bool = dataclasses.field(default=False, repr=False, compare=False)
    #: True while a sealed generation still shares this live image's
    #: device payload: the NEXT patch must not donate dst/wgt/rows (the
    #: per-buffer COW — jax immutability makes the non-donated merge a
    #: copy-on-write detach; the patch outputs are fresh buffers, so the
    #: flag clears after one dispatch).
    _cow: bool = dataclasses.field(default=False, repr=False, compare=False)

    # ------------------------------------------------------------------
    @property
    def cap_e(self) -> int:
        return int(self.dst.shape[0])

    @property
    def occupancy(self) -> float:
        """Live-edge fraction of the image's allocated slot prefix."""
        return self.live / max(int(self.bump), 1)

    # ------------------------------------------------------------------
    # integrity (DESIGN.md §13 — the auditor's image half)
    # ------------------------------------------------------------------
    def audit(self) -> dict:
        """Geometry + content invariant sweep; raises ``AuditError``.

        Asserts everything the patch engine and the walk scan rely on:
        blocks live inside the bump frontier and are pairwise disjoint,
        every live slot carries an in-range destination owned by its
        block's row and rows stay strictly ascending, slack slots are
        SENTINEL (the merge gather masks on it — a non-SENTINEL slack
        slot would resurrect a ghost edge on the next patch), and the
        per-row degrees account for exactly ``self.live`` edges.
        """
        from ..runtime import faultinject as _fi

        chk = _fi._check
        nv, bump, cap_e = int(self.nv), int(self.bump), self.cap_e
        chk(0 <= bump <= cap_e, f"bump {bump} outside [0, cap_e {cap_e}]")
        chk(
            self.starts.shape[0] >= nv
            and self.caps.shape[0] >= nv
            and self.degs.shape[0] >= nv,
            "block geometry arrays shorter than nv",
        )
        starts = np.asarray(self.starts[:nv], np.int64)
        caps = np.asarray(self.caps[:nv], np.int64)
        degs = np.asarray(self.degs[:nv], np.int64)
        chk(bool((degs >= 0).all()), "negative image degree")
        chk(bool((caps >= degs).all()), "image degree exceeds block capacity")
        blocked = caps > 0
        chk(bool((degs[~blocked] == 0).all()), "edges on a block-less row")
        chk(bool((starts[blocked] >= 0).all()), "blocked row with start < 0")
        chk(
            bool(((starts[blocked] + caps[blocked]) <= bump).all()),
            "block extends past the bump frontier",
        )
        if blocked.any():
            order = np.argsort(starts[blocked], kind="stable")
            s_b, c_b = starts[blocked][order], caps[blocked][order]
            chk(
                bool(((s_b[:-1] + c_b[:-1]) <= s_b[1:]).all()),
                "overlapping blocks",
            )
        m = int(degs.sum())
        chk(m == int(self.live), f"degree sum {m} != image live {int(self.live)}")
        n_blocks = int(blocked.sum())
        if m:
            d = np.asarray(self.dst)
            w = np.asarray(self.wgt)
            r = np.asarray(self.rows)
            first = np.cumsum(degs) - degs
            gidx = np.repeat(starts, degs) + (
                np.arange(m, dtype=np.int64) - np.repeat(first, degs)
            )
            owner = np.repeat(np.arange(nv, dtype=np.int64), degs)
            dl, wl, rl = d[gidx], w[gidx], r[gidx]
            chk(not bool((dl == SENTINEL).any()), "SENTINEL inside a live prefix")
            chk(
                bool((dl >= 0).all()) and bool((dl < nv).all()),
                "image dst id out of [0, nv)",
            )
            chk(bool((rl == owner).all()), "live slot owned by the wrong row")
            chk(bool(np.isfinite(wl).all()), "non-finite live image weight")
            interior = owner[1:] == owner[:-1]
            chk(
                not bool((interior & (dl[1:] <= dl[:-1])).any()),
                "image row not strictly ascending",
            )
        slack = caps - degs
        if int(slack.sum()):
            sfirst = np.cumsum(slack) - slack
            sidx = np.repeat(starts + degs, slack) + (
                np.arange(int(slack.sum()), dtype=np.int64)
                - np.repeat(sfirst, slack)
            )
            chk(
                bool((np.asarray(self.dst)[sidx] == SENTINEL).all()),
                "non-SENTINEL slack slot",
            )
        return {
            "blocks": n_blocks,
            "bump": bump,
            "slack": int(slack.sum()),
            "occupancy": self.occupancy,
        }

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_csr_arrays(cls, offsets, dst, wgt, nv: int, *,
                        engine: str = "auto",
                        dense: Optional[bool] = None,
                        min_cap_e: int = 0) -> "WalkImage":
        """Build a slack-padded OR dense image from CSR-ordered arrays.

        Reuses the ingest engine's ``arena_image`` fill (DESIGN.md §10):
        CP2AA block placement on host, one fused fill + transfer for the
        device payload.  ``dense=None`` applies the §12 compaction
        policy: when the CP2AA layout's live fraction would fall below
        ``DENSE_THRESHOLD``, blocks take their exact degree (occupancy
        1.0) so the walk processes live edges only.  ``cap_e`` keeps
        >= 25% bump headroom either way so grown rows can relocate
        without an immediate rebuild.  ``min_cap_e`` floors the slot
        capacity — the sharded layer (§14) passes one common floor so
        every shard's image compiles to the same program shape.
        """
        from ..kernels.csr_build import ops as _cb_ops

        o = np.asarray(offsets, np.int64)
        nv = int(nv)
        deg = np.diff(o)
        m = int(o[-1]) if o.shape[0] else 0
        caps = np.where(deg > 0, alloc.edge_capacities(deg), 0)
        total = int(caps.sum())
        if dense is None:
            dense = m > 0 and m < DENSE_THRESHOLD * total
        if dense:
            caps = deg.copy()
            total = m
        csum = np.cumsum(caps)
        starts = np.where(caps > 0, csum - caps, -1)
        cap_e = alloc.pow2_with_headroom(total, 1.0 if dense else 0.25)
        cap_e = max(cap_e, int(min_cap_e))
        w = wgt if wgt is not None else np.ones(m, np.float32)
        # slice padded source buffers to the live prefix: the device
        # arena_image path derives its edge count (and jit-cache key)
        # from dst.shape[0], so SENTINEL tail capacity would be scattered
        # for nothing on TPU
        dst_d, wgt_d, rows_d = _cb_ops.arena_image(
            o, dst[:m], w[:m], starts, caps, cap_e, nv,
            total=total, engine=engine,
        )
        STATS["builds"] += 1
        return cls(
            dst=dst_d, wgt=wgt_d, rows=rows_d,
            starts=starts.astype(np.int64), caps=caps.astype(np.int64),
            degs=deg.astype(np.int64), nv=nv, bump=total, live=m,
            base_occupancy=m / max(total, 1),
        )

    @classmethod
    def from_blocks(cls, dst, wgt, rows, starts, caps, degs, nv: int,
                    bump: int, live: int, *, shared: bool = False) -> "WalkImage":
        """Wrap pre-blocked device buffers (DiGraph arena, page gathers)."""
        STATS["builds"] += 1
        return cls(
            dst=dst, wgt=wgt, rows=rows,
            starts=np.asarray(starts, np.int64),
            caps=np.asarray(caps, np.int64),
            degs=np.asarray(degs, np.int64),
            nv=int(nv), bump=int(bump), live=int(live), shared=shared,
            base_occupancy=int(live) / max(int(bump), 1),
        )

    # ------------------------------------------------------------------
    # generation sealing (DESIGN.md §16 — snapshot-isolated serving)
    # ------------------------------------------------------------------
    def seal(self, generation: int = 0) -> "WalkImage":
        """Seal the current state as an immutable read-only generation.

        O(1) on device: the sealed image *shares* the live device payload
        (jax arrays are immutable) and copies only the small host
        geometry arrays.  The live image is flagged ``_cow`` so its next
        patch suppresses buffer donation — the merge then writes fresh
        buffers instead of invalidating the generation's (per-buffer
        COW, §10), after which the flag clears and donation resumes.
        Readers walk the sealed generation while the writer patches the
        live image: a reader can never observe a half-applied plan.

        Requires a flushed image (no queued plans, not stale) — the
        serve layer seals via :func:`seal_generation`, which flushes or
        rebuilds first.  Shared (arena-backed) images cannot seal here:
        their owner's update engine mutates host metadata in place, so
        the owner rep must be snapshotted instead (``seal_generation``
        handles that too).
        """
        if self.shared:
            raise ValueError("seal(): shared image — snapshot the owner rep")
        if self._pending or self._stale:
            raise ValueError("seal(): image has unflushed plans")
        gen = WalkImage(
            dst=self.dst, wgt=self.wgt, rows=self.rows,
            starts=self.starts[: self.nv].copy(),
            caps=self.caps[: self.nv].copy(),
            degs=self.degs[: self.nv].copy(),
            nv=self.nv, bump=self.bump, live=self.live,
            base_occupancy=self.base_occupancy,
            generation=int(generation), _frozen=True,
        )
        self._cow = True
        STATS["seals"] += 1
        return gen

    # ------------------------------------------------------------------
    # incremental maintenance
    # ------------------------------------------------------------------
    def queue(self, plan) -> None:
        """Record an applied UpdatePlan; the next walk flushes it.

        Past MAX_PENDING the image is only ever rebuilt, so the queue is
        dropped and the image marked stale — an update-only stream must
        not pin every plan's batch arrays in memory until someone walks.
        """
        if self._frozen:
            raise RuntimeError(
                f"sealed walk generation {self.generation} is read-only"
            )
        if self.shared or self._stale:  # shared: the arena IS the image
            return
        self._pending.append(plan)
        if len(self._pending) > MAX_PENDING:
            self._pending.clear()
            self._stale = True

    def block_ranges(self, rows: np.ndarray) -> np.ndarray:
        """``[K, 2]`` half-open slot ranges of ``rows``'s CURRENT blocks.

        The §15 differential checkpointer calls this before AND after a
        patch: a relocated row's old slots are cleared to SENTINEL (the
        walk masks on ``dst == SENTINEL`` over the whole bump prefix), so
        both the vacated and the new extent are dirty bytes.  Rows
        without a block contribute nothing.
        """
        rows = np.asarray(rows, np.int64)
        st = np.asarray(self.starts[rows], np.int64)
        cp = np.asarray(self.caps[rows], np.int64)
        has = (st >= 0) & (cp > 0)
        return np.stack([st[has], st[has] + cp[has]], axis=1)

    def _needs_compact(self) -> bool:
        return (
            self.bump >= COMPACT_MIN_SLOTS
            and self.occupancy < COMPACT_THRESHOLD * self.base_occupancy
        )

    def flush(self) -> bool:
        """Patch all queued plans in; False = owner must rebuild."""
        if self._stale:
            STATS["rebuilds"] += 1
            return False
        if not self._pending:
            return True
        while self._pending:
            if not self._patch_one(self._pending[0]):
                STATS["rebuilds"] += 1
                return False
            self._pending.pop(0)
        # occupancy-triggered compaction (§7, image-level): once dead
        # slots dominate the walked prefix — relative to how dense this
        # layout was as built — one rebuild beats every subsequent walk
        # dragging them through the step loop.
        if self._needs_compact():
            STATS["rebuilds"] += 1
            return False
        return True

    # -- patch pipeline: host planning, fused dispatch, host commit ------
    def _plan_patch(self, plan):
        """Host half of one plan's patch: geometry + dispatch operands.

        Mirrors ``DiGraph._apply_impl``'s planning against the image's
        own geometry, producing the operand set of ONE fused
        ``slot_update.fused_apply`` dispatch (every pow-2 width class of
        the plan merges in the same program; grown rows land in fresh
        bump blocks).  Returns None when only a rebuild can represent
        the result (new vertices, or a grown row with no bump slack
        left) — all failure checks precede any state mutation, so a
        failed planning pass is side-effect free.
        """
        from ..kernels.slot_update import ops as _su_ops

        if plan.n_ops == 0:
            return ()
        if plan.max_insert_vertex() >= self.nv:
            return None  # vertex growth changes the visits shape: rebuild
        sel, rows, deg_old, ins_count = plan.active_rows(self.degs, self.nv)
        if sel.shape[0] == 0:
            return ()
        old_caps = self.caps[rows]
        old_starts = self.starts[rows]
        ub = deg_old + ins_count
        grow = ub > old_caps
        new_caps = old_caps.copy()
        new_starts = old_starts.copy()
        if grow.any():
            need = alloc.edge_capacities(ub[grow])
            if self.bump + int(need.sum()) > self.cap_e:
                return None  # slack exhausted: rebuild repacks densely
            g_idx = np.nonzero(grow)[0]
            new_caps[g_idx] = need
            new_starts[g_idx] = self.bump + (np.cumsum(need) - need)
            self.bump += int(need.sum())

        on_tpu = jax.default_backend() == "tpu"
        backend = (
            "pallas" if on_tpu and self.nv < _su_ops.PALLAS_MAX_ID else "xla"
        )
        has_moves = bool(grow.any())
        touched = int(new_caps.sum() + old_caps[grow].sum())
        scatter = _su_ops.choose_scatter(self.cap_e, touched)
        groups, layout = plan.fused_groups(
            sel, rows, deg_old, grow,
            old_starts, old_caps, new_starts, new_caps,
            _su_ops.width_floor(), self.nv,
        )
        slot_map = owner_patch = None
        rebuild_hi = 0
        if not scatter:
            rebuild_hi = self.edges_hi()  # post-growth bump, same lattice
            slot_map, owner_patch = _su_ops.host_patch_layout(
                layout, rows, old_starts, old_caps, new_starts, new_caps,
                grow, rebuild_hi, self.nv, has_moves,
            )
        return dict(
            rows=rows, deg_old=deg_old, grow=grow,
            new_caps=new_caps, new_starts=new_starts,
            groups=groups, layout=layout, backend=backend,
            scatter=scatter, slot_map=slot_map, owner_patch=owner_patch,
            rebuild_hi=rebuild_hi,
        )

    def _commit_patch(self, prep, counts_list) -> None:
        """Install the post-dispatch geometry (degrees, moved blocks)."""
        rows, deg_old = prep["rows"], prep["deg_old"]
        net = 0
        for (_wv, gsel, _a), counts in zip(prep["layout"], counts_list):
            counts = np.asarray(counts, dtype=np.int64)[: gsel.shape[0]]
            self.degs[rows[gsel]] = counts
            net += int(counts.sum() - deg_old[gsel].sum())
        if prep["grow"].any():
            self.starts[rows] = prep["new_starts"]
            self.caps[rows] = prep["new_caps"]
        self.live += net
        self._blocks = None
        STATS["patches"] += 1

    def _patch_one(self, plan) -> bool:
        """Apply one plan to the image: ONE fused dispatch, all groups."""
        from ..kernels.slot_update import ops as _su_ops

        prep = self._plan_patch(plan)
        if prep is None:
            return False
        if prep == ():
            return True
        self.dst, self.wgt, self.rows, counts, _ = _su_ops.fused_apply(
            self.dst, self.wgt, self.rows, prep["groups"],
            scatter=prep["scatter"], backend=prep["backend"],
            donate=not self._cow,
            slot_map=prep["slot_map"], owner_patch=prep["owner_patch"],
            rebuild_hi=prep["rebuild_hi"],
        )
        self._cow = False  # outputs are fresh buffers; generations detached
        STATS["dispatches"] += 1
        self._commit_patch(prep, counts)
        return True

    # ------------------------------------------------------------------
    # walking
    # ------------------------------------------------------------------
    def edges_hi(self) -> int:
        """Bump prefix bound, quantized so jit shapes stay coarse (§6).

        cap_e/8 granularity (<= 8 shapes per capacity): under update
        streams the bump pointer only grows, and every quantum crossing
        recompiles the walk scan — a coarse lattice trades <= 12.5% dead
        pad slots for rounds of warm-shape walks between crossings.
        """
        q = max(self.cap_e // 8, 128)
        return min(-(-max(int(self.bump), 1) // q) * q, self.cap_e)

    def device_blocks(self):
        """Device [lo, hi) interval arrays, memoized until the next patch."""
        if self._blocks is None:
            starts = self.starts[: self.nv]
            has_block = starts >= 0
            lo = np.where(has_block, starts, 0).astype(np.int32)
            hi = np.where(
                has_block, starts + self.degs[: self.nv], 0
            ).astype(np.int32)
            self._blocks = (jnp.asarray(lo), jnp.asarray(hi))
        return self._blocks

    def walk(
        self,
        steps: int,
        *,
        backend: str = "auto",
        normalize: bool = False,
        interpret: bool = False,
        visits0: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """k-step reverse walk over the image via the slot_walk engine.

        ``visits0`` may be a ``[B, num_vertices]`` stack of initial visit
        vectors — all B walks then ride the same fused step programs.
        Assumes the image is flushed (owners call ``walk_flush`` or
        ``to_walk_image()`` first).
        """
        from ..kernels.slot_walk import ops as _sw_ops

        STATS["dispatches"] += 1
        return _sw_ops.slot_walk_image(
            self,
            steps,
            backend=backend,
            normalize=normalize,
            interpret=interpret,
            visits0=visits0,
        )

    def walk_flush(
        self,
        steps: int,
        *,
        backend: str = "auto",
        normalize: bool = False,
        interpret: bool = False,
        visits0: Optional[jnp.ndarray] = None,
    ) -> Optional[jnp.ndarray]:
        """Flush queued plans AND walk — fused into ONE dispatch (§12).

        The steady-state stream round (one queued plan, then a walk)
        lowers to a single jitted program: the plan's merge groups run
        as a prologue, the [lo, hi) geometry updates in-program from the
        merge counts, and the step scan consumes the patched buffers
        directly — no intermediate flush dispatch, no host round-trip
        before the walk.  Deeper queues flush all but the last plan
        first (one fused dispatch each).  Returns None when the image
        can only be rebuilt — the owner falls back to
        ``to_walk_image().walk(...)`` (rebuild accounting happens there,
        in ``flush``; a failed planning pass here is side-effect free).
        """
        from ..kernels.slot_update import ops as _su_ops

        if self.shared or self._stale:
            return None if self._stale else self.walk(
                steps, backend=backend, normalize=normalize,
                interpret=interpret, visits0=visits0,
            )
        while len(self._pending) > 1:
            if not self._patch_one(self._pending[0]):
                return None
            self._pending.pop(0)
        if not self._pending:
            return self.walk(
                steps, backend=backend, normalize=normalize,
                interpret=interpret, visits0=visits0,
            )
        prep = self._plan_patch(self._pending[0])
        if prep is None:
            return None
        if prep == ():
            self._pending.pop(0)
            return self.walk(
                steps, backend=backend, normalize=normalize,
                interpret=interpret, visits0=visits0,
            )
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        nwalks = 0 if visits0 is None else int(visits0.shape[0])
        if nwalks:
            visits0 = jnp.asarray(visits0, jnp.float32)
        lo, hi = self.device_blocks()
        self.dst, self.wgt, self.rows, counts, walk_out = _su_ops.fused_apply(
            self.dst, self.wgt, self.rows, prep["groups"],
            scatter=prep["scatter"], backend=prep["backend"],
            donate=not self._cow,
            slot_map=prep["slot_map"], owner_patch=prep["owner_patch"],
            rebuild_hi=prep["rebuild_hi"],
            walk=(steps, self.nv, self.edges_hi(), nwalks,
                  bool(normalize), backend),
            lo=lo, hi=hi, visits0=visits0,
            interpret=interpret,
        )
        self._cow = False  # outputs are fresh buffers; generations detached
        STATS["dispatches"] += 1
        self._pending.pop(0)
        self._commit_patch(prep, counts)
        visits, lo2, hi2 = walk_out
        self._blocks = (lo2, hi2)  # in-program-updated geometry, reusable
        if self._needs_compact():
            # this walk already ran on the sparse image; make the NEXT
            # access rebuild densely instead of patching further.
            self._stale = True
        return visits
