"""Multi-device sharded walk images (DESIGN.md §14).

``ShardedGraph`` is a thin wrapper over per-shard ``WalkImage``s: vertices
block-partition over a 1-D ``("data",)`` mesh (shard s owns the contiguous
range ``[s·rows_max, (s+1)·rows_max)``, the analogue of the paper's Alg-5
per-thread partition), and each shard's edges live in its OWN standard
walk image — same packed tiles, same CP2AA/dense layout policy, same
``kernels/slot_walk`` / ``kernels/slot_update`` programs as the
single-device path.  There is no bespoke distributed walk or apply any
more:

  * ``reverse_walk`` — ONE jitted shard_map program
    (``kernels/slot_walk/sharded``): every shard runs the blocked
    interval step on its tiles and the only cross-shard exchange per
    step is the frontier all_gather, (S-1)·rows_max·4 ≈ |V|·4 bytes per
    device per step.  Shard cuts align to block boundaries by
    construction, so the hierarchical prefix's inter-tile base scan
    cancels inside each shard and never crosses devices.
  * ``apply`` — ``route_updates`` slices a canonical ``UpdatePlan`` by
    owning shard on host (the stream is (src, dst)-sorted, so routing is
    a searchsorted over block boundaries — zero re-sort) and each shard
    patches its slice through its image's fused ``slot_update`` path:
    one dispatch per device per plan, executing on the shard's own
    device because its buffers are committed there.
  * ``gather_csr`` — reassembles a host CSR from the live block
    prefixes (per-shard pow-2 slack drops by construction), validating
    that every shard's edges sit inside its owned row range — a
    row-count mismatch raises instead of silently mis-stitching offsets.

Growth and overflow take the rebuild path every representation uses:
gather, host-apply the unapplied plans, re-shard once — this is how a
grown row (or a new vertex) relocates across a shard boundary.

**Shard failover (DESIGN.md §17).**  A shard that faults mid-walk or
mid-patch (``shard.walk`` / ``shard.patch`` injection points, or a real
device error) is *quarantined* instead of taking the mesh down:
``quarantine`` marks it in ``down``, drains its unapplied plans into a
per-shard host spool, and every subsequent routed update for it spools
too.  Walks keep running over the surviving shards — ``_assemble``
masks a down shard's row intervals to ``lo == hi == 0``, so its rows
contribute exact zeros and ``coverage`` tells readers how much of the
vertex space the response covers.  ``reintegrate`` atomically swaps a
rebuilt image back in (after the shard audit passes) and the next
sealed generation flips readers back to full coverage.  Silent bit-rot
is caught by the opt-in integrity tracker (``enable_integrity``):
per-buffer chunk CRCs maintained transactionally with each fused patch
and re-verified by ``verify_shard`` / ``audit_shard`` between rounds.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, updates as upd_mod, util, walk_image
from ..launch import mesh as mesh_mod
from ..runtime import faultinject as _fi

SENTINEL = util.SENTINEL

#: Device buffers covered by the per-shard integrity descriptor (the
#: host geometry hashes as one combined digest — see _shard_crc_table).
_INTEGRITY_BUFS = ("dst", "wgt", "rows")


class ShardFaultError(RuntimeError):
    """One shard failed (device loss mid-walk/patch, audit violation).

    Carries ``sid`` so the serving layer can quarantine exactly the
    failed shard and keep the rest of the mesh live.
    """

    def __init__(self, sid: int, stage: str, detail: str = ""):
        msg = f"shard {sid} fault during {stage}"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)
        self.sid = int(sid)
        self.stage = stage


class ShardIntegrityError(ShardFaultError):
    """A shard's content no longer matches its maintained CRC descriptor
    (silent corruption — bit rot, a misbehaving device)."""


class ShardDownError(RuntimeError):
    """The operation needs the full mesh but shards are quarantined
    (vertex growth, global gather/rebuild, checkpointing)."""


def _shard_crc_table(img) -> dict:
    """Integrity descriptor of one shard: live-extent counts + per-buffer
    chunk CRCs over the full device payload + one digest of the host
    block geometry.  Chunking matches the checkpoint manifests
    (``checkpoint.manager.CHUNK_BYTES``) so a mismatch names the damaged
    chunk directly."""
    from ..checkpoint.manager import _chunk_crcs

    table = {
        k: _chunk_crcs(np.asarray(getattr(img, k)).tobytes())
        for k in _INTEGRITY_BUFS
    }
    geom = 0
    for k in ("starts", "caps", "degs"):
        geom = zlib.crc32(np.ascontiguousarray(getattr(img, k)).tobytes(), geom)
    table["geom"] = geom
    table["live"] = int(img.live)
    table["bump"] = int(img.bump)
    return table


def _dense_policy(deg: np.ndarray, m: int) -> bool:
    """The §12 compaction decision, made ONCE globally so every shard
    builds the same layout (and the jit-shape lattice stays shared)."""
    caps = np.where(deg > 0, alloc.edge_capacities(deg), 0)
    total = int(caps.sum())
    return m > 0 and m < walk_image.DENSE_THRESHOLD * total


def _shard_cap(deg_s: np.ndarray, dense: bool) -> int:
    """The cap_e ``WalkImage.from_csr_arrays`` would pick for one shard."""
    if dense:
        total = int(deg_s.sum())
    else:
        total = int(np.where(deg_s > 0, alloc.edge_capacities(deg_s), 0).sum())
    return alloc.pow2_with_headroom(total, 1.0 if dense else 0.25)


@dataclasses.dataclass
class ShardedGraph:
    """Per-shard WalkImages over a block vertex partition (DESIGN.md §14).

    Every image spans the PADDED global vertex space ``v_pad =
    n_shards·rows_max`` (so visit vectors concatenate without index
    remapping — vertex ids are identical on every shard) but holds only
    its owned rows' blocks; rows outside the owned range have no block
    and contribute exact zeros to the walk step.
    """

    shards: list          # [S] WalkImage, nv == v_pad each
    n: int                # true global vertex count (<= v_pad)
    rows_max: int         # vertices per shard block
    n_shards: int
    mesh: Optional[object] = None   # jax Mesh; None = single-device local mode
    dense: bool = False             # global layout policy (shared by shards)
    #: bumped by every ``_rebuild`` — lets observers (the §15 dirty-block
    #: tracker) distinguish in-place per-shard patches from a global
    #: re-shard that invalidates every shard's layout
    generation: int = dataclasses.field(default=0, compare=False)
    #: quarantined shard ids (§17) — excluded from walks/patches, their
    #: routed updates spool until ``reintegrate``
    down: set = dataclasses.field(default_factory=set, compare=False)
    #: per-down-shard FIFO of routed subplans awaiting reintegration
    _spool: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    #: opt-in integrity descriptors {sid: crc table}; None = disabled
    _integrity: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    #: True on sealed generations (§16) — apply() refuses
    _frozen: bool = dataclasses.field(default=False, compare=False)
    _placed: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------------
    @property
    def v_pad(self) -> int:
        return self.n_shards * self.rows_max

    @property
    def nv(self) -> int:
        """Walkable vertex count (serve-layer protocol: visits are [B, nv])."""
        return self.n

    @property
    def coverage(self) -> float:
        """Fraction of the vertex space served by healthy shards (§17)."""
        if not self.down:
            return 1.0
        lost = sum(
            hi - lo for lo, hi in (self.owned_range(s) for s in self.down)
        )
        return 1.0 - lost / max(self.n, 1)

    def down_rows(self) -> np.ndarray:
        """Vertex ids owned by quarantined shards (walk rows reading zero)."""
        if not self.down:
            return np.empty(0, np.int64)
        return np.concatenate([
            np.arange(*self.owned_range(s), dtype=np.int64)
            for s in sorted(self.down)
        ])

    @property
    def cap_e(self) -> int:
        return self.shards[0].cap_e

    @property
    def m(self) -> int:
        return sum(int(img.live) for img in self.shards)

    def owned_range(self, s: int) -> tuple[int, int]:
        return s * self.rows_max, min((s + 1) * self.rows_max, self.n)

    def edges_hi(self) -> int:
        """Shared static walk bound: shards share cap_e, so the max of the
        per-shard quantized bumps is on the same lattice."""
        return max(img.edges_hi() for img in self.shards)

    def _devices(self):
        return list(np.asarray(self.mesh.devices).reshape(-1))

    def _lohi(self, img) -> tuple[np.ndarray, np.ndarray]:
        starts = np.asarray(img.starts[: self.v_pad], np.int64)
        degs = np.asarray(img.degs[: self.v_pad], np.int64)
        has = starts >= 0
        lo = np.where(has, starts, 0).astype(np.int32)
        hi = np.where(has, starts + degs, 0).astype(np.int32)
        return lo, hi

    # ------------------------------------------------------------------
    # updates: host routing + per-shard fused patches
    # ------------------------------------------------------------------
    def apply(self, plan) -> None:
        """Apply one canonical UpdatePlan across the mesh.

        Width groups route host-side to the shard owning their rows and
        each shard patches its slice through the unchanged fused
        ``slot_update`` dispatch — exactly one device program per
        touched shard (its buffers are committed to its device, so the
        patch executes there).  Vertex growth or a shard whose bump
        slack is exhausted falls back to ONE gather + host-apply +
        re-shard — the relocation path that can move rows across shard
        boundaries.

        Failover semantics (§17): a sub routed to a quarantined shard
        spools (the plan is still *accepted* — the spool replays through
        the shard's fused patch path on reintegration); a shard that
        faults during its patch is quarantined mid-call and the
        remaining shards still receive their slices, so healthy shards
        never diverge from the WAL.  Callers detect new quarantines by
        watching ``down`` — apply() itself stays non-raising for patch
        faults.  Vertex growth (a global re-shard) while degraded raises
        :class:`ShardDownError`.
        """
        if self._frozen:
            raise RuntimeError("sealed walk generation is read-only")
        plan.validate()
        if plan.n_ops == 0:
            return
        if plan.max_insert_vertex() >= self.n:
            if self.down:
                raise ShardDownError(
                    f"vertex growth needs a global re-shard but shards "
                    f"{sorted(self.down)} are quarantined — rebuild first"
                )
            self._rebuild(extra=(plan,))
            return
        failed = []
        for sid, sub in route_updates(plan, self.n_shards, self.rows_max):
            if sid in self.down:
                self._spool.setdefault(sid, []).append(sub)
                continue
            img = self.shards[sid]
            try:
                _fi.fire("shard.patch")
                img.queue(sub)
                ok = img.flush()
            except Exception:
                # device fault mid-patch: quarantine THIS shard, make
                # sure its sub spools exactly once (flush leaves a
                # failed sub queued; quarantine drains the queue), and
                # keep patching the rest of the mesh.
                self.quarantine(sid)
                spool = self._spool[sid]
                if not spool or spool[-1] is not sub:
                    spool.append(sub)
                continue
            if not ok:
                failed.append(sid)  # sub / compaction request pends on img
                continue
            if self._integrity is not None:
                self._integrity[sid] = _shard_crc_table(img)
            self._corrupt_tick(sid)
        self._placed = None
        if failed:
            if self.down:
                # a global re-shard is impossible while degraded: the
                # overflowing shards join the quarantine (their pending
                # plans drain into the spool) instead of wedging apply.
                for sid in failed:
                    self.quarantine(sid)
            else:
                self._rebuild()

    def _rebuild(self, extra=()) -> None:
        """Gather + host-apply unapplied plans + re-shard ONCE."""
        if self.down:
            raise ShardDownError(
                f"global re-shard with shards {sorted(self.down)} "
                f"quarantined — rebuild them first"
            )
        src, dst, wgt = _gather_coo(self)
        plans = [p for img in self.shards for p in img._pending]
        plans.extend(extra)
        n_new = self.n
        for p in plans:
            n_new = max(n_new, p.max_insert_vertex() + 1)
        for p in plans:
            src, dst, wgt = _host_apply(src, dst, wgt, p)
        c = csr_mod.from_coo(src, dst, wgt, n=n_new, dedup=False)
        g = shard_csr(c, self.n_shards, mesh=self.mesh, dense=None)
        self.shards = g.shards
        self.n = g.n
        self.rows_max = g.rows_max
        self.dense = g.dense
        self.generation += 1
        self._placed = None
        if self._integrity is not None:
            self.enable_integrity()

    # ------------------------------------------------------------------
    # failover: quarantine / integrity / reintegration (DESIGN.md §17)
    # ------------------------------------------------------------------
    def quarantine(self, sid: int) -> None:
        """Mark one shard down and drain its unapplied plans to the spool.

        Idempotent.  The shard's image stays in ``shards`` (walks mask
        its row intervals to zero-length), its integrity entry drops
        (the content is no longer trusted), and every later routed
        update for it spools until :meth:`reintegrate`.
        """
        sid = int(sid)
        if not (0 <= sid < self.n_shards):
            raise ValueError(f"quarantine: no shard {sid}")
        if sid in self.down:
            return
        self.down.add(sid)
        img = self.shards[sid]
        spool = self._spool.setdefault(sid, [])
        spool.extend(img._pending)
        img._pending.clear()
        img._stale = False
        if self._integrity is not None:
            self._integrity.pop(sid, None)
        self._placed = None

    def reintegrate(self, sid: int, img) -> None:
        """Atomically swap a rebuilt image in for a quarantined shard.

        The shard audit must pass on the candidate BEFORE the swap
        becomes durable: on audit failure the old (garbage) image is
        restored and the shard stays down — a reader can never observe
        a half-reintegrated shard, because readers only see the swap
        via the NEXT sealed generation.
        """
        sid = int(sid)
        if sid not in self.down:
            raise ValueError(f"reintegrate: shard {sid} is not quarantined")
        if img.cap_e != self.cap_e or int(img.nv) != self.v_pad:
            raise ValueError(
                f"reintegrate: shard {sid} image layout (cap_e={img.cap_e}, "
                f"nv={img.nv}) != mesh layout (cap_e={self.cap_e}, "
                f"nv={self.v_pad})"
            )
        old = self.shards[sid]
        self.shards[sid] = img
        self.down.discard(sid)
        try:
            self.audit_shard(sid, verify=False)
        except Exception:
            self.shards[sid] = old
            self.down.add(sid)
            raise
        self._spool.pop(sid, None)
        if self._integrity is not None:
            self._integrity[sid] = _shard_crc_table(img)
        self._placed = None

    def spooled(self, sid: int) -> list:
        """The quarantine-window FIFO of routed subplans for one shard."""
        return list(self._spool.get(int(sid), ()))

    def enable_integrity(self) -> None:
        """Start maintaining per-shard CRC descriptors (§17 detection).

        Each successful fused patch refreshes its shard's table
        transactionally, so any out-of-band mutation (bit rot, a buggy
        kernel, ``shard.corrupt`` injection) is caught by the next
        :meth:`verify_shard` / :meth:`audit_shard`.  Opt-in: hashing
        pulls the device payload to host, which the benchmarks must not
        pay.
        """
        self._integrity = {
            s: _shard_crc_table(img)
            for s, img in enumerate(self.shards)
            if s not in self.down
        }

    def shard_descriptor(self, sid: int) -> dict:
        """Current integrity descriptor of one shard (seal/checkpoint
        callers persist this next to the payload)."""
        return _shard_crc_table(self.shards[int(sid)])

    def verify_shard(self, sid: int) -> None:
        """Recompute one shard's descriptor against the maintained table.

        Raises :class:`ShardIntegrityError` naming the damaged buffers
        and chunk indices.  No-op when integrity tracking is off; a
        shard with no entry yet (fresh reintegration) is seeded.
        """
        if self._integrity is None:
            return
        sid = int(sid)
        img = self.shards[sid]
        want = self._integrity.get(sid)
        if want is None:
            self._integrity[sid] = _shard_crc_table(img)
            return
        got = _shard_crc_table(img)
        if got == want:
            return
        bad = []
        for k in _INTEGRITY_BUFS:
            if len(want[k]) != len(got[k]):
                bad.append(f"{k}: chunk count {len(want[k])} -> {len(got[k])}")
                continue
            chunks = [
                i for i, (a, b) in enumerate(zip(want[k], got[k])) if a != b
            ]
            if chunks:
                bad.append(f"{k}: chunks {chunks[:4]}")
        for k in ("geom", "live", "bump"):
            if want[k] != got[k]:
                bad.append(f"{k}: {want[k]} -> {got[k]}")
        raise ShardIntegrityError(
            sid, "integrity", "; ".join(bad) or "descriptor mismatch"
        )

    def audit_shard(self, sid: int, *, verify: bool = True) -> dict:
        """One shard's structural audit + stray-row pass + CRC verify."""
        sid = int(sid)
        if sid in self.down:
            raise ShardDownError(f"audit_shard: shard {sid} is quarantined")
        img = self.shards[sid]
        report = img.audit()
        lo_v, hi_v = self.owned_range(sid)
        degs = np.asarray(img.degs[: self.v_pad], np.int64)
        stray = degs.copy()
        stray[lo_v:hi_v] = 0
        if stray.any():
            raise ShardFaultError(
                sid, "audit",
                f"edges on non-owned rows {np.nonzero(stray)[0][:8].tolist()}",
            )
        if verify:
            self.verify_shard(sid)
        return report

    def _corrupt_tick(self, sid: int) -> None:
        """``shard.corrupt`` injection point: after a successful patch,
        silently flip a live weight on this shard — no exception escapes
        (that is the point: only the integrity pass can see it)."""
        try:
            _fi.fire("shard.corrupt")
        except _fi.InjectedKernelError:
            from ..runtime import failover

            failover.corrupt_shard(self, sid, kind="wgt")

    def seal_generation(self, generation: int = 0) -> "ShardedGraph":
        """Seal the mesh as one immutable read-only generation (§16/§17).

        Every healthy shard seals O(1) via :meth:`WalkImage.seal` (the
        live images turn copy-on-write); quarantined shards keep their
        live reference but stay masked — the generation's ``coverage``
        and ``down_rows`` tell readers exactly what the walk covers.
        """
        sealed = [
            img if s in self.down else img.seal(generation)
            for s, img in enumerate(self.shards)
        ]
        return ShardedGraph(
            shards=sealed, n=self.n, rows_max=self.rows_max,
            n_shards=self.n_shards, mesh=self.mesh, dense=self.dense,
            generation=self.generation, down=set(self.down), _frozen=True,
        )

    def block_on(self) -> None:
        """Barrier: wait for every shard's device buffers (bench timing)."""
        for img in self.shards:
            jax.block_until_ready(img.dst)

    # ------------------------------------------------------------------
    # traversal: one program, frontier-exchange only
    # ------------------------------------------------------------------
    def _assemble(self):
        """(dst_g, lo_g, hi_g) walk operands, memoized until the next apply.

        Mesh mode builds the global [S, ...] arrays zero-copy from the
        per-shard committed buffers (``make_array_from_single_device_
        arrays``); local mode stacks them on the one device.
        """
        if self._placed is not None:
            return self._placed
        S, v_pad, cap_e = self.n_shards, self.v_pad, self.cap_e
        zero = None
        if self.down:
            zero = (np.zeros(v_pad, np.int32), np.zeros(v_pad, np.int32))
        lohi = [
            zero if s in self.down else self._lohi(img)
            for s, img in enumerate(self.shards)
        ]
        if self.mesh is None:
            dst_g = jnp.stack([img.dst for img in self.shards])
            lo_g = jnp.stack([jnp.asarray(lo) for lo, _ in lohi])
            hi_g = jnp.stack([jnp.asarray(hi) for _, hi in lohi])
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            devs = self._devices()
            sh = NamedSharding(self.mesh, P("data", None))

            def _global(shape, parts):
                return jax.make_array_from_single_device_arrays(
                    shape, sh, parts
                )

            dst_g = _global(
                (S, cap_e),
                [jnp.reshape(img.dst, (1, cap_e)) for img in self.shards],
            )
            lo_g = _global(
                (S, v_pad),
                [
                    jax.device_put(lo.reshape(1, v_pad), d)
                    for (lo, _), d in zip(lohi, devs)
                ],
            )
            hi_g = _global(
                (S, v_pad),
                [
                    jax.device_put(hi.reshape(1, v_pad), d)
                    for (_, hi), d in zip(lohi, devs)
                ],
            )
        self._placed = (dst_g, lo_g, hi_g)
        return self._placed

    def reverse_walk(self, steps: int, *, visits0=None):
        """k-step reverse walk; [n] (or [B, n] with ``visits0`` [B, n]).

        One jitted program per walk: the shard_map frontier-exchange
        build on a mesh, or its bit-identical local emulation on one
        device.  Unweighted visit counts are exact small integers in
        f32, so both modes (and the single-device WalkImage path) agree
        bitwise on the graphs the parity suite sweeps.

        Quarantined shards are masked out (their rows read exact zeros);
        a healthy shard that faults here raises :class:`ShardFaultError`
        carrying its ``sid`` so the serving layer can quarantine it and
        retry degraded instead of failing the batch.
        """
        from ..kernels.slot_walk import sharded as _sw

        for s in range(self.n_shards):
            if s in self.down:
                continue
            try:
                _fi.fire("shard.walk")
            except Exception as e:
                raise ShardFaultError(s, "walk", str(e)) from e
        nwalks = 0 if visits0 is None else int(visits0.shape[0])
        b = max(nwalks, 1)
        vis = np.ones((b, self.v_pad), np.float32)
        if visits0 is not None:
            # pad rows keep 1.0 — no edge ever references them, so their
            # value is unobservable and trimmed from the result
            vis[:, : self.n] = np.asarray(visits0, np.float32)
        dst_g, lo_g, hi_g = self._assemble()
        e_hi = self.edges_hi()
        if self.mesh is None:
            fn = _sw.make_local_walk(
                steps, self.n_shards, self.rows_max, self.cap_e, e_hi, nwalks
            )
            out = fn(dst_g, lo_g, hi_g, jnp.asarray(vis))
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            fn = _sw.make_sharded_walk(
                self.mesh, steps, self.n_shards, self.rows_max, self.cap_e,
                e_hi, nwalks,
            )
            vis_r = jax.device_put(
                vis, NamedSharding(self.mesh, P(None, None))
            )
            out = fn(dst_g, lo_g, hi_g, vis_r)
        walk_image.STATS["dispatches"] += 1
        out = out[:, : self.n]
        return out[0] if visits0 is None else out

    def walk(self, steps: int, *, visits0=None, backend: str = "auto"):
        """WalkImage-protocol alias (serve-layer dispatch target).

        ``backend`` is accepted for protocol compatibility and ignored —
        the sharded program picks its own lowering.
        """
        del backend
        return self.reverse_walk(steps, visits0=visits0)

    def collective_bytes_per_step(self, steps: int, *, nwalks: int = 0) -> int:
        """Measured per-device collective bytes per walk step (jaxpr proof).

        0 in local mode — the emulation genuinely exchanges nothing.
        """
        from ..kernels.slot_walk import sharded as _sw

        if self.mesh is None:
            return 0
        return _sw.collective_bytes_per_step(
            self.mesh, steps, self.n_shards, self.rows_max, self.cap_e,
            self.edges_hi(), nwalks,
        )

    # ------------------------------------------------------------------
    # checkpoint: one file per shard under a shared step manifest
    # ------------------------------------------------------------------
    def state_trees(self) -> dict:
        """{shard_id: flat state dict} — the sharded checkpoint payload."""
        if self.down:
            raise ShardDownError(
                f"state_trees: shards {sorted(self.down)} are quarantined — "
                f"a checkpoint would persist garbage; rebuild first"
            )
        out = {}
        for s, img in enumerate(self.shards):
            out[s] = {
                "dst": np.asarray(img.dst),
                "wgt": np.asarray(img.wgt),
                "rows": np.asarray(img.rows),
                "starts": np.asarray(img.starts, np.int64),
                "caps": np.asarray(img.caps, np.int64),
                "degs": np.asarray(img.degs, np.int64),
                "meta": np.asarray(
                    [img.nv, img.bump, img.live, self.n, self.rows_max,
                     self.n_shards, int(self.dense)],
                    np.int64,
                ),
            }
        return out

    def save(self, ckpt_dir: str, step: int, *, keep: int = 3) -> str:
        from ..checkpoint import manager as ckpt

        return ckpt.save_arrays_sharded(
            ckpt_dir, step, self.state_trees(), keep=keep
        )

    @classmethod
    def restore(
        cls, ckpt_dir: str, *, step: Optional[int] = None, mesh=None
    ) -> "ShardedGraph":
        """Serial per-shard replay of a sharded step manifest."""
        from ..checkpoint import manager as ckpt

        trees, _step = ckpt.restore_arrays_sharded(ckpt_dir, step=step)
        return cls.from_state_trees(trees, mesh=mesh)

    @classmethod
    def from_state_trees(cls, trees: dict, *, mesh=None) -> "ShardedGraph":
        metas = {s: t["meta"] for s, t in trees.items()}
        any_meta = next(iter(metas.values()))
        n, rows_max, n_shards, dense = (
            int(any_meta[3]), int(any_meta[4]), int(any_meta[5]),
            bool(any_meta[6]),
        )
        if sorted(trees) != list(range(n_shards)):
            raise ValueError(
                f"sharded restore: have shards {sorted(trees)}, "
                f"manifest says n_shards={n_shards}"
            )
        devs = (
            list(np.asarray(mesh.devices).reshape(-1))
            if mesh is not None
            else [None] * n_shards
        )
        shards = [
            image_from_state_tree(trees[s], device=devs[s])
            for s in range(n_shards)
        ]
        return cls(
            shards=shards, n=n, rows_max=rows_max, n_shards=n_shards,
            mesh=mesh, dense=dense,
        )

    def audit(self) -> dict:
        """Per-shard image audits plus the cross-shard boundary pass.

        Quarantined shards are skipped (their content is untrusted by
        definition) and reported in ``down`` — a degraded mesh audits
        clean on its healthy part instead of tripping on garbage.
        """
        reports = [
            None if s in self.down else self.audit_shard(s)
            for s in range(self.n_shards)
        ]
        return {"shards": reports, "m": self.m, "down": sorted(self.down)}


# ---------------------------------------------------------------------------
# construction / routing / gathering
# ---------------------------------------------------------------------------
def shard_csr(
    c: csr_mod.CSR,
    n_shards: int,
    *,
    mesh=None,
    dense: Optional[bool] = None,
) -> ShardedGraph:
    """Partition a CSR into per-shard WalkImages on a block vertex layout.

    All shards share one cap_e (``min_cap_e`` floors each build at the
    largest shard's natural capacity) so every per-shard program — walk
    step, fused patch — compiles once for the whole mesh.  With a mesh,
    each shard's device payload is committed to its own device; without
    one the graph runs in single-device local mode (parity tests, the
    shards=1 bench row).
    """
    if n_shards < 1:
        raise ValueError(f"shard_csr: n_shards must be >= 1, got {n_shards}")
    if c.n < n_shards:
        raise ValueError(
            f"shard_csr: need n >= n_shards, got n={c.n}, S={n_shards}"
        )
    if mesh is not None and len(np.asarray(mesh.devices).reshape(-1)) != n_shards:
        raise ValueError("shard_csr: mesh device count != n_shards")
    rows_max = -(-c.n // n_shards)
    v_pad = n_shards * rows_max
    o = np.asarray(c.offsets, np.int64)
    d = np.asarray(c.dst)
    w = (
        np.asarray(c.wgt, np.float32)
        if c.wgt is not None
        else np.ones(c.m, np.float32)
    )
    deg = np.diff(o)
    if dense is None:
        dense = _dense_policy(deg, int(c.m))

    deg_full = np.zeros(v_pad, np.int64)
    deg_full[: c.n] = deg
    cap_shared = max(
        _shard_cap(deg_full[s * rows_max:(s + 1) * rows_max], dense)
        for s in range(n_shards)
    )
    devs = (
        list(np.asarray(mesh.devices).reshape(-1))
        if mesh is not None
        else [None] * n_shards
    )
    shards = []
    for s in range(n_shards):
        lo_v = s * rows_max
        hi_v = min((s + 1) * rows_max, c.n)
        deg_s = np.zeros(v_pad, np.int64)
        if hi_v > lo_v:
            deg_s[lo_v:hi_v] = deg[lo_v:hi_v]
        offsets_s = np.concatenate([[0], np.cumsum(deg_s)])
        e0, e1 = (int(o[lo_v]), int(o[hi_v])) if hi_v > lo_v else (0, 0)
        img = walk_image.WalkImage.from_csr_arrays(
            offsets_s, d[e0:e1], w[e0:e1], v_pad,
            dense=dense, min_cap_e=cap_shared,
        )
        if devs[s] is not None:
            img.dst = jax.device_put(img.dst, devs[s])
            img.wgt = jax.device_put(img.wgt, devs[s])
            img.rows = jax.device_put(img.rows, devs[s])
        shards.append(img)
    return ShardedGraph(
        shards=shards, n=int(c.n), rows_max=rows_max, n_shards=n_shards,
        mesh=mesh, dense=bool(dense),
    )


def route_updates(plan, n_shards: int, rows_max: int):
    """Slice a canonical UpdatePlan by owning shard: [(shard_id, subplan)].

    The op stream is (src, dst)-sorted, so each shard's ops are one
    contiguous slice — routing is a searchsorted over the block
    boundaries, zero re-sort, and every slice is itself canonical
    (strictly increasing keys), so ``plan_from_canonical`` rebuilds the
    per-shard run structure byte-identically to a locally-planned batch.
    Ops beyond the padded vertex space land on the last shard, where the
    image's own row-range filter drops them (out-of-range deletes stay
    silently filtered, as everywhere else).
    """
    bounds = np.arange(1, n_shards, dtype=np.int64) * rows_max
    cuts = np.searchsorted(plan.q_src, bounds, side="left")
    idx = np.concatenate([[0], cuts, [plan.n_ops]]).astype(np.int64)
    out = []
    for s in range(n_shards):
        a, b = int(idx[s]), int(idx[s + 1])
        if a == b:
            continue
        out.append((
            s,
            upd_mod.plan_from_canonical(
                plan.q_src[a:b], plan.q_dst[a:b],
                plan.q_wgt[a:b], plan.q_del[a:b],
            ),
        ))
    return out


def _image_coo(img, lo_v: int, hi_v: int, n: int, v_pad: int, sid: int):
    """One shard's live (src, dst, wgt) from its block prefixes, validated.

    Per-shard pow-2 slack drops by construction (only ``deg`` slots per
    row are read).  Edges on rows the shard does not own, or destination
    ids outside ``[0, n)``, raise — silent mis-stitching of the
    reassembled offsets is exactly the failure mode this guards.
    """
    degs = np.asarray(img.degs[:v_pad], np.int64)
    stray = degs.copy()
    stray[lo_v:hi_v] = 0
    if stray.any():
        bad = np.nonzero(stray)[0][:8].tolist()
        raise ValueError(
            f"gather_csr: shard {sid} owns rows [{lo_v}, {hi_v}) but "
            f"carries edges on rows {bad} — shard row-count mismatch"
        )
    dg = degs[lo_v:hi_v]
    m_s = int(dg.sum())
    if m_s == 0:
        z = np.empty(0, np.int64)
        return z, z.copy(), np.empty(0, np.float32)
    starts = np.asarray(img.starts[lo_v:hi_v], np.int64)
    first = np.cumsum(dg) - dg
    gidx = np.repeat(starts, dg) + (
        np.arange(m_s, dtype=np.int64) - np.repeat(first, dg)
    )
    d = np.asarray(img.dst)[gidx]
    if bool((d == SENTINEL).any()) or bool((d >= n).any()):
        raise ValueError(
            f"gather_csr: shard {sid} live prefix holds destination ids "
            f"outside [0, {n}) — shard row-count mismatch"
        )
    return (
        np.repeat(np.arange(lo_v, hi_v, dtype=np.int64), dg),
        d.astype(np.int64),
        np.asarray(img.wgt)[gidx].astype(np.float32),
    )


def _gather_coo(g: ShardedGraph):
    """Live (src, dst, wgt) from every shard's block prefixes, validated."""
    if g.down:
        raise ShardDownError(
            f"gather: shards {sorted(g.down)} are quarantined — a global "
            f"gather would stitch garbage; rebuild first"
        )
    srcs, dsts, wgts = [], [], []
    for s, img in enumerate(g.shards):
        lo_v, hi_v = g.owned_range(s)
        src_s, dst_s, wgt_s = _image_coo(img, lo_v, hi_v, g.n, g.v_pad, s)
        if src_s.shape[0] == 0:
            continue
        srcs.append(src_s)
        dsts.append(dst_s)
        wgts.append(wgt_s)
    if not srcs:
        z = np.empty(0, np.int64)
        return z, z.copy(), np.empty(0, np.float32)
    return (
        np.concatenate(srcs), np.concatenate(dsts),
        np.concatenate(wgts).astype(np.float32),
    )


def gather_csr(g: ShardedGraph) -> csr_mod.CSR:
    """Collect the sharded graph back into a host CSR (tests, rebuilds)."""
    src, dst, wgt = _gather_coo(g)
    return csr_mod.from_coo(src, dst, wgt, n=g.n, dedup=False)


def _host_apply(src, dst, wgt, plan):
    """Apply one canonical plan to host COO arrays (the rebuild path).

    Keys touched by the plan (either op kind) drop from the old stream —
    an insert replaces, a delete removes — then the plan's inserts
    append.  ``from_coo`` re-sorts afterwards.
    """
    keys = (src.astype(np.int64) << 32) | dst.astype(np.int64)
    pk = (plan.q_src.astype(np.int64) << 32) | plan.q_dst.astype(np.int64)
    pos = np.searchsorted(pk, keys)
    pos_c = np.minimum(pos, max(pk.shape[0] - 1, 0))
    hit = (pos < pk.shape[0]) & (pk[pos_c] == keys) if pk.shape[0] else (
        np.zeros(keys.shape[0], bool)
    )
    ins = ~plan.q_del
    return (
        np.concatenate([src[~hit], plan.q_src[ins].astype(np.int64)]),
        np.concatenate([dst[~hit], plan.q_dst[ins].astype(np.int64)]),
        np.concatenate([wgt[~hit], plan.q_wgt[ins]]).astype(np.float32),
    )


def image_from_state_tree(t: dict, *, device=None) -> walk_image.WalkImage:
    """Build ONE shard's WalkImage from its flat checkpoint state dict.

    The single-shard slice of :meth:`ShardedGraph.from_state_trees` —
    the §17 online rebuild restores exactly one shard this way and
    replays its WAL window into it before reintegration.
    """
    nv, bump, live = int(t["meta"][0]), int(t["meta"][1]), int(t["meta"][2])
    put = (lambda a: jax.device_put(a, device)) if device is not None \
        else jnp.asarray
    return walk_image.WalkImage(
        dst=put(t["dst"]), wgt=put(t["wgt"]), rows=put(t["rows"]),
        starts=np.asarray(t["starts"], np.int64).copy(),
        caps=np.asarray(t["caps"], np.int64).copy(),
        degs=np.asarray(t["degs"], np.int64).copy(),
        nv=nv, bump=bump, live=live,
        base_occupancy=live / max(bump, 1),
    )


def shard_image_apply(g: ShardedGraph, sid: int, img, sub):
    """Apply one routed subplan to a standalone (not-yet-reintegrated)
    shard image through the same fused ``slot_update`` path the live
    mesh uses; returns the image (possibly repacked).

    Overflow/compaction cannot take the global re-shard (the mesh is
    degraded — that is why this image exists): the shard repacks ALONE
    at the shared ``cap_e``, falling back to the §12 dense layout
    (occupancy 1.0 — the minimal footprint) when the policy layout's
    slack no longer fits.  If even the dense repack exceeds the shared
    capacity the single-shard rebuild is impossible and
    :class:`ShardDownError` directs the caller to a full ``recover()``.
    """
    img.queue(sub)
    if img.flush():
        return img
    lo_v, hi_v = g.owned_range(sid)
    pending = list(img._pending)
    img._pending.clear()
    img._stale = False
    src, dst, wgt = _image_coo(img, lo_v, hi_v, g.n, g.v_pad, sid)
    for p in pending:
        src, dst, wgt = _host_apply(src, dst, wgt, p)
    c = csr_mod.from_coo(src, dst, wgt, n=g.v_pad, dedup=False)
    offs = np.asarray(c.offsets, np.int64)
    dsts = np.asarray(c.dst)
    wgts = (
        np.asarray(c.wgt, np.float32)
        if c.wgt is not None else np.ones(c.m, np.float32)
    )
    new = walk_image.WalkImage.from_csr_arrays(
        offs, dsts, wgts, g.v_pad, dense=g.dense, min_cap_e=g.cap_e,
    )
    if new.cap_e != g.cap_e and not g.dense:
        new = walk_image.WalkImage.from_csr_arrays(
            offs, dsts, wgts, g.v_pad, dense=True, min_cap_e=g.cap_e,
        )
    if new.cap_e > g.cap_e and int(new.bump) <= g.cap_e:
        # the build's pow-2 bump reserve overshot the shared capacity
        # but the slots themselves fit: trim to the mesh's program
        # shape (the shard just has less relocation slack than policy —
        # the next overflow takes the healthy-mesh global re-shard)
        new.dst = new.dst[: g.cap_e]
        new.wgt = new.wgt[: g.cap_e]
        new.rows = new.rows[: g.cap_e]
    if new.cap_e != g.cap_e:
        raise ShardDownError(
            f"shard {sid} outgrew the shared cap_e ({new.cap_e} > "
            f"{g.cap_e}) during single-shard rebuild — the mesh needs a "
            f"global re-shard; run a full recover()"
        )
    if g.mesh is not None:
        dev = g._devices()[sid]
        new.dst = jax.device_put(new.dst, dev)
        new.wgt = jax.device_put(new.wgt, dev)
        new.rows = jax.device_put(new.rows, dev)
    return new


def reverse_walk(g: ShardedGraph, steps: int, *, visits0=None):
    """Module-level convenience wrapper over ``ShardedGraph.reverse_walk``."""
    return g.reverse_walk(steps, visits0=visits0)
