"""Distributed dynamic graph: the paper's per-partition CSR (Alg 5) as the
shard layout of a multi-pod mesh (DESIGN.md §5).

Vertices are block-partitioned over the mesh's data axes (each shard owns a
contiguous vertex range — the analogue of the paper's per-thread partition);
edges live with their source vertex.  Three distributed operations:

  * ``reverse_walk`` — per-step: all-gather the frontier (visits vector),
    local gather + segment-sum.  This is the halo exchange of a 1-D vertex
    partitioning; the collective term is |V|·4 bytes per step per shard.
  * ``route_updates`` — bucket a batch by owning shard (host), pad buckets
    to a shared pow-2 width (CP2AA bucketing keeps the all-to-all shape
    stable across steps), exchange, apply locally.
  * ``apply_updates`` — per-shard sort-merge into the local padded CSR
    (functional; local slack follows the same pow-2 class policy).

Implementation notes: everything here is mesh-generic ``shard_map`` code.
Tests run it on a small forced-host-device mesh; the dry-run lowers it on
the production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import alloc, csr as csr_mod, util

if hasattr(jax, "shard_map"):  # jax >= 0.5
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        # older jax spells check_vma as check_rep
        return _shard_map_legacy(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )

SENTINEL = util.SENTINEL


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Equal-size per-shard slotted rows: [S, rows_per_shard * slots]."""

    src_local: jnp.ndarray   # [S, E_loc] local row id (or SENTINEL)
    dst: jnp.ndarray         # [S, E_loc] global dst   (or SENTINEL)
    wgt: jnp.ndarray         # [S, E_loc]
    n: int                   # global vertex count
    rows_per_shard: int
    n_shards: int

    @property
    def e_loc(self) -> int:
        return int(self.dst.shape[1])


def shard_csr(c: csr_mod.CSR, n_shards: int) -> ShardedGraph:
    """Partition a CSR into equal vertex blocks with pow-2 local capacity."""
    rows_per = -(-c.n // n_shards)
    o = np.asarray(c.offsets)
    d = np.asarray(c.dst)
    w = np.asarray(c.wgt) if c.wgt is not None else np.ones(c.m, np.float32)
    counts = [
        int(o[min((s + 1) * rows_per, c.n)] - o[min(s * rows_per, c.n)])
        for s in range(n_shards)
    ]
    e_loc = alloc.next_pow2(max(max(counts), 1))
    src_l = np.full((n_shards, e_loc), SENTINEL, np.int32)
    dst_l = np.full((n_shards, e_loc), SENTINEL, np.int32)
    wgt_l = np.zeros((n_shards, e_loc), np.float32)
    rows_global = np.repeat(np.arange(c.n), np.diff(o))
    for s in range(n_shards):
        lo, hi = o[min(s * rows_per, c.n)], o[min((s + 1) * rows_per, c.n)]
        k = hi - lo
        src_l[s, :k] = rows_global[lo:hi] - s * rows_per
        dst_l[s, :k] = d[lo:hi]
        wgt_l[s, :k] = w[lo:hi]
    return ShardedGraph(
        src_local=jnp.asarray(src_l),
        dst=jnp.asarray(dst_l),
        wgt=jnp.asarray(wgt_l),
        n=int(c.n),
        rows_per_shard=rows_per,
        n_shards=n_shards,
    )


def _walk_step(src_local, dst, visits_local, rows_per_shard, axis):
    """One reverse-walk step inside shard_map: all-gather frontier, local
    gather + segment-sum.  visits_local: [rows_per_shard]."""
    frontier = jax.lax.all_gather(visits_local, axis, tiled=True)  # [n_global_pad]
    valid = dst != SENTINEL
    vals = jnp.where(valid, frontier[jnp.clip(dst, 0, frontier.shape[0] - 1)], 0.0)
    seg = jnp.where(valid, src_local, rows_per_shard).astype(jnp.int32)
    out = jax.ops.segment_sum(vals, seg, num_segments=rows_per_shard + 1)
    return out[:rows_per_shard]


def make_reverse_walk(
    mesh: Mesh, steps: int, rows_per_shard: int, axis=("data",)
):
    """Build a jitted sharded reverse walk over the mesh axes ``axis``."""
    axis_names = axis if isinstance(axis, tuple) else (axis,)
    spec = P(axis_names)

    @functools.partial(
        jax.jit,
        static_argnames=(),
    )
    def walk(src_local, dst, visits0):
        def shard_fn(src_l, d, v):
            # shard_map gives [1, ...] blocks on the sharded leading dim
            src_l, d, v = src_l[0], d[0], v[0]

            def body(vis, _):
                return _walk_step(src_l, d, vis, rows_per_shard, axis_names), None

            v, _ = jax.lax.scan(body, v, None, length=steps)
            return v[None]

        return _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=spec,
            check_vma=False,
        )(src_local, dst, visits0)

    return walk


def reverse_walk(g: ShardedGraph, steps: int, mesh: Mesh, axis=("data",)):
    """Run the sharded reverse walk; returns visits [n] (host-trimmed)."""
    axis_names = axis if isinstance(axis, tuple) else (axis,)
    visits0 = jnp.ones((g.n_shards, g.rows_per_shard), jnp.float32)
    spec = NamedSharding(mesh, P(axis_names))
    src_local = jax.device_put(g.src_local, spec)
    dst = jax.device_put(g.dst, spec)
    visits0 = jax.device_put(visits0, spec)
    walk = make_reverse_walk(mesh, steps, g.rows_per_shard, axis_names)
    out = walk(src_local, dst, visits0)
    return out.reshape(-1)[: g.n]


# ---------------------------------------------------------------------------
# distributed batch updates
# ---------------------------------------------------------------------------
def route_updates(
    batch_src: np.ndarray,
    batch_dst: np.ndarray,
    batch_wgt: Optional[np.ndarray],
    g: ShardedGraph,
):
    """Bucket a COO batch by owning shard, pad to pow-2 width [S, K].

    On real hardware each host buckets its local slice and the exchange is
    an all-to-all; in this single-controller build the bucketing is global
    host work with the same pow-2-padded layout.
    """
    owner = batch_src // g.rows_per_shard
    # per-shard slices must stay (src, dst)-lexsorted for binary search
    order = np.lexsort((batch_dst, batch_src, owner))
    owner_s = owner[order]
    counts = np.bincount(owner_s, minlength=g.n_shards)
    k = alloc.next_pow2(max(int(counts.max()), 1))
    s_out = np.full((g.n_shards, k), SENTINEL, np.int32)
    d_out = np.full((g.n_shards, k), SENTINEL, np.int32)
    w_out = np.zeros((g.n_shards, k), np.float32)
    w = batch_wgt if batch_wgt is not None else np.ones_like(batch_src, np.float32)
    srt_s, srt_d, srt_w = batch_src[order], batch_dst[order], w[order]
    pos = 0
    for s in range(g.n_shards):
        c = int(counts[s])
        s_out[s, :c] = srt_s[pos : pos + c] - s * g.rows_per_shard
        d_out[s, :c] = srt_d[pos : pos + c]
        w_out[s, :c] = srt_w[pos : pos + c]
        pos += c
    return jnp.asarray(s_out), jnp.asarray(d_out), jnp.asarray(w_out)


@functools.lru_cache(maxsize=None)
def _jit_shard_update(out_cap: int, op: str, mesh_axes, rows_per_shard: int):
    """Per-shard sort-merge update (insert='union', delete='difference')."""

    def local(src_l, dst_l, wgt_l, bs, bd, bw):
        src_l, dst_l, wgt_l = src_l[0], dst_l[0], wgt_l[0]
        bs, bd, bw = bs[0], bd[0], bw[0]
        if op == "insert":
            s = jnp.concatenate([bs, src_l])
            d = jnp.concatenate([bd, dst_l])
            w = jnp.concatenate([bw, wgt_l])
            order = util.lexsort2(s, d)
            s, d, w = s[order], d[order], w[order]
            dup = jnp.concatenate(
                [jnp.array([False]), (s[1:] == s[:-1]) & (d[1:] == d[:-1])]
            )
            s = jnp.where(dup, SENTINEL, s)
            d = jnp.where(dup, SENTINEL, d)
            order = util.lexsort2(s, d)
            s, d, w = s[order][:out_cap], d[order][:out_cap], w[order][:out_cap]
        else:
            _, found = util.searchsorted_2d(bs, bd, src_l, dst_l)
            s = jnp.where(found, SENTINEL, src_l)
            d = jnp.where(found, SENTINEL, dst_l)
            order = util.lexsort2(s, d)
            s, d, w = s[order][:out_cap], d[order][:out_cap], wgt_l[order][:out_cap]
        m_loc = jnp.sum(s != SENTINEL, dtype=jnp.int32)
        return s[None], d[None], w[None], m_loc[None]

    def fn(mesh, src_l, dst_l, wgt_l, bs, bd, bw):
        spec = P(mesh_axes)
        return _shard_map(
            local,
            mesh=mesh,
            in_specs=(spec,) * 6,
            out_specs=(spec, spec, spec, P(mesh_axes)),
            check_vma=False,
        )(src_l, dst_l, wgt_l, bs, bd, bw)

    return fn


def apply_updates(
    g: ShardedGraph,
    batch_src: np.ndarray,
    batch_dst: np.ndarray,
    batch_wgt: Optional[np.ndarray],
    mesh: Mesh,
    *,
    op: str = "insert",
    axis=("data",),
) -> ShardedGraph:
    axis_names = axis if isinstance(axis, tuple) else (axis,)
    bs, bd, bw = route_updates(batch_src, batch_dst, batch_wgt, g)
    if op == "insert":
        out_cap = alloc.next_pow2(g.e_loc + int(bs.shape[1]))
    else:
        out_cap = g.e_loc
    fn = _jit_shard_update(out_cap, op, axis_names, g.rows_per_shard)
    spec = NamedSharding(mesh, P(axis_names))
    args = [jax.device_put(x, spec) for x in (g.src_local, g.dst, g.wgt, bs, bd, bw)]
    s, d, w, m_loc = jax.jit(
        functools.partial(fn, mesh)
    )(*args)
    return dataclasses.replace(
        g, src_local=s, dst=d, wgt=w
    ), int(jnp.sum(m_loc))


def gather_csr(g: ShardedGraph) -> csr_mod.CSR:
    """Collect the sharded graph back into a host CSR (tests)."""
    s = np.asarray(g.src_local)
    d = np.asarray(g.dst)
    w = np.asarray(g.wgt)
    srcs, dsts, wgts = [], [], []
    for sh in range(g.n_shards):
        mask = s[sh] != SENTINEL
        srcs.append(s[sh][mask].astype(np.int64) + sh * g.rows_per_shard)
        dsts.append(d[sh][mask])
        wgts.append(w[sh][mask])
    return csr_mod.from_coo(
        np.concatenate(srcs), np.concatenate(dsts), np.concatenate(wgts), n=g.n,
        dedup=False,
    )
