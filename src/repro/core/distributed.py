"""Multi-device sharded walk images (DESIGN.md §14).

``ShardedGraph`` is a thin wrapper over per-shard ``WalkImage``s: vertices
block-partition over a 1-D ``("data",)`` mesh (shard s owns the contiguous
range ``[s·rows_max, (s+1)·rows_max)``, the analogue of the paper's Alg-5
per-thread partition), and each shard's edges live in its OWN standard
walk image — same packed tiles, same CP2AA/dense layout policy, same
``kernels/slot_walk`` / ``kernels/slot_update`` programs as the
single-device path.  There is no bespoke distributed walk or apply any
more:

  * ``reverse_walk`` — ONE jitted shard_map program
    (``kernels/slot_walk/sharded``): every shard runs the blocked
    interval step on its tiles and the only cross-shard exchange per
    step is the frontier all_gather, (S-1)·rows_max·4 ≈ |V|·4 bytes per
    device per step.  Shard cuts align to block boundaries by
    construction, so the hierarchical prefix's inter-tile base scan
    cancels inside each shard and never crosses devices.
  * ``apply`` — ``route_updates`` slices a canonical ``UpdatePlan`` by
    owning shard on host (the stream is (src, dst)-sorted, so routing is
    a searchsorted over block boundaries — zero re-sort) and each shard
    patches its slice through its image's fused ``slot_update`` path:
    one dispatch per device per plan, executing on the shard's own
    device because its buffers are committed there.
  * ``gather_csr`` — reassembles a host CSR from the live block
    prefixes (per-shard pow-2 slack drops by construction), validating
    that every shard's edges sit inside its owned row range — a
    row-count mismatch raises instead of silently mis-stitching offsets.

Growth and overflow take the rebuild path every representation uses:
gather, host-apply the unapplied plans, re-shard once — this is how a
grown row (or a new vertex) relocates across a shard boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, updates as upd_mod, util, walk_image
from ..launch import mesh as mesh_mod

SENTINEL = util.SENTINEL


def _dense_policy(deg: np.ndarray, m: int) -> bool:
    """The §12 compaction decision, made ONCE globally so every shard
    builds the same layout (and the jit-shape lattice stays shared)."""
    caps = np.where(deg > 0, alloc.edge_capacities(deg), 0)
    total = int(caps.sum())
    return m > 0 and m < walk_image.DENSE_THRESHOLD * total


def _shard_cap(deg_s: np.ndarray, dense: bool) -> int:
    """The cap_e ``WalkImage.from_csr_arrays`` would pick for one shard."""
    if dense:
        total = int(deg_s.sum())
    else:
        total = int(np.where(deg_s > 0, alloc.edge_capacities(deg_s), 0).sum())
    return alloc.pow2_with_headroom(total, 1.0 if dense else 0.25)


@dataclasses.dataclass
class ShardedGraph:
    """Per-shard WalkImages over a block vertex partition (DESIGN.md §14).

    Every image spans the PADDED global vertex space ``v_pad =
    n_shards·rows_max`` (so visit vectors concatenate without index
    remapping — vertex ids are identical on every shard) but holds only
    its owned rows' blocks; rows outside the owned range have no block
    and contribute exact zeros to the walk step.
    """

    shards: list          # [S] WalkImage, nv == v_pad each
    n: int                # true global vertex count (<= v_pad)
    rows_max: int         # vertices per shard block
    n_shards: int
    mesh: Optional[object] = None   # jax Mesh; None = single-device local mode
    dense: bool = False             # global layout policy (shared by shards)
    #: bumped by every ``_rebuild`` — lets observers (the §15 dirty-block
    #: tracker) distinguish in-place per-shard patches from a global
    #: re-shard that invalidates every shard's layout
    generation: int = dataclasses.field(default=0, compare=False)
    _placed: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------------
    @property
    def v_pad(self) -> int:
        return self.n_shards * self.rows_max

    @property
    def cap_e(self) -> int:
        return self.shards[0].cap_e

    @property
    def m(self) -> int:
        return sum(int(img.live) for img in self.shards)

    def owned_range(self, s: int) -> tuple[int, int]:
        return s * self.rows_max, min((s + 1) * self.rows_max, self.n)

    def edges_hi(self) -> int:
        """Shared static walk bound: shards share cap_e, so the max of the
        per-shard quantized bumps is on the same lattice."""
        return max(img.edges_hi() for img in self.shards)

    def _devices(self):
        return list(np.asarray(self.mesh.devices).reshape(-1))

    def _lohi(self, img) -> tuple[np.ndarray, np.ndarray]:
        starts = np.asarray(img.starts[: self.v_pad], np.int64)
        degs = np.asarray(img.degs[: self.v_pad], np.int64)
        has = starts >= 0
        lo = np.where(has, starts, 0).astype(np.int32)
        hi = np.where(has, starts + degs, 0).astype(np.int32)
        return lo, hi

    # ------------------------------------------------------------------
    # updates: host routing + per-shard fused patches
    # ------------------------------------------------------------------
    def apply(self, plan) -> None:
        """Apply one canonical UpdatePlan across the mesh.

        Width groups route host-side to the shard owning their rows and
        each shard patches its slice through the unchanged fused
        ``slot_update`` dispatch — exactly one device program per
        touched shard (its buffers are committed to its device, so the
        patch executes there).  Vertex growth or a shard whose bump
        slack is exhausted falls back to ONE gather + host-apply +
        re-shard — the relocation path that can move rows across shard
        boundaries.
        """
        plan.validate()
        if plan.n_ops == 0:
            return
        if plan.max_insert_vertex() >= self.n:
            self._rebuild(extra=(plan,))
            return
        failed = False
        for sid, sub in route_updates(plan, self.n_shards, self.rows_max):
            img = self.shards[sid]
            img.queue(sub)
            if not img.flush():
                failed = True  # sub (or a compaction request) pends on img
        self._placed = None
        if failed:
            self._rebuild()

    def _rebuild(self, extra=()) -> None:
        """Gather + host-apply unapplied plans + re-shard ONCE."""
        src, dst, wgt = _gather_coo(self)
        plans = [p for img in self.shards for p in img._pending]
        plans.extend(extra)
        n_new = self.n
        for p in plans:
            n_new = max(n_new, p.max_insert_vertex() + 1)
        for p in plans:
            src, dst, wgt = _host_apply(src, dst, wgt, p)
        c = csr_mod.from_coo(src, dst, wgt, n=n_new, dedup=False)
        g = shard_csr(c, self.n_shards, mesh=self.mesh, dense=None)
        self.shards = g.shards
        self.n = g.n
        self.rows_max = g.rows_max
        self.dense = g.dense
        self.generation += 1
        self._placed = None

    def block_on(self) -> None:
        """Barrier: wait for every shard's device buffers (bench timing)."""
        for img in self.shards:
            jax.block_until_ready(img.dst)

    # ------------------------------------------------------------------
    # traversal: one program, frontier-exchange only
    # ------------------------------------------------------------------
    def _assemble(self):
        """(dst_g, lo_g, hi_g) walk operands, memoized until the next apply.

        Mesh mode builds the global [S, ...] arrays zero-copy from the
        per-shard committed buffers (``make_array_from_single_device_
        arrays``); local mode stacks them on the one device.
        """
        if self._placed is not None:
            return self._placed
        S, v_pad, cap_e = self.n_shards, self.v_pad, self.cap_e
        lohi = [self._lohi(img) for img in self.shards]
        if self.mesh is None:
            dst_g = jnp.stack([img.dst for img in self.shards])
            lo_g = jnp.stack([jnp.asarray(lo) for lo, _ in lohi])
            hi_g = jnp.stack([jnp.asarray(hi) for _, hi in lohi])
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            devs = self._devices()
            sh = NamedSharding(self.mesh, P("data", None))

            def _global(shape, parts):
                return jax.make_array_from_single_device_arrays(
                    shape, sh, parts
                )

            dst_g = _global(
                (S, cap_e),
                [jnp.reshape(img.dst, (1, cap_e)) for img in self.shards],
            )
            lo_g = _global(
                (S, v_pad),
                [
                    jax.device_put(lo.reshape(1, v_pad), d)
                    for (lo, _), d in zip(lohi, devs)
                ],
            )
            hi_g = _global(
                (S, v_pad),
                [
                    jax.device_put(hi.reshape(1, v_pad), d)
                    for (_, hi), d in zip(lohi, devs)
                ],
            )
        self._placed = (dst_g, lo_g, hi_g)
        return self._placed

    def reverse_walk(self, steps: int, *, visits0=None):
        """k-step reverse walk; [n] (or [B, n] with ``visits0`` [B, n]).

        One jitted program per walk: the shard_map frontier-exchange
        build on a mesh, or its bit-identical local emulation on one
        device.  Unweighted visit counts are exact small integers in
        f32, so both modes (and the single-device WalkImage path) agree
        bitwise on the graphs the parity suite sweeps.
        """
        from ..kernels.slot_walk import sharded as _sw

        nwalks = 0 if visits0 is None else int(visits0.shape[0])
        b = max(nwalks, 1)
        vis = np.ones((b, self.v_pad), np.float32)
        if visits0 is not None:
            # pad rows keep 1.0 — no edge ever references them, so their
            # value is unobservable and trimmed from the result
            vis[:, : self.n] = np.asarray(visits0, np.float32)
        dst_g, lo_g, hi_g = self._assemble()
        e_hi = self.edges_hi()
        if self.mesh is None:
            fn = _sw.make_local_walk(
                steps, self.n_shards, self.rows_max, self.cap_e, e_hi, nwalks
            )
            out = fn(dst_g, lo_g, hi_g, jnp.asarray(vis))
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            fn = _sw.make_sharded_walk(
                self.mesh, steps, self.n_shards, self.rows_max, self.cap_e,
                e_hi, nwalks,
            )
            vis_r = jax.device_put(
                vis, NamedSharding(self.mesh, P(None, None))
            )
            out = fn(dst_g, lo_g, hi_g, vis_r)
        walk_image.STATS["dispatches"] += 1
        out = out[:, : self.n]
        return out[0] if visits0 is None else out

    def collective_bytes_per_step(self, steps: int, *, nwalks: int = 0) -> int:
        """Measured per-device collective bytes per walk step (jaxpr proof).

        0 in local mode — the emulation genuinely exchanges nothing.
        """
        from ..kernels.slot_walk import sharded as _sw

        if self.mesh is None:
            return 0
        return _sw.collective_bytes_per_step(
            self.mesh, steps, self.n_shards, self.rows_max, self.cap_e,
            self.edges_hi(), nwalks,
        )

    # ------------------------------------------------------------------
    # checkpoint: one file per shard under a shared step manifest
    # ------------------------------------------------------------------
    def state_trees(self) -> dict:
        """{shard_id: flat state dict} — the sharded checkpoint payload."""
        out = {}
        for s, img in enumerate(self.shards):
            out[s] = {
                "dst": np.asarray(img.dst),
                "wgt": np.asarray(img.wgt),
                "rows": np.asarray(img.rows),
                "starts": np.asarray(img.starts, np.int64),
                "caps": np.asarray(img.caps, np.int64),
                "degs": np.asarray(img.degs, np.int64),
                "meta": np.asarray(
                    [img.nv, img.bump, img.live, self.n, self.rows_max,
                     self.n_shards, int(self.dense)],
                    np.int64,
                ),
            }
        return out

    def save(self, ckpt_dir: str, step: int, *, keep: int = 3) -> str:
        from ..checkpoint import manager as ckpt

        return ckpt.save_arrays_sharded(
            ckpt_dir, step, self.state_trees(), keep=keep
        )

    @classmethod
    def restore(
        cls, ckpt_dir: str, *, step: Optional[int] = None, mesh=None
    ) -> "ShardedGraph":
        """Serial per-shard replay of a sharded step manifest."""
        from ..checkpoint import manager as ckpt

        trees, _step = ckpt.restore_arrays_sharded(ckpt_dir, step=step)
        return cls.from_state_trees(trees, mesh=mesh)

    @classmethod
    def from_state_trees(cls, trees: dict, *, mesh=None) -> "ShardedGraph":
        metas = {s: t["meta"] for s, t in trees.items()}
        any_meta = next(iter(metas.values()))
        n, rows_max, n_shards, dense = (
            int(any_meta[3]), int(any_meta[4]), int(any_meta[5]),
            bool(any_meta[6]),
        )
        if sorted(trees) != list(range(n_shards)):
            raise ValueError(
                f"sharded restore: have shards {sorted(trees)}, "
                f"manifest says n_shards={n_shards}"
            )
        devs = (
            list(np.asarray(mesh.devices).reshape(-1))
            if mesh is not None
            else [None] * n_shards
        )
        shards = []
        for s in range(n_shards):
            t = trees[s]
            nv, bump, live = (int(t["meta"][0]), int(t["meta"][1]),
                              int(t["meta"][2]))
            dev = devs[s]
            put = (lambda a: jax.device_put(a, dev)) if dev is not None \
                else jnp.asarray
            img = walk_image.WalkImage(
                dst=put(t["dst"]), wgt=put(t["wgt"]), rows=put(t["rows"]),
                starts=np.asarray(t["starts"], np.int64),
                caps=np.asarray(t["caps"], np.int64),
                degs=np.asarray(t["degs"], np.int64),
                nv=nv, bump=bump, live=live,
                base_occupancy=live / max(bump, 1),
            )
            shards.append(img)
        return cls(
            shards=shards, n=n, rows_max=rows_max, n_shards=n_shards,
            mesh=mesh, dense=dense,
        )

    def audit(self) -> dict:
        """Per-shard image audits plus the cross-shard boundary pass."""
        reports = [img.audit() for img in self.shards]
        for s, img in enumerate(self.shards):
            lo_v, hi_v = self.owned_range(s)
            degs = np.asarray(img.degs[: self.v_pad], np.int64)
            stray = degs.copy()
            stray[lo_v:hi_v] = 0
            if stray.any():
                raise ValueError(
                    f"shard {s}: edges on non-owned rows "
                    f"{np.nonzero(stray)[0][:8].tolist()}"
                )
        return {"shards": reports, "m": self.m}


# ---------------------------------------------------------------------------
# construction / routing / gathering
# ---------------------------------------------------------------------------
def shard_csr(
    c: csr_mod.CSR,
    n_shards: int,
    *,
    mesh=None,
    dense: Optional[bool] = None,
) -> ShardedGraph:
    """Partition a CSR into per-shard WalkImages on a block vertex layout.

    All shards share one cap_e (``min_cap_e`` floors each build at the
    largest shard's natural capacity) so every per-shard program — walk
    step, fused patch — compiles once for the whole mesh.  With a mesh,
    each shard's device payload is committed to its own device; without
    one the graph runs in single-device local mode (parity tests, the
    shards=1 bench row).
    """
    if n_shards < 1:
        raise ValueError(f"shard_csr: n_shards must be >= 1, got {n_shards}")
    if c.n < n_shards:
        raise ValueError(
            f"shard_csr: need n >= n_shards, got n={c.n}, S={n_shards}"
        )
    if mesh is not None and len(np.asarray(mesh.devices).reshape(-1)) != n_shards:
        raise ValueError("shard_csr: mesh device count != n_shards")
    rows_max = -(-c.n // n_shards)
    v_pad = n_shards * rows_max
    o = np.asarray(c.offsets, np.int64)
    d = np.asarray(c.dst)
    w = (
        np.asarray(c.wgt, np.float32)
        if c.wgt is not None
        else np.ones(c.m, np.float32)
    )
    deg = np.diff(o)
    if dense is None:
        dense = _dense_policy(deg, int(c.m))

    deg_full = np.zeros(v_pad, np.int64)
    deg_full[: c.n] = deg
    cap_shared = max(
        _shard_cap(deg_full[s * rows_max:(s + 1) * rows_max], dense)
        for s in range(n_shards)
    )
    devs = (
        list(np.asarray(mesh.devices).reshape(-1))
        if mesh is not None
        else [None] * n_shards
    )
    shards = []
    for s in range(n_shards):
        lo_v = s * rows_max
        hi_v = min((s + 1) * rows_max, c.n)
        deg_s = np.zeros(v_pad, np.int64)
        if hi_v > lo_v:
            deg_s[lo_v:hi_v] = deg[lo_v:hi_v]
        offsets_s = np.concatenate([[0], np.cumsum(deg_s)])
        e0, e1 = (int(o[lo_v]), int(o[hi_v])) if hi_v > lo_v else (0, 0)
        img = walk_image.WalkImage.from_csr_arrays(
            offsets_s, d[e0:e1], w[e0:e1], v_pad,
            dense=dense, min_cap_e=cap_shared,
        )
        if devs[s] is not None:
            img.dst = jax.device_put(img.dst, devs[s])
            img.wgt = jax.device_put(img.wgt, devs[s])
            img.rows = jax.device_put(img.rows, devs[s])
        shards.append(img)
    return ShardedGraph(
        shards=shards, n=int(c.n), rows_max=rows_max, n_shards=n_shards,
        mesh=mesh, dense=bool(dense),
    )


def route_updates(plan, n_shards: int, rows_max: int):
    """Slice a canonical UpdatePlan by owning shard: [(shard_id, subplan)].

    The op stream is (src, dst)-sorted, so each shard's ops are one
    contiguous slice — routing is a searchsorted over the block
    boundaries, zero re-sort, and every slice is itself canonical
    (strictly increasing keys), so ``plan_from_canonical`` rebuilds the
    per-shard run structure byte-identically to a locally-planned batch.
    Ops beyond the padded vertex space land on the last shard, where the
    image's own row-range filter drops them (out-of-range deletes stay
    silently filtered, as everywhere else).
    """
    bounds = np.arange(1, n_shards, dtype=np.int64) * rows_max
    cuts = np.searchsorted(plan.q_src, bounds, side="left")
    idx = np.concatenate([[0], cuts, [plan.n_ops]]).astype(np.int64)
    out = []
    for s in range(n_shards):
        a, b = int(idx[s]), int(idx[s + 1])
        if a == b:
            continue
        out.append((
            s,
            upd_mod.plan_from_canonical(
                plan.q_src[a:b], plan.q_dst[a:b],
                plan.q_wgt[a:b], plan.q_del[a:b],
            ),
        ))
    return out


def _gather_coo(g: ShardedGraph):
    """Live (src, dst, wgt) from every shard's block prefixes, validated.

    Per-shard pow-2 slack drops by construction (only ``deg`` slots per
    row are read).  Edges on rows a shard does not own, or destination
    ids outside ``[0, n)``, raise — silent mis-stitching of the
    reassembled offsets is exactly the failure mode this guards.
    """
    srcs, dsts, wgts = [], [], []
    for s, img in enumerate(g.shards):
        lo_v, hi_v = g.owned_range(s)
        degs = np.asarray(img.degs[: g.v_pad], np.int64)
        stray = degs.copy()
        stray[lo_v:hi_v] = 0
        if stray.any():
            bad = np.nonzero(stray)[0][:8].tolist()
            raise ValueError(
                f"gather_csr: shard {s} owns rows [{lo_v}, {hi_v}) but "
                f"carries edges on rows {bad} — shard row-count mismatch"
            )
        dg = degs[lo_v:hi_v]
        m_s = int(dg.sum())
        if m_s == 0:
            continue
        starts = np.asarray(img.starts[lo_v:hi_v], np.int64)
        first = np.cumsum(dg) - dg
        gidx = np.repeat(starts, dg) + (
            np.arange(m_s, dtype=np.int64) - np.repeat(first, dg)
        )
        d = np.asarray(img.dst)[gidx]
        if bool((d == SENTINEL).any()) or bool((d >= g.n).any()):
            raise ValueError(
                f"gather_csr: shard {s} live prefix holds destination ids "
                f"outside [0, {g.n}) — shard row-count mismatch"
            )
        srcs.append(np.repeat(np.arange(lo_v, hi_v, dtype=np.int64), dg))
        dsts.append(d.astype(np.int64))
        wgts.append(np.asarray(img.wgt)[gidx])
    if not srcs:
        z = np.empty(0, np.int64)
        return z, z.copy(), np.empty(0, np.float32)
    return (
        np.concatenate(srcs), np.concatenate(dsts),
        np.concatenate(wgts).astype(np.float32),
    )


def gather_csr(g: ShardedGraph) -> csr_mod.CSR:
    """Collect the sharded graph back into a host CSR (tests, rebuilds)."""
    src, dst, wgt = _gather_coo(g)
    return csr_mod.from_coo(src, dst, wgt, n=g.n, dedup=False)


def _host_apply(src, dst, wgt, plan):
    """Apply one canonical plan to host COO arrays (the rebuild path).

    Keys touched by the plan (either op kind) drop from the old stream —
    an insert replaces, a delete removes — then the plan's inserts
    append.  ``from_coo`` re-sorts afterwards.
    """
    keys = (src.astype(np.int64) << 32) | dst.astype(np.int64)
    pk = (plan.q_src.astype(np.int64) << 32) | plan.q_dst.astype(np.int64)
    pos = np.searchsorted(pk, keys)
    pos_c = np.minimum(pos, max(pk.shape[0] - 1, 0))
    hit = (pos < pk.shape[0]) & (pk[pos_c] == keys) if pk.shape[0] else (
        np.zeros(keys.shape[0], bool)
    )
    ins = ~plan.q_del
    return (
        np.concatenate([src[~hit], plan.q_src[ins].astype(np.int64)]),
        np.concatenate([dst[~hit], plan.q_dst[ins].astype(np.int64)]),
        np.concatenate([wgt[~hit], plan.q_wgt[ins]]).astype(np.float32),
    )


def reverse_walk(g: ShardedGraph, steps: int, *, visits0=None):
    """Module-level convenience wrapper over ``ShardedGraph.reverse_walk``."""
    return g.reverse_walk(steps, visits0=visits0)
