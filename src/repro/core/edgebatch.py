"""EdgeBatch — a batch of edge updates ΔG (paper: "a batch of edges is
represented using DiGraph"; here a sorted, deduped, pow-2-padded COO).

The batch is the unit of the paper's union / subtraction operations.  Its
capacity is pow-2 bucketed (alloc.py) so repeated batches of similar sizes
hit the same compiled programs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, util


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """src/dst/wgt sorted by (src, dst), deduped; slots >= n are SENTINEL."""

    src: jnp.ndarray  # int32 [CAP]
    dst: jnp.ndarray  # int32 [CAP]
    wgt: jnp.ndarray  # float32 [CAP]
    n: int            # live edges

    def tree_flatten(self):
        return (self.src, self.dst, self.wgt), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def capacity(self) -> int:
        return self.src.shape[0]

    def to_numpy(self):
        s = np.asarray(self.src)[: self.n]
        d = np.asarray(self.dst)[: self.n]
        w = np.asarray(self.wgt)[: self.n]
        return s, d, w

    def to_sets(self) -> set[tuple[int, int]]:
        s, d, _ = self.to_numpy()
        return set(zip(s.tolist(), d.tolist()))

    def max_vertex(self) -> int:
        s, d, _ = self.to_numpy()
        if s.shape[0] == 0:
            return -1
        return int(max(s.max(), d.max()))

    def row_counts(self, n_vertices: int) -> np.ndarray:
        s, _, _ = self.to_numpy()
        return np.bincount(s, minlength=n_vertices)


def _offending(mask: np.ndarray, limit: int = 5) -> str:
    """Render the first few True indices of a bad-element mask, e.g.
    ``indices [3, 17, 40] (+2 more)`` — validation errors name *where* the
    bad values sit so serve-layer rejections are debuggable from the
    message alone."""
    idx = np.flatnonzero(mask)
    head = ", ".join(str(int(i)) for i in idx[:limit])
    more = f" (+{idx.size - limit} more)" if idx.size > limit else ""
    return f"indices [{head}]{more}"


def _validate_ids(arr, name: str) -> np.ndarray:
    """Coerce vertex ids to int32, rejecting anything that can't be one.

    Negative ids, ids >= INT32_MAX (the SENTINEL), non-integral floats
    and non-numeric dtypes all raise — silently wrapping them into the
    arena would corrupt rows far from the call site.  Messages name the
    array and the offending indices (first few).
    """
    a = np.asarray(arr).reshape(-1)
    if a.dtype.kind == "f":
        bad = a != np.floor(a)
        if a.size and bool(bad.any()):
            raise ValueError(
                f"{name}: non-integral vertex ids at {_offending(bad)}: "
                f"{a[bad][:5].tolist()}"
            )
    elif a.dtype.kind not in "iu":
        raise TypeError(f"{name}: vertex ids must be integers, got {a.dtype}")
    if a.size:
        neg = a < 0
        if bool(neg.any()):
            raise ValueError(
                f"{name}: negative vertex ids at {_offending(neg)}: "
                f"{a[neg][:5].astype(np.int64).tolist()}"
            )
        big = a >= np.iinfo(np.int32).max
        if bool(big.any()):
            raise ValueError(
                f"{name}: vertex ids overflow int32 at {_offending(big)}: "
                f"{a[big][:5].astype(np.int64).tolist()}"
            )
    return a.astype(np.int32)


def dedup_arrays(src: np.ndarray, dst: np.ndarray, *values, keep: str = "first"):
    """(src, dst)-lexsort host arrays and drop duplicate keys.

    ``keep`` selects which duplicate survives ("first" or "last" in the
    original order); ``values`` ride along.  Shared by ``from_arrays``
    and the UpdatePlan canonicalization in ``core/updates.py``.
    """
    n = src.shape[0]
    if keep == "last":
        order = np.lexsort((-np.arange(n), dst, src))
    else:
        order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    values = tuple(v[order] for v in values)
    if n:
        uniq = np.concatenate(
            [[True], (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])]
        )
        src, dst = src[uniq], dst[uniq]
        values = tuple(v[uniq] for v in values)
    return (src, dst, *values)


def from_arrays(
    src,
    dst,
    wgt=None,
    *,
    dedup: bool = True,
    symmetric: bool = False,
) -> EdgeBatch:
    """Host-side constructor: validate, sort by (src,dst), dedup, pad pow-2."""
    src = _validate_ids(src, "src")
    dst = _validate_ids(dst, "dst")
    if src.shape[0] != dst.shape[0]:
        raise ValueError(
            f"src/dst length mismatch: src has {src.shape[0]} ids, "
            f"dst has {dst.shape[0]}"
        )
    if wgt is None:
        wgt = np.ones_like(src, dtype=np.float32)
    wgt = np.asarray(wgt, dtype=np.float32).reshape(-1)
    if wgt.shape[0] != src.shape[0]:
        raise ValueError(
            f"wgt length mismatch: wgt has {wgt.shape[0]} weights for "
            f"{src.shape[0]} edges"
        )
    nonfinite = ~np.isfinite(wgt)
    if wgt.shape[0] and bool(nonfinite.any()):
        # NaN/inf weights would survive every merge unnoticed (no kernel
        # compares them) and poison walk sums far from the call site
        raise ValueError(
            f"wgt: non-finite edge weights at {_offending(nonfinite)}: "
            f"{wgt[nonfinite][:5].tolist()}"
        )
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        wgt = np.concatenate([wgt, wgt])
    if dedup:
        src, dst, wgt = dedup_arrays(src, dst, wgt, keep="first")
    else:
        order = np.lexsort((dst, src))
        src, dst, wgt = src[order], dst[order], wgt[order]
    n = int(src.shape[0])
    cap = alloc.next_pow2(max(n, 1))
    pad = cap - n
    src = np.concatenate([src, np.full(pad, util.SENTINEL, np.int32)])
    dst = np.concatenate([dst, np.full(pad, util.SENTINEL, np.int32)])
    wgt = np.concatenate([wgt, np.zeros(pad, np.float32)])
    return EdgeBatch(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(wgt), n)


def random_insertions(
    rng: np.random.Generator, n_vertices: int, count: int, *, weighted_range=(1.0, 1.0)
) -> EdgeBatch:
    """Paper §4.2.4: uniformly random vertex pairs."""
    src = rng.integers(0, n_vertices, size=count, dtype=np.int64)
    dst = rng.integers(0, n_vertices, size=count, dtype=np.int64)
    lo, hi = weighted_range
    wgt = rng.uniform(lo, hi, size=count).astype(np.float32)
    return from_arrays(src, dst, wgt)


def random_deletions(rng: np.random.Generator, csr, count: int) -> EdgeBatch:
    """Paper §4.2.3: uniformly sampled existing edges."""
    m = int(csr.m)
    count = min(count, m)
    pick = rng.choice(m, size=count, replace=False)
    rows = np.asarray(csr.row_ids())[pick]
    dsts = np.asarray(csr.dst)[pick]
    return from_arrays(rows, dsts)
