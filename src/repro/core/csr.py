"""Immutable CSR container + builders.

This is the *static* representation every dynamic representation converts
to/from; traversal oracles run on it.  Builders mirror the paper's Alg 5
convertToCsr(): partitioned degree counting + shifted-offset fill (the
partitions are the paper's contention optimization; vectorized here the
partition loop becomes a partitioned bincount, kept for fidelity and used
by the sharded builder).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import util


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """offsets[N+1], dst[M], wgt[M] (optional), n = #vertices, m = #edges."""

    offsets: jnp.ndarray
    dst: jnp.ndarray
    wgt: Optional[jnp.ndarray]
    n: int
    m: int

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return (self.offsets, self.dst, self.wgt), (self.n, self.m)

    @classmethod
    def tree_unflatten(cls, aux, children):
        offsets, dst, wgt = children
        n, m = aux
        return cls(offsets, dst, wgt, n, m)

    # -- accessors -------------------------------------------------------
    @property
    def degrees(self) -> jnp.ndarray:
        return (self.offsets[1:] - self.offsets[:-1]).astype(jnp.int32)

    def degree(self, u: int) -> int:
        return int(self.offsets[u + 1] - self.offsets[u])

    def edges_of(self, u: int) -> np.ndarray:
        o = np.asarray(self.offsets)
        return np.asarray(self.dst)[o[u] : o[u + 1]]

    def row_ids(self) -> jnp.ndarray:
        """Row id per edge (for segment ops)."""
        return util.expand_rows(self.offsets, self.dst.shape[0])

    def to_dense(self) -> np.ndarray:
        """Dense adjacency (tests only)."""
        a = np.zeros((self.n, self.n), dtype=np.float32)
        o = np.asarray(self.offsets)
        d = np.asarray(self.dst)
        w = np.asarray(self.wgt) if self.wgt is not None else np.ones_like(d, np.float32)
        for u in range(self.n):
            a[u, d[o[u] : o[u + 1]]] = w[o[u] : o[u + 1]]
        return a

    def to_edge_sets(self) -> list[set[int]]:
        o = np.asarray(self.offsets)
        d = np.asarray(self.dst)
        return [set(d[o[u] : o[u + 1]].tolist()) for u in range(self.n)]


def from_coo(
    src,
    dst,
    wgt=None,
    *,
    n: Optional[int] = None,
    num_partitions: int = 4,
    dedup: bool = True,
    sort: bool = True,
    engine: str = "auto",
    presorted: Optional[bool] = None,
) -> CSR:
    """Build a CSR from COO arrays via the counting-sort engines (Alg 5).

    ``num_partitions`` reproduces the paper's per-partition degree counting;
    partial bincounts are computed per block of edges and summed, exactly the
    role partitions play in Alg 5 lines 4-8.  ``engine`` selects the
    ``kernels/csr_build`` backend: ``host`` (packed-key radix argsort,
    default off-TPU), ``xla`` (one fused device program, default on TPU)
    or ``pallas`` (tile-kernel degree count).  The seed's ``np.lexsort``
    is retired — the packed single-key sort does the same stable
    (src, dst) ordering in one radix pass.
    """
    from ..kernels.csr_build import ops as _build_ops

    src = np.asarray(src, dtype=np.int64)
    dst_a = np.asarray(dst, dtype=np.int64)
    w = np.asarray(wgt, dtype=np.float32) if wgt is not None else None
    if n is None:
        n = int(max(src.max(initial=-1), dst_a.max(initial=-1)) + 1)
    if engine == "auto":
        engine = _build_ops.default_engine()

    if engine in ("xla", "pallas") and sort and not dedup:
        return _from_coo_device(src, dst_a, w, n=int(n), engine=engine)

    # shifted-offset fill: a stable counting sort realizes the same
    # placement the paper achieves with atomic offset increments.  Inputs
    # already in (src, dst) order — CSR-order files, which is how both
    # our writer and most real MTX corpora lay edges out — skip the sort
    # AND the three permutation gathers, and read offsets straight off
    # the sorted runs (no degree-count pass at all).
    # ``presorted`` lets the caller pass an order observation made for
    # free elsewhere (the compiled row parser tracks it while folding);
    # None means detect here.
    if presorted is None:
        presorted = sort and _build_ops.is_coo_sorted(src, dst_a)
    else:
        presorted = bool(presorted) and sort
    if presorted:
        src_s, dst_s, w_s = src, dst_a, w
    elif sort:
        src_s, dst_s, *wrest = (
            _build_ops.sort_coo_host(src, dst_a, w)
            if w is not None
            else _build_ops.sort_coo_host(src, dst_a)
        )
        w_s = wrest[0] if w is not None else None
    else:
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst_a[order]
        w_s = w[order] if w is not None else None

    if (presorted and not dedup) or (dedup and sort and src_s.shape[0]):
        # offsets come straight off the sorted runs, or the dedup pass
        # below recounts — a degree pass here would only be discarded
        degrees = None
    else:
        # per-partition degree counting (Alg 5: degrees[0] += degrees[p])
        degrees = _build_ops.count_degrees(
            src, int(n), num_partitions=num_partitions, engine="host"
        )

    if dedup and sort and src_s.shape[0]:
        keep = np.concatenate(
            [[True], (src_s[1:] != src_s[:-1]) | (dst_s[1:] != dst_s[:-1])]
        )
        src_s, dst_s = src_s[keep], dst_s[keep]
        w_s = w_s[keep] if w_s is not None else None
        degrees = np.bincount(src_s, minlength=n)

    if degrees is None:
        offsets = np.searchsorted(src_s, np.arange(n + 1, dtype=np.int64))
    else:
        offsets = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(degrees, out=offsets[1:])
    # out-of-range ids (negative or >= n) fall out of both the degree
    # histogram and the searchsorted window; the seed's np.bincount
    # raised on them — keep failing loudly instead of emitting a CSR
    # whose offsets orphan edges
    if int(offsets[0]) != 0 or int(offsets[-1]) != int(src_s.shape[0]):
        raise ValueError("from_coo: source id out of range [0, n)")
    return CSR(
        offsets=jnp.asarray(offsets, dtype=jnp.int32),
        dst=jnp.asarray(dst_s, dtype=jnp.int32),
        wgt=jnp.asarray(w_s, dtype=jnp.float32) if w_s is not None else None,
        n=int(n),
        m=int(dst_s.shape[0]),
    )


def _from_coo_device(src, dst, wgt, *, n: int, engine: str) -> CSR:
    """Fused on-device counting-sort build (pow-2 padded, no host sort).

    Pad edges carry src = n so they sort to the tail; the returned CSR
    slices them off.  With ``engine="pallas"`` the degree histogram runs
    through the partitioned tile kernel instead of the scatter-add.
    """
    from ..kernels.csr_build import ops as _build_ops
    from . import alloc

    m = int(src.shape[0])
    m_pad = alloc.next_pow2(max(m, 2))
    sp = np.full(m_pad, n, np.int32)
    sp[:m] = src
    dp = np.zeros(m_pad, np.int32)
    dp[:m] = dst
    wp = np.zeros(m_pad, np.float32)
    if wgt is not None:
        wp[:m] = wgt
    else:
        wp[:m] = 1.0
    if engine == "pallas":
        # the tile kernel supplies the histogram; sort-only device pass
        # (no second degree count + cumsum inside the fused build)
        _, dst_s, wgt_s = _build_ops.sort_coo_device(sp, dp, wp)
        deg = _build_ops.count_degrees(sp, n, engine="pallas")
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(deg, dtype=jnp.int32)]
        )
    else:
        offsets, _, dst_s, wgt_s = _build_ops.coo_to_csr_device(sp, dp, wp, n=n)
    # same loud failure as the host engine: ids outside [0, n) fall out
    # of the degree histogram (negatives additionally shift every row's
    # window) — a 2-element readback is cheap insurance against silently
    # orphaned edges
    ends = np.asarray(offsets[jnp.array([0, n])])
    if int(ends[0]) != 0 or int(ends[1]) != m:
        raise ValueError("from_coo: source id out of range [0, n)")
    return CSR(
        offsets=offsets,
        dst=dst_s[:m],
        wgt=wgt_s[:m] if wgt is not None else None,
        n=n,
        m=m,
    )


def from_dense(a: np.ndarray) -> CSR:
    src, dst = np.nonzero(a)
    return from_coo(src, dst, a[src, dst], n=a.shape[0])


def validate(csr: CSR) -> None:
    """Invariant checks (tests): offsets monotone, rows sorted unique."""
    o = np.asarray(csr.offsets)
    d = np.asarray(csr.dst)
    assert o[0] == 0 and o[-1] == d.shape[0] == csr.m
    assert (np.diff(o) >= 0).all()
    for u in range(csr.n):
        row = d[o[u] : o[u + 1]]
        assert (np.diff(row) > 0).all(), f"row {u} not sorted-unique"
        assert ((row >= 0) & (row < csr.n)).all()
