"""Immutable CSR container + builders.

This is the *static* representation every dynamic representation converts
to/from; traversal oracles run on it.  Builders mirror the paper's Alg 5
convertToCsr(): partitioned degree counting + shifted-offset fill (the
partitions are the paper's contention optimization; vectorized here the
partition loop becomes a partitioned bincount, kept for fidelity and used
by the sharded builder).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import util


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """offsets[N+1], dst[M], wgt[M] (optional), n = #vertices, m = #edges."""

    offsets: jnp.ndarray
    dst: jnp.ndarray
    wgt: Optional[jnp.ndarray]
    n: int
    m: int

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return (self.offsets, self.dst, self.wgt), (self.n, self.m)

    @classmethod
    def tree_unflatten(cls, aux, children):
        offsets, dst, wgt = children
        n, m = aux
        return cls(offsets, dst, wgt, n, m)

    # -- accessors -------------------------------------------------------
    @property
    def degrees(self) -> jnp.ndarray:
        return (self.offsets[1:] - self.offsets[:-1]).astype(jnp.int32)

    def degree(self, u: int) -> int:
        return int(self.offsets[u + 1] - self.offsets[u])

    def edges_of(self, u: int) -> np.ndarray:
        o = np.asarray(self.offsets)
        return np.asarray(self.dst)[o[u] : o[u + 1]]

    def row_ids(self) -> jnp.ndarray:
        """Row id per edge (for segment ops)."""
        return util.expand_rows(self.offsets, self.dst.shape[0])

    def to_dense(self) -> np.ndarray:
        """Dense adjacency (tests only)."""
        a = np.zeros((self.n, self.n), dtype=np.float32)
        o = np.asarray(self.offsets)
        d = np.asarray(self.dst)
        w = np.asarray(self.wgt) if self.wgt is not None else np.ones_like(d, np.float32)
        for u in range(self.n):
            a[u, d[o[u] : o[u + 1]]] = w[o[u] : o[u + 1]]
        return a

    def to_edge_sets(self) -> list[set[int]]:
        o = np.asarray(self.offsets)
        d = np.asarray(self.dst)
        return [set(d[o[u] : o[u + 1]].tolist()) for u in range(self.n)]


def from_coo(
    src,
    dst,
    wgt=None,
    *,
    n: Optional[int] = None,
    num_partitions: int = 4,
    dedup: bool = True,
    sort: bool = True,
) -> CSR:
    """Build a CSR from COO arrays (host numpy path, mirrors Alg 5).

    ``num_partitions`` reproduces the paper's per-partition degree counting;
    partial bincounts are computed per block of edges and summed, exactly the
    role partitions play in Alg 5 lines 4-8.
    """
    src = np.asarray(src, dtype=np.int64)
    dst_a = np.asarray(dst, dtype=np.int64)
    w = np.asarray(wgt, dtype=np.float32) if wgt is not None else None
    if n is None:
        n = int(max(src.max(initial=-1), dst_a.max(initial=-1)) + 1)

    # per-partition degree counting (Alg 5: degrees[0] += degrees[p])
    rho = max(int(num_partitions), 1)
    bounds = np.linspace(0, src.shape[0], rho + 1).astype(np.int64)
    degrees = np.zeros(n, dtype=np.int64)
    for p in range(rho):
        lo, hi = bounds[p], bounds[p + 1]
        degrees += np.bincount(src[lo:hi], minlength=n)

    # shifted-offset fill: a stable sort by src realizes the same placement
    # the paper achieves with atomic offset increments.
    if sort:
        order = np.lexsort((dst_a, src))
    else:
        order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst_a[order]
    w_s = w[order] if w is not None else None

    if dedup and sort and src_s.shape[0]:
        keep = np.concatenate(
            [[True], (src_s[1:] != src_s[:-1]) | (dst_s[1:] != dst_s[:-1])]
        )
        src_s, dst_s = src_s[keep], dst_s[keep]
        w_s = w_s[keep] if w_s is not None else None
        degrees = np.bincount(src_s, minlength=n)

    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(degrees, out=offsets[1:])
    return CSR(
        offsets=jnp.asarray(offsets, dtype=jnp.int32),
        dst=jnp.asarray(dst_s, dtype=jnp.int32),
        wgt=jnp.asarray(w_s, dtype=jnp.float32) if w_s is not None else None,
        n=int(n),
        m=int(dst_s.shape[0]),
    )


def from_dense(a: np.ndarray) -> CSR:
    src, dst = np.nonzero(a)
    return from_coo(src, dst, a[src, dst], n=a.shape[0])


def validate(csr: CSR) -> None:
    """Invariant checks (tests): offsets monotone, rows sorted unique."""
    o = np.asarray(csr.offsets)
    d = np.asarray(csr.dst)
    assert o[0] == 0 and o[-1] == d.shape[0] == csr.m
    assert (np.diff(o) >= 0).all()
    for u in range(csr.n):
        row = d[o[u] : o[u + 1]]
        assert (np.diff(row) > 0).all(), f"row {u} not sorted-unique"
        assert ((row >= 0) & (row < csr.n)).all()
