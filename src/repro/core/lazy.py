"""LazyCSR — the SuiteSparse:GraphBLAS-analogue representation.

GraphBLAS handles dynamic updates with *zombies* (deleted entries marked in
place) and *pending tuples* (insertions buffered unsorted), consolidating
lazily when an operation needs the assembled matrix.  Here:

  * base CSR (offsets/dst/wgt) + ``dead`` mask  — zombies,
  * pow-2 ring of pending COO tuples           — pending insertions,
  * ``assemble()``                              — the consolidation phase
    (sort-merge of live base + deduped pending), triggered by traversal.

Updates are therefore O(batch) ; the first traversal after updates pays the
consolidation — exactly the trade the paper measures for GraphBLAS.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, edgebatch, updates, util, walk_image

SENTINEL = util.SENTINEL


@functools.lru_cache(maxsize=None)
def _jit_append(donate: bool):
    def fn(ps, pd, pw, bs, bd, bw, at):
        ps = jax.lax.dynamic_update_slice(ps, bs, (at,))
        pd = jax.lax.dynamic_update_slice(pd, bd, (at,))
        pw = jax.lax.dynamic_update_slice(pw, bw, (at,))
        return ps, pd, pw

    return jax.jit(fn, donate_argnums=(0, 1, 2) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_mark_base(donate: bool):
    def fn(dead, base_dst, lo, hi, qd):
        pos, found = util.binsearch_window(base_dst, lo, hi, qd)
        # a zombie slot must not match again: dead mask checked separately —
        # re-deleting a dead edge is a no-op for the count
        already = dead[jnp.clip(pos, 0, dead.shape[0] - 1)]
        newly = found & ~already
        dead = dead.at[jnp.where(newly, pos, dead.shape[0])].set(True, mode="drop")
        return dead, newly

    return jax.jit(fn, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_mark_pending(donate: bool):
    def fn(pdead, ps, pd, bs, bd):
        # flip the search: every pending tuple (incl. duplicates) checks its
        # own membership in the (sorted) deletion batch.
        _, found = util.searchsorted_2d(bs, bd, ps, pd)
        return pdead | (found & (ps != SENTINEL)), found

    return jax.jit(fn, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_assemble(out_cap: int):
    def fn(base_rows, base_dst, base_wgt, dead, ps, pd, pw, pdead, p_n):
        lane = jnp.arange(ps.shape[0])
        p_live = (lane < p_n) & ~pdead & (ps != SENTINEL)
        # reverse pending so the *latest* duplicate wins dedup-keep-first
        ps_r, pd_r, pw_r, pl_r = ps[::-1], pd[::-1], pw[::-1], p_live[::-1]
        s = jnp.concatenate([jnp.where(pl_r, ps_r, SENTINEL), jnp.where(dead, SENTINEL, base_rows)])
        d = jnp.concatenate([jnp.where(pl_r, pd_r, SENTINEL), jnp.where(dead, SENTINEL, base_dst)])
        w = jnp.concatenate([pw_r, base_wgt])
        order = util.lexsort2(s, d)
        s, d, w = s[order], d[order], w[order]
        dup = jnp.concatenate(
            [jnp.array([False]), (s[1:] == s[:-1]) & (d[1:] == d[:-1])]
        )
        s = jnp.where(dup, SENTINEL, s)
        d2 = jnp.where(dup, SENTINEL, d)
        order = util.lexsort2(s, d2)
        s, d2, w = s[order], d2[order], w[order]
        m = jnp.sum(s != SENTINEL).astype(jnp.int32)
        s, d2, w = s[:out_cap], d2[:out_cap], w[:out_cap]
        return s, d2, w, m

    return jax.jit(fn)


@dataclasses.dataclass
class LazyCSR:
    # assembled base (flat COO-with-row-ids view of a CSR; rows sorted)
    base_rows: jnp.ndarray
    base_dst: jnp.ndarray
    base_wgt: jnp.ndarray
    offsets: np.ndarray          # host offsets into base (valid when clean)
    dead: jnp.ndarray            # bool, zombie mask over base slots
    # pending ring
    p_src: jnp.ndarray
    p_dst: jnp.ndarray
    p_wgt: jnp.ndarray
    p_dead: jnp.ndarray
    p_n: int
    n: int
    m: int                       # live-edge count (exact when clean)
    n_zombies: int
    dirty: bool
    # per-buffer seal-on-snapshot (DESIGN.md §10): zombie marking detaches
    # only the masks, pending appends only the ring — the (large) base
    # arrays are never mutated in place and therefore never copied.
    _sealed: set = dataclasses.field(default_factory=set)
    # cached walk image (DESIGN.md §11): patched per applied plan, so
    # walks skip assemble() entirely — consolidation only serves to_csr.
    _image: Optional[walk_image.WalkImage] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    #: every device buffer participating in snapshot sharing
    _PAYLOAD = (
        "base_rows", "base_dst", "base_wgt", "dead",
        "p_src", "p_dst", "p_wgt", "p_dead",
    )

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "LazyCSR":
        from ..kernels.csr_build import ops as _cb_ops

        cap = alloc.next_pow2(max(c.m, 2))
        w = c.wgt if c.wgt is not None else np.ones(c.m, np.float32)
        base_rows, base_dst, base_wgt = _cb_ops.flat_image(
            c.offsets, c.dst, w, cap
        )
        pcap = 16
        return cls(
            base_rows=base_rows,
            base_dst=base_dst,
            base_wgt=base_wgt,
            offsets=np.asarray(c.offsets, np.int64),
            dead=jnp.zeros((cap,), bool),
            p_src=jnp.full((pcap,), SENTINEL, jnp.int32),
            p_dst=jnp.full((pcap,), SENTINEL, jnp.int32),
            p_wgt=jnp.zeros((pcap,), jnp.float32),
            p_dead=jnp.zeros((pcap,), bool),
            p_n=0,
            n=int(c.n),
            m=int(c.m),
            n_zombies=0,
            dirty=False,
        )

    def block_on(self) -> None:
        self.base_dst.block_until_ready()

    @property
    def sealed(self) -> bool:
        return bool(self._sealed)

    def _detach(self, *names: str) -> None:
        """Copy ONLY the named snapshot-shared buffers (one fused dispatch)."""
        util.cow_detach(self, self._sealed, names or self._PAYLOAD)

    # -- updates ----------------------------------------------------------
    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g, dm = self.apply(updates.plan_update(inserts=batch), inplace=inplace)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g, dm = self.apply(updates.plan_update(deletes=batch), inplace=inplace)
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = True):
        """Mixed batch: mark zombies first, then buffer pending inserts.

        GraphBLAS semantics are inherently split — deletions become
        zombies in the assembled base, insertions wait in the pending
        ring — so a mixed plan drives both halves from its split views.
        Returns the *lazy* net ΔM estimate (exact after assemble()).
        """
        if plan.n_ops == 0:
            return self, 0
        plan.validate()  # corrupt plans (WAL replay) fail loudly (§13)
        g = self if inplace else self.clone()
        dm = 0
        if plan.n_del:
            dm -= g._mark_deletes(*plan.delete_arrays())
        if plan.n_ins:
            dm += g._append_pending(plan.insert_batch())
        g.dirty = True
        if g._image is not None:
            g._image.queue(plan)  # zombies + pending splice into the image
        return g, dm

    def _mark_deletes(self, s: np.ndarray, d: np.ndarray) -> int:
        """Zombie-mark (s, d) pairs in base + pending; returns #newly dead."""
        # zombie masks are the only buffers this writes (per-buffer COW)
        self._detach("dead", *(("p_dead",) if self.p_n > 0 else ()))
        s64 = s.astype(np.int64)
        valid = s64 < self.offsets.shape[0] - 1
        lo = np.where(valid, self.offsets[np.minimum(s64, self.offsets.shape[0] - 2)], 0)
        hi = np.where(valid, self.offsets[np.minimum(s64 + 1, self.offsets.shape[0] - 1)], 0)
        self.dead, newly = _jit_mark_base(True)(
            self.dead,
            self.base_dst,
            lo.astype(np.int32),
            hi.astype(np.int32),
            d,
        )
        nz = int(np.asarray(jnp.sum(newly)))
        self.n_zombies += nz
        if self.p_n > 0:
            self.p_dead, _ = _jit_mark_pending(True)(
                self.p_dead, self.p_src, self.p_dst, s, d
            )
        self.m -= nz
        return nz

    def _append_pending(self, batch: edgebatch.EdgeBatch) -> int:
        """Ring-buffer the insert batch; returns the lazy ΔM estimate."""
        need = self.p_n + batch.capacity
        if need > self.p_src.shape[0]:
            newcap = alloc.next_pow2(need)
            pad = newcap - self.p_src.shape[0]
            self.p_src = jnp.concatenate([self.p_src, jnp.full((pad,), SENTINEL, jnp.int32)])
            self.p_dst = jnp.concatenate([self.p_dst, jnp.full((pad,), SENTINEL, jnp.int32)])
            self.p_wgt = jnp.concatenate([self.p_wgt, jnp.zeros((pad,), jnp.float32)])
            self.p_dead = jnp.concatenate([self.p_dead, jnp.zeros((pad,), bool)])
            # ring growth produced fresh buffers; any snapshot keeps the old
            self._sealed -= {"p_src", "p_dst", "p_wgt", "p_dead"}
        else:
            # only the pending ring is written (per-buffer COW)
            self._detach("p_src", "p_dst", "p_wgt")
        self.p_src, self.p_dst, self.p_wgt = _jit_append(True)(
            self.p_src, self.p_dst, self.p_wgt, batch.src, batch.dst, batch.wgt, self.p_n
        )
        self.p_n += batch.capacity
        self.n = max(self.n, batch.max_vertex() + 1)
        return batch.n

    # -- consolidation (GraphBLAS "wait") ----------------------------------
    def assemble(self) -> None:
        if not self.dirty:
            return
        out_cap = alloc.next_pow2(max(self.base_dst.shape[0] + self.p_n, 2))
        s, d, w, m = _jit_assemble(out_cap)(
            self.base_rows,
            self.base_dst,
            self.base_wgt,
            self.dead,
            self.p_src,
            self.p_dst,
            self.p_wgt,
            self.p_dead,
            self.p_n,
        )
        self.base_rows, self.base_dst, self.base_wgt = s, d, w
        self.m = int(m)
        cap = s.shape[0]
        self.dead = jnp.zeros((cap,), bool)
        src_host = np.asarray(s)[: self.m]
        self.n = max(self.n, int(src_host.max(initial=-1)) + 1)
        counts = np.bincount(src_host, minlength=self.n)
        self.offsets = np.zeros(self.n + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        pcap = 16
        self.p_src = jnp.full((pcap,), SENTINEL, jnp.int32)
        self.p_dst = jnp.full((pcap,), SENTINEL, jnp.int32)
        self.p_wgt = jnp.zeros((pcap,), jnp.float32)
        self.p_dead = jnp.zeros((pcap,), bool)
        self.p_n = 0
        self.n_zombies = 0
        self.dirty = False
        self._sealed.clear()  # fresh buffers, nothing shared

    # -- export / queries ---------------------------------------------------
    def clone(self) -> "LazyCSR":
        copies = util.fused_copy(*(getattr(self, n) for n in self._PAYLOAD))
        return dataclasses.replace(
            self,
            offsets=self.offsets.copy(),
            _sealed=set(),
            _image=None,  # images are handle-private (patched in place)
            **dict(zip(self._PAYLOAD, copies)),
        )

    def snapshot(self) -> "LazyCSR":
        """GraphBLAS-style lazy copy: share buffers until next mutation.

        Per-buffer COW keeps the base arrays shared forever — updates
        only ever detach the zombie masks or the pending ring.
        """
        self._sealed = set(self._PAYLOAD)
        return dataclasses.replace(
            self,
            offsets=self.offsets.copy(),
            _sealed=set(self._PAYLOAD),
            _image=None,  # images are handle-private (patched in place)
        )

    # -- durable state (checkpoint/restore, DESIGN.md §13) ---------------
    def state_tree(self) -> dict:
        return {
            "base_rows": np.asarray(self.base_rows),
            "base_dst": np.asarray(self.base_dst),
            "base_wgt": np.asarray(self.base_wgt),
            "offsets": self.offsets.copy(),
            "dead": np.asarray(self.dead),
            "p_src": np.asarray(self.p_src),
            "p_dst": np.asarray(self.p_dst),
            "p_wgt": np.asarray(self.p_wgt),
            "p_dead": np.asarray(self.p_dead),
            "p_n": np.int64(self.p_n),
            "n": np.int64(self.n),
            "m": np.int64(self.m),
            "n_zombies": np.int64(self.n_zombies),
            "dirty": np.int64(int(self.dirty)),
        }

    @classmethod
    def from_state_tree(cls, t: dict) -> "LazyCSR":
        return cls(
            base_rows=jnp.asarray(t["base_rows"]),
            base_dst=jnp.asarray(t["base_dst"]),
            base_wgt=jnp.asarray(t["base_wgt"]),
            offsets=np.asarray(t["offsets"], np.int64),
            dead=jnp.asarray(t["dead"]),
            p_src=jnp.asarray(t["p_src"]),
            p_dst=jnp.asarray(t["p_dst"]),
            p_wgt=jnp.asarray(t["p_wgt"]),
            p_dead=jnp.asarray(t["p_dead"]),
            p_n=int(t["p_n"]),
            n=int(t["n"]),
            m=int(t["m"]),
            n_zombies=int(t["n_zombies"]),
            dirty=bool(int(t["dirty"])),
        )

    def to_csr(self) -> csr_mod.CSR:
        self.assemble()
        s = np.asarray(self.base_rows)[: self.m]
        d = np.asarray(self.base_dst)[: self.m]
        w = np.asarray(self.base_wgt)[: self.m]
        return csr_mod.from_coo(s, d, w, n=self.n, dedup=False)

    def to_walk_image(self) -> walk_image.WalkImage:
        """Cached walk image: zombie masking and pending-run splicing ride
        the generic patch engine, so a *dirty* LazyCSR walks without
        paying assemble() — the GraphBLAS consolidation only remains on
        the export path (``to_csr``).  The build itself consolidates
        once so the base arrays are CSR-ordered.
        """
        img = self._image
        if img is not None and img.flush():
            return img
        self.assemble()
        self._image = img = walk_image.WalkImage.from_csr_arrays(
            self.offsets, self.base_dst, self.base_wgt, self.n
        )
        return img

    def walk_occupancy(self) -> float:
        return self.to_walk_image().occupancy

    def reverse_walk(
        self, steps: int, *, visits0: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        # fused flush→walk: one dispatch per stream round (§12)
        return walk_image.reverse_walk_via_image(self, steps, visits0=visits0)

    def to_edge_sets(self) -> list[set[int]]:
        return self.to_csr().to_edge_sets()
