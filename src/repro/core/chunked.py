"""ChunkedGraph — the Aspen-analogue representation (DESIGN.md §3).

Aspen stores adjacency in purely-functional C-trees: elements chunked into
arrays, updates copy only the path/chunks they touch, snapshots are a root
pointer.  The TPU-native analogue: an **append-only page store**.

  * pages_dst/pages_wgt: [P_CAP, PAGE] device arrays (the chunk pool),
  * page_table:          host [CAP_V, ≤PPV] page-id lists per vertex,
  * updates write merged rows to *fresh* pages (bump allocation) and swap
    the affected page_table rows — old pages are never mutated, so any
    previously-taken snapshot (= dataclass copy holding the old table)
    stays valid: purely functional, O(touched-rows) update, O(1) snapshot.
  * ``vacuum()`` is the garbage-collection analogue (Aspen's reference
    counting): rewrites live pages compactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc, csr as csr_mod, edgebatch, updates, util, walk_image

SENTINEL = util.SENTINEL
PAGE = 64  # edges per page (Aspen chunks are ~dozens of ints)


@functools.lru_cache(maxsize=None)
def _jit_apply_rows(k_old: int, k_batch: int, k_new: int):
    """Mixed merge of batch runs [A,k_batch] into page rows [A,k_old].

    Delete ops mask their row hits to SENTINEL; insert ops concatenate
    *ahead* of the row so the stable sort + dedup-keep-first pass
    implements weight upsert — one program for insert, delete and mixed
    plans (the UpdatePlan guarantees one op per key).
    """

    def fn(row_d, row_w, b_d, b_w, b_del):
        bdel = b_del != 0
        eq = b_d[:, :, None] == row_d[:, None, :]  # [A, K, W]
        killed = jnp.any(eq & bdel[:, :, None], axis=1)
        row_d2 = jnp.where(killed, SENTINEL, row_d)
        ins_d = jnp.where(bdel, SENTINEL, b_d)
        keys = jnp.concatenate([ins_d, row_d2], axis=1)
        vals = jnp.concatenate([b_w, row_w], axis=1)
        order = jnp.argsort(keys, axis=1, stable=True)
        keys = jnp.take_along_axis(keys, order, axis=1)
        vals = jnp.take_along_axis(vals, order, axis=1)
        keys, vals, counts = util.dedup_sorted_rows(keys, vals)
        return keys[:, :k_new], vals[:, :k_new], counts

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_gather_pages(npages: int):
    def fn(pages_d, pages_w, page_ids):
        ok = page_ids >= 0
        safe = jnp.clip(page_ids, 0, pages_d.shape[0] - 1)
        d = jnp.where(ok[:, :, None], pages_d[safe], SENTINEL)
        w = jnp.where(ok[:, :, None], pages_w[safe], 0.0)
        a = page_ids.shape[0]
        return d.reshape(a, npages * PAGE), w.reshape(a, npages * PAGE)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_write_pages(npages: int, donate: bool = False):
    def fn(pages_d, pages_w, owners, page_ids, rows_d, rows_w, row_ids):
        a = page_ids.shape[0]
        d = rows_d.reshape(a, npages, PAGE)
        w = rows_w.reshape(a, npages, PAGE)
        ok = page_ids >= 0
        tgt = jnp.where(ok, page_ids, pages_d.shape[0]).reshape(-1)
        pages_d = pages_d.at[tgt].set(d.reshape(-1, PAGE), mode="drop")
        pages_w = pages_w.at[tgt].set(w.reshape(-1, PAGE), mode="drop")
        own = jnp.broadcast_to(row_ids[:, None], page_ids.shape).reshape(-1)
        owners = owners.at[tgt].set(own, mode="drop")
        return pages_d, pages_w, owners

    return jax.jit(fn, donate_argnums=(0, 1, 2) if donate else ())


@functools.lru_cache(maxsize=None)
def _jit_dense_gather():
    """One device gather strips PAGE tails: pool -> packed live edges."""

    def fn(pages_d, pages_w, gidx):
        ok = gidx >= 0
        safe = jnp.clip(gidx, 0, pages_d.size - 1)
        d = jnp.where(ok, pages_d.reshape(-1)[safe], SENTINEL)
        w = jnp.where(ok, pages_w.reshape(-1)[safe], 0.0)
        return d, w

    return jax.jit(fn)


def _pad2(a: np.ndarray, rows: int, fill) -> np.ndarray:
    out = np.full((rows,) + a.shape[1:], fill, a.dtype)
    out[: a.shape[0]] = a
    return out


@dataclasses.dataclass
class ChunkedGraph:
    pages_dst: jnp.ndarray       # [P_CAP, PAGE]
    pages_wgt: jnp.ndarray       # [P_CAP, PAGE]
    page_owner: jnp.ndarray      # [P_CAP] vertex id (CAP_V = dead)
    page_table: list[np.ndarray]  # per-vertex page-id arrays (host)
    degrees: np.ndarray
    n: int
    m: int
    next_page: int
    # per-buffer seal-on-snapshot (DESIGN.md §10): names of device buffers
    # shared with a snapshot.  Page writes detach the page pool; growing
    # the pool concatenates into fresh buffers and unseals for free.
    _sealed: set = dataclasses.field(default_factory=set)
    # cached walk image (DESIGN.md §11): the flat page gather, patched
    # incrementally instead of being reconstructed on every walk.
    _image: Optional[walk_image.WalkImage] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    _PAYLOAD = ("pages_dst", "pages_wgt", "page_owner")

    # ------------------------------------------------------------------
    @property
    def cap_v(self) -> int:
        return self.degrees.shape[0]

    @property
    def p_cap(self) -> int:
        return int(self.pages_dst.shape[0])

    def block_on(self) -> None:
        self.pages_dst.block_until_ready()

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "ChunkedGraph":
        """Vectorized page-pool build (DESIGN.md §10).

        The seed filled pages with a python loop over vertices; this is
        the csr_build shifted-offset fill quantized to PAGE-sized blocks
        — a handful of numpy passes + three transfers regardless of n.
        """
        from ..kernels.csr_build import ops as _cb_ops

        degrees = np.asarray(c.degrees, np.int64)
        npages = -(-degrees // PAGE)
        total_pages = int(npages.sum())
        p_cap = alloc.next_pow2(max(total_pages, 2))
        ww = c.wgt if c.wgt is not None else np.ones(c.m, np.float32)
        page_base = np.cumsum(npages) - npages
        pages_d, pages_w, owner = _cb_ops.pages_image_host(
            c.offsets, c.dst, ww, page_base, npages, PAGE, p_cap, int(c.n)
        )
        bounds = np.cumsum(npages)
        all_ids = np.arange(total_pages, dtype=np.int64)
        table = np.split(all_ids, bounds[:-1]) if c.n else []
        return cls(
            pages_dst=jnp.asarray(pages_d),
            pages_wgt=jnp.asarray(pages_w),
            page_owner=jnp.asarray(owner),
            page_table=list(table),
            degrees=degrees.copy(),
            n=int(c.n),
            m=int(c.m),
            next_page=total_pages,
        )

    # ------------------------------------------------------------------
    def _reserve_vertices(self, n_needed: int) -> None:
        if n_needed <= len(self.page_table):
            return
        for _ in range(n_needed - len(self.page_table)):
            self.page_table.append(np.empty(0, np.int64))
        deg = np.zeros(n_needed, np.int64)
        deg[: self.degrees.shape[0]] = self.degrees
        self.degrees = deg
        self.n = max(self.n, n_needed)

    def _alloc_pages(self, count: int) -> np.ndarray:
        if self.next_page + count > self.p_cap:
            new_cap = alloc.next_pow2(self.next_page + count)
            padp = new_cap - self.p_cap
            self.pages_dst = jnp.concatenate(
                [self.pages_dst, jnp.full((padp, PAGE), SENTINEL, jnp.int32)]
            )
            self.pages_wgt = jnp.concatenate(
                [self.pages_wgt, jnp.zeros((padp, PAGE), jnp.float32)]
            )
            self.page_owner = jnp.concatenate(
                [self.page_owner, jnp.full((padp,), self.cap_v, jnp.int32)]
            )
            self._sealed.clear()  # grown pool = fresh buffers
        ids = np.arange(self.next_page, self.next_page + count, dtype=np.int64)
        self.next_page += count
        return ids

    # ------------------------------------------------------------------
    @property
    def sealed(self) -> bool:
        return bool(self._sealed)

    def _detach(self, *names: str) -> None:
        """COW: copy the named snapshot-shared buffers in one fused dispatch."""
        util.cow_detach(self, self._sealed, names or self._PAYLOAD)

    def _apply_plan(self, plan: updates.UpdatePlan) -> int:
        if plan.n_ops == 0:
            return 0
        plan.validate()  # corrupt plans (WAL replay) fail loudly (§13)
        if plan.n_ins:
            self._reserve_vertices(plan.max_insert_vertex() + 1)
        # shared out-of-range filter (delete-only runs at unseen rows)
        sel = np.nonzero(plan.rows_in_range(len(self.page_table)))[0]
        if sel.shape[0] == 0:
            return 0
        rows = plan.rows[sel]
        deg_old = self.degrees[rows]
        ins_count = plan.ins_count[sel]
        total_dm = 0
        # bucket rows by pow-2 page count of the merged row (upper bound)
        pages_new = np.maximum(-(-(deg_old + ins_count) // PAGE), 1)
        pclass = updates.next_pow2_vec(pages_new)
        for pc in np.unique(pclass):
            gsel = np.nonzero(pclass == pc)[0]
            r = rows[gsel]
            a_pad = alloc.next_pow2(max(r.shape[0], 1))
            # gather current rows
            tbl = np.full((a_pad, int(pc)), -1, np.int64)
            for i, u in enumerate(r):
                ids = self.page_table[u]
                tbl[i, : ids.shape[0]] = ids[: int(pc)]
            row_d, row_w = _jit_gather_pages(int(pc))(
                self.pages_dst, self.pages_wgt, jnp.asarray(tbl)
            )
            # the group's batch runs, built lazily from the plan's op
            # stream (K floored at 4 to keep the jit-shape lattice coarse)
            kb = max(alloc.next_pow2(int(plan.run_count[sel[gsel]].max())), 4)
            b_d, b_w, b_l = plan.run_tiles(sel[gsel], kb, a_pad)
            n = r.shape[0]
            new_d, new_w, cnts = _jit_apply_rows(int(pc) * PAGE, kb, int(pc) * PAGE)(
                row_d, row_w, b_d, b_w, b_l
            )
            cnts = np.asarray(cnts, np.int64)[:n]
            # functional write: fresh pages for every touched row
            need_pages = np.maximum(-(-cnts // PAGE), 1)
            new_tbl = np.full((a_pad, int(pc)), -1, np.int64)
            for i, u in enumerate(r):
                ids = self._alloc_pages(int(need_pages[i]))
                self.page_table[u] = ids
                new_tbl[i, : ids.shape[0]] = ids
            rr = _pad2(r.astype(np.int32), a_pad, self.cap_v)
            # detach at the write site, AFTER _alloc_pages: pool growth
            # concatenates into fresh buffers and unseals for free, so a
            # growing post-snapshot batch pays no COW copy at all
            self._detach()
            self.pages_dst, self.pages_wgt, self.page_owner = _jit_write_pages(
                int(pc), True
            )(
                self.pages_dst,
                self.pages_wgt,
                self.page_owner,
                jnp.asarray(new_tbl),
                new_d,
                new_w,
                jnp.asarray(rr),
            )
            dm = int((cnts - self.degrees[r]).sum())
            self.degrees[r] = cnts
            total_dm += dm
        self.m += total_dm
        if self._image is not None:
            self._image.queue(plan)  # the flat walk view patches lazily
        return total_dm

    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g = self if inplace else self.snapshot()
        dm = g._apply_plan(updates.plan_update(inserts=batch))
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g = self if inplace else self.snapshot()
        dm = g._apply_plan(updates.plan_update(deletes=batch))
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = True):
        """Mixed delete+insert batch in one pass; returns (graph, net ΔM)."""
        g = self if inplace else self.snapshot()
        return g, g._apply_plan(plan)

    # ------------------------------------------------------------------
    def snapshot(self) -> "ChunkedGraph":
        """Aspen acquire_version(): O(#vertices) host metadata, zero device.

        Seals the shared payload; the next page write on either handle
        pays one fused detach copy (copy-on-write), while pool growth
        unseals for free.
        """
        self._sealed = set(self._PAYLOAD)
        return dataclasses.replace(
            self,
            page_table=[ids for ids in self.page_table],
            degrees=self.degrees.copy(),
            _sealed=set(self._PAYLOAD),
            _image=None,  # images are handle-private (patched in place)
        )

    def clone(self) -> "ChunkedGraph":
        copies = util.fused_copy(*(getattr(self, n) for n in self._PAYLOAD))
        return dataclasses.replace(
            self,
            page_table=[ids.copy() for ids in self.page_table],
            degrees=self.degrees.copy(),
            _sealed=set(),
            _image=None,
            **dict(zip(self._PAYLOAD, copies)),
        )

    # -- durable state (checkpoint/restore, DESIGN.md §13) ---------------
    def state_tree(self) -> dict:
        lens = np.array([ids.shape[0] for ids in self.page_table], np.int64)
        flat = (
            np.concatenate(self.page_table)
            if self.page_table
            else np.empty(0, np.int64)
        ).astype(np.int64)
        return {
            "pages_dst": np.asarray(self.pages_dst),
            "pages_wgt": np.asarray(self.pages_wgt),
            "page_owner": np.asarray(self.page_owner),
            "table_lens": lens,
            "table_flat": flat,
            "degrees": self.degrees.copy(),
            "n": np.int64(self.n),
            "m": np.int64(self.m),
            "next_page": np.int64(self.next_page),
        }

    @classmethod
    def from_state_tree(cls, t: dict) -> "ChunkedGraph":
        lens = np.asarray(t["table_lens"], np.int64)
        flat = np.asarray(t["table_flat"], np.int64)
        bounds = np.cumsum(lens)[:-1]
        table = [a.copy() for a in np.split(flat, bounds)] if lens.shape[0] else []
        return cls(
            pages_dst=jnp.asarray(t["pages_dst"]),
            pages_wgt=jnp.asarray(t["pages_wgt"]),
            page_owner=jnp.asarray(t["page_owner"]),
            page_table=table,
            degrees=np.asarray(t["degrees"], np.int64).copy(),
            n=int(t["n"]),
            m=int(t["m"]),
            next_page=int(t["next_page"]),
        )

    def vacuum(self) -> None:
        """GC: rebuild the page store with only live pages (Aspen refcount GC)."""
        c = self.to_csr()
        fresh = ChunkedGraph.from_csr(c)
        self.__dict__.update(fresh.__dict__)

    def to_csr(self) -> csr_mod.CSR:
        srcs, dsts, wgts = [], [], []
        pd = np.asarray(self.pages_dst)
        pw = np.asarray(self.pages_wgt)
        for u, ids in enumerate(self.page_table[: self.n]):
            if ids.shape[0] == 0:
                continue
            deg = int(self.degrees[u])
            flat_d = pd[ids].reshape(-1)[:deg]
            flat_w = pw[ids].reshape(-1)[:deg]
            srcs.append(np.full(deg, u, np.int64))
            dsts.append(flat_d)
            wgts.append(flat_w)
        if not srcs:
            return csr_mod.from_coo(
                np.empty(0, np.int64), np.empty(0, np.int64), None, n=self.n
            )
        return csr_mod.from_coo(
            np.concatenate(srcs),
            np.concatenate(dsts),
            np.concatenate(wgts),
            n=self.n,
            dedup=False,
        )

    def to_walk_image(self) -> walk_image.WalkImage:
        """Cached walk image: one flat gather of THIS version's pages.

        Liveness is version-local (superseded pages stay in the pool for
        older snapshots), so the build gathers the current page_table
        into a packed buffer whose blocks are the rows' page runs —
        PAGE-quantized slack that the patch engine then maintains
        incrementally, so repeat walks and update/walk streams never
        reconstruct the flat view again.
        """
        img = self._image
        if img is not None and img.flush():
            return img
        self._image = img = self._build_image()
        return img

    def _build_image(self) -> walk_image.WalkImage:
        lens = np.array(
            [ids.shape[0] for ids in self.page_table[: self.n]], np.int64
        )
        total_pages = int(lens.sum())
        if total_pages == 0:
            return walk_image.WalkImage.from_blocks(
                jnp.full((2,), SENTINEL, jnp.int32),
                jnp.zeros((2,), jnp.float32),
                jnp.full((2,), self.n, jnp.int32),
                np.full(max(self.n, 1), -1, np.int64),
                np.zeros(max(self.n, 1), np.int64),
                np.zeros(max(self.n, 1), np.int64),
                self.n, 0, 0,
            )
        degs = self.degrees[: self.n]
        m = int(degs.sum())
        # dense image compaction (DESIGN.md §12): the PAGE-quantized
        # gather builds at ~0.3 occupancy on typical degree mixes, and a
        # 42-step walk re-reads every dead lane per step — when the
        # slack dominates, strip it and walk live edges only.
        if m and m < walk_image.DENSE_THRESHOLD * total_pages * PAGE:
            return self._build_dense_image(lens, degs, m)
        live = np.concatenate(
            [ids for ids in self.page_table[: self.n] if ids.shape[0]]
        )
        bump = total_pages * PAGE
        cap_pages = alloc.pow2_with_headroom(total_pages)
        live_p = np.full(cap_pages, -1, np.int64)
        live_p[:total_pages] = live
        own_p = np.full(cap_pages, self.n, np.int32)
        own_p[:total_pages] = np.repeat(
            np.arange(self.n, dtype=np.int32), lens
        )
        ids_d = jnp.asarray(live_p)
        pages_d = jnp.where(
            ids_d[:, None] >= 0,
            self.pages_dst[jnp.clip(ids_d, 0, self.p_cap - 1)],
            SENTINEL,
        )
        pages_w = jnp.where(
            ids_d[:, None] >= 0,
            self.pages_wgt[jnp.clip(ids_d, 0, self.p_cap - 1)],
            0.0,
        )
        csum = np.cumsum(lens)
        starts = np.where(lens > 0, (csum - lens) * PAGE, -1)
        return walk_image.WalkImage.from_blocks(
            pages_d.reshape(-1),
            pages_w.reshape(-1),
            jnp.repeat(jnp.asarray(own_p), PAGE),
            starts,
            lens * PAGE,
            self.degrees[: self.n].copy(),
            self.n, bump, int(self.m),
        )

    def _build_dense_image(self, lens, degs, m: int) -> walk_image.WalkImage:
        """Dense walk image: PAGE tails stripped, blocks = exact degrees.

        Host builds one per-live-edge gather index into the flat page
        pool (edge j of row u lives at ``page_ids[j // PAGE] * PAGE +
        j % PAGE``); one device gather packs the pool into a CSR-ordered
        buffer with occupancy 1.0 — the patch engine then maintains it
        incrementally, relocating grown rows into the 100% bump reserve
        dense layouts take (every insert-touched row relocates).
        """
        live = np.concatenate(
            [ids for ids in self.page_table[: self.n] if ids.shape[0]]
        )
        dcs = np.cumsum(degs)
        e_local = np.arange(m, dtype=np.int64) - np.repeat(dcs - degs, degs)
        page_rank = np.repeat(np.cumsum(lens) - lens, degs) + e_local // PAGE
        gidx = live[page_rank] * PAGE + e_local % PAGE
        cap_e = alloc.pow2_with_headroom(m, 1.0)  # dense: deep bump reserve
        gidx_p = np.full(cap_e, -1, np.int64)
        gidx_p[:m] = gidx
        rows = np.full(cap_e, self.n, np.int32)
        rows[:m] = np.repeat(np.arange(self.n, dtype=np.int32), degs)
        dst_d, wgt_d = _jit_dense_gather()(
            self.pages_dst, self.pages_wgt, gidx_p
        )
        starts = np.where(degs > 0, dcs - degs, -1)
        return walk_image.WalkImage.from_blocks(
            dst_d, wgt_d, jnp.asarray(rows),
            starts, degs.copy(), degs.copy(),
            self.n, m, m,
        )

    def walk_occupancy(self) -> float:
        return self.to_walk_image().occupancy

    def reverse_walk(
        self, steps: int, *, visits0: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        # fused flush→walk: one dispatch per stream round (§12)
        return walk_image.reverse_walk_via_image(self, steps, visits0=visits0)

    def to_edge_sets(self) -> list[set[int]]:
        return self.to_csr().to_edge_sets()
