"""Vector2D — the paper's Fig. 1 strawman: per-vertex host arrays.

Stands in for the PetGraph/SNAP class of representations (per-vertex
containers, allocation on every touched row, no slack).  Intentionally
allocation-heavy: every touched row reallocates (np.union1d / setdiff1d),
every clone reallocates every row — this is the 74%-alloc-time baseline
the paper's Figure 1 motivates CP2AA with.
"""
from __future__ import annotations

import numpy as np

from . import csr as csr_mod, edgebatch, traversal


class Vector2D:
    def __init__(self, rows: list[np.ndarray], wrows: list[np.ndarray], n: int, m: int):
        self.rows = rows
        self.wrows = wrows
        self.n = n
        self.m = m

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "Vector2D":
        o = np.asarray(c.offsets)
        d = np.asarray(c.dst)
        w = np.asarray(c.wgt) if c.wgt is not None else np.ones(c.m, np.float32)
        rows = [d[o[u] : o[u + 1]].copy() for u in range(c.n)]
        wrows = [w[o[u] : o[u + 1]].copy() for u in range(c.n)]
        return cls(rows, wrows, int(c.n), int(c.m))

    def block_on(self) -> None:  # host rep: nothing to wait for
        pass

    def _reserve(self, n: int) -> None:
        while len(self.rows) < n:
            self.rows.append(np.empty(0, np.int32))
            self.wrows.append(np.empty(0, np.float32))
        self.n = max(self.n, n)

    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g = self if inplace else self.clone()
        s, d, w = batch.to_numpy()
        if s.shape[0] == 0:
            return g, 0
        g._reserve(int(max(s.max(), d.max())) + 1)
        dm = 0
        rows, first, counts = np.unique(s, return_index=True, return_counts=True)
        for u, fi, ct in zip(rows, first, counts):
            old = g.rows[u]
            add_d, add_w = d[fi : fi + ct], w[fi : fi + ct]
            new = np.union1d(old, add_d).astype(np.int32)  # fresh allocation
            pos = np.searchsorted(new, old)
            neww = np.zeros(new.shape[0], np.float32)
            neww[pos] = g.wrows[u]
            neww[np.searchsorted(new, add_d)] = add_w  # batch weight wins
            dm += new.shape[0] - old.shape[0]
            g.rows[u], g.wrows[u] = new, neww
        g.m += dm
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g = self if inplace else self.clone()
        s, d, _ = batch.to_numpy()
        dm = 0
        rows, first, counts = np.unique(s, return_index=True, return_counts=True)
        for u, fi, ct in zip(rows, first, counts):
            if u >= len(g.rows):
                continue
            old = g.rows[u]
            keep = ~np.isin(old, d[fi : fi + ct])
            dm += old.shape[0] - int(keep.sum())
            g.rows[u] = old[keep]          # fresh allocation
            g.wrows[u] = g.wrows[u][keep]
        g.m -= dm
        return g, dm

    def clone(self) -> "Vector2D":
        return Vector2D(
            [r.copy() for r in self.rows],
            [w.copy() for w in self.wrows],
            self.n,
            self.m,
        )

    def snapshot(self) -> "Vector2D":
        return self.clone()  # no cheap snapshot in this class — the point

    def to_csr(self) -> csr_mod.CSR:
        if self.m == 0:
            return csr_mod.from_coo(np.empty(0, np.int64), np.empty(0, np.int64), n=self.n)
        src = np.concatenate(
            [np.full(r.shape[0], u, np.int64) for u, r in enumerate(self.rows)]
        )
        dst = np.concatenate(self.rows)
        wgt = np.concatenate(self.wrows)
        return csr_mod.from_coo(src, dst, wgt, n=self.n, dedup=False)

    def reverse_walk(self, steps: int):
        # ragged host traversal: flatten once per call (the locality penalty
        # of non-contiguous storage), then iterate with np.add.at.
        c = self.to_csr()
        return traversal.reverse_walk_csr(c.offsets, c.dst, steps, c.n)

    def to_edge_sets(self) -> list[set[int]]:
        return [set(np.asarray(r).tolist()) for r in self.rows]
