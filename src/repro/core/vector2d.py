"""Vector2D — the paper's Fig. 1 strawman: per-vertex host arrays.

Stands in for the PetGraph/SNAP class of representations (per-vertex
containers, allocation on every touched row, no slack).  Intentionally
allocation-heavy: every touched row reallocates (np.union1d / setdiff1d),
every clone reallocates every row — this is the 74%-alloc-time baseline
the paper's Figure 1 motivates CP2AA with.
"""
from __future__ import annotations

import numpy as np

from . import csr as csr_mod, edgebatch, updates, walk_image


class Vector2D:
    def __init__(self, rows: list[np.ndarray], wrows: list[np.ndarray], n: int, m: int):
        self.rows = rows
        self.wrows = wrows
        self.n = n
        self.m = m
        # cached walk image (DESIGN.md §11): even the strawman's walks ride
        # the shared engine — its *update* path stays allocation-heavy.
        self._image: walk_image.WalkImage | None = None

    @classmethod
    def from_csr(cls, c: csr_mod.CSR) -> "Vector2D":
        o = np.asarray(c.offsets)
        d = np.ascontiguousarray(np.asarray(c.dst))
        w = np.ascontiguousarray(
            np.asarray(c.wgt) if c.wgt is not None else np.ones(c.m, np.float32)
        )
        # one np.split instead of n fancy-index copies; rows are views of
        # one backing buffer, which is safe because updates always REPLACE
        # a row array (union1d / boolean keep), never write into it
        cuts = o[1:-1]
        rows = np.split(d, cuts) if c.n else []
        wrows = np.split(w, cuts) if c.n else []
        return cls(list(rows), list(wrows), int(c.n), int(c.m))

    def block_on(self) -> None:  # host rep: nothing to wait for
        pass

    def _reserve(self, n: int) -> None:
        while len(self.rows) < n:
            self.rows.append(np.empty(0, np.int32))
            self.wrows.append(np.empty(0, np.float32))
        self.n = max(self.n, n)

    def add_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g, dm = self.apply(updates.plan_update(inserts=batch), inplace=inplace)
        return g, dm

    def remove_edges(self, batch: edgebatch.EdgeBatch, *, inplace: bool = True):
        g, dm = self.apply(updates.plan_update(deletes=batch), inplace=inplace)
        return g, -dm

    def apply(self, plan: updates.UpdatePlan, *, inplace: bool = True):
        """Mixed plan, one reallocation per touched row (the point)."""
        g = self if inplace else self.clone()
        if plan.n_ops == 0:
            return g, 0
        plan.validate()  # corrupt plans (WAL replay) fail loudly (§13)
        if plan.n_ins:
            g._reserve(plan.max_insert_vertex() + 1)
        dm = 0
        for u, fi, ct in zip(plan.rows, plan.run_first, plan.run_count):
            if u >= len(g.rows):  # delete-only run at an unseen row
                continue
            run_d = plan.q_dst[fi : fi + ct]
            run_w = plan.q_wgt[fi : fi + ct]
            run_del = plan.q_del[fi : fi + ct]
            old, oldw = g.rows[u], g.wrows[u]
            if run_del.any():
                keep = ~np.isin(old, run_d[run_del])
                old, oldw = old[keep], oldw[keep]  # fresh allocation
            ins_d, ins_w = run_d[~run_del], run_w[~run_del]
            if ins_d.shape[0]:
                new = np.union1d(old, ins_d).astype(np.int32)  # fresh again
                neww = np.zeros(new.shape[0], np.float32)
                neww[np.searchsorted(new, old)] = oldw
                neww[np.searchsorted(new, ins_d)] = ins_w  # batch weight wins
            else:
                new, neww = old, oldw
            dm += new.shape[0] - g.rows[u].shape[0]
            g.rows[u], g.wrows[u] = new, neww
        g.m += dm
        if g._image is not None:
            g._image.queue(plan)
        return g, dm

    def clone(self) -> "Vector2D":
        return Vector2D(
            [r.copy() for r in self.rows],
            [w.copy() for w in self.wrows],
            self.n,
            self.m,
        )

    def snapshot(self) -> "Vector2D":
        return self.clone()  # no cheap snapshot in this class — the point

    # -- durable state (checkpoint/restore, DESIGN.md §13) ---------------
    def state_tree(self) -> dict:
        lens = np.array([r.shape[0] for r in self.rows], np.int64)
        return {
            "row_lens": lens,
            "dst_flat": (
                np.concatenate(self.rows) if self.rows else np.empty(0, np.int32)
            ).astype(np.int32),
            "wgt_flat": (
                np.concatenate(self.wrows) if self.wrows else np.empty(0, np.float32)
            ).astype(np.float32),
            "n": np.int64(self.n),
            "m": np.int64(self.m),
        }

    @classmethod
    def from_state_tree(cls, t: dict) -> "Vector2D":
        lens = np.asarray(t["row_lens"], np.int64)
        bounds = np.cumsum(lens)[:-1]
        d = np.asarray(t["dst_flat"], np.int32)
        w = np.asarray(t["wgt_flat"], np.float32)
        rows = [a.copy() for a in np.split(d, bounds)] if lens.shape[0] else []
        wrows = [a.copy() for a in np.split(w, bounds)] if lens.shape[0] else []
        return cls(rows, wrows, int(t["n"]), int(t["m"]))

    def to_csr(self) -> csr_mod.CSR:
        if self.m == 0:
            return csr_mod.from_coo(np.empty(0, np.int64), np.empty(0, np.int64), n=self.n)
        src = np.concatenate(
            [np.full(r.shape[0], u, np.int64) for u, r in enumerate(self.rows)]
        )
        dst = np.concatenate(self.rows)
        wgt = np.concatenate(self.wrows)
        return csr_mod.from_coo(src, dst, wgt, n=self.n, dedup=False)

    def to_walk_image(self) -> walk_image.WalkImage:
        """Cached walk image: one ragged host flatten at build time (the
        locality penalty of per-vertex arrays), then incrementally
        patched — repeat walks never re-flatten the rows."""
        img = self._image
        if img is not None and img.flush():
            return img
        if self.m == 0:
            offsets = np.zeros(self.n + 1, np.int64)
            dst = np.empty(0, np.int32)
            wgt = np.empty(0, np.float32)
        else:
            offsets = np.zeros(self.n + 1, np.int64)
            np.cumsum([r.shape[0] for r in self.rows], out=offsets[1:])
            dst = np.concatenate(self.rows).astype(np.int32)
            wgt = np.concatenate(self.wrows).astype(np.float32)
        self._image = img = walk_image.WalkImage.from_csr_arrays(
            offsets, dst, wgt, self.n
        )
        return img

    def walk_occupancy(self) -> float:
        return self.to_walk_image().occupancy

    def reverse_walk(self, steps: int, *, visits0=None):
        # fused flush→walk: one dispatch per stream round (§12)
        return walk_image.reverse_walk_via_image(self, steps, visits0=visits0)

    def to_edge_sets(self) -> list[set[int]]:
        return [set(np.asarray(r).tolist()) for r in self.rows]
