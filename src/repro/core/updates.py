"""UpdatePlan — the shared host planning layer for batch updates (DESIGN.md §9).

Every representation's ``add_edges`` / ``remove_edges`` / ``apply`` funnels
through one plan object so the host-side work of a batch update — sorting,
in-batch dedup, per-row run splitting (one ``np.unique`` pass), padded
device operand layout — happens exactly once per batch, no matter how many
structures consume it or how many times a stream replays it:

  * **Canonical op stream**: ``(src, dst)``-sorted ops, at most one op per
    edge key.  In a *mixed* plan an insert wins over a delete of the same
    key (delete-then-insert ≡ replace), so ``apply`` is deterministic.
  * **Per-row runs**: ``rows / run_first / run_count / ins_count`` from a
    single ``np.unique`` pass, plus ``[R, K]`` padded run matrices
    (``K`` = pow-2 of the longest run) — the operand layout of the fused
    ``kernels/slot_update`` device pass.
  * **Pow-2 padding everywhere** so repeated batch shapes hit the same
    compiled programs (the CP2AA shape policy, ``core/alloc.py``).
  * **Plan cache**: plans are memoized per source-batch identity, so a
    steady-state stream that reapplies the same ``EdgeBatch`` (or applies
    one batch to several representations) skips host planning entirely.

Plans are graph-independent: grow/compact decisions are made by each
representation against its own metadata at apply time.
"""
from __future__ import annotations

import collections
import dataclasses
import weakref
from typing import Optional

import numpy as np

from . import alloc, edgebatch, util

SENTINEL = util.SENTINEL


def next_pow2_vec(x: np.ndarray) -> np.ndarray:
    """Vectorized ``alloc.next_pow2`` (exact for values < 2**52)."""
    x = np.maximum(np.asarray(x, dtype=np.int64), 1)
    return (2 ** np.ceil(np.log2(x)).astype(np.int64)).astype(np.int64)


@dataclasses.dataclass
class UpdatePlan:
    """One canonicalized batch of mixed edge updates, device-operand ready."""

    # canonical op stream (host, sorted by (src, dst); one op per key)
    q_src: np.ndarray  # int32 [Q]
    q_dst: np.ndarray  # int32 [Q]
    q_wgt: np.ndarray  # float32 [Q]
    q_del: np.ndarray  # bool [Q]  (True = delete op)
    # per-row run structure (one np.unique pass)
    rows: np.ndarray       # int64 [R] unique touched rows, ascending
    run_first: np.ndarray  # int64 [R] first op index of each row's run
    run_count: np.ndarray  # int64 [R] ops per row
    ins_count: np.ndarray  # int64 [R] insert ops per row
    #: pow-2 of the longest run — the K ceiling for run_tiles()
    run_width: int = 1
    # memoized derived views
    _ins_batch: Optional[edgebatch.EdgeBatch] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _del_batch: Optional[edgebatch.EdgeBatch] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _validated: bool = dataclasses.field(default=False, repr=False, compare=False)

    # ------------------------------------------------------------------
    @property
    def n_ops(self) -> int:
        return int(self.q_src.shape[0])

    @property
    def n_ins(self) -> int:
        return int(self.n_ops - self.q_del.sum())

    @property
    def n_del(self) -> int:
        return int(self.q_del.sum())

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])

    @property
    def del_count(self) -> np.ndarray:
        return self.run_count - self.ins_count

    def run_tiles(self, sel: np.ndarray, k: int, a_pad: Optional[int] = None):
        """Padded [A, k] run matrices for the plan rows indexed by ``sel``.

        Built on demand per width group, so a skewed batch (one hub run
        next to thousands of single-op rows) never materializes a dense
        [R, max_run] matrix — each group only pays its own rows at its
        own run width.  ``k`` must cover every selected run; rows pad to
        ``a_pad`` (SENTINEL / 0).  Returns (b_dst, b_wgt, b_del).
        """
        n = int(sel.shape[0])
        a = int(a_pad) if a_pad is not None else n
        bd = np.full((a, k), SENTINEL, np.int32)
        bw = np.zeros((a, k), np.float32)
        bl = np.zeros((a, k), np.int32)
        rc = self.run_count[sel]
        if n == 0 or int(rc.max(initial=0)) == 0:
            return bd, bw, bl
        assert int(rc.max()) <= k, "run width k too small for selected rows"
        q = int(rc.sum())
        rowi = np.repeat(np.arange(n, dtype=np.int64), rc)
        col = np.arange(q, dtype=np.int64) - np.repeat(np.cumsum(rc) - rc, rc)
        src = np.repeat(self.run_first[sel], rc) + col
        bd[rowi, col] = self.q_dst[src]
        bw[rowi, col] = self.q_wgt[src]
        bl[rowi, col] = self.q_del[src].astype(np.int32)
        return bd, bw, bl

    def validate(self, num_vertices: Optional[int] = None) -> "UpdatePlan":
        """Boundary validation at ``apply()`` time (DESIGN.md §13).

        Every representation calls this before touching its arrays, so a
        corrupt plan — typically a damaged WAL record surviving its CRC by
        construction rather than by luck — fails loudly instead of
        poisoning the arena: negative vertex ids and non-finite insert
        weights raise ``ValueError``.  With ``num_vertices`` (the WAL
        record's vertex watermark on replay) insert ids must also stay
        below the bound; out-of-range *deletes* remain silently filtered
        downstream (``rows_in_range``), as always.  The unconditional
        checks are memoized per plan, so a cached plan replayed across a
        stream or across representations pays them once.
        """
        if not self._validated:
            if self.q_src.shape[0]:
                for name, arr in (("q_src", self.q_src), ("q_dst", self.q_dst)):
                    neg = arr < 0
                    if bool(neg.any()):
                        raise ValueError(
                            f"UpdatePlan: negative vertex ids in {name} at "
                            f"{edgebatch._offending(neg)}: "
                            f"{arr[neg][:5].astype(np.int64).tolist()}"
                        )
                ins = ~self.q_del
                bad = ins & ~np.isfinite(self.q_wgt)
                if bool(bad.any()):
                    raise ValueError(
                        f"UpdatePlan: non-finite insert weights in q_wgt at "
                        f"{edgebatch._offending(bad)}: "
                        f"{self.q_wgt[bad][:5].tolist()}"
                    )
            self._validated = True
        if num_vertices is not None:
            mx = self.max_insert_vertex()
            if mx >= int(num_vertices):
                raise ValueError(
                    f"UpdatePlan: insert vertex id {mx} >= bound {int(num_vertices)}"
                )
        return self

    def max_insert_vertex(self) -> int:
        """Largest vertex id an insert op touches (-1 when insert-free)."""
        ins = ~self.q_del
        if not ins.any():
            return -1
        return int(
            max(self.q_src[ins].max(), self.q_dst[ins].max())
        )

    # -- split views (for representations without a fused mixed path) ----
    def insert_arrays(self):
        """(src, dst, wgt) of the insert ops, (src, dst)-sorted."""
        ins = ~self.q_del
        return self.q_src[ins], self.q_dst[ins], self.q_wgt[ins]

    def delete_arrays(self):
        """(src, dst) of the delete ops, (src, dst)-sorted."""
        dl = self.q_del
        return self.q_src[dl], self.q_dst[dl]

    def insert_batch(self) -> edgebatch.EdgeBatch:
        """Insert ops as a pow-2 padded EdgeBatch (memoized)."""
        if self._ins_batch is None:
            s, d, w = self.insert_arrays()
            self._ins_batch = edgebatch.from_arrays(s, d, w, dedup=False)
        return self._ins_batch

    def delete_batch(self) -> edgebatch.EdgeBatch:
        """Delete ops as a pow-2 padded EdgeBatch (memoized)."""
        if self._del_batch is None:
            s, d = self.delete_arrays()
            self._del_batch = edgebatch.from_arrays(s, d, dedup=False)
        return self._del_batch

    # -- shared row filtering (all representations) ----------------------
    def active_rows(self, degrees: np.ndarray, cap_v: int):
        """Dirty-row export: the plan rows that can affect a structure.

        Given the consumer's per-vertex ``degrees`` (and ``cap_v`` vertex
        slots), drops out-of-range rows and inert runs (delete-only runs
        at empty rows), returning ``(sel, rows, deg_old, ins_count)``
        aligned on the surviving rows.  ``sel`` indexes back into the
        plan's run structure (``run_tiles(sel[...], ...)``).  This is the
        shared head of every patch loop — the DiGraph arena update and
        the walk-image maintenance engine both start here.
        """
        sel = np.nonzero(self.rows_in_range(cap_v))[0]
        deg_old = degrees[self.rows[sel]]
        ins_count = self.ins_count[sel]
        act = (deg_old > 0) | (ins_count > 0)
        sel = sel[act]
        return sel, self.rows[sel], deg_old[act], ins_count[act]

    def width_groups(self, sel: np.ndarray, new_caps: np.ndarray, floor: int):
        """Iterate the plan rows ``sel`` by pow-2 width class.

        The operand layout of one fused ``kernels/slot_update`` dispatch
        per group, shared by every patch loop (DiGraph's arena update
        and the walk-image maintenance engine) so the jit-shape lattice
        — width class floored at the backend's, row-count pad ``a_pad``
        (pow-2, floor 16), run width ``k`` (pow-2 of the group's longest
        run, floor 4) — has a single definition.  Yields
        ``(width, gsel, a_pad, pad1, b_dst, b_wgt, b_del)`` with
        ``gsel`` indexing into ``sel``/``new_caps`` and ``pad1`` the
        group's [A]-operand padder.
        """
        wclass = np.maximum(next_pow2_vec(new_caps), floor)
        for wv in np.unique(wclass):
            gsel = np.nonzero(wclass == wv)[0]
            n = gsel.shape[0]
            # floors keep the (width, A, K) jit-shape lattice coarse, so
            # a stream of varying batches stops compiling after a few
            # rounds; wide classes floor lower — 15 pad rows of a
            # 1024-slot class are 15k dead merge lanes, and hub classes
            # rarely hold more than a handful of rows per batch
            a_pad = max(alloc.next_pow2(n), 4 if int(wv) >= 256 else 16)

            def pad1(a, fill, dtype=np.int32, *, _n=n, _a=a_pad):
                out = np.full(_a, fill, dtype)
                out[:_n] = a
                return out

            # the group's own run width: short runs shouldn't pay a hub
            # row's padding (K floored at 4 for jit-shape coarseness)
            k = max(alloc.next_pow2(int(self.run_count[sel[gsel]].max())), 4)
            bd, bw, bl = self.run_tiles(sel[gsel], k, a_pad)
            yield int(wv), gsel, a_pad, pad1, bd, bw, bl

    def fused_groups(self, sel, rows, deg_old, grow,
                     old_starts, old_caps, new_starts, new_caps,
                     floor: int, row_pad: int):
        """Packed per-group operands of one ``fused_apply`` dispatch (§12).

        The single definition of the fused engine's group contract —
        ``(width, a_pad, k, d_k, moves, (row_ops [6, A], b_dstdel
        [2, A, K], b_wgt [A, K]))`` — shared by ``DiGraph._apply_impl``
        and ``WalkImage._plan_patch`` so the operand packing and the
        jit-key fields can never drift between the two patch engines.
        ``row_pad`` fills pad rows' ids (the consumer's drop bound);
        ``d_k`` is the group's pow-2 delete-run ceiling (the merge's
        hole-compaction window).  Returns ``(groups, layout)`` with
        ``layout = [(width, gsel, a_pad), ...]`` for the counts commit
        and the host slot map.
        """
        groups, layout = [], []
        for wv, gsel, a_pad, pad1, bd, bw, bl in self.width_groups(
            sel, new_caps, floor
        ):
            ops3 = (
                np.stack([
                    pad1(old_starts[gsel], -1),
                    pad1(old_caps[gsel], 0),
                    pad1(new_starts[gsel], -1),
                    pad1(new_caps[gsel], 0),
                    pad1(deg_old[gsel], 0),
                    pad1(rows[gsel], row_pad),
                ]),
                np.stack([bd, bl]),
                bw,
            )
            dmax = int(self.del_count[sel[gsel]].max(initial=0))
            d_k = alloc.next_pow2(dmax) if dmax else 0
            groups.append(
                (int(wv), a_pad, bd.shape[1], d_k,
                 bool(grow[gsel].any()), ops3)
            )
            layout.append((int(wv), gsel, a_pad))
        return groups, layout

    def rows_in_range(self, cap_v: int) -> np.ndarray:
        """Mask of plan rows a graph with ``cap_v`` vertex slots can touch.

        Insert rows are expected to be reserved by the caller first, so
        after reservation this only drops delete-only runs aimed at rows
        the graph has never seen — the out-of-range filter every
        representation shares (previously each delete path hand-rolled
        its own).
        """
        return self.rows < cap_v

    def touched_rows(self, cap_v: int) -> np.ndarray:
        """In-range rows this plan can modify — the WAL-window dirty-row
        export the §15 differential checkpointer accumulates.

        A conservative superset of :meth:`active_rows` (inert delete-only
        runs are kept; they cannot change state, so over-marking them
        dirty costs a few redundant chunks, never correctness) that needs
        no degree array — callable before OR after the apply.
        """
        return self.rows[self.rows_in_range(cap_v)]


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------
def _empty_plan() -> UpdatePlan:
    return UpdatePlan(
        q_src=np.empty(0, np.int32),
        q_dst=np.empty(0, np.int32),
        q_wgt=np.empty(0, np.float32),
        q_del=np.empty(0, bool),
        rows=np.empty(0, np.int64),
        run_first=np.empty(0, np.int64),
        run_count=np.empty(0, np.int64),
        ins_count=np.empty(0, np.int64),
    )


def _pair_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """(src, dst) -> sortable int64 key (ids are validated non-negative)."""
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


def _canonicalize(src, dst, *values):
    """Enforce (src, dst)-sorted unique keys, O(B) when already true.

    EdgeBatches from ``from_arrays`` are already canonical, so the hot
    path is a strictly-increasing-keys check; only ``dedup=False``
    batches with duplicate or unsorted keys pay the full re-sort.
    """
    keys = _pair_keys(src, dst)
    if keys.shape[0] < 2 or bool(np.all(keys[1:] > keys[:-1])):
        return (src, dst, *values)
    return edgebatch.dedup_arrays(src, dst, *values, keep="first")


def _build_plan(
    inserts: Optional[edgebatch.EdgeBatch],
    deletes: Optional[edgebatch.EdgeBatch],
) -> UpdatePlan:
    ins_s, ins_d, ins_w = (
        inserts.to_numpy() if inserts is not None else (None, None, None)
    )
    del_s, del_d, _ = deletes.to_numpy() if deletes is not None else (None, None, None)

    # enforce the one-op-per-key invariant every consumer relies on —
    # EdgeBatches are normally pre-deduped (O(B) check), but dedup=False
    # batches must not smuggle duplicate keys into the merge kernels.
    if ins_s is not None and ins_s.shape[0]:
        ins_s, ins_d, ins_w = _canonicalize(ins_s, ins_d, ins_w)
    if del_s is not None and del_s.shape[0]:
        del_s, del_d = _canonicalize(del_s, del_d)

    if del_s is not None and del_s.shape[0] and ins_s is not None and ins_s.shape[0]:
        # cross-batch dedup: an insert wins over a delete of the same key
        # (delete-then-insert ≡ replace), so conflicting deletes drop out.
        ins_keys = _pair_keys(ins_s, ins_d)
        del_keys = _pair_keys(del_s, del_d)
        pos = np.searchsorted(ins_keys, del_keys)
        pos_c = np.minimum(pos, ins_keys.shape[0] - 1)
        clash = (pos < ins_keys.shape[0]) & (ins_keys[pos_c] == del_keys)
        del_s, del_d = del_s[~clash], del_d[~clash]

    parts_s, parts_d, parts_w, parts_del = [], [], [], []
    if ins_s is not None and ins_s.shape[0]:
        parts_s.append(ins_s)
        parts_d.append(ins_d)
        parts_w.append(ins_w)
        parts_del.append(np.zeros(ins_s.shape[0], bool))
    if del_s is not None and del_s.shape[0]:
        parts_s.append(del_s)
        parts_d.append(del_d)
        parts_w.append(np.zeros(del_s.shape[0], np.float32))
        parts_del.append(np.ones(del_s.shape[0], bool))
    if not parts_s:
        return _empty_plan()

    q_src = np.concatenate(parts_s)
    q_dst = np.concatenate(parts_d)
    q_wgt = np.concatenate(parts_w).astype(np.float32)
    q_del = np.concatenate(parts_del)
    # both sides are individually (src, dst)-sorted and their keys are now
    # disjoint, so one stable argsort over the merged keys canonicalizes.
    if len(parts_s) > 1:
        order = np.argsort(_pair_keys(q_src, q_dst), kind="stable")
        q_src, q_dst, q_wgt, q_del = (
            q_src[order], q_dst[order], q_wgt[order], q_del[order]
        )

    # per-row runs: the single np.unique pass shared by insert and delete
    rows, run_first, run_count = np.unique(
        q_src, return_index=True, return_counts=True
    )
    rows = rows.astype(np.int64)
    run_first = run_first.astype(np.int64)
    run_count = run_count.astype(np.int64)
    ins_count = np.add.reduceat((~q_del).astype(np.int64), run_first)
    k = int(next_pow2_vec(run_count.max())[()]) if rows.shape[0] else 1

    return UpdatePlan(
        q_src=q_src,
        q_dst=q_dst,
        q_wgt=q_wgt,
        q_del=q_del,
        rows=rows,
        run_first=run_first,
        run_count=run_count,
        ins_count=ins_count,
        run_width=k,
    )


def plan_from_canonical(q_src, q_dst, q_wgt, q_del) -> UpdatePlan:
    """Rebuild an UpdatePlan from its canonical op stream (WAL replay path).

    The journal persists exactly the four canonical arrays; everything else
    (runs, widths) is derived state, recomputed here with the same
    ``np.unique`` pass ``_build_plan`` uses — so a replayed plan drives
    ``apply`` through byte-identical operands.  The stream must already be
    canonical: (src, dst)-sorted with strictly increasing keys, negative
    ids rejected.  Value-level validation (finite weights, vertex bounds)
    stays in :meth:`UpdatePlan.validate`, which replay calls with the
    record's vertex watermark.
    """
    q_src = np.ascontiguousarray(q_src, np.int32)
    q_dst = np.ascontiguousarray(q_dst, np.int32)
    q_wgt = np.ascontiguousarray(q_wgt, np.float32)
    q_del = np.ascontiguousarray(q_del, bool)
    n = q_src.shape[0]
    if not (q_dst.shape[0] == q_wgt.shape[0] == q_del.shape[0] == n):
        raise ValueError("plan_from_canonical: op stream arrays disagree on length")
    if n == 0:
        return _empty_plan()
    if int(q_src.min()) < 0 or int(q_dst.min()) < 0:
        raise ValueError("plan_from_canonical: negative vertex id")
    keys = _pair_keys(q_src, q_dst)
    if n >= 2 and not bool(np.all(keys[1:] > keys[:-1])):
        raise ValueError("plan_from_canonical: op stream not (src, dst)-sorted unique")

    rows, run_first, run_count = np.unique(
        q_src, return_index=True, return_counts=True
    )
    rows = rows.astype(np.int64)
    run_first = run_first.astype(np.int64)
    run_count = run_count.astype(np.int64)
    ins_count = np.add.reduceat((~q_del).astype(np.int64), run_first)
    k = int(next_pow2_vec(run_count.max())[()])
    return UpdatePlan(
        q_src=q_src,
        q_dst=q_dst,
        q_wgt=q_wgt,
        q_del=q_del,
        rows=rows,
        run_first=run_first,
        run_count=run_count,
        ins_count=ins_count,
        run_width=k,
    )


# ---------------------------------------------------------------------------
# plan cache — steady-state streams skip host planning entirely
# ---------------------------------------------------------------------------
_CACHE_SIZE = 32
_cache: "collections.OrderedDict[tuple[int, int], tuple]" = collections.OrderedDict()


def _ref(obj):
    if obj is None:
        return lambda: None
    return weakref.ref(obj)


def plan_update(
    inserts: Optional[edgebatch.EdgeBatch] = None,
    deletes: Optional[edgebatch.EdgeBatch] = None,
) -> UpdatePlan:
    """Build (or recall) the UpdatePlan for an insert/delete batch pair.

    Plans are memoized by batch identity: reapplying the same
    ``EdgeBatch`` objects — a replayed stream round, or one batch applied
    to all five representations — returns the cached plan with zero host
    work.  Identity is verified through weakrefs, so a recycled ``id()``
    can never alias a dead batch.
    """
    key = (id(inserts), id(deletes))
    hit = _cache.get(key)
    if hit is not None and hit[0]() is inserts and hit[1]() is deletes:
        _cache.move_to_end(key)
        return hit[2]
    plan = _build_plan(inserts, deletes)
    _cache[key] = (_ref(inserts), _ref(deletes), plan)
    while len(_cache) > _CACHE_SIZE:
        _cache.popitem(last=False)
    return plan


def plan_cache_clear() -> None:
    _cache.clear()
