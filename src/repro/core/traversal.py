"""k-step reverse walk (paper Alg 13): visits = Aᵀᵏ · 1̄ computed directly on
the out-edge representation (visits1[u] = Σ_{(u,v)∈E} visits0[v]).

``reverse_walk_flat`` is the seed baseline (gather + segment_sum over the
FULL slot-buffer capacity, re-masking every dead SENTINEL lane per step);
``reverse_walk_slotted`` is the optimized path through the fused
``kernels/slot_walk`` tile engine (DESIGN.md §6), which only walks the
arena's live prefix and uses the MXU one-hot-rank reduction on TPU.
``reverse_walk_image`` walks a canonical ``core.walk_image.WalkImage``
(DESIGN.md §11) — the representation-independent entry every structure
now lowers to; the per-representation ``reverse_walk_coo`` /
``reverse_walk_csr`` slow paths are retired in its favour (the flat
baseline is kept as the benchmarked seed reference).
float32 counts: 42 steps on large graphs overflow int; the paper benchmarks
wall-time, not values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import util

SENTINEL = util.SENTINEL


@functools.partial(jax.jit, static_argnames=("steps", "num_vertices"))
def reverse_walk_flat(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    normalize: bool = False,
) -> jnp.ndarray:
    """Reverse walk over a flat slotted edge buffer (DiGraph payload).

    Empty slots carry ``dst == SENTINEL`` and are masked; ``slot_rows`` maps
    each slot to its owning vertex (stale entries point at dead slots whose
    contribution is zeroed by the mask).
    """
    valid = dst != SENTINEL
    safe_dst = jnp.where(valid, dst, 0)
    safe_row = jnp.where(
        valid & (slot_rows < num_vertices), slot_rows, num_vertices
    ).astype(jnp.int32)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        vals = jnp.where(valid, visits[safe_dst], 0.0)
        nxt = jax.ops.segment_sum(vals, safe_row, num_segments=num_vertices + 1)[
            :num_vertices
        ]
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


def reverse_walk_slotted(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int | None = None,
    backend: str = "auto",
    block_lo: jnp.ndarray | None = None,
    block_hi: jnp.ndarray | None = None,
    normalize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Reverse walk via the fused slot_walk tile engine (DESIGN.md §6).

    Same semantics as ``reverse_walk_flat`` but only the ``edges_hi``-slot
    arena prefix is processed, tiled into 128-slot MXU tiles.  ``backend``
    selects the Pallas kernel ("pallas"), the jnp tile fold ("xla"), or
    picks per accelerator ("auto"); per-vertex block intervals enable the
    scatter-free prefix-sum step off-TPU.
    """
    from ..kernels.slot_walk import ops as _slot_ops  # lazy: avoid import cycle

    return _slot_ops.slot_walk(
        dst,
        slot_rows,
        steps,
        num_vertices,
        edges_hi=edges_hi,
        backend=backend,
        block_lo=block_lo,
        block_hi=block_hi,
        normalize=normalize,
        interpret=interpret,
    )


def reverse_walk_image(
    image,
    steps: int,
    *,
    backend: str = "auto",
    normalize: bool = False,
    interpret: bool = False,
    visits0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Reverse walk over a canonical walk image (DESIGN.md §11).

    Every representation's ``reverse_walk`` lowers to this: the image
    carries the packed buffers, quantized prefix bound and per-vertex
    block intervals, so all five structures share one traversal engine
    (and its warm jit shapes).  ``visits0`` [B, V] batches B walks
    through the same fused step loop.
    """
    from ..kernels.slot_walk import ops as _slot_ops  # lazy: avoid import cycle

    return _slot_ops.slot_walk_image(
        image,
        steps,
        backend=backend,
        normalize=normalize,
        interpret=interpret,
        visits0=visits0,
    )


def reverse_walk_dense_oracle(adj, steps: int):
    """Numpy oracle: Aᵏ · 1̄ over the 0/1 out-adjacency (tests only)."""
    import numpy as np

    a = (np.asarray(adj) != 0).astype(np.float64)
    v = np.ones(a.shape[0])
    for _ in range(steps):
        v = a @ v
    return v
