"""Device-buffer arena: CP2AA's block allocator applied to a flat jnp buffer.

The paper's CP2AA hands out power-of-2-sized blocks from pools and recycles
freed blocks through per-size-class free lists.  Here the "pool" is one flat
device array of edge slots; *this class only does the bookkeeping on host*
(which slots belong to which vertex).  Handing a freed block to a new vertex
is a metadata operation — no device traffic — exactly like CP2AA's free-list
pop.  Growing the pool is a pow-2 whole-buffer reallocation (the amortized
path, mirroring AA's "allocate a new pool").
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from . import alloc


@dataclasses.dataclass
class ArenaLayout:
    """Host-side slot allocator for a flat device buffer of ``capacity`` slots."""

    capacity: int
    bump: int = 0
    freed: dict[int, list[int]] = dataclasses.field(
        default_factory=lambda: defaultdict(list)
    )
    n_alloc: int = 0
    n_free: int = 0
    n_reuse: int = 0

    def try_alloc(self, size_class: int) -> int | None:
        """Allocate a block of ``size_class`` slots; None if pool exhausted.

        Mirrors FAA.allocate() (paper Alg 9): freed list first, then bump.
        """
        lst = self.freed.get(size_class)
        if lst:
            self.n_reuse += 1
            return lst.pop()
        if self.bump + size_class <= self.capacity:
            start = self.bump
            self.bump += size_class
            self.n_alloc += 1
            return start
        return None

    def free(self, start: int, size_class: int) -> None:
        self.freed[int(size_class)].append(int(start))
        self.n_free += 1

    def grow_target(self, extra: int) -> int:
        """New pool capacity able to fit ``extra`` more slots (pow-2 growth)."""
        return alloc.next_pow2(max(self.bump + extra, self.capacity + 1))

    def live_slots(self) -> int:
        freed_total = sum(k * len(v) for k, v in self.freed.items())
        return self.bump - freed_total

    def clone(self) -> "ArenaLayout":
        c = ArenaLayout(self.capacity, self.bump)
        c.freed = defaultdict(list, {k: list(v) for k, v in self.freed.items()})
        return c
