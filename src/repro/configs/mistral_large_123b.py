"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
import jax.numpy as jnp

from ..models.transformer.config import TransformerConfig
from . import base

FULL = TransformerConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=32768,
    rope_theta=1e6,
    attn_impl="blocked",
)

SMOKE = TransformerConfig(
    name="mistral-large-123b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    attn_impl="ref",
    compute_dtype=jnp.float32,
)

base.register(
    base.ArchEntry(
        name="mistral-large-123b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        model="transformer",
        skip_shapes={
            "long_500k": "pure full attention (quadratic) — skipped per "
            "assignment; see DESIGN.md §4"
        },
    )
)
