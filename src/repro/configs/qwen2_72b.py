"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""
import jax.numpy as jnp

from ..models.transformer.config import TransformerConfig
from . import base

FULL = TransformerConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    attn_impl="blocked",
)

SMOKE = TransformerConfig(
    name="qwen2-72b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    attn_impl="ref",
    compute_dtype=jnp.float32,
)

base.register(
    base.ArchEntry(
        name="qwen2-72b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        model="transformer",
        skip_shapes={
            "long_500k": "pure full attention (quadratic) — skipped per "
            "assignment; see DESIGN.md §4"
        },
    )
)
