"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566; paper]."""
from ..models.gnn.schnet import SchNetConfig
from . import base

FULL = SchNetConfig(name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
SMOKE = SchNetConfig(name="schnet-smoke", n_interactions=2, d_hidden=16, n_rbf=16, cutoff=5.0)

base.register(
    base.ArchEntry(name="schnet", family="gnn", full=FULL, smoke=SMOKE, model="schnet")
)
