"""graphcast [gnn]: n_layers=16 d_hidden=512 mesh_refinement=6 aggregator=sum
n_vars=227 — encoder-processor-decoder mesh GNN [arXiv:2212.12794; unverified]."""
from ..models.gnn.graphcast import GraphCastConfig
from . import base

FULL = GraphCastConfig(
    name="graphcast", n_layers=16, d_hidden=512, n_vars=227, mesh_refinement=6,
    aggregator="sum",
)
SMOKE = GraphCastConfig(
    name="graphcast-smoke", n_layers=2, d_hidden=32, n_vars=11, mesh_refinement=1
)

base.register(
    base.ArchEntry(
        name="graphcast", family="gnn", full=FULL, smoke=SMOKE, model="graphcast"
    )
)
