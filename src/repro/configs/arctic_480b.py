"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base; hf]."""
import jax.numpy as jnp

from ..models.transformer.config import MoEConfig, TransformerConfig
from . import base

FULL = TransformerConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=0,
    vocab=32000,
    moe=MoEConfig(
        n_experts=128, top_k=2, d_ff_expert=4864, dense_residual_ff=4864
    ),
    rope_theta=1e6,
    attn_impl="blocked",
    # 480B params: bf16 params + bf16 adam m/v — 8 B/param -> ~15 GB/chip
    # on the 256-chip pod (DESIGN.md §5 memory budget)
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=0,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, dense_residual_ff=48),
    attn_impl="ref",
    compute_dtype=jnp.float32,
)

base.register(
    base.ArchEntry(
        name="arctic-480b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        model="transformer",
        skip_shapes={
            "long_500k": "pure full attention (quadratic) — skipped per "
            "assignment; see DESIGN.md §4"
        },
    )
)
