"""Architecture registry: 10 assigned archs × their shape sets (40 cells).

Each arch module defines FULL (exact assigned config), SMOKE (reduced, CPU
one-step testable) and registers itself here.  Shapes are per-family; the
``skip`` table marks cells that are skipped by-design (long_500k on pure
full-attention LMs — DESIGN.md §4) — they still appear in the cell list so
EXPERIMENTS.md accounts for all 40.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

# ---------------------------------------------------------------------------
# shape sets (assignment block, verbatim)
# ---------------------------------------------------------------------------
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": dict(
        kind="sampled",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1024,
        fanout=(15, 10),
    ),
    "ogb_products": dict(
        kind="full", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100
    ),
    "molecule": dict(kind="batched", n_nodes=30, n_edges=64, batch=128),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    family: str                  # lm | gnn | recsys
    full: Any                    # exact assigned config
    smoke: Any                   # reduced config
    model: str                   # model module key
    skip_shapes: dict = dataclasses.field(default_factory=dict)  # shape -> reason


_REGISTRY: dict[str, ArchEntry] = {}


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> ArchEntry:
    _ensure_loaded()
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchEntry]:
    _ensure_loaded()
    return dict(_REGISTRY)


def all_cells() -> list[tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells."""
    _ensure_loaded()
    out = []
    for name, e in _REGISTRY.items():
        for shape in FAMILY_SHAPES[e.family]:
            out.append((name, shape, e.skip_shapes.get(shape)))
    return out


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        arctic_480b,
        gcn_cora,
        graphcast,
        h2o_danube_1_8b,
        mace,
        mistral_large_123b,
        qwen2_72b,
        qwen3_moe_235b_a22b,
        schnet,
        two_tower_retrieval,
    )
