"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family; hf]."""
import jax.numpy as jnp

from ..models.transformer.config import MoEConfig, TransformerConfig
from . import base

FULL = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=0,
    vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    rope_theta=1e6,
    attn_impl="blocked",
    # 235B params: bf16 storage + bf16 adam states to fit single-pod HBM
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=0,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
    attn_impl="ref",
    compute_dtype=jnp.float32,
)

base.register(
    base.ArchEntry(
        name="qwen3-moe-235b-a22b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        model="transformer",
        skip_shapes={
            "long_500k": "pure full attention (quadratic) — skipped per "
            "assignment; see DESIGN.md §4"
        },
    )
)
