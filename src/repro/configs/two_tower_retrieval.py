"""two-tower-retrieval [recsys]: embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 (YouTube); unverified]."""
from ..models.recsys.two_tower import TwoTowerConfig
from . import base

FULL = TwoTowerConfig(
    name="two-tower-retrieval",
    embed_dim=256,
    tower_mlp=(1024, 512, 256),
    interaction="dot",
    n_users=10_000_000,
    n_items=10_000_000,
    n_user_fields=4,
    n_item_fields=2,
    bag_size=16,
)
SMOKE = TwoTowerConfig(
    name="two-tower-smoke",
    embed_dim=16,
    tower_mlp=(32, 16),
    n_users=1000,
    n_items=1000,
    n_user_fields=2,
    n_item_fields=2,
    bag_size=4,
)

base.register(
    base.ArchEntry(
        name="two-tower-retrieval",
        family="recsys",
        full=FULL,
        smoke=SMOKE,
        model="two_tower",
    )
)
