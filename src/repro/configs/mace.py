"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8
equivariance=E(3)-ACE [arXiv:2206.07697; paper]."""
from ..models.gnn.mace import MACEConfig
from . import base

FULL = MACEConfig(
    name="mace", n_layers=2, d_hidden=128, l_max=2, correlation_order=3, n_rbf=8
)
SMOKE = MACEConfig(
    name="mace-smoke", n_layers=2, d_hidden=16, l_max=2, correlation_order=3, n_rbf=4
)

base.register(
    base.ArchEntry(name="mace", family="gnn", full=FULL, smoke=SMOKE, model="mace")
)
