"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; hf]."""
import jax.numpy as jnp

from ..models.transformer.config import TransformerConfig
from . import base

FULL = TransformerConfig(
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,          # the mistral-style SWA mix
    rope_theta=1e4,
    attn_impl="blocked",
)

SMOKE = TransformerConfig(
    name="h2o-danube-1.8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=256,
    sliding_window=16,
    attn_impl="ref",
    compute_dtype=jnp.float32,
)

base.register(
    base.ArchEntry(
        name="h2o-danube-1.8b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        model="transformer",
        # SWA is sub-quadratic: long_500k RUNS for this arch (ring cache)
    )
)
