"""gcn-cora [gnn]: n_layers=2 d_hidden=16 aggregator=mean norm=sym
[arXiv:1609.02907; paper]."""
from ..models.gnn.gcn import GCNConfig
from . import base

FULL = GCNConfig(
    name="gcn-cora", n_layers=2, d_hidden=16, d_in=1433, n_classes=7,
    aggregator="mean", norm="sym",
)
SMOKE = GCNConfig(
    name="gcn-cora-smoke", n_layers=2, d_hidden=8, d_in=32, n_classes=4
)

base.register(
    base.ArchEntry(name="gcn-cora", family="gnn", full=FULL, smoke=SMOKE, model="gcn")
)
