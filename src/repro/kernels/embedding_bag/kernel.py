"""Pallas TPU kernel: EmbeddingBag — gather + segment-reduce over bags.

JAX has no native EmbeddingBag; the recsys hot path is a ragged gather over
a huge table followed by a per-bag reduction.  TPU formulation: the bag's
indices ride in as a *scalar-prefetch* operand so the table BlockSpec
index_map chases them — each grid step DMAs exactly one table row-block
from HBM into VMEM (no dense one-hot, no full-table sweep), accumulating
into the bag's output block.  This is the Pallas block-table-indirection
pattern (same machinery as paged attention KV lookup).

Grid (n_bags, K): K (bag slots, pow-2 padded) is innermost so output
blocks accumulate in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, wgt_ref, table_ref, o_ref, *, combine: str):
    b = pl.program_id(0)
    k = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        if combine == "max":
            o_ref[...] = jnp.full_like(o_ref, -jnp.inf)
        else:
            o_ref[...] = jnp.zeros_like(o_ref)

    valid = idx_ref[b, k] >= 0

    @pl.when(valid)
    def _acc():
        row = table_ref[...]  # [1, D] current table row block
        if combine == "sum" or combine == "mean":
            o_ref[...] += row * wgt_ref[b, k]
        else:  # max
            o_ref[...] = jnp.maximum(o_ref[...], row)

    if combine == "mean":

        @pl.when(k == n_k - 1)
        def _norm():
            cnt = jnp.sum((idx_ref[b, :] >= 0).astype(jnp.float32))
            o_ref[...] /= jnp.maximum(cnt, 1.0)

    if combine == "max":

        @pl.when(k == n_k - 1)
        def _fix_empty():
            o_ref[...] = jnp.where(jnp.isfinite(o_ref[...]), o_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def embedding_bag(
    table: jnp.ndarray,   # [V, D]
    indices: jnp.ndarray,  # [n_bags, K] int32, -1 padding
    weights: jnp.ndarray,  # [n_bags, K] f32 per-sample weights
    *,
    combine: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    n_bags, k = indices.shape
    v, d = table.shape

    def table_idx(b, kk, idx_ref, wgt_ref):
        return (jnp.clip(idx_ref[b, kk], 0, v - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_bags, k),
        in_specs=[pl.BlockSpec((1, d), table_idx)],
        out_specs=pl.BlockSpec((1, d), lambda b, kk, *_: (b, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, combine=combine),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(indices, weights, table)
