"""Pure-jnp EmbeddingBag oracle: take + segment reduce."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_reference(table, indices, weights, *, combine: str = "sum"):
    """table [V,D], indices [B,K] (-1 pad), weights [B,K] -> [B,D]."""
    valid = indices >= 0
    safe = jnp.clip(indices, 0, table.shape[0] - 1)
    rows = table[safe]  # [B, K, D]
    if combine in ("sum", "mean"):
        rows = rows * jnp.where(valid, weights, 0.0)[..., None]
        out = rows.sum(axis=1)
        if combine == "mean":
            out = out / jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        return out
    rows = jnp.where(valid[..., None], rows, -jnp.inf)
    out = rows.max(axis=1)
    return jnp.where(jnp.isfinite(out), out, 0.0)
