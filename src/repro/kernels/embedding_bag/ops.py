"""jit'd EmbeddingBag wrapper with pow-2 bag padding + jnp fallback."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import alloc
from . import kernel as _kernel
from . import ref as _ref


def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights=None,
    *,
    combine: str = "sum",
    use_kernel: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """EmbeddingBag over [n_bags, K] ragged index bags (-1 = padding).

    ``use_kernel=False`` routes to the jnp path (used on CPU and inside
    models whose dry-run shapes make per-row DMA suboptimal; the pjit
    sharding of the table is identical either way).
    """
    if indices.ndim == 1:
        indices = indices[None]
    k = indices.shape[-1]
    k_pad = alloc.next_pow2(max(k, 1))
    if k_pad != k:
        pad = jnp.full(indices.shape[:-1] + (k_pad - k,), -1, indices.dtype)
        indices = jnp.concatenate([indices, pad], axis=-1)
    if weights is None:
        weights = jnp.ones(indices.shape, jnp.float32)
    elif weights.shape[-1] != indices.shape[-1]:
        wpad = jnp.zeros(
            weights.shape[:-1] + (indices.shape[-1] - weights.shape[-1],),
            jnp.float32,
        )
        weights = jnp.concatenate([weights, wpad], axis=-1)
    if not use_kernel:
        return _ref.embedding_bag_reference(table, indices, weights, combine=combine)
    return _kernel.embedding_bag(
        table,
        indices.astype(jnp.int32),
        weights.astype(jnp.float32),
        combine=combine,
        interpret=interpret,
    )


def embedding_bag_reference(table, indices, weights=None, *, combine="sum"):
    if weights is None:
        weights = jnp.ones(indices.shape, jnp.float32)
    return _ref.embedding_bag_reference(table, indices, weights, combine=combine)
