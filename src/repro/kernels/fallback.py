"""Health-gated backend fallback chain for kernel dispatch (DESIGN.md §13).

A Pallas miscompile or a device OOM mid-stream should degrade throughput,
not kill the update pipeline.  Every chained dispatch site (``slot_update``
fused apply, ``slot_walk``) runs its attempt through :func:`run_chain`,
which walks the backend chain

    pallas → xla → host ref        (or xla → ref when pallas isn't requested)

under a per-(site, backend) circuit breaker:

* **closed** — backend healthy, dispatch goes straight through (cost on the
  healthy path: one dict lookup);
* each candidate gets **retry-once** (transient failures — a flaky
  allocation — don't trip the breaker needlessly);
* two consecutive failures **trip** the breaker: the backend is *open* for
  an exponentially growing cool-down (``cooldown * 2^(trips-1)``, capped),
  and dispatch falls through to the next link;
* an expired cool-down moves the breaker to **half-open**: exactly ONE
  probe dispatch is admitted (``admit`` returns ``"probe"``; concurrent
  dispatchers are refused until the probe resolves or its window lapses)
  and gets a single attempt — success closes the breaker (full
  re-promotion, trip history cleared), failure re-trips it with a doubled
  cool-down.  A probe that never reports back (its thread died) expires
  after one base cool-down so the backend is not stranded half-open.

The last link of a chain is always attempted even when its breaker is open
(there is nothing further to fall back to); if it too fails,
:class:`FallbackExhausted` carries the final error.

``faultinject.fire(f"{site}.{backend}")`` runs *before* every attempt, so
injected kernel failures hit with operands untouched — which also means a
donated-buffer first attempt can always be retried on the next link.  A
real failure *after* a donated buffer was consumed is not retryable (jax
reports the deleted buffer and the chain exhausts); injection points and
off-device failures (compile/lowering errors) both fire pre-execution, so
every failure mode this layer is tested against falls back cleanly.

:class:`SimulatedCrash` is a BaseException and flies through the chain —
a process kill is not a kernel failure.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from ..runtime import faultinject

CHAINS = {
    "pallas": ("pallas", "xla", "ref"),
    "xla": ("xla", "ref"),
    "ref": ("ref",),
}

#: retries per candidate before its breaker trips (retry-once)
RETRIES = 1

#: site -> backend that served the most recent successful dispatch
LAST_USED: dict = {}


class FallbackExhausted(RuntimeError):
    """Every backend in the chain failed; ``__cause__`` is the final error."""


class CircuitBreaker:
    """Per-key trip/cool-down state.  Keys are (site, backend) tuples.

    The clock is injectable so tests drive cool-down expiry with a
    simulated clock instead of sleeping.  All transitions are guarded by
    a lock so concurrent dispatchers (the serve layer) share one breaker
    safely; ``admit`` implements the explicit half-open protocol.
    """

    def __init__(
        self,
        *,
        cooldown: float = 0.25,
        max_cooldown: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cooldown = cooldown
        self.max_cooldown = max_cooldown
        self.clock = clock
        self._lock = threading.Lock()
        # key -> {"trips": int, "open_until": float, "probe_until": float}
        # probe_until > 0 means a half-open probe is in flight until then
        self._state: dict = {}

    def available(self, key) -> bool:
        with self._lock:
            st = self._state.get(key)
            return st is None or self.clock() >= st["open_until"]

    def admit(self, key) -> Optional[str]:
        """Half-open admission: ``"closed"`` (healthy, dispatch freely),
        ``"probe"`` (this caller is THE single half-open probe and gets
        one attempt), or ``None`` (open / probe already in flight —
        fall through to the next link)."""
        with self._lock:
            st = self._state.get(key)
            if st is None:
                return "closed"
            now = self.clock()
            if now < st["open_until"]:
                return None
            if now < st["probe_until"]:
                return None  # another dispatcher holds the probe slot
            # claim the probe slot; a probe that never resolves expires
            # after one base cool-down instead of stranding the backend
            st["probe_until"] = now + self.cooldown
            return "probe"

    def trip(self, key) -> None:
        with self._lock:
            st = self._state.setdefault(
                key, {"trips": 0, "open_until": 0.0, "probe_until": 0.0}
            )
            st["trips"] += 1
            wait = min(
                self.cooldown * (2.0 ** (st["trips"] - 1)), self.max_cooldown
            )
            st["open_until"] = self.clock() + wait
            st["probe_until"] = 0.0  # probe resolved (by failing)

    def record_success(self, key) -> None:
        # full re-promotion: the trip history is cleared, not just paused
        with self._lock:
            self._state.pop(key, None)

    def state(self, key) -> Optional[dict]:
        with self._lock:
            st = self._state.get(key)
            return None if st is None else dict(st)

    def reset(self) -> None:
        with self._lock:
            self._state.clear()


#: process-wide breaker shared by all chained dispatch sites
BREAKER = CircuitBreaker()


def run_chain(site: str, backend: str, attempt: Callable, *, breaker: Optional[CircuitBreaker] = None):
    """Run ``attempt(candidate)`` down ``CHAINS[backend]``.

    Returns ``(result, used_backend)``.  Raises :exc:`FallbackExhausted`
    when every candidate fails; lets :class:`SimulatedCrash` (BaseException)
    propagate untouched.
    """
    br = breaker if breaker is not None else BREAKER
    candidates = CHAINS.get(backend, (backend,))
    last_err: Optional[Exception] = None
    for i, b in enumerate(candidates):
        key = (site, b)
        mode = br.admit(key)
        if mode is None:
            if i < len(candidates) - 1:
                continue  # cooling down; the chain floor always gets a shot
            mode = "probe"  # open floor: one attempt, nothing to fall to
        # half-open probes get exactly one attempt; closed links retry-once
        tries = 1 if mode == "probe" else RETRIES + 1
        for _ in range(tries):
            try:
                faultinject.fire(f"{site}.{b}")
                out = attempt(b)
            except Exception as e:
                last_err = e
                continue
            br.record_success(key)
            LAST_USED[site] = b
            return out, b
        br.trip(key)
    raise FallbackExhausted(
        f"{site}: all backends failed (chain {candidates}, requested {backend!r})"
    ) from last_err
