"""Health-gated backend fallback chain for kernel dispatch (DESIGN.md §13).

A Pallas miscompile or a device OOM mid-stream should degrade throughput,
not kill the update pipeline.  Every chained dispatch site (``slot_update``
fused apply, ``slot_walk``) runs its attempt through :func:`run_chain`,
which walks the backend chain

    pallas → xla → host ref        (or xla → ref when pallas isn't requested)

under a per-(site, backend) circuit breaker:

* **closed** — backend healthy, dispatch goes straight through (cost on the
  healthy path: one dict lookup);
* each candidate gets **retry-once** (transient failures — a flaky
  allocation — don't trip the breaker needlessly);
* two consecutive failures **trip** the breaker: the backend is *open* for
  an exponentially growing cool-down (``cooldown * 2^(trips-1)``, capped),
  and dispatch falls through to the next link;
* an expired cool-down is the implicit **half-open** probe: the next
  dispatch tries the backend again — success closes the breaker
  (re-promotion), failure re-trips it with a doubled cool-down.

The last link of a chain is always attempted even when its breaker is open
(there is nothing further to fall back to); if it too fails,
:class:`FallbackExhausted` carries the final error.

``faultinject.fire(f"{site}.{backend}")`` runs *before* every attempt, so
injected kernel failures hit with operands untouched — which also means a
donated-buffer first attempt can always be retried on the next link.  A
real failure *after* a donated buffer was consumed is not retryable (jax
reports the deleted buffer and the chain exhausts); injection points and
off-device failures (compile/lowering errors) both fire pre-execution, so
every failure mode this layer is tested against falls back cleanly.

:class:`SimulatedCrash` is a BaseException and flies through the chain —
a process kill is not a kernel failure.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from ..runtime import faultinject

CHAINS = {
    "pallas": ("pallas", "xla", "ref"),
    "xla": ("xla", "ref"),
    "ref": ("ref",),
}

#: retries per candidate before its breaker trips (retry-once)
RETRIES = 1

#: site -> backend that served the most recent successful dispatch
LAST_USED: dict = {}


class FallbackExhausted(RuntimeError):
    """Every backend in the chain failed; ``__cause__`` is the final error."""


class CircuitBreaker:
    """Per-key trip/cool-down state.  Keys are (site, backend) tuples.

    The clock is injectable so tests drive cool-down expiry with a
    simulated clock instead of sleeping.
    """

    def __init__(
        self,
        *,
        cooldown: float = 0.25,
        max_cooldown: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cooldown = cooldown
        self.max_cooldown = max_cooldown
        self.clock = clock
        self._state: dict = {}  # key -> {"trips": int, "open_until": float}

    def available(self, key) -> bool:
        st = self._state.get(key)
        return st is None or self.clock() >= st["open_until"]

    def trip(self, key) -> None:
        st = self._state.setdefault(key, {"trips": 0, "open_until": 0.0})
        st["trips"] += 1
        wait = min(self.cooldown * (2.0 ** (st["trips"] - 1)), self.max_cooldown)
        st["open_until"] = self.clock() + wait

    def record_success(self, key) -> None:
        # full re-promotion: the trip history is cleared, not just paused
        self._state.pop(key, None)

    def state(self, key) -> Optional[dict]:
        st = self._state.get(key)
        return None if st is None else dict(st)

    def reset(self) -> None:
        self._state.clear()


#: process-wide breaker shared by all chained dispatch sites
BREAKER = CircuitBreaker()


def run_chain(site: str, backend: str, attempt: Callable, *, breaker: Optional[CircuitBreaker] = None):
    """Run ``attempt(candidate)`` down ``CHAINS[backend]``.

    Returns ``(result, used_backend)``.  Raises :exc:`FallbackExhausted`
    when every candidate fails; lets :class:`SimulatedCrash` (BaseException)
    propagate untouched.
    """
    br = breaker if breaker is not None else BREAKER
    candidates = CHAINS.get(backend, (backend,))
    last_err: Optional[Exception] = None
    for i, b in enumerate(candidates):
        key = (site, b)
        if i < len(candidates) - 1 and not br.available(key):
            continue  # cooling down; the chain floor always gets a shot
        for _ in range(RETRIES + 1):
            try:
                faultinject.fire(f"{site}.{b}")
                out = attempt(b)
            except Exception as e:
                last_err = e
                continue
            br.record_success(key)
            LAST_USED[site] = b
            return out, b
        br.trip(key)
    raise FallbackExhausted(
        f"{site}: all backends failed (chain {candidates}, requested {backend!r})"
    ) from last_err
