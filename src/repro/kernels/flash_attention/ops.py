"""jit'd attention wrapper: flash kernel for prefill/train, jnp for decode.

Decode (single-query) attention is a memory-bound matvec — XLA's fused
path is already roofline-bound there, so the Pallas kernel only covers
prefill/training shapes (Sq > 1).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    use_kernel: bool = True, interpret: bool = False,
    block_q: int = 128, block_k: int = 128,
):
    sq = q.shape[2]
    if not use_kernel or sq == 1:
        return _ref.attention_reference(q, k, v, causal=causal, window=window)
    return _kernel.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def decode_attention(q, k, v, kv_len, *, window: int = 0):
    """Single-token decode vs a prefix of the KV cache.

    q [B,Hq,1,D]; k/v [B,Hkv,S,D] ring/linear caches; kv_len scalar = live
    prefix length.  Masks cache slots >= kv_len.
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    ids = jnp.arange(s)[None, None, None, :]
    mask = ids < kv_len
    if window > 0:
        mask = mask & (ids > kv_len - 1 - window)
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


attention_reference = _ref.attention_reference
