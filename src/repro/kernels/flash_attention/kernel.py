"""Pallas TPU kernel: flash attention (online softmax) with causal and
sliding-window masking and GQA head grouping.

Grid (B·Hq, Sq/BQ, Skv/BK), kv innermost.  Running max/denominator live in
VMEM scratch; fully-masked kv blocks are skipped via @pl.when (this is what
makes sliding-window attention O(S·w) — the h2o-danube/long_500k path).
K/V BlockSpecs map the query head to its KV head (GQA: h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int, bq: int, bk: int, n_kv: int
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = qi * bq
    k0 = ki * bk

    # block-level skip: entirely above the diagonal (causal) or entirely
    # left of the window
    run = jnp.bool_(True)
    if causal:
        run = run & (k0 <= q0 + bq - 1)
    if window > 0:
        run = run & (k0 + bk - 1 >= q0 - window + 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)           # [BQ, D]
        k = k_ref[0].astype(jnp.float32)           # [BK, D]
        v = v_ref[0].astype(jnp.float32)           # [BK, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_ids = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_ids = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.bool_(True)
        if causal:
            mask = mask & (k_ids <= q_ids)
        if window > 0:
            mask = mask & (k_ids > q_ids - window)
        s = jnp.where(mask, s, NEG_INF)
        m_old = m_scr[:, :1]                        # [BQ, 1]
        m_new = jnp.maximum(m_old, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_old - m_new)
        l_new = alpha * l_scr[:, :1] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unlimited; >0 = sliding window size
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def q_idx(i, qi, ki):
        return (i, qi, 0)

    def kv_idx(i, qi, ki):
        bh = i // hq
        h = i % hq
        return (bh * hkv + h // group, ki, 0)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=int(window),
        bq=bq, bk=bk, n_kv=skv // bk,
    )
    out = pl.pallas_call(
        kern,
        grid=(b * hq, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_idx),
            pl.BlockSpec((1, bk, d), kv_idx),
            pl.BlockSpec((1, bk, d), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_idx),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
