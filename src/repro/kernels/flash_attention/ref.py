"""Pure-jnp attention oracle (f32 softmax, causal/window/GQA)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_reference(q, k, v, *, causal=True, window=0):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    q_ids = jnp.arange(sq)[:, None]
    k_ids = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window > 0:
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
