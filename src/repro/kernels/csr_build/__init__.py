"""csr_build — counting-sort COO→CSR→arena construction (DESIGN.md §10)."""
