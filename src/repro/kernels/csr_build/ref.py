"""Pure-numpy oracle for the CSR counting-sort build.

Deliberately naive per-edge semantics: count degrees with a python-level
histogram, prefix-sum offsets, and place every edge via the paper's
shifted-offset fill (Alg 5) — a per-row cursor that appends edges in
(src, dst) order.  Both production engines (the host packed-key sort in
``ops.py`` and the device XLA / Pallas formulations) are tested against
this, as is the arena-image builder.
"""
from __future__ import annotations

import numpy as np

from ...core import util

SENTINEL = util.SENTINEL


def coo_to_csr_reference(src, dst, wgt=None, *, n: int, dedup: bool = False):
    """(src, dst[, wgt]) COO -> (offsets, dst, wgt) with sorted-unique rows.

    Duplicate keys keep the FIRST occurrence's weight (file order), the
    contract ``core.csr.from_coo`` has always had.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = (
        np.asarray(wgt, np.float32)
        if wgt is not None
        else np.ones(src.shape[0], np.float32)
    )
    rows: list[dict] = [dict() for _ in range(n)]
    for s, d, x in zip(src.tolist(), dst.tolist(), w.tolist()):
        r = rows[s]
        if dedup:
            r.setdefault(d, x)
        else:
            r.setdefault(d, []).append(x)
    out_d, out_w, degs = [], [], []
    for r in rows:
        items = sorted(r.items())
        if dedup:
            degs.append(len(items))
            out_d.extend(k for k, _ in items)
            out_w.extend(v for _, v in items)
        else:
            deg = 0
            for k, vs in items:
                for v in vs:
                    out_d.append(k)
                    out_w.append(v)
                    deg += 1
            degs.append(deg)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(degs, out=offsets[1:])
    return (
        offsets.astype(np.int32),
        np.asarray(out_d, np.int32),
        np.asarray(out_w, np.float32),
    )


def count_degrees_reference(src, n: int) -> np.ndarray:
    """Per-vertex out-degree histogram (the Alg 5 degree-count oracle)."""
    deg = np.zeros(n, np.int64)
    for s in np.asarray(src, np.int64).tolist():
        if 0 <= s < n:
            deg[s] += 1
    return deg


def arena_image_reference(offsets, dst, wgt, starts, caps, cap_e, cap_v):
    """CSR -> slotted arena image, one edge at a time (DiGraph layout)."""
    o = np.asarray(offsets, np.int64)
    d = np.asarray(dst, np.int64)
    w = np.asarray(wgt, np.float32)
    a_dst = np.full(cap_e, SENTINEL, np.int32)
    a_wgt = np.zeros(cap_e, np.float32)
    a_rows = np.full(cap_e, cap_v, np.int32)
    for u in range(o.shape[0] - 1):
        if caps[u] <= 0:
            continue
        for k in range(int(caps[u])):
            a_rows[int(starts[u]) + k] = u
        for j in range(int(o[u]), int(o[u + 1])):
            slot = int(starts[u]) + (j - int(o[u]))
            a_dst[slot] = d[j]
            a_wgt[slot] = w[j]
    return a_dst, a_wgt, a_rows
