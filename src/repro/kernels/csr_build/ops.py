"""COO → CSR → arena construction engines (DESIGN.md §10).

The seed built CSRs with a host ``np.lexsort`` — O(M log M) with two key
passes and the slowest single step of graph loading.  This package keeps
the paper's Alg 5 structure (partitioned degree count + shifted-offset
fill) and realizes it as a counting-sort build with three engines:

  host    pack (src, dst) into ONE int64 key and radix argsort it
          (``np.argsort(kind="stable")`` is a radix sort for ints — on
          this container 53k edges sort in ~1ms vs ~5ms for the seed
          lexsort).  Degrees come from a partitioned bincount, offsets
          from one cumsum, and the sorted order IS the shifted-offset
          fill.  Default off-TPU: measured faster than dispatching XLA
          programs for every bench graph size.
  xla     the same counting sort as one jitted program: a multi-operand
          ``lax.sort`` keyed on (src, dst) — no id-width packing limit —
          plus scatter-add degrees and cumsum offsets, all fused.
          Default on TPU, where the host round-trip is the cost.
  pallas  the xla fill with the degree histogram computed by the
          partitioned tile kernel in ``kernel.py`` (TPU; ``interpret=``
          for parity tests elsewhere).

``arena_image`` builds the DiGraph slotted-arena payload (dst/wgt/
slot_rows) straight from CSR arrays — host formulation off-TPU, fused
XLA scatter program on TPU — so load never materializes an intermediate
python-object graph.  ``pages_image`` is the same fill quantized to
ChunkedGraph's PAGE-sized chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import util
from . import kernel as _kernel

SENTINEL = util.SENTINEL
EB = _kernel.EB


def default_engine() -> str:
    return "xla" if jax.default_backend() == "tpu" else "host"


# ---------------------------------------------------------------------------
# degree counting (paper Alg 5 lines 4-8)
# ---------------------------------------------------------------------------
def count_degrees(src, n: int, *, num_partitions: int = 4,
                  engine: str = "auto", interpret: bool = False):
    """Per-vertex degree histogram; out-of-range sources are dropped.

    ``num_partitions`` keeps the paper's per-partition counting shape on
    the host engine (partial bincounts summed — the shard layout of the
    distributed builder); the device engines express the same partition
    structure as edge tiles.
    """
    if engine == "auto":
        engine = default_engine()
    if engine == "host":
        s = np.asarray(src, np.int64)
        s = s[(s >= 0) & (s < n)]
        rho = max(int(num_partitions), 1)
        bounds = np.linspace(0, s.shape[0], rho + 1).astype(np.int64)
        deg = np.zeros(n, np.int64)
        for p in range(rho):
            deg += np.bincount(s[bounds[p]:bounds[p + 1]], minlength=n)
        return deg
    if engine == "xla":
        return _jit_count(int(n))(jnp.asarray(src))
    if engine == "pallas":
        nv = -(-int(n) // EB) * EB
        s = np.asarray(src, np.int64)
        m_pad = -(-max(s.shape[0], 1) // EB) * EB
        tiles = np.full(m_pad, nv, np.int32)
        tiles[: s.shape[0]] = np.where((s >= 0) & (s < n), s, nv)
        deg = _kernel.count_degrees_pallas(
            jnp.asarray(tiles.reshape(-1, EB)), nv=nv, interpret=interpret
        )
        return deg[:n]
    raise ValueError(f"unknown csr_build engine: {engine!r}")


@functools.lru_cache(maxsize=None)
def _jit_count(n: int):
    def fn(src):
        ok = (src >= 0) & (src < n)
        return jnp.zeros((n,), jnp.int32).at[
            jnp.where(ok, src, n)
        ].add(1, mode="drop")

    return jax.jit(fn)


# ---------------------------------------------------------------------------
# the counting-sort CSR fill
# ---------------------------------------------------------------------------
def is_coo_sorted(src: np.ndarray, dst: np.ndarray) -> bool:
    """True when edges are already in (src, dst) order (CSR-order file)."""
    if src.shape[0] < 2:
        return True
    key = (src.astype(np.int64) << 32) | dst.astype(np.uint32).astype(np.int64)
    return bool((key[1:] >= key[:-1]).all())


def sort_coo_host(src: np.ndarray, dst: np.ndarray, *values: np.ndarray):
    """Stable (src, dst) order via ONE packed-key radix argsort.

    Packing both int32 ids into an int64 key turns the seed's two-pass
    ``np.lexsort`` into a single radix sort — the core host-side speedup
    of the ingest engine.  Stability preserves file order among duplicate
    keys (the dedup-keep-first contract).
    """
    key = (src.astype(np.int64) << 32) | dst.astype(np.uint32).astype(np.int64)
    order = np.argsort(key, kind="stable")
    return (src[order], dst[order], *(v[order] for v in values))


@functools.lru_cache(maxsize=None)
def _jit_coo_to_csr(n: int, m: int):
    """Fused device counting sort: lex sort + degree scatter + cumsum.

    Pad edges must carry src >= n; they sort to the tail and fall out of
    the degree histogram, so offsets/dst/wgt prefixes match the host
    engine bit for bit.
    """

    def fn(src, dst, wgt):
        src, dst, wgt = jax.lax.sort(
            (src, dst, wgt), dimension=0, num_keys=2, is_stable=True
        )
        deg = _jit_count(n)(src)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(deg, dtype=jnp.int32)]
        )
        return offsets, src, dst, wgt

    return jax.jit(fn)


def coo_to_csr_device(src, dst, wgt, *, n: int):
    """Device counting-sort build; returns (offsets, src_s, dst_s, wgt_s).

    Arrays keep their padded length; live edges occupy the prefix (pad
    entries carry src >= n and sort last).
    """
    src = jnp.asarray(src, jnp.int32)
    return _jit_coo_to_csr(int(n), int(src.shape[0]))(
        src, jnp.asarray(dst, jnp.int32), jnp.asarray(wgt, jnp.float32)
    )


@functools.lru_cache(maxsize=None)
def _jit_sort_coo(m: int):
    def fn(src, dst, wgt):
        return jax.lax.sort(
            (src, dst, wgt), dimension=0, num_keys=2, is_stable=True
        )

    return jax.jit(fn)


def sort_coo_device(src, dst, wgt):
    """Device (src, dst) lex sort only — for engines that source their
    degree histogram elsewhere (the Pallas tile kernel)."""
    src = jnp.asarray(src, jnp.int32)
    return _jit_sort_coo(int(src.shape[0]))(
        src, jnp.asarray(dst, jnp.int32), jnp.asarray(wgt, jnp.float32)
    )


# ---------------------------------------------------------------------------
# CSR -> DiGraph arena image (the paper's load-into-representation step)
# ---------------------------------------------------------------------------
def arena_image_host(offsets, dst, wgt, starts, caps, cap_e: int, cap_v: int):
    """Numpy shifted-offset fill of the slotted arena (single pass each).

    ``starts``/``caps`` are the host CP2AA block placement; every edge
    lands at ``starts[row] + (edge_idx - offsets[row])`` and every block
    slot records its owning row.
    """
    o = np.asarray(offsets, np.int64)
    deg = np.diff(o)
    n = deg.shape[0]
    total = int(caps[:n].sum())
    m = int(o[-1])
    a_dst = np.full(cap_e, SENTINEL, np.int32)
    a_wgt = np.zeros(cap_e, np.float32)
    a_rows = np.full(cap_e, cap_v, np.int32)
    if m:
        gidx = np.repeat(starts[:n].clip(0), deg) + (
            np.arange(m) - np.repeat(o[:-1], deg)
        )
        a_dst[gidx] = np.asarray(dst)[:m]
        a_wgt[gidx] = np.asarray(wgt)[:m]
    if total:
        a_rows[:total] = np.repeat(
            np.arange(n, dtype=np.int32), caps[:n].astype(np.int64)
        )
    return a_dst, a_wgt, a_rows


@functools.lru_cache(maxsize=None)
def _jit_arena_image(cap_e: int, cap_v: int, n: int, m: int):
    """Fused device arena fill: expand rows, scatter edges, paint owners."""

    def fn(offsets, dst, wgt, starts, caps, total):
        row = util.expand_rows(offsets, m)              # row id per edge
        ok = row < n
        slot = jnp.where(
            ok, starts[jnp.clip(row, 0, n - 1)] + (
                jnp.arange(m, dtype=jnp.int32) - offsets[jnp.clip(row, 0, n - 1)]
            ), cap_e,
        )
        a_dst = jnp.full((cap_e,), SENTINEL, jnp.int32).at[slot].set(
            dst[:m], mode="drop", unique_indices=True
        )
        a_wgt = jnp.zeros((cap_e,), jnp.float32).at[slot].set(
            wgt[:m], mode="drop", unique_indices=True
        )
        # owner per block slot: searchsorted into the block-start cumsum
        bend = jnp.cumsum(caps, dtype=jnp.int32)        # block end per row
        pos = jnp.arange(cap_e, dtype=jnp.int32)
        owner = jnp.searchsorted(bend, pos, side="right").astype(jnp.int32)
        a_rows = jnp.where(pos < total, jnp.minimum(owner, cap_v), cap_v)
        return a_dst, a_wgt, a_rows

    return jax.jit(fn)


def arena_image_device(offsets, dst, wgt, starts, caps, cap_e: int, cap_v: int,
                       *, total: int):
    n = int(np.asarray(offsets).shape[0]) - 1
    m = int(np.asarray(dst).shape[0])
    return _jit_arena_image(int(cap_e), int(cap_v), n, m)(
        jnp.asarray(offsets, jnp.int32),
        jnp.asarray(dst, jnp.int32),
        jnp.asarray(wgt, jnp.float32),
        jnp.asarray(starts, jnp.int32),
        jnp.asarray(caps, jnp.int32),
        jnp.int32(total),
    )


def arena_image(offsets, dst, wgt, starts, caps, cap_e: int, cap_v: int,
                *, total: int, engine: str = "auto"):
    """Backend-dispatched arena build; returns three jnp arrays.

    Off-TPU the numpy fill + one transfer beats XLA CPU scatters (~100ns
    per scattered slot); on TPU the fused program keeps everything
    device-resident.
    """
    if engine == "auto":
        engine = default_engine()
    if engine == "host":
        a_dst, a_wgt, a_rows = arena_image_host(
            np.asarray(offsets), np.asarray(dst), np.asarray(wgt),
            np.asarray(starts), np.asarray(caps), cap_e, cap_v,
        )
        return jnp.asarray(a_dst), jnp.asarray(a_wgt), jnp.asarray(a_rows)
    return arena_image_device(
        offsets, dst, wgt, starts, caps, cap_e, cap_v, total=total
    )


# ---------------------------------------------------------------------------
# CSR -> flat padded COO image (SortedCOO / LazyCSR base arrays)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jit_flat_image(cap: int, m: int):
    def fn(offsets, dst, wgt):
        rows = util.expand_rows(offsets, m)
        pad = cap - m
        r = jnp.concatenate([rows, jnp.full((pad,), SENTINEL, jnp.int32)])
        d = jnp.concatenate([dst, jnp.full((pad,), SENTINEL, jnp.int32)])
        w = jnp.concatenate([wgt, jnp.zeros((pad,), jnp.float32)])
        return r, d, w

    return jax.jit(fn)


def flat_image(offsets, dst, wgt, cap: int):
    """(row_ids, dst, wgt) padded to ``cap`` in ONE fused dispatch.

    The row-major flat layout SortedCOO and LazyCSR share; replaces the
    seed's per-buffer expand + three concatenate dispatches.
    """
    m = int(np.asarray(dst).shape[0])
    return _jit_flat_image(int(cap), m)(
        jnp.asarray(offsets, jnp.int32),
        jnp.asarray(dst, jnp.int32),
        jnp.asarray(wgt, jnp.float32),
    )


# ---------------------------------------------------------------------------
# CSR -> ChunkedGraph page image (same fill, PAGE-quantized blocks)
# ---------------------------------------------------------------------------
def pages_image_host(offsets, dst, wgt, page_base, npages, page: int,
                     p_cap: int, n_sentinel: int):
    """Page-pool image: edges land at page_base[row]*page + intra-row idx."""
    o = np.asarray(offsets, np.int64)
    deg = np.diff(o)
    n = deg.shape[0]
    m = int(o[-1])
    pages_d = np.full(p_cap * page, SENTINEL, np.int32)
    pages_w = np.zeros(p_cap * page, np.float32)
    owner = np.full(p_cap, n_sentinel, np.int32)
    if m:
        gidx = np.repeat(page_base[:n] * page, deg) + (
            np.arange(m) - np.repeat(o[:-1], deg)
        )
        pages_d[gidx] = np.asarray(dst)[:m]
        pages_w[gidx] = np.asarray(wgt)[:m]
    total_pages = int(npages[:n].sum())
    if total_pages:
        owner[:total_pages] = np.repeat(
            np.arange(n, dtype=np.int32), npages[:n].astype(np.int64)
        )
    return (
        pages_d.reshape(p_cap, page),
        pages_w.reshape(p_cap, page),
        owner,
    )
