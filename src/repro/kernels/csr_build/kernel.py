"""Pallas TPU kernel: partitioned per-vertex degree counting (paper Alg 5).

The paper's loader counts degrees in parallel partitions and merges the
partial histograms; on TPU the partition becomes an *edge tile* and the
merge becomes grid accumulation.  Grid = (vertex tiles × edge tiles):
each step compares one 128-wide src tile against one 128-wide vertex-id
tile and folds the match count into the output block, so the histogram is
built from O(M·N/128²) VPU compares with no scatters (TPU scatters
serialize; dense compare+reduce tiles don't).

Ids are compared as int32 — exact for any int32 vertex id, so unlike the
slot_update merge kernel this path has no 2**24 id ceiling.

Inputs (ops.py pads to whole tiles):
  src [T, EB] int32 edge sources; pad slots carry ``n_pad`` (out of range)
Output:
  degrees [NV] int32, NV a multiple of the 128-lane vertex tile
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: edge-tile / vertex-tile width (one VPU lane row)
EB = 128


def _kernel(src_ref, deg_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        deg_ref[...] = jnp.zeros_like(deg_ref)

    i = pl.program_id(0)
    src = src_ref[0]                          # [EB] edge tile
    # this block's vertex ids: i*EB + lane
    vg = i * EB + jax.lax.broadcasted_iota(jnp.int32, (1, EB), 1)
    hits = (src[:, None] == vg).astype(jnp.int32)   # [EB, EB]
    deg_ref[...] += jnp.sum(hits, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("nv", "interpret"))
def count_degrees_pallas(src_tiles: jnp.ndarray, *, nv: int,
                         interpret: bool = False) -> jnp.ndarray:
    """Degree histogram of src_tiles [T, EB] over ``nv`` vertices.

    ``nv`` must be a multiple of EB (ops.py rounds); pad edges must carry
    an id >= nv so they fall outside every vertex tile.
    """
    t, eb = src_tiles.shape
    assert eb == EB, f"edge tiles must be {EB} wide, got {eb}"
    nv = int(nv)
    assert nv % EB == 0, f"vertex range must be a multiple of {EB}"
    deg = pl.pallas_call(
        _kernel,
        grid=(nv // EB, t),
        in_specs=[pl.BlockSpec((1, EB), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((1, EB), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nv // EB, EB), jnp.int32),
        interpret=interpret,
    )(src_tiles)
    return deg.reshape(nv)
