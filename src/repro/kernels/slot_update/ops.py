"""jit'd wrappers: one fused dispatch applies a whole mixed UpdatePlan.

``fused_apply`` lowers EVERY pow-2 width group of a plan into one
program (DESIGN.md §9/§12) — the per-group ``slot_update`` /
``merge_group`` / ``rebuild_arena`` micro-dispatch pipeline is retired:

  gather   touched rows' live prefixes into [A, W] tiles per group
           (W = the group's pow-2 width class, >= every member's
           capacity; EB=128 floor on TPU so all small classes share one
           compiled shape),
  merge    the sorted batch runs [A, K] into the sorted rows — deletes,
           weight upserts and ranked inserts in one pass (two backends:
           the Pallas one-hot-rank kernel in kernel.py, or the XLA
           bisect + rank-arithmetic formulation in ``_merge_rows_xla``),
  write    all merged groups back in one pass — either per-group
           scatters (grown rows land directly in their NEW block while
           their old block is SENTINEL-filled, so CP2AA block moves ride
           the same dispatch) or a host-mapped gather rebuild of the
           quantized bump prefix (``choose_scatter`` picks),
  walk     optionally, the k-step interval walk scan fused right behind
           the write-back (``WalkImage.walk_flush``): one dispatch per
           steady-state stream round.

Buffer donation keeps the arena update in place; every operand shape is
pow-2 bucketed so steady-state streams never recompile.  The Pallas
backend places int32 ids via f32 matmuls and therefore requires vertex
ids < 2**24; ``auto`` only selects it on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import util
from .. import fallback as _fb
from . import kernel as _kernel
from . import ref as _refmod

SENTINEL = util.SENTINEL
#: Off-TPU write-back dispatch: arenas up to this many slots always use
#: the full-buffer gather rebuild (its dense passes beat CPU XLA scatter
#: overhead there); beyond it, batches touching < 1/10 of the arena
#: switch to per-group scatters so small updates stay O(batch).
REBUILD_MAX_CAP = 1 << 21
#: TPU row-group width floor: merges run in whole 128-slot MXU tiles.  The
#: XLA fallback instead groups rows by their exact pow-2 capacity class
#: (floor XLA_FLOOR) — CPU sort/scatter cost is linear in slots touched,
#: so padding every small class to 128 lanes would inflate it ~10x.
EB = 128
XLA_FLOOR = 8
#: The Pallas kernel places int32 vertex ids through f32 matmuls, which
#: are exact only below the f32 mantissa bound.  Callers must route
#: graphs with ids >= this to the XLA formulation (DiGraph does, by
#: cap_v) — above it the kernel silently rounds ids to the nearest
#: representable float.
PALLAS_MAX_ID = 1 << 24

#: Module-level dispatch counter: each ``fused_apply`` call is one device
#: program.  The sharded layer reads deltas to prove every shard's flush
#: stays at round_dispatches=1 per device (DESIGN.md §14).
STATS = {"dispatches": 0}


def stats_snapshot() -> dict:
    return dict(STATS)


def width_floor(backend: str = "auto") -> int:
    """Row-group width floor for a (resolved) backend."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    return EB if backend == "pallas" else XLA_FLOOR


# ---------------------------------------------------------------------------
# merge core, XLA formulation (shape-identical to the Pallas kernel)
# ---------------------------------------------------------------------------
#: Runs wider than this take the sort-based merge; narrower runs (the
#: steady-state stream regime, K floored at 4) use the window-compaction
#: merge — no lax.sort, which costs ~4x the rest of the merge on CPU.
MERGE_WINDOW_MAX_K = 32


def _merge_rows_xla(d_rows, w_rows, degs, b_dst, b_wgt, b_del,
                    max_holes: int | None = None):
    """Scatter-free (and for narrow runs sort- and eq-tensor-free) merge.

    Rows arrive sorted (live ascending prefix, SENTINEL pad = int32
    max), and so do each row's batch ops, so op membership is a batched
    BRANCHLESS BISECT — log2(W) statically-unrolled take_along_axis
    steps over the [A, K] query set — instead of an [A, K, W] equality
    tensor, and all op effects land as [A, K]-sized scatters (~a few
    thousand indices) on the row planes:

      * deletes mark their hit lane in a ``killed`` plane,
      * upserts overwrite their hit lane's weight in place,
      * new inserts scatter value/weight/flag planes at their merged
        position (``#surviving-entries-below-key + insert-rank``).

    Final positioning is rank arithmetic (DESIGN.md §12): a delete
    punches at most ``max_holes`` holes into the sorted row (callers
    pass the group's pow-2 delete-run ceiling; the steady-state stream
    regime is 1-2), so a (holes+1)-wide select window compacts the row
    and one take_along_axis gather interleaves the inserts.  ``lax.sort``
    — which costs ~4x the rest of the merge on CPU — remains only for
    wide runs (K > MERGE_WINDOW_MAX_K, bulk hub loads), where the
    classic eq-tensor + [A, W+K] sort formulation wins.
    """
    a, w = d_rows.shape
    k = b_dst.shape[1]
    bdel = b_del != 0
    live = jnp.arange(w, dtype=jnp.int32)[None, :] < degs[:, None]

    if k > MERGE_WINDOW_MAX_K:
        # eq-tensor head + full sort (the wide-run path)
        eq = (b_dst[:, :, None] == d_rows[:, None, :]) & live[:, None, :]
        eqf = eq.astype(jnp.float32)
        not_del = (~bdel).astype(jnp.float32)
        lhs = jnp.stack(
            [bdel.astype(jnp.float32), b_wgt * not_del, not_del], axis=1
        )  # [A, 3, K]
        red = jax.lax.batch_matmul(lhs, eqf)  # [A, 3, W]
        found = (
            jax.lax.batch_matmul(
                eqf, jnp.ones((a, w, 1), jnp.float32)
            )[:, :, 0]
            > 0.0
        ) & (b_dst != SENTINEL)
        new_ins = (~found) & (~bdel) & (b_dst != SENTINEL)
        killed = red[:, 0, :] > 0.0
        d_keep = jnp.where(live & ~killed, d_rows, SENTINEL)
        w_keep = jnp.where(red[:, 2, :] > 0.0, red[:, 1, :], w_rows)
        keys = jnp.concatenate(
            [d_keep, jnp.where(new_ins, b_dst, SENTINEL)], axis=1
        )
        vals = jnp.concatenate([w_keep, b_wgt], axis=1)
        keys, vals = jax.lax.sort(
            (keys, vals), dimension=1, num_keys=1, is_stable=False
        )
        d_out = keys[:, :w]
        w_out = jnp.where(d_out != SENTINEL, vals[:, :w], 0.0)
        counts = jnp.sum(d_out != SENTINEL, axis=1).astype(jnp.int32)
        return d_out, w_out, counts

    holes = k if max_holes is None else min(int(max_holes), k)
    # --- batched branchless bisect: pos = #row entries with key < q ---
    pos = jnp.zeros((a, k), jnp.int32)
    h = w // 2
    while h >= 1:
        cand = pos + h
        at = jnp.take_along_axis(d_rows, cand - 1, axis=1)
        pos = jnp.where(at < b_dst, cand, pos)
        h //= 2
    at = jnp.take_along_axis(d_rows, jnp.minimum(pos, w - 1), axis=1)
    ilive = b_dst != SENTINEL
    found = (at == b_dst) & ilive & (pos < w)
    rowi = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[:, None], (a, k))

    # deletes: mark hit lanes (tiny scatter; misses dump past the plane)
    kill_idx = jnp.where(found & bdel, rowi * w + pos, a * w)
    killed = (
        jnp.zeros((a * w + 1,), bool)
        .at[kill_idx.reshape(-1)]
        .set(True)[: a * w]
        .reshape(a, w)
    )
    # upserts: weight lands in place
    up_idx = jnp.where(found & ~bdel, rowi * w + pos, a * w)
    w_keep = (
        jnp.concatenate([w_rows.reshape(-1), jnp.zeros((1,), jnp.float32)])
        .at[up_idx.reshape(-1)]
        .set(b_wgt.reshape(-1))[: a * w]
        .reshape(a, w)
    )

    keep = live & ~killed
    kept_cum = jnp.cumsum(keep.astype(jnp.int32), axis=1)
    n_kept = kept_cum[:, -1]
    kex = kept_cum - keep.astype(jnp.int32)  # kept strictly before lane i
    d_keep = jnp.where(keep, d_rows, SENTINEL)

    # new-insert placement: surviving entries below the key + run rank
    kill_cum = jnp.cumsum(killed.astype(jnp.int32), axis=1)
    kill_excl = jnp.concatenate(
        [kill_cum - killed.astype(jnp.int32), kill_cum[:, -1:]], axis=1
    )
    new_ins = ilive & ~found & ~bdel
    lt_kept = pos - jnp.take_along_axis(kill_excl, pos, axis=1)
    ins_rank = jnp.cumsum(new_ins.astype(jnp.int32), axis=1) - new_ins
    pos_ins = lt_kept + ins_rank
    ins_idx = jnp.where(
        new_ins, rowi * (w + 1) + jnp.minimum(pos_ins, w), a * (w + 1)
    ).reshape(-1)
    is_ins = (
        jnp.zeros((a * (w + 1) + 1,), bool)
        .at[ins_idx].set(True)[: a * (w + 1)].reshape(a, w + 1)[:, :w]
    )
    ins_d = (
        jnp.zeros((a * (w + 1) + 1,), jnp.int32)
        .at[ins_idx].set(b_dst.reshape(-1))[: a * (w + 1)]
        .reshape(a, w + 1)[:, :w]
    )
    ins_w = (
        jnp.zeros((a * (w + 1) + 1,), jnp.float32)
        .at[ins_idx].set(b_wgt.reshape(-1))[: a * (w + 1)]
        .reshape(a, w + 1)[:, :w]
    )
    ins_lt = jnp.cumsum(is_ins.astype(jnp.int32), axis=1) - is_ins

    # hole compaction: kept lane i lands at kex[i], a left shift bounded
    # by the group delete-run ceiling — (holes+1)-wide select window
    j_row = jnp.arange(w, dtype=jnp.int32)[None, :]
    if holes:
        pad_d = jnp.concatenate(
            [d_keep, jnp.full((a, holes), SENTINEL, jnp.int32)], 1
        )
        pad_w = jnp.concatenate(
            [w_keep, jnp.zeros((a, holes), jnp.float32)], 1
        )
        pad_keep = jnp.concatenate([keep, jnp.zeros((a, holes), bool)], 1)
        pad_kex = jnp.concatenate(
            [kex, jnp.full((a, holes), w + k, jnp.int32)], 1
        )
    else:
        pad_d, pad_w, pad_keep, pad_kex = d_keep, w_keep, keep, kex
    comp_d = jnp.full((a, w), SENTINEL, jnp.int32)
    comp_w = jnp.zeros((a, w), jnp.float32)
    for o in range(holes + 1):
        sel = pad_keep[:, o:o + w] & (pad_kex[:, o:o + w] == j_row)
        comp_d = jnp.where(sel, pad_d[:, o:o + w], comp_d)
        comp_w = jnp.where(sel, pad_w[:, o:o + w], comp_w)

    r = jnp.clip(j_row - ins_lt, 0, w - 1)
    g_d = jnp.take_along_axis(comp_d, r, axis=1)
    g_w = jnp.take_along_axis(comp_w, r, axis=1)
    counts = (n_kept + jnp.sum(new_ins.astype(jnp.int32), axis=1)).astype(
        jnp.int32
    )
    valid = j_row < counts[:, None]
    d_out = jnp.where(valid, jnp.where(is_ins, ins_d, g_d), SENTINEL)
    w_out = jnp.where(valid, jnp.where(is_ins, ins_w, g_w), 0.0)
    return d_out, w_out, counts


def merge_rows(
    d_rows, w_rows, degs, b_dst, b_wgt, b_del, *, backend="xla",
    interpret=False, max_holes=None,
):
    """Backend-dispatched row merge (parity-test entry point).

    ``max_holes`` (static) bounds the delete-hole compaction window of
    the XLA formulation; None means the full run width.
    """
    if backend == "pallas":
        return _kernel.merge_rows_pallas(
            d_rows, w_rows, degs, b_dst, b_wgt, b_del, interpret=interpret
        )
    if backend == "xla":
        return _merge_rows_xla(
            d_rows, w_rows, degs, b_dst, b_wgt, b_del, max_holes=max_holes
        )
    raise ValueError(f"unknown slot_update backend: {backend!r}")


# ---------------------------------------------------------------------------
# fused multi-group apply (+ optional fused walk epilogue) — DESIGN.md §12
# ---------------------------------------------------------------------------
def choose_scatter(cap_e: int, touched: int) -> bool:
    """Write-back dispatch: scatter per group (TPU / huge-arena small
    batch) vs one full-buffer gather rebuild (the off-TPU default)."""
    on_tpu = jax.default_backend() == "tpu"
    return on_tpu or (cap_e > REBUILD_MAX_CAP and touched * 10 < cap_e)


def quantized_prefix(cap_e: int, bump: int) -> int:
    """Bump prefix bound on the cap_e/8 lattice (the walk's edges_hi
    policy): coarse enough that streaming bump growth rarely changes the
    static rebuild shape, tight enough to skip the SENTINEL tail."""
    q = max(cap_e // 8, 128)
    return min(-(-max(int(bump), 1) // q) * q, cap_e)


def host_patch_layout(layout, rows, old_starts, old_caps, new_starts,
                      new_caps, grow, map_hi: int, cap_v: int,
                      has_moves: bool):
    """Host-built rebuild operands for the gather write-back.

    ``layout`` is [(width, gsel, a_pad), ...] in group-iteration order —
    merged group g's rows occupy consecutive [a_pad, width] regions of
    the concatenated patch stream.  ``slot_map[map_hi]`` (``map_hi`` =
    the quantized bump prefix; every touched slot sits below it) holds
    -1 for untouched slots, a patch index for slots of a touched row's
    (possibly new) block, and the trailing SENTINEL slot for vacated old
    blocks.  Shared by the DiGraph arena update and the walk-image patch
    engine (both feed it to ``fused_apply(scatter=False)``).
    """
    patch_base = np.zeros(rows.shape[0], np.int64)
    base = 0
    for wv, gsel, a_pad in layout:
        patch_base[gsel] = base + np.arange(gsel.shape[0], dtype=np.int64) * int(wv)
        base += int(a_pad) * int(wv)
    slot_map = np.full(map_hi, -1, np.int32)
    if has_moves:  # vacated blocks clear via the trailing patch slot
        mv = np.nonzero(grow & (old_starts >= 0) & (old_caps > 0))[0]
        oc = old_caps[mv]
        intra = np.arange(int(oc.sum()), dtype=np.int64) - np.repeat(
            np.cumsum(oc) - oc, oc
        )
        slot_map[np.repeat(old_starts[mv], oc) + intra] = base
    intra = np.arange(int(new_caps.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(new_caps) - new_caps, new_caps
    )
    arena_idx = np.repeat(new_starts, new_caps) + intra
    slot_map[arena_idx] = np.repeat(patch_base, new_caps) + intra
    if has_moves:
        owner_patch = np.full(base + 1, cap_v, np.int32)
        owner_patch[np.repeat(patch_base, new_caps) + intra] = np.repeat(
            rows, new_caps
        )
    else:
        owner_patch = np.zeros(1, np.int32)
    return slot_map, owner_patch


@functools.lru_cache(maxsize=None)
def _jit_fused(groups: tuple, scatter: bool, rebuild_hi: int, any_moves: bool,
               donate: bool, backend: str, interpret: bool, blocks: bool,
               walk: tuple):
    """ONE program for a whole UpdatePlan — and, optionally, the walk.

    ``groups`` is ``((width, a_pad, k, d_k, moves), ...)``: every pow-2
    width class of the plan merges inside the same dispatch (the groups
    touch disjoint rows, so their gathers all read the pre-update buffer
    and their writes never collide).  Compared to one dispatch per group
    this pays a single XLA launch + a single host counts sync per
    *batch* instead of per class.  ``d_k`` bounds each group's
    delete-hole compaction window (see ``_merge_rows_xla``).

    ``blocks`` updates the [lo, hi) interval geometry in-program from
    the merge counts and returns it (the shared-image arena keeps its
    walk operands warm across updates without a host rebuild).  ``walk``
    is ``()`` or ``(steps, nv, edges_hi, nwalks, normalize, engine)``:
    the patched buffers additionally feed the scatter-free interval step
    scan directly, so a steady-state stream round (flush + k-step walk)
    is ONE dispatch with zero intermediate materialization (§12).
    """
    n_g = len(groups)

    def fn(dst, wgt, slot_rows, slot_map, owner_patch, lo, hi, visits0, *ops):
        cap_e = dst.shape[0]
        dst0, wgt0 = dst, wgt
        counts_all = []
        d_patches, w_patches = [], []
        for gi in range(n_g):
            width, a_pad, k, d_k, moves = groups[gi]
            # each group ships 3 packed operands, not 9 loose ones — the
            # per-array jit argument transfer overhead dominates the
            # bytes at these sizes
            row_ops, bdl, bw = ops[gi * 3:(gi + 1) * 3]
            (old_starts, old_caps, new_starts, new_caps, degs,
             row_ids) = (row_ops[i] for i in range(6))
            bd, bl = bdl[0], bdl[1]
            d_rows = util.rows_to_padded(dst0, old_starts, degs, width, SENTINEL)
            w_rows = util.rows_to_padded(wgt0, old_starts, degs, width, 0.0)
            d_rows, w_rows, counts = merge_rows(
                d_rows, w_rows, degs, bd, bw, bl,
                backend=backend, interpret=interpret, max_holes=d_k,
            )
            counts_all.append(counts)
            if blocks or walk:
                # padded rows carry row_ids >= nv and drop out
                lo = lo.at[row_ids].set(new_starts, mode="drop")
                hi = hi.at[row_ids].set(new_starts + counts, mode="drop")
            if scatter:
                lane = jnp.arange(width, dtype=jnp.int32)[None, :]
                if moves:
                    moved = (new_starts != old_starts) & (old_starts >= 0)
                    old_idx = jnp.where(
                        moved[:, None] & (lane < old_caps[:, None]),
                        old_starts[:, None] + lane,
                        cap_e,
                    )
                    dst = dst.at[old_idx.reshape(-1)].set(
                        SENTINEL, mode="drop", unique_indices=True
                    )
                ok = new_starts >= 0
                new_idx = jnp.where(
                    ok[:, None] & (lane < new_caps[:, None]),
                    new_starts[:, None] + lane,
                    cap_e,
                ).reshape(-1)
                dst = dst.at[new_idx].set(
                    d_rows.reshape(-1), mode="drop", unique_indices=True
                )
                wgt = wgt.at[new_idx].set(
                    w_rows.reshape(-1), mode="drop", unique_indices=True
                )
                if moves:
                    slot_rows = slot_rows.at[new_idx].set(
                        jnp.broadcast_to(
                            row_ids[:, None], (a_pad, width)
                        ).reshape(-1),
                        mode="drop",
                        unique_indices=True,
                    )
            else:
                d_patches.append(d_rows)
                w_patches.append(w_rows)
        if not scatter and n_g:
            pd = jnp.concatenate(
                [p.reshape(-1) for p in d_patches]
                + [jnp.full((1,), SENTINEL, jnp.int32)]
            )
            pw = jnp.concatenate(
                [p.reshape(-1) for p in w_patches]
                + [jnp.zeros((1,), jnp.float32)]
            )
            safe = jnp.clip(slot_map, 0, pd.shape[0] - 1)
            touched = slot_map >= 0
            if 0 < rebuild_hi < cap_e:
                # every touched slot sits below the bump pointer: run the
                # gather-select over the (quantized) bump prefix only and
                # splice it back — the SENTINEL tail is never re-read.
                # ``slot_map`` arrives [rebuild_hi]-sized from the host.
                pre_d = jnp.where(
                    touched, pd[safe],
                    jax.lax.dynamic_slice(dst, (0,), (rebuild_hi,)),
                )
                pre_w = jnp.where(
                    touched, pw[safe],
                    jax.lax.dynamic_slice(wgt, (0,), (rebuild_hi,)),
                )
                dst = jax.lax.dynamic_update_slice(dst, pre_d, (0,))
                wgt = jax.lax.dynamic_update_slice(wgt, pre_w, (0,))
                if any_moves:
                    pre_r = jnp.where(
                        touched, owner_patch[safe],
                        jax.lax.dynamic_slice(slot_rows, (0,), (rebuild_hi,)),
                    )
                    slot_rows = jax.lax.dynamic_update_slice(
                        slot_rows, pre_r, (0,)
                    )
            else:
                dst = jnp.where(touched, pd[safe], dst)
                wgt = jnp.where(touched, pw[safe], wgt)
                if any_moves:
                    slot_rows = jnp.where(touched, owner_patch[safe], slot_rows)

        outs = [dst, wgt]
        if any_moves:
            outs.append(slot_rows)
        outs.append(
            jnp.concatenate(counts_all)
            if len(counts_all) > 1
            else counts_all[0]
        )
        if walk:
            from ..slot_walk import ops as _sw  # lazy: avoid import cycle

            steps, nv, edges_hi, nwalks, normalize, engine = walk
            gidx_p = _sw._prep_gidx(dst, nv, edges_hi)
            step = _sw.make_blocked_step(
                gidx_p, lo, hi, nv, engine=engine, interpret=interpret
            )
            v = (
                jnp.asarray(visits0, jnp.float32)
                if nwalks
                else jnp.ones((1, nv), jnp.float32)
            )

            def body(vis, _):
                nxt = step(vis)
                if normalize:
                    nxt = nxt / jnp.maximum(
                        jnp.max(nxt, axis=1, keepdims=True), 1.0
                    )
                return nxt, None

            v, _ = jax.lax.scan(body, v, None, length=steps)
            outs.append(v if nwalks else v[0])
        if blocks or walk:
            outs.extend([lo, hi])
        return tuple(outs)

    if not donate:
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(0, 1, 2) if any_moves else (0, 1))


def _fused_apply_ref(dst, wgt, slot_rows, groups, *, any_moves: bool,
                     blocks: bool, wkey: tuple, lo, hi, visits0):
    """Host-numpy fused apply — the fallback chain's floor (DESIGN.md §13).

    Replays the whole plan through ``merge_rows_reference`` with direct
    array writes (the scatter/rebuild distinction collapses on host),
    mirroring the device program's full output contract: patched buffers,
    concatenated counts, refreshed [lo, hi) geometry and — when a walk
    epilogue is fused — the host walk over the patched intervals.  Slow
    by design; its job is stream survival when both device merge
    backends are tripped.
    """
    d = np.array(dst)
    w = np.array(wgt)
    r = np.array(slot_rows) if any_moves else slot_rows
    lo_h = np.array(lo) if (blocks or wkey) else None
    hi_h = np.array(hi) if (blocks or wkey) else None
    counts_all = []
    for width, a_pad, _k, _dk, moves, ops3 in groups:
        row_ops, bdl, bw = ops3
        old_starts, old_caps, new_starts, new_caps, degs, row_ids = (
            np.asarray(row_ops[i], np.int64) for i in range(6)
        )
        d_rows = np.full((a_pad, width), SENTINEL, np.int32)
        w_rows = np.zeros((a_pad, width), np.float32)
        for i in range(a_pad):
            dg = int(degs[i])
            if dg and old_starts[i] >= 0:
                s = int(old_starts[i])
                d_rows[i, :dg] = d[s:s + dg]
                w_rows[i, :dg] = w[s:s + dg]
        out_d, out_w, counts = _refmod.merge_rows_reference(
            d_rows, w_rows, degs, bdl[0], bw, bdl[1]
        )
        counts_all.append(counts.astype(np.int32))
        for i in range(a_pad):
            ns, nc = int(new_starts[i]), int(new_caps[i])
            if ns < 0 or nc <= 0:
                continue  # pad row
            if moves and old_starts[i] >= 0 and old_starts[i] != ns:
                os_, oc = int(old_starts[i]), int(old_caps[i])
                d[os_:os_ + oc] = SENTINEL  # vacated block goes dead
                w[os_:os_ + oc] = 0.0
            d[ns:ns + nc] = out_d[i, :nc]
            w[ns:ns + nc] = out_w[i, :nc]
            if any_moves:
                r[ns:ns + nc] = row_ids[i]
            if lo_h is not None and row_ids[i] < lo_h.shape[0]:
                lo_h[row_ids[i]] = ns
                hi_h[row_ids[i]] = ns + int(counts[i])
    outs = [jnp.asarray(d), jnp.asarray(w)]
    if any_moves:
        outs.append(jnp.asarray(r))
    outs.append(np.concatenate(counts_all) if counts_all else np.zeros(0, np.int32))
    if wkey:
        from ..slot_walk import ref as _sw_ref  # lazy: avoid import cycle

        steps, nv, edges_hi, nwalks, normalize, _engine = wkey
        v0 = (
            np.asarray(visits0, np.float32)
            if nwalks
            else np.ones((1, nv), np.float32)
        )
        v = _sw_ref.slot_walk_host(
            d, None, steps, nv, edges_hi=edges_hi,
            block_lo=lo_h[:nv], block_hi=hi_h[:nv],
            normalize=normalize, visits0=v0,
        )
        outs.append(v if nwalks else v[0])
    if blocks or wkey:
        outs.extend([jnp.asarray(lo_h), jnp.asarray(hi_h)])
    return tuple(outs)


def fused_apply(
    dst, wgt, slot_rows, groups,
    *, scatter: bool, backend: str = "auto", interpret: bool = False,
    donate: bool = True, slot_map=None, owner_patch=None, rebuild_hi: int = 0,
    walk=None, lo=None, hi=None, visits0=None,
):
    """Apply EVERY width group of a plan in one dispatch (DESIGN.md §12).

    ``groups`` is ``[(width, a_pad, k, d_k, moves, operands), ...]``
    with ``operands`` the packed 3-tuple ``(row_ops [6, A] int32 =
    old_starts/old_caps/new_starts/new_caps/degs/row_ids, b_dstdel
    [2, A, K] int32, b_wgt [A, K] f32)`` (numpy fine — jit's argument
    path transfers them; packing matters because per-array transfer
    overhead dominates at these sizes) and ``d_k`` the group's (pow-2)
    delete-run ceiling, bounding the merge's hole-compaction window.
    ``scatter=False`` takes the host-mapped gather rebuild
    (``host_patch_layout`` supplies ``slot_map``/``owner_patch``);
    ``rebuild_hi`` (static, quantized to the caller's bump lattice)
    bounds that pass to the allocated prefix so the SENTINEL tail is
    never re-read.  ``walk=(steps, nv, edges_hi, nwalks, normalize,
    engine)`` fuses the k-step interval walk into the same program, fed
    by the in-program-updated [lo, hi) geometry; passing ``lo``/``hi``
    WITHOUT ``walk`` still updates and returns them (interval-cache
    refresh for the shared arena image).

    Returns ``(dst, wgt, slot_rows, counts_list, extra)`` where
    ``extra`` is ``None``, ``(lo2, hi2)`` (blocks-only), or
    ``(visits, lo2, hi2)`` (fused walk).
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown slot_update backend: {backend!r}")
    gkey = tuple(
        (int(w), int(a), int(k), int(dk), bool(mv))
        for w, a, k, dk, mv, _ in groups
    )
    any_moves = any(g[4] for g in gkey)
    blocks = walk is None and lo is not None and hi is not None
    wkey = () if walk is None else tuple(walk)
    ops_flat = [o for *_hdr, ops9 in groups for o in ops9]
    dummy = np.zeros(1, np.int32)

    # dispatch runs through the health-gated fallback chain (DESIGN.md
    # §13).  Injected faults and compile/lowering failures fire BEFORE
    # execution, so operands are intact for the next link; only the
    # first attempt may donate — a retry must still own its inputs.  (A
    # real device failure AFTER a donated buffer was consumed is not
    # retryable: jax reports the deleted buffer and the chain exhausts.)
    state = {"first": True}

    def _dispatch(b: str):
        first, state["first"] = state["first"], False
        if b == "ref":
            return _fused_apply_ref(
                dst, wgt, slot_rows, groups, any_moves=any_moves,
                blocks=blocks, wkey=wkey, lo=lo, hi=hi, visits0=visits0,
            )
        # a walk engine tied to the failing backend degrades with it; an
        # explicitly mixed request (e.g. xla merge + pallas walk parity
        # runs) keeps its engine
        wk = wkey[:5] + (b,) if (wkey and wkey[5] == backend) else wkey
        fn = _jit_fused(
            gkey, bool(scatter), int(rebuild_hi), any_moves,
            donate and first, b, interpret, blocks, wk,
        )
        return fn(
            dst, wgt, slot_rows,
            dummy if slot_map is None else slot_map,
            dummy if owner_patch is None else owner_patch,
            dummy if lo is None else lo,
            dummy if hi is None else hi,
            np.zeros((1, 1), np.float32) if visits0 is None else visits0,
            *ops_flat,
        )

    out, _used = _fb.run_chain("slot_update", backend, _dispatch)
    STATS["dispatches"] += 1
    i = 2
    if any_moves:
        new_rows = out[i]
        i += 1
    else:
        new_rows = slot_rows
    # one concatenated counts sync, split back per group on host
    counts_cat = np.asarray(out[i])
    i += 1
    counts, at = [], 0
    for _w, a_pad, *_r in gkey:
        counts.append(counts_cat[at:at + a_pad])
        at += a_pad
    if walk is not None:
        extra = tuple(out[i:i + 3])
    elif blocks:
        extra = tuple(out[i:i + 2])
    else:
        extra = None
    return out[0], out[1], new_rows, counts, extra
