"""jit'd wrappers: one fused dispatch applies a mixed update plan group.

``slot_update`` replaces the retired ``_jit_insert_chain`` /
``_jit_delete_chain`` / per-class ``_sort_dirty_rows`` / ``_jit_move_blocks``
micro-dispatch pipeline in ``core/digraph.py`` with a single program per
width group:

  gather   touched rows' live prefixes into [A, W] tiles (W = the group's
           pow-2 width class, >= every member's capacity; EB=128 floor so
           all small classes share one compiled shape),
  merge    the sorted batch runs [A, K] into the sorted rows — deletes,
           weight upserts and ranked inserts in one pass (two backends:
           the Pallas one-hot-rank kernel in kernel.py, or a plain XLA
           searchsorted + argsort formulation),
  scatter  merged rows back — grown rows land directly in their NEW block
           while their old block is SENTINEL-filled, so CP2AA block moves
           ride the same dispatch instead of paying their own.

Buffer donation keeps the arena update in place; every operand shape is
pow-2 bucketed so steady-state streams never recompile.  The Pallas
backend places int32 ids via f32 matmuls and therefore requires vertex
ids < 2**24; ``auto`` only selects it on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import util
from . import kernel as _kernel

SENTINEL = util.SENTINEL
#: TPU row-group width floor: merges run in whole 128-slot MXU tiles.  The
#: XLA fallback instead groups rows by their exact pow-2 capacity class
#: (floor XLA_FLOOR) — CPU sort/scatter cost is linear in slots touched,
#: so padding every small class to 128 lanes would inflate it ~10x.
EB = 128
XLA_FLOOR = 8
#: The Pallas kernel places int32 vertex ids through f32 matmuls, which
#: are exact only below the f32 mantissa bound.  Callers must route
#: graphs with ids >= this to the XLA formulation (DiGraph does, by
#: cap_v) — above it the kernel silently rounds ids to the nearest
#: representable float.
PALLAS_MAX_ID = 1 << 24


def width_floor(backend: str = "auto") -> int:
    """Row-group width floor for a (resolved) backend."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    return EB if backend == "pallas" else XLA_FLOOR


# ---------------------------------------------------------------------------
# merge core, XLA formulation (shape-identical to the Pallas kernel)
# ---------------------------------------------------------------------------
def _merge_rows_xla(d_rows, w_rows, degs, b_dst, b_wgt, b_del):
    """Scatter-free row merge: two windowed binary searches + one sort.

    CPU XLA scatters cost ~100ns per index, so nothing here scatters:
    op→slot membership flags the *new* inserts, slot→op membership flags
    deletions and gathers upserted weights, and the new inserts ride a
    concatenated [A, W+K] unstable key-value sort back into position
    (keys are unique per row — one op per key — so stability is not
    needed; SENTINEL ties only ever carry weights that get zeroed).
    """
    w = d_rows.shape[1]
    bdel = b_del != 0

    # one [A, K, W] equality matrix answers membership both ways — a
    # fused compare+reduce beats binary search here, whose lax.scan
    # steps cost ~0.5ms of fixed overhead per dispatch on CPU.  K is the
    # group's run width (small), so the matrix stays a few hundred KB.
    live = jnp.arange(w, dtype=jnp.int32)[None, :] < degs[:, None]
    eq = (b_dst[:, :, None] == d_rows[:, None, :]) & live[:, None, :]
    found = jnp.any(eq, axis=2) & (b_dst != SENTINEL)
    new_ins = (~found) & (~bdel) & (b_dst != SENTINEL)
    killed = jnp.any(eq & bdel[:, :, None], axis=1)
    upsel = eq & (~bdel)[:, :, None]
    w_up = jnp.sum(jnp.where(upsel, b_wgt[:, :, None], 0.0), axis=1)
    d_keep = jnp.where(live & ~killed, d_rows, SENTINEL)
    w_keep = jnp.where(jnp.any(upsel, axis=1), w_up, w_rows)

    keys = jnp.concatenate(
        [d_keep, jnp.where(new_ins, b_dst, SENTINEL)], axis=1
    )
    vals = jnp.concatenate([w_keep, b_wgt], axis=1)
    keys, vals = jax.lax.sort(
        (keys, vals), dimension=1, num_keys=1, is_stable=False
    )
    d_out = keys[:, :w]
    w_out = jnp.where(d_out != SENTINEL, vals[:, :w], 0.0)
    counts = jnp.sum(d_out != SENTINEL, axis=1).astype(jnp.int32)
    return d_out, w_out, counts


def merge_rows(
    d_rows, w_rows, degs, b_dst, b_wgt, b_del, *, backend="xla", interpret=False
):
    """Backend-dispatched row merge (parity-test entry point)."""
    if backend == "pallas":
        return _kernel.merge_rows_pallas(
            d_rows, w_rows, degs, b_dst, b_wgt, b_del, interpret=interpret
        )
    if backend == "xla":
        return _merge_rows_xla(d_rows, w_rows, degs, b_dst, b_wgt, b_del)
    raise ValueError(f"unknown slot_update backend: {backend!r}")


# ---------------------------------------------------------------------------
# rebuild write-back: gather-only full-buffer pass (the off-TPU fast path)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jit_merge_group(width: int, backend: str, interpret: bool):
    """Read-only gather + merge for one width group (no write-back)."""

    def fn(dst, wgt, old_starts, degs, b_dst, b_wgt, b_del):
        d_rows = util.rows_to_padded(dst, old_starts, degs, width, SENTINEL)
        w_rows = util.rows_to_padded(wgt, old_starts, degs, width, 0.0)
        return merge_rows(
            d_rows, w_rows, degs, b_dst, b_wgt, b_del,
            backend=backend, interpret=interpret,
        )

    return jax.jit(fn)


def merge_group(
    dst, wgt, old_starts, degs, b_dst, b_wgt, b_del,
    *, width: int, backend: str = "auto", interpret: bool = False,
):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    return _jit_merge_group(int(width), backend, interpret)(
        dst, wgt, old_starts, degs, b_dst, b_wgt, b_del
    )


@functools.lru_cache(maxsize=None)
def _jit_rebuild(n_patches: int, has_moves: bool, donate: bool):
    """One gather pass rewrites every touched arena slot.

    ``slot_map[CAP]`` (host-built) holds -1 for untouched slots, a patch
    index for slots of a touched row's (possibly new) block, and ``P``
    (one past the concatenated patches) for vacated old blocks, which a
    trailing SENTINEL/0 patch slot then clears.  XLA scatters on CPU cost
    ~100ns per slot written; this formulation replaces them with three
    dense gather+select passes over the buffer (~10ns/slot), which wins
    whenever a batch touches more than ~a few percent of the arena —
    scatter mode (``_jit_apply``) remains the TPU path.
    """

    def fn(dst, wgt, slot_rows, slot_map, owner_patch, *patches):
        pd = jnp.concatenate(
            [p.reshape(-1) for p in patches[:n_patches]]
            + [jnp.full((1,), SENTINEL, jnp.int32)]
        )
        pw = jnp.concatenate(
            [p.reshape(-1) for p in patches[n_patches:]]
            + [jnp.zeros((1,), jnp.float32)]
        )
        safe = jnp.clip(slot_map, 0, pd.shape[0] - 1)
        touched = slot_map >= 0
        dst = jnp.where(touched, pd[safe], dst)
        wgt = jnp.where(touched, pw[safe], wgt)
        if has_moves:
            slot_rows = jnp.where(touched, owner_patch[safe], slot_rows)
            return dst, wgt, slot_rows
        # owner map untouched: neither donated nor returned (per-buffer COW)
        return dst, wgt

    if not donate:
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(0, 1, 2) if has_moves else (0, 1))


def rebuild_arena(
    dst, wgt, slot_rows, slot_map, owner_patch, d_patches, w_patches,
    *, has_moves: bool, donate: bool = True,
):
    """Write all merged groups back in one gather pass (see _jit_rebuild)."""
    out = _jit_rebuild(len(d_patches), bool(has_moves), donate)(
        dst, wgt, slot_rows, slot_map, owner_patch, *d_patches, *w_patches
    )
    if has_moves:
        return out
    return out[0], out[1], slot_rows


# ---------------------------------------------------------------------------
# fused apply: gather + merge + scatter (+ block move) in one program
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jit_apply(width: int, backend: str, interpret: bool, donate: bool,
               has_moves: bool):
    """Without moves, ``slot_rows`` is read-only: it is neither donated
    nor returned, so a snapshot-shared owner map stays shared (per-buffer
    COW — the graph handle keeps its existing array object)."""

    def fn(
        dst, wgt, slot_rows,
        old_starts, old_caps, new_starts, new_caps, degs, row_ids,
        b_dst, b_wgt, b_del,
    ):
        a = old_starts.shape[0]
        cap_e = dst.shape[0]
        lane = jnp.arange(width, dtype=jnp.int32)[None, :]

        d_rows = util.rows_to_padded(dst, old_starts, degs, width, SENTINEL)
        w_rows = util.rows_to_padded(wgt, old_starts, degs, width, 0.0)
        d_rows, w_rows, counts = merge_rows(
            d_rows, w_rows, degs, b_dst, b_wgt, b_del,
            backend=backend, interpret=interpret,
        )

        if has_moves:
            # grown rows: SENTINEL-fill the vacated block (freed blocks
            # must read empty; slot_rows may go stale there — consumers
            # mask on dst != SENTINEL)
            moved = (new_starts != old_starts) & (old_starts >= 0)
            old_idx = jnp.where(
                moved[:, None] & (lane < old_caps[:, None]),
                old_starts[:, None] + lane,
                cap_e,
            )
            dst = dst.at[old_idx.reshape(-1)].set(
                SENTINEL, mode="drop", unique_indices=True
            )

        # write each merged row over its (possibly new) full block
        ok = new_starts >= 0
        new_idx = jnp.where(
            ok[:, None] & (lane < new_caps[:, None]),
            new_starts[:, None] + lane,
            cap_e,
        ).reshape(-1)
        dst = dst.at[new_idx].set(
            d_rows.reshape(-1), mode="drop", unique_indices=True
        )
        wgt = wgt.at[new_idx].set(
            w_rows.reshape(-1), mode="drop", unique_indices=True
        )
        if has_moves:
            # only moved rows need fresh slot owners
            slot_rows = slot_rows.at[new_idx].set(
                jnp.broadcast_to(row_ids[:, None], (a, width)).reshape(-1),
                mode="drop",
                unique_indices=True,
            )
        if has_moves:
            return dst, wgt, slot_rows, counts
        return dst, wgt, counts

    if not donate:
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(0, 1, 2) if has_moves else (0, 1))


def slot_update(
    dst: jnp.ndarray,
    wgt: jnp.ndarray,
    slot_rows: jnp.ndarray,
    old_starts: jnp.ndarray,
    old_caps: jnp.ndarray,
    new_starts: jnp.ndarray,
    new_caps: jnp.ndarray,
    degs: jnp.ndarray,
    row_ids: jnp.ndarray,
    b_dst: jnp.ndarray,
    b_wgt: jnp.ndarray,
    b_del: jnp.ndarray,
    width: int,
    backend: str = "auto",
    interpret: bool = False,
    donate: bool = True,
    has_moves: bool = True,
):
    """Apply one width group of a mixed UpdatePlan to the slotted arena.

    ``width`` is the group's static pow-2 row class (>= every member's
    ``new_caps``; callers floor it at ``width_floor(backend)``).  All row
    operands are [A] (A pow-2; pad rows carry ``old_starts == new_starts
    == -1`` and drop out), run operands are [A, K]; numpy operands are
    fine — jit's argument path transfers them cheaper than explicit
    ``device_put`` calls.  ``has_moves=False`` elides the block-move
    writes (old-block SENTINEL fill + slot-owner refresh) for groups
    where no row changed class — then ``slot_rows`` is read-only and
    passes through untouched (never donated, never copied: the caller's
    array object survives, which is what makes per-buffer COW free for
    non-moving updates).  Returns ``(dst, wgt, slot_rows, counts)`` with
    ``counts`` the merged live length per row.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    out = _jit_apply(int(width), backend, interpret, donate, bool(has_moves))(
        dst, wgt, slot_rows,
        old_starts, old_caps, new_starts, new_caps, degs, row_ids,
        b_dst, b_wgt, b_del,
    )
    if has_moves:
        return out
    d, w, counts = out
    return d, w, slot_rows, counts
