"""Pallas TPU kernel: fused sorted-merge of batch runs into arena rows.

One grid step merges one touched row's gathered block prefix ``[1, W]``
with its batch run ``[1, K]`` (both ascending, SENTINEL-padded; at most
one op per key, guaranteed by UpdatePlan).  The merge is scatter-free —
TPUs have no scatter unit, so every output element is *ranked* instead of
moved:

  membership   [K, W] equality matrix between run values and row values
               (VPU compares; K and W are pow-2, lanes stay dense),
  ranks        survivors keep ``cumsum`` order plus the count of new
               inserts below them; new inserts symmetrically — two
               comparison-matrix reductions give both counts,
  placement    ``[slot, rank]`` one-hot matrices fold values into their
               final positions with two MXU matmuls (``vals @ onehot``),
               exactly the slot_walk one-hot-rank trick run in reverse.

f32 matmuls place int32 vertex ids, so ids must stay below 2**24 (f32
mantissa); ``ops.py`` only routes to this kernel on TPU (or for
interpret-mode parity tests) and documents that bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import util

SENTINEL = util.SENTINEL


def _kernel(deg_ref, d_ref, w_ref, bd_ref, bw_ref, bdel_ref,
            od_ref, ow_ref, cnt_ref):
    d = d_ref[...]        # [1, W] int32 row values (live prefix ascending)
    w = w_ref[...]        # [1, W] f32 row weights
    bd = bd_ref[...]      # [1, K] int32 run values (ascending, SENTINEL pad)
    bw = bw_ref[...]      # [1, K] f32 run weights
    bdel = bdel_ref[...] != 0  # [1, K] delete-op mask
    deg = deg_ref[0, 0]
    kk = bd.shape[1]
    ww = d.shape[1]

    iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, ww), 1)
    live = iota_w < deg
    bvalid = bd != SENTINEL
    bd_c = bd.reshape(kk, 1)          # run values as a column
    bdel_c = bdel.reshape(kk, 1)

    # membership: eq[k, s] — run op k hits live row slot s
    eq = (bd_c == d) & live           # [K, W]
    found = jnp.any(eq, axis=1).reshape(1, kk) & bvalid
    new_ins = (~found) & (~bdel) & bvalid
    # deletions kill their row slot; upserts replace its weight
    killed = jnp.any(eq & bdel_c, axis=0).reshape(1, ww)
    upd = eq & (~bdel_c)
    w_up = jnp.sum(jnp.where(upd, bw.reshape(kk, 1), 0.0), axis=0).reshape(1, ww)
    has_up = jnp.any(upd, axis=0).reshape(1, ww)
    w2 = jnp.where(has_up, w_up, w)
    surv = live & ~killed

    # ranks: survivors shift up by the new inserts below them, and vice
    # versa — both counts fall out of the same comparison matrix.
    surv_i = surv.astype(jnp.int32)
    surv_rank = jnp.cumsum(surv_i, axis=1) - surv_i
    below = bd_c < d                  # [K, W]
    ins_before = jnp.sum(
        (below & new_ins.reshape(kk, 1)).astype(jnp.int32), axis=0
    ).reshape(1, ww)
    pos_surv = surv_rank + ins_before
    ins_i = new_ins.astype(jnp.int32)
    ins_rank = jnp.cumsum(ins_i, axis=1) - ins_i
    surv_before = jnp.sum(
        ((~below) & (bd_c != d) & surv).astype(jnp.int32), axis=1
    ).reshape(1, kk)
    pos_ins = ins_rank + surv_before

    # placement: one-hot [slot, rank] matmuls (MXU) fold both sources
    pw = jax.lax.broadcasted_iota(jnp.int32, (ww, ww), 1)
    oh_s = ((pos_surv.reshape(ww, 1) == pw) & surv.reshape(ww, 1)).astype(
        jnp.float32
    )
    pk = jax.lax.broadcasted_iota(jnp.int32, (kk, ww), 1)
    oh_i = ((pos_ins.reshape(kk, 1) == pk) & new_ins.reshape(kk, 1)).astype(
        jnp.float32
    )
    out_d = jnp.dot(
        jnp.where(surv, d, 0).astype(jnp.float32), oh_s,
        preferred_element_type=jnp.float32,
    ) + jnp.dot(
        jnp.where(new_ins, bd, 0).astype(jnp.float32), oh_i,
        preferred_element_type=jnp.float32,
    )
    out_w = jnp.dot(
        jnp.where(surv, w2, 0.0), oh_s, preferred_element_type=jnp.float32
    ) + jnp.dot(
        jnp.where(new_ins, bw, 0.0), oh_i, preferred_element_type=jnp.float32
    )
    count = jnp.sum(surv_i) + jnp.sum(ins_i)
    od_ref[...] = jnp.where(iota_w < count, out_d.astype(jnp.int32), SENTINEL)
    ow_ref[...] = jnp.where(iota_w < count, out_w, 0.0)
    cnt_ref[0, 0] = count


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_rows_pallas(
    d_rows: jnp.ndarray,
    w_rows: jnp.ndarray,
    degs: jnp.ndarray,
    b_dst: jnp.ndarray,
    b_wgt: jnp.ndarray,
    b_del: jnp.ndarray,
    *,
    interpret: bool = False,
):
    """Row-tile merge: [A, W] rows × [A, K] runs -> (out_d, out_w, counts).

    ``surv_before``'s comparison uses ``~(bd < d) & (bd != d)`` rather
    than ``d < bd`` so SENTINEL row padding never counts (it equals the
    run padding value).
    """
    a, w = d_rows.shape
    k = b_dst.shape[1]
    deg2 = degs.reshape(a, 1).astype(jnp.int32)
    row_spec = pl.BlockSpec((1, w), lambda i: (i, 0))
    run_spec = pl.BlockSpec((1, k), lambda i: (i, 0))
    one_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    out_d, out_w, counts = pl.pallas_call(
        _kernel,
        grid=(a,),
        in_specs=[one_spec, row_spec, row_spec, run_spec, run_spec, run_spec],
        out_specs=[row_spec, row_spec, one_spec],
        out_shape=[
            jax.ShapeDtypeStruct((a, w), jnp.int32),
            jax.ShapeDtypeStruct((a, w), jnp.float32),
            jax.ShapeDtypeStruct((a, 1), jnp.int32),
        ],
        interpret=interpret,
    )(deg2, d_rows, w_rows, b_dst, b_wgt, b_del)
    return out_d, out_w, counts.reshape(a)
