"""Pure-numpy oracle for the slot_update row merge.

Dict-per-row semantics, deliberately naive: start from the row's live
prefix, apply every op of its batch run (delete pops, insert/upsert
assigns — one op per key, guaranteed by UpdatePlan), and emit the result
ascending with SENTINEL padding.  Both device backends (the Pallas kernel
and the XLA fallback in ``ops.py``) are tested against this.
"""
from __future__ import annotations

import numpy as np

from ...core import util

SENTINEL = util.SENTINEL


def merge_rows_reference(d_rows, w_rows, degs, b_dst, b_wgt, b_del):
    """Merge batch runs into gathered rows; returns (out_d, out_w, counts).

    d_rows/w_rows: [A, W] gathered rows (live prefix + SENTINEL/0 tail)
    degs:          [A]    live lengths
    b_dst/b_wgt:   [A, K] batch run values (ascending, SENTINEL pad)
    b_del:         [A, K] 1 = delete op
    """
    d_rows = np.asarray(d_rows)
    w_rows = np.asarray(w_rows)
    degs = np.asarray(degs)
    b_dst = np.asarray(b_dst)
    b_wgt = np.asarray(b_wgt)
    b_del = np.asarray(b_del)
    a, w = d_rows.shape
    out_d = np.full((a, w), SENTINEL, np.int32)
    out_w = np.zeros((a, w), np.float32)
    counts = np.zeros(a, np.int32)
    for i in range(a):
        deg = int(degs[i])
        cur = dict(zip(d_rows[i, :deg].tolist(), w_rows[i, :deg].tolist()))
        for v, wt, dl in zip(b_dst[i].tolist(), b_wgt[i].tolist(), b_del[i].tolist()):
            if v == int(SENTINEL):
                continue
            if dl:
                cur.pop(v, None)
            else:
                cur[v] = wt
        keys = sorted(cur)
        counts[i] = len(keys)
        out_d[i, : len(keys)] = keys
        out_w[i, : len(keys)] = [cur[k] for k in keys]
    return out_d, out_w, counts
