"""jit'd wrapper: pad/tile sorted edges, run the kernel, fold seam partials."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import alloc
from . import kernel as _kernel
from . import ref as _ref

EB = 128  # edges per tile (MXU-native)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "interpret", "d_tile")
)
def edge_segment_sum(
    rows: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    num_segments: int,
    d_tile: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Segment-sum of row-sorted edge values on the MXU.

    rows [E] ascending (pad entries must be >= num_segments), vals [E, D].
    """
    e, d = vals.shape
    dt = d_tile or min(128, alloc.next_pow2(d))
    d_pad = -(-d // dt) * dt
    t = -(-e // EB)
    e_pad = t * EB
    sink = num_segments
    rows_p = jnp.full((e_pad,), sink, jnp.int32).at[:e].set(
        jnp.minimum(rows, sink).astype(jnp.int32)
    )
    vals_p = jnp.zeros((e_pad, d_pad), jnp.float32).at[:e, :d].set(
        vals.astype(jnp.float32)
    )
    part, rank = _kernel.edge_segment_partials(
        rows_p.reshape(t, EB),
        vals_p.reshape(t, EB, d_pad),
        d_tile=dt,
        sink=sink,
        interpret=interpret,
    )
    # fold per-tile partials: at most EB live ranks per tile; seam rows
    # (shared across tile boundaries) merge here.
    flat_rows = rank.reshape(-1)
    flat_vals = part.reshape(-1, d_pad)
    out = jax.ops.segment_sum(
        flat_vals, jnp.minimum(flat_rows, sink), num_segments=sink + 1
    )
    return out[:num_segments, :d]


def edge_segment_sum_reference(rows, vals, *, num_segments: int):
    return _ref.segment_sum_reference(rows, vals, num_segments)
