"""Pure-jnp oracle: plain segment_sum over edges."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_reference(
    rows: jnp.ndarray, vals: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    """rows [E] int32 (>= num_segments means dropped), vals [E, D]."""
    safe = jnp.minimum(rows, num_segments)
    out = jax.ops.segment_sum(vals, safe, num_segments=num_segments + 1)
    return out[:num_segments]
