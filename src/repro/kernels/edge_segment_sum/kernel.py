"""Pallas TPU kernel: segment-sum over sorted edges via one-hot matmuls.

The GNN message-passing primitive (scatter-add of edge messages into
destination rows) has no native TPU scatter unit.  For row-sorted edges the
standard MXU formulation: per 128-edge tile, build the one-hot matrix of
*local segment ranks* (cumsum of row-change flags) and reduce the tile with
one 128×128 matmul — O(E/128) MXU ops instead of E scalar scatters.  A tiny
cross-tile segment_sum outside the kernel folds the per-tile partials
(tiles overlap in at most their seam rows).

Inputs (host pads edges to tiles):
  rows [T, EB]     int32, ascending within+across tiles; pad rows = big
  vals [T, EB, D]  f32, pad lanes zero
Outputs:
  partials  [T, EB, D]  per-tile per-rank sums
  rank_rows [T, EB]     global row id per rank (or ``sink`` for dead ranks)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rows_ref, vals_ref, part_ref, rank_ref, *, sink: int):
    rows = rows_ref[0]                      # [EB]
    vals = vals_ref[0]                      # [EB, DT]
    eb = rows.shape[0]
    prev = jnp.concatenate([jnp.full((1,), -1, rows.dtype), rows[:-1]])
    seg_start = rows != prev
    rank = jnp.cumsum(seg_start.astype(jnp.int32)) - 1  # [EB] in [0, EB)
    oh = (
        jax.lax.broadcasted_iota(jnp.int32, (eb, eb), 1) == rank[:, None]
    ).astype(jnp.float32)                    # [edge, rank]
    part_ref[0] = jnp.dot(oh.T, vals, preferred_element_type=jnp.float32)
    live = rows < sink
    rr = jnp.max(
        jnp.where(oh.astype(bool) & live[:, None], rows[:, None], -1), axis=0
    )
    rank_ref[0] = jnp.where(rr >= 0, rr, sink)


@functools.partial(jax.jit, static_argnames=("d_tile", "sink", "interpret"))
def edge_segment_partials(
    rows: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    d_tile: int = 128,
    sink: int,
    interpret: bool = False,
):
    t, eb = rows.shape
    d = vals.shape[-1]
    assert d % d_tile == 0

    grid = (t, d // d_tile)
    kern = functools.partial(_kernel, sink=sink)
    part, rank = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, eb), lambda i, j: (i, 0)),
            pl.BlockSpec((1, eb, d_tile), lambda i, j: (i, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, eb, d_tile), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, eb), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, eb, d), jnp.float32),
            jax.ShapeDtypeStruct((t, eb), jnp.int32),
        ],
        interpret=interpret,
    )(rows, vals)
    return part, rank
