"""jit'd wrapper: CSR -> BSR conversion + SpMM / reverse-walk entry points."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...core import alloc, csr as csr_mod
from . import kernel as _kernel
from . import ref as _ref


@dataclasses.dataclass(frozen=True)
class BSR:
    row_ptr: jnp.ndarray     # [R+1]
    block_cols: jnp.ndarray  # [NNZB_pad]
    blocks: jnp.ndarray      # [NNZB_pad, B, B]
    n_rows: int              # padded row count (R*B)
    n_cols: int              # padded col count
    max_blocks_per_row: int
    block_size: int


def csr_to_bsr(c: csr_mod.CSR, *, block_size: int = 128, weighted: bool = False) -> BSR:
    """Re-block a CSR adjacency into dense B×B tiles (host).

    Pads rows/cols to a block multiple; block count per row-block is
    pow-2 bucketed (CP2AA policy) so the kernel grid shape stays stable
    across graphs of similar density.
    """
    b = block_size
    n_pad = -(-c.n // b) * b
    o = np.asarray(c.offsets)
    dst = np.asarray(c.dst)
    wgt = (
        np.asarray(c.wgt)
        if (weighted and c.wgt is not None)
        else np.ones(c.m, np.float32)
    )
    rows = np.repeat(np.arange(c.n, dtype=np.int64), np.diff(o))
    br = rows // b
    bc = dst.astype(np.int64) // b
    key = br * (n_pad // b) + bc
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, first = np.unique(key_s, return_index=True)
    nnzb = uniq.shape[0]
    blk_of_edge = np.searchsorted(uniq, key)
    # dense tiles
    blocks = np.zeros((max(nnzb, 1), b, b), np.float32)
    blocks[blk_of_edge, rows % b, dst % b] = wgt
    u_br = (uniq // (n_pad // b)).astype(np.int64)
    u_bc = (uniq % (n_pad // b)).astype(np.int32)
    r_total = n_pad // b
    counts = np.bincount(u_br, minlength=r_total)
    row_ptr = np.zeros(r_total + 1, np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    maxb = alloc.next_pow2(max(int(counts.max(initial=1)), 1))
    return BSR(
        row_ptr=jnp.asarray(row_ptr, jnp.int32),
        block_cols=jnp.asarray(u_bc, jnp.int32),
        blocks=jnp.asarray(blocks),
        n_rows=n_pad,
        n_cols=n_pad,
        max_blocks_per_row=int(maxb),
        block_size=b,
    )


def spmm(bsr: BSR, x: jnp.ndarray, *, interpret: bool = False, d_tile=None) -> jnp.ndarray:
    """Y = A @ X via the Pallas kernel; pads X/D to block multiples."""
    d = x.shape[-1]
    dt = d_tile or min(128, alloc.next_pow2(d))
    d_pad = -(-d // dt) * dt
    n_pad = bsr.n_cols
    x_p = jnp.zeros((n_pad, d_pad), jnp.float32)
    x_p = x_p.at[: x.shape[0], :d].set(x.astype(jnp.float32))
    y = _kernel.bsr_spmm(
        bsr.row_ptr,
        bsr.block_cols,
        bsr.blocks,
        x_p,
        max_blocks_per_row=bsr.max_blocks_per_row,
        d_tile=dt,
        interpret=interpret,
    )
    return y[: x.shape[0], :d]


def spmm_reference(bsr: BSR, x: jnp.ndarray) -> jnp.ndarray:
    n_pad = bsr.n_cols
    x_p = jnp.zeros((n_pad, x.shape[-1]), jnp.float32)
    x_p = x_p.at[: x.shape[0]].set(x.astype(jnp.float32))
    y = _ref.spmm_reference(bsr.row_ptr, bsr.block_cols, bsr.blocks, x_p)
    return y[: x.shape[0]]


def reverse_walk_bsr(
    bsr: BSR, steps: int, n: int, *, interpret: bool = False
) -> jnp.ndarray:
    """Paper Alg 13 on the MXU: visits = A^k 1̄ as iterated BSR SpMM.

    The visits vector rides in a [N, 8] lane-padded panel (column 0 live)
    so every step is MXU matmuls instead of gather/scatter.
    """
    v = jnp.zeros((n, 8), jnp.float32).at[:, 0].set(1.0)
    for _ in range(steps):
        v = spmm(bsr, v, d_tile=8, interpret=interpret)
    return v[:, 0]
