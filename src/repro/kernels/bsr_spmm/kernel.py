"""Pallas TPU kernel: BSR (block-sparse row) SpMM — Y = A @ X.

TPU adaptation of the paper's reverse-walk/SpMV hot loop (DESIGN.md §2):
the MXU has no scatter unit, so the adjacency is re-blocked into dense
B×B tiles (B=128 matches the MXU systolic array) and the segment
reduction becomes a sequence of dense tile matmuls.

Layout:
  row_ptr    int32 [R+1]        — blocks of row-block r live at
                                   [row_ptr[r], row_ptr[r+1])
  block_cols int32 [NNZB_pad]   — block-column index per stored block
  blocks     f32   [NNZB_pad, B, B] — dense tiles
  x          f32   [C*B, D]     — dense operand

Grid (R, D/DT, MAXB): the s axis (innermost) walks a row's blocks and
accumulates into the same output tile; `row_ptr`/`block_cols` ride in as
scalar-prefetch operands so BlockSpec index_maps can chase the indirection
(the block-table indirection of the paper's per-vertex blocks, tile-ified).
VMEM per step: B·B (tile) + B·DT (x) + B·DT (out) floats — 128·128·4 +
2·128·DT·4 ≈ 64 KiB + 1 KiB·DT, comfortably inside the ~16 MiB VMEM budget
for DT ≤ 512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(row_ptr_ref, block_cols_ref, blocks_ref, x_ref, o_ref):
    r = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    count = row_ptr_ref[r + 1] - row_ptr_ref[r]

    @pl.when(s < count)
    def _acc():
        a = blocks_ref[0]          # [B, B]
        x = x_ref[...]             # [B, DT]
        o_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("max_blocks_per_row", "d_tile", "interpret")
)
def bsr_spmm(
    row_ptr: jnp.ndarray,
    block_cols: jnp.ndarray,
    blocks: jnp.ndarray,
    x: jnp.ndarray,
    *,
    max_blocks_per_row: int,
    d_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    n_row_blocks = row_ptr.shape[0] - 1
    b = blocks.shape[-1]
    d = x.shape[-1]
    assert d % d_tile == 0, (d, d_tile)

    def blocks_idx(r, dt, s, row_ptr_ref, block_cols_ref):
        i = row_ptr_ref[r] + s
        return (jnp.minimum(i, blocks.shape[0] - 1), 0, 0)

    def x_idx(r, dt, s, row_ptr_ref, block_cols_ref):
        i = jnp.minimum(row_ptr_ref[r] + s, block_cols.shape[0] - 1)
        return (block_cols_ref[i], dt)

    def o_idx(r, dt, s, row_ptr_ref, block_cols_ref):
        return (r, dt)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_row_blocks, d // d_tile, max_blocks_per_row),
        in_specs=[
            pl.BlockSpec((1, b, b), blocks_idx),
            pl.BlockSpec((b, d_tile), x_idx),
        ],
        out_specs=pl.BlockSpec((b, d_tile), o_idx),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_row_blocks * b, d), jnp.float32),
        interpret=interpret,
    )(row_ptr, block_cols, blocks, x)
