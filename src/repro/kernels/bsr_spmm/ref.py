"""Pure-jnp oracle for block-sparse SpMM (Y = A @ X)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bsr_to_dense(row_ptr, block_cols, blocks, n_rows: int, n_cols: int):
    """Reassemble the dense matrix from BSR parts (host/numpy, tests)."""
    b = blocks.shape[-1]
    out = np.zeros((n_rows, n_cols), np.float32)
    rp = np.asarray(row_ptr)
    bc = np.asarray(block_cols)
    bl = np.asarray(blocks)
    for r in range(rp.shape[0] - 1):
        for s in range(rp[r], rp[r + 1]):
            c = bc[s]
            out[r * b : (r + 1) * b, c * b : (c + 1) * b] = bl[s]
    return out


def spmm_reference(row_ptr, block_cols, blocks, x):
    """Dense-equivalent SpMM oracle: per-row-block accumulation in jnp."""
    b = blocks.shape[-1]
    n_row_blocks = row_ptr.shape[0] - 1
    x_blk = x.reshape(-1, b, x.shape[-1])

    rows = []
    rp = np.asarray(row_ptr)
    bc = np.asarray(block_cols)
    for r in range(n_row_blocks):
        acc = jnp.zeros((b, x.shape[-1]), jnp.float32)
        for s in range(int(rp[r]), int(rp[r + 1])):
            acc = acc + blocks[s].astype(jnp.float32) @ x_blk[bc[s]].astype(jnp.float32)
        rows.append(acc)
    return jnp.concatenate(rows, axis=0)
