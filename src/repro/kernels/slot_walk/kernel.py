"""Pallas TPU kernel: fused reverse-walk tile reduction over the slotted arena.

The k-step reverse walk (paper Alg 13) is, per step, a segment-sum of
gathered ``visits`` values into the owning row of every live edge slot.  On
the slotted DiGraph buffer each vertex's block is *contiguous*, so within a
128-slot tile the row ids form contiguous runs (a run per block, dead-slot
tails mapped to ``sink``).  That lets each tile be reduced with one MXU
matmul: cumsum the run-change flags into local *ranks*, build the
[slot, rank] one-hot matrix, and fold ``vals @ onehot`` into per-rank
partial sums — O(CAP_E/128) matmuls instead of CAP_E scalar scatters.  A
tiny cross-tile segment-sum outside the kernel merges tile-seam runs
(ops.py), and the step loop is a ``lax.scan`` *around* the kernel so
``visits`` never leaves the device between steps.

Inputs (ops.py pads the live prefix to whole tiles):
  rows [T, EB]  int32 slot owners; dead/pad slots carry ``sink``
  vals [T, EB]  f32 gathered visits, zero on dead/pad slots
Outputs:
  partials  [T, EB]  per-tile per-rank sums
  rank_rows [T, EB]  global row id per rank (``sink`` for dead ranks)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cumsum_kernel(vals_ref, out_ref):
    v = vals_ref[...]                       # [1, EB]
    eb = v.shape[-1]
    # inclusive prefix within the tile as ONE MXU matmul against the
    # upper-triangular ones matrix: out[j] = Σ_{k<=j} v[k]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (eb, eb), 0)
        <= jax.lax.broadcasted_iota(jnp.int32, (eb, eb), 1)
    ).astype(jnp.float32)
    out_ref[...] = jnp.dot(v, tri, preferred_element_type=jnp.float32)


def tile_cumsum(vals: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Per-tile inclusive cumsum: vals [T, EB] -> [T, EB] (MXU matmul).

    The intra-tile level of the hierarchical walk prefix (DESIGN.md §12):
    each 128-slot tile's running sum is one [1,128]@[128,128] triangular
    matmul, so the scatter-free interval walk needs no per-slot owner
    operand on the Pallas backend either — the inter-tile base scan and
    the [lo, hi) differencing stay in the XLA glue (ops.py).  Plain
    function (not jitted) so callers can inline it into fused programs.
    """
    t, eb = vals.shape
    return pl.pallas_call(
        _cumsum_kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, eb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, eb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, eb), jnp.float32),
        interpret=interpret,
    )(vals)


def _kernel(rows_ref, vals_ref, part_ref, rank_ref, *, sink: int):
    rows = rows_ref[0]                      # [EB]
    vals = vals_ref[...]                    # [1, EB]
    eb = rows.shape[0]
    prev = jnp.concatenate([jnp.full((1,), -1, rows.dtype), rows[:-1]])
    run_start = rows != prev                # block boundaries within the tile
    rank = jnp.cumsum(run_start.astype(jnp.int32)) - 1  # [EB] in [0, EB)
    oh = (
        jax.lax.broadcasted_iota(jnp.int32, (eb, eb), 1) == rank[:, None]
    ).astype(jnp.float32)                   # [slot, rank]
    part_ref[...] = jnp.dot(vals, oh, preferred_element_type=jnp.float32)
    live = rows < sink
    rr = jnp.max(
        jnp.where(oh.astype(bool) & live[:, None], rows[:, None], -1), axis=0
    )
    rank_ref[0] = jnp.where(rr >= 0, rr, sink)


@functools.partial(jax.jit, static_argnames=("sink", "interpret"))
def slot_walk_partials(
    rows: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    sink: int,
    interpret: bool = False,
):
    """One walk step's tile reduction: rows/vals [T, EB] -> (partials, rank_rows)."""
    t, eb = rows.shape
    kern = functools.partial(_kernel, sink=sink)
    part, rank = pl.pallas_call(
        kern,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, eb), jnp.float32),
            jax.ShapeDtypeStruct((t, eb), jnp.int32),
        ],
        interpret=interpret,
    )(rows, vals)
    return part, rank
