"""jit'd wrapper: prefix-tile the slotted buffer, scan the step loop.

Two jitted backends behind one dispatcher:

  * ``pallas``  — the MXU tile kernels (kernel.py); ``interpret=True`` runs
    the same programs on CPU for parity tests.
  * ``xla``     — identical prefix/tile semantics via plain jnp ops
    (the fast path off-TPU, and the shape the Pallas kernels must match).

When the caller supplies per-vertex [lo, hi) block intervals (every
``WalkImage`` does), BOTH backends use the scatter-free hierarchical
prefix formulation (``make_blocked_step``): the per-slot ``slot_rows``
operand is folded into the interval geometry and each step moves only
the gather plane plus O(V) interval reads — roughly half the bytes of
the segment-sum formulation.  The legacy rows-carrying paths remain for
interval-less callers (raw arenas, the seed baseline).

Both only process ``edges_hi`` slots (the arena's bump prefix, rounded up
to a power of two by the caller so the jit cache stays O(log CAP_E))
instead of the full CAP_E buffer — on updated graphs that alone is the
difference between walking the paper's live edges and walking every dead
SENTINEL lane the allocator ever reserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import util
from .. import fallback as _fb
from . import kernel as _kernel
from . import ref as _ref

SENTINEL = util.SENTINEL
EB = 128  # slots per tile (MXU-native)


def _prep(dst, slot_rows, num_vertices: int, edges_hi: int):
    """Slice the live prefix, mask dead slots, pad to whole tiles.

    Dead/pad slots get row ``sink`` and gather index ``num_vertices`` —
    the step loop extends ``visits`` with a zero sink entry, so no
    per-step masking is needed (masks are folded once, here, outside the
    scan).
    """
    e = min(int(edges_hi), dst.shape[0])
    t = max(-(-e // EB), 1)
    e_pad = t * EB
    sink = num_vertices
    d = dst[:e]
    sr = slot_rows[:e]
    valid = (d != SENTINEL) & (sr < num_vertices)
    rows = jnp.where(valid, sr, sink).astype(jnp.int32)
    gidx = jnp.where(valid, jnp.clip(d, 0, num_vertices - 1), num_vertices)
    rows_p = jnp.full((e_pad,), sink, jnp.int32).at[:e].set(rows).reshape(t, EB)
    gidx_p = (
        jnp.full((e_pad,), num_vertices, jnp.int32).at[:e].set(gidx).reshape(t, EB)
    )
    return rows_p, gidx_p


@functools.partial(
    jax.jit,
    static_argnames=("steps", "num_vertices", "edges_hi", "normalize", "interpret"),
)
def slot_walk_pallas(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    zero = jnp.zeros((1,), jnp.float32)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zero])[gidx_p]  # sink gathers 0.0
        part, rank = _kernel.slot_walk_partials(
            rows_p, vals, sink=sink, interpret=interpret
        )
        nxt = jax.ops.segment_sum(
            part.reshape(-1),
            jnp.minimum(rank.reshape(-1), sink),
            num_segments=sink + 1,
        )[:num_vertices]
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_xla(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    rows_f = rows_p.reshape(-1)
    gidx_f = gidx_p.reshape(-1)
    zero = jnp.zeros((1,), jnp.float32)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zero])[gidx_f]  # sink gathers 0.0
        nxt = jax.ops.segment_sum(vals, rows_f, num_segments=sink + 1)[
            :num_vertices
        ]
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


def _twosum(a, b):
    """Knuth TwoSum: s + e == a + b exactly (s = fl(a+b))."""
    s = a + b
    bp = s - a
    return s, (a - (s - bp)) + (b - bp)


def _comp_combine(l, r):
    s, e = _twosum(l[0], r[0])
    return s, l[1] + r[1] + e


def _comp_scan(x, axis=0):
    """Compensated inclusive scan: returns (hi, lo) with hi+lo ≈ exact."""
    return jax.lax.associative_scan(
        _comp_combine, (x, jnp.zeros_like(x)), axis=axis
    )


def _prep_gidx(dst, num_vertices: int, edges_hi: int):
    """Tile-padded gather indices, masked from ``dst`` ALONE.

    Dead slots carry ``dst == SENTINEL`` (arena/image invariant), so the
    interval walk needs no per-slot owner operand at all — ``slot_rows``
    is folded into the [lo, hi) block geometry and the step loop's only
    per-slot operand is this one int32 index plane (DESIGN.md §12).
    """
    e = min(int(edges_hi), dst.shape[0])
    t = max(-(-e // EB), 1)
    e_pad = t * EB
    d = dst[:e]
    gidx = jnp.where(
        d == SENTINEL, num_vertices, jnp.clip(d, 0, num_vertices - 1)
    ).astype(jnp.int32)
    return (
        jnp.full((e_pad,), num_vertices, jnp.int32)
        .at[:e]
        .set(gidx)
        .reshape(t, EB)
    )


def make_blocked_step(gidx_p, block_lo, block_hi, num_vertices: int, *,
                      engine: str = "xla", interpret: bool = False):
    """Build the scatter-free interval walk step (batched: [B, V] -> [B, V]).

    Each vertex's slots are one contiguous interval [block_lo, block_hi)
    (§2 invariant) and dead slots gather 0.0, so a step reduces to
    ``P[hi] - P[lo]`` over the running prefix sum of the gathered values
    — gather + prefix + a few [V] gathers, no scatter unit needed.
    Rows without a block pass lo == hi == 0.

    The prefix is *hierarchical* (DESIGN.md §12): an inclusive cumsum
    within each 128-slot tile plus a TwoSum-compensated scan over the T
    tile totals, with the difference assembled per part so the large
    bases are never rounded into the result.  ``engine`` picks the
    intra-tile level: ``xla`` (jnp.cumsum) or ``pallas`` (one triangular
    MXU matmul per tile, ``kernel.tile_cumsum``) — either way the step's
    per-slot operand set is just the gather plane, no slot_rows.

    A naive global f32 cumsum loses the row sum to cancellation once the
    total dwarfs it (err ~ ulp(total)).  The residual envelope here is
    the *intra-tile* partial, ~ulp(sum of one tile): on skewed social
    graphs a hub row sharing its tile with ~1e10-magnitude partials can
    see ~2e-4 relative error at high step counts (measured; fully
    compensating or f64-ing the intra level costs 2-10x the whole step —
    not worth it for a wall-time benchmark whose 42-step counts saturate
    f32 by design).
    """
    t = gidx_p.shape[0]
    e_pad = t * EB
    lo = jnp.clip(block_lo, 0, e_pad).astype(jnp.int32)
    hi = jnp.clip(block_hi, 0, e_pad).astype(jnp.int32)
    # split each prefix position into (tile, offset); position e_pad folds
    # onto the last tile's tail so the gather stays in range.
    q_lo = jnp.minimum(lo // EB, t - 1)
    q_hi = jnp.minimum(hi // EB, t - 1)
    r_lo = lo - q_lo * EB
    r_hi = hi - q_hi * EB
    # prefix position (q, r) reads the tile's INCLUSIVE cumsum at lane
    # r-1, or 0.0 at a tile start — no [t, EB+1] exclusive-prefix copy
    # is ever materialized in the loop
    z_lo = r_lo == 0
    z_hi = r_hi == 0
    i_lo = q_lo * EB + jnp.maximum(r_lo - 1, 0)
    i_hi = q_hi * EB + jnp.maximum(r_hi - 1, 0)

    def step(visits):  # [B, num_vertices] -> [B, num_vertices]
        b = visits.shape[0]
        zrow = jnp.zeros((b, 1), jnp.float32)
        vals = jnp.concatenate([visits, zrow], axis=1)[:, gidx_p]  # [B,t,EB]
        if engine == "pallas":
            incl = _kernel.tile_cumsum(
                vals.reshape(b * t, EB), interpret=interpret
            ).reshape(b, t, EB)
        else:
            incl = jnp.cumsum(vals, axis=2)
        bh, bl = _comp_scan(incl[:, :, -1], axis=1)  # inclusive tile bases
        bh = jnp.concatenate([zrow, bh[:, :-1]], axis=1)  # -> exclusive
        bl = jnp.concatenate([zrow, bl[:, :-1]], axis=1)
        incl_f = incl.reshape(b, -1)
        ih = jnp.where(z_hi, 0.0, jnp.take(incl_f, i_hi, axis=1))
        il = jnp.where(z_lo, 0.0, jnp.take(incl_f, i_lo, axis=1))
        return (jnp.take(bh, q_hi, axis=1) - jnp.take(bh, q_lo, axis=1)) + (
            (ih - il)
            + (jnp.take(bl, q_hi, axis=1) - jnp.take(bl, q_lo, axis=1))
        )

    return step


@functools.partial(
    jax.jit,
    static_argnames=(
        "steps", "num_vertices", "edges_hi", "normalize", "engine", "interpret"
    ),
)
def slot_walk_blocked(
    dst: jnp.ndarray,
    block_lo: jnp.ndarray,
    block_hi: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    engine: str = "xla",
    interpret: bool = False,
) -> jnp.ndarray:
    """Scatter-free walk step via block-interval prefix sums.

    See ``make_blocked_step`` for the hierarchical two-level prefix and
    the TwoSum compensation that keeps skewed-magnitude rows exact.  No
    ``slot_rows`` operand: dead slots are masked from ``dst`` alone.
    """
    gidx_p = _prep_gidx(dst, num_vertices, edges_hi)
    step = make_blocked_step(
        gidx_p, block_lo, block_hi, num_vertices,
        engine=engine, interpret=interpret,
    )
    visits = jnp.ones((1, num_vertices), jnp.float32)

    def body(visits, _):
        nxt = step(visits)
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits[0]


# ---------------------------------------------------------------------------
# multi-walk batching: B visit vectors through the same step programs
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_multi_xla(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    """Batched walk: ``visits0`` [B, V] -> [B, V], one fused step loop.

    The gather broadcasts over the batch axis and the per-step
    segment-sum runs once on the transposed [E, B] values, so B walks
    cost one scan instead of B dispatch loops.
    """
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    rows_f = rows_p.reshape(-1)
    gidx_f = gidx_p.reshape(-1)
    zcol = jnp.zeros((visits0.shape[0], 1), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zcol], axis=1)[:, gidx_f]  # [B, E]
        nxt = jax.ops.segment_sum(vals.T, rows_f, num_segments=sink + 1)[
            :num_vertices
        ].T
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


@functools.partial(
    jax.jit,
    static_argnames=(
        "steps", "num_vertices", "edges_hi", "normalize", "engine", "interpret"
    ),
)
def slot_walk_multi_blocked(
    dst: jnp.ndarray,
    block_lo: jnp.ndarray,
    block_hi: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    engine: str = "xla",
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched scatter-free prefix-sum walk: visits0 [B, V] -> [B, V].

    The blocked step is natively batched — the interval index arithmetic
    is shared, only the gathered values and prefix sums carry a batch
    dim (the Pallas intra-tile cumsum sees B*T independent tiles of the
    same kernel).
    """
    gidx_p = _prep_gidx(dst, num_vertices, edges_hi)
    step = make_blocked_step(
        gidx_p, block_lo, block_hi, num_vertices,
        engine=engine, interpret=interpret,
    )

    def body(visits, _):
        nxt = step(visits)
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


@functools.partial(
    jax.jit,
    static_argnames=("steps", "num_vertices", "edges_hi", "normalize", "interpret"),
)
def slot_walk_multi_pallas(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched Pallas walk: stack the B walks' tiles into one kernel call.

    ``rows`` are identical per walk, so tiling them B times turns the
    batch into B*T independent tiles of the SAME one-hot-rank kernel —
    one ``pallas_call`` per step regardless of B.  The seam fold then
    segments with a per-walk offset (walk b's rows live in segment ids
    ``[b*(sink+1), (b+1)*(sink+1))``).
    """
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    t = rows_p.shape[0]
    b = visits0.shape[0]
    rows_t = jnp.tile(rows_p, (b, 1))  # [B*T, EB]
    zcol = jnp.zeros((b, 1), jnp.float32)
    offs = jnp.repeat(
        jnp.arange(b, dtype=jnp.int32) * (sink + 1), t
    )[:, None]  # [B*T, 1]

    def body(visits, _):
        vals = jnp.concatenate([visits, zcol], axis=1)[:, gidx_p]  # [B,T,EB]
        part, rank = _kernel.slot_walk_partials(
            rows_t, vals.reshape(b * t, EB), sink=sink, interpret=interpret
        )
        ids = jnp.minimum(rank, sink) + offs
        nxt = jax.ops.segment_sum(
            part.reshape(-1), ids.reshape(-1), num_segments=b * (sink + 1)
        ).reshape(b, sink + 1)[:, :num_vertices]
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


def slot_walk(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int | None = None,
    backend: str = "auto",
    block_lo: jnp.ndarray | None = None,
    block_hi: jnp.ndarray | None = None,
    normalize: bool = False,
    interpret: bool = False,
    visits0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """k-step reverse walk over the slotted arena's live prefix.

    ``edges_hi`` bounds the slots processed (callers pass the arena bump,
    quantized); None means the whole buffer.  ``backend`` is ``auto``
    (pallas on TPU, xla elsewhere), ``pallas`` or ``xla``.  When the
    caller can supply per-vertex block intervals (``block_lo`` /
    ``block_hi``, int32 [num_vertices], lo == hi == 0 for blockless
    rows), the xla backend upgrades to the scatter-free prefix-sum
    formulation.  ``visits0`` switches to multi-walk batching: a
    [B, num_vertices] f32 stack of initial visit vectors walks together
    through one fused step loop, returning [B, num_vertices].
    """
    if edges_hi is None:
        edges_hi = dst.shape[0]
    edges_hi = min(int(edges_hi), dst.shape[0])
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown slot_walk backend: {backend!r}")
    if visits0 is not None:
        if visits0.ndim != 2 or visits0.shape[1] != num_vertices:
            raise ValueError(
                "visits0 must be [num_walks, num_vertices], got "
                f"{visits0.shape}"
            )
        visits0 = jnp.asarray(visits0, jnp.float32)

    # dispatch runs through the health-gated fallback chain (DESIGN.md
    # §13): a failing backend is retried once, then the call degrades
    # pallas → xla → host ref under the per-backend circuit breaker
    # instead of killing the stream
    def _dispatch(b: str) -> jnp.ndarray:
        if b == "ref":
            return _ref.slot_walk_host(
                dst, slot_rows, steps, num_vertices, edges_hi=edges_hi,
                block_lo=block_lo, block_hi=block_hi, normalize=normalize,
                visits0=visits0,
            )
        if visits0 is not None:
            if block_lo is not None and block_hi is not None:
                return slot_walk_multi_blocked(
                    dst, block_lo, block_hi, visits0, steps,
                    num_vertices, edges_hi=edges_hi, normalize=normalize,
                    engine=b, interpret=interpret,
                )
            if b == "pallas":
                return slot_walk_multi_pallas(
                    dst, slot_rows, visits0, steps, num_vertices,
                    edges_hi=edges_hi, normalize=normalize,
                    interpret=interpret,
                )
            return slot_walk_multi_xla(
                dst, slot_rows, visits0, steps, num_vertices,
                edges_hi=edges_hi, normalize=normalize,
            )
        if block_lo is not None and block_hi is not None:
            return slot_walk_blocked(
                dst, block_lo, block_hi, steps, num_vertices,
                edges_hi=edges_hi, normalize=normalize, engine=b,
                interpret=interpret,
            )
        if b == "pallas":
            return slot_walk_pallas(
                dst, slot_rows, steps, num_vertices,
                edges_hi=edges_hi, normalize=normalize, interpret=interpret,
            )
        return slot_walk_xla(
            dst, slot_rows, steps, num_vertices,
            edges_hi=edges_hi, normalize=normalize,
        )

    out, _used = _fb.run_chain("slot_walk", backend, _dispatch)
    return out


def slot_walk_image(
    image,
    steps: int,
    *,
    backend: str = "auto",
    normalize: bool = False,
    interpret: bool = False,
    visits0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Image-input entry point: walk a ``core.walk_image.WalkImage``.

    The image supplies the full operand set — packed buffers, quantized
    prefix bound, per-vertex block intervals — so every representation's
    walk lands on the same engine with the same jit-shape policy.  All
    backends now take the scatter-free interval formulation (DESIGN.md
    §12): ``slot_rows`` is folded into the [lo, hi) geometry, so the
    step loop's per-slot operand set is the gather plane alone — Pallas
    runs the intra-tile prefix level on the MXU, XLA on the vector unit.
    """
    block_lo, block_hi = image.device_blocks()
    return slot_walk(
        image.dst,
        image.rows,
        steps,
        image.nv,
        edges_hi=image.edges_hi(),
        backend=backend,
        block_lo=block_lo,
        block_hi=block_hi,
        normalize=normalize,
        interpret=interpret,
        visits0=visits0,
    )
