"""jit'd wrapper: prefix-tile the slotted buffer, scan the step loop.

Two jitted backends behind one dispatcher:

  * ``pallas``  — the MXU tile kernel (kernel.py); ``interpret=True`` runs
    the same program on CPU for parity tests.
  * ``xla``     — identical prefix/tile semantics via a plain segment-sum
    (the fast path off-TPU, and the shape the Pallas kernel must match).

Both only process ``edges_hi`` slots (the arena's bump prefix, rounded up
to a power of two by the caller so the jit cache stays O(log CAP_E))
instead of the full CAP_E buffer — on updated graphs that alone is the
difference between walking the paper's live edges and walking every dead
SENTINEL lane the allocator ever reserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import util
from . import kernel as _kernel

SENTINEL = util.SENTINEL
EB = 128  # slots per tile (MXU-native)


def _prep(dst, slot_rows, num_vertices: int, edges_hi: int):
    """Slice the live prefix, mask dead slots, pad to whole tiles.

    Dead/pad slots get row ``sink`` and gather index ``num_vertices`` —
    the step loop extends ``visits`` with a zero sink entry, so no
    per-step masking is needed (masks are folded once, here, outside the
    scan).
    """
    e = min(int(edges_hi), dst.shape[0])
    t = max(-(-e // EB), 1)
    e_pad = t * EB
    sink = num_vertices
    d = dst[:e]
    sr = slot_rows[:e]
    valid = (d != SENTINEL) & (sr < num_vertices)
    rows = jnp.where(valid, sr, sink).astype(jnp.int32)
    gidx = jnp.where(valid, jnp.clip(d, 0, num_vertices - 1), num_vertices)
    rows_p = jnp.full((e_pad,), sink, jnp.int32).at[:e].set(rows).reshape(t, EB)
    gidx_p = (
        jnp.full((e_pad,), num_vertices, jnp.int32).at[:e].set(gidx).reshape(t, EB)
    )
    return rows_p, gidx_p


@functools.partial(
    jax.jit,
    static_argnames=("steps", "num_vertices", "edges_hi", "normalize", "interpret"),
)
def slot_walk_pallas(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    zero = jnp.zeros((1,), jnp.float32)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zero])[gidx_p]  # sink gathers 0.0
        part, rank = _kernel.slot_walk_partials(
            rows_p, vals, sink=sink, interpret=interpret
        )
        nxt = jax.ops.segment_sum(
            part.reshape(-1),
            jnp.minimum(rank.reshape(-1), sink),
            num_segments=sink + 1,
        )[:num_vertices]
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_xla(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    rows_f = rows_p.reshape(-1)
    gidx_f = gidx_p.reshape(-1)
    zero = jnp.zeros((1,), jnp.float32)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zero])[gidx_f]  # sink gathers 0.0
        nxt = jax.ops.segment_sum(vals, rows_f, num_segments=sink + 1)[
            :num_vertices
        ]
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


def _twosum(a, b):
    """Knuth TwoSum: s + e == a + b exactly (s = fl(a+b))."""
    s = a + b
    bp = s - a
    return s, (a - (s - bp)) + (b - bp)


def _comp_combine(l, r):
    s, e = _twosum(l[0], r[0])
    return s, l[1] + r[1] + e


def _comp_scan(x):
    """Compensated inclusive scan: returns (hi, lo) with hi+lo ≈ exact."""
    return jax.lax.associative_scan(_comp_combine, (x, jnp.zeros_like(x)))


def _make_blocked_step(gidx_p, block_lo, block_hi, num_vertices: int):
    """Build the scatter-free interval walk step (shared single/multi).

    Each vertex's slots are one contiguous interval [block_lo, block_hi)
    (§2 invariant) and dead slots gather 0.0, so a step reduces to
    ``P[hi] - P[lo]`` over the running prefix sum of the gathered values
    — gather + cumsum + a few [V] gathers, no scatter unit needed.
    Rows without a block pass lo == hi == 0.

    A naive global f32 cumsum loses the row sum to cancellation once the
    total dwarfs it (err ~ ulp(total)).  The prefix is therefore kept in
    two levels: a plain cumsum *within* each 128-slot tile (row-local
    magnitudes) plus a TwoSum-compensated scan over the T tile totals,
    and the difference is assembled per part so the large bases are
    never rounded into the result.  The residual envelope is the
    *intra-tile* partial, ~ulp(sum of one tile): on skewed social graphs
    a hub row sharing its tile with ~1e10-magnitude partials can see
    ~2e-4 relative error at high step counts (measured; fully
    compensating or f64-ing the intra level costs 2-10x the whole step
    — not worth it for a wall-time benchmark whose 42-step counts
    saturate f32 by design).
    """
    t = gidx_p.shape[0]
    e_pad = t * EB
    lo = jnp.clip(block_lo, 0, e_pad).astype(jnp.int32)
    hi = jnp.clip(block_hi, 0, e_pad).astype(jnp.int32)
    # split each prefix position into (tile, offset); position e_pad folds
    # onto the last tile's tail so the gather stays in range.
    q_lo = jnp.minimum(lo // EB, t - 1)
    q_hi = jnp.minimum(hi // EB, t - 1)
    r_lo = lo - q_lo * EB
    r_hi = hi - q_hi * EB
    zero = jnp.zeros((1,), jnp.float32)
    zcol = jnp.zeros((t, 1), jnp.float32)

    def step(visits):  # [num_vertices] -> [num_vertices]
        vals = jnp.concatenate([visits, zero])[gidx_p]   # [t, EB]; sink -> 0.0
        intra = jnp.concatenate([zcol, jnp.cumsum(vals, axis=1)], axis=1)
        bh, bl = _comp_scan(intra[:, -1])                # inclusive tile bases
        bh = jnp.concatenate([zero, bh[:-1]])            # -> exclusive
        bl = jnp.concatenate([zero, bl[:-1]])
        intra_f = intra.reshape(-1)
        ih = intra_f[q_hi * (EB + 1) + r_hi]
        il = intra_f[q_lo * (EB + 1) + r_lo]
        return (bh[q_hi] - bh[q_lo]) + ((ih - il) + (bl[q_hi] - bl[q_lo]))

    return step


@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_blocked(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    block_lo: jnp.ndarray,
    block_hi: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    """Scatter-free walk step via block-interval prefix sums.

    See ``_make_blocked_step`` for the formulation and the two-level
    TwoSum compensation that keeps skewed-magnitude rows exact.
    """
    _, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    step = _make_blocked_step(gidx_p, block_lo, block_hi, num_vertices)
    visits = jnp.ones((num_vertices,), jnp.float32)

    def body(visits, _):
        nxt = step(visits)
        if normalize:
            nxt = nxt / jnp.maximum(jnp.max(nxt), 1.0)
        return nxt, None

    visits, _ = jax.lax.scan(body, visits, None, length=steps)
    return visits


# ---------------------------------------------------------------------------
# multi-walk batching: B visit vectors through the same step programs
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_multi_xla(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    """Batched walk: ``visits0`` [B, V] -> [B, V], one fused step loop.

    The gather broadcasts over the batch axis and the per-step
    segment-sum runs once on the transposed [E, B] values, so B walks
    cost one scan instead of B dispatch loops.
    """
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    rows_f = rows_p.reshape(-1)
    gidx_f = gidx_p.reshape(-1)
    zcol = jnp.zeros((visits0.shape[0], 1), jnp.float32)

    def body(visits, _):
        vals = jnp.concatenate([visits, zcol], axis=1)[:, gidx_f]  # [B, E]
        nxt = jax.ops.segment_sum(vals.T, rows_f, num_segments=sink + 1)[
            :num_vertices
        ].T
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


@functools.partial(
    jax.jit, static_argnames=("steps", "num_vertices", "edges_hi", "normalize")
)
def slot_walk_multi_blocked(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    block_lo: jnp.ndarray,
    block_hi: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
) -> jnp.ndarray:
    """Batched scatter-free prefix-sum walk: visits0 [B, V] -> [B, V].

    The single-walk step (``_make_blocked_step``) is vmapped over the
    batch axis inside one jitted scan — the interval index arithmetic is
    shared, only the gathered values and prefix sums carry a batch dim.
    """
    _, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    step = _make_blocked_step(gidx_p, block_lo, block_hi, num_vertices)

    def body(visits, _):
        nxt = jax.vmap(step)(visits)
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


@functools.partial(
    jax.jit,
    static_argnames=("steps", "num_vertices", "edges_hi", "normalize", "interpret"),
)
def slot_walk_multi_pallas(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    visits0: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int,
    normalize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched Pallas walk: stack the B walks' tiles into one kernel call.

    ``rows`` are identical per walk, so tiling them B times turns the
    batch into B*T independent tiles of the SAME one-hot-rank kernel —
    one ``pallas_call`` per step regardless of B.  The seam fold then
    segments with a per-walk offset (walk b's rows live in segment ids
    ``[b*(sink+1), (b+1)*(sink+1))``).
    """
    sink = num_vertices
    rows_p, gidx_p = _prep(dst, slot_rows, num_vertices, edges_hi)
    t = rows_p.shape[0]
    b = visits0.shape[0]
    rows_t = jnp.tile(rows_p, (b, 1))  # [B*T, EB]
    zcol = jnp.zeros((b, 1), jnp.float32)
    offs = jnp.repeat(
        jnp.arange(b, dtype=jnp.int32) * (sink + 1), t
    )[:, None]  # [B*T, 1]

    def body(visits, _):
        vals = jnp.concatenate([visits, zcol], axis=1)[:, gidx_p]  # [B,T,EB]
        part, rank = _kernel.slot_walk_partials(
            rows_t, vals.reshape(b * t, EB), sink=sink, interpret=interpret
        )
        ids = jnp.minimum(rank, sink) + offs
        nxt = jax.ops.segment_sum(
            part.reshape(-1), ids.reshape(-1), num_segments=b * (sink + 1)
        ).reshape(b, sink + 1)[:, :num_vertices]
        if normalize:
            nxt = nxt / jnp.maximum(
                jnp.max(nxt, axis=1, keepdims=True), 1.0
            )
        return nxt, None

    visits, _ = jax.lax.scan(body, visits0, None, length=steps)
    return visits


def slot_walk(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    *,
    edges_hi: int | None = None,
    backend: str = "auto",
    block_lo: jnp.ndarray | None = None,
    block_hi: jnp.ndarray | None = None,
    normalize: bool = False,
    interpret: bool = False,
    visits0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """k-step reverse walk over the slotted arena's live prefix.

    ``edges_hi`` bounds the slots processed (callers pass the arena bump,
    quantized); None means the whole buffer.  ``backend`` is ``auto``
    (pallas on TPU, xla elsewhere), ``pallas`` or ``xla``.  When the
    caller can supply per-vertex block intervals (``block_lo`` /
    ``block_hi``, int32 [num_vertices], lo == hi == 0 for blockless
    rows), the xla backend upgrades to the scatter-free prefix-sum
    formulation.  ``visits0`` switches to multi-walk batching: a
    [B, num_vertices] f32 stack of initial visit vectors walks together
    through one fused step loop, returning [B, num_vertices].
    """
    if edges_hi is None:
        edges_hi = dst.shape[0]
    edges_hi = min(int(edges_hi), dst.shape[0])
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if visits0 is not None:
        if visits0.ndim != 2 or visits0.shape[1] != num_vertices:
            raise ValueError(
                "visits0 must be [num_walks, num_vertices], got "
                f"{visits0.shape}"
            )
        visits0 = jnp.asarray(visits0, jnp.float32)
        if backend == "pallas":
            return slot_walk_multi_pallas(
                dst, slot_rows, visits0, steps, num_vertices,
                edges_hi=edges_hi, normalize=normalize, interpret=interpret,
            )
        if backend == "xla":
            if block_lo is not None and block_hi is not None:
                return slot_walk_multi_blocked(
                    dst, slot_rows, block_lo, block_hi, visits0, steps,
                    num_vertices, edges_hi=edges_hi, normalize=normalize,
                )
            return slot_walk_multi_xla(
                dst, slot_rows, visits0, steps, num_vertices,
                edges_hi=edges_hi, normalize=normalize,
            )
        raise ValueError(f"unknown slot_walk backend: {backend!r}")
    if backend == "pallas":
        return slot_walk_pallas(
            dst,
            slot_rows,
            steps,
            num_vertices,
            edges_hi=edges_hi,
            normalize=normalize,
            interpret=interpret,
        )
    if backend == "xla":
        if block_lo is not None and block_hi is not None:
            return slot_walk_blocked(
                dst,
                slot_rows,
                block_lo,
                block_hi,
                steps,
                num_vertices,
                edges_hi=edges_hi,
                normalize=normalize,
            )
        return slot_walk_xla(
            dst,
            slot_rows,
            steps,
            num_vertices,
            edges_hi=edges_hi,
            normalize=normalize,
        )
    raise ValueError(f"unknown slot_walk backend: {backend!r}")


def slot_walk_image(
    image,
    steps: int,
    *,
    backend: str = "auto",
    normalize: bool = False,
    interpret: bool = False,
    visits0: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Image-input entry point: walk a ``core.walk_image.WalkImage``.

    The image supplies the full operand set — packed buffers, quantized
    prefix bound, per-vertex block intervals — so every representation's
    walk lands on the same engine with the same jit-shape policy.  The
    interval arrays only feed the off-TPU scatter-free path; the Pallas
    backend reads just the packed buffers.
    """
    use_blocks = backend == "xla" or (
        backend == "auto" and jax.default_backend() != "tpu"
    )
    block_lo, block_hi = image.device_blocks() if use_blocks else (None, None)
    return slot_walk(
        image.dst,
        image.rows,
        steps,
        image.nv,
        edges_hi=image.edges_hi(),
        backend=backend,
        block_lo=block_lo,
        block_hi=block_hi,
        normalize=normalize,
        interpret=interpret,
        visits0=visits0,
    )
