"""Pure-jnp oracle: full-buffer masked gather + segment_sum walk.

Shape-identical semantics to ops.slot_walk (the seed ``reverse_walk_flat``
formulation): every slot of the buffer is re-masked each step, so dead
SENTINEL lanes and stale ``slot_rows`` contribute nothing.  Tests compare
the tiled kernel against this and against the dense numpy oracle in
``core.traversal``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core import util

SENTINEL = util.SENTINEL


def slot_walk_reference(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    normalize: bool = False,
) -> jnp.ndarray:
    valid = (dst != SENTINEL) & (slot_rows < num_vertices)
    safe_dst = jnp.where(valid, jnp.clip(dst, 0, num_vertices - 1), 0)
    rows = jnp.where(valid, slot_rows, num_vertices).astype(jnp.int32)
    visits = jnp.ones((num_vertices,), jnp.float32)
    for _ in range(steps):
        vals = jnp.where(valid, visits[safe_dst], 0.0)
        visits = jax.ops.segment_sum(
            vals, rows, num_segments=num_vertices + 1
        )[:num_vertices]
        if normalize:
            visits = visits / jnp.maximum(jnp.max(visits), 1.0)
    return visits


def slot_walk_host(
    dst,
    slot_rows,
    steps: int,
    num_vertices: int,
    *,
    edges_hi=None,
    block_lo=None,
    block_hi=None,
    normalize: bool = False,
    visits0=None,
) -> jnp.ndarray:
    """Pure-numpy walk — the fallback chain's floor (DESIGN.md §13).

    Accepts every operand form the dispatcher routes (rows-carrying
    buffers, [lo, hi) interval geometry, batched ``visits0``) so any
    ``slot_walk`` call can complete here when both device backends are
    tripped.  Per-step f32 rounding of the bincount accumulation differs
    from the device formulations (host sums are sequential), so results
    are reference-accurate, not bit-identical to a healthy round — the
    chain trades exact dispatch parity for stream survival at this link.
    """
    d_full = np.asarray(dst)
    e = d_full.shape[0] if edges_hi is None else min(int(edges_hi), d_full.shape[0])
    d = d_full[:e].astype(np.int64)
    nv = int(num_vertices)
    if block_lo is not None and block_hi is not None:
        # fold the interval geometry into a per-slot owner plane
        lo = np.clip(np.asarray(block_lo, np.int64), 0, e)
        hi = np.clip(np.asarray(block_hi, np.int64), 0, e)
        deg = np.maximum(hi - lo, 0)
        rows = np.full(e, nv, np.int64)
        total = int(deg.sum())
        if total:
            first = np.cumsum(deg) - deg
            idx = np.repeat(lo, deg) + (np.arange(total) - np.repeat(first, deg))
            rows[idx] = np.repeat(np.arange(deg.shape[0], dtype=np.int64), deg)
    else:
        rows = np.asarray(slot_rows)[:e].astype(np.int64)
    valid = (d != int(SENTINEL)) & (rows >= 0) & (rows < nv)
    gidx = np.where(valid, np.clip(d, 0, nv - 1), 0)
    seg = np.where(valid, rows, nv)
    if visits0 is None:
        vis = np.ones((1, nv), np.float32)
    else:
        vis = np.asarray(visits0, np.float32).reshape(-1, nv)
    for _ in range(int(steps)):
        vals = np.where(valid[None, :], vis[:, gidx], np.float32(0.0))
        nxt = np.empty_like(vis)
        for b in range(vis.shape[0]):
            nxt[b] = np.bincount(
                seg, weights=vals[b], minlength=nv + 1
            )[:nv].astype(np.float32)
        if normalize:
            nxt = nxt / np.maximum(nxt.max(axis=1, keepdims=True), 1.0)
        vis = nxt.astype(np.float32)
    out = jnp.asarray(vis)
    return out if visits0 is not None else out[0]
