"""Pure-jnp oracle: full-buffer masked gather + segment_sum walk.

Shape-identical semantics to ops.slot_walk (the seed ``reverse_walk_flat``
formulation): every slot of the buffer is re-masked each step, so dead
SENTINEL lanes and stale ``slot_rows`` contribute nothing.  Tests compare
the tiled kernel against this and against the dense numpy oracle in
``core.traversal``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import util

SENTINEL = util.SENTINEL


def slot_walk_reference(
    dst: jnp.ndarray,
    slot_rows: jnp.ndarray,
    steps: int,
    num_vertices: int,
    normalize: bool = False,
) -> jnp.ndarray:
    valid = (dst != SENTINEL) & (slot_rows < num_vertices)
    safe_dst = jnp.where(valid, jnp.clip(dst, 0, num_vertices - 1), 0)
    rows = jnp.where(valid, slot_rows, num_vertices).astype(jnp.int32)
    visits = jnp.ones((num_vertices,), jnp.float32)
    for _ in range(steps):
        vals = jnp.where(valid, visits[safe_dst], 0.0)
        visits = jax.ops.segment_sum(
            vals, rows, num_segments=num_vertices + 1
        )[:num_vertices]
        if normalize:
            visits = visits / jnp.maximum(jnp.max(visits), 1.0)
    return visits
