"""Sharded walk-image traversal (DESIGN.md §14).

The dense WalkImage shards by tile range: each device owns a contiguous
vertex block's packed tiles and runs the SAME scatter-free blocked step
(``ops.make_blocked_step``) the single-device engine uses — intra-tile
cumsum, TwoSum-compensated inter-tile scan, ``P[hi] - P[lo]`` interval
reads.  Shard cuts align to block boundaries by construction (a vertex's
block lives wholly inside its owner's slot space), so the inter-tile
base scan CANCELS within each shard and never crosses devices.  The only
cross-shard exchange per walk step is the frontier: every shard emits
its own ``[B, rows_max]`` visits slice and an ``all_gather`` reassembles
the ``[B, V_pad]`` frontier — (S-1)·rows_max·4 ≈ |V|·4 bytes received
per device per step, independent of |E|.

Two bit-identical builders share the math:

  * ``make_sharded_walk`` — the shard_map program over a 1-D ``("data",)``
    mesh (one jitted dispatch for the whole k-step walk);
  * ``make_local_walk``   — the same per-shard step closures looped on one
    device (meshless parity tests and the S=1 degenerate row).

``collective_bytes_per_step`` proves the model by traversing the lowered
jaxpr: the per-device bytes every collective receives, scan trip counts
folded in — no runtime tracing hooks, the program IS the evidence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ...launch import mesh as mesh_mod
from . import ops as _ops


def _shard_step(dst_l, lo_l, hi_l, v_pad: int, e_hi: int):
    """This shard's blocked step: [B, v_pad] frontier -> [B, v_pad] visits.

    Rows outside the shard's owned range carry lo == hi == 0, so their
    output is exactly 0.0 and the owner's slice is the only information
    the step produces — the frontier exchange below carries it.
    """
    gidx_p = _ops._prep_gidx(dst_l, v_pad, e_hi)
    return _ops.make_blocked_step(gidx_p, lo_l, hi_l, v_pad)


@functools.lru_cache(maxsize=None)
def make_sharded_walk(
    mesh, steps: int, n_shards: int, rows_max: int, cap_e: int, e_hi: int,
    nwalks: int,
):
    """jitted shard_map walk: (dst [S,cap_e], lo/hi [S,v_pad], vis [B,v_pad]).

    One device program for the whole k-step walk; per step each shard
    computes its own visits slice and ``all_gather``s the frontier
    (tiled, so the output IS the next [B, v_pad] frontier).  The result
    is replicated — ``check=False`` because jax cannot prove an
    all_gather'ed value replicated across the unrolled scan.
    """
    v_pad = n_shards * rows_max

    def shard_fn(dst_g, lo_g, hi_g, vis):
        step = _shard_step(dst_g[0], lo_g[0], hi_g[0], v_pad, e_hi)
        idx = jax.lax.axis_index("data")

        def one(v, _):
            own = jax.lax.dynamic_slice_in_dim(
                step(v), idx * rows_max, rows_max, axis=1
            )
            return jax.lax.all_gather(own, "data", axis=1, tiled=True), None

        vis, _ = jax.lax.scan(one, vis, None, length=steps)
        return vis

    fn = mesh_mod.shard_map_compat(
        shard_fn,
        mesh=mesh,
        in_specs=(P("data", None), P("data", None), P("data", None), P()),
        out_specs=P(),
        check=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def make_local_walk(
    steps: int, n_shards: int, rows_max: int, cap_e: int, e_hi: int,
    nwalks: int,
):
    """Single-device emulation of the sharded walk, same math shard-by-shard.

    Each shard's step closure runs on its own tile range and contributes
    exactly its owned visits slice; the concat stands in for the
    all_gather.  Exists so parity tests need no mesh and the bench's
    shards=1 row is a real program, not a special case.
    """
    v_pad = n_shards * rows_max

    @jax.jit
    def walk(dst_g, lo_g, hi_g, vis):
        steps_fns = [
            _shard_step(dst_g[s], lo_g[s], hi_g[s], v_pad, e_hi)
            for s in range(n_shards)
        ]

        def one(v, _):
            parts = [
                jax.lax.dynamic_slice_in_dim(
                    f(v), s * rows_max, rows_max, axis=1
                )
                for s, f in enumerate(steps_fns)
            ]
            return jnp.concatenate(parts, axis=1), None

        vis, _ = jax.lax.scan(one, vis, None, length=steps)
        return vis

    return walk


# ---------------------------------------------------------------------------
# collective-bytes model proof (DESIGN.md §14)
# ---------------------------------------------------------------------------
_RECV_COLLECTIVES = ("all_gather", "all_gather_invariant")
_MOVE_COLLECTIVES = ("ppermute", "all_to_all", "pgather")

try:  # jaxpr container types moved under jax.extend on newer jax
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def _sub_jaxprs(params):
    for v in params.values():
        for x in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(x, _ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, _Jaxpr):
                yield x


def _collective_bytes(jaxpr, mult: int = 1) -> int:
    """Per-device bytes received by collectives under ``jaxpr``.

    ``all_gather`` receives (out - in) bytes per device (its own shard it
    already holds); data-movement collectives count their full output.
    Scan bodies multiply by trip count; every other sub-jaxpr (pjit,
    shard_map, cond branches) recurses at the current multiplier — the
    shard_map body's avals are per-shard shapes, which is exactly the
    per-device accounting the |V|·4 model is stated in.
    """
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        m = mult * int(eqn.params["length"]) if name == "scan" else mult
        if name in _RECV_COLLECTIVES:
            out_b = sum(_aval_bytes(v) for v in eqn.outvars)
            in_b = sum(_aval_bytes(v) for v in eqn.invars)
            total += m * max(out_b - in_b, 0)
        elif name in _MOVE_COLLECTIVES:
            total += m * sum(_aval_bytes(v) for v in eqn.outvars)
        for sub in _sub_jaxprs(eqn.params):
            total += _collective_bytes(sub, m)
    return total


def collective_bytes_per_step(
    mesh, steps: int, n_shards: int, rows_max: int, cap_e: int, e_hi: int,
    nwalks: int,
) -> int:
    """Measured per-device collective bytes per walk step, via the jaxpr.

    Builds the exact walk program ``make_sharded_walk`` dispatches and
    inspects its lowered form — the proof field bench rows publish
    against the ``(S-1)·rows_max·B·4`` frontier model.  S=1 programs
    still contain the all_gather; its out == in, so the count is 0.
    """
    v_pad = n_shards * rows_max
    b = max(nwalks, 1)
    args = (
        jax.ShapeDtypeStruct((n_shards, cap_e), jnp.int32),
        jax.ShapeDtypeStruct((n_shards, v_pad), jnp.int32),
        jax.ShapeDtypeStruct((n_shards, v_pad), jnp.int32),
        jax.ShapeDtypeStruct((b, v_pad), jnp.float32),
    )
    fn = make_sharded_walk(mesh, steps, n_shards, rows_max, cap_e, e_hi, nwalks)
    jaxpr = jax.make_jaxpr(fn)(*args)
    return _collective_bytes(jaxpr.jaxpr) // max(steps, 1)


def model_bytes_per_step(n_shards: int, rows_max: int, nwalks: int) -> int:
    """The |V|·4 frontier model: bytes each device receives per step."""
    return (n_shards - 1) * rows_max * max(nwalks, 1) * 4
