"""Fault tolerance & elasticity runtime (DESIGN.md §5).

On a real 1000+-node fleet, the control plane watches per-step heartbeats,
declares stragglers/failures by deadline, and restarts the job on the
surviving mesh from the last checkpoint.  All of that logic is host-side
python — exactly what this module implements; the device-count-specific
parts (re-mesh + re-shard) rebuild pjit shardings for the new topology.
This container exercises the full state machine with simulated heartbeats
(tests/test_runtime.py); nothing here is TPU-count dependent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from ..checkpoint import manager as ckpt


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    slow_strikes: int = 0
    alive: bool = True


class FleetMonitor:
    """Heartbeat/straggler tracking with deterministic deadlines.

    * a worker missing ``fail_timeout`` seconds of heartbeats is DEAD →
      triggers elastic restart on the survivors;
    * a worker whose step time exceeds ``straggler_factor`` × the fleet
      median on ``strike_limit`` consecutive steps is a STRAGGLER →
      scheduled for replacement (the mitigation real fleets use before
      paying a restart).
    """

    def __init__(
        self,
        n_workers: int,
        *,
        fail_timeout: float = 60.0,
        straggler_factor: float = 2.0,
        strike_limit: int = 3,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.clock = clock
        self.fail_timeout = fail_timeout
        self.straggler_factor = straggler_factor
        self.strike_limit = strike_limit
        now = clock()
        self.workers = {i: WorkerState(now) for i in range(n_workers)}
        self.step_times: dict[int, float] = {}

    def heartbeat(self, worker: int, step_time: Optional[float] = None) -> None:
        w = self.workers[worker]
        w.last_heartbeat = self.clock()
        if step_time is not None:
            self.step_times[worker] = step_time

    def check(self) -> dict:
        """Returns {dead: [...], stragglers: [...], healthy: n}."""
        now = self.clock()
        dead, stragglers = [], []
        times = [t for t in self.step_times.values()]
        median = float(np.median(times)) if times else 0.0
        for i, w in self.workers.items():
            if not w.alive:
                continue
            if now - w.last_heartbeat > self.fail_timeout:
                w.alive = False
                dead.append(i)
                continue
            t = self.step_times.get(i)
            if t is not None and median > 0 and t > self.straggler_factor * median:
                w.slow_strikes += 1
                if w.slow_strikes >= self.strike_limit:
                    stragglers.append(i)
            else:
                w.slow_strikes = 0
        healthy = sum(1 for w in self.workers.values() if w.alive)
        return {"dead": dead, "stragglers": stragglers, "healthy": healthy}

    def evict(self, worker: int) -> None:
        self.workers[worker].alive = False

    def alive_workers(self) -> list[int]:
        return [i for i, w in self.workers.items() if w.alive]


def elastic_mesh_shape(n_devices: int, *, model_parallel: int = 16):
    """Largest (data, model) mesh fitting the surviving device count.

    Keeps TP fixed (model-parallel groups must stay whole — losing one
    member kills the group) and shrinks the data axis; pow-2 bucketing of
    the data axis keeps the recompiled program count logarithmic under
    repeated shrink/grow events (CP2AA policy applied to topology).
    """
    data = max(n_devices // model_parallel, 1)
    data_pow2 = 1 << (data.bit_length() - 1)  # round DOWN to pow-2
    return (data_pow2, model_parallel)


def restart_from_checkpoint(ckpt_dir: str, like, *, step=None):
    """Restore the newest durable state (the recovery path after a failure)."""
    return ckpt.restore(ckpt_dir, like, step=step)


@dataclasses.dataclass
class ElasticTrainer:
    """Orchestrates monitor + checkpoint + re-mesh decisions.

    drive() consumes (step_time per worker) samples — in production these
    come from the coordinator's RPC stream; in tests, from a simulator.
    """

    monitor: FleetMonitor
    ckpt_dir: str
    model_parallel: int = 16
    events: list = dataclasses.field(default_factory=list)

    def on_step(self, step: int, state, step_times: dict[int, float]):
        for w, t in step_times.items():
            if self.monitor.workers[w].alive:
                self.monitor.heartbeat(w, t)
        report = self.monitor.check()
        if report["dead"]:
            # failure: re-mesh on survivors, restore from durable state
            new_shape = elastic_mesh_shape(
                len(self.monitor.alive_workers()), model_parallel=self.model_parallel
            )
            self.events.append(
                {"step": step, "kind": "remesh", "dead": report["dead"],
                 "new_mesh": new_shape}
            )
            restored, at = restart_from_checkpoint(self.ckpt_dir, state)
            self.events.append({"step": step, "kind": "restore", "from_step": at})
            return restored, new_shape
        if report["stragglers"]:
            for w in report["stragglers"]:
                self.monitor.evict(w)
            self.events.append(
                {"step": step, "kind": "evict_stragglers", "workers": report["stragglers"]}
            )
        return state, None
