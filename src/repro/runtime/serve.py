"""Overload-safe multi-tenant walk serving front-end (DESIGN.md §16).

The ROADMAP's production-traffic item: an async request queue in front of
the walk engine, turning concurrent per-user walk requests into the
single batched ``[B, V]`` dispatches PR 4/5 made fast, with a robustness
contract stronger than the sum of its parts:

* **Snapshot isolation** — one writer thread applies ``UpdatePlan``s to
  the live representation and *seals* an immutable ``WalkImage``
  generation after each group (``core.walk_image.seal_generation``,
  riding the per-buffer COW of §10).  Readers always walk the last
  sealed generation: a reader can never observe a half-applied plan,
  because generations are frozen images and the writer's subsequent
  patches copy-on-write instead of donating shared buffers.  Every
  response carries its ``generation`` id, so consistency is *checkable*
  (the bench and the hypothesis sweep verify bit-parity against a dense
  oracle per generation — ``torn_reads == 0``).

* **Admission control + backpressure** — both queues are bounded.  A
  walk submitted past ``max_queue`` depth is rejected immediately with a
  ``Retry-After``-style hint (``RejectedError.retry_after``, estimated
  from the EMA per-request service time); a request whose deadline
  expired while it waited is shed before dispatch (load shedding: the
  batch never pays for work nobody is waiting for).

* **Graceful degradation** — walk dispatches run through the
  ``kernels/fallback`` breaker chain (pallas → xla → ref), so a tripped
  backend degrades throughput instead of failing requests; serve-level
  transient failures get bounded retry with backoff
  (``dispatch_retries``), and only an exhausted chain fails a ticket —
  visibly, never silently.

* **Fault-injected audits** — ``faultinject`` points at the three
  boundary transitions (``serve.enqueue``, ``serve.seal``,
  ``serve.dispatch``) prove the zero-lost contract: every submitted
  ticket resolves as served / rejected / failed (``assert_no_lost``),
  and a failed seal keeps readers on the previous consistent generation
  while the writer retries.

* **Shard failover (DESIGN.md §17)** — serving a ``ShardedGraph``, a
  single lost shard degrades coverage instead of availability.  A walk
  dispatch that trips ``ShardFaultError(sid)`` queues that shard for
  quarantine on the writer (``_pending_quarantine``) and retries the
  batch — against the previous sealed generation first, then against
  the degraded reseal once the writer flips it.  Every response carries
  ``coverage`` (fraction of the vertex space served) and
  ``down_shards`` so a degraded answer is *explicit*, never silent.
  The writer optionally paces a round-robin integrity audit
  (``audit_every`` > 0 → one ``failover.AuditScheduler`` tick per N
  writer rounds) to catch *silent* corruption on the live rep before it
  can reach a sealed generation; ``run_on_writer`` executes admin work
  (chaos injection, ``rebuild_shard`` reintegration) on the writer
  thread, serialized with applies, with an optional reseal after.

The server is representation-agnostic: anything exposing
``apply(plan)`` (returning ``(rep, dm)`` or mutating in place) plus
either ``to_walk_image()`` or its own ``seal_generation`` (all five
single-device representations, and ``ShardedGraph`` across a mesh)
serves.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import jax
import numpy as np

from ..core import alloc, updates, walk_image
from ..kernels import fallback as _fb
from . import faultinject

#: Ticket terminal states.  "pending" is the only non-terminal one.
SERVED, REJECTED, FAILED, PENDING = "served", "rejected", "failed", "pending"


class RejectedError(RuntimeError):
    """A request the server declined cleanly (never started).

    ``reason`` is one of the admission reasons ("backpressure",
    "expired", "shutdown", "enqueue_fault", "seed_out_of_range",
    "shape_mismatch"); ``retry_after`` (seconds, backpressure only) is
    the Retry-After hint — the estimated time for the queue to drain
    below the watermark.
    """

    def __init__(self, reason: str, retry_after: Optional[float] = None):
        msg = f"request rejected: {reason}"
        if retry_after is not None:
            msg += f" (retry after {retry_after * 1e3:.1f}ms)"
        super().__init__(msg)
        self.reason = reason
        self.retry_after = retry_after


@dataclasses.dataclass
class Generation:
    """One sealed, immutable walk image plus its bookkeeping."""

    gen_id: int
    image: walk_image.WalkImage
    #: updates applied to the live rep when this generation sealed
    seq: int
    sealed_at: float


class _Ticket:
    """Base request handle: threading.Event + terminal outcome."""

    __slots__ = (
        "status", "reason", "retry_after", "error", "generation",
        "submitted_at", "_done",
    )

    def __init__(self):
        self.status = PENDING
        self.reason: Optional[str] = None
        self.retry_after: Optional[float] = None
        self.error: Optional[BaseException] = None
        self.generation: Optional[int] = None
        self.submitted_at = time.monotonic()
        self._done = threading.Event()

    # -- resolution (server side) ---------------------------------------
    def _resolve(self, status: str) -> None:
        self.status = status
        self._done.set()

    def _reject(self, reason: str, retry_after: Optional[float] = None):
        self.reason = reason
        self.retry_after = retry_after
        self._resolve(REJECTED)
        return self

    def _fail(self, err: BaseException):
        self.error = err
        self._resolve(FAILED)
        return self

    # -- caller side -----------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def _raise_terminal(self):
        if self.status == REJECTED:
            raise RejectedError(self.reason or "rejected", self.retry_after)
        if self.status == FAILED:
            raise RuntimeError("request failed") from self.error


class WalkTicket(_Ticket):
    """Handle for one walk request; ``result()`` blocks for the visits.

    ``coverage``/``down_shards`` describe the serving generation the
    response was computed on: 1.0 and ``()`` for a healthy mesh (or any
    single-device image); < 1.0 names the degraded fraction and the
    quarantined shard ids whose rows read as zero (§17).
    """

    __slots__ = ("seeds", "weights", "visits_row", "steps", "deadline",
                 "attempts", "visits", "latency_s", "coverage",
                 "down_shards")

    def __init__(self, seeds, weights, visits_row, steps, deadline):
        super().__init__()
        self.seeds = seeds
        self.weights = weights
        self.visits_row = visits_row
        self.steps = int(steps)
        self.deadline = deadline
        self.attempts = 0
        self.visits: Optional[np.ndarray] = None
        self.latency_s: Optional[float] = None
        self.coverage: Optional[float] = None
        self.down_shards: tuple = ()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self.wait(timeout):
            raise TimeoutError("walk ticket still pending")
        self._raise_terminal()
        return self.visits


class UpdateTicket(_Ticket):
    """Handle for one update; acked only once a sealed generation holds it."""

    __slots__ = ("plan", "dm")

    def __init__(self, plan):
        super().__init__()
        self.plan = plan
        self.dm: Optional[int] = None

    def result(self, timeout: Optional[float] = None) -> int:
        """Blocks until the update is visible to readers; returns ΔM."""
        if not self.wait(timeout):
            raise TimeoutError("update ticket still pending")
        self._raise_terminal()
        return self.dm


class AdminTicket(_Ticket):
    """Handle for one writer-thread admin op (``run_on_writer``).

    The callable runs on the writer thread — serialized with applies and
    seals, so it may safely mutate the live representation (quarantine,
    ``rebuild_shard`` reintegration, chaos corruption).  It is NOT part
    of the zero-lost walk/update ledgers; ``admin_ops`` counts it.
    """

    __slots__ = ("fn", "reseal", "value")

    def __init__(self, fn, reseal: bool):
        super().__init__()
        self.fn = fn
        self.reseal = bool(reseal)
        self.value = None

    def result(self, timeout: Optional[float] = None):
        if not self.wait(timeout):
            raise TimeoutError("admin ticket still pending")
        self._raise_terminal()
        return self.value


def _fresh_stats() -> dict:
    return {
        # walk-side accounting (the zero-lost ledger)
        "submitted": 0, "served": 0, "shed_expired": 0,
        "rejected_backpressure": 0, "rejected_other": 0, "failed": 0,
        # update side
        "updates_submitted": 0, "updates_applied": 0, "updates_failed": 0,
        "updates_rejected": 0,
        # engine health
        "seals": 0, "seal_failures": 0, "batches": 0, "max_batch": 0,
        "dispatch_retries": 0, "breaker_fallbacks": 0,
        # shard failover (§17)
        "shard_quarantines": 0, "audit_detections": 0,
        "served_degraded": 0, "admin_ops": 0,
    }


class WalkServer:
    """Batched, snapshot-isolated, overload-safe walk service (§16).

    One writer thread owns the live representation; one dispatcher
    thread drains the walk queue into coalesced ``[B, V]`` batched
    dispatches against the last sealed generation.  All tuning knobs
    are constructor arguments so tests can drive every regime:

    ``max_queue``        walk admission bound (backpressure watermark)
    ``batch_max``        max requests coalesced into one dispatch
    ``default_timeout``  per-request deadline when the caller gives none
                         (None = no deadline)
    ``dispatch_retries`` serve-level retries of a failed batch dispatch
    ``retry_backoff``    base seconds of the retry backoff (attempt 1)
    ``retry_max_backoff`` ceiling of the exponential retry backoff
    ``update_queue_max`` update admission bound
    ``seal_group_max``   updates coalesced under one seal
    ``walk_backend``     slot_walk backend request ("auto" → device)
    ``audit_every``      writer rounds between AuditScheduler ticks
                         (0 = no background integrity audits)
    """

    def __init__(
        self,
        rep,
        *,
        max_queue: int = 256,
        batch_max: int = 32,
        default_timeout: Optional[float] = None,
        dispatch_retries: int = 2,
        retry_backoff: float = 0.002,
        retry_max_backoff: float = 0.25,
        update_queue_max: int = 64,
        seal_group_max: int = 8,
        walk_backend: str = "auto",
        audit_every: int = 0,
    ):
        self._rep = rep
        self.max_queue = int(max_queue)
        self.batch_max = int(batch_max)
        self.default_timeout = default_timeout
        self.dispatch_retries = int(dispatch_retries)
        self.retry_backoff = float(retry_backoff)
        self.retry_max_backoff = float(retry_max_backoff)
        self.update_queue_max = int(update_queue_max)
        self.seal_group_max = int(seal_group_max)
        self.walk_backend = walk_backend
        self.audit_every = int(audit_every)

        self._lock = threading.Lock()
        self._walk_cv = threading.Condition(self._lock)
        self._upd_cv = threading.Condition(self._lock)
        self._walk_q: collections.deque = collections.deque()
        self._upd_q: collections.deque = collections.deque()
        self._stats = _fresh_stats()
        self._ema_service_s = 1e-3  # per-request EMA, seeded optimistically
        self._generation: Optional[Generation] = None
        self._gen_counter = 0
        self._seq = 0  # updates applied to the live rep
        self._seal_pending: list = []  # applied updates awaiting a seal ack
        self._closed = False
        self._threads: list[threading.Thread] = []
        # §17 failover control plane (writer-owned except the queues)
        self._admin_q: collections.deque = collections.deque()
        self._pending_quarantine: set = set()
        self._auditor = None  # lazy failover.AuditScheduler
        self._known_down: set = set()
        self._rng = np.random.default_rng(0x5EED)  # retry jitter only

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "WalkServer":
        """Seal generation 0 and start the writer + dispatcher threads."""
        if self._threads:
            raise RuntimeError("server already started")
        self._known_down = set(getattr(self._base_rep(), "down", ()) or ())
        self._seal_locked(initial=True)
        self._closed = False
        for name, fn in (("serve-writer", self._writer_loop),
                         ("serve-dispatch", self._dispatch_loop)):
            t = threading.Thread(target=fn, name=name, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, *, drain: bool = True, timeout: float = 30.0) -> dict:
        """Stop accepting requests; drain (or reject) the queues; join.

        With ``drain=True`` both threads finish everything already
        admitted before exiting — in-flight requests are never dropped.
        Returns the final stats dict.
        """
        with self._lock:
            self._closed = True
            if not drain:
                while self._walk_q:
                    self._resolve_reject(
                        self._walk_q.popleft(), "shutdown", walk=True
                    )
                while self._upd_q:
                    self._resolve_reject(
                        self._upd_q.popleft(), "shutdown", walk=False
                    )
                while self._admin_q:
                    self._admin_q.popleft()._reject("shutdown")
            self._walk_cv.notify_all()
            self._upd_cv.notify_all()
        for t in self._threads:
            t.join(timeout)
        self._threads.clear()
        return self.stats()

    def __enter__(self) -> "WalkServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            out = dict(self._stats)
            out["queue_depth"] = len(self._walk_q)
            out["update_depth"] = len(self._upd_q)
            out["generation"] = (
                self._generation.gen_id if self._generation else -1
            )
            out["ema_service_ms"] = self._ema_service_s * 1e3
            gen = self._generation
            out["coverage"] = (
                float(getattr(gen.image, "coverage", 1.0)) if gen else 1.0
            )
            out["down_shards"] = tuple(
                sorted(getattr(gen.image, "down", ()) or ())
            ) if gen else ()
        return out

    @property
    def generation(self) -> Optional[Generation]:
        return self._generation

    def assert_no_lost(self) -> dict:
        """The zero-lost ledger: submitted == served+shed+rejected+failed.

        Call after ``stop()``; raises AssertionError when any admitted
        request neither resolved nor remains queued (i.e. was silently
        lost).  Returns the stats dict for convenience.
        """
        s = self.stats()
        resolved = (
            s["served"] + s["shed_expired"] + s["rejected_backpressure"]
            + s["rejected_other"] + s["failed"]
        )
        assert resolved == s["submitted"] and s["queue_depth"] == 0, (
            f"lost walk requests: submitted={s['submitted']} "
            f"resolved={resolved} queued={s['queue_depth']}"
        )
        u_resolved = (
            s["updates_applied"] + s["updates_failed"] + s["updates_rejected"]
        )
        assert u_resolved == s["updates_submitted"] and s["update_depth"] == 0, (
            f"lost updates: submitted={s['updates_submitted']} "
            f"resolved={u_resolved} queued={s['update_depth']}"
        )
        return s

    # ------------------------------------------------------------------
    # admission (caller threads)
    # ------------------------------------------------------------------
    def _resolve_reject(self, ticket, reason, *, walk: bool,
                        retry_after=None):
        """Reject + account under self._lock (callers hold it)."""
        key = (
            "rejected_backpressure" if reason == "backpressure"
            else "shed_expired" if reason == "expired"
            else "rejected_other"
        )
        if walk:
            self._stats[key] += 1
        else:
            self._stats["updates_rejected"] += 1
        return ticket._reject(reason, retry_after)

    def submit_walk(
        self,
        seeds=None,
        *,
        weights=None,
        visits0=None,
        steps: int = 4,
        timeout: Optional[float] = None,
    ) -> WalkTicket:
        """Admit one walk request; returns a ticket (maybe pre-rejected).

        ``seeds`` (vertex ids, optionally with per-seed ``weights``) or a
        full ``visits0`` row [nv] define the initial visit vector; the
        dispatcher materializes it against the serving generation's
        vertex count.  ``timeout`` seconds (default: the server's
        ``default_timeout``) bound end-to-end latency — an expired
        request is shed, never walked.  Rejections resolve the ticket
        immediately with ``reason`` and, for backpressure, a
        ``retry_after`` hint; they are never raised here (``result()``
        raises :class:`RejectedError` for the caller that wants one).
        """
        timeout = self.default_timeout if timeout is None else timeout
        now = time.monotonic()
        deadline = None if timeout is None else now + float(timeout)
        t = WalkTicket(seeds, weights, visits0, steps, deadline)
        with self._lock:
            self._stats["submitted"] += 1
            try:
                faultinject.fire("serve.enqueue")
            except Exception as e:  # injected enqueue fault: clean reject
                t.error = e
                return self._resolve_reject(t, "enqueue_fault", walk=True)
            if self._closed:
                return self._resolve_reject(t, "shutdown", walk=True)
            depth = len(self._walk_q)
            if depth >= self.max_queue:
                retry_after = (depth - self.max_queue + 1) * self._ema_service_s
                return self._resolve_reject(
                    t, "backpressure", walk=True, retry_after=retry_after
                )
            if deadline is not None and deadline <= now:
                return self._resolve_reject(t, "expired", walk=True)
            self._walk_q.append(t)
            self._walk_cv.notify()
        return t

    def submit_update(
        self,
        plan: Optional[updates.UpdatePlan] = None,
        *,
        inserts=None,
        deletes=None,
    ) -> UpdateTicket:
        """Admit one update; the ticket acks when a sealed generation
        contains it (readers can see it) — never earlier."""
        if plan is None:
            plan = updates.plan_update(inserts=inserts, deletes=deletes)
        t = UpdateTicket(plan)
        with self._lock:
            self._stats["updates_submitted"] += 1
            try:
                faultinject.fire("serve.enqueue")
            except Exception as e:
                t.error = e
                return self._resolve_reject(t, "enqueue_fault", walk=False)
            if self._closed:
                return self._resolve_reject(t, "shutdown", walk=False)
            if len(self._upd_q) >= self.update_queue_max:
                retry_after = len(self._upd_q) * self._ema_service_s
                return self._resolve_reject(
                    t, "backpressure", walk=False, retry_after=retry_after
                )
            self._upd_q.append(t)
            self._upd_cv.notify()
        return t

    def run_on_writer(self, fn, *, reseal: bool = False) -> AdminTicket:
        """Run ``fn(server)`` on the writer thread; returns an AdminTicket.

        The callable executes serialized with plan applies and seals —
        the only safe place to mutate the live representation from
        outside (quarantine a shard, reintegrate via
        ``DurableGraph.rebuild_shard``, inject chaos).  With
        ``reseal=True`` the writer seals a fresh generation right after,
        so readers observe the admin change on their next dispatch;
        leave it False for mutations that must NOT reach readers until
        an audit passes (e.g. modeled corruption).
        """
        t = AdminTicket(fn, reseal)
        with self._lock:
            if self._closed:
                return t._reject("shutdown")
            self._admin_q.append(t)
            self._upd_cv.notify()
        return t

    def request_quarantine(self, sid: int) -> None:
        """Ask the writer to quarantine shard ``sid`` (idempotent)."""
        with self._lock:
            self._pending_quarantine.add(int(sid))
            self._upd_cv.notify()

    # ------------------------------------------------------------------
    # writer thread: control → apply → audit → seal → ack
    # ------------------------------------------------------------------
    def _base_rep(self):
        """The shard-bearing representation (unwraps DurableGraph.rep)."""
        return getattr(self._rep, "rep", self._rep)

    def _note_quarantines(self) -> bool:
        """Sync ``_known_down`` with the live rep; count new quarantines.

        Returns True when the down-set changed (quarantine OR
        reintegration) — either way the serving generation is stale and
        the writer must reseal.
        """
        down = set(getattr(self._base_rep(), "down", ()) or ())
        if down == self._known_down:
            return False
        new = down - self._known_down
        self._known_down = down
        if new:
            with self._lock:
                self._stats["shard_quarantines"] += len(new)
        return True

    def _drain_control(self) -> bool:
        """Apply queued quarantine requests + admin ops (writer thread).

        Returns True when the serving generation must be resealed.
        """
        with self._lock:
            sids = sorted(self._pending_quarantine)
            self._pending_quarantine.clear()
            admin = list(self._admin_q)
            self._admin_q.clear()
        dirty = False
        base = self._base_rep()
        for sid in sids:
            if hasattr(base, "quarantine") and sid not in getattr(
                base, "down", ()
            ):
                base.quarantine(int(sid))
        if sids:
            dirty |= self._note_quarantines()
        for t in admin:
            try:
                t.value = t.fn(self)
            except Exception as e:
                t._fail(e)
            else:
                with self._lock:
                    self._stats["admin_ops"] += 1
                t._resolve(SERVED)
                dirty |= t.reseal
            dirty |= self._note_quarantines()
        return dirty

    def _audit_tick(self) -> bool:
        """One paced AuditScheduler tick; quarantines on detection.

        Returns True when a shard was quarantined (reseal needed).
        """
        base = self._base_rep()
        if not hasattr(base, "audit_shard"):
            return False
        if self._auditor is None or self._auditor.g is not base:
            from . import failover
            self._auditor = failover.AuditScheduler(base)
        hit = self._auditor.tick()
        if hit is None:
            return False
        sid, _exc = hit
        base.quarantine(int(sid))
        with self._lock:
            self._stats["audit_detections"] += 1
        self._note_quarantines()
        return True
    def _seal_locked(self, *, initial: bool = False) -> bool:
        """Seal a new generation and ack the updates it contains.

        On failure (an injected seal fault, an exhausted fallback chain
        inside the image flush) readers keep the previous generation —
        still consistent — the applied-but-unsealed updates stay queued
        for ack, and the writer retries on its next tick.
        """
        gen_id = self._gen_counter + (0 if initial else 1)
        try:
            faultinject.fire("serve.seal")
            img = walk_image.seal_generation(self._rep, gen_id)
        except Exception:
            self._stats["seal_failures"] += 1
            return False
        self._gen_counter = gen_id
        self._generation = Generation(
            gen_id=gen_id, image=img, seq=self._seq, sealed_at=time.monotonic()
        )
        self._stats["seals"] += 1
        for t in self._seal_pending:
            t.generation = gen_id
            t._resolve(SERVED)
        self._seal_pending.clear()
        return True

    def _writer_loop(self) -> None:
        audit_round = 0
        while True:
            with self._lock:
                while (
                    not self._upd_q and not self._closed
                    and not self._seal_pending and not self._admin_q
                    and not self._pending_quarantine
                ):
                    self._upd_cv.wait(0.05)
                    if self.audit_every:
                        break  # idle tick: keep the audit sweep moving
                if (
                    self._closed and not self._upd_q
                    and not self._seal_pending and not self._admin_q
                ):
                    return
                group = [
                    self._upd_q.popleft()
                    for _ in range(min(len(self._upd_q), self.seal_group_max))
                ]
            dirty = self._drain_control()
            for t in group:
                try:
                    # rep protocol adapter: single-device reps return
                    # (rep, dm); ShardedGraph.apply mutates in place and
                    # returns None (ΔM read off the live edge count).
                    m0 = int(getattr(self._rep, "m", 0))
                    out = self._rep.apply(t.plan)
                    if out is None:
                        dm = int(getattr(self._rep, "m", m0)) - m0
                    else:
                        self._rep, dm = out
                    t.dm = int(dm)
                    self._seq += 1
                    with self._lock:
                        self._stats["updates_applied"] += 1
                        self._seal_pending.append(t)
                except Exception as e:
                    # the plan did not take effect (validation, or an
                    # exhausted fallback chain before any state landed);
                    # the ticket fails VISIBLY and the stream continues.
                    with self._lock:
                        self._stats["updates_failed"] += 1
                    t._fail(e)
            # a sharded apply quarantines faulted shards in place
            # (non-raising, §17) — pick those up and reseal degraded
            dirty |= self._note_quarantines()
            audit_round += 1
            if self.audit_every and audit_round >= self.audit_every:
                audit_round = 0
                dirty |= self._audit_tick()
            if group or self._seal_pending or dirty:
                with self._lock:
                    if not self._seal_locked():
                        # failed seal: retry after a short pause so an
                        # injected multi-shot fault can't spin the CPU
                        pass
                if self._seal_pending:
                    time.sleep(self.retry_backoff)

    # ------------------------------------------------------------------
    # dispatcher thread: coalesce → shed → walk → fulfil
    # ------------------------------------------------------------------
    def _take_batch(self) -> Optional[list]:
        """Pop up to batch_max same-steps requests (head-of-line steps)."""
        with self._lock:
            while not self._walk_q:
                if self._closed:
                    return None
                self._walk_cv.wait(0.05)
            head = self._walk_q.popleft()
            batch = [head]
            kept = collections.deque()
            while self._walk_q and len(batch) < self.batch_max:
                t = self._walk_q.popleft()
                if t.steps == head.steps:
                    batch.append(t)
                else:
                    kept.append(t)
            kept.extend(self._walk_q)
            self._walk_q = kept
        return batch

    def _visits_row(self, t: WalkTicket, nv: int) -> Optional[np.ndarray]:
        """Materialize the request's initial visit vector, or reject."""
        if t.visits_row is not None:
            row = np.asarray(t.visits_row, np.float32).reshape(-1)
            if row.shape[0] != nv:
                with self._lock:
                    self._resolve_reject(t, "shape_mismatch", walk=True)
                return None
            return row
        seeds = np.atleast_1d(np.asarray(t.seeds, np.int64))
        if seeds.size == 0 or seeds.min() < 0 or seeds.max() >= nv:
            with self._lock:
                self._resolve_reject(t, "seed_out_of_range", walk=True)
            return None
        row = np.zeros(nv, np.float32)
        w = (
            np.ones(seeds.shape[0], np.float32)
            if t.weights is None
            else np.asarray(t.weights, np.float32).reshape(-1)
        )
        np.add.at(row, seeds, w)
        return row

    def _dispatch_loop(self) -> None:
        primary = self.walk_backend
        if primary == "auto":
            primary = "pallas" if jax.default_backend() == "tpu" else "xla"
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            now = time.monotonic()
            live: list[WalkTicket] = []
            for t in batch:
                if t.deadline is not None and t.deadline <= now:
                    with self._lock:
                        self._resolve_reject(t, "expired", walk=True)
                else:
                    live.append(t)
            if not live:
                continue
            gen = self._generation
            nv = int(gen.image.nv)
            rows, tickets = [], []
            for t in live:
                row = self._visits_row(t, nv)
                if row is not None:
                    rows.append(row)
                    tickets.append(t)
            if not tickets:
                continue
            b = len(tickets)
            b_pad = max(alloc.next_pow2(b), 4)  # warm [B, V] jit shapes
            v0 = np.zeros((b_pad, nv), np.float32)
            v0[:b] = np.stack(rows)
            t0 = time.monotonic()
            try:
                faultinject.fire("serve.dispatch")
                out = np.asarray(
                    gen.image.walk(
                        int(tickets[0].steps),
                        backend=self.walk_backend,
                        visits0=v0,
                    )
                )
            except Exception as e:
                # a shard-attributed walk fault (§17): ask the writer to
                # quarantine that shard, then retry the batch — against
                # the previous (still clean) generation first, and the
                # degraded reseal once the writer flips it.
                sid = getattr(e, "sid", None)
                if sid is not None:
                    self.request_quarantine(int(sid))
                self._retry_or_fail(tickets, e)
                continue
            dt = time.monotonic() - t0
            cov = float(getattr(gen.image, "coverage", 1.0))
            downs = tuple(sorted(getattr(gen.image, "down", ()) or ()))
            used = _fb.LAST_USED.get("slot_walk")
            with self._lock:
                if used is not None and used != primary:
                    self._stats["breaker_fallbacks"] += 1
                self._stats["batches"] += 1
                self._stats["max_batch"] = max(self._stats["max_batch"], b)
                self._stats["served"] += b
                if cov < 1.0:
                    self._stats["served_degraded"] += b
                self._ema_service_s += 0.2 * (dt / b - self._ema_service_s)
            done = time.monotonic()
            for i, t in enumerate(tickets):
                t.visits = out[i]
                t.generation = gen.gen_id
                t.coverage = cov
                t.down_shards = downs
                t.latency_s = done - t.submitted_at
                t._resolve(SERVED)

    def _retry_sleep_s(self, attempt: int) -> float:
        """Jittered exponential backoff: base·2^(attempt-1), capped, with
        uniform ±50% jitter so retry storms decorrelate."""
        base = min(
            self.retry_backoff * (2.0 ** max(int(attempt) - 1, 0)),
            self.retry_max_backoff,
        )
        return base * float(self._rng.uniform(0.5, 1.5))

    def _retry_or_fail(self, tickets: list, err: Exception) -> None:
        """Bounded retry with backoff; exhausted tickets fail visibly."""
        retry, dead = [], []
        for t in tickets:
            t.attempts += 1
            (retry if t.attempts <= self.dispatch_retries else dead).append(t)
        with self._lock:
            if retry:
                self._stats["dispatch_retries"] += 1
                self._walk_q.extendleft(reversed(retry))
                self._walk_cv.notify()
            for t in dead:
                self._stats["failed"] += 1
                t._fail(err)
        if retry:
            time.sleep(self._retry_sleep_s(max(t.attempts for t in retry)))
