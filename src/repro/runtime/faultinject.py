"""Deterministic fault injection + cross-layer invariant auditor (DESIGN.md §13).

The durability layer (``runtime/durable.py``) and the kernel fallback chain
(``kernels/fallback.py``) are only trustworthy if every failure mode they
claim to survive is actually exercised.  This module provides the four
injection families the recovery protocol is tested against:

* **kernel failure** — ``fire("slot_update.xla")`` etc. raises an
  :class:`InjectedKernelError` at the named dispatch site, driving the
  circuit-breaker chain;
* **process kill** — ``fire("durable.pre_append" | "durable.post_append" |
  "durable.post_apply")`` raises :class:`SimulatedCrash` (a *BaseException*,
  so nothing in the pipeline accidentally swallows it) at the three
  WAL-ordering-critical points of ``DurableGraph.apply``;
* **torn / corrupted WAL segments** — :func:`tear_tail` and
  :func:`corrupt_byte` damage log files the way a crashed writer or bad
  sector would;
* **interrupted checkpoint** — ``fire("checkpoint.pre_rename")`` kills the
  writer between the tmp-dir write and the atomic rename, leaving the
  ``.tmp_ckpt_*`` debris a real crash leaves.

Injection points are *armed* host-side (``arm``/``injected``) and fire
deterministically: ``after`` skips that many hits, ``times`` bounds how many
raise.  :class:`FaultSchedule` derives a seeded (round, point) schedule for
randomized sweeps.  ``fire()`` on an un-armed point is a dict lookup — the
production hot path pays nothing.

**Point-name registry.**  Every ``fire()`` site in the tree, by layer
(default exception class in brackets; ``durable.*``/``checkpoint.*``
default to :class:`SimulatedCrash`, everything else to
:class:`InjectedKernelError`; ``arm(..., exc=...)`` overrides):

=========================  ==================================================
point                      fires
=========================  ==================================================
``slot_update.pallas``     before each fused-apply attempt on that backend
``slot_update.xla``        (kernels/fallback.run_chain, operands untouched)
``slot_update.ref``
``slot_walk.pallas``       before each walk-kernel attempt on that backend
``slot_walk.xla``
``slot_walk.ref``
``durable.pre_append``     DurableGraph.apply, before the WAL append [crash]
``durable.post_append``    after the WAL append, before the device apply
``durable.post_apply``     after the device apply, before the ack
``checkpoint.pre_rename``  between tmp-dir write and atomic rename [crash]
``serve.enqueue``          WalkServer admission, inside the queue lock —
                           the request must resolve as a clean rejection
``serve.seal``             writer thread, before sealing a generation —
                           readers must keep the previous sealed image
``serve.dispatch``         dispatcher, before a batched walk — the batch
                           must be retried or failed, never dropped
``shard.walk``             ShardedGraph.reverse_walk, once per healthy
                           shard — surfaces as ShardFaultError(sid) so
                           the serving layer quarantines that shard
``shard.patch``            ShardedGraph.apply, before one shard's fused
                           patch — the shard quarantines, its sub
                           spools, the REST of the mesh still patches
``shard.corrupt``          after one shard's successful patch — silently
                           flips a live weight (no exception escapes);
                           only the §17 integrity pass can detect it
``wal.write``              UpdateJournal._write_flush, before the
                           segment write — surfaces as WalDiskFullError
                           with the segment truncated back intact
=========================  ==================================================

Tests arm points through :func:`arm`/:func:`injected`; the autouse
``_faultinject_leak_guard`` fixture in ``tests/conftest.py`` fails any
test that leaks an armed point past its own teardown (a leaked point
would fire inside an unrelated test and misattribute the failure).

:func:`audit` is the post-recovery invariant pass: CSR well-formedness,
WalkImage block-geometry/content integrity (``WalkImage.audit``), and
CSR↔image cross-consistency, for any of the five representations.

No ``repro.core`` imports — the kernel packages import this module, and
core imports the kernel packages; keeping this module core-free breaks the
cycle.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

import numpy as np


class SimulatedCrash(BaseException):
    """Process-kill stand-in.  BaseException: only the test harness (or a
    deliberate ``except BaseException``) may catch it — ordinary
    ``except Exception`` recovery/fallback code must let it fly, exactly
    like a real SIGKILL."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at {point}")
        self.point = point


class InjectedKernelError(RuntimeError):
    """Stand-in for a kernel-level failure (miscompile, device OOM)."""

    def __init__(self, point: str):
        super().__init__(f"injected kernel failure at {point}")
        self.point = point


class AuditError(RuntimeError):
    """An invariant audit found cross-layer inconsistency."""


# point -> {"after": int, "times": int, "seen": int, "fired": int, "exc": type}
_ARMED: dict = {}


def arm(point: str, *, after: int = 0, times: int = 1, exc=None) -> None:
    """Arm ``point``: the next ``fire(point)`` calls skip ``after`` hits,
    then raise ``exc(point)`` on the following ``times`` hits."""
    if exc is None:
        exc = SimulatedCrash if point.startswith(("durable.", "checkpoint.")) else InjectedKernelError
    _ARMED[point] = {"after": int(after), "times": int(times), "seen": 0, "fired": 0, "exc": exc}


def disarm(point: Optional[str] = None) -> None:
    """Disarm one point, or everything when ``point`` is None."""
    if point is None:
        _ARMED.clear()
    else:
        _ARMED.pop(point, None)


def fired(point: str) -> int:
    """How many times ``point`` has actually raised since it was armed."""
    st = _ARMED.get(point)
    return 0 if st is None else st["fired"]


def armed() -> tuple:
    """Names of all currently armed points (leak-guard introspection)."""
    return tuple(sorted(_ARMED))


def fire(point: str) -> None:
    """Hit an injection point.  No-op unless armed (production cost: one
    falsy-dict check)."""
    if not _ARMED:
        return
    st = _ARMED.get(point)
    if st is None:
        return
    st["seen"] += 1
    if st["seen"] <= st["after"] or st["fired"] >= st["times"]:
        return
    st["fired"] += 1
    raise st["exc"](point)


@contextlib.contextmanager
def injected(point: str, *, after: int = 0, times: int = 1, exc=None):
    """Scoped ``arm``; always disarms the point on exit."""
    arm(point, after=after, times=times, exc=exc)
    try:
        yield
    finally:
        disarm(point)


class FaultSchedule:
    """Seeded (round, point) schedule for randomized crash sweeps.

    ``plan(n_rounds)`` picks one injection point and the round it fires in,
    deterministically from the seed — hypothesis/parametrized sweeps share
    one code path and every failure reproduces from its seed alone.
    """

    def __init__(self, seed: int, points: tuple):
        self.seed = int(seed)
        self.points = tuple(points)
        self._rng = np.random.default_rng(self.seed)

    def plan(self, n_rounds: int) -> tuple:
        """Returns (round_index, point) with round_index in [0, n_rounds)."""
        rnd = int(self._rng.integers(0, max(n_rounds, 1)))
        point = self.points[int(self._rng.integers(0, len(self.points)))]
        return rnd, point


# -- file damage helpers (WAL / checkpoint corruption) ----------------------


def tear_tail(path: str, nbytes: int) -> int:
    """Truncate the final ``nbytes`` of ``path`` (a torn write at the tail:
    the crash happened mid-record).  Returns the new size."""
    size = os.path.getsize(path)
    new = max(size - int(nbytes), 0)
    os.truncate(path, new)
    return new


def corrupt_byte(path: str, offset: int) -> None:
    """Flip one byte of ``path`` in place (bit rot / bad sector: the record
    is complete but its checksum no longer matches)."""
    with open(path, "r+b") as f:
        f.seek(int(offset))
        b = f.read(1)
        if not b:
            raise ValueError(f"offset {offset} beyond end of {path}")
        f.seek(int(offset))
        f.write(bytes([b[0] ^ 0xFF]))


# -- invariant auditor ------------------------------------------------------


def _check(cond, msg: str):
    if not cond:
        raise AuditError(msg)


def audit(rep) -> dict:
    """Cross-consistency audit of a live representation (post-recovery gate).

    Verifies, for any of the five representations:

    1. the canonical CSR is well-formed — monotone offsets, per-row strictly
       ascending in-range destinations, finite weights, edge count agreeing
       with ``rep.m``;
    2. the representation's WalkImage passes its own geometry/content audit
       (:meth:`WalkImage.audit` — blocks inside the bump frontier, disjoint,
       live prefixes owned and sorted, SENTINEL slack);
    3. CSR ↔ image cross-consistency — the image's live payload, gathered in
       row order, is exactly the CSR's dst/wgt streams.

    Sharded graphs (anything exposing per-shard ``shards`` plus its own
    ``audit``, i.e. ``ShardedGraph`` — duck-typed so this module stays
    core-import-free) delegate to their own per-shard + cross-boundary
    audit pass (DESIGN.md §14), which is the §15 recovery gate.

    Raises :class:`AuditError` on the first violation; returns summary stats.
    """
    if hasattr(rep, "shards") and hasattr(rep, "audit"):
        try:
            return rep.audit()
        except ValueError as e:
            raise AuditError(str(e)) from e
    c = rep.to_csr()
    off = np.asarray(c.offsets).astype(np.int64)
    nv, m = int(c.n), int(c.m)
    d = np.asarray(c.dst)[:m]
    w = np.asarray(c.wgt)[:m] if c.wgt is not None else np.ones(m, np.float32)

    _check(off.shape[0] == nv + 1, f"csr offsets length {off.shape[0]} != n+1 ({nv + 1})")
    _check(int(off[0]) == 0, "csr offsets[0] != 0")
    _check(bool((np.diff(off) >= 0).all()), "csr offsets not monotone")
    _check(int(off[-1]) == m, f"csr offsets[-1] {int(off[-1])} != m {m}")
    _check(int(rep.m) == m, f"rep.m {int(rep.m)} != csr.m {m}")
    if m:
        _check(bool((d >= 0).all()) and bool((d < nv).all()), "csr dst id out of [0, n)")
        _check(bool(np.isfinite(w).all()), "non-finite csr weight")
        row_of = np.repeat(np.arange(nv, dtype=np.int64), np.diff(off))
        interior = row_of[1:] == row_of[:-1]
        _check(
            not bool((interior & (d[1:] <= d[:-1])).any()),
            "csr row not strictly ascending",
        )

    img = rep.to_walk_image()
    stats = img.audit()
    _check(int(img.nv) == nv, f"image nv {int(img.nv)} != csr n {nv}")
    _check(int(img.live) == m, f"image live {int(img.live)} != csr m {m}")
    if m:
        starts = np.asarray(img.starts[:nv], np.int64)
        degs = np.asarray(img.degs[:nv], np.int64)
        _check(bool((degs == np.diff(off)).all()), "image degrees != csr degrees")
        first = np.cumsum(degs) - degs
        gidx = np.repeat(starts, degs) + (np.arange(m) - np.repeat(first, degs))
        _check(
            bool((np.asarray(img.dst)[gidx] == d).all()),
            "image dst payload != csr dst",
        )
        _check(
            bool((np.asarray(img.wgt)[gidx] == w).all()),
            "image wgt payload != csr wgt",
        )
    return {"n": nv, "m": m, **stats}
