"""Gradient compression for the slow (pod) axis all-reduce.

Two standard schemes with error feedback:
  * int8 quantization (per-tensor scale): 4× fewer bytes on the wire,
  * top-k sparsification: k largest |g| entries, rest fed back next step.

Error feedback keeps both unbiased-in-the-limit (Karimireddy et al. 2019).
The compress hook plugs into train.loop.make_train_step(compress_fn=...);
on a multi-pod mesh it wraps the pod-axis psum inside shard_map.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def int8_compress(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def make_int8_ef_compressor():
    """Stateful int8 compressor with error feedback (host-carried state)."""
    state = {"residual": None}

    def compress(grads):
        res = state["residual"]
        if res is None:
            res = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = int8_compress(x)
            deq = int8_decompress(q, s)
            return deq, x - deq

        pairs = jax.tree.map(one, grads, res)
        out = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        state["residual"] = jax.tree.map(
            lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        return out

    return compress


def topk_compress(g: jnp.ndarray, frac: float = 0.01):
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    out = jnp.zeros_like(flat).at[idx].set(kept)
    return out.reshape(g.shape), (g.astype(jnp.float32) - out.reshape(g.shape))


def make_topk_ef_compressor(frac: float = 0.01):
    state = {"residual": None}

    def compress(grads):
        res = state["residual"]
        if res is None:
            res = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

        def one(g, r):
            return topk_compress(g.astype(jnp.float32) + r, frac)

        pairs = jax.tree.map(one, grads, res)
        out = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        state["residual"] = jax.tree.map(
            lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        return out

    return compress


def compressed_psum_bytes(n_params: int, scheme: str = "int8", frac: float = 0.01) -> int:
    """Wire bytes per pod-axis all-reduce — feeds the roofline collective
    term for the compressed variant (§Perf)."""
    if scheme == "int8":
        return n_params * 1 + 4
    if scheme == "topk":
        return int(n_params * frac) * 8  # value + index
    return n_params * 4
