"""Shard-failover helpers: incremental audit scheduling + corruption
modeling (DESIGN.md §17).

Detection of a *silently* damaged shard (bit rot, a misbehaving device
writing garbage — no exception anywhere) cannot ride on the fused patch
path: nothing fails.  Instead the serving writer runs an
:class:`AuditScheduler` between rounds — ONE healthy shard per tick,
round-robin, so a full mesh sweep costs ``n_shards`` idle ticks and the
steady-state stream never stalls behind a monolithic audit.  A tick that
trips (``ShardedGraph.audit_shard``: structural audit, stray-row pass,
CRC descriptor verify when ``enable_integrity()`` is on) hands the
failed shard id back for quarantine.

:func:`corrupt_shard` is the fault model itself — the damage
``shard.corrupt`` injection and the chaos harness inflict: flip one live
slot in place, exactly the way a bad DIMM or a mis-targeted DMA would,
with no exception raised and sealed generations (which hold the
pre-damage buffers — jax arrays are immutable, corruption *replaces*
the live reference) unaffected.
"""
from __future__ import annotations

import numpy as np

from ..core import util

SENTINEL = util.SENTINEL


class AuditScheduler:
    """Round-robin one-shard-per-tick audit over a ShardedGraph (§17).

    ``tick()`` audits the next healthy shard; returns ``None`` when it
    passes (or no shard is auditable) and ``(sid, exc)`` on a violation
    — the caller quarantines.  Down shards are skipped, so a degraded
    mesh keeps sweeping its healthy part.
    """

    def __init__(self, g):
        self.g = g
        self._cursor = 0
        self.ticks = 0
        self.detections: list = []

    def tick(self):
        g = self.g
        sid = None
        for k in range(g.n_shards):
            cand = (self._cursor + k) % g.n_shards
            if cand not in g.down:
                sid = cand
                break
        if sid is None:
            return None
        self._cursor = (sid + 1) % g.n_shards
        self.ticks += 1
        try:
            g.audit_shard(sid)
        except Exception as e:
            self.detections.append((sid, e))
            return sid, e
        return None


def corrupt_shard(g, sid: int, *, kind: str = "wgt"):
    """Silently damage one live slot of shard ``sid`` in place.

    * ``kind="wgt"`` perturbs a live weight — structurally valid, so
      ONLY the CRC integrity descriptor can catch it;
    * ``kind="dst"`` stamps SENTINEL into a live destination slot — a
      structural violation the plain ``WalkImage.audit`` content sweep
      trips on even with integrity tracking off.

    Returns the damaged slot index, or ``None`` when the shard holds no
    live edges (nothing to damage).  Never raises into the update path —
    that is the point: detection must come from the audit side.
    """
    sid = int(sid)
    img = g.shards[sid]
    lo_v, hi_v = g.owned_range(sid)
    degs = np.asarray(img.degs[lo_v:hi_v], np.int64)
    rows = np.nonzero(degs > 0)[0]
    if rows.size == 0:
        return None
    row = int(rows[-1]) + lo_v
    slot = int(np.asarray(img.starts[row]))
    if kind == "wgt":
        img.wgt = img.wgt.at[slot].add(0.5)
    elif kind == "dst":
        img.dst = img.dst.at[slot].set(SENTINEL)
    else:
        raise ValueError(f"corrupt_shard: unknown kind {kind!r}")
    g._placed = None
    return slot
