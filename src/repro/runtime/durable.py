"""Durable self-healing update pipeline (DESIGN.md §13).

Two halves turn any of the five representations into a crash-recoverable
graph:

* :class:`UpdateJournal` — a write-ahead log of canonical ``UpdatePlan``
  op streams.  Every ``DurableGraph.apply`` appends ONE compact binary
  record (the four canonical arrays + a monotone sequence number + a
  CRC32) *before* the fused dispatch runs, so any applied update is
  reconstructible from disk.  Records pack into size-rotated segment
  files; replay tolerates a torn final record (the crash happened
  mid-append) and refuses everything else (bit rot, mid-log tears).

* :class:`DurableGraph` — wraps a representation with the WAL, periodic
  checkpoints of its full canonical state (``state_tree()`` through
  ``checkpoint.manager.save_arrays``), and :func:`DurableGraph.recover`:
  newest complete checkpoint + WAL replay through the SAME ``apply``
  path the live process used.  Checkpoints capture exact buffers (arena
  geometry included), and every apply is deterministic given its plan,
  so a recovered graph is **bit-identical** to the uncrashed one — not
  merely equivalent.

Failure model: process crash (SIGKILL, OOM-kill) at any instant.  A
record is durable once ``flush()`` hands it to the OS — fsync per append
is available (``fsync=True``) for the power-loss model but off by
default, matching the paper-bench requirement that journaling stay off
the update critical path.  Replay is at-least-once: a crash between the
WAL append and the in-memory apply re-applies the record's plan on
recovery, which is safe because the op stream is idempotent (inserts are
upserts at fixed weights, deletes of absent keys filter out).

Crash points, torn-tail repair, and the post-recovery invariant sweep
are exercised through ``runtime/faultinject.py``.

Sharded-scale recovery (DESIGN.md §15) extends both halves:

* **group commit** — :meth:`UpdateJournal.append_group` encodes a
  round's plans into ONE buffer with a single ``flush()`` (and a single
  ``fsync`` under the power-loss model), so journal cost amortizes over
  the round instead of per plan.  A crash mid-group tears a byte suffix;
  ``repair_tail`` truncates to the last complete record boundary and the
  un-acked suffix replays as absent — the same prefix-durability
  contract as a single torn append.
* **owner-routed parallel replay** — a :class:`DurableGraph` wrapping a
  ``ShardedGraph`` partitions replayed records by shard owner through
  the SAME ``route_updates`` searchsorted the live path uses and drains
  each shard's queue on its own thread (each through the shard's
  committed-device fused ``slot_update`` patch path).  Growth records
  fence the fan-out into epochs; the per-shard + cross-boundary
  ``audit()`` gates the result.
* **differential checkpoints** — with ``diff=True`` the wrapper tracks
  the WAL window's dirty blocks (plan rows + image block geometry) and
  persists only those chunks via ``checkpoint.manager.save_arrays_diff``,
  with a full compaction checkpoint every ``full_every`` snapshots.
"""
from __future__ import annotations

import errno
import os
import struct
import zlib
from typing import Iterator, Optional

import numpy as np

from ..checkpoint import manager as ckpt
from ..core import REPRESENTATIONS, updates
from . import faultinject

#: Record header: magic "WAL1", sequence number, vertex watermark, op
#: count, CRC32 of the payload bytes.  Little-endian, 24 bytes.
_HEADER = struct.Struct("<IQIII")
_MAGIC = 0x314C4157  # b"WAL1"
#: An n_ops beyond this is implausible for any batch this repo builds —
#: treat it as corruption instead of attempting a huge read.
_MAX_OPS = 1 << 26
#: Bytes per op in the payload: src i4 + dst i4 + wgt f4 (+ packed del bits).
_OP_BYTES = 12


class WalCorruptError(RuntimeError):
    """The journal is damaged beyond the benign torn-tail case."""


class WalDiskFullError(RuntimeError):
    """A segment write failed mid-append (ENOSPC, short write, I/O error).

    The failed append was rolled back — the segment is truncated to its
    last pre-append boundary, so every previously acknowledged record is
    intact and the SAME append may be retried once space returns.  The
    in-memory graph was never touched (WAL-first ordering: the apply
    only runs after the append succeeds)."""


def _payload_size(n_ops: int) -> int:
    return n_ops * _OP_BYTES + (n_ops + 7) // 8


def encode_record(seq: int, nv_bound: int, plan: updates.UpdatePlan) -> bytes:
    """One WAL record: header + packed canonical op stream."""
    n = plan.n_ops
    payload = b"".join(
        (
            np.ascontiguousarray(plan.q_src, np.int32).tobytes(),
            np.ascontiguousarray(plan.q_dst, np.int32).tobytes(),
            np.ascontiguousarray(plan.q_wgt, np.float32).tobytes(),
            np.packbits(plan.q_del.astype(bool)).tobytes(),
        )
    )
    head = _HEADER.pack(_MAGIC, seq, int(nv_bound), n, zlib.crc32(payload))
    return head + payload


def decode_record(head: bytes, payload: bytes):
    """Inverse of :func:`encode_record`; raises :class:`WalCorruptError`."""
    magic, seq, nv_bound, n, crc = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise WalCorruptError(f"bad record magic {magic:#x}")
    if n > _MAX_OPS:
        raise WalCorruptError(f"implausible record size: {n} ops")
    if len(payload) != _payload_size(n):
        raise WalCorruptError("record payload size disagrees with header")
    if zlib.crc32(payload) != crc:
        raise WalCorruptError(f"record {seq}: payload CRC mismatch")
    q_src = np.frombuffer(payload[: 4 * n], np.int32)
    q_dst = np.frombuffer(payload[4 * n : 8 * n], np.int32)
    q_wgt = np.frombuffer(payload[8 * n : 12 * n], np.float32)
    q_del = np.unpackbits(
        np.frombuffer(payload[12 * n :], np.uint8), count=n
    ).astype(bool)
    return seq, nv_bound, (q_src, q_dst, q_wgt, q_del)


class UpdateJournal:
    """Segment-rotated write-ahead log of UpdatePlan records.

    Segments are ``wal-{first_seq:012d}.seg`` — the name carries the
    first sequence number the segment holds, so truncation after a
    checkpoint is pure filename arithmetic.  ``repair=True`` (the
    recovery path) truncates a torn record off the FINAL segment's tail;
    a torn record anywhere else, or a complete record that fails its
    CRC, is real corruption and raises :class:`WalCorruptError`.
    """

    def __init__(
        self,
        wal_dir: str,
        *,
        segment_bytes: int = 1 << 20,
        fsync: bool = False,
        repair: bool = False,
    ):
        self.wal_dir = wal_dir
        self.segment_bytes = int(segment_bytes)
        self.fsync = bool(fsync)
        os.makedirs(wal_dir, exist_ok=True)
        self._fh = None
        self._cur_path: Optional[str] = None
        #: write()+flush() syscall rounds — the group-commit proof field
        self.flushes = 0
        if repair:
            self.repair_tail()
        self.next_seq = self._scan_next_seq()

    # -- segment bookkeeping -------------------------------------------
    def segments(self) -> list[str]:
        names = sorted(
            n for n in os.listdir(self.wal_dir)
            if n.startswith("wal-") and n.endswith(".seg")
        )
        return [os.path.join(self.wal_dir, n) for n in names]

    @staticmethod
    def _first_seq(path: str) -> int:
        return int(os.path.basename(path)[4:-4])

    def _scan_next_seq(self) -> int:
        """Next sequence number — learned from the FINAL segment only.

        Segment filenames carry their first record's sequence number, so
        the scan anchors at ``first_seq - 1`` and walks one segment's
        records forward (the seed decoded the ENTIRE log on every open —
        O(history) for a number the last few hundred KiB determine).  A
        torn tail just stops the walk; bad magic mid-segment is real
        corruption and raises.
        """
        segs = self.segments()
        if not segs:
            return 1
        path = segs[-1]
        last = self._first_seq(path) - 1
        with open(path, "rb") as f:
            data = f.read()
        pos, size = 0, len(data)
        while pos < size:
            head = data[pos : pos + _HEADER.size]
            if len(head) < _HEADER.size:
                break  # torn header at the tail
            magic, seq, _nv, n, _crc = _HEADER.unpack(head)
            if magic != _MAGIC or n > _MAX_OPS:
                raise WalCorruptError(f"{path}: bad record at offset {pos}")
            if pos + _HEADER.size + _payload_size(n) > size:
                break  # torn payload at the tail
            last = seq
            pos += _HEADER.size + _payload_size(n)
        return last + 1

    # -- append side ----------------------------------------------------
    def _open_segment(self, first_seq: int) -> None:
        self._close_fh()
        self._cur_path = os.path.join(
            self.wal_dir, f"wal-{first_seq:012d}.seg"
        )
        self._fh = open(self._cur_path, "ab")
        if self.fsync:
            # power-loss model: file durability alone does not make the
            # new NAME durable — fsync the directory after rotation
            dfd = os.open(self.wal_dir, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)

    def _close_fh(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def _segment_for(self, seq: int) -> None:
        """Position the append handle, rotating BEFORE the write — a
        record (or group) never splits across segments."""
        if self._fh is None:
            segs = self.segments()
            if segs and os.path.getsize(segs[-1]) < self.segment_bytes:
                self._cur_path = segs[-1]
                self._fh = open(self._cur_path, "ab")
            else:
                self._open_segment(seq)
        elif self._fh.tell() >= self.segment_bytes:
            self._open_segment(seq)

    def _write_flush(self, buf: bytes) -> None:
        """Write + flush one append, or roll the segment back untouched.

        A failed or short write (disk full, I/O error, the ``wal.write``
        injection point) must not leave a half-record at the tail: the
        handle is closed, the file truncated to the pre-append boundary,
        and a fresh append handle opened — then :class:`WalDiskFullError`
        tells the caller the append is retryable.  ``next_seq`` only
        advances in the caller after this returns, so a retry reuses the
        same sequence numbers.
        """
        size0 = self._fh.tell()
        try:
            faultinject.fire("wal.write")
            wrote = self._fh.write(buf)
            if wrote != len(buf):
                raise OSError(errno.ENOSPC, f"short write: {wrote}/{len(buf)}")
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
        except (OSError, faultinject.InjectedKernelError) as e:
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = None
            try:
                os.truncate(self._cur_path, size0)
            except OSError:
                pass
            self._fh = open(self._cur_path, "ab")
            raise WalDiskFullError(
                f"{self._cur_path}: segment write failed at offset {size0} "
                f"({e}) — segment rolled back, append retryable"
            ) from e
        self.flushes += 1

    def append(self, plan: updates.UpdatePlan, nv_bound: int) -> int:
        """Write one record; durable (to the OS) before this returns."""
        seq = self.next_seq
        self._segment_for(seq)
        self._write_flush(encode_record(seq, nv_bound, plan))
        self.next_seq = seq + 1
        return seq

    def append_group(self, plans, nv_bounds) -> list[int]:
        """Group commit: a round's plans in ONE buffer, ONE flush/fsync.

        Records keep their individual seq/CRC framing (replay and repair
        are unchanged), only the syscall cost amortizes.  The group lands
        in a single segment — rotation happens before the write, never
        inside it — so a crash tears at most the group's byte suffix,
        which ``repair_tail`` truncates back to the last complete record
        boundary: the surviving prefix was durable, the lost suffix was
        never acknowledged.
        """
        if len(plans) != len(nv_bounds):
            raise ValueError("append_group: plans/nv_bounds length mismatch")
        if not plans:
            return []
        seq0 = self.next_seq
        seqs = list(range(seq0, seq0 + len(plans)))
        self._segment_for(seq0)
        buf = b"".join(
            encode_record(s, nv, p)
            for s, nv, p in zip(seqs, nv_bounds, plans)
        )
        self._write_flush(buf)
        self.next_seq = seq0 + len(plans)
        return seqs

    # -- read side ------------------------------------------------------
    def replay(self, after: int = 0) -> Iterator[tuple]:
        """Yield ``(seq, nv_bound, (q_src, q_dst, q_wgt, q_del))`` in order.

        Sequence numbers must be strictly increasing across the whole
        log; an incomplete record is tolerated only at the very tail of
        the FINAL segment (the append the crash interrupted) — replay
        stops there.  Anything else raises :class:`WalCorruptError`.
        """
        segs = self.segments()
        last_seq = None
        for si, path in enumerate(segs):
            final_seg = si == len(segs) - 1
            with open(path, "rb") as f:
                data = f.read()
            pos, size = 0, len(data)
            while pos < size:
                head = data[pos : pos + _HEADER.size]
                if len(head) < _HEADER.size:
                    if final_seg:
                        return  # torn header at the log tail
                    raise WalCorruptError(f"{path}: torn record mid-log")
                magic, seq, nv_bound, n, _crc = _HEADER.unpack(head)
                if magic != _MAGIC or n > _MAX_OPS:
                    raise WalCorruptError(f"{path}: bad record at offset {pos}")
                body = data[pos + _HEADER.size : pos + _HEADER.size + _payload_size(n)]
                if len(body) < _payload_size(n):
                    if final_seg:
                        return  # torn payload at the log tail
                    raise WalCorruptError(f"{path}: torn record mid-log")
                seq, nv_bound, arrs = decode_record(head, body)
                if last_seq is not None and seq <= last_seq:
                    raise WalCorruptError(
                        f"{path}: sequence {seq} not after {last_seq}"
                    )
                last_seq = seq
                pos += _HEADER.size + _payload_size(n)
                if seq > after:
                    yield seq, nv_bound, arrs

    def repair_tail(self) -> int:
        """Truncate a torn record off the final segment; returns bytes cut.

        Walks complete records to find the last clean boundary, checking
        CRCs along the way — a complete-but-corrupt record is NOT
        repairable and raises (truncating it would silently lose an
        acknowledged update and every record after it).
        """
        segs = self.segments()
        if not segs:
            return 0
        path = segs[-1]
        with open(path, "rb") as f:
            data = f.read()
        pos, size = 0, len(data)
        while pos < size:
            head = data[pos : pos + _HEADER.size]
            if len(head) < _HEADER.size:
                break
            magic, _seq, _nv, n, _crc = _HEADER.unpack(head)
            if magic != _MAGIC or n > _MAX_OPS:
                raise WalCorruptError(f"{path}: bad record at offset {pos}")
            body = data[pos + _HEADER.size : pos + _HEADER.size + _payload_size(n)]
            if len(body) < _payload_size(n):
                break
            decode_record(head, body)  # CRC check; raises on rot
            pos += _HEADER.size + _payload_size(n)
        cut = size - pos
        if cut:
            os.truncate(path, pos)
        return cut

    def truncate_through(self, seq: int) -> int:
        """Drop segments made redundant by a checkpoint at ``seq``.

        A segment is deletable when its SUCCESSOR's first record is
        already covered (first_seq − 1 <= seq): everything the segment
        holds replays to state the checkpoint captured.  The last
        segment always survives — it is the append target.
        """
        segs = self.segments()
        removed = 0
        for i in range(len(segs) - 1):
            if self._first_seq(segs[i + 1]) - 1 <= seq:
                os.remove(segs[i])
                removed += 1
        return removed

    def close(self) -> None:
        self._close_fh()


class _ShardDirty:
    """Per-shard dirty-block accumulator for differential checkpoints."""

    __slots__ = ("full", "touched", "rows", "ranges")

    def __init__(self):
        self.full = False     # whole shard dirty (rebuild / tracker overflow)
        self.touched = False
        self.rows = []        # np arrays of touched row ids
        self.ranges = []      # np [K, 2] half-open slot-element ranges


#: Beyond this many tracked rows a shard's accumulator degrades to
#: "full" — the diff would approach full size anyway and the tracking
#: lists must not grow with the WAL window unbounded.
_DIRTY_CAP = 1 << 16


class DurableGraph:
    """A representation wrapped in WAL-first apply + checkpoint/restore.

    Ordering contract (the injection points bracket it):

        validate → WAL append → fused apply → watermark advance

    so every state the in-memory graph can reach is reconstructible as
    ``checkpoint ⊕ WAL[seq+1:]``.  ``checkpoint_every=k`` snapshots the
    full canonical state every k applies (k=0: manual only); the
    constructor writes a step-0 checkpoint so recovery always has a
    base.

    ``rep`` may be any of the five registered single-device
    representations OR a ``ShardedGraph`` (§14) — the wrapper detects
    which and routes applies, checkpoints, and recovery accordingly.
    ``diff=True`` switches periodic checkpoints to §15 differential
    steps (every ``full_every``-th snapshot is a full compaction point
    that re-anchors the chain).
    """

    def __init__(
        self,
        rep,
        wal_dir: str,
        ckpt_dir: str,
        *,
        checkpoint_every: int = 0,
        keep: int = 3,
        fsync: bool = False,
        segment_bytes: int = 1 << 20,
        diff: bool = False,
        full_every: int = 8,
        _recovering: bool = False,
    ):
        from ..core import distributed as dist  # lazy: single-device users
                                                # never pay the mesh import
        self.rep = rep
        self.wal_dir = wal_dir
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = int(checkpoint_every)
        self.keep = int(keep)
        self.diff = bool(diff)
        self.full_every = max(int(full_every), 1)
        self._sharded = isinstance(rep, dist.ShardedGraph)
        self._ckpts_since_full = 0
        self._dirty: dict = {}
        # replay applies are not dirty-tracked → first post-recovery
        # checkpoint must be a full one
        self._force_full = bool(_recovering)
        self.journal = UpdateJournal(
            wal_dir, segment_bytes=segment_bytes, fsync=fsync,
            repair=_recovering,
        )
        self.seq = self.journal.next_seq - 1
        self._applies_since_ckpt = 0
        self._nv_bound = max(int(rep.n), 1)
        if not _recovering and ckpt.latest_step(ckpt_dir) is None:
            self.checkpoint()

    @property
    def rep_name(self) -> str:
        if self._sharded:
            return "sharded"
        cls = type(self.rep)
        for name, c in REPRESENTATIONS.items():
            if c is cls:
                return name
        raise TypeError(f"unregistered representation {cls.__name__}")

    # -- dirty-block tracking (differential checkpoints, §15) ----------
    def _dirty_pre(self, plan):
        """Snapshot the block geometry a plan is about to disturb."""
        from ..core import distributed as dist

        rep = self.rep
        per = []
        for sid, sub in dist.route_updates(plan, rep.n_shards, rep.rows_max):
            img = rep.shards[sid]
            rows = sub.touched_rows(rep.v_pad)
            per.append((sid, rows, img.block_ranges(rows), img.bump))
        return rep.generation, per

    def _dirty_post(self, pre) -> None:
        gen0, per = pre
        rep = self.rep
        if rep.generation != gen0:
            # a rebuild re-sharded every image: whole-mesh dirty
            for sid in range(rep.n_shards):
                d = self._dirty.setdefault(sid, _ShardDirty())
                d.full = d.touched = True
                d.rows, d.ranges = [], []
            return
        for sid, rows, old_ranges, bump0 in per:
            img = rep.shards[sid]
            d = self._dirty.setdefault(sid, _ShardDirty())
            d.touched = True
            if d.full:
                continue
            d.rows.append(rows)
            d.ranges.append(old_ranges)            # vacated slots → SENTINEL
            d.ranges.append(img.block_ranges(rows))  # current extents
            if img.bump > bump0:                   # freshly bumped blocks
                d.ranges.append(np.array([[bump0, img.bump]], np.int64))
            if sum(r.shape[0] for r in d.rows) > _DIRTY_CAP:
                d.full, d.rows, d.ranges = True, [], []

    def _export_dirty(self) -> dict:
        """The {shard: hint} dirty-block set ``save_arrays_diff`` consumes."""
        meta_full = {
            "__meta__/rep": "full", "__meta__/wal_seq": "full",
            "__meta__/nv_bound": "full",
        }
        out = {}
        for sid in range(self.rep.n_shards):
            d = self._dirty.get(sid)
            if d is None or not d.touched:
                if sid == 0:
                    # shard 0 carries the wrapper meta, which always moves
                    hint = {k: "clean" for k in
                            ("dst", "wgt", "rows", "starts", "caps", "degs")}
                    hint["meta"] = "clean"
                    hint.update(meta_full)
                    out[sid] = hint
                else:
                    out[sid] = "clean"
                continue
            if d.full:
                out[sid] = "full"
                continue
            rows = (
                np.unique(np.concatenate(d.rows)).astype(np.int64)
                if d.rows else np.empty(0, np.int64)
            )
            row_ranges = np.stack([rows, rows + 1], axis=1)
            slot_ranges = (
                np.concatenate([np.asarray(r).reshape(-1, 2) for r in d.ranges])
                if d.ranges else np.empty((0, 2), np.int64)
            )
            hint = {
                "dst": slot_ranges, "wgt": slot_ranges, "rows": slot_ranges,
                "starts": row_ranges, "caps": row_ranges, "degs": row_ranges,
                "meta": "full",  # nv/bump/live counters, a few ints
            }
            if sid == 0:
                hint.update(meta_full)
            out[sid] = hint
        return out

    def _reset_dirty(self) -> None:
        self._dirty = {}
        self._force_full = False

    # -- the durable apply path ----------------------------------------
    def _rep_apply(self, plan: updates.UpdatePlan) -> int:
        """Dispatch one validated plan into the live representation."""
        if not self._sharded:
            # reps with rebuild semantics (SortedCOO) return a successor
            # instance — rebind so the wrapper always tracks live state
            self.rep, dm = self.rep.apply(plan)
            return dm
        pre = self._dirty_pre(plan) if self.diff else None
        self.rep.apply(plan)  # ShardedGraph mutates in place
        if pre is not None:
            self._dirty_post(pre)
        return 0

    def apply(self, plan: updates.UpdatePlan):
        """WAL-first apply; returns (self, net ΔM)."""
        if plan.n_ops == 0:
            return self, 0
        plan.validate()
        nv_bound = max(self._nv_bound, plan.max_insert_vertex() + 1)
        faultinject.fire("durable.pre_append")
        seq = self.journal.append(plan, nv_bound)
        faultinject.fire("durable.post_append")
        dm = self._rep_apply(plan)
        self.seq = seq
        self._nv_bound = nv_bound
        faultinject.fire("durable.post_apply")
        self._applies_since_ckpt += 1
        if self.checkpoint_every and self._applies_since_ckpt >= self.checkpoint_every:
            self.checkpoint()
        return self, dm

    def apply_group(self, plans):
        """Group-committed apply: one WAL flush for a round's plans.

        Same ordering contract as :meth:`apply` — every plan is durable
        (one ``append_group`` buffer) before the first fused dispatch
        runs.  A crash mid-round therefore re-applies the whole round on
        recovery (at-least-once, idempotent); a crash mid-append tears
        the group's suffix, which was never acknowledged.  Returns
        ``(self, net ΔM)`` summed over the round.
        """
        plans = [p for p in plans if p.n_ops]
        if not plans:
            return self, 0
        bounds, nv = [], self._nv_bound
        for p in plans:
            p.validate()
            nv = max(nv, p.max_insert_vertex() + 1)
            bounds.append(nv)
        faultinject.fire("durable.pre_append")
        seqs = self.journal.append_group(plans, bounds)
        faultinject.fire("durable.post_append")
        total = 0
        for p, seq, b in zip(plans, seqs, bounds):
            total += self._rep_apply(p)
            self.seq = seq
            self._nv_bound = b
            faultinject.fire("durable.post_apply")
        self._applies_since_ckpt += len(plans)
        if self.checkpoint_every and self._applies_since_ckpt >= self.checkpoint_every:
            self.checkpoint()
        return self, total

    # -- checkpoint / recover ------------------------------------------
    def checkpoint(self) -> str:
        """Snapshot the canonical state; prune the WAL behind it.

        With ``diff=True`` this writes a §15 differential step against
        the latest checkpoint — unless no base exists, the chain is
        ``full_every`` long (periodic compaction), or the window holds
        untracked applies (post-recovery replay) — in which case it
        falls back to a full step that re-anchors the chain.
        """
        meta = {
            "__meta__/rep": np.array(self.rep_name),
            "__meta__/wal_seq": np.int64(self.seq),
            "__meta__/nv_bound": np.int64(self._nv_bound),
        }
        if self._sharded:
            shards = {int(s): dict(t) for s, t in self.rep.state_trees().items()}
            shards[0].update(meta)
        else:
            arrays = dict(self.rep.state_tree())
            arrays.update(meta)
            shards = {0: arrays}
        step = max(self.seq, 0)
        want_diff = (
            self.diff
            and not self._force_full
            and ckpt.latest_step(self.ckpt_dir) is not None
            and self._ckpts_since_full < self.full_every - 1
        )
        if want_diff:
            # sharded applies tracked exact dirty blocks; single-device
            # diffs hash-compare chunks against the base (hint = None)
            dirty = self._export_dirty() if self._sharded and self.diff else None
            path = ckpt.save_arrays_diff(
                self.ckpt_dir, step, shards, keep=self.keep, dirty=dirty
            )
            self._ckpts_since_full += 1
        else:
            path = ckpt.save_arrays_sharded(
                self.ckpt_dir, step, shards, keep=self.keep
            )
            self._ckpts_since_full = 0
        self._reset_dirty()
        self.journal.truncate_through(self.seq)
        self._applies_since_ckpt = 0
        return path

    @classmethod
    def recover(
        cls,
        wal_dir: str,
        ckpt_dir: str,
        *,
        checkpoint_every: int = 0,
        keep: int = 3,
        fsync: bool = False,
        segment_bytes: int = 1 << 20,
        audit: bool = True,
        parallel: bool = True,
        mesh=None,
        diff: bool = False,
        full_every: int = 8,
        stats: Optional[dict] = None,
    ) -> "DurableGraph":
        """Newest complete checkpoint + WAL replay = the uncrashed graph.

        1. sweep ``.tmp_ckpt_*`` debris (writers the crash interrupted);
        2. restore the newest complete checkpoint's exact state arrays —
           full, sharded, or a §15 differential chain, resolved
           uniformly through ``restore_arrays_diff``;
        3. repair the WAL tail (the append the crash interrupted) and
           replay every record past the checkpoint's watermark — for a
           sharded graph with ``parallel=True``, owner-routed across
           per-shard threads (:meth:`_replay_parallel`); otherwise
           serially through the ordinary ``apply`` path — each record
           validated against its own vertex watermark;
        4. run the cross-layer invariant audit on the result (the
           per-shard + cross-boundary pass for sharded graphs).

        ``mesh`` re-places recovered shards on devices (None = local
        mode).  ``stats``, if given, receives ``restore_s`` /
        ``replay_s`` / ``records`` for benchmarking.
        """
        import time

        t0 = time.perf_counter()
        ckpt.clean_stale(ckpt_dir)
        trees, _step = ckpt.restore_arrays_diff(ckpt_dir)
        meta_sid = 0 if 0 in trees else min(trees)
        meta = trees[meta_sid]
        name = str(meta.pop("__meta__/rep")[()])
        wal_seq = int(meta.pop("__meta__/wal_seq")[()])
        nv_bound = int(meta.pop("__meta__/nv_bound")[()])
        if name == "sharded":
            from ..core import distributed as dist

            rep = dist.ShardedGraph.from_state_trees(trees, mesh=mesh)
        else:
            rep = REPRESENTATIONS[name].from_state_tree(trees[meta_sid])
        t1 = time.perf_counter()
        g = cls(
            rep, wal_dir, ckpt_dir,
            checkpoint_every=checkpoint_every, keep=keep, fsync=fsync,
            segment_bytes=segment_bytes, diff=diff, full_every=full_every,
            _recovering=True,
        )
        g.seq = wal_seq
        g._nv_bound = max(nv_bound, 1)
        if g._sharded and parallel:
            records = g._replay_parallel(wal_seq)
        else:
            records = 0
            for seq, rec_nv, (qs, qd, qw, ql) in g.journal.replay(after=wal_seq):
                plan = updates.plan_from_canonical(qs, qd, qw, ql)
                plan.validate(num_vertices=int(rec_nv))
                if g._sharded:
                    g.rep.apply(plan)
                else:
                    g.rep, _ = g.rep.apply(plan)
                g.seq = seq
                g._nv_bound = max(g._nv_bound, int(rec_nv))
                records += 1
        t2 = time.perf_counter()
        if audit:
            faultinject.audit(g.rep)
        if stats is not None:
            stats.update(
                restore_s=t1 - t0, replay_s=t2 - t1, records=records
            )
        return g

    def _replay_parallel(self, after: int) -> int:
        """Owner-routed parallel WAL replay over the shard mesh (§15).

        Records are decoded and validated up front, then split into
        epochs at growth records (a growth triggers the global re-shard,
        which must see every earlier record applied and fences every
        later one).  Within an epoch each record is routed by the same
        ``route_updates`` searchsorted the live path uses into per-shard
        FIFO queues; one thread per touched shard drains its queue
        through the shard's committed-device fused patch path.  A shard
        whose flush fails stops queueing immediately (queue depth past
        ``MAX_PENDING`` would silently drop plans) and hands its ordered
        remainder to ONE global ``_rebuild`` — the exact fallback the
        live path takes, so recovered content is identical.
        """
        from concurrent.futures import ThreadPoolExecutor

        from ..core import distributed as dist

        records = []
        for seq, rec_nv, (qs, qd, qw, ql) in self.journal.replay(after=after):
            plan = updates.plan_from_canonical(qs, qd, qw, ql)
            plan.validate(num_vertices=int(rec_nv))
            records.append((seq, int(rec_nv), plan))
        if not records:
            return 0

        def drain(sid, subs):
            img = self.rep.shards[sid]
            for k, sub in enumerate(subs):
                if img._stale:
                    return subs[k:]
                img.queue(sub)
                if not img.flush():
                    return subs[k + 1 :]  # sub itself pends on img
            return []

        with ThreadPoolExecutor(max_workers=self.rep.n_shards) as ex:
            i = 0
            while i < len(records):
                j = i
                while (
                    j < len(records)
                    and records[j][2].max_insert_vertex() < self.rep.n
                ):
                    j += 1
                if j > i:  # fan an epoch of non-growth records out
                    queues: dict = {}
                    for _seq, _nv, plan in records[i:j]:
                        for sid, sub in dist.route_updates(
                            plan, self.rep.n_shards, self.rep.rows_max
                        ):
                            queues.setdefault(sid, []).append(sub)
                    leftovers = list(
                        ex.map(lambda kv: drain(*kv), sorted(queues.items()))
                    )
                    extra = [p for rest in leftovers for p in rest]
                    if extra or any(img._pending for img in self.rep.shards):
                        # _rebuild folds per-image pending queues first,
                        # then extras — global (src, dst) order restored
                        self.rep._rebuild(extra=tuple(extra))
                if j < len(records):  # the growth record fencing the epoch
                    self.rep.apply(records[j][2])
                    j += 1
                i = j
        self.seq = records[-1][0]
        self._nv_bound = max(self._nv_bound, max(nv for _s, nv, _p in records))
        return len(records)

    # -- shard failover: online single-shard rebuild (§17) -------------
    def seal_generation(self, generation: int = 0):
        """Seal the live representation as a read-only walk generation.

        Sharded reps seal per-shard with quarantine masking (§17);
        everything else goes through the ordinary §16 image seal.
        """
        from ..core import walk_image as _wi

        if self._sharded:
            return self.rep.seal_generation(generation)
        return _wi.seal_generation(self.rep, generation)

    def rebuild_shard(self, sid: int, *, stats: Optional[dict] = None) -> int:
        """Rebuild ONE quarantined shard online and reintegrate it (§17).

        restore just this shard's ``shard_{sid}.npz`` diff chain →
        replay its slice of the WAL window (checkpoint step == wal_seq,
        so ``replay(after=step)`` is exactly the window) through the
        shard's fused ``slot_update`` path → replay the quarantine-era
        spool → audit → atomic ``reintegrate``.  The rest of the mesh
        keeps serving throughout — nothing here touches a healthy shard.

        Replay double-applies the records the shard already saw live
        before the fault; the canonical op stream is last-op-wins per
        (src, dst) key, so the double-apply converges bit-identically.
        A growth record in the window means the layout was re-sharded
        globally — single-shard rebuild is unsound then and
        :class:`ShardDownError` directs the caller to a full
        ``recover()``.  Returns the number of WAL records replayed.
        """
        import time

        from ..core import distributed as dist

        if not self._sharded:
            raise TypeError("rebuild_shard: single-device rep has no shards")
        rep = self.rep
        sid = int(sid)
        if sid not in rep.down:
            raise ValueError(f"rebuild_shard: shard {sid} is not quarantined")
        t0 = time.perf_counter()
        arrays, step = ckpt.restore_shard_diff(self.ckpt_dir, sid)
        arrays = {
            k: v for k, v in arrays.items() if not k.startswith("__meta__/")
        }
        meta = arrays["meta"]
        if (
            int(meta[3]) != rep.n
            or int(meta[4]) != rep.rows_max
            or int(meta[5]) != rep.n_shards
        ):
            raise dist.ShardDownError(
                f"rebuild_shard: checkpoint layout (n={int(meta[3])}, "
                f"rows_max={int(meta[4])}, S={int(meta[5])}) predates a "
                f"global re-shard of the live mesh (n={rep.n}, "
                f"rows_max={rep.rows_max}, S={rep.n_shards}) — run a "
                f"full recover()"
            )
        dev = rep._devices()[sid] if rep.mesh is not None else None
        img = dist.image_from_state_tree(arrays, device=dev)
        if img.cap_e != rep.cap_e:
            raise dist.ShardDownError(
                f"rebuild_shard: checkpoint cap_e={img.cap_e} != live "
                f"cap_e={rep.cap_e} — layout re-sharded; run a full recover()"
            )
        t1 = time.perf_counter()
        records = 0
        for _seq, rec_nv, (qs, qd, qw, ql) in self.journal.replay(after=step):
            plan = updates.plan_from_canonical(qs, qd, qw, ql)
            plan.validate(num_vertices=int(rec_nv))
            records += 1
            if plan.max_insert_vertex() >= rep.n:
                raise dist.ShardDownError(
                    "rebuild_shard: growth record in the WAL window — the "
                    "mesh re-sharded globally; run a full recover()"
                )
            for s2, sub in dist.route_updates(plan, rep.n_shards, rep.rows_max):
                if s2 == sid:
                    img = dist.shard_image_apply(rep, sid, img, sub)
        for sub in rep.spooled(sid):
            img = dist.shard_image_apply(rep, sid, img, sub)
        rep.reintegrate(sid, img)
        if self.diff:
            # replay applies were not dirty-tracked: the next differential
            # checkpoint must persist this shard in full
            d = self._dirty.setdefault(sid, _ShardDirty())
            d.full = d.touched = True
            d.rows, d.ranges = [], []
        t2 = time.perf_counter()
        if stats is not None:
            stats.update(
                restore_s=t1 - t0, replay_s=t2 - t1, records=records
            )
        return records

    # -- passthrough conveniences --------------------------------------
    def to_csr(self):
        if self._sharded:
            from ..core import distributed as dist

            return dist.gather_csr(self.rep)
        return self.rep.to_csr()

    def reverse_walk(self, steps: int, *, visits0=None):
        return self.rep.reverse_walk(steps, visits0=visits0)

    def close(self) -> None:
        self.journal.close()
