"""Durable self-healing update pipeline (DESIGN.md §13).

Two halves turn any of the five representations into a crash-recoverable
graph:

* :class:`UpdateJournal` — a write-ahead log of canonical ``UpdatePlan``
  op streams.  Every ``DurableGraph.apply`` appends ONE compact binary
  record (the four canonical arrays + a monotone sequence number + a
  CRC32) *before* the fused dispatch runs, so any applied update is
  reconstructible from disk.  Records pack into size-rotated segment
  files; replay tolerates a torn final record (the crash happened
  mid-append) and refuses everything else (bit rot, mid-log tears).

* :class:`DurableGraph` — wraps a representation with the WAL, periodic
  checkpoints of its full canonical state (``state_tree()`` through
  ``checkpoint.manager.save_arrays``), and :func:`DurableGraph.recover`:
  newest complete checkpoint + WAL replay through the SAME ``apply``
  path the live process used.  Checkpoints capture exact buffers (arena
  geometry included), and every apply is deterministic given its plan,
  so a recovered graph is **bit-identical** to the uncrashed one — not
  merely equivalent.

Failure model: process crash (SIGKILL, OOM-kill) at any instant.  A
record is durable once ``flush()`` hands it to the OS — fsync per append
is available (``fsync=True``) for the power-loss model but off by
default, matching the paper-bench requirement that journaling stay off
the update critical path.  Replay is at-least-once: a crash between the
WAL append and the in-memory apply re-applies the record's plan on
recovery, which is safe because the op stream is idempotent (inserts are
upserts at fixed weights, deletes of absent keys filter out).

Crash points, torn-tail repair, and the post-recovery invariant sweep
are exercised through ``runtime/faultinject.py``.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, Optional

import numpy as np

from ..checkpoint import manager as ckpt
from ..core import REPRESENTATIONS, updates
from . import faultinject

#: Record header: magic "WAL1", sequence number, vertex watermark, op
#: count, CRC32 of the payload bytes.  Little-endian, 24 bytes.
_HEADER = struct.Struct("<IQIII")
_MAGIC = 0x314C4157  # b"WAL1"
#: An n_ops beyond this is implausible for any batch this repo builds —
#: treat it as corruption instead of attempting a huge read.
_MAX_OPS = 1 << 26
#: Bytes per op in the payload: src i4 + dst i4 + wgt f4 (+ packed del bits).
_OP_BYTES = 12


class WalCorruptError(RuntimeError):
    """The journal is damaged beyond the benign torn-tail case."""


def _payload_size(n_ops: int) -> int:
    return n_ops * _OP_BYTES + (n_ops + 7) // 8


def encode_record(seq: int, nv_bound: int, plan: updates.UpdatePlan) -> bytes:
    """One WAL record: header + packed canonical op stream."""
    n = plan.n_ops
    payload = b"".join(
        (
            np.ascontiguousarray(plan.q_src, np.int32).tobytes(),
            np.ascontiguousarray(plan.q_dst, np.int32).tobytes(),
            np.ascontiguousarray(plan.q_wgt, np.float32).tobytes(),
            np.packbits(plan.q_del.astype(bool)).tobytes(),
        )
    )
    head = _HEADER.pack(_MAGIC, seq, int(nv_bound), n, zlib.crc32(payload))
    return head + payload


def decode_record(head: bytes, payload: bytes):
    """Inverse of :func:`encode_record`; raises :class:`WalCorruptError`."""
    magic, seq, nv_bound, n, crc = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise WalCorruptError(f"bad record magic {magic:#x}")
    if n > _MAX_OPS:
        raise WalCorruptError(f"implausible record size: {n} ops")
    if len(payload) != _payload_size(n):
        raise WalCorruptError("record payload size disagrees with header")
    if zlib.crc32(payload) != crc:
        raise WalCorruptError(f"record {seq}: payload CRC mismatch")
    q_src = np.frombuffer(payload[: 4 * n], np.int32)
    q_dst = np.frombuffer(payload[4 * n : 8 * n], np.int32)
    q_wgt = np.frombuffer(payload[8 * n : 12 * n], np.float32)
    q_del = np.unpackbits(
        np.frombuffer(payload[12 * n :], np.uint8), count=n
    ).astype(bool)
    return seq, nv_bound, (q_src, q_dst, q_wgt, q_del)


class UpdateJournal:
    """Segment-rotated write-ahead log of UpdatePlan records.

    Segments are ``wal-{first_seq:012d}.seg`` — the name carries the
    first sequence number the segment holds, so truncation after a
    checkpoint is pure filename arithmetic.  ``repair=True`` (the
    recovery path) truncates a torn record off the FINAL segment's tail;
    a torn record anywhere else, or a complete record that fails its
    CRC, is real corruption and raises :class:`WalCorruptError`.
    """

    def __init__(
        self,
        wal_dir: str,
        *,
        segment_bytes: int = 1 << 20,
        fsync: bool = False,
        repair: bool = False,
    ):
        self.wal_dir = wal_dir
        self.segment_bytes = int(segment_bytes)
        self.fsync = bool(fsync)
        os.makedirs(wal_dir, exist_ok=True)
        self._fh = None
        self._cur_path: Optional[str] = None
        if repair:
            self.repair_tail()
        self.next_seq = self._scan_next_seq()

    # -- segment bookkeeping -------------------------------------------
    def segments(self) -> list[str]:
        names = sorted(
            n for n in os.listdir(self.wal_dir)
            if n.startswith("wal-") and n.endswith(".seg")
        )
        return [os.path.join(self.wal_dir, n) for n in names]

    @staticmethod
    def _first_seq(path: str) -> int:
        return int(os.path.basename(path)[4:-4])

    def _scan_next_seq(self) -> int:
        last = 0
        for seq, _nv, _arrs in self.replay():
            last = seq
        return last + 1

    # -- append side ----------------------------------------------------
    def _open_segment(self, first_seq: int) -> None:
        self._close_fh()
        self._cur_path = os.path.join(
            self.wal_dir, f"wal-{first_seq:012d}.seg"
        )
        self._fh = open(self._cur_path, "ab")

    def _close_fh(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def append(self, plan: updates.UpdatePlan, nv_bound: int) -> int:
        """Write one record; durable (to the OS) before this returns."""
        seq = self.next_seq
        if self._fh is None:
            segs = self.segments()
            if segs and os.path.getsize(segs[-1]) < self.segment_bytes:
                self._cur_path = segs[-1]
                self._fh = open(self._cur_path, "ab")
            else:
                self._open_segment(seq)
        elif self._fh.tell() >= self.segment_bytes:
            self._open_segment(seq)
        self._fh.write(encode_record(seq, nv_bound, plan))
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.next_seq = seq + 1
        return seq

    # -- read side ------------------------------------------------------
    def replay(self, after: int = 0) -> Iterator[tuple]:
        """Yield ``(seq, nv_bound, (q_src, q_dst, q_wgt, q_del))`` in order.

        Sequence numbers must be strictly increasing across the whole
        log; an incomplete record is tolerated only at the very tail of
        the FINAL segment (the append the crash interrupted) — replay
        stops there.  Anything else raises :class:`WalCorruptError`.
        """
        segs = self.segments()
        last_seq = None
        for si, path in enumerate(segs):
            final_seg = si == len(segs) - 1
            with open(path, "rb") as f:
                data = f.read()
            pos, size = 0, len(data)
            while pos < size:
                head = data[pos : pos + _HEADER.size]
                if len(head) < _HEADER.size:
                    if final_seg:
                        return  # torn header at the log tail
                    raise WalCorruptError(f"{path}: torn record mid-log")
                magic, seq, nv_bound, n, _crc = _HEADER.unpack(head)
                if magic != _MAGIC or n > _MAX_OPS:
                    raise WalCorruptError(f"{path}: bad record at offset {pos}")
                body = data[pos + _HEADER.size : pos + _HEADER.size + _payload_size(n)]
                if len(body) < _payload_size(n):
                    if final_seg:
                        return  # torn payload at the log tail
                    raise WalCorruptError(f"{path}: torn record mid-log")
                seq, nv_bound, arrs = decode_record(head, body)
                if last_seq is not None and seq <= last_seq:
                    raise WalCorruptError(
                        f"{path}: sequence {seq} not after {last_seq}"
                    )
                last_seq = seq
                pos += _HEADER.size + _payload_size(n)
                if seq > after:
                    yield seq, nv_bound, arrs

    def repair_tail(self) -> int:
        """Truncate a torn record off the final segment; returns bytes cut.

        Walks complete records to find the last clean boundary, checking
        CRCs along the way — a complete-but-corrupt record is NOT
        repairable and raises (truncating it would silently lose an
        acknowledged update and every record after it).
        """
        segs = self.segments()
        if not segs:
            return 0
        path = segs[-1]
        with open(path, "rb") as f:
            data = f.read()
        pos, size = 0, len(data)
        while pos < size:
            head = data[pos : pos + _HEADER.size]
            if len(head) < _HEADER.size:
                break
            magic, _seq, _nv, n, _crc = _HEADER.unpack(head)
            if magic != _MAGIC or n > _MAX_OPS:
                raise WalCorruptError(f"{path}: bad record at offset {pos}")
            body = data[pos + _HEADER.size : pos + _HEADER.size + _payload_size(n)]
            if len(body) < _payload_size(n):
                break
            decode_record(head, body)  # CRC check; raises on rot
            pos += _HEADER.size + _payload_size(n)
        cut = size - pos
        if cut:
            os.truncate(path, pos)
        return cut

    def truncate_through(self, seq: int) -> int:
        """Drop segments made redundant by a checkpoint at ``seq``.

        A segment is deletable when its SUCCESSOR's first record is
        already covered (first_seq − 1 <= seq): everything the segment
        holds replays to state the checkpoint captured.  The last
        segment always survives — it is the append target.
        """
        segs = self.segments()
        removed = 0
        for i in range(len(segs) - 1):
            if self._first_seq(segs[i + 1]) - 1 <= seq:
                os.remove(segs[i])
                removed += 1
        return removed

    def close(self) -> None:
        self._close_fh()


class DurableGraph:
    """A representation wrapped in WAL-first apply + checkpoint/restore.

    Ordering contract (the injection points bracket it):

        validate → WAL append → fused apply → watermark advance

    so every state the in-memory graph can reach is reconstructible as
    ``checkpoint ⊕ WAL[seq+1:]``.  ``checkpoint_every=k`` snapshots the
    full canonical state every k applies (k=0: manual only); the
    constructor writes a step-0 checkpoint so recovery always has a
    base.
    """

    def __init__(
        self,
        rep,
        wal_dir: str,
        ckpt_dir: str,
        *,
        checkpoint_every: int = 0,
        keep: int = 3,
        fsync: bool = False,
        segment_bytes: int = 1 << 20,
        _recovering: bool = False,
    ):
        self.rep = rep
        self.wal_dir = wal_dir
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = int(checkpoint_every)
        self.keep = int(keep)
        self.journal = UpdateJournal(
            wal_dir, segment_bytes=segment_bytes, fsync=fsync,
            repair=_recovering,
        )
        self.seq = self.journal.next_seq - 1
        self._applies_since_ckpt = 0
        self._nv_bound = max(int(rep.n), 1)
        if not _recovering and ckpt.latest_step(ckpt_dir) is None:
            self.checkpoint()

    @property
    def rep_name(self) -> str:
        cls = type(self.rep)
        for name, c in REPRESENTATIONS.items():
            if c is cls:
                return name
        raise TypeError(f"unregistered representation {cls.__name__}")

    # -- the durable apply path ----------------------------------------
    def apply(self, plan: updates.UpdatePlan):
        """WAL-first apply; returns (self, net ΔM)."""
        if plan.n_ops == 0:
            return self, 0
        plan.validate()
        nv_bound = max(self._nv_bound, plan.max_insert_vertex() + 1)
        faultinject.fire("durable.pre_append")
        seq = self.journal.append(plan, nv_bound)
        faultinject.fire("durable.post_append")
        # reps with rebuild semantics (SortedCOO) return a successor
        # instance — rebind so the wrapper always tracks live state
        self.rep, dm = self.rep.apply(plan)
        self.seq = seq
        self._nv_bound = nv_bound
        faultinject.fire("durable.post_apply")
        self._applies_since_ckpt += 1
        if self.checkpoint_every and self._applies_since_ckpt >= self.checkpoint_every:
            self.checkpoint()
        return self, dm

    # -- checkpoint / recover ------------------------------------------
    def checkpoint(self) -> str:
        """Snapshot the full canonical state; prune the WAL behind it."""
        arrays = dict(self.rep.state_tree())
        arrays["__meta__/rep"] = np.array(self.rep_name)
        arrays["__meta__/wal_seq"] = np.int64(self.seq)
        arrays["__meta__/nv_bound"] = np.int64(self._nv_bound)
        path = ckpt.save_arrays(
            self.ckpt_dir, max(self.seq, 0), arrays, keep=self.keep
        )
        self.journal.truncate_through(self.seq)
        self._applies_since_ckpt = 0
        return path

    @classmethod
    def recover(
        cls,
        wal_dir: str,
        ckpt_dir: str,
        *,
        checkpoint_every: int = 0,
        keep: int = 3,
        fsync: bool = False,
        segment_bytes: int = 1 << 20,
        audit: bool = True,
    ) -> "DurableGraph":
        """Newest complete checkpoint + WAL replay = the uncrashed graph.

        1. sweep ``.tmp_ckpt_*`` debris (writers the crash interrupted);
        2. restore the newest complete checkpoint's exact state arrays;
        3. repair the WAL tail (the append the crash interrupted) and
           replay every record past the checkpoint's watermark through
           the representation's ordinary ``apply`` — validated against
           the record's own vertex watermark;
        4. run the cross-layer invariant audit on the result.
        """
        ckpt.clean_stale(ckpt_dir)
        arrays, _step = ckpt.restore_arrays(ckpt_dir)
        name = str(arrays.pop("__meta__/rep")[()])
        wal_seq = int(arrays.pop("__meta__/wal_seq")[()])
        nv_bound = int(arrays.pop("__meta__/nv_bound")[()])
        rep_cls = REPRESENTATIONS[name]
        rep = rep_cls.from_state_tree(arrays)
        g = cls(
            rep, wal_dir, ckpt_dir,
            checkpoint_every=checkpoint_every, keep=keep, fsync=fsync,
            segment_bytes=segment_bytes, _recovering=True,
        )
        g.seq = wal_seq
        g._nv_bound = max(nv_bound, 1)
        for seq, rec_nv, (qs, qd, qw, ql) in g.journal.replay(after=wal_seq):
            plan = updates.plan_from_canonical(qs, qd, qw, ql)
            plan.validate(num_vertices=int(rec_nv))
            g.rep, _ = g.rep.apply(plan)
            g.seq = seq
            g._nv_bound = max(g._nv_bound, int(rec_nv))
        if audit:
            faultinject.audit(g.rep)
        return g

    # -- passthrough conveniences --------------------------------------
    def to_csr(self):
        return self.rep.to_csr()

    def reverse_walk(self, steps: int, *, visits0=None):
        return self.rep.reverse_walk(steps, visits0=visits0)

    def close(self) -> None:
        self.journal.close()
