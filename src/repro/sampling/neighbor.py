"""GraphSAGE-style fanout neighbor sampler (minibatch_lg shape).

Samples a k-hop neighborhood subgraph around seed nodes from a CSR with
per-hop fanouts (e.g. 15-10).  Fully jit-able: output shapes are static
(seeds × Π fanouts), sampling uses uniform random slot picks with
replacement for high-degree rows and masking for low-degree rows —
the standard padded-TPU formulation of neighbor sampling.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core import util

SENTINEL = util.SENTINEL


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One hop: edges (dst_node_idx -> src_node_idx) in *local* numbering."""

    src_nodes: jnp.ndarray   # [n_src] global ids of source (sampled) nodes
    edge_src: jnp.ndarray    # [n_edges] local index into src_nodes
    edge_dst: jnp.ndarray    # [n_edges] local index into the previous layer
    mask: jnp.ndarray        # [n_edges] valid edge


@functools.partial(jax.jit, static_argnames=("fanout",))
def sample_hop(key, offsets, dst, seeds, seed_mask, fanout: int):
    """Sample ``fanout`` neighbors per seed (with replacement).

    Returns (neigh [S, fanout] global ids, valid [S, fanout]).
    """
    deg = offsets[seeds + 1] - offsets[seeds]
    r = jax.random.uniform(key, (seeds.shape[0], fanout))
    pick = (r * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = offsets[seeds][:, None] + pick
    neigh = dst[jnp.clip(idx, 0, dst.shape[0] - 1)]
    valid = jnp.broadcast_to(
        (deg[:, None] > 0) & seed_mask[:, None], (seeds.shape[0], fanout)
    )
    return jnp.where(valid, neigh, 0), valid


def sample_subgraph(
    key,
    offsets: jnp.ndarray,
    dst: jnp.ndarray,
    seeds: jnp.ndarray,
    fanouts: Sequence[int],
):
    """Multi-hop sampled subgraph, GraphSAGE layout.

    Layer 0 = seeds; layer h = neighbors of layer h-1 (flattened).  Returns
    a list of SampledBlock (outermost hop first, as consumed by a GNN that
    aggregates inward) plus the full node frontier per layer.
    """
    layers = [seeds]
    masks = [jnp.ones_like(seeds, dtype=bool)]
    blocks: list[SampledBlock] = []
    for h, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        cur = layers[-1]
        cur_mask = masks[-1]
        neigh, valid = sample_hop(sub, offsets, dst, cur, cur_mask, int(f))
        n_prev = cur.shape[0]
        edge_dst = jnp.repeat(jnp.arange(n_prev, dtype=jnp.int32), int(f))
        edge_src = jnp.arange(n_prev * int(f), dtype=jnp.int32)
        blocks.append(
            SampledBlock(
                src_nodes=neigh.reshape(-1),
                edge_src=edge_src,
                edge_dst=edge_dst,
                mask=valid.reshape(-1),
            )
        )
        layers.append(neigh.reshape(-1))
        masks.append(valid.reshape(-1))
    return blocks, layers, masks


def flat_sizes(batch_nodes: int, fanouts: Sequence[int]) -> list[int]:
    """Frontier sizes per layer for static shape planning."""
    sizes = [batch_nodes]
    for f in fanouts:
        sizes.append(sizes[-1] * int(f))
    return sizes
